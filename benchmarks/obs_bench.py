"""Observability overhead micro-benchmark (`repro.obs`).

Times the two states that matter for the telemetry contract
(docs/observability.md):

* **disabled** — the default. `metrics.inc` / `metrics.observe` /
  `trace.span` must be a single module-bool check; the pinned
  zero-allocation test (`tests/test_obs.py`) asserts the same path
  allocates nothing, this bench reports what it costs in time.
* **enabled** — the instrumented halo/serve hot paths pay this per event:
  a lock, a dict lookup, and (histograms) a `bisect`.

Rows print through `benchmarks.run` (suite label ``obs``) in the standard
``name,us_per_call,derived`` CSV. Global obs state is saved and restored —
the bench never leaves metrics enabled for later suites.
"""
from __future__ import annotations

import time

from repro.obs import metrics, trace

N_DISABLED = 100_000
N_ENABLED = 20_000


def _per_call_us(fn, n: int) -> float:
    fn()  # warm (first call creates the series)
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def obs_rows():
    rows = []
    was_enabled = metrics.enabled()
    old_reg = metrics.set_default_registry(metrics.MetricsRegistry())
    old_tr = trace.set_default_tracer(None)
    metrics.disable()
    try:
        rows.append(("obs/inc_disabled",
                     _per_call_us(lambda: metrics.inc("bench.c"), N_DISABLED),
                     "no-op fast path"))
        rows.append(("obs/observe_disabled",
                     _per_call_us(lambda: metrics.observe("bench.h", 0.5), N_DISABLED),
                     "no-op fast path"))

        def _null_span():
            with trace.span("bench.s"):
                pass

        rows.append(("obs/span_disabled",
                     _per_call_us(_null_span, N_DISABLED),
                     "reused null context manager"))

        metrics.enable()
        rows.append(("obs/inc_enabled",
                     _per_call_us(lambda: metrics.inc("bench.c"), N_ENABLED),
                     "locked counter add"))
        rows.append(("obs/set_gauge_enabled",
                     _per_call_us(lambda: metrics.set_gauge("bench.g", 1.0), N_ENABLED),
                     "locked gauge set"))
        rows.append(("obs/observe_enabled",
                     _per_call_us(lambda: metrics.observe("bench.h", 0.5), N_ENABLED),
                     "locked bisect into fixed buckets"))

        trace.set_default_tracer(trace.TraceRecorder())

        def _live_span():
            with trace.span("bench.s"):
                pass

        rows.append(("obs/span_enabled",
                     _per_call_us(_live_span, N_ENABLED),
                     "perf_counter_ns edges + event append"))

        reg = metrics.default_registry()
        t0 = time.perf_counter()
        snap = reg.snapshot()
        us = (time.perf_counter() - t0) * 1e6
        rows.append(("obs/snapshot", us, f"series={len(snap)}"))

        tr = trace.default_tracer()
        t0 = time.perf_counter()
        chrome = tr.to_chrome()
        us = (time.perf_counter() - t0) * 1e6
        rows.append(("obs/to_chrome", us, f"events={len(chrome['traceEvents'])}"))
    finally:
        metrics.disable()
        metrics.set_default_registry(old_reg)
        trace.set_default_tracer(old_tr)
        if was_enabled:
            metrics.enable(old_reg)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in obs_rows():
        print(f"{name},{us:.3f},{derived}")
