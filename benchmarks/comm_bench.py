"""Communication benchmark: the overlapped/quantized halo wire trajectory.

Builds the PINNED 2-pod × 4-device exchange case (the same 2000-node /
12000-edge BFS+refined citation graph as `docs/communication.md` §5–§6 and
`tests/test_hier_halo.py`) and records, per payload format, what the halo
exchange moves and what the critical path actually waits on:

* total vs **exposed** exchange bytes (`ExchangeCost`: exposed =
  wire × (1 − overlap_fraction), the share the interior/boundary-split
  overlapped schedule cannot hide),
* the plan's `overlap_fraction` (interior-edge share),
* quantized wire bytes per payload (fp32 / bf16 / int8 — bits/32 scaling),
* the hierarchical per-tier split (inter-pod crossing vs intra-pod relay).

`write_comm_bench` persists BENCH_comm.json and **asserts the acceptance
gate**: the bf16 payload at least halves the boundary wire bytes of the
fp32 baseline on this pinned case. CI uploads the file as an artifact so
the numbers version with the code (`benchmarks.run` prints the same rows).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.dataflow import exchange_cost
from repro.core.partition import partition_graph
from repro.core.quant import PAYLOAD_BITS, payload_bits
from repro.dist.halo import build_halo_plan
from repro.graph.generators import citation_like

# The pinned case (docs/communication.md §5): k=8 as 2 pods × 4, d=64.
PINNED = dict(n=2000, e=12000, seed=1, k=8, pods=2, d=64)
PAYLOADS = ("fp32", "bf16", "int8")


def _plans(cfg=PINNED):
    g = citation_like(cfg["n"], cfg["e"], seed=cfg["seed"])
    part = partition_graph(
        cfg["n"], g.edge_index, cfg["k"], method="bfs", seed=0, refine=True
    )
    flat = build_halo_plan(part, g.edge_index)
    hier = build_halo_plan(
        part, g.edge_index, axes=("pod", "model"), pods=cfg["pods"]
    )
    return flat, hier


def comm_bench_record(cfg=PINNED) -> dict:
    """The BENCH_comm.json record (host-side plan accounting, no devices)."""
    flat, hier = _plans(cfg)
    d = cfg["d"]
    ov = flat.overlap_fraction()
    rec: dict = {
        "case": dict(cfg),
        "n_local": int(flat.n_local),
        "s_max": int(flat.s_max),
        "s_loc": int(hier.s_loc),
        "s_rem": int(hier.s_rem),
        "overlap_fraction": float(ov),
        "interior_edges": int(flat.interior_edges),
        "boundary_edges": int(flat.boundary_edges),
        "boundary_rows_max_device": int(flat.boundary_rows_per_device().max()),
        "payloads": {},
    }
    k_model = cfg["k"] // cfg["pods"]
    inter_rows = cfg["pods"] * hier.s_rem
    intra_rows = k_model * (hier.s_loc + cfg["pods"] * hier.s_rem)
    for payload in PAYLOADS:
        bits = payload_bits(payload)
        ec = exchange_cost(flat.halo_rows_per_device, d, bits, ov)
        rec["payloads"][payload] = {
            "bits": bits,
            "wire_bytes_per_device_layer": ec.wire_bytes,
            "exposed_bytes_per_device_layer": ec.exposed_bytes,
            "compression_vs_fp32": ec.compression,
            "hier_inter_pod_bytes": inter_rows * d * bits / 8.0,
            "hier_intra_pod_bytes": intra_rows * d * bits / 8.0,
            "hier_crossing_bytes": (cfg["pods"] - 1) * hier.s_rem * d * bits / 8.0,
        }
    return rec


def write_comm_bench(path: str = "BENCH_comm.json", cfg=PINNED) -> dict:
    rec = comm_bench_record(cfg)
    fp32 = rec["payloads"]["fp32"]
    bf16 = rec["payloads"]["bf16"]
    # The acceptance gate: bf16 at least halves the boundary wire bytes.
    assert bf16["wire_bytes_per_device_layer"] * 2 <= fp32["wire_bytes_per_device_layer"], (
        "bf16 payload must at least halve the fp32 boundary wire bytes",
        bf16["wire_bytes_per_device_layer"],
        fp32["wire_bytes_per_device_layer"],
    )
    assert bf16["hier_crossing_bytes"] * 2 <= fp32["hier_crossing_bytes"]
    # Overlap must expose strictly less than it ships (real interior work).
    assert 0.0 < rec["overlap_fraction"] < 1.0
    for p in rec["payloads"].values():
        assert p["exposed_bytes_per_device_layer"] < p["wire_bytes_per_device_layer"]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def comm_rows():
    """`benchmarks.run` suite: persist BENCH_comm.json + print per-payload
    wire/exposed bytes for the pinned 2×4 case."""
    rec = write_comm_bench()
    rows = []
    for payload, p in rec["payloads"].items():
        rows.append((
            f"comm/halo_wire_{payload}",
            0.0,
            f"wire_B={p['wire_bytes_per_device_layer']:.0f} "
            f"exposed_B={p['exposed_bytes_per_device_layer']:.0f} "
            f"overlap={rec['overlap_fraction']:.3f} "
            f"compression={p['compression_vs_fp32']:.1f}x "
            f"inter_pod_crossing_B={p['hier_crossing_bytes']:.0f}",
        ))
    return rows


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_comm.json")
    args = ap.parse_args(argv)
    rec = write_comm_bench(args.out)
    print(json.dumps(rec, indent=1))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
