"""Online GCN serving benchmark: hot-neighbor cache on vs off (DESIGN.md §9).

Serves an identical degree-weighted (hub-heavy) query stream through two
`repro.serve.graph.GraphBatcher` engines — cache enabled and disabled — and
reports p50/p99 per-query latency, per-query sampled nodes/edges, the cache
hit-rate/bytes-saved accounting, and the max logit divergence between the two
engines (the §9 exactness contract: it must sit at fp32 noise). A third row
compares partition-aligned vs FIFO packing by foreign (would-be halo) rows.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_arch
from repro.launch.serve import build_graph_engine
from repro.serve.graph import hot_query_stream

N_QUERIES = 96
WARM_FRACTION = 0.5           # first half warms the cache, second half is hot


def _serve(engine, nodes) -> float:
    t0 = time.perf_counter()
    for v in nodes:
        engine.submit(int(v))
    engine.run_until_drained()
    return time.perf_counter() - t0


def serve_rows(n_queries: int = N_QUERIES):
    spec = get_arch("coin_gcn")
    rows = []
    engines = {}
    for label, cap in (("cache_off", 0), ("cache_on", 256)):
        engine, graph = build_graph_engine(spec, cache_capacity=cap, n_parts=4, seed=0)
        nodes = hot_query_stream(graph, n_queries)
        # Warm pass (compile + cache fill) is excluded from the timed stats.
        _serve(engine, nodes[: int(len(nodes) * WARM_FRACTION)])
        n_warm = len(engine.finished)
        dt = _serve(engine, nodes)
        s = engine.stats()
        lat = sorted(q.latency_s for q in engine.finished[n_warm:])
        p50 = lat[len(lat) // 2] * 1e3
        p99 = lat[min(int(0.99 * len(lat)), len(lat) - 1)] * 1e3
        derived = (
            f"p50_ms={p50:.2f} p99_ms={p99:.2f} "
            f"nodes/q={s['nodes_per_query']:.1f} edges/q={s['edges_per_query']:.1f} "
            f"traces={s['traces']}"
        )
        if "cache" in s:
            c = s["cache"]
            derived += (
                f" hit_rate={c['hit_rate']:.2f} rows_saved={c['rows_saved']}"
                f" bytes_saved={c['bytes_saved']:.0f}"
            )
        rows.append((f"serve/gcn_{label}", dt / max(len(lat), 1) * 1e6, derived))
        engines[label] = engine
    # Exactness: both engines saw the same stream → identical logits.
    a = {q.qid: q.logits for q in engines["cache_off"].finished}
    b = {q.qid: q.logits for q in engines["cache_on"].finished}
    err = max(float(np.abs(a[k] - b[k]).max()) for k in a)
    saved = (
        engines["cache_off"].nodes_sampled + engines["cache_off"].edges_sampled
        - engines["cache_on"].nodes_sampled - engines["cache_on"].edges_sampled
    )
    rows.append(("serve/gcn_cache_vs_off", 0.0,
                 f"logit_err={err:.1e} sampled_rows_cut={saved}"))
    # Partition-aligned vs FIFO packing: foreign rows per micro-batch.
    fifo, _ = build_graph_engine(spec, cache_capacity=0, n_parts=0, seed=0)
    aligned, graph = build_graph_engine(spec, cache_capacity=0, n_parts=4, seed=0)
    nodes = hot_query_stream(graph, n_queries)
    for eng in (fifo, aligned):
        _serve(eng, nodes)
    part = aligned.partition

    def foreign_seeds(engine) -> int:
        """Seeds outside their micro-batch's majority part (the queries whose
        subgraphs a per-part deployment would fetch across devices)."""
        by_batch: dict[int, list[int]] = {}
        for q in engine.finished:
            by_batch.setdefault(q.micro_batch, []).append(q.node)
        out = 0
        for batch_nodes in by_batch.values():
            parts = part.assignment[np.asarray(batch_nodes)]
            out += int((parts != np.bincount(parts).argmax()).sum())
        return out

    rows.append((
        "serve/packing_partition_aligned", 0.0,
        f"foreign_seeds_fifo={foreign_seeds(fifo)} "
        f"foreign_seeds_aligned={foreign_seeds(aligned)} "
        f"foreign_block_rows_aligned={aligned.foreign_rows} "
        f"batches={aligned.micro_batches}",
    ))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in serve_rows():
        print(f"{name},{us:.1f},{derived}")
