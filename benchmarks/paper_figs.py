"""Paper figures/tables driven by the calibrated NoC + energy models:
Fig. 1 (baseline comm energy), Fig. 9 (mesh sweep), Fig. 10/11 (energy vs
baseline), Fig. 12 (c-mesh), Fig. 13/14 (EDP), Table III (comm fraction)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    A2_BITS,
    baseline_energy,
    calibrated_noc,
    coin_energy,
    dataset_partition,
    timed,
)
from repro.core.energy import CoinEnergyModel
from repro.core.partition import measured_probabilities
from repro.core.solver import SQUARE_MESHES
from repro.graph.generators import TABLE_I

DATASETS = list(TABLE_I)


def fig01_baseline_comm():
    """Fig. 1: baseline (CE-per-node) comm energy grows with node count;
    derived column = J; also reports hop-weighted TB for Nell (§I's 2.7 TB)."""
    rows = []
    for name in DATASETS:
        s, us = timed(baseline_energy, name, repeat=1)
        rows.append((f"fig01/{name}", us, f"comm_J={s.comm_j:.4g}"))
    nell = baseline_energy("nell")
    rows.append(
        ("fig01/nell_hop_TB", 0.0, f"hopTB={nell.summary.hop_bits / 8 / 1e12:.2f} (paper: 2.7)")
    )
    energies = [baseline_energy(n).comm_j for n in DATASETS]
    nodes = [TABLE_I[n].n_nodes for n in DATASETS]
    mono = all(
        e2 > e1 for (n1, e1), (n2, e2) in zip(
            sorted(zip(nodes, energies)), sorted(zip(nodes, energies))[1:]
        )
    )
    rows.append(("fig01/monotone_in_nodes", 0.0, f"monotone={mono}"))
    return rows


def fig09_mesh_sweep():
    """Fig. 9: comm energy vs NoC size 3×3..10×10 per dataset (both the
    analytic Eq.3 with measured p and the trace-driven NoC model)."""
    rows = []
    for name in DATASETS:
        # analytic with measured probabilities at k=16
        part = dataset_partition(name, 16)
        p1, p2 = measured_probabilities(part)
        model = CoinEnergyModel(
            TABLE_I[name].n_nodes, A2_BITS,
            p_intra=float(p1.mean()),
            p_inter=float(p2.sum() / (16 * 15)),
        )
        analytic = {k: float(model.total(float(k))) for k in SQUARE_MESHES}
        best_a = min(analytic, key=analytic.get)
        # trace-driven
        noc_e = {}
        for k in SQUARE_MESHES:
            part_k = dataset_partition(name, k)
            noc = calibrated_noc(k)
            inter = part_k.inter_ce_traffic_bits(A2_BITS, broadcast=True)
            e, _ = noc.energy_for_traffic(inter)
            e += noc.intra_ce_energy(part_k.intra_ce_traffic_bits(A2_BITS), part_k.n_nodes / k)
            noc_e[k] = e
        best_t = min(noc_e, key=noc_e.get)
        rows.append(
            (f"fig09/{name}", 0.0,
             f"best_mesh_analytic={int(np.sqrt(best_a))}x{int(np.sqrt(best_a))}"
             f" best_mesh_noc={int(np.sqrt(best_t))}x{int(np.sqrt(best_t))}"
             f" e16={noc_e[16]:.3g}J e100={noc_e[100]:.3g}J")
        )
    return rows


def fig10_11_energy_vs_baseline():
    """Fig. 10 (total) and Fig. 11 (comm) energy: baseline vs COIN."""
    rows = []
    for name in DATASETS:
        b = baseline_energy(name)
        c = coin_energy(name)
        rows.append(
            (f"fig10/{name}", 0.0,
             f"baseline_J={b.total_j:.4g} coin_J={c.total_j:.4g} impr={b.total_j / c.total_j:.3g}x")
        )
        rows.append(
            (f"fig11/{name}", 0.0,
             f"baseline_comm_J={b.comm_j:.4g} coin_comm_J={c.comm_j:.4g} "
             f"impr={b.comm_j / c.comm_j:.3g}x")
        )
    return rows


def fig12_cmesh():
    """Fig. 12: COIN mesh vs c-mesh inter-CE communication energy."""
    rows = []
    for name in DATASETS:
        mesh_e = coin_energy(name, cmesh=False)
        cmesh_e = coin_energy(name, cmesh=True)
        rows.append(
            (f"fig12/{name}", 0.0,
             f"cmesh/mesh={cmesh_e.comm_j / mesh_e.comm_j:.3f}x (paper: ≥1, Nell 1.3x)")
        )
    return rows


def fig13_edp():
    """Fig. 13/14: communication EDP, baseline vs COIN vs c-mesh."""
    rows = []
    for name in DATASETS:
        b, c = baseline_energy(name), coin_energy(name)
        edp_b = b.comm_j * b.summary.latency_s
        edp_c = c.comm_j * c.summary.latency_s
        cm = coin_energy(name, cmesh=True)
        edp_cm = cm.comm_j * cm.summary.latency_s
        rows.append(
            (f"fig13/{name}", 0.0,
             f"edp_baseline={edp_b:.4g} edp_coin={edp_c:.4g} "
             f"impr={edp_b / max(edp_c, 1e-30):.3g}x coin_vs_cmesh={edp_cm / max(edp_c, 1e-30):.2f}x")
        )
    return rows


def tbl3_comm_fraction():
    """Table III: communication energy as % of total, baseline vs COIN."""
    paper = {"cora": (43, 4.7), "citeseer": (44, 5.3), "pubmed": (96, 0.007),
             "extcora": (58, 0.003), "nell": (99, 0.0006)}
    rows = []
    for name in DATASETS:
        b, c = baseline_energy(name), coin_energy(name)
        pb, pc = paper[name]
        rows.append(
            (f"tbl3/{name}", 0.0,
             f"baseline%={b.comm_pct:.1f} (paper {pb}) coin%={c.comm_pct:.4g} (paper {pc})")
        )
    return rows


def comm_tier_rows():
    """Comm-tier accounting (docs/communication.md §5): per-device rows the
    hierarchical (pod, model) halo schedule moves on each tier vs the flat
    single-axis plan, on the pinned 2000-node/12000-edge BFS+refined case
    (2 pods × 4 devices). Derived column reports intra/inter rows and the
    inter-pod crossing cut — the acceptance inequality made a benchmark."""
    from repro.core.partition import partition_graph
    from repro.dist.halo import build_halo_plan
    from repro.graph.generators import citation_like

    g = citation_like(2000, 12000, seed=1)
    part = partition_graph(2000, g.edge_index, 8, method="bfs", seed=0, refine=True)
    (flat, hier), us = timed(
        lambda: (
            build_halo_plan(part, g.edge_index),
            build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=2),
        ),
        repeat=1,
    )
    cut = hier.flat_inter_pod_rows_crossing / max(hier.inter_pod_rows_crossing, 1)
    return [
        (
            "comm-tier/2x4", us,
            f"flat_rows={flat.halo_rows_per_device} "
            f"hier_intra={hier.intra_pod_rows_per_device} "
            f"hier_inter={hier.inter_pod_rows_per_device} "
            f"crossing_flat={hier.flat_inter_pod_rows_crossing} "
            f"crossing_hier={hier.inter_pod_rows_crossing} cut={cut:.1f}x",
        )
    ]


def halo_vs_broadcast():
    """Beyond-paper: halo exchange vs the paper's broadcast dataflow."""
    rows = []
    for name in DATASETS:
        bc = coin_energy(name, broadcast=True)
        halo = coin_energy(name, broadcast=False)
        rows.append(
            (f"halo/{name}", 0.0,
             f"broadcast_comm_J={bc.comm_j:.4g} halo_comm_J={halo.comm_j:.4g} "
             f"saving={bc.comm_j / max(halo.comm_j, 1e-30):.2f}x")
        )
    return rows
