"""Fig. 7: accuracy vs quantization bits (2–32) for the paper's GCN.

Real training on the exact-statistics synthetic datasets (labels synthetic →
we reproduce the TREND: monotone-ish accuracy vs bits, 4-bit ≈ fp32 within a
few points), with QAT fake-quant on weights AND activations as in §V-B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig
from repro.graph.generators import make_dataset
from repro.graph.structure import to_padded
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init, gcn_loss
from repro.train.optimizer import adam

BITS = (2, 4, 8, 32)


def _train_gcn(dataset: str, bits: int, epochs: int = 120, seed: int = 0) -> float:
    spec, g = make_dataset(dataset, seed=seed)
    gs = g.symmetrized().with_self_loops()
    pg = to_padded(gs, weights=gs.sym_normalized_weights())
    cfg = GCNConfig(
        layer_dims=(spec.n_features, spec.hidden, spec.n_labels),
        quant=QuantConfig(bits, bits, enabled=bits < 32),
    )
    params = gcn_init(jax.random.PRNGKey(seed), cfg)
    feats = jnp.asarray(g.features, jnp.float32)
    labels = jnp.asarray(g.labels)
    n = spec.n_nodes
    train_mask = (jnp.arange(n) % 4 != 0).astype(jnp.float32)   # 75/25 split
    opt = adam(1e-2)
    state = opt.init(params)

    @jax.jit
    def step(params, state, feats):
        # feats passed as an argument (a closure constant would get
        # constant-folded through the quant top_k at compile time).
        loss, grads = jax.value_and_grad(gcn_loss)(
            params, feats, pg.senders, pg.receivers, pg.edge_weight, labels, train_mask, cfg
        )
        return *opt.update(grads, state, params), loss

    for _ in range(epochs):
        params, state, _ = step(params, state, feats)
    logits = gcn_forward(params, feats, pg.senders, pg.receivers, pg.edge_weight, cfg)
    test = 1.0 - train_mask
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return float((correct * test).sum() / test.sum())


def fig07_quant_accuracy(datasets=("cora", "citeseer"), epochs: int = 120):
    rows = []
    for ds in datasets:
        accs = {b: _train_gcn(ds, b, epochs) for b in BITS}
        trend_ok = accs[4] >= accs[32] - 0.05 and accs[2] <= accs[32] + 0.02
        rows.append(
            (f"fig07/{ds}", 0.0,
             " ".join(f"acc@{b}b={accs[b]:.3f}" for b in BITS)
             + f" 4bit≈fp32={trend_ok} (paper: 4-bit within a few points of 32-bit)")
        )
    return rows
