"""Delta-replan benchmark: incremental halo repair vs full plan rebuild.

Builds the PINNED 16384-node / 65536-edge power-law citation graph
(`repro.graph.generators.citation_like`, seed 1) BFS+refine-partitioned over
8 devices, materializes the flat AND the hierarchical (2-pod) `HaloPlan`
plus their memoized blocked adjacencies (`plan_blocked_adjacency` and the
interior/boundary `plan_split_blocked_adjacency` pair, block=128) through
one `repro.dist.delta.DeltaPlanner`, then times 1%-of-edges `GraphDelta`
batches (half deletes drawn from live edges, half uniform inserts):

* **rebuild** — `build_halo_plan` + re-blocking from scratch on the
  post-delta edge list, flat + hierarchical (what a mutation cost before
  this subsystem), vs
* **delta**  — ONE `DeltaPlanner.apply` repairing both cached plans AND all
  six blocked tables in place (dirty-segment export refresh, scoped sender
  remap, touched-tile recompute — no re-blocking).

The timed deltas are STEADY-STATE applies: untimed warmup deltas run first
until an apply comes back fully clean (no pad growth, all six blocked
tables patched in place), and any timed apply that happens to land on a
geometric growth event (uniform inserts keep enlarging the boundary, so
pads re-double every O(pad) mutations) is excluded and the tables
re-materialized. That matches the amortized cost in a long mutation
stream — pads and tile tables never shrink and at least double on growth,
so growth events thin out geometrically while every common-case apply pays
only the incremental repair. The record reports how many timed applies
were structural so the exclusion is visible in the JSON.

`write_delta_bench` persists BENCH_delta.json and **asserts the acceptance
gate**: the incremental path is at least 5× faster than the rebuild on this
pinned case. Correctness is NOT re-proven here — that is the job of the
differential harness in tests/test_graph_delta.py (tests/_delta_oracle.py);
the bench only spot-checks edge conservation and that the timed applies
really took the patch path (nothing dropped, no growth). CI uploads the
JSON as an artifact so the numbers version with the code (`benchmarks.run`
prints the same rows).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.partition import partition_graph
from repro.dist.delta import DeltaPlanner, GraphDelta
from repro.dist.halo import (
    build_halo_plan,
    plan_blocked_adjacency,
    plan_split_blocked_adjacency,
)
from repro.graph.generators import citation_like

# The pinned case: the ISSUE acceptance graph — 16384 nodes, 65536 edges,
# k=8 (2 pods x 4), a 1%-of-edges mutation batch, 128-square tiles.
PINNED = dict(n=16384, e=65536, seed=1, k=8, pods=2, delta_frac=0.01, block=128)
SPEEDUP_GATE = 5.0


def _pinned_graph(cfg=PINNED):
    g = citation_like(cfg["n"], cfg["e"], seed=cfg["seed"])
    ei = g.edge_index.astype(np.int64)
    w = (0.1 + np.random.default_rng(cfg["seed"]).random(ei.shape[1])).astype(
        np.float32)
    part = partition_graph(
        cfg["n"], ei, cfg["k"], method="bfs", seed=0, refine=True)
    return part, ei, w


def _mutation(rng, ei_now, w_now, n: int, frac: float):
    """One 1%-of-current-edges batch + the post-delta edge list/weights."""
    ops = max(2, int(round(ei_now.shape[1] * frac)))
    n_del = ops // 2
    n_ins = ops - n_del
    drop = rng.choice(ei_now.shape[1], n_del, replace=False)
    ins = rng.integers(0, n, (2, n_ins))
    delta = GraphDelta(
        edge_inserts=ins,
        edge_deletes=ei_now[:, drop],
        insert_w=(0.1 + rng.random(n_ins)).astype(np.float32),
    )
    keep = np.ones(ei_now.shape[1], bool)
    keep[drop] = False
    ei2 = np.concatenate([ei_now[:, keep], ins], axis=1)
    w2 = np.concatenate([w_now[keep], delta.insert_w])
    return delta, ei2, w2


def _materialize(plan, block: int) -> None:
    plan_blocked_adjacency(plan, block=block)
    plan_split_blocked_adjacency(plan, block=block)


def delta_bench_record(cfg=PINNED, repeats: int = 3) -> dict:
    """The BENCH_delta.json record (host-side planning only, no devices)."""
    part, ei, w = _pinned_graph(cfg)
    axes, pods, block = ("pod", "model"), cfg["pods"], cfg["block"]
    rng = np.random.default_rng(2)

    # Reach the steady state (untimed): cached plans + blocked tables, pads
    # and tile capacity already grown. Warm up until one apply comes back
    # fully clean — all six tables (2x combined + 2x interior/boundary
    # pair) patched in place, no pad growth, nothing dropped back to cold.
    pl = DeltaPlanner(part, ei, w)
    flat = pl.plan()
    hier = pl.plan(axes=axes, pods=pods)
    ei_now, w_now = ei, w
    _materialize(flat, block)
    _materialize(hier, block)

    def _clean(rep: dict) -> bool:
        return (rep["blocked_dropped"] == 0 and rep["blocked_patched"] == 6
                and rep["blocked_grown"] == 0)

    def _step():
        nonlocal ei_now, w_now
        d, ei_now, w_now = _mutation(
            rng, ei_now, w_now, cfg["n"], cfg["delta_frac"])
        t0 = time.perf_counter()
        rep = pl.apply(d)
        dt = time.perf_counter() - t0
        assert pl.n_edges == ei_now.shape[1], "delta lost or invented edges"
        if rep["blocked_dropped"] > 0:     # growth dropped some tables:
            _materialize(flat, block)      # restore the steady state
            _materialize(hier, block)
        return d, rep, dt

    for _ in range(16):
        _, rep, _ = _step()
        if _clean(rep):
            break
    else:
        raise AssertionError("no steady-state apply within 16 warmup deltas")

    delta_s = np.inf
    report: dict = {}
    ops = {"deletes": 0, "inserts": 0}
    structural = 0
    measured = 0
    while measured < repeats:
        d, rep, dt = _step()
        if not _clean(rep):                # growth event: amortized out, see
            structural += 1                # the module docstring
            assert structural <= 16, "mutation stream never settles"
            continue
        measured += 1
        report = rep
        ops = {"deletes": int(d.edge_deletes.shape[1]),
               "inserts": int(d.edge_inserts.shape[1])}
        delta_s = min(delta_s, dt)

    # The rebuild arm replans + re-blocks the FINAL edge list from scratch —
    # the cost a mutation used to pay per batch before the delta path.
    rebuild_s = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        f2 = build_halo_plan(part, ei_now, w_now)
        h2 = build_halo_plan(part, ei_now, w_now, axes=axes, pods=pods)
        _materialize(f2, block)
        _materialize(h2, block)
        rebuild_s = min(rebuild_s, time.perf_counter() - t0)

    return {
        "case": dict(cfg),
        "delta_ops": ops,
        "rebuild_ms": rebuild_s * 1e3,
        "delta_ms": delta_s * 1e3,
        "speedup": rebuild_s / delta_s,
        "dirty_devices": report.get("dirty_devices"),
        "senders_remapped": report.get("senders_remapped"),
        "blocked_patched": report.get("blocked_patched"),
        "structural_applies_excluded": structural,
    }


def write_delta_bench(path: str = "BENCH_delta.json", cfg=PINNED) -> dict:
    rec = delta_bench_record(cfg)
    # The acceptance gate: incremental repair beats the rebuild >= 5x on a
    # 1% delta (both plan flavors + all blocked tables repaired by the
    # single apply).
    assert rec["speedup"] >= SPEEDUP_GATE, (
        "delta replan lost its edge over the full rebuild",
        rec["speedup"], rec["rebuild_ms"], rec["delta_ms"],
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def delta_rows():
    """`benchmarks.run` suite: persist BENCH_delta.json + print the replan
    trajectory for the pinned 16384-node 1%-mutation case."""
    rec = write_delta_bench()
    return [(
        "delta/replan_vs_rebuild",
        rec["delta_ms"] * 1e3,
        f"rebuild_ms={rec['rebuild_ms']:.1f} delta_ms={rec['delta_ms']:.2f} "
        f"speedup={rec['speedup']:.1f}x "
        f"dirty_devices={rec['dirty_devices']} "
        f"remapped={rec['senders_remapped']}",
    )]


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_delta.json")
    args = ap.parse_args(argv)
    rec = write_delta_bench(args.out)
    print(json.dumps(rec, indent=1))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
