"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (harness contract). Fast by default;
``--full`` adds the slower quantization sweep over more datasets and the
roofline rows for the multi-pod mesh.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter on benchmark name")
    args = ap.parse_args(argv)

    from benchmarks.autotune_bench import autotune_rows
    from benchmarks.comm_bench import comm_rows
    from benchmarks.delta_bench import delta_rows
    from benchmarks.obs_bench import obs_rows
    from benchmarks.relocal_bench import relocal_rows
    from benchmarks.fig07_quant import fig07_quant_accuracy
    from benchmarks.kernel_bench import bench_kernels_rows, kernel_rows, spmm_compare_rows
    from benchmarks.serve_bench import serve_rows
    from benchmarks.paper_figs import (
        comm_tier_rows,
        fig01_baseline_comm,
        fig09_mesh_sweep,
        fig10_11_energy_vs_baseline,
        fig12_cmesh,
        fig13_edp,
        halo_vs_broadcast,
        tbl3_comm_fraction,
    )
    from benchmarks.paper_tables import (
        tbl_accel_compare,
        tbl_chips,
        tbl_dataflow,
        tbl_optimal_k,
    )
    from benchmarks.roofline import roofline_rows

    suites = [
        ("fig01", fig01_baseline_comm),
        ("optk", tbl_optimal_k),
        ("dataflow", tbl_dataflow),
        ("fig09", fig09_mesh_sweep),
        ("fig10/11", fig10_11_energy_vs_baseline),
        ("fig12", fig12_cmesh),
        ("fig13", fig13_edp),
        ("tbl3", tbl3_comm_fraction),
        ("halo", halo_vs_broadcast),
        ("comm-tier", comm_tier_rows),
        ("comm", comm_rows),
        ("delta", delta_rows),
        ("relocal", relocal_rows),
        ("autotune", autotune_rows),
        ("chips", tbl_chips),
        ("tbl4/6/7", tbl_accel_compare),
        ("kernels", kernel_rows),
        ("kernels-ragged", bench_kernels_rows),
        ("spmm", lambda: spmm_compare_rows(full=args.full)),
        ("serve", serve_rows),
        ("obs", obs_rows),
        ("fig07", lambda: fig07_quant_accuracy(
            datasets=("cora", "citeseer", "pubmed") if args.full else ("cora",),
            epochs=120,
        )),
        ("roofline-16x16", lambda: roofline_rows("16x16")),
    ]
    if args.full:
        suites.append(("roofline-2x16x16", lambda: roofline_rows("2x16x16")))

    print("name,us_per_call,derived")
    failures = 0
    for label, fn in suites:
        if args.only and args.only not in label:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except FileNotFoundError as e:
            print(f"{label},0.0,SKIPPED({e})")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{label},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
