"""§Roofline: render the per-(arch × shape × mesh) roofline table from the
dry-run sweep (results/dryrun.json) and emit the markdown EXPERIMENTS.md
consumes. Terms per the assignment:

    compute    = HLO_FLOPs_per_device / 197e12
    memory     = HLO_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")


def load(path: str = RESULTS) -> list[dict]:
    """Accepts both dry-run results schemas: the v1 bare record list and
    the v2 ``{"schema": 2, "records": [...]}`` wrapper
    (`repro.launch.dryrun.load_results` is the canonical loader; this stays
    import-light so the bench never pins the 512-device XLA flag)."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):
        return list(data.get("records", []))
    return list(data)


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("µs", 1e-6), ("ns", 1e-9)):
        if x >= scale:
            return f"{x / scale:.3g}{unit}"
    return f"{x:.2e}s"


def roofline_rows(mesh: str = "16x16", path: str = RESULTS):
    rows = []
    for r in load(path):
        if r["mesh"] != mesh:
            continue
        name = f"roofline/{r['arch']}×{r['shape']}"
        if r["status"] == "SKIP":
            rows.append((name, 0.0, f"SKIP({r['reason'][:60]})"))
            continue
        if r["status"] != "OK":
            rows.append((name, 0.0, f"FAIL({r.get('error', '')[:60]})"))
            continue
        rf = r["roofline"]
        ur = r.get("useful_flops_ratio")
        rows.append(
            (name, 0.0,
             f"compute={fmt_s(rf['compute_s'])} memory={fmt_s(rf['memory_s'])} "
             f"collective={fmt_s(rf['collective_s'])} dominant={rf['dominant']} "
             f"useful_flops_ratio={ur:.3g}" if ur else
             f"compute={fmt_s(rf['compute_s'])} memory={fmt_s(rf['memory_s'])} "
             f"collective={fmt_s(rf['collective_s'])} dominant={rf['dominant']}")
        )
    return rows


def markdown_table(mesh: str = "16x16", path: str = RESULTS) -> str:
    lines = [
        f"| arch | shape | kind | compute | memory | collective | dominant | useful-FLOPs ratio | peak bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(load(path), key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "SKIP":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP | — | — |")
            continue
        if r["status"] != "OK":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | FAIL | — | — |")
            continue
        rf = r["roofline"]
        ur = r.get("useful_flops_ratio")
        peak = (r.get("memory") or {}).get("peak_bytes")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {fmt_s(rf['compute_s'])} "
            f"| {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | {rf['dominant']} "
            f"| {f'{ur:.3g}' if ur else '—'} | {f'{peak/1e9:.2f} GB' if peak else '—'} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
