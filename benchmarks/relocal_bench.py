"""Online re-localization benchmark: drift-triggered maintenance vs decay.

Builds the PINNED shuffled 16384-node / 65536-edge power-law graph (the
kernel-bench graph), starts BOTH arms from the SAME fresh locality order
(`repro.dist.delta._relocalized_assignment`, k=8 balanced chunks of the
canonical `locality_block_order`, so `drift_ratio` opens at exactly 1.0),
then replays an identical severed-ties churn stream — each step deletes
1%-of-E edges incident to a random 48-node member set and inserts the same
count INTERNAL to it, the emergent-community migration that steadily
destroys blocked locality without changing |E|:

* **maintained**   — a `DeltaPlanner` whose `RelocalizePolicy` watches
  `locality_drift` and re-localizes in place when the hysteresis trips
  (threshold 1.05, patience 2, cooldown 3 at block=128);
* **unmaintained** — the same planner WITHOUT a policy: the v0 order goes
  stale under the churn (what every mutation stream paid before this
  subsystem);
* **fresh**        — the executed-tile count of a from-scratch reorder of
  the FINAL edge list: the floor both ratios are measured against.

`write_relocal_bench` persists BENCH_relocal.json and asserts the ISSUE 9
acceptance gates: maintained executed tiles ≤ 1.15× the fresh reorder
while the unmaintained order degrades to ≥ 2×, and `compact()` on the
churned (unmaintained) planner reclaims pad bytes. Correctness is NOT
re-proven here — tests/test_relocalize.py and the soak harness in
tests/test_graph_delta.py pin that; the bench only gates the locality and
memory trajectories. Tile counts and ratios are pure functions of the
pinned seeds, so `tools/bench_check.py` compares them exactly (the
``*_ms`` leaves are machine-dependent and skipped).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.partition import partition_from_assignment
from repro.dist.delta import (
    DeltaPlanner,
    GraphDelta,
    RelocalizePolicy,
    _relocalized_assignment,
)
from repro.graph.generators import citation_like
from repro.graph.structure import blocked_stats, permute_edge_index

# The pinned case: the kernel-bench graph + 1%-of-E severed-ties churn.
PINNED = dict(n=16384, e=65536, n_labels=128, homophily=0.9, seed=1,
              shuffle_seed=7, k=8, block=128, delta_frac=0.01, steps=40,
              members=48, churn_seed=42)
POLICY = dict(threshold=1.05, patience=2, cooldown=3)
MAINTAINED_GATE = 1.15
DEGRADED_GATE = 2.0


def _w_of(ei):
    ei = np.asarray(ei, np.int64)
    return (0.1 + (ei[0] * 131 + ei[1] * 17) % 97 / 97.0).astype(np.float32)


def _pinned_setup(cfg=PINNED):
    g = citation_like(cfg["n"], cfg["e"], n_labels=cfg["n_labels"],
                      homophily=cfg["homophily"], seed=cfg["seed"])
    shuf = np.random.default_rng(cfg["shuffle_seed"]).permutation(
        cfg["n"]).astype(np.int64)
    ei = permute_edge_index(shuf, g.edge_index).astype(np.int64)
    assignment = _relocalized_assignment(
        cfg["n"], ei, cfg["k"], block=cfg["block"])
    part = partition_from_assignment(assignment, cfg["k"], ei)
    return part, ei


def _churn_stream(cfg=PINNED):
    """The pinned severed-ties delta sequence, generated ONCE from an
    oracle edge list so both arms replay byte-identical mutations."""
    _, ei = _pinned_setup(cfg)
    rng = np.random.default_rng(cfg["churn_seed"])
    ops = max(2, int(round(ei.shape[1] * cfg["delta_frac"])))
    cur = ei
    deltas = []
    for _ in range(cfg["steps"]):
        mem = rng.choice(cfg["n"], cfg["members"], replace=False)
        inc = np.flatnonzero(
            np.isin(cur[0], mem) | np.isin(cur[1], mem))[:ops // 2]
        m = inc.size
        s = mem[rng.integers(0, cfg["members"], m)]
        d = mem[rng.integers(0, cfg["members"], m)]
        bad = s == d
        d[bad] = mem[(np.searchsorted(np.sort(mem), d[bad]) + 1)
                     % cfg["members"]]
        ins = np.stack([s, d])
        deltas.append(GraphDelta(edge_inserts=ins, edge_deletes=cur[:, inc],
                                 insert_w=_w_of(ins)))
        keep = np.ones(cur.shape[1], bool)
        keep[inc] = False
        cur = np.concatenate([cur[:, keep], ins], axis=1)
    return deltas, cur


def relocal_bench_record(cfg=PINNED) -> dict:
    part, ei = _pinned_setup(cfg)
    blk = cfg["block"]
    deltas, final_ei = _churn_stream(cfg)

    # fresh floor: a from-scratch reorder of the FINAL edge list
    fresh_a = _relocalized_assignment(cfg["n"], final_ei, cfg["k"], block=blk)
    fresh_perm = np.argsort(fresh_a, kind="stable").astype(np.int64)
    tiles_fresh = int(blocked_stats(
        cfg["n"], permute_edge_index(fresh_perm, final_ei), blk)["nnz_blocks"])

    # maintained arm: policy-driven in-place re-localization
    pol = RelocalizePolicy(block=blk, **POLICY)
    maintained = DeltaPlanner(part, ei, _w_of(ei), graph_key="relocal-bench-m",
                              relocalize_policy=pol)
    maintained.plan()
    fired = 0
    t0 = time.perf_counter()
    for d in deltas:
        rep = maintained.apply(d)
        fired += rep["relocalized"] is not None
    maintain_s = time.perf_counter() - t0
    drift_m = maintained.locality_drift(blk)
    tiles_maintained = drift_m["executed_tiles_current"]

    # unmaintained arm: same stream, the v0 order left to decay
    unmaintained = DeltaPlanner(part, ei, _w_of(ei),
                                graph_key="relocal-bench-u")
    unmaintained.plan()
    t0 = time.perf_counter()
    for d in deltas:
        unmaintained.apply(d)
    churn_s = time.perf_counter() - t0
    drift_u = unmaintained.locality_drift(blk)
    tiles_stale = drift_u["executed_tiles_current"]

    # pad compaction on the churned planner: high-water pads -> occupancy
    occ_before = unmaintained.pad_occupancy()
    comp = unmaintained.compact()

    return {
        "case": dict(cfg),
        "policy": dict(POLICY),
        "delta_ops_per_step": int(deltas[0].n_ops),
        "tiles_fresh_reorder": tiles_fresh,
        "tiles_maintained": int(tiles_maintained),
        "tiles_unmaintained": int(tiles_stale),
        "maintained_ratio": tiles_maintained / tiles_fresh,
        "degraded_ratio": tiles_stale / tiles_fresh,
        "relocalizes_fired": int(fired),
        "final_drift_maintained": drift_m["drift_ratio"],
        "compact": {
            "changed": bool(comp["changed"]),
            "bytes_reclaimed": int(comp["bytes_reclaimed"]),
            "pad_rows_reclaimed": comp["pad_rows_reclaimed"],
            "occupancy_before_frac": occ_before["frac"],
            "occupancy_after_frac": unmaintained.pad_occupancy()["frac"],
        },
        "maintain_ms": maintain_s * 1e3,
        "churn_ms": churn_s * 1e3,
    }


def write_relocal_bench(path: str = "BENCH_relocal.json", cfg=PINNED) -> dict:
    rec = relocal_bench_record(cfg)
    # The ISSUE 9 acceptance gates, asserted before anything is written.
    assert rec["relocalizes_fired"] >= 1, "policy never fired on the churn"
    assert rec["maintained_ratio"] <= MAINTAINED_GATE, (
        "maintenance stopped holding the locality floor",
        rec["maintained_ratio"], rec["tiles_maintained"],
        rec["tiles_fresh_reorder"])
    assert rec["degraded_ratio"] >= DEGRADED_GATE, (
        "churn no longer degrades the unmaintained order — the bench "
        "stopped measuring anything", rec["degraded_ratio"])
    assert rec["compact"]["bytes_reclaimed"] > 0, (
        "compact() reclaimed nothing after the churn high-water")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def relocal_rows():
    """`benchmarks.run` suite: persist BENCH_relocal.json + print the
    maintenance trajectory for the pinned churn case."""
    rec = write_relocal_bench()
    return [(
        "relocal/maintained_vs_decay",
        rec["maintain_ms"] * 1e3,
        f"maintained={rec['maintained_ratio']:.2f}x "
        f"degraded={rec['degraded_ratio']:.2f}x of fresh "
        f"({rec['relocalizes_fired']} fires) "
        f"compact_reclaimed={rec['compact']['bytes_reclaimed']/1e3:.1f}kB",
    )]


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_relocal.json")
    args = ap.parse_args(argv)
    rec = write_relocal_bench(args.out)
    print(json.dumps(rec, indent=1))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
