"""Shared benchmark infrastructure: the calibrated COIN energy pipeline.

Absolute-joule calibration (DESIGN.md §9): one global NoC energy scale is
fixed so the paper's headline point — Cora on the 4×4 mesh consumes 2.7 µJ
of communication energy (§V-D) — is matched exactly; one compute constant
(J/MAC, covering crossbar+ADC+accumulator) is fixed so Cora's total COIN
energy is 0.05 mJ (Table IV). Everything else is a *prediction* of the
model; tables report model vs paper side by side.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.core.dataflow import dense_multiply_count
from repro.core.energy import CoinEnergyModel
from repro.core.noc import CMeshNoC, MeshNoC, TrafficSummary, baseline_broadcast_summary
from repro.core.partition import Partition, measured_probabilities, partition_graph
from repro.graph.generators import TABLE_I, citation_like

ACT_BITS = 4          # §V-B: 4-bit activations
HIDDEN = 16           # Kipf–Welling hidden width (paper's Nell example)
A2_BITS = HIDDEN * ACT_BITS   # a(2) = 64 bits/node exchanged at the layer boundary

# Calibration targets from the paper.
CORA_COMM_TARGET_J = 2.7e-6        # §V-D: Cora 4×4 comm energy
CORA_TOTAL_TARGET_J = 0.05e-3      # Table IV: Cora COIN total energy


@dataclasses.dataclass
class DatasetEnergy:
    name: str
    comm_j: float
    compute_j: float
    latency_s: float
    summary: TrafficSummary
    part: Partition

    @property
    def total_j(self) -> float:
        return self.comm_j + self.compute_j

    @property
    def comm_pct(self) -> float:
        return 100.0 * self.comm_j / self.total_j

    @property
    def edp(self) -> float:
        return self.total_j * self.latency_s


@functools.lru_cache(maxsize=None)
def dataset_partition(name: str, k: int = 16, method: str = "bfs") -> Partition:
    spec = TABLE_I[name]
    g = citation_like(spec.n_nodes, spec.n_edges, None, spec.n_labels, seed=0)
    return partition_graph(g.n_nodes, g.edge_index, k, method=method, seed=0, refine=True)


def dataset_macs(name: str) -> float:
    """Feature-first dense MAC count (the paper's crossbar accounting)."""
    spec = TABLE_I[name]
    dims = [spec.n_features, HIDDEN, spec.n_labels]
    total = 0.0
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        total += dense_multiply_count(spec.n_nodes, d_in, d_out).feature_first
    return total


@functools.lru_cache(maxsize=1)
def calibration() -> tuple[float, float]:
    """(noc_energy_scale, j_per_mac) from the two Cora anchors."""
    noc = MeshNoC(4, 4)
    part = dataset_partition("cora")
    raw = _comm_energy(noc, part, broadcast=True)
    scale = CORA_COMM_TARGET_J / raw
    macs = dataset_macs("cora")
    j_per_mac = (CORA_TOTAL_TARGET_J - CORA_COMM_TARGET_J) / macs
    return scale, j_per_mac


def _comm_energy(noc: MeshNoC, part: Partition, broadcast: bool) -> float:
    inter = part.inter_ce_traffic_bits(A2_BITS, broadcast=broadcast)
    e_inter, _ = noc.energy_for_traffic(inter)
    intra = part.intra_ce_traffic_bits(A2_BITS)
    e_intra = noc.intra_ce_energy(intra, part.n_nodes / part.k)
    return e_inter + e_intra


def calibrated_noc(k: int = 16, cmesh: bool = False) -> MeshNoC:
    scale, _ = calibration()
    cls = CMeshNoC if cmesh else MeshNoC
    return cls.square(k).calibrated(scale)


def coin_energy(name: str, k: int = 16, broadcast: bool = True, cmesh: bool = False) -> DatasetEnergy:
    """Full COIN energy/latency for one dataset on a k-CE chip."""
    noc = calibrated_noc(k, cmesh=cmesh)
    part = dataset_partition(name, k)
    comm = _comm_energy(noc, part, broadcast)
    inter = part.inter_ce_traffic_bits(A2_BITS, broadcast=broadcast)
    summary = noc.summarize(inter)
    _, j_per_mac = calibration()
    compute = dataset_macs(name) * j_per_mac
    # Compute latency: crossbars operate column-parallel at 1 GHz with
    # bit-serial inputs; per-layer latency dominated by input streaming —
    # modeled as MACs / (parallel crossbar lanes).
    lanes = 16 * 30 * 16 * 128.0  # CEs × tiles × PEs × rows
    compute_s = dataset_macs(name) / lanes / noc.freq_hz * ACT_BITS
    return DatasetEnergy(
        name=name,
        comm_j=comm,
        compute_j=compute,
        latency_s=summary.latency_s + compute_s,
        summary=summary,
        part=part,
    )


def baseline_energy(name: str) -> DatasetEnergy:
    """The paper's baseline: one CE per GCN node on a √N×√N mesh NoC."""
    spec = TABLE_I[name]
    scale, j_per_mac = calibration()
    side = int(np.ceil(np.sqrt(spec.n_nodes)))
    noc = MeshNoC(side, side).calibrated(scale)
    s = baseline_broadcast_summary(noc, spec.n_nodes, A2_BITS)
    compute = dataset_macs(name) * j_per_mac
    part = dataset_partition(name)      # reused only for bookkeeping
    return DatasetEnergy(
        name=name, comm_j=s.energy_j, compute_j=compute,
        latency_s=s.latency_s, summary=s, part=part,
    )


def timed(fn, *args, repeat: int = 3, **kw):
    """(result, microseconds per call)."""
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us
