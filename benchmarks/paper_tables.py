"""Paper tables: optimal k (§IV-B3), dataflow multiplies (§IV-C3),
chips required (§V-C), accelerator comparisons (Tables IV/VI/VII)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import coin_energy, timed
from repro.core.chip import ChipModel, chips_required
from repro.core.dataflow import dense_multiply_count
from repro.core.energy import model_from_gcn
from repro.core.solver import optimal_ce_count
from repro.graph.generators import TABLE_I

HIDDEN = 16


def tbl_optimal_k():
    """§IV-B3: interior-point solve per dataset; paper picks 4×4 overall
    ('least communication energy for most of the dataset'), 10 ms solve."""
    rows = []
    for name, spec in TABLE_I.items():
        m = model_from_gcn(spec.n_nodes, [spec.n_features, HIDDEN, spec.n_labels], 4)
        res, us = timed(optimal_ce_count, m, repeat=3)
        rows.append(
            (f"optk/{name}", us,
             f"k*={res.k_star:.1f} mesh={res.mesh_shape[0]}x{res.mesh_shape[1]} "
             f"solve_ms={res.solve_ms:.2f} (paper: 10ms, 4x4)")
        )
    m6000 = model_from_gcn(6000, [1433, HIDDEN, 7], 4)
    res = optimal_ce_count(m6000)
    rows.append(("optk/N6000_fig19", 0.0,
                 f"k*={res.k_star:.2f} mesh={res.mesh_shape} (paper: 16 = 4x4)"))
    return rows


def tbl_dataflow():
    """§IV-C3: multiply counts, aggregation-first vs feature-first."""
    rows = []
    for name, spec in TABLE_I.items():
        c = dense_multiply_count(spec.n_nodes, spec.n_features, HIDDEN)
        rows.append(
            (f"dataflow/{name}", 0.0,
             f"agg_first={c.aggregation_first:.3g} feat_first={c.feature_first:.3g} "
             f"reduction={c.reduction:.0f}x")
        )
    nell = dense_multiply_count(65755, 5414, 16)
    rows.append(("dataflow/nell_paper_check", 0.0,
                 f"2.3e13 vs {nell.aggregation_first:.2g}; 7.4e10 vs "
                 f"{nell.feature_first:.2g}; 311x vs {nell.reduction:.0f}x"))
    return rows


def tbl_chips():
    """§V-C: chips required (paper: 1/1/3/20/45)."""
    paper = {"cora": 1, "citeseer": 1, "pubmed": 3, "extcora": 20, "nell": 45}
    cm = ChipModel()
    rows = []
    for name, spec in TABLE_I.items():
        dims = [spec.n_features, HIDDEN, spec.n_labels]
        xb = chips_required(cm, spec.n_nodes, dims, mode="crossbar")
        cell = chips_required(cm, spec.n_nodes, dims, mode="cell")
        rows.append(
            (f"chips/{name}", 0.0,
             f"crossbar={xb} cell={cell} paper={paper[name]}")
        )
    return rows


# Published numbers (the comparison baselines the paper measures against).
_RTX8000 = {  # Table IV: energy mJ, latency ms
    "cora": (62.2, 1.22), "citeseer": (90.50, 1.22), "pubmed": (89.1, 1.22),
    "extcora": (1787.3, 7.45), "nell": (1504.0, 14.94),
}
_AWB_32NM = {"cora": 5.27, "citeseer": 8.54, "pubmed": 73.0, "nell": 1020.0}  # mJ
_COIN_PAPER = {  # Table IV: COIN energy mJ / latency ms
    "cora": (0.05, 0.6), "citeseer": (0.10, 1.10), "pubmed": (38.13, 0.57),
    "extcora": (257.4, 9.96), "nell": (577.1, 1.04),
}


def tbl_accel_compare():
    """Tables IV/VI/VII: our modeled COIN numbers next to the published COIN
    and baseline-accelerator numbers; improvement factors recomputed."""
    rows = []
    for name in TABLE_I:
        c = coin_energy(name)
        model_mj = c.total_j * 1e3
        paper_mj, paper_ms = _COIN_PAPER[name]
        rtx_mj, rtx_ms = _RTX8000[name]
        rows.append(
            (f"tbl4/{name}", 0.0,
             f"model_COIN_mJ={model_mj:.3g} paper_COIN_mJ={paper_mj} "
             f"RTX_mJ={rtx_mj} impr_vs_RTX(paper_basis)={rtx_mj / paper_mj:.0f}x "
             f"impr_vs_RTX(model_basis)={rtx_mj / max(model_mj, 1e-12):.0f}x")
        )
    for name, awb in _AWB_32NM.items():
        paper_mj, _ = _COIN_PAPER[name]
        c = coin_energy(name)
        rows.append(
            (f"tbl6/{name}", 0.0,
             f"AWB32nm_mJ={awb} COIN_paper_mJ={paper_mj} impr_paper={awb / paper_mj:.3g}x "
             f"impr_model={awb / max(c.total_j * 1e3, 1e-12):.3g}x (paper headline: Cora 105x)")
        )
    return rows
