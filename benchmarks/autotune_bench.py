"""Placement-autotuner benchmark: quotient pod mapping + joint config search.

Runs ``repro.launch.autotune.run_autotune`` on the PINNED benchmark graph
(the shuffled 16384-node / 65536-edge power-law case the kernel and relocal
benches share, at k=32 parts over pods=2) and records the measured
default-vs-autotuned accounting on really-built halo plans.

``write_autotune_bench`` asserts the ISSUE 10 acceptance gates BEFORE
anything is written:

* inter-pod crossing rows reduced ≥ ``CROSSING_GATE``× vs the naive
  contiguous map (measured, not predicted);
* exposed wire bytes per exchange reduced ≥ ``EXPOSED_GATE``× under the
  chosen payload/overlap config;
* executed bsr tiles no worse than the default config's;
* the calibration block is empty — every shared predicted field matched
  its measured twin exactly.

Everything upstream is seeded, so every non-timing leaf of
BENCH_autotune.json is deterministic and ``tools/bench_check.py`` compares
it exactly against the pinned baseline (the improvement ratios get loose
floors so a regression fails without requiring a re-pin for strict gains).
"""
from __future__ import annotations

import json
import time

from repro.launch.autotune import run_autotune

# The pinned case: k=32 parts on the shared benchmark graph, 2 pods.
PINNED = dict(n=16384, e=65536, k=32, pods=2, d_feat=64,
              layer_dims=(64, 32, 7), n_labels=128, homophily=0.9,
              graph_seed=1, shuffle_seed=7, partition_seed=0,
              seed=0, rounds=3)
CROSSING_GATE = 1.3
EXPOSED_GATE = 1.3


def autotune_bench_record(cfg=PINNED) -> dict:
    t0 = time.perf_counter()
    rec = run_autotune(**cfg)
    rec["search_ms"] = (time.perf_counter() - t0) * 1e3
    return rec


def write_autotune_bench(path: str = "BENCH_autotune.json", cfg=PINNED) -> dict:
    rec = autotune_bench_record(cfg)
    imp = rec["improvement"]
    # The ISSUE 10 acceptance gates, asserted before anything is written.
    assert rec["calibration_mismatches"] == {}, (
        "predicted fields drifted from measured accounting",
        rec["calibration_mismatches"])
    assert imp["crossing_improvement"] >= CROSSING_GATE, (
        "pod mapper stopped beating the contiguous map",
        imp["crossing_improvement"],
        rec["measured"]["default"]["inter_pod_rows_crossing"],
        rec["measured"]["autotuned"]["inter_pod_rows_crossing"])
    assert imp["exposed_improvement"] >= EXPOSED_GATE, (
        "autotuned config stopped cutting exposed wire bytes",
        imp["exposed_improvement"])
    assert imp["tiles_ratio"] <= 1.0, (
        "autotuned placement made the blocked compute WORSE",
        imp["tiles_ratio"])
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def autotune_rows():
    """`benchmarks.run` suite: persist BENCH_autotune.json + print the
    placement win for the pinned k=32 / pods=2 case."""
    rec = write_autotune_bench()
    imp = rec["improvement"]
    md, mt = rec["measured"]["default"], rec["measured"]["autotuned"]
    return [(
        "autotune/placement_search",
        rec["search_ms"] * 1e3,
        f"crossing={md['inter_pod_rows_crossing']}->"
        f"{mt['inter_pod_rows_crossing']}rows({imp['crossing_improvement']:.2f}x) "
        f"exposed={imp['exposed_improvement']:.2f}x "
        f"tiles_ratio={imp['tiles_ratio']:.3f} "
        f"payload={rec['config']['payload'] or 'fp32'}",
    )]


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_autotune.json")
    args = ap.parse_args(argv)
    rec = write_autotune_bench(args.out)
    print(json.dumps(rec, indent=1, default=str))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
