"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle vs the
segment-sum system path. On CPU interpret-mode timing measures correctness
plumbing, not TPU perf — TPU perf comes from the §Roofline analysis — but the
harness rows keep the kernels exercised end-to-end in `benchmarks.run`."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.graph.ops import aggregate
from repro.graph.structure import blocked_adjacency
from repro.kernels.ops import bsr_spmm, flash_attention, fm_interaction
from repro.kernels.ref import bsr_spmm_ref, flash_attention_ref, fm_interaction_ref


def kernel_rows():
    rng = np.random.default_rng(0)
    rows = []

    # bsr_spmm on a Cora-sized blocked adjacency
    n, e, f = 2708, 10556, 128
    ei = rng.integers(0, n, size=(2, e)).astype(np.int32)
    ba = blocked_adjacency(n, ei, block=128)
    vals, cols = jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols)
    z = jnp.asarray(rng.standard_normal((ba.n_padded, f)), jnp.float32)
    out_k, us_k = timed(lambda: jax.block_until_ready(bsr_spmm(vals, cols, z)), repeat=2)
    out_r, us_r = timed(lambda: jax.block_until_ready(bsr_spmm_ref(vals, cols, z)), repeat=2)
    _, us_s = timed(
        lambda: jax.block_until_ready(
            aggregate(z[:n], jnp.asarray(ei[0]), jnp.asarray(ei[1]), n)
        ),
        repeat=2,
    )
    err = float(jnp.abs(out_k - out_r).max())
    rows.append(("kernel/bsr_spmm_interp", us_k, f"ref_us={us_r:.0f} segsum_us={us_s:.0f} err={err:.1e}"))

    # fm_interaction at the deepfm train shape (downscaled batch)
    emb = jnp.asarray(rng.standard_normal((4096, 39, 10)), jnp.float32)
    out_k, us_k = timed(lambda: jax.block_until_ready(fm_interaction(emb)), repeat=2)
    out_r, us_r = timed(lambda: jax.block_until_ready(fm_interaction_ref(emb)), repeat=2)
    err = float(jnp.abs(out_k - out_r).max())
    rows.append(("kernel/fm_interaction_interp", us_k, f"ref_us={us_r:.0f} err={err:.1e}"))

    # flash attention (small, causal + window)
    q = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    out_k, us_k = timed(lambda: jax.block_until_ready(flash_attention(q, k, v, window=128)), repeat=1)
    out_r, us_r = timed(lambda: jax.block_until_ready(flash_attention_ref(q, k, v, window=128)), repeat=1)
    err = float(jnp.abs(out_k - out_r).max())
    rows.append(("kernel/flash_attention_interp", us_k, f"ref_us={us_r:.0f} err={err:.1e}"))
    return rows


def spmm_compare_rows(full: bool = False):
    """`bsr_spmm` vs the segment-sum system path at increasing scale — the
    ROADMAP's kernel-perf entry. On CPU the Pallas kernel runs in interpret
    mode, so these rows track correctness plumbing and the segment-sum
    baseline; native-TPU numbers come from the same rows on real hardware.
    ``--full`` adds an ogbn-products-density point (~25 edges/node)."""
    rng = np.random.default_rng(0)
    rows = []
    scales = [(2048, 32768, 64)]
    if full:
        scales.append((8192, 204_800, 100))   # products density at 1/300 nodes
    for n, e, f in scales:
        ei = rng.integers(0, n, size=(2, e)).astype(np.int32)
        ba = blocked_adjacency(n, ei, block=128)
        vals, cols = jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols)
        z = jnp.asarray(rng.standard_normal((ba.n_padded, f)), jnp.float32)
        zn = z[:n]
        s, d = jnp.asarray(ei[0]), jnp.asarray(ei[1])
        out_b, us_b = timed(lambda: jax.block_until_ready(bsr_spmm(vals, cols, z)), repeat=2)
        out_s, us_s = timed(lambda: jax.block_until_ready(aggregate(zn, s, d, n)), repeat=2)
        err = float(jnp.abs(out_b[:n] - out_s).max())
        gb = ba.block_vals.nbytes / 1e9
        rows.append((
            f"kernel/bsr_vs_segsum_n{n}", us_b,
            f"segsum_us={us_s:.0f} err={err:.1e} blocks={ba.block_vals.shape[0]*ba.block_vals.shape[1]}"
            f" bsr_gb={gb:.2f} density={ba.density:.3f}",
        ))
    return rows
