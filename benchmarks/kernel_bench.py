"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle vs the
segment-sum system path. On CPU interpret-mode timing measures correctness
plumbing, not TPU perf — TPU perf comes from the §Roofline analysis — but the
harness rows keep the kernels exercised end-to-end in `benchmarks.run`.

`kernel_bench_record` / the CLI (``python benchmarks/kernel_bench.py``)
additionally persist BENCH_kernels.json — the kernel-perf trajectory record:
blocked-layout statistics of the PINNED shuffled power-law benchmark graph
(nonzero 128×128 tiles, dense-T executed tiles, padded-tile fractions,
before/after the `locality_block_order` reorder), the halo rows-moved
accounting, and the per-shard blocked (bsr-under-halo) statistics. CI
uploads the file as an artifact so the numbers version with the code.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.graph.ops import aggregate
from repro.graph.structure import (
    blocked_adjacency,
    blocked_stats,
    locality_block_order,
    permute_edge_index,
)
from repro.kernels.ops import bsr_spmm, flash_attention, fm_interaction, fused_gcn_layer
from repro.kernels.ref import bsr_spmm_ref, flash_attention_ref, fm_interaction_ref

# The pinned kernel-perf benchmark graph: power-law (alpha 1.6) community
# structure at 128-node-community scale, node ids SHUFFLED (real-world ids
# are arbitrary — the generator's contiguous order would hand the blocker
# the answer). Stats-only paths handle it at full size; timing paths use
# the smaller cora-scale graphs below.
PINNED_GRAPH = dict(n=16384, e=65536, n_labels=128, homophily=0.9, seed=1, shuffle_seed=7)


def kernel_rows():
    rng = np.random.default_rng(0)
    rows = []

    # bsr_spmm on a Cora-sized blocked adjacency
    n, e, f = 2708, 10556, 128
    ei = rng.integers(0, n, size=(2, e)).astype(np.int32)
    ba = blocked_adjacency(n, ei, block=128)
    vals, cols = jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols)
    z = jnp.asarray(rng.standard_normal((ba.n_padded, f)), jnp.float32)
    out_k, us_k = timed(lambda: jax.block_until_ready(bsr_spmm(vals, cols, z)), repeat=2)
    out_r, us_r = timed(lambda: jax.block_until_ready(bsr_spmm_ref(vals, cols, z)), repeat=2)
    _, us_s = timed(
        lambda: jax.block_until_ready(
            aggregate(z[:n], jnp.asarray(ei[0]), jnp.asarray(ei[1]), n)
        ),
        repeat=2,
    )
    err = float(jnp.abs(out_k - out_r).max())
    rows.append(("kernel/bsr_spmm_interp", us_k, f"ref_us={us_r:.0f} segsum_us={us_s:.0f} err={err:.1e}"))

    # fm_interaction at the deepfm train shape (downscaled batch)
    emb = jnp.asarray(rng.standard_normal((4096, 39, 10)), jnp.float32)
    out_k, us_k = timed(lambda: jax.block_until_ready(fm_interaction(emb)), repeat=2)
    out_r, us_r = timed(lambda: jax.block_until_ready(fm_interaction_ref(emb)), repeat=2)
    err = float(jnp.abs(out_k - out_r).max())
    rows.append(("kernel/fm_interaction_interp", us_k, f"ref_us={us_r:.0f} err={err:.1e}"))

    # flash attention (small, causal + window)
    q = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    out_k, us_k = timed(lambda: jax.block_until_ready(flash_attention(q, k, v, window=128)), repeat=1)
    out_r, us_r = timed(lambda: jax.block_until_ready(flash_attention_ref(q, k, v, window=128)), repeat=1)
    err = float(jnp.abs(out_k - out_r).max())
    rows.append(("kernel/flash_attention_interp", us_k, f"ref_us={us_r:.0f} err={err:.1e}"))
    return rows


def _pinned_edges() -> tuple[int, np.ndarray]:
    from repro.graph.generators import citation_like

    p = PINNED_GRAPH
    g = citation_like(
        p["n"], p["e"], n_labels=p["n_labels"], homophily=p["homophily"], seed=p["seed"]
    )
    shuf = np.random.default_rng(p["shuffle_seed"]).permutation(p["n"]).astype(np.int64)
    return p["n"], permute_edge_index(shuf, g.edge_index)


def kernel_bench_record(k_devices: int = 8) -> dict:
    """The BENCH_kernels.json record (all host-side stats, no tile alloc).

    ``layout`` compares the CURRENT dense-T layout on the raw node order
    (what the kernel executed before this PR: R·T tiles, padding multiplied
    as zeros) against the reordered ragged layout (nnz tiles executed,
    padding skipped). ``halo`` records the rows-moved accounting and the
    per-shard blocked statistics of the same graph partitioned over
    ``k_devices`` — the `backend="bsr"`-under-halo path.
    """
    from repro.core.partition import partition_graph
    from repro.dist.halo import get_halo_plan, plan_blocked_shape

    n, ei = _pinned_edges()
    base = blocked_stats(n, ei)
    perm = locality_block_order(n, ei, block=128)
    reord = blocked_stats(n, permute_edge_index(perm, ei))
    layout = {
        "baseline_dense_T": {
            **base,
            "executed_tiles": base["dense_tiles"],
            "executed_padded_fraction": base["padded_tile_fraction"],
        },
        "reordered_ragged": {
            **reord,
            "executed_tiles": reord["nnz_blocks"],
            "executed_padded_fraction": 0.0,   # ragged lens skip every pad tile
        },
        "nnz_block_cut": base["nnz_blocks"] / max(reord["nnz_blocks"], 1),
        "executed_tile_cut": base["dense_tiles"] / max(reord["nnz_blocks"], 1),
        "padded_fraction_before_after": [base["padded_tile_fraction"], 0.0],
    }
    part = partition_graph(n, ei, k_devices, method="bfs", seed=0, refine=True)
    plan = get_halo_plan(part, ei)
    halo = {
        "k": k_devices,
        "halo_rows_per_device": plan.halo_rows_per_device,
        "broadcast_rows_per_device": plan.broadcast_rows_per_device,
        "wire_fraction": plan.wire_fraction(),
        "bsr": plan_blocked_shape(plan),
    }
    return {"pinned_graph": dict(PINNED_GRAPH), "layout": layout, "halo": halo}


def write_kernel_bench(path: str = "BENCH_kernels.json", k_devices: int = 8) -> dict:
    """Write (and return) the kernel-perf trajectory record."""
    rec = kernel_bench_record(k_devices)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def ragged_fused_rows():
    """Benchmark rows for the ragged/fused kernels on a materializable graph
    (cora-scale shuffled community structure): dense-T vs ragged bsr_spmm,
    and the fused layer vs the unfused matmul∘SpMM∘bias∘relu pipeline."""
    from repro.graph.generators import citation_like

    rng = np.random.default_rng(0)
    n, e = 2048, 8192
    g = citation_like(n, e, n_labels=16, homophily=0.9, seed=1)
    shuf = np.random.default_rng(7).permutation(n).astype(np.int64)
    ei = permute_edge_index(shuf, g.edge_index)
    perm = locality_block_order(n, ei, block=128)
    ba = blocked_adjacency(n, permute_edge_index(perm, ei), block=128)
    vals, cols, lens = ba.arrays()
    f = 64
    z = jnp.asarray(rng.standard_normal((ba.n_col_padded, f)), jnp.float32)
    out_d, us_dense = timed(lambda: jax.block_until_ready(bsr_spmm(vals, cols, z)), repeat=2)
    out_r, us_ragged = timed(
        lambda: jax.block_until_ready(bsr_spmm(vals, cols, z, lens=lens)), repeat=2
    )
    err = float(jnp.abs(out_d - out_r).max())
    rows = [(
        "kernel/bsr_ragged_vs_denseT_interp", us_ragged,
        f"denseT_us={us_dense:.0f} err={err:.1e} nnzb={ba.nnz_blocks} "
        f"T={ba.max_nnzb} padfrac={ba.padded_tile_fraction:.2f}",
    )]
    W = jnp.asarray(rng.standard_normal((f, 16)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    out_f, us_fused = timed(
        lambda: jax.block_until_ready(
            fused_gcn_layer(vals, cols, lens, z, W, b, order="feature_first")
        ),
        repeat=2,
    )

    def unfused():
        h = bsr_spmm(vals, cols, z @ W, lens=lens) + b
        return jax.block_until_ready(jax.nn.relu(h))

    out_u, us_unfused = timed(unfused, repeat=2)
    err = float(jnp.abs(out_f - out_u).max())
    rows.append((
        "kernel/fused_gcn_layer_interp", us_fused,
        f"unfused_us={us_unfused:.0f} err={err:.1e}",
    ))
    return rows


def bench_kernels_rows():
    """`benchmarks.run` suite: persist BENCH_kernels.json + print the layout
    and rows-moved numbers as derived columns."""
    rec = write_kernel_bench()
    lay, halo = rec["layout"], rec["halo"]
    base, reord = lay["baseline_dense_T"], lay["reordered_ragged"]
    return [
        (
            "kernel/pinned_layout", 0.0,
            f"denseT_tiles={base['executed_tiles']} ragged_reord_tiles={reord['executed_tiles']}"
            f" nnz_cut={lay['nnz_block_cut']:.2f}x exec_cut={lay['executed_tile_cut']:.2f}x"
            f" padfrac {base['executed_padded_fraction']:.3f}->0.0",
        ),
        (
            "kernel/pinned_rows_moved", 0.0,
            f"halo={halo['halo_rows_per_device']} broadcast={halo['broadcast_rows_per_device']}"
            f" wire_frac={halo['wire_fraction']:.3f} bsr_nnzb={halo['bsr']['nnz_blocks']}"
            f" bsr_padfrac={halo['bsr']['padded_tile_fraction']:.3f}",
        ),
    ] + ragged_fused_rows()


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--devices", type=int, default=8, help="halo partition size")
    args = ap.parse_args(argv)
    rec = write_kernel_bench(args.out, args.devices)
    lay = rec["layout"]
    print(json.dumps(rec, indent=1))
    ok = lay["executed_tile_cut"] >= 2.0
    print(f"executed-tile cut {lay['executed_tile_cut']:.2f}x (>=2x: {ok}) -> {args.out}")
    return 0 if ok else 1


def spmm_compare_rows(full: bool = False):
    """`bsr_spmm` vs the segment-sum system path at increasing scale — the
    ROADMAP's kernel-perf entry. On CPU the Pallas kernel runs in interpret
    mode, so these rows track correctness plumbing and the segment-sum
    baseline; native-TPU numbers come from the same rows on real hardware.
    ``--full`` adds an ogbn-products-density point (~25 edges/node)."""
    rng = np.random.default_rng(0)
    rows = []
    scales = [(2048, 32768, 64)]
    if full:
        scales.append((8192, 204_800, 100))   # products density at 1/300 nodes
    for n, e, f in scales:
        ei = rng.integers(0, n, size=(2, e)).astype(np.int32)
        ba = blocked_adjacency(n, ei, block=128)
        vals, cols = jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols)
        z = jnp.asarray(rng.standard_normal((ba.n_padded, f)), jnp.float32)
        zn = z[:n]
        s, d = jnp.asarray(ei[0]), jnp.asarray(ei[1])
        out_b, us_b = timed(lambda: jax.block_until_ready(bsr_spmm(vals, cols, z)), repeat=2)
        out_s, us_s = timed(lambda: jax.block_until_ready(aggregate(zn, s, d, n)), repeat=2)
        err = float(jnp.abs(out_b[:n] - out_s).max())
        gb = ba.block_vals.nbytes / 1e9
        rows.append((
            f"kernel/bsr_vs_segsum_n{n}", us_b,
            f"segsum_us={us_s:.0f} err={err:.1e} blocks={ba.block_vals.shape[0]*ba.block_vals.shape[1]}"
            f" bsr_gb={gb:.2f} density={ba.density:.3f}",
        ))
    return rows


if __name__ == "__main__":
    import sys

    sys.exit(main())
