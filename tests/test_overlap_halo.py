"""Overlapped halo schedule + quantized wire payloads (docs/communication.md
"Overlapped schedule"): interior/boundary row-partition invariants, numpy
emulation of the split aggregation, split blocked-adjacency equivalence,
plan-cache eviction accounting, and the 8-device overlapped-vs-serialized /
payload-tolerance subprocess acceptance runs.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import partition_graph
from repro.dist.halo import build_halo_plan
from repro.graph.generators import citation_like

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _blocked(plan, x: np.ndarray) -> np.ndarray:
    out = np.zeros((plan.k, plan.n_local) + x.shape[1:], x.dtype)
    off = 0
    for b in range(plan.k):
        sz = int(plan.part_sizes[b])
        out[b, :sz] = x[plan.perm[off:off + sz]]
        off += sz
    return out


def _flat_halo(plan, zb: np.ndarray) -> np.ndarray:
    """Pure-numpy emulation of the flat halo block (the all-gather of every
    member's export rows — identical on all devices)."""
    return np.concatenate([zb[m][plan.send_idx[m]] for m in range(plan.k)], axis=0)


# ---------------------------------------------------- interior/boundary split
@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(64, 400),
    e=st.integers(100, 2000),
    k=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 50),
)
def test_interior_boundary_partition_every_row_exactly_once(n, e, k, seed):
    """The tentpole invariant: interior ∪ boundary covers every block row of
    every device exactly once (padding rows count interior), and the edge
    split is exhaustive — interior + boundary == every real edge."""
    g = citation_like(n, e, seed=seed)
    part = partition_graph(n, g.edge_index, k, method="bfs", seed=seed)
    plans = [build_halo_plan(part, g.edge_index)]
    if k >= 4:
        plans.append(build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=2))
    for plan in plans:
        bm, im = plan.boundary_row_mask(), plan.interior_row_mask()
        assert bm.shape == im.shape == (plan.k, plan.n_local)
        # partition: every row in exactly one set
        assert np.array_equal(bm ^ im, np.ones_like(bm))
        assert int(plan.interior_edges) + int(plan.boundary_edges) == e
        assert 0.0 <= plan.overlap_fraction() <= 1.0
        assert int(plan.boundary_rows_per_device().sum()
                   + plan.interior_rows_per_device().sum()) == plan.k * plan.n_local
        # boundary rows receive ≥1 halo edge each, so they can't outnumber them
        assert int(plan.boundary_rows_per_device().sum()) <= int(plan.boundary_edges)


def test_overlap_fraction_extremes():
    """k=1 has no halo senders at all → everything interior, fraction 1."""
    g = citation_like(100, 600, seed=3)
    part = partition_graph(100, g.edge_index, 1, method="block")
    plan = build_halo_plan(part, g.edge_index)
    assert plan.boundary_edges == 0 and plan.overlap_fraction() == 1.0
    assert not plan.boundary_row_mask().any()


def test_split_aggregate_matches_combined_numpy_emulation():
    """split_halo_aggregate(z, halo) == the combined [local ‖ halo] gather
    aggregation, bit-for-bit on the same table rows (flat 4-way plan)."""
    import jax.numpy as jnp

    from repro.dist.halo import split_halo_aggregate

    g = citation_like(300, 1800, seed=9)
    w = np.abs(np.random.default_rng(0).standard_normal(g.n_edges)).astype(np.float32) + 0.1
    part = partition_graph(g.n_nodes, g.edge_index, 4, method="bfs", seed=0, refine=True)
    plan = build_halo_plan(part, g.edge_index, w)
    z = np.random.default_rng(1).standard_normal((g.n_nodes, 12)).astype(np.float32)
    zb = _blocked(plan, z)
    halo = _flat_halo(plan, zb)
    for dev in range(plan.k):
        table = np.concatenate([zb[dev], halo], axis=0)
        ref = np.zeros_like(zb[dev])
        np.add.at(ref, plan.receivers_l[dev],
                  table[plan.senders_l[dev]] * plan.edge_w[dev][:, None])
        out = np.asarray(split_halo_aggregate(
            jnp.asarray(zb[dev]), jnp.asarray(halo),
            jnp.asarray(plan.senders_l[dev]), jnp.asarray(plan.receivers_l[dev]),
            jnp.asarray(plan.edge_w[dev]),
        ))
        np.testing.assert_allclose(out, ref, atol=2e-5)


def test_split_blocked_adjacency_matches_combined():
    """interior(z) + boundary(halo) through the split bsr tables equals the
    combined per-shard blocked aggregation — per device, both plans cached."""
    import jax.numpy as jnp

    from repro.dist.halo import (
        plan_blocked_adjacency,
        plan_split_blocked_adjacency,
        plan_split_blocked_shape,
    )
    from repro.kernels.ops import bsr_spmm

    g = citation_like(300, 1800, seed=9)
    w = np.abs(np.random.default_rng(0).standard_normal(g.n_edges)).astype(np.float32) + 0.1
    part = partition_graph(g.n_nodes, g.edge_index, 4, method="bfs", seed=0, refine=True)
    plan = build_halo_plan(part, g.edge_index, w)
    comb = plan_blocked_adjacency(plan)
    ia, bd = plan_split_blocked_adjacency(plan)
    assert plan_split_blocked_adjacency(plan) == (ia, bd)   # memoized
    shp = plan_split_blocked_shape(plan)
    assert shp["interior"]["nnz_blocks"] == ia.nnz_blocks
    assert shp["boundary"]["nnz_blocks"] == bd.nnz_blocks
    assert shp["overlap_fraction"] == plan.overlap_fraction()
    z = np.random.default_rng(1).standard_normal((g.n_nodes, 16)).astype(np.float32)
    zb = _blocked(plan, z)
    halo = _flat_halo(plan, zb)
    cv, cc, cl = comb.device_arrays()
    iv, ic, il = ia.device_arrays()
    bv, bc, bl = bd.device_arrays()
    for dev in range(plan.k):
        table = jnp.asarray(np.concatenate([zb[dev], halo], axis=0))
        ref = np.asarray(bsr_spmm(cv[dev], cc[dev], table, lens=cl[dev]))[: plan.n_local]
        interior = bsr_spmm(iv[dev], ic[dev], jnp.asarray(zb[dev]), lens=il[dev])
        boundary = bsr_spmm(bv[dev], bc[dev], jnp.asarray(halo), lens=bl[dev])
        out = np.asarray(interior)[: plan.n_local] + np.asarray(boundary)[: plan.n_local]
        np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


# ------------------------------------------------------- plan-cache evictions
def test_plan_cache_evictions_counted_and_resettable():
    """Satellite 3: `invalidate_halo_plans` bumps the `evictions` counter by
    the number of entries dropped, and `reset_plan_cache_stats` zeroes the
    counters WITHOUT touching cached entries."""
    from repro.dist import halo

    halo.invalidate_halo_plans()
    halo.reset_plan_cache_stats()
    g = citation_like(120, 700, seed=11)
    part = partition_graph(120, g.edge_index, 4, method="bfs", seed=0)
    plan = halo.get_halo_plan(part, g.edge_index)                 # miss
    assert halo.get_halo_plan(part, g.edge_index) is plan         # hit
    s = halo.plan_cache_stats()
    assert s["misses"] == 1 and s["hits"] == 1 and s["evictions"] == 0
    # reset leaves the entry hot: the next get is a HIT on the same object.
    halo.reset_plan_cache_stats()
    s = halo.plan_cache_stats()
    assert s["hits"] == s["misses"] == s["evictions"] == 0 and s["size"] >= 1
    assert halo.get_halo_plan(part, g.edge_index) is plan
    assert halo.plan_cache_stats()["hits"] == 1
    # targeted invalidation counts exactly the dropped entries
    key = halo.graph_fingerprint(part.n_nodes, g.edge_index, None, part.assignment)
    dropped = halo.invalidate_halo_plans(key)
    assert dropped >= 1
    assert halo.plan_cache_stats()["evictions"] == dropped
    # full invalidation keeps accumulating
    halo.get_halo_plan(part, g.edge_index)
    dropped2 = halo.invalidate_halo_plans()
    assert halo.plan_cache_stats()["evictions"] == dropped + dropped2


# --------------------------------------------------- 8-device acceptance runs
def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
    return out.stdout


_PRELUDE = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.partition import partition_graph
from repro.dist.halo import build_halo_plan, get_halo_plan, relocate_node_array, restore_node_array
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.generators import citation_like

g = citation_like(400, 2400, seed=5)
w = np.abs(np.random.default_rng(0).standard_normal(g.n_edges)).astype(np.float32) + 0.1
# Receiver-degree normalization (the GCN Ã convention): row sums of 1 keep
# the aggregation non-amplifying, so wire rounding stays O(eps·|act|) per hop
# instead of growing with the weighted degree.
_deg = np.bincount(g.edge_index[1], weights=w, minlength=g.n_nodes)
w = (w / _deg[g.edge_index[1]]).astype(np.float32)
part = partition_graph(g.n_nodes, g.edge_index, 8, method="bfs", seed=0, refine=True)
x = np.random.default_rng(1).standard_normal((g.n_nodes, 16)).astype(np.float32)
senders = jnp.asarray(g.edge_index[0]); receivers = jnp.asarray(g.edge_index[1])
"""


@pytest.mark.slow
def test_gcn_overlapped_equals_serialized_flat_subprocess():
    """The tentpole acceptance, flat 8-way: the overlapped (split
    interior/boundary) schedule equals both the serialized halo schedule and
    the global forward, for BOTH dataflow orders, and bf16/int8 payloads stay
    within their documented tolerances."""
    code = _PRELUDE + """
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

plan = get_halo_plan(part, g.edge_index, w)
mesh = jax.make_mesh((8,), ("model",))
si, sl, rl, ew = plan.device_arrays()
xb = jnp.asarray(relocate_node_array(plan, x))

def run(pol0, cfg, params):
    def body(fe, a, b, c, d):
        return gcn_forward(params, fe, b, c, d, cfg, pol0.bind_halo(a))
    f = jax.shard_map(
        lambda fe, a, b, c, d: body(fe[0], a[0], b[0], c[0], d[0])[None],
        mesh=mesh, in_specs=(P("model"),) * 5, out_specs=P("model"), check_vma=False,
    )
    return restore_node_array(plan, np.asarray(f(xb, si, sl, rl, ew)))

for dataflow in ("feature_first", "aggregation_first"):
    cfg = GCNConfig(layer_dims=(16, 32, 7), dataflow=dataflow)
    params = gcn_init(jax.random.PRNGKey(0), cfg)
    ref = np.asarray(gcn_forward(params, jnp.asarray(x), senders, receivers,
                                 jnp.asarray(w), cfg, NO_POLICY))
    overlapped = run(ShardingPolicy(comm="halo", halo_overlap=True), cfg, params)
    serialized = run(ShardingPolicy(comm="halo", halo_overlap=False), cfg, params)
    assert np.abs(serialized - ref).max() < 1e-4, dataflow
    assert np.abs(overlapped - ref).max() < 1e-4, dataflow
    # quantized wire payloads, overlapped schedule
    bf16 = run(ShardingPolicy(comm="halo", halo_payload="bf16"), cfg, params)
    assert np.abs(bf16 - ref).max() < 1e-2, (dataflow, np.abs(bf16 - ref).max())
    int8 = run(ShardingPolicy(comm="halo", halo_payload="int8"), cfg, params)
    # int8 documented tolerance (docs/communication.md): per-export-block
    # amax/254 wire rounding through two quantized halo hops, the second on
    # post-matmul activations — measured ~0.026 max-abs here, so 5e-2 abs
    # plus a 1% relative-L2 guard against gross breakage.
    err8 = np.abs(int8 - ref).max()
    rel8 = np.linalg.norm(int8 - ref) / np.linalg.norm(ref)
    assert err8 < 5e-2 and rel8 < 1e-2, (dataflow, err8, rel8)
print("OK")
"""
    _run(code)


@pytest.mark.slow
def test_gcn_overlapped_equals_serialized_hier_subprocess():
    """Same acceptance on the hierarchical 2×4 (pod, model) mesh — the
    two-phase exchange under the overlapped schedule and bf16 payload."""
    code = _PRELUDE + """
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

plan = build_halo_plan(part, g.edge_index, w, axes=("pod", "model"), pods=2)
mesh = jax.make_mesh((2, 4), ("pod", "model"))
sloc, srem, sl, rl, ew = plan.device_arrays()
xb = jnp.asarray(relocate_node_array(plan, x))

def run(pol0, cfg, params):
    def body(fe, a, a2, b, c, d):
        pol = pol0.bind_halo(send_loc=a[0], send_rem=a2[0])
        return gcn_forward(params, fe[0], b[0], c[0], d[0], cfg, pol)[None]
    f = jax.shard_map(body, mesh=mesh, in_specs=(P(("pod", "model")),) * 6,
                      out_specs=P(("pod", "model")), check_vma=False)
    return restore_node_array(plan, np.asarray(f(xb, sloc, srem, sl, rl, ew)))

base = ShardingPolicy(comm="halo", halo_axes=("pod", "model"))
for dataflow in ("feature_first", "aggregation_first"):
    cfg = GCNConfig(layer_dims=(16, 32, 7), dataflow=dataflow)
    params = gcn_init(jax.random.PRNGKey(0), cfg)
    ref = np.asarray(gcn_forward(params, jnp.asarray(x), senders, receivers,
                                 jnp.asarray(w), cfg, NO_POLICY))
    overlapped = run(base, cfg, params)
    serialized = run(dataclasses.replace(base, halo_overlap=False), cfg, params)
    assert np.abs(serialized - ref).max() < 1e-4, dataflow
    assert np.abs(overlapped - ref).max() < 1e-4, dataflow
    bf16 = run(dataclasses.replace(base, halo_payload="bf16"), cfg, params)
    assert np.abs(bf16 - ref).max() < 1e-2, (dataflow, np.abs(bf16 - ref).max())
print("OK")
"""
    _run(code)


@pytest.mark.slow
def test_gcn_split_bsr_overlap_subprocess():
    """backend="bsr" over the SPLIT blocked tables (interior over local
    columns + boundary over the halo block) inside the 8-device shard_map
    equals the global segment forward — flat and hierarchical."""
    code = _PRELUDE + """
from repro.dist.halo import plan_split_blocked_adjacency
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

cfg = GCNConfig(layer_dims=(16, 32, 7), backend="bsr")
params = gcn_init(jax.random.PRNGKey(0), cfg)
ref = np.asarray(gcn_forward(params, jnp.asarray(x), senders, receivers,
                             jnp.asarray(w), GCNConfig(layer_dims=(16, 32, 7)),
                             NO_POLICY))

# flat
plan = get_halo_plan(part, g.edge_index, w)
ia, bd = plan_split_blocked_adjacency(plan)
mesh = jax.make_mesh((8,), ("model",))
si, sl, rl, ew = plan.device_arrays()
iv, ic, il = ia.device_arrays(); bv, bc, bl = bd.device_arrays()
xb = jnp.asarray(relocate_node_array(plan, x))
pol0 = ShardingPolicy(comm="halo")
def body(fe, a, b, c, d, v1, c1, l1, v2, c2, l2):
    pol = pol0.bind_halo(a[0])
    return gcn_forward(params, fe[0], b[0], c[0], d[0], cfg, pol,
                       adjacency=(v1[0], c1[0], l1[0]),
                       adjacency_boundary=(v2[0], c2[0], l2[0]))[None]
f = jax.shard_map(body, mesh=mesh, in_specs=(P("model"),) * 11,
                  out_specs=P("model"), check_vma=False)
out = restore_node_array(plan, np.asarray(f(xb, si, sl, rl, ew, iv, ic, il, bv, bc, bl)))
err = np.abs(out - ref).max()
assert err < 1e-3, ("flat", err)

# hierarchical 2x4 with a bf16 wire on top
plan_h = build_halo_plan(part, g.edge_index, w, axes=("pod", "model"), pods=2)
ia, bd = plan_split_blocked_adjacency(plan_h)
mesh_h = jax.make_mesh((2, 4), ("pod", "model"))
sloc, srem, sl, rl, ew = plan_h.device_arrays()
iv, ic, il = ia.device_arrays(); bv, bc, bl = bd.device_arrays()
xb = jnp.asarray(relocate_node_array(plan_h, x))
pol_h = ShardingPolicy(comm="halo", halo_axes=("pod", "model"), halo_payload="bf16")
def body_h(fe, a, a2, b, c, d, v1, c1, l1, v2, c2, l2):
    pol = pol_h.bind_halo(send_loc=a[0], send_rem=a2[0])
    return gcn_forward(params, fe[0], b[0], c[0], d[0], cfg, pol,
                       adjacency=(v1[0], c1[0], l1[0]),
                       adjacency_boundary=(v2[0], c2[0], l2[0]))[None]
f = jax.shard_map(body_h, mesh=mesh_h, in_specs=(P(("pod", "model")),) * 12,
                  out_specs=P(("pod", "model")), check_vma=False)
out = restore_node_array(plan_h, np.asarray(
    f(xb, sloc, srem, sl, rl, ew, iv, ic, il, bv, bc, bl)))
err_h = np.abs(out - ref).max()
assert err_h < 1e-2, ("hier bf16", err_h)
print("OK", err, err_h)
"""
    _run(code)


@pytest.mark.slow
def test_pna_payload_bf16_subprocess():
    """PNA ships its neighbor table through the same quantized wire: bf16
    payload matches the fp32 global forward within 1e-2 (PNA keeps the
    combined gather — no interior/boundary split — so the payload is the
    whole overlap story for it)."""
    code = _PRELUDE + """
from repro.models.pna import PNAConfig, pna_forward, pna_init

plan = get_halo_plan(part, g.edge_index, w)
mesh = jax.make_mesh((8,), ("model",))
si, sl, rl, ew = plan.device_arrays()
xb = jnp.asarray(relocate_node_array(plan, x))
cfg = PNAConfig(n_layers=2, d_hidden=32, d_in=16, d_out=3)
params = pna_init(jax.random.PRNGKey(1), cfg)
ref = np.asarray(pna_forward(params, jnp.asarray(x), senders, receivers, cfg, NO_POLICY))

def run(pol0):
    def body(fe, a, b, c, d):
        pol = pol0.bind_halo(a)
        mask = (d > 0).astype(jnp.float32)
        return pna_forward(params, fe, b, c, cfg, pol, edge_mask=mask)
    f = jax.shard_map(
        lambda fe, a, b, c, d: body(fe[0], a[0], b[0], c[0], d[0])[None],
        mesh=mesh, in_specs=(P("model"),) * 5, out_specs=P("model"), check_vma=False,
    )
    return restore_node_array(plan, np.asarray(f(xb, si, sl, rl, ew)))

fp32 = run(ShardingPolicy(comm="halo"))
assert np.abs(fp32 - ref).max() < 1e-3
bf16 = run(ShardingPolicy(comm="halo", halo_payload="bf16"))
# PNA's min/max aggregators pass wire rounding straight through (no
# averaging) and the std/scaler terms amplify it — measured ~0.016 max-abs
# vs the GCN's ~0.004, so 5e-2 abs with a 1% relative-L2 guard.
err = np.abs(bf16 - ref).max()
rel = np.linalg.norm(bf16 - ref) / np.linalg.norm(ref)
assert err < 5e-2 and rel < 1e-2, (err, rel)
print("OK", err)
"""
    _run(code)
