"""Halo-exchange plan + collective: invariants and exact equivalence."""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import partition_graph
from repro.dist.halo import build_halo_plan
from repro.graph.generators import citation_like

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(64, 400),
    e=st.integers(100, 2000),
    k=st.sampled_from([4, 8]),
    seed=st.integers(0, 50),
)
def test_halo_plan_accounts_every_edge(n, e, k, seed):
    g = citation_like(n, e, seed=seed)
    part = partition_graph(n, g.edge_index, k, method="bfs", seed=seed)
    plan = build_halo_plan(part, g.edge_index)
    # Every original edge appears exactly once across the device edge lists.
    total_valid = int((plan.edge_w > 0).sum())
    assert total_valid == e
    # Receivers are always local rows; senders index [local ‖ halo].
    assert plan.receivers_l.max() < plan.n_local
    assert plan.senders_l.max() < plan.n_local + plan.k * plan.s_max
    # The permutation is a bijection.
    assert np.array_equal(np.sort(plan.perm), np.arange(n))


def test_halo_plan_wire_volume_below_broadcast():
    g = citation_like(2000, 12000, seed=1)
    part = partition_graph(2000, g.edge_index, 8, method="bfs", seed=0, refine=True)
    plan = build_halo_plan(part, g.edge_index)
    halo_rows = plan.k * plan.s_max          # per device
    broadcast_rows = (plan.k - 1) * plan.n_local
    assert halo_rows < broadcast_rows


@pytest.mark.slow
def test_halo_aggregate_equals_global_subprocess():
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.partition import partition_graph
from repro.dist.halo import build_halo_plan, halo_aggregate
from repro.graph.generators import citation_like
from repro.graph.ops import aggregate

g = citation_like(500, 3000, seed=3)
w = np.abs(np.random.default_rng(0).standard_normal(g.n_edges)).astype(np.float32)
part = partition_graph(g.n_nodes, g.edge_index, 8, method="bfs", seed=0, refine=True)
plan = build_halo_plan(part, g.edge_index, w)
d = 16
z = np.random.default_rng(1).standard_normal((g.n_nodes, d)).astype(np.float32)
zb = np.zeros((8, plan.n_local, d), np.float32)
sizes = np.bincount(part.assignment, minlength=8)
off = 0
for i in range(8):
    zb[i, :sizes[i]] = z[plan.perm[off:off+sizes[i]]]
    off += sizes[i]
mesh = jax.make_mesh((8,), ("model",))
si, sl, rl, ew = plan.device_arrays()
ref = np.asarray(aggregate(jnp.asarray(z), jnp.asarray(g.edge_index[0]),
                           jnp.asarray(g.edge_index[1]), g.n_nodes, jnp.asarray(w)))
refb = np.zeros_like(zb)
off = 0
for i in range(8):
    refb[i, :sizes[i]] = ref[plan.perm[off:off+sizes[i]]]
    off += sizes[i]
for via in ("all_gather", "ppermute"):    # both collective lowerings
    f = jax.shard_map(
        lambda zl, a, b, c, dd: halo_aggregate(zl[0], a[0], b[0], c[0], dd[0], "model", via=via)[None],
        mesh=mesh, in_specs=(P("model"),) * 5, out_specs=P("model"), check_vma=False,
    )
    out = np.asarray(f(jnp.asarray(zb), si, sl, rl, ew))
    err = np.abs(out - refb).max()
    assert err < 1e-4, (via, err)
print("HALO_OK", err)
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, timeout=300)
    assert "HALO_OK" in out.stdout, out.stderr[-1500:]


def test_grouped_moe_equals_flat():
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.nn.moe import MoEConfig, moe_apply, moe_init

    key = jax.random.PRNGKey(0)
    cfg1 = MoEConfig(num_experts=8, top_k=2, d_model=32, d_ff=64, capacity_factor=8.0, groups=1)
    cfg4 = dataclasses.replace(cfg1, groups=4)
    p = moe_init(key, cfg1)
    x = jax.random.normal(key, (128, 32))
    y1, a1 = moe_apply(p, x, cfg1)
    y4, a4 = moe_apply(p, x, cfg4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-6)
    assert abs(float(a1 - a4)) < 1e-6
