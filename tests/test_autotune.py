"""Communication-aware autotuner (docs/autotune.md): quotient-graph pod
mapper invariants, refine_partition determinism/monotonicity, plan-cache
pod_map keying, the pinned predicted==measured calibration contract on the
2×4 worked example, the pinned benchmark-graph crossing win, and the
8-device autotuned-vs-default logits equivalence (slow).
"""
import dataclasses
import os
import subprocess
import sys
import types

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.autotune import (
    BoundaryIndex,
    CandidateConfig,
    _crossing_objective,
    autotune_config,
    comm_stats_from_plan,
    map_parts_to_pods,
    predict_config_cost,
    refine_pod_map,
)
from repro.core.partition import partition_graph, quotient_graph, refine_partition
from repro.dist.halo import build_halo_plan
from repro.graph.generators import citation_like

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _graph(n, e, seed):
    g = citation_like(n, e, seed=seed)
    return g, g.edge_index


# ------------------------------------------------------- quotient graph (S4)
@settings(max_examples=15, deadline=None)
@given(n=st.integers(60, 300), k=st.sampled_from([4, 8]), seed=st.integers(0, 5))
def test_quotient_weights_sum_to_dedup_boundary_rows(n, k, seed):
    """Σ quotient weights == total deduplicated boundary (node, dest-part)
    pairs — the unit the halo export tiers pad — and the weight matrix is
    exactly the BoundaryIndex row-traffic matrix."""
    g, ei = _graph(n, 5 * n, seed)
    part = partition_graph(n, ei, k, method="bfs", seed=0)
    q_ei, q_w = quotient_graph(part, ei)
    index = BoundaryIndex(part, ei)
    assert int(q_w.sum()) == index.pair_node.size
    dense = np.zeros((k, k), np.int64)
    dense[q_ei[0], q_ei[1]] = q_w
    np.testing.assert_array_equal(dense, index.row_traffic)
    assert not np.any(q_ei[0] == q_ei[1])          # self-loops dropped
    assert np.all(q_w > 0)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(80, 300), pods=st.sampled_from([2, 4]), seed=st.integers(0, 4))
def test_pod_map_balanced_and_deterministic(n, pods, seed):
    """map_parts_to_pods hosts exactly k/pods parts per pod and is a pure
    function of its inputs (same call twice → identical array)."""
    k = 8
    g, ei = _graph(n, 5 * n, seed)
    part = partition_graph(n, ei, k, method="bfs", seed=0)
    pm = map_parts_to_pods(part, ei, pods)
    np.testing.assert_array_equal(np.bincount(pm, minlength=pods), k // pods)
    np.testing.assert_array_equal(pm, map_parts_to_pods(part, ei, pods))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(80, 250), seed=st.integers(0, 4), perm_seed=st.integers(0, 100))
def test_pod_map_edge_order_invariance(n, seed, perm_seed):
    """Permuting the edge list changes nothing: the quotient/index dedup via
    np.unique is order-free, so the mapper's output is identical."""
    g, ei = _graph(n, 5 * n, seed)
    part = partition_graph(n, ei, 8, method="bfs", seed=0)
    perm = np.random.default_rng(perm_seed).permutation(ei.shape[1])
    pm_a = map_parts_to_pods(part, ei, 2)
    pm_b = map_parts_to_pods(part, ei[:, perm], 2)
    np.testing.assert_array_equal(pm_a, pm_b)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(80, 250), seed=st.integers(0, 4), map_seed=st.integers(0, 50))
def test_refine_pod_map_monotone_and_balanced(n, seed, map_seed):
    """FM swap passes never increase the crossing objective and preserve the
    exact per-pod part count of ANY balanced starting map."""
    k, pods = 8, 2
    g, ei = _graph(n, 5 * n, seed)
    part = partition_graph(n, ei, k, method="bfs", seed=0)
    index = BoundaryIndex(part, ei)
    start = np.repeat(np.arange(pods), k // pods)
    np.random.default_rng(map_seed).shuffle(start)
    refined = refine_pod_map(start, pods, index)
    assert _crossing_objective(refined, pods, index) <= _crossing_objective(start, pods, index)
    np.testing.assert_array_equal(np.bincount(refined, minlength=pods), k // pods)
    # Idempotent at a local optimum: re-refining moves nothing.
    np.testing.assert_array_equal(refine_pod_map(refined, pods, index), refined)


# ----------------------------------------------------- refine_partition (S2)
@settings(max_examples=10, deadline=None)
@given(n=st.integers(60, 250), k=st.sampled_from([4, 8]), seed=st.integers(0, 4),
       perm_seed=st.integers(0, 100))
def test_refine_partition_edge_order_invariant(n, k, seed, perm_seed):
    g, ei = _graph(n, 4 * n, seed)
    base = partition_graph(n, ei, k, method="block")
    perm = np.random.default_rng(perm_seed).permutation(ei.shape[1])
    a = refine_partition(base.assignment, k, ei[0], ei[1], passes=3)
    b = refine_partition(base.assignment, k, ei[0][perm], ei[1][perm], passes=3)
    np.testing.assert_array_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(60, 250), k=st.sampled_from([4, 8]), seed=st.integers(0, 4),
       passes=st.integers(1, 5))
def test_refine_partition_cut_monotone_and_balance_capped(n, k, seed, passes):
    """The cut never exceeds the input's cut (a worsening pass is reverted)
    and no part ever grows past the balance cap."""
    g, ei = _graph(n, 4 * n, seed)
    base = partition_graph(n, ei, k, method="block")
    src, dst = ei[0], ei[1]
    cut0 = int((base.assignment[src] != base.assignment[dst]).sum())
    refined = refine_partition(base.assignment, k, src, dst, passes=passes)
    cut1 = int((refined[src] != refined[dst]).sum())
    assert cut1 <= cut0
    cap = int(np.ceil(n / k) * 1.05) + 1
    sizes0 = np.bincount(base.assignment, minlength=k)
    sizes1 = np.bincount(refined, minlength=k)
    assert np.all(sizes1 <= np.maximum(sizes0, cap))


# -------------------------------------------- BoundaryIndex calibration
@settings(max_examples=8, deadline=None)
@given(n=st.integers(100, 300), seed=st.integers(0, 4), tuned=st.booleans())
def test_boundary_index_matches_built_plan(n, seed, tuned):
    """index.comm_stats(pods, pod_map) == comm_stats_from_plan(built plan)
    for flat, default-map hierarchical, and autotuned-map hierarchical —
    the analytic model IS the plan geometry."""
    k, pods = 8, 2
    g, ei = _graph(n, 6 * n, seed)
    part = partition_graph(n, ei, k, method="bfs", seed=0, refine=True)
    index = BoundaryIndex(part, ei)
    flat = build_halo_plan(part, ei)
    assert index.comm_stats() == comm_stats_from_plan(flat)
    pm = map_parts_to_pods(part, ei, pods, index=index) if tuned else None
    hier = build_halo_plan(part, ei, axes=("pod", "model"), pods=pods, pod_map=pm)
    assert index.comm_stats(pods, pm) == comm_stats_from_plan(hier)


# ------------------------------------- pinned 2×4 worked example (S3)
def _worked_example():
    g = citation_like(2000, 12000, seed=1)
    part = partition_graph(2000, g.edge_index, 8, method="bfs", seed=0, refine=True)
    return g, part


def test_dryrun_predicted_matches_measured_worked_example():
    """exchange_accounting's ``predicted`` block agrees EXACTLY with the
    measured fields on the docs/communication.md 2×4 worked example — the
    shipped calibration contract, pinned to the documented numbers."""
    from repro.launch.dryrun import exchange_accounting

    g, part = _worked_example()
    plan = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=2)
    # The documented geometry (docs/communication.md §5–§6).
    assert (plan.n_local, plan.s_max, plan.s_loc, plan.s_rem) == (263, 40, 31, 25)
    assert plan.halo_rows_per_device == 374          # 2·25 + 4·(31 + 2·25)
    assert plan.inter_pod_rows_crossing == 25
    assert plan.flat_inter_pod_rows_crossing == 160  # (2−1)·4·40
    assert plan.overlap_fraction() == 0.6869166666666666

    shape = types.SimpleNamespace(d_feat=64)
    for payload, overlap in ((None, False), ("int8", True)):
        cell = types.SimpleNamespace(
            comm="halo", halo_plan=plan, halo_payload=payload, halo_overlap=overlap
        )
        acc = exchange_accounting(cell, shape)
        pred = acc["predicted"]
        for f in (
            "halo_rows_per_device", "broadcast_rows_per_device", "wire_fraction",
            "halo_bytes_per_exchange", "payload", "payload_bits",
            "payload_compression", "overlap", "overlap_fraction",
            "halo_wire_bytes_per_exchange", "halo_exposed_bytes_per_exchange",
            "pods", "intra_pod_rows_per_device", "inter_pod_rows_per_device",
            "inter_pod_rows_crossing", "flat_inter_pod_rows_crossing",
            "inter_pod_bytes_crossing", "flat_inter_pod_bytes_crossing",
        ):
            assert pred[f] == acc[f], (payload, overlap, f, pred[f], acc[f])
    # Pinned fp32 bytes: 374 rows × 64 feats × 4 B.
    cell = types.SimpleNamespace(comm="halo", halo_plan=plan)
    acc = exchange_accounting(cell, shape)
    assert acc["predicted"]["halo_wire_bytes_per_exchange"] == 374 * 64 * 4
    assert acc["predicted"]["halo_exposed_bytes_per_exchange"] == 374 * 64 * 4


def test_predict_config_cost_rejects_pod_mismatch():
    g, part = _worked_example()
    stats = BoundaryIndex(part, g.edge_index).comm_stats(2)
    with pytest.raises(ValueError):
        predict_config_cost(CandidateConfig(pods=1), stats, d_feat=64)


def test_autotune_config_improves_predicted_objective():
    """Coordinate descent on the worked example: the chosen config's
    predicted objective is no worse than the seed defaults', the history is
    non-trivial, and the chosen pod_map is balanced."""
    g, part = _worked_example()
    result = autotune_config(part, g.edge_index, pods=2, d_feat=64,
                             layer_dims=(64, 32, 7))
    assert result.predicted["objective_s"] <= result.baseline["objective_s"]
    assert result.predicted_improvement >= 1.0
    assert result.history[0][0] == "seed defaults" and len(result.history) >= 2
    assert result.config.pods == 2
    if result.config.pod_map is not None:
        pm = np.asarray(result.config.pod_map)
        np.testing.assert_array_equal(np.bincount(pm, minlength=2), 4)


# -------------------------------- pinned benchmark-graph crossing win (S4)
def test_pod_mapper_beats_contiguous_on_benchmark_graph():
    """The pinned BENCH_autotune case (16384 n / 65536 e power-law, shuffled
    node ids, k=32, pods=2): the quotient mapper's deduplicated inter-pod
    crossing rows beat the naive contiguous map by ≥ 1.3× (exact pinned
    values — everything upstream is seeded)."""
    g = citation_like(16384, 65536, n_labels=128, homophily=0.9, seed=1)
    ei = np.random.default_rng(7).permutation(16384)[g.edge_index]
    part = partition_graph(16384, ei, 32, method="bfs", seed=0, refine=True)
    index = BoundaryIndex(part, ei)
    _, s_rem_default = index.tier_sizes(2, None)
    pm = map_parts_to_pods(part, ei, 2, index=index)
    _, s_rem_tuned = index.tier_sizes(2, pm)
    assert (s_rem_default, s_rem_tuned) == (30, 21)
    assert s_rem_default / s_rem_tuned >= 1.3


# --------------------------------------------------- plan cache keying (S1)
def test_plan_cache_default_and_pod_map_coexist():
    """Default-map and autotuned-map hierarchical plans of the SAME graph
    cache under distinct keys (pod_map fingerprint in the axes component),
    stay identity-stable, and ONE graph-scoped invalidation evicts every
    flavor (mirrors test_plan_cache_flat_and_hier_coexist)."""
    from repro.dist import halo

    halo.invalidate_halo_plans()
    g = citation_like(300, 1800, seed=2)
    part = partition_graph(300, g.edge_index, 8, method="bfs", seed=0)
    pm = map_parts_to_pods(part, g.edge_index, 2)
    flat = halo.get_halo_plan(part, g.edge_index)
    default = halo.get_halo_plan(part, g.edge_index, pods=2)
    tuned = halo.get_halo_plan(part, g.edge_index, pods=2, pod_map=pm)
    assert default is not tuned and tuned.is_hierarchical
    # All three hit their own entries on re-request...
    assert halo.get_halo_plan(part, g.edge_index) is flat
    assert halo.get_halo_plan(part, g.edge_index, pods=2) is default
    assert halo.get_halo_plan(part, g.edge_index, pods=2, pod_map=pm) is tuned
    # ...and an equal map ARRAY (not object) resolves to the same entry.
    assert halo.get_halo_plan(part, g.edge_index, pods=2, pod_map=pm.copy()) is tuned
    assert halo.plan_cache_stats()["size"] >= 3
    # One scoped sweep drops every flavor of this graph.
    evicted = halo.invalidate_halo_plans(
        halo.graph_fingerprint(part.n_nodes, g.edge_index, None, part.assignment)
    )
    assert evicted >= 3
    assert halo.get_halo_plan(part, g.edge_index, pods=2) is not default
    assert halo.get_halo_plan(part, g.edge_index, pods=2, pod_map=pm) is not tuned


def test_pod_map_fingerprint_distinguishes_maps():
    from repro.dist.halo import pod_map_fingerprint, validate_pod_map

    a = np.array([0, 0, 1, 1], np.int64)
    b = np.array([0, 1, 0, 1], np.int64)
    assert pod_map_fingerprint(None) == "contig"
    assert pod_map_fingerprint(a) == pod_map_fingerprint(a.copy())
    assert pod_map_fingerprint(a) != pod_map_fingerprint(b)
    with pytest.raises(ValueError):
        validate_pod_map(np.array([0, 0, 0, 1]), 4, 2)   # unbalanced
    with pytest.raises(ValueError):
        validate_pod_map(np.array([0, 0, 1, 2]), 4, 2)   # pod id out of range


# -------------------------------------------- launch CLI record (fast path)
def test_run_autotune_record_schema_small():
    """End-to-end CLI record on a small graph: calibration block empty (the
    contract), measured improvement fields present, config JSON-round-trips
    into the dryrun --autotune-config consumer shape."""
    from repro.launch.autotune import run_autotune

    rec = run_autotune(n=2000, e=12000, k=8, pods=2, d_feat=64,
                       layer_dims=(64, 32, 7), shuffle_seed=None, rounds=2)
    assert rec["calibration_mismatches"] == {}
    assert rec["improvement"]["crossing_improvement"] >= 1.0
    assert rec["measured"]["autotuned"]["inter_pod_rows_crossing"] <= \
        rec["measured"]["default"]["inter_pod_rows_crossing"]
    cfg = rec["config"]
    assert cfg["pods"] == 2 and cfg["backend"] in ("segment", "bsr")
    assert sorted(np.bincount(cfg["pod_map"], minlength=2)) == [4, 4] \
        if cfg["pod_map"] is not None else True


# ----------------------------------------- 8-device 2×4 acceptance (slow)
def _run(code: str) -> None:
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])


@pytest.mark.slow
def test_autotuned_pod_map_logits_equal_default_subprocess():
    """The paper GCN on the 8-device 2×4 mesh: the autotuned pod_map plan
    produces the same logits as the default contiguous mapping (< 1e-4) —
    placement moves rows between tiers, never changes the math — while
    shipping no more inter-pod crossing rows."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.autotune import map_parts_to_pods
from repro.core.partition import partition_graph
from repro.dist.halo import build_halo_plan, relocate_node_array, restore_node_array
from repro.dist.policy import ShardingPolicy
from repro.graph.generators import citation_like
from repro.launch.mesh import make_halo_mesh
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

g = citation_like(2000, 12000, seed=1)
part = partition_graph(g.n_nodes, g.edge_index, 8, method="bfs", seed=0, refine=True)
pm = map_parts_to_pods(part, g.edge_index, 2)
default = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=2)
tuned = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=2, pod_map=pm)
assert tuned.inter_pod_rows_crossing <= default.inter_pod_rows_crossing
mesh = make_halo_mesh(2, 4, pod_map=pm)   # validation path; raveling unchanged
x = np.random.default_rng(1).standard_normal((g.n_nodes, 16)).astype(np.float32)
cfg = GCNConfig(layer_dims=(16, 32, 7), dataflow="feature_first")
params = gcn_init(jax.random.PRNGKey(0), cfg)
AX = ("pod", "model")

def run(plan):
    sloc, srem, sl, rl, ew = plan.device_arrays()
    xb = jnp.asarray(relocate_node_array(plan, x))
    pol0 = ShardingPolicy(comm="halo", halo_axes=AX)
    f = jax.shard_map(
        lambda fe, a, b, c, d, e: gcn_forward(
            params, fe[0], c[0], d[0], e[0], cfg,
            pol0.bind_halo(send_loc=a[0], send_rem=b[0]))[None],
        mesh=mesh, in_specs=(P(AX),) * 6, out_specs=P(AX), check_vma=False,
    )
    return restore_node_array(plan, np.asarray(f(xb, sloc, srem, sl, rl, ew)))

err = np.abs(run(tuned) - run(default)).max()
assert err < 1e-4, err
print("OK", err)
"""
    _run(code)
