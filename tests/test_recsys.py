"""RecSys substrate: embedding bag, hashing, FM identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.recsys.embedding import embedding_bag, field_lookup, hash_ids


def test_embedding_bag_matches_manual():
    r = np.random.default_rng(0)
    table = jnp.asarray(r.standard_normal((50, 8)), jnp.float32)
    ids = jnp.asarray([0, 1, 2, 10, 10, 49])
    segs = jnp.asarray([0, 0, 1, 1, 2, 2])
    out = embedding_bag(table, ids, segs, num_bags=4)
    ref = np.zeros((4, 8), np.float32)
    for i, s in zip(np.asarray(ids), np.asarray(segs)):
        ref[s] += np.asarray(table)[i]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
    out_mean = embedding_bag(table, ids, segs, num_bags=4, mode="mean")
    ref[0] /= 2; ref[1] /= 2; ref[2] /= 2
    np.testing.assert_allclose(np.asarray(out_mean), ref, rtol=1e-6)


def test_embedding_bag_weighted():
    table = jnp.eye(4, dtype=jnp.float32)
    out = embedding_bag(
        table, jnp.asarray([0, 1]), jnp.asarray([0, 0]), num_bags=1,
        weights=jnp.asarray([2.0, 3.0]),
    )
    np.testing.assert_allclose(np.asarray(out)[0], [2, 3, 0, 0])


@settings(max_examples=20, deadline=None)
@given(bucket=st.integers(2, 10_000), seed=st.integers(0, 100))
def test_hash_ids_range_and_determinism(bucket, seed):
    r = np.random.default_rng(seed)
    raw = jnp.asarray(r.integers(0, 2**31 - 1, 256), jnp.int32)
    h1 = hash_ids(raw, bucket, field_salt=3)
    h2 = hash_ids(raw, bucket, field_salt=3)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    assert int(h1.min()) >= 0 and int(h1.max()) < bucket
    # different salts decorrelate
    h3 = hash_ids(raw, bucket, field_salt=4)
    if bucket > 100:
        assert np.mean(np.asarray(h1) == np.asarray(h3)) < 0.2


def test_field_lookup_offsets():
    table = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    ids = jnp.asarray([[0, 1], [2, 0]])
    offs = jnp.asarray([0, 5])
    out = field_lookup(table, ids, offs)
    np.testing.assert_allclose(np.asarray(out[0, 1]), np.asarray(table[6]))
    np.testing.assert_allclose(np.asarray(out[1, 0]), np.asarray(table[2]))


def test_deepfm_fm_equals_pairwise():
    from repro.models.deepfm import fm_interaction

    r = np.random.default_rng(1)
    emb = jnp.asarray(r.standard_normal((16, 6, 4)), jnp.float32)
    fast = fm_interaction(emb)
    slow = np.zeros(16, np.float32)
    e = np.asarray(emb)
    for i in range(6):
        for j in range(i + 1, 6):
            slow += (e[:, i] * e[:, j]).sum(-1)
    np.testing.assert_allclose(np.asarray(fast), slow, rtol=1e-4, atol=1e-4)
