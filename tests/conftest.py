"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device (the dry-run sets its own 512-device flag in a fresh process)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_rotation(rng) -> np.ndarray:
    a = np.linalg.qr(rng.standard_normal((3, 3)))[0]
    if np.linalg.det(a) < 0:
        a[:, 0] *= -1
    return a.astype(np.float32)
