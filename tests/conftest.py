"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device (the dry-run sets its own 512-device flag in a fresh process).

Also registers the vendored `hypothesis` fallback (tests/_hypothesis_stub.py)
when the real package is not installed, so the property tests run in minimal
environments (e.g. the pinned CPU container). Install `hypothesis`
(requirements-dev.txt) to get real shrinking and coverage.
"""
import importlib.util
import pathlib
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401  (the real thing wins when available)
except ModuleNotFoundError:
    _stub_path = pathlib.Path(__file__).with_name("_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies

# Flaky-seed hygiene: property tests must reproduce locally from a CI log.
# Real hypothesis gets a pinned derandomize profile; the vendored stub is
# already derandomized (per-test crc32 seeds) and accepts the same calls.
from hypothesis import settings as _h_settings  # noqa: E402

try:
    _h_settings.register_profile(
        "repro-derandomize", _h_settings(derandomize=True, deadline=None))
    _h_settings.load_profile("repro-derandomize")
except Exception:  # pragma: no cover — exotic hypothesis versions
    pass


def pytest_addoption(parser):
    parser.addoption(
        "--delta-seed",
        action="store",
        type=int,
        default=0,
        help="Extra seed mixed into the graph-delta mutation suites "
             "(tests/test_graph_delta.py). CI failures print the active "
             "seed; rerun with `--delta-seed=<n>` to reproduce locally.",
    )


@pytest.fixture
def delta_seed(request) -> int:
    """The --delta-seed CLI value (0 by default, pinned in CI)."""
    return int(request.config.getoption("--delta-seed"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_rotation(rng) -> np.ndarray:
    a = np.linalg.qr(rng.standard_normal((3, 3)))[0]
    if np.linalg.det(a) < 0:
        a[:, 0] *= -1
    return a.astype(np.float32)
