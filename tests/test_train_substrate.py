"""Optimizers, checkpointing, fault tolerance, compression, elasticity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train.checkpoint import available_steps, latest_step, restore_checkpoint, save_checkpoint
from repro.train.compression import (
    error_feedback_update,
    int8_compress,
    int8_decompress,
    topk_compress,
)
from repro.train.elastic import elastic_replan, scale_batch
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import adam, adamw, lamb, sgd

KEY = jax.random.PRNGKey(0)


# -------------------------------------------------------------------- optims
@pytest.mark.parametrize("make", [lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
                                  lambda: adam(0.05), lambda: adamw(0.05), lambda: lamb(0.05)])
def test_optimizers_minimize_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_adam_matches_reference_formula():
    opt = adam(lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    p = {"x": jnp.asarray([1.0])}
    s = opt.init(p)
    g = {"x": jnp.asarray([0.5])}
    p1, s1 = opt.update(g, s, p)
    m = 0.1 * 0.5
    v = 0.001 * 0.25
    upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.999)) + 1e-8)
    np.testing.assert_allclose(float(p1["x"][0]), 1.0 - 0.1 * upd, rtol=1e-6)


# --------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip_and_gc():
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.int32), "none": None},
    }
    with tempfile.TemporaryDirectory() as d:
        for step in [1, 2, 3, 4, 5]:
            save_checkpoint(d, step, tree, metadata={"s": step}, keep=3)
        assert available_steps(d) == [3, 4, 5]
        assert latest_step(d) == 5
        step, restored, meta = restore_checkpoint(d, tree)
        assert step == 5 and meta["s"] == 5
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
        assert restored["nested"]["none"] is None


def test_checkpoint_atomicity_partial_tmp_ignored():
    tree = {"a": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        # Simulate a crash mid-save: orphan tmp dir + step dir without manifest.
        os.makedirs(os.path.join(d, ".tmp_step_2"))
        os.makedirs(os.path.join(d, "step_3"))
        assert latest_step(d) == 1


def test_trainer_crash_and_resume():
    params = {"w": jnp.asarray([4.0])}
    loss_fn = lambda p, b: jnp.sum((p["w"] - b) ** 2)
    batch = jnp.asarray([1.0])
    with tempfile.TemporaryDirectory() as d:
        cfg = TrainerConfig(ckpt_dir=d, ckpt_every=5, log_every=1000)
        tr = Trainer(loss_fn, adam(0.1), params, cfg)
        gen = iter(lambda: batch, None)
        with pytest.raises(RuntimeError, match="injected crash"):
            tr.fit(gen, max_steps=50, crash_at=17)
        tr2 = Trainer(loss_fn, adam(0.1), params, cfg)
        assert tr2.resume() and tr2.step == 15
        losses = tr2.fit(gen, max_steps=150)
        assert losses[-1] < 1e-2


def test_trainer_straggler_monitor():
    import time

    params = {"w": jnp.asarray([1.0])}
    loss_fn = lambda p, b: jnp.sum(p["w"] ** 2)
    tr = Trainer(loss_fn, sgd(0.01), params, TrainerConfig(log_every=1000, straggler_factor=5.0))

    # Inject a stall INSIDE the timed step (a straggling device, not input).
    orig, calls = tr._step_fn, {"n": 0}

    def slow_step(*a):
        calls["n"] += 1
        if calls["n"] == 20:
            time.sleep(0.3)
        return orig(*a)

    tr._step_fn = slow_step
    tr.fit(iter(lambda: jnp.asarray([0.0]), None), max_steps=25)
    assert any(ev["step"] >= 20 for ev in tr.straggler_events)


# --------------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(4, 2000))
def test_int8_roundtrip_error_bound(seed, n):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal(n), jnp.float32)
    q, s = int8_compress(x)
    err = jnp.abs(int8_decompress(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_time():
    """With error feedback, the sum of compressed grads tracks the sum of
    true grads (residual stays bounded) — the 1-bit-SGD guarantee."""
    r = np.random.default_rng(0)
    residual = {"g": jnp.zeros(64)}
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    chan = lambda g: topk_compress(g, 0.25)
    for i in range(50):
        g = {"g": jnp.asarray(r.standard_normal(64), jnp.float32)}
        sent, residual = error_feedback_update(g, residual, chan)
        total_true += np.asarray(g["g"])
        total_sent += np.asarray(sent["g"])
    drift = np.abs(total_true - total_sent)
    assert float(np.abs(np.asarray(residual["g"])).max()) < 20
    np.testing.assert_allclose(total_sent + np.asarray(residual["g"]), total_true, atol=1e-3)


def test_trainer_with_compression_converges():
    params = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(16), dtype=jnp.float32)}
    loss_fn = lambda p, b: jnp.sum(p["w"] ** 2)
    tr = Trainer(loss_fn, adam(0.05), params,
                 TrainerConfig(log_every=1000, compress_grads=True))
    losses = tr.fit(iter(lambda: jnp.zeros(1), None), max_steps=150)
    assert losses[-1] < 1e-2


# ------------------------------------------------------------------- elastic
@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 512), m=st.sampled_from([1, 2, 4, 8, 16]))
def test_elastic_replan_fits_and_preserves_model_axis(n, m):
    plan = elastic_replan(n, m)
    assert plan.n_devices <= n
    if n >= m:
        assert plan.shape[1] == m          # model axis preserved
    assert plan.shape[0] >= 1


def test_scale_batch_keeps_per_device_constant():
    assert scale_batch(256, 32, 28) == 224
    assert scale_batch(256, 32, 32) == 256
