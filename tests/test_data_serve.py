"""Sharded data pipeline + continuous-batching scheduler."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train.data import ShardedStream, click_batch_fn, epoch_permutation, token_batch_fn


@settings(max_examples=20, deadline=None)
@given(
    n_hosts=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 100),
    step=st.integers(0, 50),
)
def test_host_shards_tile_the_global_batch(n_hosts, seed, step):
    gb = 32
    fn = token_batch_fn(vocab=97, seq_len=8)
    shards = [
        ShardedStream(fn, gb, n_hosts=n_hosts, host_id=h, seed=seed).batch_at(step)
        for h in range(n_hosts)
    ]
    full = np.concatenate(shards, axis=0)
    ref = ShardedStream(fn, gb, n_hosts=1, host_id=0, seed=seed).batch_at(step)
    np.testing.assert_array_equal(full, ref)


def test_stream_resume_exact():
    fn = click_batch_fn(n_fields=5, rows_per_field=100)
    s1 = ShardedStream(fn, 16, seed=3)
    batches = [next(s1) for _ in range(10)]
    # crash at step 6 → resume from checkpointed step
    s2 = ShardedStream(fn, 16, seed=3, start_step=6)
    for i in range(6, 10):
        b = next(s2)
        np.testing.assert_array_equal(b["ids"], batches[i]["ids"])


def test_epoch_permutation_consistent_across_hosts():
    p1 = epoch_permutation(1000, epoch=4, seed=7)
    p2 = epoch_permutation(1000, epoch=4, seed=7)
    np.testing.assert_array_equal(p1, p2)
    assert not np.array_equal(p1, epoch_permutation(1000, epoch=5, seed=7))
    assert np.array_equal(np.sort(p1), np.arange(1000))


# ------------------------------------------------------------------ serving
def _tiny_lm():
    from repro.models.transformer_lm import LMConfig, lm_init

    cfg = LMConfig("tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=101)
    return cfg, lm_init(jax.random.PRNGKey(0), cfg)


def test_continuous_batcher_matches_sequential_decode():
    """Continuous batching produces exactly the tokens a one-request-at-a-
    time greedy decode produces (slot interleaving must not change math)."""
    from repro.models.transformer_lm import lm_decode_step, lm_init_cache
    from repro.serve.scheduler import ContinuousBatcher, Request

    cfg, params = _tiny_lm()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=p).astype(np.int32) for p in (3, 5, 4, 6, 2)]

    # Reference: sequential greedy decode per request.
    import jax.numpy as jnp

    def reference(prompt, n_new):
        cache = lm_init_cache(cfg, 1, 32)
        tok = None
        out = []
        for t in range(len(prompt) + n_new - 1):
            feed = prompt[t] if t < len(prompt) else tok
            logits, cache = lm_decode_step(
                params, cache, jnp.asarray([feed]), jnp.asarray(t, jnp.int32), cfg
            )
            if t >= len(prompt) - 1:
                tok = int(np.argmax(np.asarray(logits)[0]))
                out.append(tok)
        return out

    n_new = 4
    refs = [reference(p, n_new) for p in prompts]

    # Continuous batching with fewer slots than requests (forces turnover).
    cb = ContinuousBatcher(params, cfg, n_slots=2, max_len=32)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    finished = cb.run_until_drained()
    assert len(finished) == len(prompts)
    by_rid = {r.rid: r.generated for r in finished}
    for i, ref in enumerate(refs):
        assert by_rid[i] == ref, (i, by_rid[i], ref)


def test_batcher_eos_first_decode_step_retires_and_readmits():
    """A request whose very first decode step emits EOS must retire in that
    same step(), and the freed slot must be refilled from the pending queue
    within the same step() (not one engine iteration later)."""
    from repro.serve.scheduler import ContinuousBatcher, Request

    cfg, params = _tiny_lm()

    # Discover the token greedy decode emits first for this prompt.
    probe = ContinuousBatcher(params, cfg, n_slots=1, max_len=16)
    probe.submit(Request(rid=0, prompt=np.asarray([7], np.int32), max_new_tokens=2))
    probe.run_until_drained()
    eos = probe.finished[0].generated[0]

    cb = ContinuousBatcher(params, cfg, n_slots=1, max_len=16)
    cb.submit(Request(rid=0, prompt=np.asarray([7], np.int32), max_new_tokens=4, eos_id=eos))
    cb.submit(Request(rid=1, prompt=np.asarray([1, 2], np.int32), max_new_tokens=2))
    cb.step()  # first decode step emits EOS
    assert [r.rid for r in cb.finished] == [0]
    assert cb.finished[0].generated == [eos]
    assert cb.active == 1, "freed slot must be re-admitted in the same step()"
    assert cb.slot_req[0].rid == 1 and not cb.pending
    cb.run_until_drained()
    assert len(cb.finished) == 2 and len(cb.finished[1].generated) == 2

    # Multi-token prompt variant: EOS on the first post-prefill step.
    cb2 = ContinuousBatcher(params, cfg, n_slots=1, max_len=16)
    cb2.submit(Request(rid=0, prompt=np.asarray([3, 7], np.int32), max_new_tokens=4, eos_id=None))
    cb2.step()
    first = None
    while cb2.active and first is None:
        cb2.step()
        if cb2.finished or (cb2.slot_req[0] and cb2.slot_req[0].generated):
            first = (cb2.finished or [cb2.slot_req[0]])[0].generated[0]
    cb3 = ContinuousBatcher(params, cfg, n_slots=1, max_len=16)
    cb3.submit(Request(rid=0, prompt=np.asarray([3, 7], np.int32), max_new_tokens=4, eos_id=first))
    cb3.step()  # prefill
    assert not cb3.finished
    cb3.step()  # first decode step → EOS → retire
    assert [r.rid for r in cb3.finished] == [0]
    assert cb3.finished[0].generated == [first]


def test_batcher_slot_turnover_and_capacity():
    from repro.serve.scheduler import ContinuousBatcher, Request

    cfg, params = _tiny_lm()
    cb = ContinuousBatcher(params, cfg, n_slots=3, max_len=16)
    for i in range(7):
        cb.submit(Request(rid=i, prompt=np.asarray([1, 2, 3], np.int32), max_new_tokens=3))
    finished = cb.run_until_drained()
    assert len(finished) == 7
    assert all(len(r.generated) == 3 for r in finished)
    assert cb.active == 0 and not cb.pending
