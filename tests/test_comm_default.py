"""The default GNN communication path (DESIGN.md §8): halo vs broadcast.

Pins the PR-2 contract: full-graph `build_cell` GNN cells default to the
halo exchange, model forwards produce IDENTICAL outputs under the halo and
broadcast schedules (fp32 tolerance), and the halo default moves strictly
fewer bytes than the broadcast escape hatch on the 8-device mesh.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> None:
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])


_PRELUDE = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.partition import partition_graph
from repro.dist.halo import get_halo_plan, relocate_node_array, restore_node_array
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.generators import citation_like

g = citation_like(400, 2400, seed=5)
w = np.abs(np.random.default_rng(0).standard_normal(g.n_edges)).astype(np.float32) + 0.1
part = partition_graph(g.n_nodes, g.edge_index, 8, method="bfs", seed=0, refine=True)
plan = get_halo_plan(part, g.edge_index, w)
mesh = jax.make_mesh((8,), ("model",))
si, sl, rl, ew = plan.device_arrays()
x = np.random.default_rng(1).standard_normal((g.n_nodes, 16)).astype(np.float32)
xb = jnp.asarray(relocate_node_array(plan, x))
senders = jnp.asarray(g.edge_index[0]); receivers = jnp.asarray(g.edge_index[1])
halo_pol = ShardingPolicy(comm="halo")
"""


@pytest.mark.slow
def test_gcn_halo_equals_broadcast_subprocess():
    """The paper GCN: halo shard_map forward == global forward, per node."""
    code = _PRELUDE + """
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

cfg = GCNConfig(layer_dims=(16, 32, 7), dataflow="feature_first")
params = gcn_init(jax.random.PRNGKey(0), cfg)
ref = np.asarray(gcn_forward(params, jnp.asarray(x), senders, receivers,
                             jnp.asarray(w), cfg, NO_POLICY))

def body(fe, a, b, c, d):
    pol = halo_pol.bind_halo(a)
    return gcn_forward(params, fe, b, c, d, cfg, pol)

f = jax.shard_map(
    lambda fe, a, b, c, d: body(fe[0], a[0], b[0], c[0], d[0])[None],
    mesh=mesh, in_specs=(P("model"),) * 5, out_specs=P("model"), check_vma=False,
)
out = restore_node_array(plan, np.asarray(f(xb, si, sl, rl, ew)))
err = np.abs(out - ref).max()
assert err < 1e-4, err
print("OK", err)
"""
    _run(code)


@pytest.mark.slow
def test_pna_halo_equals_broadcast_subprocess():
    """PNA (mean/max/min/std aggregators + degree scalers): halo == global.
    Exercises the masked multi-aggregator path (plan padding edges)."""
    code = _PRELUDE + """
from repro.models.pna import PNAConfig, pna_forward, pna_init

cfg = PNAConfig(n_layers=2, d_hidden=32, d_in=16, d_out=3)
params = pna_init(jax.random.PRNGKey(1), cfg)
ref = np.asarray(pna_forward(params, jnp.asarray(x), senders, receivers, cfg, NO_POLICY))

def body(fe, a, b, c, d):
    pol = halo_pol.bind_halo(a)
    mask = (d > 0).astype(jnp.float32)
    return pna_forward(params, fe, b, c, cfg, pol, edge_mask=mask)

f = jax.shard_map(
    lambda fe, a, b, c, d: body(fe[0], a[0], b[0], c[0], d[0])[None],
    mesh=mesh, in_specs=(P("model"),) * 5, out_specs=P("model"), check_vma=False,
)
out = restore_node_array(plan, np.asarray(f(xb, si, sl, rl, ew)))
err = np.abs(out - ref).max()
# fp32 tolerance: the std aggregator's E[x^2]-E[x]^2 cancellation amplifies
# reduction-order differences between the sharded and global programs.
assert err < 1e-3, err
print("OK", err)
"""
    _run(code)


@pytest.mark.slow
def test_egnn_halo_equals_broadcast_subprocess():
    """EGNN (coordinate + feature updates): halo == global, both outputs."""
    code = _PRELUDE + """
from repro.models.egnn import EGNNConfig, egnn_forward, egnn_init

cfg = EGNNConfig(n_layers=2, d_hidden=24, d_in=16, d_out=2)
params = egnn_init(jax.random.PRNGKey(2), cfg)
pos = np.random.default_rng(3).standard_normal((g.n_nodes, 3)).astype(np.float32)
pb = jnp.asarray(relocate_node_array(plan, pos))
ref, ref_x = egnn_forward(params, jnp.asarray(x), jnp.asarray(pos), senders, receivers, cfg, NO_POLICY)
ref, ref_x = np.asarray(ref), np.asarray(ref_x)

def body(fe, po, a, b, c, d):
    pol = halo_pol.bind_halo(a)
    mask = (d > 0).astype(jnp.float32)
    return egnn_forward(params, fe, po, b, c, cfg, pol, edge_mask=mask)

f = jax.shard_map(
    lambda fe, po, a, b, c, d: tuple(o[None] for o in body(fe[0], po[0], a[0], b[0], c[0], d[0])),
    mesh=mesh, in_specs=(P("model"),) * 6, out_specs=(P("model"), P("model")),
    check_vma=False,
)
out_h, out_x = f(xb, pb, si, sl, rl, ew)
err = max(
    np.abs(restore_node_array(plan, np.asarray(out_h)) - ref).max(),
    np.abs(restore_node_array(plan, np.asarray(out_x)) - ref_x).max(),
)
assert err < 1e-4, err
print("OK", err)
"""
    _run(code)


@pytest.mark.slow
def test_default_cell_wire_below_broadcast_subprocess():
    """Acceptance pin: the default full-graph cell is halo, and its dry-run
    bytes-moved is strictly below the broadcast schedule on 8 devices —
    both analytically (k·s_max < (k−1)·n_local rows) and in the compiled
    HLO's per-device collective bytes."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import jax
from repro.configs import get_arch
from repro.launch.dryrun import collective_bytes, exchange_accounting
from repro.launch.steps import build_cell

mesh = jax.make_mesh((1, 8), ("data", "model"))
spec = get_arch("pna")
shape = spec.shapes["full_graph_sm"]
cell = build_cell(spec, shape, mesh)                    # the default
assert cell.comm == "halo", cell.comm
ex = exchange_accounting(cell, shape)
assert ex["halo_rows_per_device"] < ex["broadcast_rows_per_device"], ex
assert ex["wire_fraction"] < 1.0, ex
halo = collective_bytes(cell.lower(mesh).compile().as_text())
cell_b = build_cell(spec, shape, mesh, comm="broadcast")
assert cell_b.comm == "broadcast"
bcast = collective_bytes(cell_b.lower(mesh).compile().as_text())
assert halo["all-gather"] < bcast["all-gather"], (halo, bcast)
assert halo["total"] < bcast["total"], (halo, bcast)
print("OK", ex["wire_fraction"], halo["total"] / max(bcast["total"], 1))
"""
    _run(code)


def test_default_cell_compiles_one_device():
    """The halo default degenerates cleanly to k=1 (s_max=0, empty exchange)
    on the local mesh — the same code path unit tests and CPU examples use."""
    import jax

    from repro.configs import get_arch
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_cell

    mesh = make_local_mesh()
    spec = get_arch("pna")
    cell = build_cell(spec, spec.shapes["full_graph_sm"], mesh)
    assert cell.comm == "halo" and cell.halo_plan.k == 1
    assert cell.halo_plan.s_max == 0
    compiled = cell.lower(mesh).compile()
    assert (compiled.cost_analysis() or {}).get("flops", 0) > 0
