"""repro.obs unit contracts (ISSUE 8 satellite):

* deterministic snapshots — two identical recording runs produce
  byte-identical ``to_json`` output,
* histogram percentiles vs a numpy oracle — error bounded by the width of
  the bucket the estimate falls in; p0/p100 exact,
* Chrome trace-event schema — every complete event carries
  ``ph``/``ts``/``dur``/``pid``/``tid`` and the export round-trips JSON,
* the PINNED zero-overhead contract — with obs disabled, the module
  helpers and the instrument recorders allocate nothing measurable on the
  hot path.
"""
import json
import sys
from bisect import bisect_left

import numpy as np
import pytest

from repro.obs import metrics, trace
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    exponential_buckets,
)
from repro.obs.trace import TraceRecorder


@pytest.fixture(autouse=True)
def _isolate_obs():
    """Never leak enabled obs state (or recorded series) into other tests."""
    old_reg = metrics.set_default_registry(MetricsRegistry())
    was_enabled = metrics.enabled()
    metrics.disable()
    old_tracer = trace.set_default_tracer(None)
    yield
    metrics.disable()
    metrics.set_default_registry(old_reg)
    if was_enabled:
        metrics.enable()
    trace.set_default_tracer(old_tracer)


# ------------------------------------------------------------ instruments
def test_counter_monotonic_and_rejects_negative():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1.0)


def test_gauge_set_add():
    g = Gauge()
    g.set(4.0)
    g.add(-1.5)
    assert g.value == 2.5


def test_registry_name_kind_conflict_is_error():
    reg = MetricsRegistry()
    reg.counter("x.events")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x.events")


def test_labels_normalized_and_keyed():
    reg = MetricsRegistry()
    a = reg.counter("c", (("b", "2"), ("a", "1")))
    b = reg.counter("c", {"a": 1, "b": 2})        # dict, ints — same series
    assert a is b
    assert "c{a=1,b=2}" in reg.snapshot()


def test_exponential_buckets_validation():
    assert len(exponential_buckets(1.0, 2.0, 4)) == 4
    with pytest.raises(ValueError):
        exponential_buckets(0.0, 2.0, 4)
    with pytest.raises(ValueError):
        exponential_buckets(1.0, 1.0, 4)


# ------------------------------------------------- deterministic snapshots
def _record(reg: MetricsRegistry) -> None:
    reg.counter("halo.exchanges").inc(3)
    reg.gauge("halo.wire_bytes_per_exchange").set(81920.0)
    reg.gauge("bsr.executed_tiles", (("scope", "plan"),)).set(1305)
    h = reg.histogram("serve.latency_ms")
    for v in (0.3, 1.7, 2.2, 9.5, 0.3):
        h.observe(v)


def test_snapshot_deterministic_across_identical_runs(tmp_path):
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    _record(r1)
    _record(r2)
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    t1 = r1.to_json(str(p1))
    t2 = r2.to_json(str(p2))
    assert t1 == t2
    assert p1.read_text() == p2.read_text()
    # and the snapshot is sorted, JSON-round-trippable pure data
    snap = json.loads(t1)
    assert list(snap) == sorted(snap)
    assert snap["halo.exchanges"] == {"type": "counter", "value": 3.0}


def test_snapshot_insertion_order_independent():
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    r1.counter("a").inc()
    r1.gauge("b").set(1)
    r2.gauge("b").set(1)
    r2.counter("a").inc()
    assert r1.to_json() == r2.to_json()


# ------------------------------------------------ histogram vs numpy oracle
def _bucket_width_at(h: Histogram, value: float) -> float:
    """Width of the histogram bucket containing ``value`` (clamped to the
    recorded min/max, matching the interpolation rule)."""
    i = bisect_left(h.bounds, value)
    lo = h.bounds[i - 1] if i > 0 else h.min
    hi = h.bounds[i] if i < len(h.bounds) else h.max
    return max(min(hi, h.max) - max(lo, h.min), 0.0)


@pytest.mark.parametrize("seed,scale", [(0, 1.0), (1, 37.0), (2, 0.004)])
def test_percentiles_within_one_bucket_of_numpy(seed, scale):
    rng = np.random.default_rng(seed)
    data = rng.lognormal(mean=0.0, sigma=1.2, size=4000) * scale
    h = Histogram()
    for v in data:
        h.observe(float(v))
    for p in (1, 10, 25, 50, 75, 90, 99):
        oracle = float(np.percentile(data, p))
        est = h.percentile(p)
        width = max(_bucket_width_at(h, oracle), _bucket_width_at(h, est))
        assert abs(est - oracle) <= width, (p, est, oracle, width)
    assert h.percentile(0) == pytest.approx(float(data.min()))
    assert h.percentile(100) == pytest.approx(float(data.max()))
    assert h.count == len(data)
    assert h.mean == pytest.approx(float(data.mean()))


def test_histogram_single_value_stays_exact():
    h = Histogram()
    for _ in range(10):
        h.observe(3.25)
    for p in (0, 50, 99, 100):
        assert h.percentile(p) == 3.25


def test_empty_histogram_and_bad_percentile():
    h = Histogram()
    assert h.percentile(50) == 0.0
    with pytest.raises(ValueError):
        h.percentile(101)


# --------------------------------------------------- chrome trace schema
def test_chrome_trace_schema_and_roundtrip(tmp_path):
    tr = TraceRecorder(process_name="test")
    with tr.span("layer.op", args={"k": 8}):
        pass
    with tr.span("layer.tracked", track="wire"):
        pass
    tr.complete("layer.raw", ts_us=10.0, dur_us=5.0, tid=tr.track_tid("wire"))
    tr.instant("layer.event", {"n": 1})
    tr.counter("layer.gauge", {"v": 2})
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    ev = doc["traceEvents"]
    assert {e["ph"] for e in ev} >= {"X", "M", "i", "C"}
    for e in ev:
        assert {"name", "ph", "pid", "tid"} <= set(e), e
        if e["ph"] == "X":
            assert "ts" in e and "dur" in e and e["dur"] >= 0.0, e
        if e["ph"] in ("i", "C"):
            assert "ts" in e
    # the logical track got a thread_name metadata row and its own tid
    names = [e for e in ev if e["ph"] == "M" and e["name"] == "thread_name"]
    assert any(e["args"]["name"] == "wire" for e in names)
    wire_tid = tr.track_tid("wire")
    assert wire_tid != tr._thread_tid()
    spans = {e["name"]: e for e in ev if e["ph"] == "X"}
    assert spans["layer.tracked"]["tid"] == wire_tid
    assert spans["layer.op"]["args"] == {"k": 8}


def test_traced_decorator_and_module_span():
    tr = trace.enable_tracing()

    @trace.traced("layer.fn")
    def fn(x):
        return x + 1

    assert fn(1) == 2
    with trace.span("layer.block") as h:
        h.args["note"] = "v"
    names = [e["name"] for e in tr.events() if e["ph"] == "X"]
    assert "layer.fn" in names and "layer.block" in names
    trace.disable_tracing()
    assert trace.export("/dev/null") is False


def test_disabled_span_is_reused_singleton():
    s1 = trace.span("a")
    s2 = trace.span("b")
    assert s1 is s2                     # no per-call allocation
    with s1 as h:
        h.sync = object()               # accepted, dropped on exit
    assert h.sync is None


# ---------------------------------------------- pinned zero-overhead path
def test_disabled_helpers_allocate_nothing():
    """PINNED: with obs disabled, the per-event helpers on the halo/serve
    hot loops must be allocation-free (one global read + return). Measured
    as allocated-block growth over 10k calls of each helper — anything
    per-call would show up as >= 10k blocks."""
    from repro.obs.instrument import observe_plan_cache, record_exchange

    assert not metrics.enabled()
    for _ in range(200):  # warm any lazy caches
        metrics.inc("x")
        metrics.set_gauge("y", 1.0)
        metrics.observe("z", 0.5)
        record_exchange(None, 64)       # early-returns before touching plan
        observe_plan_cache()
        with trace.span("s"):
            pass
    before = sys.getallocatedblocks()
    for _ in range(10_000):
        metrics.inc("x")
        metrics.set_gauge("y", 1.0)
        metrics.observe("z", 0.5)
        record_exchange(None, 64)
        observe_plan_cache()
        with trace.span("s"):
            pass
    grown = sys.getallocatedblocks() - before
    assert grown < 50, f"disabled obs path allocated {grown} blocks / 10k calls"
    assert len(metrics.default_registry()) == 0


def test_enable_disable_routing():
    reg = metrics.enable(MetricsRegistry())
    metrics.inc("c", 2.0)
    metrics.set_gauge("g", 7.0, {"scope": "t"})
    metrics.observe("h", 1.0)
    snap = metrics.snapshot()
    assert snap["c"]["value"] == 2.0
    assert snap["g{scope=t}"]["value"] == 7.0
    assert snap["h"]["count"] == 1
    metrics.disable()
    metrics.inc("c", 5.0)
    assert reg.snapshot()["c"]["value"] == 2.0  # no-op while disabled
