"""Incremental halo replan + scoped invalidation for mutating graphs
(`repro.dist.delta`), pinned by the delta-vs-rebuild differential harness
(tests/_delta_oracle.py): random mutation sequences where EVERY step asserts
the incrementally repaired plan equals a from-scratch `build_halo_plan`
(export segments, pads, sender encodings, masks, numpy-emulated exchange +
aggregation) and the tile-patched blocked adjacencies equal a re-block —
flat and hierarchical, 1 and 8 devices, plus the plan-cache versioned
re-key / scoped-eviction contracts and the elastic pure-resize regression.

`--delta-seed N` (tests/conftest.py) re-seeds the long mutation runs.
"""
import dataclasses
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import _delta_oracle as O
from repro.core.partition import partition_graph
from repro.dist import halo
from repro.dist.delta import (
    DeltaPlanner,
    GraphDelta,
    RelocalizePolicy,
    apply_delta_to_graph,
    delta_update_blocked_adjacency,
)
from repro.dist.halo import (
    build_halo_plan,
    cached_halo_plan,
    invalidate_halo_plans,
    plan_blocked_adjacency,
    plan_cache_stats,
    plan_split_blocked_adjacency,
    register_halo_plan,
)
from repro.graph.generators import citation_like
from repro.graph.structure import blocked_adjacency
from repro.kernels.bsr_spmm import poison_padding
from repro.kernels.ops import bsr_spmm
from repro.train.elastic import elastic_replan

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _mk(n, e, k, seed, *, refine=False):
    g = citation_like(n, e, seed=seed)
    w = (0.1 + np.random.default_rng(seed).random(g.n_edges)).astype(np.float32)
    part = partition_graph(n, g.edge_index, k, method="bfs", seed=seed, refine=refine)
    return g, w, part


def _plan_fields_equal(a, b):
    for f in ("send_idx", "senders_l", "receivers_l", "edge_w", "perm",
              "part_sizes", "send_loc", "send_rem"):
        x, y = getattr(a, f), getattr(b, f)
        if x is None:
            assert y is None, f
            continue
        assert np.array_equal(x, y), f
    assert (a.s_max, a.s_loc, a.s_rem, a.e_local, a.n_local, a.axes, a.n_pods) \
        == (b.s_max, b.s_loc, b.s_rem, b.e_local, b.n_local, b.axes, b.n_pods)


# ---------------------------------------------------------- v0 == build_halo
def test_v0_plans_bit_identical_to_builder():
    """Before any delta, the planner's plans must be BIT-identical to
    `build_halo_plan` — same slot layout, same padding, same arrays — for
    the flat and the hierarchical schedule (the whole differential harness
    leans on this anchor)."""
    g, w, part = _mk(128, 700, 4, seed=3)
    pl = DeltaPlanner(part, g.edge_index, w)
    _plan_fields_equal(pl.plan(), build_halo_plan(part, g.edge_index, w))
    _plan_fields_equal(
        pl.plan(axes=("pod", "model"), pods=2),
        build_halo_plan(part, g.edge_index, w, axes=("pod", "model"), pods=2))


# ------------------------------------------------- random-mutation sequences
def _mutation_run(n, e, k, seed, steps, schedules, max_ops=8):
    g, w, part = _mk(n, e, k, seed=seed)
    pl = DeltaPlanner(part, g.edge_index, w)
    plans = [pl.plan(axes=axes, pods=pods) for axes, pods in schedules]
    ei, ww = g.edge_index.astype(np.int64), w
    rng = np.random.default_rng(seed + 1)
    for _ in range(steps):
        d = O.random_delta(rng, n, ei, max_ops=max_ops)
        pl.apply(d)
        ei, ww = O.apply_delta_to_edges(ei, ww, d)
        assert pl.n_edges == ei.shape[1]
        for p in plans:
            O.assert_plan_matches_rebuild(p, part, ei, ww)
    return pl, plans, part, ei, ww


@settings(max_examples=5, deadline=None)
@given(n=st.integers(48, 140), e=st.integers(120, 600),
       k=st.sampled_from([2, 4]), seed=st.integers(0, 30))
def test_delta_vs_rebuild_flat_random_sequences(n, e, k, seed):
    _mutation_run(n, e, k, seed, steps=6, schedules=[(("model",), 1)])


@settings(max_examples=5, deadline=None)
@given(n=st.integers(64, 160), e=st.integers(200, 700), seed=st.integers(0, 30))
def test_delta_vs_rebuild_hier_random_sequences(n, e, seed):
    _mutation_run(n, e, 4, seed, steps=6,
                  schedules=[(("pod", "model"), 2)])


def test_delta_200_step_acceptance(delta_seed):
    """The headline acceptance: 200+ random mutation steps on one planner
    holding a flat AND a hierarchical plan, every step differentially
    checked against a rebuild, blocked tables checked against a re-block at
    checkpoints. Reseedable via ``--delta-seed``."""
    seed = 1000 + delta_seed
    n, e, k, blk = 192, 1200, 4, 32
    g, w, part = _mk(n, e, k, seed=seed % 97)
    pl = DeltaPlanner(part, g.edge_index, w)
    plans = [pl.plan(), pl.plan(axes=("pod", "model"), pods=2)]
    for p in plans:
        plan_blocked_adjacency(p, blk)
        plan_split_blocked_adjacency(p, blk)
    ei, ww = g.edge_index.astype(np.int64), w
    rng = np.random.default_rng(seed)
    patched = dropped = 0
    for step in range(200):
        d = O.random_delta(rng, n, ei, max_ops=10)
        rep = pl.apply(d)
        patched += rep["blocked_patched"]
        dropped += rep["blocked_dropped"]
        ei, ww = O.apply_delta_to_edges(ei, ww, d)
        for p in plans:
            O.assert_plan_matches_rebuild(p, part, ei, ww)
        if step % 25 == 24:
            for p in plans:
                mine_c = plan_blocked_adjacency(p, blk)
                mine_i, mine_b = plan_split_blocked_adjacency(p, blk)
                fresh = dataclasses.replace(p)         # empty blocked cache
                O.assert_blocked_matches(mine_c, plan_blocked_adjacency(fresh, blk))
                ref_i, ref_b = plan_split_blocked_adjacency(fresh, blk)
                O.assert_blocked_matches(mine_i, ref_i)
                O.assert_blocked_matches(mine_b, ref_b)
    assert patched > 0, "no blocked table was ever tile-patched"
    assert pl.version == 200
    assert pl.graph_key.endswith("@d200")


# ------------------------------------------- maintenance soak + acceptance
def _w_of(ei):
    """Weight as a pure function of (u, v): duplicate edge instances share
    it, so after a re-localization reorders the planner's internal slots a
    delete can never consume a 'different-weight' duplicate than the numpy
    oracle does (same trick as the 8-device prelude)."""
    ei = np.asarray(ei, np.int64)
    return (0.1 + (ei[0] * 131 + ei[1] * 17) % 97 / 97.0).astype(np.float32)


def _maintenance_delta(rng, n, ei, max_ops=8):
    d = O.random_delta(rng, n, ei, max_ops=max_ops)
    return dataclasses.replace(d, insert_w=_w_of(d.edge_inserts))


@settings(max_examples=4, deadline=None)
@given(n=st.integers(140, 220), e=st.integers(500, 1000), seed=st.integers(0, 30))
def test_soak_interleaved_maintenance_random_sequences(n, e, seed):
    """Soak: random mutation batches interleaved with `compact()` and both
    FORCED and THRESHOLD-driven re-localizations, the full delta oracle
    after every single step. Post-relocalize the oracle rebuilds against
    the planner's OWN (re-localized) partition — plans must stay equal to
    a from-scratch build at every interleaving point."""
    g, w, part = _mk(n, e, 4, seed=seed)
    w = _w_of(g.edge_index)
    pol = RelocalizePolicy(threshold=1.01, patience=2, cooldown=2, block=32)
    pl = DeltaPlanner(part, g.edge_index, w, relocalize_policy=pol)
    plans = [pl.plan(), pl.plan(axes=("pod", "model"), pods=2)]
    ei, ww = g.edge_index.astype(np.int64), w
    rng = np.random.default_rng(seed + 17)
    for step in range(12):
        act = step % 6
        if act == 4:
            pl.compact()
        elif act == 5:
            pl.relocalize(block=32)
            assert pl.locality_drift(32)["drift_ratio"] == 1.0
        else:
            d = _maintenance_delta(rng, n, ei, max_ops=8)
            pl.apply(d)                  # may auto-relocalize via the policy
            ei, ww = O.apply_delta_to_edges(ei, ww, d)
        assert pl.n_edges == ei.shape[1]
        for p in plans:
            O.assert_plan_matches_rebuild(p, pl.part, ei, ww)


def test_delta_200_step_acceptance_with_maintenance(delta_seed):
    """The ISSUE 9 acceptance twin of the 200-step run: same mutation load,
    but with the relocalize policy armed and periodic compaction — the
    oracle must hold after every step, drift must come back to exactly 1.0
    at each fire, and maintenance must actually have fired."""
    seed = 2000 + delta_seed
    n, e, k, blk = 192, 1200, 4, 32
    g, w, part = _mk(n, e, k, seed=seed % 97)
    w = _w_of(g.edge_index)
    pol = RelocalizePolicy(threshold=1.02, patience=3, cooldown=8, block=blk)
    pl = DeltaPlanner(part, g.edge_index, w, relocalize_policy=pol)
    plans = [pl.plan(), pl.plan(axes=("pod", "model"), pods=2)]
    ei, ww = g.edge_index.astype(np.int64), w
    rng = np.random.default_rng(seed)
    fired = compacts = 0
    for step in range(200):
        if step % 50 == 49:
            compacts += bool(pl.compact()["changed"])
        d = _maintenance_delta(rng, n, ei, max_ops=10)
        rep = pl.apply(d)
        if rep["relocalized"] is not None:
            fired += 1
            assert pl.locality_drift(blk)["drift_ratio"] == 1.0
        ei, ww = O.apply_delta_to_edges(ei, ww, d)
        for p in plans:
            O.assert_plan_matches_rebuild(p, pl.part, ei, ww)
    assert fired >= 1, "200 uniform-insert steps never crossed the threshold"
    assert pl.version >= 200 + fired
    assert pl.n_edges == ei.shape[1]


# ------------------------------------------------------ blocked tables (bsr)
def test_patched_plan_blocked_spmm_and_poison(delta_seed):
    """Patched vs re-blocked per-shard tables through the REAL ragged
    kernel: same `bsr_spmm` output, and a poisoned-padding run proves the
    kernel never reads tombstoned/padding tiles (NaN would propagate)."""
    n, e, k = 256, 1600, 4
    g, w, part = _mk(n, e, k, seed=5)
    pl = DeltaPlanner(part, g.edge_index, w)
    plan = pl.plan()
    plan_blocked_adjacency(plan, 128)
    ei, ww = g.edge_index.astype(np.int64), w
    rng = np.random.default_rng(200 + delta_seed)
    rep = None
    for _ in range(8):
        d = O.random_delta(rng, n, ei, max_ops=12)
        rep = pl.apply(d)
        ei, ww = O.apply_delta_to_edges(ei, ww, d)
    mine = plan_blocked_adjacency(plan, 128)
    ref = plan_blocked_adjacency(dataclasses.replace(plan), 128)
    O.assert_blocked_matches(mine, ref)
    z = rng.standard_normal((mine.n_cols, 128)).astype(np.float32)
    poisoned = poison_padding(mine.vals, mine.cols, mine.lens)
    for b in range(k):
        out = np.asarray(bsr_spmm(
            jnp.asarray(mine.vals[b]), jnp.asarray(mine.cols[b]),
            jnp.asarray(z), lens=jnp.asarray(mine.lens[b])))
        out_ref = np.asarray(bsr_spmm(
            jnp.asarray(ref.vals[b]), jnp.asarray(ref.cols[b]),
            jnp.asarray(z), lens=jnp.asarray(ref.lens[b])))
        assert np.abs(out - out_ref).max() < 1e-4
        out_poison = np.asarray(bsr_spmm(
            jnp.asarray(poisoned[b]), jnp.asarray(mine.cols[b]),
            jnp.asarray(z), lens=jnp.asarray(mine.lens[b])))
        assert np.isfinite(out_poison).all(), "kernel read a poisoned tile"
        assert np.abs(out_poison - out).max() == 0.0


def test_delta_update_global_blocked_adjacency(delta_seed):
    """The standalone `BlockedAdjacency` patch path: 30 random deltas,
    densified equality against a re-block each step; T only ever grows, and
    grows geometrically."""
    g = citation_like(200, 900, seed=2)
    w = (0.1 + np.random.default_rng(1).random(g.n_edges)).astype(np.float32)
    g = dataclasses.replace(g, edge_weight=w)
    blk = 16
    ba = blocked_adjacency(g.n_nodes, g.edge_index, g.edge_weight, blk)
    rng = np.random.default_rng(9 + delta_seed)
    t_hist = [ba.max_nnzb]
    for _ in range(30):
        d = O.random_delta(rng, g.n_nodes, g.edge_index, max_ops=10)
        g = apply_delta_to_graph(g, d)
        ba = delta_update_blocked_adjacency(ba, g.edge_index, g.edge_weight, d)
        t_hist.append(ba.max_nnzb)
        ref = blocked_adjacency(g.n_nodes, g.edge_index, g.edge_weight, blk)
        dm = O.densify(ba.block_vals, ba.block_cols, ba.row_nnzb,
                       g.n_nodes, ba.n_col_nodes)
        dr = O.densify(ref.block_vals, ref.block_cols, ref.row_nnzb,
                       g.n_nodes, ref.n_col_nodes)
        assert np.abs(dm - dr).max() < 1e-5
    assert all(b >= a for a, b in zip(t_hist, t_hist[1:])), "T shrank"


def test_tombstone_then_poison_padding_zeroes():
    """A delta that empties a whole tile must tombstone it: the freed slot
    is zeroed, lens drops, and `poison_padding` covers it (the kernel-side
    never-read proof for the swap-removed slot)."""
    # two edges in one tile, one edge in another → delete the lone edge
    ei = np.asarray([[0, 1, 40], [0, 0, 0]], np.int64)
    ba = blocked_adjacency(64, ei, None, 32, n_col_nodes=64)
    assert int(ba.row_nnzb[0]) == 2
    d = GraphDelta(edge_deletes=np.asarray([[40], [0]]))
    g = dataclasses.replace(
        citation_like(64, 4, seed=0), edge_index=ei, edge_weight=None)
    g2 = apply_delta_to_graph(g, d)
    ba = delta_update_blocked_adjacency(ba, g2.edge_index, g2.edge_weight, d)
    assert int(ba.row_nnzb[0]) == 1
    assert not ba.block_vals[0, 1:].any(), "tombstoned slot not zeroed"
    pz = poison_padding(ba.block_vals, ba.block_cols, ba.row_nnzb)
    assert np.isnan(pz[0, 1]).all() and not np.isnan(pz[0, 0]).any()


def test_append_into_full_row_with_tombstone_same_delta():
    """Regression: a row block at exact tile capacity gets an append AND a
    tombstone in ONE delta. The net count fits, but replaying the append
    before the tombstone transiently overflows the table — the patcher must
    order tombstones first and size capacity on the running peak, so this
    must go through without growing T."""
    # row block 0 at capacity T=2 (col tiles 0 and 1, exact-fit build)
    ei = np.asarray([[0, 40], [0, 0]], np.int64)
    ba = blocked_adjacency(96, ei, None, 32, n_col_nodes=96)
    assert ba.max_nnzb == 2 and int(ba.row_nnzb[0]) == 2
    # one delta: empty col tile 1 (tombstone) + open col tile 2 (append)
    d = GraphDelta(edge_deletes=np.asarray([[40], [0]]),
                   edge_inserts=np.asarray([[70], [0]]))
    g = dataclasses.replace(
        citation_like(96, 4, seed=0), edge_index=ei, edge_weight=None)
    g2 = apply_delta_to_graph(g, d)
    ba = delta_update_blocked_adjacency(ba, g2.edge_index, g2.edge_weight, d)
    assert ba.max_nnzb == 2, "transient overflow forced a spurious T growth"
    assert int(ba.row_nnzb[0]) == 2
    ref = blocked_adjacency(96, g2.edge_index, g2.edge_weight, 32,
                            n_col_nodes=96)
    dm = O.densify(ba.block_vals, ba.block_cols, ba.row_nnzb, 96, 96)
    dr = O.densify(ref.block_vals, ref.block_cols, ref.row_nnzb, 96, 96)
    assert np.abs(dm - dr).max() < 1e-5


# -------------------------------------------------------- plan-cache re-key
def test_versioned_rekey_old_key_misses_new_key_hits():
    g, w, part = _mk(96, 500, 4, seed=7)
    invalidate_halo_plans()
    halo.reset_plan_cache_stats()
    pl = DeltaPlanner(part, g.edge_index, w)
    p = pl.plan()
    key0 = pl.graph_key
    assert cached_halo_plan(key0, 4, "model", builder=_boom) is p  # hit
    rep = pl.apply(GraphDelta(edge_inserts=np.asarray([[1], [90]])))
    assert rep["stale_keys_evicted"] == 1
    key1 = pl.graph_key
    assert key1 != key0 and key1.endswith("@d1")
    # new key hits the SAME repaired object; stale key re-runs the builder
    assert cached_halo_plan(key1, 4, "model", builder=_boom) is p
    with pytest.raises(RuntimeError, match="rebuilt"):
        cached_halo_plan(key0, 4, "model", builder=_boom)
    assert plan_cache_stats()["evictions"] >= 1


def _boom():
    raise RuntimeError("builder re-ran on what should be a cache hit (rebuilt)")


def test_rekey_covers_every_schedule_flavor():
    """One planner holding flat + hierarchical plans migrates ALL of them in
    one apply — each flavor's new key hits, each old key is gone."""
    g, w, part = _mk(96, 500, 4, seed=8)
    invalidate_halo_plans()
    pl = DeltaPlanner(part, g.edge_index, w)
    flat = pl.plan()
    hier = pl.plan(axes=("pod", "model"), pods=2)
    key0 = pl.graph_key
    rep = pl.apply(GraphDelta(edge_deletes=g.edge_index[:, :1]))
    assert rep["stale_keys_evicted"] == 2
    key1 = pl.graph_key
    assert cached_halo_plan(key1, 4, "model", builder=_boom) is flat
    assert cached_halo_plan(key1, 4, ("pod", "model"), pods=2,
                            builder=_boom) is hier
    for axes, pods in (("model", 1), (("pod", "model"), 2)):
        with pytest.raises(RuntimeError):
            cached_halo_plan(key0, 4, axes, pods=pods, builder=_boom)


# --------------------------------------------------- scoped cache eviction
def test_scoped_invalidation_spans_hier_flavors_and_spares_others():
    """`invalidate_halo_plans(graph_key)` drops EVERY (axes, n_pods) flavor
    of that graph — flat, 2-pod, 4-pod — in one call, while another graph's
    plans coexist untouched (the miss case)."""
    g, w, part = _mk(96, 500, 8, seed=9)
    g2, w2, part2 = _mk(96, 500, 8, seed=10)
    invalidate_halo_plans()
    a = build_halo_plan(part, g.edge_index, w)
    register_halo_plan("graph-a", 8, "model", plan=a)
    register_halo_plan("graph-a", 8, ("pod", "model"), pods=2,
                       plan=build_halo_plan(part, g.edge_index, w,
                                            axes=("pod", "model"), pods=2))
    register_halo_plan("graph-a", 8, ("pod", "model"), pods=4,
                       plan=build_halo_plan(part, g.edge_index, w,
                                            axes=("pod", "model"), pods=4))
    b = build_halo_plan(part2, g2.edge_index, w2)
    register_halo_plan("graph-b", 8, "model", plan=b)
    assert invalidate_halo_plans("graph-a") == 3
    assert cached_halo_plan("graph-b", 8, "model", builder=_boom) is b
    with pytest.raises(RuntimeError):
        cached_halo_plan("graph-a", 8, "model", builder=_boom)
    # k-scoped narrowing: a k=4 eviction leaves the k=8 entry alone
    register_halo_plan("graph-b", 4, "model", plan=b)
    assert invalidate_halo_plans("graph-b", k=4) == 1
    assert cached_halo_plan("graph-b", 8, "model", builder=_boom) is b
    invalidate_halo_plans()


# ------------------------------------------------------------------ elastic
def test_elastic_pure_resize_keeps_plans_zero_evictions():
    """Satellite regression: an elastic resize that preserves the
    model-parallel degree must not evict a single cached plan."""
    g, w, part = _mk(96, 500, 4, seed=11)
    invalidate_halo_plans()
    halo.reset_plan_cache_stats()
    register_halo_plan("elastic-g", 4, "model",
                       plan=build_halo_plan(part, g.edge_index, w))
    before = plan_cache_stats()
    plan = elastic_replan(12, 4, graph_key="elastic-g")   # data 4 → 3
    assert plan.shape == (3, 4)
    assert plan_cache_stats()["evictions"] == before["evictions"] == 0
    assert cached_halo_plan("elastic-g", 4, "model", builder=_boom) is not None


def test_elastic_model_halving_evicts_only_that_graph():
    g, w, part = _mk(96, 500, 4, seed=12)
    g2, w2, part2 = _mk(96, 500, 4, seed=13)
    invalidate_halo_plans()
    register_halo_plan("shrinks", 4, "model",
                       plan=build_halo_plan(part, g.edge_index, w))
    register_halo_plan("shrinks", 4, ("pod", "model"), pods=2,
                       plan=build_halo_plan(part, g.edge_index, w,
                                            axes=("pod", "model"), pods=2))
    survivor = build_halo_plan(part2, g2.edge_index, w2)
    register_halo_plan("survives", 4, "model", plan=survivor)
    plan = elastic_replan(3, 4, graph_key="shrinks")      # m 4 → 2: repartition
    assert plan.shape == (1, 2)
    with pytest.raises(RuntimeError):
        cached_halo_plan("shrinks", 4, "model", builder=_boom)
    assert cached_halo_plan("survives", 4, "model", builder=_boom) is survivor
    invalidate_halo_plans()


# --------------------------------------------------------------- validation
def test_graph_delta_validation_errors():
    d = GraphDelta(edge_inserts=np.asarray([[5], [99]]))
    with pytest.raises(ValueError, match="outside"):
        d.validate(50)
    with pytest.raises(ValueError, match="insert_w length"):
        GraphDelta(edge_inserts=np.asarray([[1], [2]]),
                   insert_w=np.asarray([1.0, 2.0])).validate(10)
    with pytest.raises(ValueError, match="> 0"):
        GraphDelta(edge_inserts=np.asarray([[1], [2]]),
                   insert_w=np.asarray([0.0])).validate(10)
    with pytest.raises(ValueError, match="feature_values"):
        GraphDelta(feature_touches=np.asarray([1, 2]),
                   feature_values=np.zeros((1, 4), np.float32)).validate(10)
    with pytest.raises(ValueError, match="\\(2, E\\)"):
        GraphDelta(edge_inserts=np.zeros((3, 2)))
    assert GraphDelta.empty().is_empty
    assert GraphDelta(edge_inserts=np.asarray([[1], [2]])).n_ops == 1


def test_absent_delete_raises_everywhere():
    g, w, part = _mk(64, 300, 2, seed=14)
    d = GraphDelta(edge_deletes=np.asarray([[63], [62]]))
    if ((g.edge_index[0] == 63) & (g.edge_index[1] == 62)).any():
        pytest.skip("generator produced the edge this test needs absent")
    with pytest.raises(ValueError, match="absent"):
        apply_delta_to_graph(g, d)
    pl = DeltaPlanner(part, g.edge_index, w)
    with pytest.raises(ValueError, match="absent"):
        pl.apply(d)


def test_apply_delta_to_graph_is_order_preserving():
    g = citation_like(30, 60, 8, 3, seed=1)
    keep_before = [tuple(c) for c in g.edge_index.T.tolist()]
    victim = keep_before[10]
    d = GraphDelta(edge_deletes=np.asarray([[victim[0]], [victim[1]]]),
                   edge_inserts=np.asarray([[3], [4]]),
                   feature_touches=np.asarray([7]),
                   feature_values=np.full((1, 8), 5.0, np.float32))
    g2 = apply_delta_to_graph(g, d)
    after = [tuple(c) for c in g2.edge_index.T.tolist()]
    expect = [c for i, c in enumerate(keep_before) if i != 10] + [(3, 4)]
    assert after == expect, "deletes must compact and inserts must append"
    assert np.allclose(g2.features[7], 5.0)
    same = (g2.features == np.asarray(g.features)).all(axis=1)
    assert not same[7] and same[np.arange(30) != 7].all(), (
        "exactly the touched feature row must change")
    assert g2.features is not g.features


# ------------------------------------------------ 8-device mid-training run
def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
    return out.stdout


_PRELUDE = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.partition import partition_graph
from repro.dist.delta import DeltaPlanner, GraphDelta
from repro.dist.halo import relocate_node_array, restore_node_array
from repro.graph.generators import citation_like

def w_of(ei):
    # weight = pure function of (u, v): duplicate edge instances share it,
    # so the delta path and the oracle edge list can never disagree on w
    return (0.1 + (ei[0] * 131 + ei[1] * 17) % 97 / 97.0).astype(np.float32)

g = citation_like(400, 2400, seed=5)
ei = g.edge_index.astype(np.int64)
part = partition_graph(g.n_nodes, ei, 8, method="bfs", seed=0, refine=True)
x = np.random.default_rng(1).standard_normal((g.n_nodes, 16)).astype(np.float32)
"""


@pytest.mark.slow
def test_delta_replan_mid_training_8dev_subprocess():
    """8-device acceptance: run the halo forward, mutate the graph through
    the planner mid-run, and check the repaired plan's sharded exchange +
    aggregation still matches the global reference on the NEW edges — for
    the flat AND the hierarchical schedule, without rebuilding a plan."""
    code = _PRELUDE + """
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init
from repro.dist.policy import NO_POLICY, ShardingPolicy

pl = DeltaPlanner(part, ei, w_of(ei))
plans = {"flat": pl.plan(), "hier": pl.plan(axes=("pod", "model"), pods=2)}
mesh1d = jax.make_mesh((8,), ("model",))
mesh2d = jax.make_mesh((2, 4), ("pod", "model"))
AX = ("pod", "model")
cfg = GCNConfig(layer_dims=(16, 32, 7), dataflow="feature_first")
params = gcn_init(jax.random.PRNGKey(0), cfg)

def fwd(fe, pol, s, r, ww):
    return gcn_forward(params, fe, s, r, ww, cfg, pol)

def sharded_forward(plan):
    xb = jnp.asarray(relocate_node_array(plan, x))
    if plan.is_hierarchical:
        sloc, srem, sl, rl, ew = plan.device_arrays()
        pol0 = ShardingPolicy(comm="halo", halo_axes=AX)
        f = jax.shard_map(
            lambda fe, a, b, c, d, e: fwd(
                fe[0], pol0.bind_halo(send_loc=a[0], send_rem=b[0]),
                c[0], d[0], e[0])[None],
            mesh=mesh2d, in_specs=(P(AX),) * 6, out_specs=P(AX), check_vma=False,
        )
        out = f(xb, sloc, srem, sl, rl, ew)
    else:
        si, sl, rl, ew = plan.device_arrays()
        pol0 = ShardingPolicy(comm="halo")
        f = jax.shard_map(
            lambda fe, a, b, c, d: fwd(fe[0], pol0.bind_halo(a[0]),
                                       b[0], c[0], d[0])[None],
            mesh=mesh1d, in_specs=(P("model"),) * 5, out_specs=P("model"),
            check_vma=False,
        )
        out = f(xb, si, sl, rl, ew)
    return restore_node_array(plan, np.asarray(out))

def global_ref(ei):
    return np.asarray(gcn_forward(
        params, jnp.asarray(x), jnp.asarray(ei[0]), jnp.asarray(ei[1]),
        jnp.asarray(w_of(ei)), cfg, NO_POLICY))

# pre-delta: both schedules match the global forward
ref = global_ref(ei)
for name, plan in plans.items():
    got = sharded_forward(plan)
    assert np.abs(got - ref).max() < 1e-4, ("pre", name)

# mid-training mutation: delete 40 edges, insert 40 new ones
rng = np.random.default_rng(3)
drop = rng.choice(ei.shape[1], 40, replace=False)
ins = rng.integers(0, g.n_nodes, (2, 40))
delta = GraphDelta(edge_inserts=ins, edge_deletes=ei[:, drop],
                   insert_w=w_of(ins))
rep = pl.apply(delta)
assert rep["senders_remapped"] > 0
keep = np.ones(ei.shape[1], bool); keep[drop] = False
ei2 = np.concatenate([ei[:, keep], ins], axis=1)
assert pl.n_edges == ei2.shape[1]

ref2 = global_ref(ei2)
assert np.abs(ref2 - ref).max() > 1e-3, "delta too weak to detect staleness"
for name, plan in plans.items():
    got = sharded_forward(plan)
    assert np.abs(got - ref2).max() < 1e-4, ("post", name, np.abs(got - ref2).max())
print("OK")
"""
    _run(code)
