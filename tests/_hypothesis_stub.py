"""Minimal deterministic stand-in for `hypothesis` (registered by conftest.py
ONLY when the real package is absent — environments with hypothesis installed
use it untouched).

Supports exactly the API surface this suite uses:

    from hypothesis import assume, given, settings, strategies as st
    @settings(max_examples=N, deadline=None)
    @given(x=st.integers(lo, hi), y=st.sampled_from([...]), ...)

Each test runs ``max_examples`` times over draws from a per-test seeded
generator (seeded by the test's qualified name → stable across runs and
processes). Bounds are drawn with elevated probability so the usual
off-by-one edges still get exercised. No shrinking: on failure the drawn
example is printed and the original exception propagates.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "assume", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 100


class _Unsatisfied(Exception):
    """Raised by assume(False): skip the current example."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Strategy:
    def __init__(self, draw, label: str):
        self._draw = draw
        self.label = label

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def __repr__(self):
        return self.label


def _integers(min_value=None, max_value=None):
    lo = 0 if min_value is None else int(min_value)
    hi = lo + 1_000_000 if max_value is None else int(max_value)

    def draw(rng: np.random.Generator, lo=lo, hi=hi):
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return int(rng.integers(lo, hi + 1))

    return _Strategy(draw, f"integers({lo}, {hi})")


def _sampled_from(elements):
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from requires a non-empty collection")
    return _Strategy(
        lambda rng: elems[int(rng.integers(0, len(elems)))],
        f"sampled_from({elems!r})",
    )


def _booleans():
    return _sampled_from([False, True])


def _floats(min_value=0.0, max_value=1.0, **_kw):
    lo, hi = float(min_value), float(max_value)

    def draw(rng: np.random.Generator, lo=lo, hi=hi):
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return float(lo + (hi - lo) * rng.random())

    return _Strategy(draw, f"floats({lo}, {hi})")


strategies = types.SimpleNamespace(
    integers=_integers,
    sampled_from=_sampled_from,
    booleans=_booleans,
    floats=_floats,
)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator recording the example budget on the (given-wrapped) test."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


# Profile no-ops: the stub is ALREADY derandomized (per-test crc32 seeds),
# so conftest's `settings.register_profile("repro-derandomize", ...)` /
# `load_profile` calls — real API on real hypothesis — are accepted and do
# nothing here.
settings.register_profile = lambda name, *a, **kw: None
settings.load_profile = lambda name: None


def given(*args, **strategies_kw):
    if args:
        raise TypeError("hypothesis stub supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            max_examples = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            for _ in range(max_examples):
                drawn = {name: s.draw(rng) for name, s in strategies_kw.items()}
                try:
                    fn(*a, **kw, **drawn)
                    ran += 1
                except _Unsatisfied:
                    continue
                except Exception:
                    print(f"Falsifying example {fn.__qualname__}({drawn})")
                    raise
            if ran == 0:
                raise AssertionError(
                    f"{fn.__qualname__}: assume() rejected all {max_examples} "
                    "examples — the test body never ran (mirrors hypothesis's "
                    "Unsatisfied error)"
                )

        # Hide the inner test's parameters from pytest's fixture resolution:
        # the strategies supply them, not fixtures.
        del wrapper.__wrapped__
        outer = [
            p for name, p in inspect.signature(fn).parameters.items()
            if name not in strategies_kw
        ]
        wrapper.__signature__ = inspect.Signature(outer)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


HealthCheck = types.SimpleNamespace(
    too_slow="too_slow", data_too_large="data_too_large", filter_too_much="filter_too_much"
)
