"""PINNED metrics-vs-accounting equalities (ISSUE 8 acceptance).

`repro.obs.instrument` never invents a number — every exported gauge is
fed from a value an existing layer already computes. These tests pin that
contract: the registry snapshot must reproduce, bit-for-bit,

* the halo plan's wire model (`exchange_cost`, `HaloPlan` row counts),
* the plan cache's `plan_cache_stats` counters,
* the blocked adjacency's executed-tile count (``lens.sum()``),
* the serve engine's ``stats()`` (p50/p99 latency, cache hit rate),
* the `DeltaPlanner.apply` report (repair latency, drift gauge).

The slow test drives the 8-device distributed example end to end with
``--trace``/``--metrics`` and asserts the exported Chrome trace shows the
boundary-collective wire span enclosing an interior-compute span — the
overlap, demonstrated from the artifact a user would actually load into
Perfetto.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.dataflow import exchange_cost
from repro.core.partition import partition_graph
from repro.core.quant import payload_bits
from repro.dist.delta import DeltaPlanner, GraphDelta
from repro.dist.halo import (
    build_halo_plan,
    get_halo_plan,
    invalidate_halo_plans,
    plan_blocked_adjacency,
    plan_cache_stats,
)
from repro.graph.generators import citation_like
from repro.obs import metrics, trace
from repro.obs.instrument import (
    observe_plan_cache,
    record_blocked,
    record_delta_report,
    record_exchange,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _fresh_obs():
    old_reg = metrics.set_default_registry(metrics.MetricsRegistry())
    was_enabled = metrics.enabled()
    metrics.enable()
    old_tracer = trace.set_default_tracer(None)
    yield
    metrics.disable()
    metrics.set_default_registry(old_reg)
    if was_enabled:
        metrics.enable()
    trace.set_default_tracer(old_tracer)


def _mk(n=400, e=2400, k=4, seed=2):
    g = citation_like(n, e, seed=seed)
    part = partition_graph(n, g.edge_index, k, method="bfs", seed=seed, refine=True)
    return g, part


def _gauge(snap, key):
    return snap[key]["value"]


# --------------------------------------------------- halo wire accounting
@pytest.mark.parametrize("payload", [None, "bf16", "int8"])
def test_halo_gauges_equal_exchange_cost(payload):
    g, part = _mk()
    plan = build_halo_plan(part, g.edge_index)
    d = 48
    record_exchange(plan, d, payload)
    snap = metrics.snapshot()
    bits = payload_bits(payload)
    cost = exchange_cost(plan.halo_rows_per_device, d, bits,
                         plan.overlap_fraction())
    assert _gauge(snap, "halo.wire_bytes_per_exchange") == cost.wire_bytes
    assert _gauge(snap, "halo.exposed_bytes_per_exchange") == cost.exposed_bytes
    assert _gauge(snap, "halo.compression_vs_fp32") == cost.compression
    assert _gauge(snap, "halo.payload_bits") == bits
    assert _gauge(snap, "halo.overlap_fraction") == plan.overlap_fraction()
    assert _gauge(snap, "halo.wire_fraction") == plan.wire_fraction()
    assert _gauge(snap, "halo.rows_per_device{tier=total}") == plan.halo_rows_per_device
    assert (_gauge(snap, "halo.rows_per_device{tier=broadcast}")
            == plan.broadcast_rows_per_device)
    bnd = plan.boundary_rows_per_device()
    assert _gauge(snap, "halo.boundary_rows_max_device") == int(bnd.max())
    assert snap["halo.exchanges"]["value"] == 1.0


def test_hierarchical_tier_gauges():
    g, part = _mk(k=8)
    plan = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=2)
    record_exchange(plan, 32)
    snap = metrics.snapshot()
    assert (_gauge(snap, "halo.rows_per_device{tier=inter_pod_crossing}")
            == plan.inter_pod_rows_crossing)
    assert (_gauge(snap, "halo.rows_per_device{tier=intra_pod}")
            == plan.intra_pod_rows_per_device)


# ------------------------------------------------------------- plan cache
def test_plan_cache_gauges_mirror_stats():
    g, part = _mk(seed=11)
    w = np.ones(g.n_edges, np.float32)
    get_halo_plan(part, g.edge_index, w)      # miss (or hit if cached before)
    get_halo_plan(part, g.edge_index, w)      # hit — observes stats either way
    snap = metrics.snapshot()
    stats = plan_cache_stats()
    for key in ("hits", "misses", "evictions", "size"):
        assert _gauge(snap, f"plan_cache.{key}") == stats[key], key
    observe_plan_cache()                       # the explicit mirror agrees too
    snap2 = metrics.snapshot()
    stats2 = plan_cache_stats()
    assert _gauge(snap2, "plan_cache.hits") == stats2["hits"]
    invalidate_halo_plans()


# ------------------------------------------------------ executed bsr tiles
def test_blocked_gauges_equal_lens_sum():
    g, part = _mk(n=512, e=3000, k=4, seed=5)
    plan = build_halo_plan(part, g.edge_index)
    tab = plan_blocked_adjacency(plan, block=64)
    record_blocked(tab, scope="plan")
    snap = metrics.snapshot()
    executed = int(tab.lens.sum())
    assert executed == tab.stats()["nnz_blocks"]
    assert _gauge(snap, "bsr.executed_tiles{scope=plan}") == executed
    assert _gauge(snap, "bsr.max_nnzb{scope=plan}") == tab.stats()["max_nnzb"]
    assert (_gauge(snap, "bsr.padded_tile_fraction{scope=plan}")
            == tab.stats()["padded_tile_fraction"])


# ------------------------------------------------------------------ serve
def test_serve_gauges_equal_engine_stats():
    import jax

    from repro.models.gcn import GCNConfig, gcn_init
    from repro.serve.graph import GraphBatcher, hot_query_stream

    g = citation_like(300, 2400, 16, 4, seed=0)
    cfg = GCNConfig(layer_dims=(16, 8, 4))
    params = gcn_init(jax.random.PRNGKey(0), cfg)
    eng = GraphBatcher(params, g, cfg, batch_seeds=4, fanout=4,
                       cache_capacity=64, seed=0)
    for v in hot_query_stream(g, 24, seed=1):
        eng.submit(int(v))
    eng.run_until_drained()
    s = eng.export_metrics()
    snap = metrics.snapshot()
    assert _gauge(snap, "serve.p50_ms") == s["p50_ms"]
    assert _gauge(snap, "serve.p99_ms") == s["p99_ms"]
    assert _gauge(snap, "serve.cache_hit_rate") == s["cache"]["hit_rate"]
    assert _gauge(snap, "serve.nodes_per_query") == s["nodes_per_query"]
    assert snap["serve.queries"]["value"] == s["queries"] == 24
    assert snap["serve.micro_batches"]["value"] == s["micro_batches"]
    assert snap["serve.latency_ms"]["count"] == 24
    assert snap["serve.queue_wait_ms"]["count"] == 24
    occ = snap["serve.batch_occupancy"]
    assert occ["count"] == s["micro_batches"] and 0.0 < occ["max"] <= 1.0


# ------------------------------------------------------------------ delta
def test_delta_report_gauges_and_drift():
    g, part = _mk(n=256, e=1500, k=4, seed=7)
    w = np.ones(g.n_edges, np.float32)
    pl = DeltaPlanner(part, g.edge_index, w)
    pl.plan()
    rng = np.random.default_rng(0)
    ins = np.stack([rng.integers(0, 256, 12), rng.integers(0, 256, 12)]).astype(np.int64)
    rep = pl.apply(GraphDelta(edge_inserts=ins), measure_drift=True, drift_block=64)
    snap = metrics.snapshot()
    assert snap["delta.applies"]["value"] == 1.0
    assert snap["delta.inserts"]["value"] == rep["inserts"] == 12
    assert _gauge(snap, "delta.dirty_devices") == len(rep["dirty_devices"])
    assert _gauge(snap, "delta.structural") == float(bool(rep["structural"]))
    assert snap["delta.apply_ms"]["count"] == 1
    assert snap["delta.apply_ms"]["sum"] == rep["apply_ms"]
    d = rep["drift"]
    assert d["block"] == 64
    assert _gauge(snap, "delta.drift_ratio") == d["drift_ratio"]
    assert (_gauge(snap, "delta.executed_tiles_current")
            == d["executed_tiles_current"])
    assert (_gauge(snap, "delta.executed_tiles_reordered")
            == d["executed_tiles_reordered"])
    # drift is a ratio of executed-tile counts: >= 0, and both sides > 0
    assert d["executed_tiles_current"] > 0 and d["executed_tiles_reordered"] > 0
    # re-running record_delta_report is additive on counters (apply #2)
    record_delta_report(rep)
    assert metrics.snapshot()["delta.applies"]["value"] == 2.0


# ------------------------------------------- 8-device traced overlap (slow)
@pytest.mark.slow
def test_traced_example_shows_overlap_subprocess(tmp_path):
    """Drive the distributed example with --trace/--metrics on 8 host
    devices; the exported Chrome trace must contain the boundary-collective
    span on the wire track ENCLOSING an interior-compute span (the async
    dispatch overlap), and the metrics snapshot must reproduce the plan's
    wire-byte accounting."""
    trace_path = tmp_path / "trace.json"
    metrics_path = tmp_path / "metrics.json"
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "examples/train_distributed_gcn.py", "--steps", "12",
         "--trace", str(trace_path), "--metrics", str(metrics_path)],
        capture_output=True, text=True, timeout=560,
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    doc = json.loads(trace_path.read_text())
    ev = doc["traceEvents"]
    wire = [e for e in ev if e.get("name") == "halo.exchange.boundary_collective"]
    interior = [e for e in ev if e.get("name") == "overlap.interior_compute"]
    assert wire and interior
    assert any(
        w["ts"] <= i["ts"] and i["ts"] + i["dur"] <= w["ts"] + w["dur"]
        for w in wire for i in interior
    ), "no wire span encloses an interior-compute span"
    # wire spans live on their own named track
    tids = {e["tid"] for e in wire}
    tracks = {e["tid"]: e["args"]["name"] for e in ev
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert all(tracks.get(t) == "wire" for t in tids)
    snap = json.loads(metrics_path.read_text())
    rows = snap["halo.rows_per_device{tier=total}"]["value"]
    d_feat = 64  # reduced cora feature width (make_dataset("cora", reduced=True))
    assert snap["halo.wire_bytes_per_exchange"]["value"] == rows * d_feat * 4
    assert snap["train.steps"]["value"] == 12.0
    assert snap["train.step_ms"]["count"] == 12
