"""End-to-end behaviour tests for the COIN system (deliverable c)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_coin_pipeline_end_to_end():
    """Graph → partition → traffic → NoC energy → optimal-k: the whole COIN
    methodology on a Cora-stats synthetic graph."""
    from repro.core.energy import CoinEnergyModel
    from repro.core.noc import MeshNoC, gcn_layer_traffic
    from repro.core.partition import measured_probabilities, partition_graph
    from repro.core.solver import optimal_ce_count
    from repro.graph.generators import citation_like

    g = citation_like(2708, 10556, seed=0)
    part = partition_graph(g.n_nodes, g.edge_index, 16, method="bfs", seed=0, refine=True)
    p1, p2 = measured_probabilities(part)
    model = CoinEnergyModel(
        n_nodes=g.n_nodes, act_bits_sum=64.0,
        p_intra=float(p1.mean()), p_inter=float(p2.mean() * 16 / 15),
    )
    res = optimal_ce_count(model)
    # With MEASURED probabilities the optimum sits near but above the paper's
    # uniform-p 4×4 (higher measured p_intra favors more CEs — EXPERIMENTS.md).
    assert res.k_mesh in (9, 16, 25, 36)
    noc = MeshNoC(4, 4)
    traces = gcn_layer_traffic(part, [64.0])
    summary = noc.summarize(traces[0])
    assert summary.energy_j > 0 and summary.latency_s > 0
    # Halo (beyond-paper) never ships more than broadcast (paper-faithful).
    halo = noc.summarize(part.inter_ce_traffic_bits(64.0, broadcast=False))
    assert halo.total_bits <= summary.total_bits


def test_gcn_trains_to_better_than_chance():
    """Train the paper's GCN (reduced Cora) — accuracy must beat chance by 2×."""
    from repro.graph.generators import make_dataset
    from repro.graph.structure import to_padded
    from repro.models.gcn import GCNConfig, gcn_forward, gcn_loss, gcn_init
    from repro.train.optimizer import adam

    spec, g = make_dataset("cora", reduced=True)
    gs = g.symmetrized().with_self_loops()
    pg = to_padded(gs, weights=gs.sym_normalized_weights())
    cfg = GCNConfig(layer_dims=(spec.n_features, 16, spec.n_labels))
    params = gcn_init(jax.random.PRNGKey(0), cfg)
    feats = jnp.asarray(g.features)
    labels = jnp.asarray(g.labels)
    mask = jnp.ones(spec.n_nodes)
    opt = adam(1e-2)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(gcn_loss)(
            params, feats, pg.senders, pg.receivers, pg.edge_weight, labels, mask, cfg
        )
        params, state = opt.update(grads, state, params)
        return params, state, loss

    for _ in range(60):
        params, state, loss = step(params, state)
    logits = gcn_forward(params, feats, pg.senders, pg.receivers, pg.edge_weight, cfg)
    acc = float((jnp.argmax(logits, -1) == labels).mean())
    assert acc > 2.0 / spec.n_labels, acc


@pytest.mark.slow
def test_dryrun_cell_smoke_subprocess():
    """One real dry-run cell on 64 virtual devices in a fresh process
    (device count must be set before jax init, so not in-process)."""
    code = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=64';\n"
        "import sys; sys.path.insert(0, %r)\n"
        "import jax\n"
        "from repro.configs import get_arch\n"
        "from repro.launch.steps import build_cell\n"
        "mesh = jax.make_mesh((4, 16), ('data', 'model'))\n"
        "spec = get_arch('pna')\n"
        "cell = build_cell(spec, spec.shapes['full_graph_sm'], mesh)\n"
        "compiled = cell.lower(mesh).compile()\n"
        "assert (compiled.cost_analysis() or {}).get('flops', 0) > 0\n"
        "print('SMOKE_OK')\n"
    ) % os.path.abspath(SRC)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=300
    )
    assert "SMOKE_OK" in out.stdout, out.stderr[-1500:]


@pytest.mark.slow
def test_compressed_psum_subprocess():
    """int8 reduce-scatter/all-gather mean == exact mean within quant error,
    run under shard_map on 8 virtual devices."""
    code = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';\n"
        "import sys; sys.path.insert(0, %r)\n"
        "import jax, jax.numpy as jnp, numpy as np\n"
        "from jax.sharding import PartitionSpec as P\n"
        "from repro.train.compression import compressed_psum_mean\n"
        "mesh = jax.make_mesh((8,), ('data',))\n"
        "x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 128)), jnp.float32)\n"
        "f = jax.shard_map(lambda s: compressed_psum_mean(s[0], 'data'),\n"
        "                  mesh=mesh, in_specs=P('data', None), out_specs=P(),\n"
        "                  check_vma=False)\n"
        "approx = f(x)\n"
        "exact = x.mean(0)\n"
        "err = float(jnp.abs(approx - exact).max())\n"
        "assert err < 0.1, err\n"
        "print('PSUM_OK', err)\n"
    ) % os.path.abspath(SRC)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=300
    )
    assert "PSUM_OK" in out.stdout, out.stderr[-1500:]


def test_dryrun_results_complete_if_present():
    """If the base 16x16 sweep has been run, every assigned cell must be OK
    or a documented SKIP (the multi-pod dry-run contract). A results file
    that only holds tagged variant records (e.g. '+opt+bf16' re-runs) is a
    resumable file whose base sweep has NOT been executed yet — the same
    skip as no file at all, not a failure. Normalizes both results schemas
    (v1 bare list, v2 wrapper) inline rather than importing
    `repro.launch.dryrun.load_results`: that module pins XLA_FLAGS to 512
    host devices at import, which must not leak into this process's env."""
    path = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("dry-run sweep not yet executed")
    data = json.load(open(path))
    recs = data.get("records", []) if isinstance(data, dict) else data
    singles = [r for r in recs if r["mesh"] == "16x16"]
    if not singles:
        pytest.skip("base 16x16 dry-run sweep not yet executed")
    assert len(singles) >= 40
    bad = [r for r in singles if r["status"] == "FAIL"]
    assert not bad, [(r["arch"], r["shape"], r.get("error")) for r in bad]
