"""Partitioner + NoC model: invariants and cross-checks."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.noc import CMeshNoC, MeshNoC, baseline_broadcast_summary
from repro.core.partition import measured_probabilities, partition_graph, refine_partition
from repro.graph.generators import citation_like, random_graph


def _graph(n=500, e=3000, seed=0):
    g = citation_like(n, e, seed=seed)
    return g.n_nodes, g.edge_index


@pytest.mark.parametrize("method", ["block", "random", "bfs"])
def test_partition_invariants(method):
    n, ei = _graph()
    p = partition_graph(n, ei, 8, method=method, seed=1)
    assert p.part_sizes.sum() == n
    assert p.edge_counts.sum() == ei.shape[1]
    assert p.intra_edges + p.cut_edges == ei.shape[1]
    p1, p2 = measured_probabilities(p)
    assert np.all(p1 >= 0) and np.all(p1 <= 1)
    assert np.all(p2 >= 0) and np.all(p2 <= 1)
    assert np.allclose(np.diag(p2), 0)
    if method == "bfs":
        # BFS growth enforces the cap per level (a whole frontier can land
        # in one part before sizes refresh), so allow one frontier of slack.
        assert p.part_sizes.max() <= int(np.ceil(n / 8) * 1.25)


def test_refinement_reduces_cut_on_homophilous_graph():
    n, ei = _graph(800, 6000, seed=3)
    base = partition_graph(n, ei, 8, method="random", seed=0)
    refined_asg = refine_partition(base.assignment, 8, ei[0], ei[1], passes=3)
    refined = partition_graph(n, ei, 8, method="random", seed=0)
    refined.assignment[:] = refined_asg
    from repro.core.partition import _edge_count_matrix

    counts = _edge_count_matrix(refined_asg, 8, ei[0].astype(np.int64), ei[1].astype(np.int64))
    cut_after = counts.sum() - np.trace(counts)
    assert cut_after <= base.cut_edges


def test_noc_energy_linear_and_hops_exact():
    noc = MeshNoC(4, 4)
    t = np.zeros((16, 16))
    t[0, 15] = 1000.0  # corner to corner: 3+3 = 6 hops
    e1, hop_bits = noc.energy_for_traffic(t)
    assert hop_bits == 6000.0
    e2, _ = noc.energy_for_traffic(2 * t)
    assert np.isclose(e2, 2 * e1)


def test_link_load_conservation():
    """Σ link loads == Σ bits × hops under X-Y routing."""
    rng = np.random.default_rng(0)
    noc = MeshNoC(3, 5)
    t = rng.random((15, 15)) * 100
    np.fill_diagonal(t, 0)
    h, v = noc.link_loads(t)
    _, hop_bits = noc.energy_for_traffic(t)
    assert np.isclose(h.sum() + v.sum(), hop_bits, rtol=1e-9)


def test_baseline_closed_form_matches_matrix():
    """Uniform broadcast: closed form == explicit matrix model (small k)."""
    noc = MeshNoC(4, 4)
    n = 16
    bits = 64.0
    t = np.full((n, n), bits)
    np.fill_diagonal(t, 0)
    e_matrix, hop_matrix = noc.energy_for_traffic(t)
    s = baseline_broadcast_summary(noc, n, bits)
    assert np.isclose(s.hop_bits, hop_matrix, rtol=1e-12)
    assert np.isclose(s.energy_j, e_matrix, rtol=1e-12)


def test_cmesh_lower_latency_higher_energy():
    mesh, cmesh = MeshNoC(4, 4), CMeshNoC(4, 4)
    rng = np.random.default_rng(1)
    t = rng.random((16, 16)) * 1e6
    np.fill_diagonal(t, 0)
    sm, sc = mesh.summarize(t), cmesh.summarize(t)
    assert sc.energy_j > sm.energy_j          # Fig. 12: c-mesh costs energy
    assert sc.hop_bits < sm.hop_bits          # …because express links cut hops


def test_broadcast_vs_halo_traffic():
    """The beyond-paper halo exchange ships no more than the broadcast."""
    n, ei = _graph(600, 4000, seed=2)
    p = partition_graph(n, ei, 8, method="bfs", seed=0, refine=True)
    b = p.inter_ce_traffic_bits(64, broadcast=True).sum()
    h = p.inter_ce_traffic_bits(64, broadcast=False).sum()
    assert h <= b


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(2, 6), cols=st.integers(2, 6), seed=st.integers(0, 100))
def test_latency_monotone_in_traffic(rows, cols, seed):
    noc = MeshNoC(rows, cols)
    k = rows * cols
    rng = np.random.default_rng(seed)
    t = rng.random((k, k)) * 1e4
    np.fill_diagonal(t, 0)
    l1 = noc.latency_for_traffic(t)
    l2 = noc.latency_for_traffic(3 * t)
    assert l2 >= l1
