"""COIN TPU planner + mesh plans + scheduler edge cases."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.planner import TPUHardware, coin_objective_tpu, plan_gnn_sharding
from repro.train.elastic import MeshPlan


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1000, 3_000_000),
    e=st.integers(1000, 50_000_000),
    devices=st.sampled_from([16, 64, 256, 512]),
)
def test_planner_never_worse_than_extremes(n, e, devices):
    """The chosen plan is at least as good as no-model-parallelism and
    full-model-parallelism (it searches all divisors)."""
    dims = [128, 16, 8]
    best = plan_gnn_sharding(n, e, dims, devices)
    for k in (1, devices):
        comp, intra, inter = coin_objective_tpu(n, e, dims, k)
        step = max(comp, intra) + inter
        assert best.est_step_s <= step + 1e-12


def test_planner_halo_beats_broadcast_on_low_cut():
    spec = dict(n_nodes=1_000_000, n_edges=20_000_000, feat_dims=[256, 64, 16])
    bc = plan_gnn_sharding(**spec, n_devices=256, schedule="broadcast")
    halo = plan_gnn_sharding(**spec, n_devices=256, schedule="halo", cut_fraction=0.1)
    assert halo.est_step_s < bc.est_step_s


def test_objective_terms_scale_sanely():
    """Intra/compute shrink with k; broadcast inter is ~flat (the COIN
    tension: parallelism is free except for the exchange)."""
    comp1, intra1, inter1 = coin_objective_tpu(100_000, 1_000_000, [64, 16], 16)
    comp2, intra2, inter2 = coin_objective_tpu(100_000, 1_000_000, [64, 16], 64)
    assert comp2 < comp1 and intra2 < intra1
    # broadcast inter carries the (k−1)/k factor → near-flat at large k
    assert inter2 == pytest.approx(inter1 * (63 / 64) / (15 / 16), rel=1e-6)


def test_mesh_plan_builds_on_local_devices():
    plan = MeshPlan(shape=(1, 1), axes=("data", "model"))
    mesh = plan.build()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size == 1


def test_scheduler_eos_and_overflow_guard():
    from repro.models.transformer_lm import LMConfig, lm_init
    from repro.serve.scheduler import ContinuousBatcher, Request

    cfg = LMConfig("tiny", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_ff=32, vocab=11)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(params, cfg, n_slots=2, max_len=12)
    # EOS on every token id (vocab tiny) → requests stop at first sample.
    cb.submit(Request(rid=0, prompt=np.asarray([1, 2], np.int32), max_new_tokens=8,
                      eos_id=int(np.argmax(np.zeros(1)))))  # eos likely hit by argmax
    finished = cb.run_until_drained()
    assert len(finished) == 1 and finished[0].done
    # Overflowing prompt rejected up front.
    with pytest.raises(AssertionError):
        cb.submit(Request(rid=1, prompt=np.zeros(10, np.int32), max_new_tokens=8))
