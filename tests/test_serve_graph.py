"""repro.serve.graph: online GCN query serving + hot-neighbor cache.

Pins the subsystem's three contracts (ISSUE 3 acceptance):
  * compile-once — ONE trace serves micro-batches of different live sizes,
  * cache-on == cache-off logits (fp32 tolerance) with strictly fewer
    sampled nodes+edges per query,
  * degree-ranked eviction under a tiny capacity, and invalidation on
    weight/feature updates.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _delta_oracle import random_delta
from repro.core.partition import partition_graph
from repro.graph.generators import citation_like
from repro.models.gcn import GCNConfig, gcn_init
from repro.serve.graph import (
    GraphBatcher,
    HotNeighborCache,
    ServeSampler,
    hot_query_stream,
)


def _setup(seed=0, n=300, e=2400, f=16, c=4, hidden=8, dims=None):
    g = citation_like(n, e, f, c, seed=seed)
    cfg = GCNConfig(layer_dims=dims or (f, hidden, c))
    params = gcn_init(jax.random.PRNGKey(seed), cfg)
    return g, cfg, params


# ------------------------------------------------------------------- sampler
def test_serve_sampler_deterministic_and_pure():
    g, _, _ = _setup()
    s1 = ServeSampler(g, fanout=4, n_layers=2, seed=7)
    s2 = ServeSampler(g, fanout=4, n_layers=2, seed=7)
    nodes = np.arange(50)
    np.testing.assert_array_equal(s1.neighbors(nodes), s2.neighbors(nodes))
    # Purity: a node's draw does not depend on which batch it appears in.
    np.testing.assert_array_equal(
        s1.neighbors(np.asarray([3])), s1.neighbors(np.asarray([9, 3, 40]))[1:2]
    )
    # A different seed gives a different sampled graph.
    s3 = ServeSampler(g, fanout=4, n_layers=2, seed=8)
    assert not np.array_equal(s1.neighbors(nodes), s3.neighbors(nodes))


def test_serve_sampler_block_replay_identical():
    g, _, _ = _setup()
    s = ServeSampler(g.with_self_loops(), fanout=3, n_layers=2, seed=0)
    seeds = np.asarray([5, 17, 100])
    a = s.sample_block(seeds, batch_seeds=4)
    b = s.sample_block(seeds, batch_seeds=4)
    np.testing.assert_array_equal(a.node_ids, b.node_ids)
    np.testing.assert_array_equal(a.senders, b.senders)
    np.testing.assert_array_equal(a.receivers, b.receivers)
    np.testing.assert_allclose(a.edge_weight, b.edge_weight)
    # Ghost-padding hygiene: pads are inert (weight 0, ids out of valid range).
    assert np.all(a.node_ids[a.n_nodes:] == -1)
    assert np.all(a.senders[a.n_edges:] == a.max_nodes)
    assert np.all(a.edge_weight[a.n_edges:] == 0.0)
    assert a.senders[: a.n_edges].max() < a.n_nodes


# -------------------------------------------------------------- compile once
def test_compile_once_across_live_sizes():
    g, cfg, params = _setup()
    eng = GraphBatcher(params, g, cfg, batch_seeds=4, fanout=3, seed=0)
    for wave in ([1, 2, 3, 4], [5, 6], [7]):       # live sizes 4, 2, 1
        for v in wave:
            eng.submit(v)
        eng.step()
    assert eng.micro_batches == 3
    assert eng.traces == 1, "fixed-shape micro-batches must not retrace"
    assert all(q.logits is not None for q in eng.finished)


# ------------------------------------------------------- cache == no cache
def _serve_two_waves(g, cfg, params, nodes, capacity):
    eng = GraphBatcher(params, g, cfg, batch_seeds=4, fanout=4,
                       cache_capacity=capacity, seed=0)
    for wave in (nodes, nodes):                    # second wave replays hot set
        for v in wave:
            eng.submit(int(v))
        eng.run_until_drained()
    return eng


def test_cache_on_matches_cache_off_with_fewer_samples():
    g, cfg, params = _setup()
    nodes = hot_query_stream(g, 40)
    off = _serve_two_waves(g, cfg, params, nodes, capacity=0)
    on = _serve_two_waves(g, cfg, params, nodes, capacity=64)
    lo = {q.qid: q.logits for q in off.finished}
    ln = {q.qid: q.logits for q in on.finished}
    assert set(lo) == set(ln)
    for k in lo:
        np.testing.assert_allclose(ln[k], lo[k], rtol=1e-5, atol=1e-5)
    assert on.cache.hits > 0
    assert (on.nodes_sampled + on.edges_sampled) < (off.nodes_sampled + off.edges_sampled)
    s = on.stats()["cache"]
    assert s["rows_saved"] > 0 and s["bytes_saved"] > 0


def test_cache_exactness_three_layer_gcn():
    """Deep-GCN regression: every edge runs at every layer in the merged
    forward, so requirements must propagate as (node, layer) pairs — a
    truncated hub's non-injected layers must never leak into a read value
    (they did under naive depth-BFS truncation, e.g. via self-loops)."""
    g, cfg, params = _setup(dims=(16, 8, 8, 4))          # 3 layers
    nodes = hot_query_stream(g, 40)
    off = _serve_two_waves(g, cfg, params, nodes, capacity=0)
    on = _serve_two_waves(g, cfg, params, nodes, capacity=64)
    assert on.cache.hits > 0
    lo = {q.qid: q.logits for q in off.finished}
    for q in on.finished:
        np.testing.assert_allclose(q.logits, lo[q.qid], rtol=1e-5, atol=1e-5)
    assert (on.nodes_sampled + on.edges_sampled) < (off.nodes_sampled + off.edges_sampled)


def test_eviction_under_tiny_capacity():
    g, cfg, params = _setup()
    nodes = hot_query_stream(g, 48)
    on = _serve_two_waves(g, cfg, params, nodes, capacity=2)
    assert len(on.cache) <= 2
    assert on.cache.evictions > 0
    # Correctness must survive eviction churn.
    off = _serve_two_waves(g, cfg, params, nodes, capacity=0)
    for qo, qn in zip(off.finished, on.finished):
        np.testing.assert_allclose(qn.logits, qo.logits, rtol=1e-5, atol=1e-5)


def test_cache_hits_counted_exactly_once_hand_counted():
    """Regression (ISSUE 6 satellite 2): ``stats()["hits"]`` counts each
    serving hit EXACTLY once — at lookup time during sampling. The old
    harvest path re-added ``blk.cache_hits`` on top, doubling hits and
    inflating hit_rate. Hand-counted: sample a block against an empty cache,
    admit the frontier's layer-1 rows, resample — every lookup tally on the
    cache must equal the block's own per-sample counts."""
    g, cfg, _ = _setup()
    s = ServeSampler(g, fanout=3, n_layers=2, seed=0)
    c = HotNeighborCache(capacity=64, degree=s.in_deg)
    seeds = np.asarray([5, 17])
    blk = s.sample_block(seeds, batch_seeds=2, cache=c)
    # cold cache: every lookup misses, counted once each, zero hits
    assert blk.cache_hits == 0 and c.hits == 0
    assert blk.cache_misses > 0 and c.misses == blk.cache_misses
    # Warm every block node's layer-1 row (a superset of what was looked
    # up — extra entries are inert, only actual lookups count), resample:
    # the same layer-1 lookups now hit, once per lookup, nothing re-added
    # on any other path.
    for v in blk.node_ids[: blk.n_nodes]:
        c.admit(int(v), 1, np.ones(cfg.layer_dims[1], np.float32))
    h0, m0 = c.hits, c.misses
    blk2 = s.sample_block(seeds, batch_seeds=2, cache=c)
    assert blk2.cache_hits > 0
    assert c.hits - h0 == blk2.cache_hits          # exactly once per hit
    assert c.misses - m0 == blk2.cache_misses
    assert c.stats()["hits"] == c.hits
    assert c.stats()["hit_rate"] == pytest.approx(
        c.hits / (c.hits + c.misses)
    )


def test_engine_hits_match_lookup_tally():
    """End-to-end double-count guard: wrap ``cache.lookup`` to count calls
    independently; after serving two waves the engine's ``stats()`` hit/miss
    totals must equal the wrapper's tally (the old harvest re-add made
    ``hits`` exactly double the true count)."""
    g, cfg, params = _setup()
    nodes = hot_query_stream(g, 40)
    eng = GraphBatcher(params, g, cfg, batch_seeds=4, fanout=4,
                       cache_capacity=64, seed=0)
    calls = {"hit": 0, "miss": 0}
    orig_lookup = eng.cache.lookup

    def counting_lookup(node, layer):
        val = orig_lookup(node, layer)
        calls["hit" if val is not None else "miss"] += 1
        return val

    eng.cache.lookup = counting_lookup
    for wave in (nodes, nodes):
        for v in wave:
            eng.submit(int(v))
        eng.run_until_drained()
    s = eng.stats()["cache"]
    assert calls["hit"] > 0
    assert s["hits"] == calls["hit"]
    assert s["misses"] == calls["miss"]


def test_bytes_saved_dtype_aware_formula():
    """bytes_saved derives from the feature array's dtype itemsize and the
    injected row's actual nbytes (not a hard-coded 4·F with no injection
    credit): each layer-1 injection saves rows·F·itemsize gathered feature
    bytes minus the H·itemsize activation row shipped in their place."""
    g, cfg, params = _setup()                       # F=16, H=8, 2 layers
    nodes = hot_query_stream(g, 32)
    on = _serve_two_waves(g, cfg, params, nodes, capacity=64)
    s = on.stats()["cache"]
    assert s["rows_saved"] > 0
    feat_bytes = on.features.dtype.itemsize * on.features.shape[1]
    row_bytes = on.features.dtype.itemsize * cfg.layer_dims[1]
    rows_per = on.sampler.subtree_counts(1)[0]      # per-injection row credit
    assert s["rows_saved"] % rows_per == 0
    n_inj = s["rows_saved"] // rows_per
    assert s["bytes_saved"] == pytest.approx(
        s["rows_saved"] * feat_bytes - n_inj * row_bytes
    )
    # the injected activation row is a real cost — never free bandwidth
    assert s["bytes_saved"] < s["rows_saved"] * feat_bytes


def test_degree_ranked_admission():
    deg = np.asarray([10, 1, 5, 7])
    c = HotNeighborCache(capacity=2, degree=deg)
    v = np.ones(4, np.float32)
    assert c.admit(1, 1, v)            # deg 1
    assert c.admit(2, 1, v)            # deg 5 → full
    assert c.admit(0, 1, v)            # deg 10 evicts deg 1
    assert c.lookup(1, 1) is None and c.lookup(0, 1) is not None
    assert not c.admit(1, 1, v)        # deg 1 cannot evict deg 5
    assert c.evictions == 1


# ------------------------------------------------------------- invalidation
def test_cache_invalidated_on_weight_and_feature_update():
    g, cfg, params = _setup()
    nodes = hot_query_stream(g, 24)
    eng = GraphBatcher(params, g, cfg, batch_seeds=4, fanout=4,
                       cache_capacity=64, seed=0)
    for v in nodes:
        eng.submit(int(v))
    eng.run_until_drained()
    assert len(eng.cache) > 0
    new_params = gcn_init(jax.random.PRNGKey(99), cfg)
    eng.update_params(new_params)
    assert len(eng.cache) == 0 and eng.cache.invalidations == 1
    # Post-update logits must match a fresh engine on the new weights (no
    # stale activation may leak through the cache).
    for v in nodes:
        eng.submit(int(v))
    eng.run_until_drained()
    ref = GraphBatcher(new_params, g, cfg, batch_seeds=4, fanout=4, seed=0)
    for v in nodes:
        ref.submit(int(v))
    ref.run_until_drained()
    for qa, qb in zip(eng.finished[len(nodes):], ref.finished):
        np.testing.assert_allclose(qa.logits, qb.logits, rtol=1e-5, atol=1e-5)
    eng.update_features(np.asarray(g.features))
    assert eng.cache.invalidations == 2


# ------------------------------------------------------- partition packing
def test_partition_aligned_packing_groups_parts():
    g, cfg, params = _setup()
    part = partition_graph(g.n_nodes, g.edge_index, 2, method="block")
    eng = GraphBatcher(params, g, cfg, batch_seeds=4, fanout=3,
                       partition=part, seed=0)
    # Interleave queries from the two halves; packing should un-interleave.
    lo, hi = [1, 2, 3, 4], [290, 291, 292, 293]
    for a, b in zip(lo, hi):
        eng.submit(a)
        eng.submit(b)
    first = eng.step()
    second = eng.step()
    p_first = {int(part.assignment[q.node]) for q in first}
    p_second = {int(part.assignment[q.node]) for q in second}
    assert len(p_first) == 1 and len(p_second) == 1 and p_first != p_second


# ------------------------------------------------------------ other models
def test_pna_and_egnn_serve_smoke():
    from repro.models.egnn import EGNNConfig, egnn_init
    from repro.models.pna import PNAConfig, pna_init

    g = citation_like(120, 900, 8, 3, seed=0, with_positions=True)
    pcfg = PNAConfig(n_layers=2, d_hidden=12, d_in=8, d_out=3)
    eng = GraphBatcher(pna_init(jax.random.PRNGKey(0), pcfg), g, pcfg,
                       model="pna", batch_seeds=3, fanout=3, seed=0)
    for v in (4, 9, 40, 80):
        eng.submit(v)
    eng.run_until_drained()
    assert eng.traces == 1 and all(np.isfinite(q.logits).all() for q in eng.finished)

    ecfg = EGNNConfig(n_layers=2, d_hidden=12, d_in=8, d_out=2)
    eng = GraphBatcher(egnn_init(jax.random.PRNGKey(0), ecfg), g, ecfg,
                       model="egnn", batch_seeds=3, fanout=3, seed=0)
    for v in (4, 9, 40):
        eng.submit(v)
    eng.run_until_drained()
    assert eng.traces == 1 and all(np.isfinite(q.logits).all() for q in eng.finished)

    with pytest.raises(ValueError):
        GraphBatcher(pna_init(jax.random.PRNGKey(0), pcfg), g, pcfg,
                     model="pna", cache_capacity=8)


# ------------------------------------------------------- mutating the graph
def _fresh_oracle(eng):
    """A cache-less engine rebuilt on ``eng``'s CURRENT graph — the no-cache
    ground truth for whatever mutations ``eng`` has absorbed in place."""
    return GraphBatcher(eng.params, eng.graph, eng.cfg,
                        batch_seeds=eng.batch_seeds, fanout=eng.sampler.fanout,
                        cache_capacity=0, seed=eng._seed)


def _serve_wave(eng, nodes):
    start = len(eng.finished)
    for v in nodes:
        eng.submit(int(v))
    eng.run_until_drained()
    done = eng.finished[start:]
    base = min(q.qid for q in done)
    return {q.qid - base: q.logits for q in done}


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 40))
def test_interleaved_mutations_match_no_cache_oracle(seed, delta_seed):
    """Property: under ANY interleaving of {serve wave, GraphDelta,
    scoped feature update} the cached engine's logits match a fresh
    cache-less engine rebuilt on the current graph — i.e. the scoped
    frontier-walk invalidation never leaves a stale activation behind."""
    rng = np.random.default_rng((seed << 10) ^ delta_seed)
    g, cfg, params = _setup(seed=seed % 5, n=40, e=160, f=8, hidden=6)
    eng = GraphBatcher(params, g, cfg, batch_seeds=4, fanout=2,
                       cache_capacity=16, seed=0)
    f_dim = g.features.shape[1]
    for _ in range(8):
        op = rng.random()
        if op < 0.30:
            d = random_delta(rng, g.n_nodes, eng.graph.edge_index,
                             max_ops=6, feat_dim=f_dim)
            rep = eng.apply_graph_delta(d)
            assert rep["residents_dropped"] <= rep["residents_before"]
        elif op < 0.45:
            touched = np.unique(rng.integers(0, g.n_nodes, 3))
            feats = np.array(eng.features)
            feats[touched] += rng.standard_normal(
                (touched.size, f_dim)).astype(np.float32)
            eng.update_features(feats, touched=touched)
        # hot skew (nodes 0..15) so replays actually hit the cache
        wave = rng.integers(0, 16, 4)
        got = _serve_wave(eng, wave)
        want = _serve_wave(_fresh_oracle(eng), wave)
        for k in want:
            np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-5)
    assert eng.cache.hits > 0, "interleaving never exercised the cache"


def test_scoped_invalidation_drops_strictly_fewer_than_all():
    """A localized delta (one low-degree edge deleted) must NOT nuke the
    cache: only residents whose sampled cone reaches the endpoints drop,
    the survivors keep serving, and post-delta logits stay exact."""
    g, cfg, params = _setup()
    nodes = hot_query_stream(g, 40)
    eng = _serve_two_waves(g, cfg, params, nodes, capacity=64)
    resident = len(eng.cache)
    assert resident > 8, "need a warm cache for the scoped-drop contract"
    deg = eng.sampler.in_deg
    ei = eng.graph.edge_index
    quiet = int(np.argmin(deg[ei[0]] + deg[ei[1]]))
    from repro.dist.delta import GraphDelta
    rep = eng.apply_graph_delta(GraphDelta(edge_deletes=ei[:, [quiet]]))
    assert rep["residents_before"] == resident
    assert rep["residents_dropped"] < resident, (
        "scoped invalidation degenerated into a full flush")
    assert len(eng.cache) == resident - rep["residents_dropped"]
    assert eng.cache.scoped_invalidations == 1
    assert eng.cache.invalidations == 0, "must not take the full-flush path"
    got = _serve_wave(eng, nodes)
    want = _serve_wave(_fresh_oracle(eng), nodes)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-5)
