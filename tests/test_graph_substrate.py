"""Graph substrate: message passing, blocking, sampling, generators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph.generators import TABLE_I, citation_like, make_dataset, molecule_batch
from repro.graph.ops import (
    aggregate,
    aggregate_padded,
    multi_aggregate,
    segment_softmax,
    sym_norm_edge_weights,
)
from repro.graph.sampler import NeighborSampler
from repro.graph.structure import GraphData, blocked_adjacency, to_padded


@settings(max_examples=20, deadline=None)
@given(n=st.integers(5, 200), e=st.integers(1, 1000), f=st.integers(1, 32), seed=st.integers(0, 99))
def test_aggregate_equals_dense_matmul(n, e, f, seed):
    r = np.random.default_rng(seed)
    s = r.integers(0, n, e)
    d = r.integers(0, n, e)
    w = r.standard_normal(e).astype(np.float32)
    z = r.standard_normal((n, f)).astype(np.float32)
    a = np.zeros((n, n), np.float32)
    np.add.at(a, (d, s), w)
    out = aggregate(jnp.asarray(z), jnp.asarray(s), jnp.asarray(d), n, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), a @ z, rtol=2e-4, atol=2e-4)


def test_aggregate_padded_drops_ghost():
    n, e = 10, 20
    r = np.random.default_rng(0)
    s = np.concatenate([r.integers(0, n, e), np.full(5, n)]).astype(np.int32)
    d = np.concatenate([r.integers(0, n, e), np.full(5, n)]).astype(np.int32)
    w = np.concatenate([np.ones(e), np.zeros(5)]).astype(np.float32)
    z = jnp.asarray(r.standard_normal((n, 4)), jnp.float32)
    out = aggregate_padded(z, jnp.asarray(s), jnp.asarray(d), n, jnp.asarray(w))
    ref = aggregate(z, jnp.asarray(s[:e]), jnp.asarray(d[:e]), n, jnp.asarray(w[:e]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_segment_softmax_sums_to_one():
    r = np.random.default_rng(0)
    recv = jnp.asarray(r.integers(0, 20, 200))
    logits = jnp.asarray(r.standard_normal(200), jnp.float32)
    sm = segment_softmax(logits, recv, 20)
    sums = jax.ops.segment_sum(sm, recv, num_segments=20)
    touched = np.asarray(jax.ops.segment_sum(jnp.ones(200), recv, num_segments=20)) > 0
    np.testing.assert_allclose(np.asarray(sums)[touched], 1.0, rtol=1e-5)


def test_sym_norm_matches_host_version():
    g = citation_like(300, 1500, seed=1).symmetrized().with_self_loops()
    host = g.sym_normalized_weights()
    dev = sym_norm_edge_weights(
        jnp.asarray(g.edge_index[0]), jnp.asarray(g.edge_index[1]), g.n_nodes
    )
    np.testing.assert_allclose(np.asarray(dev), host, rtol=1e-5)


def test_multi_aggregate_consistency():
    r = np.random.default_rng(0)
    n, e, f = 30, 200, 8
    s, d = r.integers(0, n, e), r.integers(0, n, e)
    z = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    aggs = multi_aggregate(z, jnp.asarray(s), jnp.asarray(d), n)
    assert np.all(np.asarray(aggs["max"]) >= np.asarray(aggs["min"]) - 1e-6)
    assert np.all(np.asarray(aggs["std"]) >= -1e-6)
    # mean lies within [min, max] for touched nodes
    touched = np.asarray(aggregate(jnp.ones((n, 1)), jnp.asarray(s), jnp.asarray(d), n))[:, 0] > 0
    mean, mx, mn = (np.asarray(aggs[k]) for k in ("mean", "max", "min"))
    assert np.all(mean[touched] <= mx[touched] + 1e-5)
    assert np.all(mean[touched] >= mn[touched] - 1e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(64, 600), e=st.integers(64, 3000), seed=st.integers(0, 20))
def test_blocked_adjacency_reconstructs(n, e, seed):
    r = np.random.default_rng(seed)
    ei = r.integers(0, n, size=(2, e)).astype(np.int32)
    w = r.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    dense = np.zeros((ba.n_padded, ba.n_padded), np.float32)
    np.add.at(dense, (ei[1], ei[0]), w)
    recon = np.zeros_like(dense)
    for rr in range(ba.n_block_rows):
        for t in range(int(ba.row_nnzb[rr])):
            c = ba.block_cols[rr, t]
            recon[rr * 128:(rr + 1) * 128, c * 128:(c + 1) * 128] += ba.block_vals[rr, t]
    np.testing.assert_allclose(recon, dense, rtol=1e-6)


def test_sampler_shapes_and_membership():
    g = citation_like(2000, 12000, seed=0)
    samp = NeighborSampler(g, fanout=(5, 3), seed=1)
    seeds = np.arange(64)
    blk = samp.sample(seeds)
    assert blk.senders.shape[0] == blk.max_edges == 64 * 5 + 64 * 5 * 3
    assert blk.n_edges == blk.max_edges
    # Seeds occupy the first rows; all local ids in range.
    np.testing.assert_array_equal(blk.node_ids[:64], seeds)
    assert blk.senders[: blk.n_edges].max() < blk.n_nodes
    # Every real edge exists in the graph OR is an isolated-node self-message.
    gids_s = blk.node_ids[blk.senders[: blk.n_edges]]
    gids_d = blk.node_ids[blk.receivers[: blk.n_edges]]
    edge_set = set(map(tuple, g.edge_index.T.tolist()))
    for a, b in zip(gids_s[:300], gids_d[:300]):
        assert (a, b) in edge_set or a == b


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 80),
    e=st.integers(0, 300),
    fan=st.integers(1, 9),
    seed=st.integers(0, 50),
)
def test_sampler_zero_degree_and_overfanout_property(n, e, fan, seed):
    """Zero-in-degree seeds, edgeless graphs, and fanout > degree must all
    produce ghost-padded blocks without relabeling corruption."""
    r = np.random.default_rng(seed)
    ei = r.integers(0, n, size=(2, e)).astype(np.int32) if e else np.zeros((2, 0), np.int32)
    g = GraphData(n_nodes=n, edge_index=ei)
    samp = NeighborSampler(g, fanout=(fan, fan), seed=seed)
    n_seeds = min(4, n)
    seeds = r.choice(n, size=n_seeds, replace=False)
    blk = samp.sample(seeds)
    # Seeds occupy the leading rows, in order.
    np.testing.assert_array_equal(blk.node_ids[:n_seeds], seeds)
    # The valid node prefix is unique and in range; padding is the ghost id.
    valid = blk.node_ids[: blk.n_nodes]
    assert np.unique(valid).shape[0] == blk.n_nodes and valid.max() < n
    assert np.all(blk.node_ids[blk.n_nodes:] == n)
    # Local edge endpoints stay inside the valid prefix; pads point at the
    # ghost row (max_nodes) so a padded gather reads the appended zero row.
    assert blk.n_edges == 0 or blk.senders[: blk.n_edges].max() < blk.n_nodes
    assert blk.n_edges == 0 or blk.receivers[: blk.n_edges].max() < blk.n_nodes
    assert np.all(blk.senders[blk.n_edges:] == blk.max_nodes)
    assert np.all(blk.receivers[blk.n_edges:] == blk.max_nodes)
    # Every materialized edge is a real graph edge or an isolated-node
    # self-message (the zero-in-degree escape).
    edge_set = set(map(tuple, ei.T.tolist()))
    gs = blk.node_ids[blk.senders[: blk.n_edges]]
    gd = blk.node_ids[blk.receivers[: blk.n_edges]]
    deg_in = np.bincount(ei[1], minlength=n)
    for a, b in zip(gs.tolist(), gd.tolist()):
        assert (a, b) in edge_set or (a == b and deg_in[b] == 0)


def test_sampler_rejects_duplicate_seeds():
    g = citation_like(100, 500, seed=0)
    samp = NeighborSampler(g, fanout=(3,), seed=0)
    with pytest.raises(ValueError):
        samp.sample(np.asarray([5, 5, 9]))


def test_generators_exact_counts():
    for name, spec in TABLE_I.items():
        if spec.n_nodes > 25_000:
            continue  # keep the test fast; sizes checked via small ones + nell below
        g = citation_like(spec.n_nodes, spec.n_edges, None, spec.n_labels, seed=0)
        assert g.n_nodes == spec.n_nodes and g.n_edges == spec.n_edges
    mb = molecule_batch(n_graphs=8, nodes_per_graph=30, edges_per_graph=64)
    assert mb.n_nodes == 240 and mb.n_edges == 512
    # Edges never cross packed-graph boundaries.
    gid_s = mb.edge_index[0] // 30
    gid_d = mb.edge_index[1] // 30
    assert np.array_equal(gid_s, gid_d)


def test_make_dataset_reduced():
    spec, g = make_dataset("cora", reduced=True)
    assert g.features is not None and g.labels is not None
    assert g.n_nodes == spec.n_nodes
