"""Deliverable (f): per-architecture smoke tests.

For each of the 10 assigned architectures (+ the paper's coin_gcn):
instantiate the REDUCED config, run one forward AND one train step on CPU,
assert output shapes and no NaNs. Full configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_arch

KEY = jax.random.PRNGKey(0)


def _finite(x) -> bool:
    return bool(jnp.all(jnp.isfinite(x)))


def _tiny_graph(n=40, e=160, d_in=8, seed=0):
    r = np.random.default_rng(seed)
    s = r.integers(0, n, e).astype(np.int32)
    d = (s + 1 + r.integers(0, n - 1, e)).astype(np.int32) % n
    return (
        jnp.asarray(r.standard_normal((n, d_in)), jnp.float32),
        jnp.asarray(s),
        jnp.asarray(d),
        jnp.asarray(r.standard_normal((n, 3)), jnp.float32),
    )


@pytest.mark.parametrize("arch_id", [a for a in ALL_ARCHS if get_arch(a).family == "lm"])
def test_lm_smoke(arch_id):
    from repro.models.transformer_lm import lm_forward, lm_init, lm_loss
    from repro.train.optimizer import adam

    spec = get_arch(arch_id)
    cfg = spec.make_reduced()
    params = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    logits, aux = lm_forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert _finite(logits) and _finite(aux)
    # one train step
    opt = adam(1e-3)
    state = opt.init(params)
    loss, grads = jax.value_and_grad(lm_loss)(params, toks, cfg)
    params2, _ = opt.update(grads, state, params)
    assert _finite(loss)
    loss2 = lm_loss(params2, toks, cfg)
    assert _finite(loss2)


@pytest.mark.parametrize("arch_id", ["egnn", "pna", "graphcast", "equiformer-v2"])
def test_gnn_smoke(arch_id):
    from repro.train.optimizer import adam

    spec = get_arch(arch_id)
    cfg = spec.make_reduced()
    feats, s, r, pos = _tiny_graph(d_in=getattr(cfg, "d_in", 8) or 8)
    n = feats.shape[0]

    if arch_id == "egnn":
        from repro.models.egnn import egnn_forward as fwd, egnn_init as init

        params = init(KEY, cfg)
        out, coords = fwd(params, feats, pos, s, r, cfg)
        assert out.shape == (n, cfg.d_out) and coords.shape == (n, 3)
        loss_fn = lambda p: jnp.mean(fwd(p, feats, pos, s, r, cfg)[0] ** 2)
    elif arch_id == "pna":
        from repro.models.pna import pna_forward as fwd, pna_init as init

        params = init(KEY, cfg)
        out = fwd(params, feats, s, r, cfg)
        assert out.shape == (n, cfg.d_out)
        loss_fn = lambda p: jnp.mean(fwd(p, feats, s, r, cfg) ** 2)
    elif arch_id == "graphcast":
        from repro.models.graphcast import graphcast_forward as fwd, graphcast_init as init

        cfg2 = cfg
        x = feats[:, : cfg2.input_dim] if cfg2.input_dim <= feats.shape[1] else jnp.tile(feats, (1, 2))[:, : cfg2.input_dim]
        ef = jnp.ones((s.shape[0], cfg2.d_edge_in))
        params = init(KEY, cfg2)
        out = fwd(params, x, ef, s, r, cfg2)
        assert out.shape == (n, cfg2.n_vars)
        loss_fn = lambda p: jnp.mean(fwd(p, x, ef, s, r, cfg2) ** 2)
    else:
        from repro.models.equiformer_v2 import equiformer_forward as fwd, equiformer_init as init

        params = init(KEY, cfg)
        out = fwd(params, feats, pos, s, r, cfg)
        assert out.shape == (n, cfg.d_out)
        loss_fn = lambda p: jnp.mean(fwd(p, feats, pos, s, r, cfg) ** 2)

    assert _finite(out)
    opt = adam(1e-3)
    state = opt.init(params)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params2, _ = opt.update(grads, state, params)
    assert _finite(loss) and _finite(loss_fn(params2))


def test_deepfm_smoke():
    from repro.models.deepfm import deepfm_forward, deepfm_init, deepfm_loss, deepfm_retrieval
    from repro.train.optimizer import adam

    spec = get_arch("deepfm")
    cfg = spec.make_reduced()
    params = deepfm_init(KEY, cfg)
    ids = jax.random.randint(KEY, (32, cfg.n_fields), 0, cfg.rows_per_field)
    logits = deepfm_forward(params, ids, cfg)
    assert logits.shape == (32,) and _finite(logits)
    labels = (jax.random.uniform(KEY, (32,)) > 0.5).astype(jnp.float32)
    opt = adam(1e-3)
    state = opt.init(params)
    loss, grads = jax.value_and_grad(deepfm_loss)(params, ids, labels, cfg)
    params2, _ = opt.update(grads, state, params)
    assert _finite(loss) and _finite(deepfm_loss(params2, ids, labels, cfg))
    scores = deepfm_retrieval(params, ids[:2], jax.random.randint(KEY, (2, 64), 0, cfg.rows_per_field), cfg)
    assert scores.shape == (2, 64) and _finite(scores)


def test_coin_gcn_smoke():
    from repro.models.gcn import gcn_forward, gcn_init

    spec = get_arch("coin_gcn")
    cfg = spec.make_reduced()
    feats, s, r, _ = _tiny_graph(d_in=cfg.layer_dims[0])
    w = jnp.ones_like(s, dtype=jnp.float32)
    params = gcn_init(KEY, cfg)
    out = gcn_forward(params, feats, s, r, w, cfg)
    assert out.shape == (feats.shape[0], cfg.layer_dims[-1])
    assert _finite(out)


def test_registry_covers_40_cells():
    cells = 0
    for a in ALL_ARCHS:
        if a == "coin_gcn":
            continue
        cells += len(get_arch(a).shapes)
    assert cells == 40
    # long_500k runs exactly for the sub-quadratic LM arch (gemma3).
    runnable_500k = [
        a for a in ALL_ARCHS
        if get_arch(a).family == "lm"
        and get_arch(a).shapes["long_500k"].skip_reason is None
    ]
    assert runnable_500k == ["gemma3-12b"]
