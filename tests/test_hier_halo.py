"""Hierarchical (pod, model) halo exchange: plan invariants, tier split,
numpy emulation of the two-phase collective, plan-cache keying, and the
8-device 2×4 equivalence/wire acceptance (docs/communication.md).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import partition_graph
from repro.dist.halo import build_halo_plan
from repro.graph.generators import citation_like

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _emulated_halo_tables(plan, zb: np.ndarray) -> np.ndarray:
    """Pure-numpy construction of every device's [local ‖ halo] neighbor
    table under the hierarchical member-block layout (the HaloPlan contract):
    member block m' = [send_loc rows of (p, m') ‖ per pod q: send_rem rows
    of (q, m')]. The shard_map collectives must produce exactly this."""
    k, km, pods = plan.k, plan.k_model, plan.n_pods
    width = plan.n_local + km * plan.block_rows
    tables = np.zeros((k, width) + zb.shape[2:], zb.dtype)
    for g in range(k):
        p = g // km
        parts = [zb[g]]
        for m in range(km):
            member = p * km + m
            parts.append(zb[member][plan.send_loc[member]])
            for q in range(pods):
                parts.append(zb[q * km + m][plan.send_rem[q * km + m]])
        tables[g] = np.concatenate(parts, axis=0)
    return tables


def _blocked(plan, x: np.ndarray) -> np.ndarray:
    out = np.zeros((plan.k, plan.n_local) + x.shape[1:], x.dtype)
    off = 0
    for b in range(plan.k):
        sz = int(plan.part_sizes[b])
        out[b, :sz] = x[plan.perm[off:off + sz]]
        off += sz
    return out


# ------------------------------------------------------------ plan properties
@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(64, 400),
    e=st.integers(100, 2000),
    kp=st.sampled_from([(4, 2), (8, 2), (8, 4)]),
    seed=st.integers(0, 50),
)
def test_hier_plan_accounts_every_edge(n, e, kp, seed):
    k, pods = kp
    g = citation_like(n, e, seed=seed)
    part = partition_graph(n, g.edge_index, k, method="bfs", seed=seed)
    plan = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=pods)
    assert plan.is_hierarchical and plan.n_pods == pods and plan.k_model == k // pods
    # Every original edge appears exactly once across the device edge lists.
    assert int((plan.edge_w > 0).sum()) == e
    # Receivers are local rows; senders index the hierarchical table.
    assert plan.receivers_l.max() < plan.n_local
    assert plan.senders_l.max() < plan.n_local + plan.k_model * plan.block_rows
    # The permutation is a bijection.
    assert np.array_equal(np.sort(plan.perm), np.arange(n))
    # Per-tier pads never exceed the flat boundary pad it splits.
    assert plan.s_loc <= plan.s_max and plan.s_rem <= plan.s_max
    # Export tables stay in local-row range.
    if plan.s_loc:
        assert plan.send_loc.min() >= 0 and plan.send_loc.max() < plan.n_local
    if plan.s_rem:
        assert plan.send_rem.min() >= 0 and plan.send_rem.max() < plan.n_local


def test_hier_aggregate_matches_global_numpy_emulation():
    """The member-block addressing is exact: emulating the two-phase exchange
    in numpy and aggregating reproduces the global aggregate bit-for-bit."""
    from repro.graph.ops import aggregate
    import jax.numpy as jnp

    g = citation_like(400, 2400, seed=5)
    w = np.abs(np.random.default_rng(0).standard_normal(g.n_edges)).astype(np.float32) + 0.1
    part = partition_graph(g.n_nodes, g.edge_index, 8, method="bfs", seed=0, refine=True)
    plan = build_halo_plan(part, g.edge_index, w, axes=("pod", "model"), pods=2)
    d = 16
    z = np.random.default_rng(1).standard_normal((g.n_nodes, d)).astype(np.float32)
    zb = _blocked(plan, z)
    tables = _emulated_halo_tables(plan, zb)
    out = np.zeros_like(zb)
    for dev in range(plan.k):
        msg = tables[dev][plan.senders_l[dev]] * plan.edge_w[dev][:, None]
        np.add.at(out[dev], plan.receivers_l[dev], msg)
    ref = np.asarray(aggregate(jnp.asarray(z), jnp.asarray(g.edge_index[0]),
                               jnp.asarray(g.edge_index[1]), g.n_nodes, jnp.asarray(w)))
    np.testing.assert_allclose(out, _blocked(plan, ref), atol=1e-4)


def test_hier_wire_tiers_beat_flat():
    """The acceptance inequality: strictly fewer rows cross the inter-pod
    fabric than under the flat single-axis schedule, and the cheap tier's
    pad is at most the global worst case it used to pay."""
    g = citation_like(2000, 12000, seed=1)
    part = partition_graph(2000, g.edge_index, 8, method="bfs", seed=0, refine=True)
    flat = build_halo_plan(part, g.edge_index)
    hier = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=2)
    # Same partition → same flat baseline numbers on both plans.
    assert hier.s_max == flat.s_max and hier.n_local == flat.n_local
    assert hier.inter_pod_rows_crossing < hier.flat_inter_pod_rows_crossing
    assert hier.s_loc <= flat.s_max
    assert hier.halo_rows_per_device < hier.broadcast_rows_per_device
    # Tier arithmetic is self-consistent.
    assert hier.inter_pod_rows_per_device == hier.n_pods * hier.s_rem
    assert hier.intra_pod_rows_per_device == hier.k_model * hier.block_rows
    assert hier.halo_rows_per_device == (
        hier.inter_pod_rows_per_device + hier.intra_pod_rows_per_device
    )


def test_hier_plan_degenerate_pods():
    g = citation_like(150, 900, seed=2)
    part = partition_graph(150, g.edge_index, 4, method="bfs", seed=0)
    # pods=1: every cut edge is intra-pod; nothing crosses the (absent) fabric.
    p1 = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=1)
    assert p1.s_rem == 0 and p1.inter_pod_rows_per_device == 0
    assert p1.s_loc == p1.s_max                  # one pod ⇒ tiers collapse
    assert int((p1.edge_w > 0).sum()) == 900
    # pods=k: singleton pods; every cut edge crosses, the cheap tier is empty.
    pk = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=4)
    assert pk.s_loc == 0 and pk.k_model == 1
    assert pk.s_rem == pk.s_max
    assert int((pk.edge_w > 0).sum()) == 900


def test_hier_plan_validation():
    g = citation_like(64, 300, seed=1)
    part = partition_graph(64, g.edge_index, 4, method="block")
    with pytest.raises(ValueError):
        build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=3)
    with pytest.raises(ValueError):
        build_halo_plan(part, g.edge_index, pods=2)          # one axis, 2 pods
    with pytest.raises(ValueError):
        build_halo_plan(part, g.edge_index, axes=("model", "model"), pods=2)
    with pytest.raises(ValueError):
        build_halo_plan(part, g.edge_index, axes=("a", "b", "c"))


def test_hier_device_arrays_arity():
    g = citation_like(100, 500, seed=3)
    part = partition_graph(100, g.edge_index, 4, method="bfs", seed=0)
    flat = build_halo_plan(part, g.edge_index)
    hier = build_halo_plan(part, g.edge_index, axes=("pod", "model"), pods=2)
    assert len(flat.device_arrays()) == 4 and len(flat.abstract_inputs()) == 4
    assert len(hier.device_arrays()) == 5 and len(hier.abstract_inputs()) == 5
    sloc, srem = hier.abstract_inputs()[:2]
    assert sloc.shape == (4, hier.s_loc) and srem.shape == (4, hier.s_rem)


# --------------------------------------------------------------- plan cache
def test_plan_cache_flat_and_hier_coexist():
    """Single-axis and hierarchical plans for the same graph live side by
    side under (graph_key, k, axes) without cross-invalidation."""
    from repro.dist import halo

    halo.invalidate_halo_plans()
    g = citation_like(120, 700, seed=7)
    part = partition_graph(120, g.edge_index, 4, method="bfs", seed=0)
    flat = halo.get_halo_plan(part, g.edge_index)
    hier = halo.get_halo_plan(part, g.edge_index, pods=2)
    assert flat is not hier and not flat.is_hierarchical and hier.is_hierarchical
    # Both hit their own entries; neither evicted the other.
    assert halo.get_halo_plan(part, g.edge_index) is flat
    assert halo.get_halo_plan(part, g.edge_index, pods=2) is hier
    assert halo.plan_cache_stats()["size"] >= 2
    # The explicit axes-tuple spelling resolves to the same cache entry.
    assert halo.get_halo_plan(part, g.edge_index, mesh_axis=("pod", "model"), pods=2) is hier
    # Graph-level invalidation drops BOTH kinds (a re-partition stales both).
    evicted = halo.invalidate_halo_plans(
        halo.graph_fingerprint(part.n_nodes, g.edge_index, None, part.assignment)
    )
    assert evicted >= 2
    assert halo.get_halo_plan(part, g.edge_index) is not flat
    assert halo.get_halo_plan(part, g.edge_index, pods=2) is not hier


def test_plan_cache_distinct_pod_counts_never_collide():
    """The member-block layout depends on the pod count, so pods=2 and
    pods=4 plans of the SAME k=8 partition must cache separately (the key's
    axes component is the (axes, pods) pair)."""
    from repro.dist import halo

    halo.invalidate_halo_plans()
    g = citation_like(200, 1200, seed=4)
    part = partition_graph(200, g.edge_index, 8, method="bfs", seed=0)
    p2 = halo.get_halo_plan(part, g.edge_index, pods=2)
    p4 = halo.get_halo_plan(part, g.edge_index, pods=4)
    assert p2 is not p4
    assert p2.n_pods == 2 and p4.n_pods == 4
    # Both stay independently hot.
    assert halo.get_halo_plan(part, g.edge_index, pods=2) is p2
    assert halo.get_halo_plan(part, g.edge_index, pods=4) is p4
    # Same collision guard on the launch layer's string-keyed entry point.
    from repro.launch.steps import _shape_halo_plan

    s2 = _shape_halo_plan(200, 1200, 8, pods=2)
    s4 = _shape_halo_plan(200, 1200, 8, pods=4)
    assert s2 is not s4 and s2.n_pods == 2 and s4.n_pods == 4


def test_plan_cache_hier_requires_pods():
    from repro.dist import halo

    g = citation_like(64, 300, seed=1)
    part = partition_graph(64, g.edge_index, 4, method="block")
    with pytest.raises(ValueError):
        halo.get_halo_plan(part, g.edge_index, mesh_axis=("pod", "model"))


# ------------------------------------------------- policy bind validation
def test_policy_hier_bind_and_validation():
    import jax.numpy as jnp

    from repro.dist.policy import ShardingPolicy

    pol = ShardingPolicy(comm="halo", halo_axes=("pod", "model"))
    assert not pol.is_halo
    loc = jnp.asarray([0, 1], jnp.int32)
    rem = jnp.asarray([2], jnp.int32)
    bound = pol.bind_halo(send_loc=loc, send_rem=rem)
    assert bound.is_halo and not pol.is_halo
    with pytest.raises(ValueError):
        pol.bind_halo(loc, send_loc=loc, send_rem=rem)
    with pytest.raises(ValueError):
        pol.bind_halo(send_loc=loc)                    # rem missing
    with pytest.raises(ValueError):
        pol.bind_halo()                                # nothing bound at all


def test_size_one_pod_axis_degenerates_to_flat():
    """A mesh whose pod axis has width 1 is no hierarchy: halo_axes reports
    the flat schedule and build_cell produces a working flat halo cell
    (regression: the hier/flat decision and the plan kind must agree)."""
    import jax

    from repro.configs import get_arch
    from repro.launch.mesh import halo_axes, make_halo_mesh
    from repro.launch.steps import build_cell

    mesh = make_halo_mesh(1, jax.device_count())
    assert halo_axes(mesh) == ("model",)
    spec = get_arch("pna")
    cell = build_cell(spec, spec.shapes["full_graph_sm"], mesh)
    assert cell.comm == "halo" and not cell.halo_plan.is_hierarchical
    assert "send_idx" in cell.abstract_args[2]
    compiled = cell.lower(mesh).compile()
    assert (compiled.cost_analysis() or {}).get("flops", 0) > 0


# ----------------------------------------- 8-device 2×4 acceptance (slow)
def _run(code: str) -> None:
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])


_PRELUDE = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.partition import partition_graph
from repro.dist.halo import get_halo_plan, relocate_node_array, restore_node_array
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.generators import citation_like

g = citation_like(400, 2400, seed=5)
w = np.abs(np.random.default_rng(0).standard_normal(g.n_edges)).astype(np.float32) + 0.1
part = partition_graph(g.n_nodes, g.edge_index, 8, method="bfs", seed=0, refine=True)
flat = get_halo_plan(part, g.edge_index, w)
hier = get_halo_plan(part, g.edge_index, w, pods=2)
assert hier.inter_pod_rows_crossing < hier.flat_inter_pod_rows_crossing
mesh2d = jax.make_mesh((2, 4), ("pod", "model"))
mesh1d = jax.make_mesh((8,), ("model",))
x = np.random.default_rng(1).standard_normal((g.n_nodes, 16)).astype(np.float32)
AX = ("pod", "model")

def run_hier(fwd):
    sloc, srem, sl, rl, ew = hier.device_arrays()
    xb = jnp.asarray(relocate_node_array(hier, x))
    pol0 = ShardingPolicy(comm="halo", halo_axes=AX)
    f = jax.shard_map(
        lambda fe, a, b, c, d, e: fwd(fe[0], pol0.bind_halo(send_loc=a[0], send_rem=b[0]),
                                      c[0], d[0], e[0])[None],
        mesh=mesh2d, in_specs=(P(AX),) * 6, out_specs=P(AX), check_vma=False,
    )
    return restore_node_array(hier, np.asarray(f(xb, sloc, srem, sl, rl, ew)))

def run_flat(fwd):
    si, sl, rl, ew = flat.device_arrays()
    xb = jnp.asarray(relocate_node_array(flat, x))
    pol0 = ShardingPolicy(comm="halo")
    f = jax.shard_map(
        lambda fe, a, b, c, d: fwd(fe[0], pol0.bind_halo(a[0]), b[0], c[0], d[0])[None],
        mesh=mesh1d, in_specs=(P("model"),) * 5, out_specs=P("model"), check_vma=False,
    )
    return restore_node_array(flat, np.asarray(f(xb, si, sl, rl, ew)))
"""


@pytest.mark.slow
def test_gcn_hier_equals_flat_equals_broadcast_subprocess():
    """The paper GCN on the 2×4 (pod, model) mesh: hierarchical halo ==
    flat halo == global broadcast forward, per node (fp32 tolerance)."""
    code = _PRELUDE + """
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

cfg = GCNConfig(layer_dims=(16, 32, 7), dataflow="feature_first")
params = gcn_init(jax.random.PRNGKey(0), cfg)
ref = np.asarray(gcn_forward(params, jnp.asarray(x), jnp.asarray(g.edge_index[0]),
                             jnp.asarray(g.edge_index[1]), jnp.asarray(w), cfg, NO_POLICY))

def fwd(fe, pol, s, r, ww):
    return gcn_forward(params, fe, s, r, ww, cfg, pol)

err_h = np.abs(run_hier(fwd) - ref).max()
err_f = np.abs(run_flat(fwd) - ref).max()
assert err_h < 1e-4 and err_f < 1e-4, (err_h, err_f)
print("OK", err_h, err_f)
"""
    _run(code)


@pytest.mark.slow
def test_pna_hier_equals_flat_equals_broadcast_subprocess():
    """PNA (mean/max/min/std aggregators) on the 2×4 mesh: hierarchical ==
    flat == global. Exercises the masked multi-aggregator path with the
    hierarchical padding (edge_w == 0 edges stay inert)."""
    code = _PRELUDE + """
from repro.models.pna import PNAConfig, pna_forward, pna_init

cfg = PNAConfig(n_layers=2, d_hidden=32, d_in=16, d_out=3)
params = pna_init(jax.random.PRNGKey(1), cfg)
ref = np.asarray(pna_forward(params, jnp.asarray(x), jnp.asarray(g.edge_index[0]),
                             jnp.asarray(g.edge_index[1]), cfg, NO_POLICY))

def fwd(fe, pol, s, r, ww):
    return pna_forward(params, fe, s, r, cfg, pol,
                       edge_mask=(ww > 0).astype(jnp.float32))

err_h = np.abs(run_hier(fwd) - ref).max()
err_f = np.abs(run_flat(fwd) - ref).max()
# fp32 tolerance: the std aggregator's E[x^2]-E[x]^2 cancellation amplifies
# reduction-order differences between the sharded and global programs.
assert err_h < 1e-3 and err_f < 1e-3, (err_h, err_f)
print("OK", err_h, err_f)
"""
    _run(code)


@pytest.mark.slow
def test_hier_cell_accounting_subprocess():
    """build_cell on a pod-tiered mesh produces a hierarchical halo cell
    whose dry-run accounting splits the tiers and whose inter-pod crossing
    rows are strictly below the flat schedule's."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import jax
from repro.configs import get_arch
from repro.launch.dryrun import exchange_accounting
from repro.launch.steps import build_cell

mesh = jax.make_mesh((2, 1, 4), ("pod", "data", "model"))
spec = get_arch("pna")
shape = spec.shapes["full_graph_sm"]
cell = build_cell(spec, shape, mesh)                    # the default
assert cell.comm == "halo" and cell.halo_plan.is_hierarchical
assert cell.halo_plan.n_pods == 2 and cell.halo_plan.k == 8
ex = exchange_accounting(cell, shape)
assert ex["pods"] == 2 and ex["axes"] == ["pod", "model"]
assert ex["inter_pod_rows_crossing"] < ex["flat_inter_pod_rows_crossing"], ex
assert ex["halo_rows_per_device"] < ex["broadcast_rows_per_device"], ex
compiled = cell.lower(mesh).compile()
assert (compiled.cost_analysis() or {{}}).get("flops", 0) > 0
print("OK", ex["inter_pod_rows_crossing"], ex["flat_inter_pod_rows_crossing"])
"""
    _run(code)
