"""Numerical equivalence of the three GCN aggregation backends and the
Pallas bsr_spmm kernel against `kernels/ref.py` — the regression net for
later kernel-perf PRs (interpret-mode Pallas on CPU, native on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph.structure import blocked_adjacency
from repro.kernels.ops import bsr_spmm
from repro.kernels.ref import bsr_spmm_ref
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

RNG = np.random.default_rng(7)


def _dense_adj(n: int, ei: np.ndarray, w: np.ndarray) -> np.ndarray:
    a = np.zeros((n, n), np.float32)
    np.add.at(a, (ei[1], ei[0]), w)       # A[r, s] = w: O = A·Z aggregates
    return a


def _graph(n: int, e: int, seed: int):
    r = np.random.default_rng(seed)
    ei = r.integers(0, n, size=(2, e)).astype(np.int32)
    w = (np.abs(r.standard_normal(e)) + 0.1).astype(np.float32)
    return ei, w


# ------------------------------------------------------- backend equivalence
@pytest.mark.parametrize("dims", [(24, 16, 8), (12, 32, 4)])
@pytest.mark.parametrize("dataflow", ["feature_first", "aggregation_first"])
def test_gcn_backends_agree(dims, dataflow):
    n, e = 256, 1200                       # n multiple of 128 → bsr-ready
    ei, w = _graph(n, e, seed=0)
    x = RNG.standard_normal((n, dims[0])).astype(np.float32)
    cfgs = {
        b: GCNConfig(layer_dims=dims, dataflow=dataflow, backend=b)
        for b in ("segment", "dense", "bsr")
    }
    params = gcn_init(jax.random.PRNGKey(0), cfgs["segment"])
    ba = blocked_adjacency(n, ei, w, block=128)
    outs = {
        "segment": gcn_forward(params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]),
                               jnp.asarray(w), cfgs["segment"]),
        "dense": gcn_forward(params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]),
                             jnp.asarray(w), cfgs["dense"],
                             dense_adj=jnp.asarray(_dense_adj(n, ei, w))),
        "bsr": gcn_forward(params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]),
                           jnp.asarray(w), cfgs["bsr"],
                           adjacency=(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols))),
    }
    ref = np.asarray(outs["segment"])
    for b in ("dense", "bsr"):
        np.testing.assert_allclose(np.asarray(outs[b]), ref, rtol=3e-4, atol=3e-4,
                                   err_msg=f"backend {b} vs segment ({dataflow})")


def test_gcn_segment_matches_numpy_oracle():
    """One layer, hand-rolled numpy: Ã·(X·W) + b, relu-free last layer."""
    n, e, d_in, d_out = 64, 300, 8, 3
    ei, w = _graph(n, e, seed=3)
    x = RNG.standard_normal((n, d_in)).astype(np.float32)
    cfg = GCNConfig(layer_dims=(d_in, d_out), dataflow="feature_first")
    params = gcn_init(jax.random.PRNGKey(1), cfg)
    out = gcn_forward(params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]),
                      jnp.asarray(w), cfg)
    a = _dense_adj(n, ei, w)
    ref = a @ (x @ np.asarray(params["w0"])) + np.asarray(params["b0"])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ bsr_spmm extra
def test_bsr_spmm_feature_pad_path():
    """F not a multiple of the tile exercises the pad/slice wrapper path."""
    n, e, f = 256, 900, 50
    ei, w = _graph(n, e, seed=1)
    ba = blocked_adjacency(n, ei, w, block=128)
    z = jnp.asarray(RNG.standard_normal((ba.n_padded, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)
    zp = jnp.pad(z, ((0, 0), (0, 128 - f)))
    ref = bsr_spmm_ref(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), zp)[:, :f]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    n=st.sampled_from([128, 256, 384]),
    e=st.integers(50, 2000),
    f=st.sampled_from([16, 64, 130]),
    seed=st.integers(0, 99),
)
def test_bsr_spmm_vs_segment_aggregate(n, e, f, seed):
    """Kernel == segment-op aggregation on random graphs (system contract)."""
    from repro.graph.ops import aggregate

    ei, w = _graph(n, e, seed)
    ba = blocked_adjacency(n, ei, w, block=128)
    r = np.random.default_rng(seed + 1)
    z = jnp.asarray(r.standard_normal((ba.n_padded, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)[:n]
    seg = aggregate(z[:n], jnp.asarray(ei[0]), jnp.asarray(ei[1]), n, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(seg), rtol=5e-4, atol=5e-4)
