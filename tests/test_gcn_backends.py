"""Numerical equivalence of the three GCN aggregation backends and the
Pallas bsr_spmm kernel against `kernels/ref.py` — the regression net for
later kernel-perf PRs (interpret-mode Pallas on CPU, native on TPU)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quant import QuantConfig
from repro.graph.structure import (
    blocked_adjacency,
    blocked_stats,
    locality_block_order,
    permute_edge_index,
    relocate_rows,
    restore_rows,
)
from repro.kernels.ops import bsr_spmm
from repro.kernels.ref import bsr_spmm_ref
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

RNG = np.random.default_rng(7)
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _dense_adj(n: int, ei: np.ndarray, w: np.ndarray) -> np.ndarray:
    a = np.zeros((n, n), np.float32)
    np.add.at(a, (ei[1], ei[0]), w)       # A[r, s] = w: O = A·Z aggregates
    return a


def _graph(n: int, e: int, seed: int):
    r = np.random.default_rng(seed)
    ei = r.integers(0, n, size=(2, e)).astype(np.int32)
    w = (np.abs(r.standard_normal(e)) + 0.1).astype(np.float32)
    return ei, w


# ------------------------------------------------------- backend equivalence
@pytest.mark.parametrize("dims", [(24, 16, 8), (12, 32, 4)])
@pytest.mark.parametrize("dataflow", ["feature_first", "aggregation_first"])
def test_gcn_backends_agree(dims, dataflow):
    n, e = 256, 1200                       # n multiple of 128 → bsr-ready
    ei, w = _graph(n, e, seed=0)
    x = RNG.standard_normal((n, dims[0])).astype(np.float32)
    cfgs = {
        b: GCNConfig(layer_dims=dims, dataflow=dataflow, backend=b)
        for b in ("segment", "dense", "bsr")
    }
    params = gcn_init(jax.random.PRNGKey(0), cfgs["segment"])
    ba = blocked_adjacency(n, ei, w, block=128)
    outs = {
        "segment": gcn_forward(params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]),
                               jnp.asarray(w), cfgs["segment"]),
        "dense": gcn_forward(params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]),
                             jnp.asarray(w), cfgs["dense"],
                             dense_adj=jnp.asarray(_dense_adj(n, ei, w))),
        "bsr": gcn_forward(params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]),
                           jnp.asarray(w), cfgs["bsr"],
                           adjacency=(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols))),
    }
    ref = np.asarray(outs["segment"])
    for b in ("dense", "bsr"):
        np.testing.assert_allclose(np.asarray(outs[b]), ref, rtol=3e-4, atol=3e-4,
                                   err_msg=f"backend {b} vs segment ({dataflow})")


def test_gcn_segment_matches_numpy_oracle():
    """One layer, hand-rolled numpy: Ã·(X·W) + b, relu-free last layer."""
    n, e, d_in, d_out = 64, 300, 8, 3
    ei, w = _graph(n, e, seed=3)
    x = RNG.standard_normal((n, d_in)).astype(np.float32)
    cfg = GCNConfig(layer_dims=(d_in, d_out), dataflow="feature_first")
    params = gcn_init(jax.random.PRNGKey(1), cfg)
    out = gcn_forward(params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]),
                      jnp.asarray(w), cfg)
    a = _dense_adj(n, ei, w)
    ref = a @ (x @ np.asarray(params["w0"])) + np.asarray(params["b0"])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


# ------------------------------------------------- ragged / fused bsr layers
@pytest.mark.parametrize("dataflow", ["feature_first", "aggregation_first"])
def test_gcn_bsr_nonmultiple_n_matches_segment(dataflow):
    """N not a multiple of 128 (ragged tail block): the fused bsr forward,
    fed the BlockedAdjacency directly, equals the segment reference."""
    n, e, dims = 300, 1500, (20, 24, 6)
    ei, w = _graph(n, e, seed=11)
    x = RNG.standard_normal((n, dims[0])).astype(np.float32)
    params = gcn_init(jax.random.PRNGKey(3), GCNConfig(layer_dims=dims))
    ba = blocked_adjacency(n, ei, w, block=128)
    args = (params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]), jnp.asarray(w))
    seg = gcn_forward(*args, GCNConfig(layer_dims=dims, dataflow=dataflow))
    out = gcn_forward(
        *args, GCNConfig(layer_dims=dims, dataflow=dataflow, backend="bsr"),
        adjacency=ba,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(seg), rtol=3e-4, atol=3e-4)


def test_gcn_bsr_matches_segment_under_fake_quant():
    """Fake-quantized weights/activations flow through the fused kernel the
    same as through the segment path (quant happens outside the kernel)."""
    n, e, dims = 384, 2000, (16, 32, 5)
    ei, w = _graph(n, e, seed=12)
    x = RNG.standard_normal((n, dims[0])).astype(np.float32)
    q = QuantConfig(4, 4, enabled=True)
    params = gcn_init(jax.random.PRNGKey(4), GCNConfig(layer_dims=dims))
    ba = blocked_adjacency(n, ei, w, block=128)
    args = (params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]), jnp.asarray(w))
    seg = gcn_forward(*args, GCNConfig(layer_dims=dims, quant=q))
    out = gcn_forward(
        *args, GCNConfig(layer_dims=dims, quant=q, backend="bsr"), adjacency=ba
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(seg), rtol=3e-4, atol=3e-4)


def test_gcn_backend_argument_validation():
    """Up-front ValueErrors instead of asserts/mid-trace failures."""
    n, e, dims = 64, 200, (8, 4)
    ei, w = _graph(n, e, seed=13)
    x = RNG.standard_normal((n, dims[0])).astype(np.float32)
    params = gcn_init(jax.random.PRNGKey(5), GCNConfig(layer_dims=dims))
    args = (params, x, jnp.asarray(ei[0]), jnp.asarray(ei[1]), jnp.asarray(w))
    with pytest.raises(ValueError, match="unknown GCN backend"):
        gcn_forward(*args, GCNConfig(layer_dims=dims, backend="sparse"))
    with pytest.raises(ValueError, match="requires adjacency"):
        gcn_forward(*args, GCNConfig(layer_dims=dims, backend="bsr"))
    with pytest.raises(ValueError, match="BlockedAdjacency"):
        gcn_forward(*args, GCNConfig(layer_dims=dims, backend="bsr"),
                    adjacency=np.zeros((4, 4)))
    with pytest.raises(ValueError, match="vals"):
        gcn_forward(*args, GCNConfig(layer_dims=dims, backend="bsr"),
                    adjacency=(np.zeros((4, 4)), np.zeros(3)))
    with pytest.raises(ValueError, match="dense_adj"):
        gcn_forward(*args, GCNConfig(layer_dims=dims, backend="dense"))


def test_locality_reorder_improves_blocking():
    """The locality permutation on a shuffled power-law community graph cuts
    both the nonzero-tile count and the dense-T executed-tile count ≥ 2×
    (stats-only — no tile materialization), and the blocked forward over the
    reordered graph matches the segment forward after restore."""
    from repro.graph.generators import citation_like

    n, e = 4096, 16384
    g = citation_like(n, e, n_labels=32, homophily=0.9, seed=1)
    shuf = np.random.default_rng(7).permutation(n).astype(np.int64)
    ei = permute_edge_index(shuf, g.edge_index)
    base = blocked_stats(n, ei)
    perm = locality_block_order(n, ei, block=128)
    reord = blocked_stats(n, permute_edge_index(perm, ei))
    assert reord["nnz_blocks"] * 2 <= base["nnz_blocks"], (base, reord)
    assert reord["nnz_blocks"] * 2 <= base["dense_tiles"], (base, reord)

    # numerical equivalence through the permutation, on a small subgraph
    n2, e2 = 384, 1600
    ei2, w2 = _graph(n2, e2, seed=14)
    perm2 = locality_block_order(n2, ei2, block=128)
    ba = blocked_adjacency(n2, permute_edge_index(perm2, ei2), w2, block=128)
    dims = (12, 8, 3)
    params = gcn_init(jax.random.PRNGKey(6), GCNConfig(layer_dims=dims))
    x = RNG.standard_normal((n2, dims[0])).astype(np.float32)
    seg = gcn_forward(params, x, jnp.asarray(ei2[0]), jnp.asarray(ei2[1]),
                      jnp.asarray(w2), GCNConfig(layer_dims=dims))
    out_p = gcn_forward(
        params, jnp.asarray(relocate_rows(perm2, x)),
        jnp.asarray(ei2[0]), jnp.asarray(ei2[1]), jnp.asarray(w2),
        GCNConfig(layer_dims=dims, backend="bsr"), adjacency=ba,
    )
    np.testing.assert_allclose(
        restore_rows(perm2, np.asarray(out_p)), np.asarray(seg), rtol=3e-4, atol=3e-4
    )


@pytest.mark.slow
def test_gcn_bsr_halo_equals_segment_subprocess():
    """backend="bsr" inside the 8-device halo shard_map path (the per-shard
    blocked adjacency over [local ‖ halo]) produces the same logits as the
    global segment forward — both dataflow orders."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {SRC!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.partition import partition_graph
from repro.dist.halo import get_halo_plan, plan_blocked_adjacency, plan_blocked_shape, relocate_node_array, restore_node_array
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.generators import citation_like
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

g = citation_like(400, 2400, seed=5)
w = np.abs(np.random.default_rng(0).standard_normal(g.n_edges)).astype(np.float32) + 0.1
part = partition_graph(g.n_nodes, g.edge_index, 8, method="bfs", seed=0, refine=True)
plan = get_halo_plan(part, g.edge_index, w)
ba = plan_blocked_adjacency(plan)
shp = plan_blocked_shape(plan)
assert shp["max_nnzb"] == ba.max_nnzb and shp["nnz_blocks"] == ba.nnz_blocks
assert plan_blocked_adjacency(plan) is ba          # cached next to the plan
mesh = jax.make_mesh((8,), ("model",))
si, sl, rl, ew = plan.device_arrays()
bv, bc, bl = ba.device_arrays()
x = np.random.default_rng(1).standard_normal((g.n_nodes, 16)).astype(np.float32)
xb = jnp.asarray(relocate_node_array(plan, x))
halo_pol = ShardingPolicy(comm="halo")
worst = 0.0
for dataflow in ("feature_first", "aggregation_first"):
    cfg = GCNConfig(layer_dims=(16, 32, 7), dataflow=dataflow, backend="bsr")
    params = gcn_init(jax.random.PRNGKey(0), cfg)
    ref = np.asarray(gcn_forward(params, jnp.asarray(x), jnp.asarray(g.edge_index[0]),
                                 jnp.asarray(g.edge_index[1]), jnp.asarray(w),
                                 GCNConfig(layer_dims=(16, 32, 7), dataflow=dataflow), NO_POLICY))
    def body(fe, a, b, c, d, v, co, le):
        pol = halo_pol.bind_halo(a)
        return gcn_forward(params, fe, b, c, d, cfg, pol, adjacency=(v, co, le))
    f = jax.shard_map(
        lambda fe, a, b, c, d, v, co, le: body(fe[0], a[0], b[0], c[0], d[0], v[0], co[0], le[0])[None],
        mesh=mesh, in_specs=(P("model"),) * 8, out_specs=P("model"), check_vma=False,
    )
    out = restore_node_array(plan, np.asarray(f(xb, si, sl, rl, ew, bv, bc, bl)))
    err = np.abs(out - ref).max()
    assert err < 1e-4, (dataflow, err)
    worst = max(worst, err)

# hierarchical (2 pods x 4): the per-shard blocking spans the member-block
# table (neighbor_table_rows, NOT halo_rows_per_device) — geometry + numerics
from repro.dist.halo import build_halo_plan
plan_h = build_halo_plan(part, g.edge_index, w, axes=("pod", "model"), pods=2)
assert plan_h.neighbor_table_rows == plan_h.n_local + plan_h.k_model * plan_h.block_rows
ba_h = plan_blocked_adjacency(plan_h)
assert ba_h.n_cols == plan_h.neighbor_table_rows
assert int(plan_h.senders_l.max()) < ba_h.n_cols
mesh_h = jax.make_mesh((2, 4), ("pod", "model"))
sloc, srem, sl, rl, ew2 = plan_h.device_arrays()
bv, bc, bl = ba_h.device_arrays()
xb = jnp.asarray(relocate_node_array(plan_h, x))
pol0 = ShardingPolicy(comm="halo", halo_axes=("pod", "model"))
cfg = GCNConfig(layer_dims=(16, 32, 7), backend="bsr")
params = gcn_init(jax.random.PRNGKey(0), cfg)
ref = np.asarray(gcn_forward(params, jnp.asarray(x), jnp.asarray(g.edge_index[0]),
                             jnp.asarray(g.edge_index[1]), jnp.asarray(w),
                             GCNConfig(layer_dims=(16, 32, 7)), NO_POLICY))
def body_h(fe, a, a2, b, c, d, v, co, le):
    pol = pol0.bind_halo(send_loc=a[0], send_rem=a2[0])
    return gcn_forward(params, fe[0], b[0], c[0], d[0], cfg, pol,
                       adjacency=(v[0], co[0], le[0]))[None]
f = jax.shard_map(body_h, mesh=mesh_h, in_specs=(P(("pod", "model")),) * 9,
                  out_specs=P(("pod", "model")), check_vma=False)
out = restore_node_array(plan_h, np.asarray(f(xb, sloc, srem, sl, rl, ew2, bv, bc, bl)))
err = np.abs(out - ref).max()
assert err < 1e-4, ("hier", err)
print("OK", max(worst, err))
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])


# ------------------------------------------------------------ bsr_spmm extra
def test_bsr_spmm_feature_pad_path():
    """F not a multiple of the tile exercises the pad/slice wrapper path."""
    n, e, f = 256, 900, 50
    ei, w = _graph(n, e, seed=1)
    ba = blocked_adjacency(n, ei, w, block=128)
    z = jnp.asarray(RNG.standard_normal((ba.n_padded, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)
    zp = jnp.pad(z, ((0, 0), (0, 128 - f)))
    ref = bsr_spmm_ref(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), zp)[:, :f]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    n=st.sampled_from([128, 256, 384]),
    e=st.integers(50, 2000),
    f=st.sampled_from([16, 64, 130]),
    seed=st.integers(0, 99),
)
def test_bsr_spmm_vs_segment_aggregate(n, e, f, seed):
    """Kernel == segment-op aggregation on random graphs (system contract)."""
    from repro.graph.ops import aggregate

    ei, w = _graph(n, e, seed)
    ba = blocked_adjacency(n, ei, w, block=128)
    r = np.random.default_rng(seed + 1)
    z = jnp.asarray(r.standard_normal((ba.n_padded, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)[:n]
    seg = aggregate(z[:n], jnp.asarray(ei[0]), jnp.asarray(ei[1]), n, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(seg), rtol=5e-4, atol=5e-4)
