"""COIN energy model + solver: paper-exact checks and property tests."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.energy import CoinEnergyModel, model_from_gcn, sum_hidden_activation_bits
from repro.core.solver import SQUARE_MESHES, interior_point_minimize, mesh_sweep, optimal_ce_count


def test_activation_bits_paper_gcn():
    # 2-layer GCN [F, 16, C] at 4 bits → Σ a(l+1) = 64 bits, independent of F/C.
    assert sum_hidden_activation_bits([1433, 16, 7], 4) == 64.0
    assert sum_hidden_activation_bits([5414, 16, 210], 4) == 64.0
    assert sum_hidden_activation_bits([10, 7], 4) == 0.0


def test_eq5_coefficients_match_paper():
    """At p1=0.25, p2=0.22 the paper's Eq. 5 coefficients are 0.94, 0.06,
    0.17, 0.19 — evaluate our analytic d² against the published form."""
    m = CoinEnergyModel(n_nodes=6000, act_bits_sum=1.0)
    for k in [5.0, 10.0, 20.0, 50.0, 100.0]:
        n = 6000.0
        paper = (
            0.9375 * n**2.5 / k**3.5
            - 0.055 * n**2 / k**1.5
            - (0.165 * n**2 + 0.1875 * n**1.5) / k**2.5
        )
        ours = float(m.d2_total(k))
        assert math.isclose(ours, paper, rel_tol=1e-9)


def test_appendix_a_claim_is_violated_but_unimodal():
    """Documented discrepancy: the literal Appendix-A claim (d²E>0 on
    [4,100] for N>2000) fails at large k, but E is unimodal and convex
    around the optimum, so the interior-point conclusion stands."""
    m = model_from_gcn(6000, [1433, 16, 7], 4)
    assert not m.is_convex(4, 100)
    assert m.d2_total(10.0) > 0      # convex where it matters
    assert m.convex_k_limit() > 30
    assert m.is_unimodal()


def test_solver_reproduces_k16_4x4():
    m = model_from_gcn(6000, [1433, 16, 7], 4)
    res = optimal_ce_count(m)
    assert res.k_mesh == 16
    assert res.mesh_shape == (4, 4)
    assert abs(res.k_star - m.continuous_argmin()) / m.continuous_argmin() < 0.1
    assert res.solve_ms < 1000  # paper: 10 ms; allow CPU slack


def test_mesh_sweep_shape():
    m = model_from_gcn(2708, [1433, 16, 7], 4)
    sweep = mesh_sweep(m)
    assert set(sweep) == set(SQUARE_MESHES)
    # Fig. 9: 4x4 best for Cora-sized graphs; energy rises toward 10x10.
    assert min(sweep, key=sweep.get) == 16
    assert sweep[100] > sweep[16]


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=2000, max_value=100_000),
    bits=st.integers(min_value=8, max_value=512),
)
def test_energy_positive_and_solver_not_worse_than_grid(n, bits):
    m = CoinEnergyModel(n_nodes=n, act_bits_sum=float(bits))
    ks = np.linspace(2, 200, 100)
    assert np.all(m.total(ks) > 0)
    res = optimal_ce_count(m)
    grid_best = min(float(m.total(float(k))) for k in SQUARE_MESHES)
    assert res.energy_at_k <= grid_best * (1 + 1e-9)


def test_interior_point_on_quadratic():
    k, iters, converged = interior_point_minimize(lambda k: (k - 7.3) ** 2, k_lo=1, k_hi=100)
    assert abs(k - 7.3) < 1e-3
    assert converged
