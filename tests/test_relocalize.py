"""Online locality maintenance (`repro.dist.delta`): RelocalizePolicy
hysteresis, drift-triggered in-place re-localization, and pad compaction —
pinned end-to-end by the delta differential oracle (tests/_delta_oracle.py)
and by bit-identity against a from-scratch `build_halo_plan`.

Contracts (ISSUE 9 acceptance):
  * `drift_ratio == 1.0` EXACTLY immediately after any re-localization
    (the drift reference order is a pure function of the edge multiset),
  * hysteresis: no fire below threshold, fire only after K consecutive
    exceedances, no double-fire inside the cooldown window,
  * `compact()` on an untouched v0 planner is a no-op (plans stay
    bit-identical to the builder, version unchanged); after churn it
    shrinks pads back to occupancy and lands bit-identical to a rebuild,
  * the fresh-reorder term of `locality_drift` runs ONE BFS per structural
    era (memo regression), and
  * live state — optimizer moments via `relocate_state_tree`, serve-cache
    residents via scoped invalidation + `adopt_partition` — survives a
    re-localization with forward results equal modulo row order (8-device
    subprocess + serve-engine variant).
"""
import numpy as np
import pytest

import _delta_oracle as O
from test_graph_delta import _PRELUDE, _boom, _mk, _plan_fields_equal, _run
from repro.dist.delta import (
    DeltaPlanner,
    GraphDelta,
    RelocalizePolicy,
    _relocalized_assignment,
)
from repro.dist.halo import (
    build_halo_plan,
    cached_halo_plan,
    invalidate_halo_plans,
    plan_blocked_adjacency,
    plan_layout,
)
from repro.graph.generators import citation_like
from repro.train.elastic import relocate_state_tree


def _churn(pl, rng, rounds=6, frac=0.02, members=20):
    """Severed-ties churn: delete edges incident to a member set, reinsert
    the same count internal to it — degrades locality without changing E."""
    for _ in range(rounds):
        ei = pl.edge_index()
        m = max(int(ei.shape[1] * frac), 2)
        mem = rng.choice(pl.n, members, replace=False)
        inc = np.flatnonzero(np.isin(ei[0], mem) | np.isin(ei[1], mem))[:m]
        if inc.size == 0:
            continue
        s = mem[rng.integers(0, mem.size, inc.size)]
        d = mem[rng.integers(0, mem.size, inc.size)]
        bad = s == d
        d[bad] = mem[(np.searchsorted(np.sort(mem), d[bad]) + 1) % mem.size]
        pl.apply(GraphDelta(edge_inserts=np.stack([s, d]),
                            edge_deletes=ei[:, inc],
                            insert_w=np.full(inc.size, 0.5, np.float32)))


# -------------------------------------------------------------- hysteresis
def test_policy_below_threshold_never_fires():
    pol = RelocalizePolicy(threshold=1.5, patience=2, cooldown=3)
    assert not any(pol.observe(r) for r in [0.9, 1.0, 1.4, 1.5, 1.49] * 4), (
        "ratios at or below threshold must never trigger")
    assert pol.streak == 0


def test_policy_fires_after_k_consecutive_and_dip_resets():
    pol = RelocalizePolicy(threshold=1.2, patience=3, cooldown=0)
    got = [pol.observe(r) for r in [1.3, 1.3, 1.1, 1.3, 1.3, 1.3]]
    assert got == [False, False, False, False, False, True], (
        "a dip below threshold must reset the consecutive-exceedance streak")


def test_policy_cooldown_blocks_double_fire():
    pol = RelocalizePolicy(threshold=1.0, patience=1, cooldown=3)
    got = [pol.observe(9.0) for _ in range(6)]
    # fire, then 3 cooldown observations are swallowed, then re-arm + fire
    assert got == [True, False, False, False, True, False]


# ----------------------------------------------- drift == 1.0 after reorder
def test_drift_ratio_exactly_one_after_relocalize():
    """The drift reference is canonicalized over the edge MULTISET, so the
    order relocalize installs IS the reference order: the ratio must come
    back 1.0 exactly (not ≈) for the same (block, method)."""
    g, w, part = _mk(300, 1800, 4, seed=6)
    pl = DeltaPlanner(part, g.edge_index, w)
    pl.plan()
    _churn(pl, np.random.default_rng(0), rounds=5)
    assert pl.locality_drift(32)["drift_ratio"] > 1.0
    rep = pl.relocalize(block=32)
    assert rep["executed_tiles_after"] <= rep["executed_tiles_before"]
    assert pl.locality_drift(32)["drift_ratio"] == 1.0
    # edge order itself is irrelevant: a shuffled copy of the same multiset
    # yields the same reference assignment
    ei = pl.edge_index()
    shuf = ei[:, np.random.default_rng(1).permutation(ei.shape[1])]
    np.testing.assert_array_equal(
        _relocalized_assignment(pl.n, ei, pl.k, block=32),
        _relocalized_assignment(pl.n, shuf, pl.k, block=32))


def test_relocalize_bit_identical_to_fresh_build_and_rekeys():
    g, w, part = _mk(256, 1500, 4, seed=8)
    invalidate_halo_plans()
    pl = DeltaPlanner(part, g.edge_index, w)
    p = pl.plan()
    h = pl.plan(axes=("pod", "model"), pods=2)
    _churn(pl, np.random.default_rng(2), rounds=4)
    key0, v0 = pl.graph_key, pl.version
    pl.relocalize(block=64)
    assert pl.version == v0 + 1 and pl.graph_key != key0
    # the repaired objects ARE the builder's output on the new partition
    ei, ww = pl.edge_index(), pl.edge_weights()
    _plan_fields_equal(p, build_halo_plan(pl.part, ei, ww))
    _plan_fields_equal(h, build_halo_plan(pl.part, ei, ww,
                                          axes=("pod", "model"), pods=2))
    for q in (p, h):
        O.assert_plan_matches_rebuild(q, pl.part, ei, ww)
    # versioned re-key: new key hits the SAME objects, old key is gone
    assert cached_halo_plan(pl.graph_key, 4, "model", builder=_boom) is p
    with pytest.raises(RuntimeError):
        cached_halo_plan(key0, 4, "model", builder=_boom)
    invalidate_halo_plans()


def test_policy_fires_through_apply_and_reports():
    g, w, part = _mk(300, 1800, 4, seed=9)
    pol = RelocalizePolicy(threshold=1.01, patience=2, cooldown=2, block=32)
    pl = DeltaPlanner(part, g.edge_index, w, relocalize_policy=pol)
    pl.plan()
    fired = 0
    rng = np.random.default_rng(3)
    for _ in range(12):
        before = pl.version
        _churn(pl, rng, rounds=1, frac=0.03)
        if pl.version > before + 1:           # apply bump + relocalize bump
            fired += 1
    assert fired >= 1, "threshold-driven relocalization never fired"
    # the report plumbs through apply()
    pl2 = DeltaPlanner(part, g.edge_index, w,
                       relocalize_policy=RelocalizePolicy(
                           threshold=0.0, patience=1, cooldown=0, block=32))
    ei = pl2.edge_index()
    rep = pl2.apply(GraphDelta(edge_deletes=ei[:, :1]))
    r = rep["relocalized"]
    assert r is not None and r["version"] == pl2.version
    assert rep["graph_key"] == pl2.graph_key == r["graph_key"]
    assert pl2.locality_drift(32)["drift_ratio"] == 1.0


# ------------------------------------------------------------------ compact
def test_compact_on_v0_planner_is_noop():
    g, w, part = _mk(128, 700, 4, seed=3)
    pl = DeltaPlanner(part, g.edge_index, w)
    p = pl.plan()
    h = pl.plan(axes=("pod", "model"), pods=2)
    plan_blocked_adjacency(p, 32)
    key0, v0 = pl.graph_key, pl.version
    rep = pl.compact()
    assert not rep["changed"] and not rep["rebuilt"]
    assert rep["bytes_reclaimed"] == 0
    assert not any(rep["pad_rows_reclaimed"].values())
    assert (pl.graph_key, pl.version) == (key0, v0)
    # builder-tight means builder-identical, still
    _plan_fields_equal(p, build_halo_plan(part, g.edge_index, w))
    _plan_fields_equal(h, build_halo_plan(part, g.edge_index, w,
                                          axes=("pod", "model"), pods=2))


def test_compact_after_churn_reclaims_and_matches_builder():
    g, w, part = _mk(256, 1500, 4, seed=11)
    pl = DeltaPlanner(part, g.edge_index, w)
    p = pl.plan()
    rng = np.random.default_rng(5)
    # grow pads (cut inserts), then delete most of them → loose high water
    a = pl.part.assignment
    src = np.flatnonzero(a == 0)[:40].astype(np.int64)
    dst = np.full(src.size, int(np.flatnonzero(a == 1)[0]), np.int64)
    grow = GraphDelta(edge_inserts=np.stack([src, dst]))
    pl.apply(grow)
    pl.apply(GraphDelta(edge_deletes=np.stack([src, dst])[:, :36]))
    ei, ww = pl.edge_index(), pl.edge_weights()
    occ_loose = pl.pad_occupancy()
    rep = pl.compact()
    assert rep["changed"] and rep["rebuilt"]
    assert rep["bytes_reclaimed"] > 0
    assert sum(rep["pad_rows_reclaimed"].values()) > 0
    # compacting removes capacity, never occupancy → utilization rises
    assert pl.pad_occupancy()["frac"] >= occ_loose["frac"]
    _plan_fields_equal(p, build_halo_plan(pl.part, ei, ww))
    O.assert_plan_matches_rebuild(p, pl.part, ei, ww)
    # idempotent: a second compact finds everything tight already
    assert not pl.compact()["changed"]


# ------------------------------------------------------- drift memo (fix)
def test_drift_fresh_reorder_memoized_per_structural_era(monkeypatch):
    """Regression: `apply(measure_drift=True)` used to re-run the reorder
    BFS on EVERY apply. The fresh term is a pure function of the edge
    multiset between structural changes, so non-structural applies must
    reuse one memoized BFS; pad growth / relocalize open a new era."""
    import repro.graph.structure as S

    g, w, part = _mk(192, 1100, 4, seed=21)
    pl = DeltaPlanner(part, g.edge_index, w)
    pl.plan()
    calls = {"n": 0}
    orig = S.locality_block_order

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(S, "locality_block_order", counting)
    ei = pl.edge_index()
    for i in range(4):                    # delete-only: non-structural
        rep = pl.apply(GraphDelta(edge_deletes=ei[:, [i]]), measure_drift=True)
        assert not rep["pads_grown"]
        assert rep["drift"] is not None
    assert calls["n"] == 1, "fresh-reorder BFS must be memoized per era"
    # structural apply (pad growth) bumps the era → exactly one more call
    a = pl.part.assignment
    src = np.flatnonzero(a == 0).astype(np.int64)
    dst = np.full(src.size, int(np.flatnonzero(a == 1)[0]), np.int64)
    rep = pl.apply(GraphDelta(edge_inserts=np.stack([src, dst])),
                   measure_drift=True)
    assert rep["pads_grown"]
    assert calls["n"] == 2
    pl.apply(GraphDelta(edge_deletes=np.stack([src, dst])[:, :1]),
             measure_drift=True)
    assert calls["n"] == 2
    # relocalize seeds the memo with its own reorder: one call, then free
    pl.relocalize()
    n_after = calls["n"]
    pl.apply(GraphDelta(edge_deletes=pl.edge_index()[:, :1]),
             measure_drift=True)
    assert calls["n"] == n_after, "relocalize must seed the drift memo"


# ------------------------------------------------------ live-state carry
def test_relocate_state_tree_round_trip_exact():
    g, w, part = _mk(300, 1800, 4, seed=13)
    pl = DeltaPlanner(part, g.edge_index, w)
    pl.plan()
    rng = np.random.default_rng(4)
    x = rng.standard_normal((pl.n, 8)).astype(np.float32)
    old = plan_layout(pl)
    tree = {
        "m": np.asarray(O.relocate(old, x)),          # per-node moment
        "v": np.asarray(O.relocate(old, x * 2.0)),
        "dense": np.full((3, 3), 7.0, np.float32),    # not per-node: untouched
        "none": None,
    }
    _churn(pl, rng, rounds=4)
    pl.relocalize(block=64)
    new = plan_layout(pl)
    moved = relocate_state_tree(old, new, tree)
    from repro.dist.halo import restore_node_array
    np.testing.assert_array_equal(restore_node_array(new, moved["m"]), x)
    np.testing.assert_array_equal(restore_node_array(new, moved["v"]), x * 2.0)
    assert moved["dense"] is tree["dense"] and moved["none"] is None


def test_relocalize_metrics_and_span_recorded():
    from repro.obs import metrics, trace
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import TraceRecorder

    rec = TraceRecorder()
    old_reg = metrics.set_default_registry(MetricsRegistry())
    old_tracer = trace.set_default_tracer(rec)
    metrics.enable()
    try:
        g, w, part = _mk(192, 1100, 4, seed=15)
        pl = DeltaPlanner(part, g.edge_index, w)
        _churn(pl, np.random.default_rng(6), rounds=3)
        pl.relocalize(block=64)
        pl.compact()
        snap = metrics.snapshot()
        assert snap["delta.relocalizes"]["value"] == 1.0
        assert snap["delta.relocalize_ms"]["count"] == 1
        assert snap["delta.compacts"]["value"] == 1.0
        assert 0.0 < snap["delta.pad_occupancy"]["value"] <= 1.0
        names = {ev.get("name") for ev in rec._events}
        assert "delta.relocalize" in names
    finally:
        metrics.disable()
        metrics.set_default_registry(old_reg)
        trace.set_default_tracer(old_tracer)


# ----------------------------------------------- serve engine across reorder
def test_serve_cache_on_equals_off_across_relocalization():
    """Serve-engine variant of the mid-training acceptance: logits from a
    cached, partition-packed engine must match a fresh cache-less engine
    across {churn deltas → policy fire → adopt_partition} — the resident
    cache and the partition swap may change COST only, never values."""
    import jax
    from repro.core.partition import partition_graph
    from repro.models.gcn import GCNConfig, gcn_init
    from repro.serve.graph import GraphBatcher, hot_query_stream

    g = citation_like(300, 2400, 16, 4, seed=0)
    cfg = GCNConfig(layer_dims=(16, 8, 4))
    params = gcn_init(jax.random.PRNGKey(0), cfg)
    part = partition_graph(g.n_nodes, g.edge_index, 4, method="bfs",
                           seed=0, refine=True)
    eng = GraphBatcher(params, g, cfg, batch_seeds=4, fanout=4,
                       cache_capacity=64, partition=part, seed=0)
    pol = RelocalizePolicy(threshold=0.5, patience=1, cooldown=0, block=32)
    pl = DeltaPlanner(part, g.edge_index, graph_key="serve-reloc",
                      relocalize_policy=pol)
    nodes = hot_query_stream(g, 40)
    for _ in range(2):                               # warm the cache
        for v in nodes:
            eng.submit(int(v))
        eng.run_until_drained()
    rng = np.random.default_rng(7)
    fired = 0
    for _ in range(3):
        ei = pl.edge_index()
        drop = rng.choice(ei.shape[1], 20, replace=False)
        mem = rng.choice(g.n_nodes, 16, replace=False)
        s = mem[rng.integers(0, mem.size, 20)]
        d = mem[rng.integers(0, mem.size, 20)]
        bad = s == d
        d[bad] = mem[(np.searchsorted(np.sort(mem), d[bad]) + 1) % mem.size]
        delta = GraphDelta(edge_inserts=np.stack([s, d]),
                           edge_deletes=ei[:, drop])
        eng.apply_graph_delta(delta)
        rep = pl.apply(delta)
        if rep["relocalized"] is not None:
            fired += 1
            eng.adopt_partition(pl.part)
    assert fired >= 1, "relocalization never fired in the serve churn"
    got, want = {}, {}
    oracle = GraphBatcher(params, eng.graph, cfg, batch_seeds=4, fanout=4,
                          cache_capacity=0, seed=0)
    for e, out in ((eng, got), (oracle, want)):
        start = len(e.finished)
        for v in nodes:
            e.submit(int(v))
        e.run_until_drained()
        done = e.finished[start:]
        base = min(q.qid for q in done)
        out.update({q.qid - base: q.logits for q in done})
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-5)
    assert eng.cache.hits > 0, "churn run never exercised the cache"


# ------------------------------------------------ 8-device mid-training run
@pytest.mark.slow
def test_relocalize_mid_training_8dev_subprocess():
    """8-device acceptance: a mutation burst crosses the drift threshold
    mid-run; the maintained planner's loss trajectory and final logits match
    the no-maintenance twin to <1e-4, executed tiles drop at the trigger,
    live blocked state rides `relocate_state_tree` bit-exactly, and the
    sharded forward through the re-localized plan still matches the global
    reference."""
    code = _PRELUDE + """
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.dist.delta import RelocalizePolicy
from repro.dist.halo import plan_layout
from repro.train.elastic import relocate_state_tree

cfg = GCNConfig(layer_dims=(16, 32, 7), dataflow="feature_first")
params = gcn_init(jax.random.PRNGKey(0), cfg)
w = w_of(ei)
A = DeltaPlanner(part, ei, w, graph_key="maint",
                 relocalize_policy=RelocalizePolicy(
                     threshold=1.02, patience=2, cooldown=4, block=32))
B = DeltaPlanner(part, ei, w, graph_key="plain")
planA = A.plan(); B.plan()
labels = np.random.default_rng(2).integers(0, 7, g.n_nodes)
onehot = jnp.asarray(np.eye(7, dtype=np.float32)[labels])

def loss_logits(pl):
    e = pl.edge_index(); ww = pl.edge_weights()
    logits = gcn_forward(params, jnp.asarray(x), jnp.asarray(e[0]),
                         jnp.asarray(e[1]), jnp.asarray(ww), cfg, NO_POLICY)
    return float(-jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), 1))), logits

state = {"m": relocate_node_array(plan_layout(A), x.copy())}
rng = np.random.default_rng(7)
fired = 0
diffs = []
for step in range(30):
    cur = A.edge_index()
    mem = rng.choice(g.n_nodes, 20, replace=False)
    inc = np.flatnonzero(np.isin(cur[0], mem) | np.isin(cur[1], mem))[:24]
    if inc.size == 0:
        continue
    s = mem[rng.integers(0, mem.size, inc.size)]
    d = mem[rng.integers(0, mem.size, inc.size)]
    bad = s == d
    d[bad] = mem[(np.searchsorted(np.sort(mem), d[bad]) + 1) % mem.size]
    ins = np.stack([s, d])
    delta = GraphDelta(edge_inserts=ins, edge_deletes=cur[:, inc],
                       insert_w=w_of(ins))
    repA = A.apply(delta); B.apply(delta)
    r = repA["relocalized"]
    if r is not None:
        fired += 1
        assert r["executed_tiles_after"] < r["executed_tiles_before"], r
        state = relocate_state_tree(r["old_layout"], plan_layout(A), state)
    la, _ = loss_logits(A)
    lb, _ = loss_logits(B)
    diffs.append(abs(la - lb))
assert fired >= 1, "drift never crossed the threshold"
assert max(diffs) < 1e-4, ("loss trajectories diverged", max(diffs))
_, logitsA = loss_logits(A)
_, logitsB = loss_logits(B)
assert np.abs(np.asarray(logitsA) - np.asarray(logitsB)).max() < 1e-4
assert np.array_equal(restore_node_array(plan_layout(A), state["m"]), x), (
    "live state lost bits across relocate_state_tree")

# the maintained (re-localized) plan still serves the sharded forward
mesh1d = jax.make_mesh((8,), ("model",))
xb = jnp.asarray(relocate_node_array(planA, x))
si, sl, rl, ew = planA.device_arrays()
pol0 = ShardingPolicy(comm="halo")
f = jax.shard_map(
    lambda fe, a, b, c, d: gcn_forward(params, fe[0], b[0], c[0], d[0], cfg,
                                       pol0.bind_halo(a[0]))[None],
    mesh=mesh1d, in_specs=(P("model"),) * 5, out_specs=P("model"),
    check_vma=False,
)
got = restore_node_array(planA, np.asarray(f(xb, si, sl, rl, ew)))
e2 = A.edge_index()
ref = np.asarray(gcn_forward(params, jnp.asarray(x), jnp.asarray(e2[0]),
                             jnp.asarray(e2[1]), jnp.asarray(A.edge_weights()),
                             cfg, NO_POLICY))
assert np.abs(got - ref).max() < 1e-4, np.abs(got - ref).max()
print("OK")
"""
    _run(code)
