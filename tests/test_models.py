"""Model-level behaviour: decode consistency, equivariance, dataflow identity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from tests.conftest import random_rotation

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------------ LM
@pytest.mark.parametrize("variant", ["dense", "moe", "slide"])
def test_lm_decode_matches_forward(variant):
    from repro.models.transformer_lm import (
        LMConfig, lm_decode_step, lm_forward, lm_init, lm_init_cache,
    )

    cfg = {
        "dense": LMConfig("d", 3, 32, 4, 2, 64, 101),
        "moe": LMConfig("m", 2, 32, 4, 4, 48, 67, moe_experts=4, moe_top_k=2),
        "slide": LMConfig("s", 6, 32, 4, 2, 64, 53, window=8, global_every=6),
    }[variant]
    p = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    cache = lm_init_cache(cfg, 2, 16)
    outs = []
    for t in range(12):
        lg, cache = lm_decode_step(p, cache, toks[:, t], jnp.asarray(t, jnp.int32), cfg)
        outs.append(lg)
    pre, _ = lm_forward(p, toks, cfg)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(pre), rtol=2e-4, atol=2e-4
    )


def test_lm_window_pattern_gemma3():
    from repro.configs import get_arch

    cfg = get_arch("gemma3-12b").make_config(None)
    ws = cfg.window_sizes()
    assert len(ws) == 48
    glob = np.flatnonzero(ws > 10_000)
    assert list(glob) == [5, 11, 17, 23, 29, 35, 41, 47]  # every 6th layer
    assert np.all(ws[ws < 10_000] == 1024)


def test_lm_loss_decreases():
    from repro.models.transformer_lm import LMConfig, lm_init, lm_loss
    from repro.train.optimizer import adam

    cfg = LMConfig("t", 2, 32, 4, 2, 64, 64)
    params = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (4, 24), 0, cfg.vocab)
    opt = adam(5e-3)
    state = opt.init(params)
    first = float(lm_loss(params, toks, cfg))
    step = jax.jit(
        lambda p, s: (lambda l, g: opt.update(g, s, p) + (l,))(*jax.value_and_grad(lm_loss)(p, toks, cfg))
    )
    for _ in range(30):
        params, state, loss = step(params, state)
    assert float(loss) < first * 0.8


# ----------------------------------------------------------------------- GCN
def test_gcn_dataflow_orders_agree():
    """(A·X)·W == A·(X·W): both dataflows give identical outputs (fp tolerance).
    The paper's reordering changes WORK, not semantics."""
    from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

    r = np.random.default_rng(0)
    n, e = 120, 600
    s = jnp.asarray(r.integers(0, n, e)); d = jnp.asarray(r.integers(0, n, e))
    w = jnp.asarray(r.standard_normal(e), jnp.float32)
    x = jnp.asarray(r.standard_normal((n, 48)), jnp.float32)
    base = GCNConfig(layer_dims=(48, 16, 4))
    p = gcn_init(KEY, base)
    out_f = gcn_forward(p, x, s, d, w, dataclasses.replace(base, dataflow="feature_first"))
    out_a = gcn_forward(p, x, s, d, w, dataclasses.replace(base, dataflow="aggregation_first"))
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_a), rtol=2e-3, atol=2e-3)


def test_gcn_bsr_backend_matches_segment():
    from repro.graph.structure import blocked_adjacency
    from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

    r = np.random.default_rng(1)
    n, e = 300, 1500
    ei = r.integers(0, n, size=(2, e)).astype(np.int32)
    w = np.abs(r.standard_normal(e)).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    x = jnp.asarray(r.standard_normal((n, 32)), jnp.float32)
    cfg_seg = GCNConfig(layer_dims=(32, 16, 4), backend="segment")
    cfg_bsr = GCNConfig(layer_dims=(32, 16, 4), backend="bsr")
    p = gcn_init(KEY, cfg_seg)
    s, d = jnp.asarray(ei[0]), jnp.asarray(ei[1])
    wj = jnp.asarray(w)
    out_seg = gcn_forward(p, x, s, d, wj, cfg_seg)
    xp = jnp.pad(x, ((0, ba.n_padded - n), (0, 0)))
    out_bsr = gcn_forward(
        p, xp, s, d, wj, cfg_bsr,
        adjacency=(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols)),
    )[:n]
    np.testing.assert_allclose(np.asarray(out_bsr), np.asarray(out_seg), rtol=3e-4, atol=3e-4)


def test_gcn_quantized_forward_close_to_fp32():
    from repro.core.quant import QuantConfig
    from repro.models.gcn import GCNConfig, gcn_forward, gcn_init

    r = np.random.default_rng(2)
    n, e = 100, 500
    s = jnp.asarray(r.integers(0, n, e)); d = jnp.asarray(r.integers(0, n, e))
    w = jnp.asarray(np.abs(r.standard_normal(e)), jnp.float32)
    x = jnp.asarray(r.standard_normal((n, 24)), jnp.float32)
    fp = GCNConfig(layer_dims=(24, 16, 4))
    q8 = GCNConfig(layer_dims=(24, 16, 4), quant=QuantConfig(8, 8, enabled=True))
    p = gcn_init(KEY, fp)
    o1, o2 = gcn_forward(p, x, s, d, w, fp), gcn_forward(p, x, s, d, w, q8)
    rel = float(jnp.linalg.norm(o1 - o2) / jnp.linalg.norm(o1))
    assert rel < 0.1


# --------------------------------------------------------------- equivariance
def test_egnn_se3_equivariance(rng):
    from repro.models.egnn import EGNNConfig, egnn_forward, egnn_init

    cfg = EGNNConfig(n_layers=2, d_hidden=16, d_in=8, d_out=2)
    p = egnn_init(KEY, cfg)
    n, e = 40, 150
    s = jnp.asarray(rng.integers(0, n, e)); d = jnp.asarray(rng.integers(0, n, e))
    h = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    pos = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    R = jnp.asarray(random_rotation(rng)); t = jnp.asarray([0.5, -1.0, 2.0])
    h1, x1 = egnn_forward(p, h, pos, s, d, cfg)
    h2, x2 = egnn_forward(p, h, pos @ R.T + t, s, d, cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(x1 @ R.T + t), np.asarray(x2), atol=2e-4)


def test_equiformer_so3_invariance_and_chunking(rng):
    from repro.models.equiformer_v2 import (
        EquiformerV2Config, equiformer_forward, equiformer_init,
    )

    cfg = EquiformerV2Config(n_layers=2, d_hidden=16, l_max=3, m_max=2, n_heads=4, d_in=8, d_out=2)
    p = equiformer_init(KEY, cfg)
    n, e = 40, 150
    s = jnp.asarray(rng.integers(0, n, e)); d = jnp.asarray(rng.integers(0, n, e))
    h = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    pos = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    R = jnp.asarray(random_rotation(rng)); t = jnp.asarray([1.0, 2.0, 3.0])
    o1 = equiformer_forward(p, h, pos, s, d, cfg)
    o2 = equiformer_forward(p, h, pos @ R.T + t, s, d, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)
    cfg_c = dataclasses.replace(cfg, edge_chunk=64)
    o3 = equiformer_forward(p, h, pos, s, d, cfg_c)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_so3_wigner_properties(seed):
    from repro.nn.so3 import real_sh_rotations

    r = np.random.default_rng(seed)
    a = np.linalg.qr(r.standard_normal((2, 3, 3)))[0]
    det = np.linalg.det(a)
    a[det < 0, :, 0] *= -1
    R = jnp.asarray(a, jnp.float32)
    D = real_sh_rotations(R, 4)
    for l, Dl in enumerate(D):
        eye = np.eye(2 * l + 1)
        np.testing.assert_allclose(
            np.asarray(jnp.einsum("bij,bkj->bik", Dl, Dl)), np.tile(eye, (2, 1, 1)), atol=2e-5
        )
    D1, D2 = real_sh_rotations(R[:1], 4), real_sh_rotations(R[1:], 4)
    D12 = real_sh_rotations(R[:1] @ R[1:], 4)
    for l in range(5):
        np.testing.assert_allclose(
            np.asarray(D12[l]), np.asarray(D1[l] @ D2[l]), atol=3e-5
        )
