"""Per-kernel allclose vs the ref.py oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph.structure import blocked_adjacency
from repro.kernels.ops import bsr_spmm, flash_attention, fm_interaction
from repro.kernels.ref import bsr_spmm_ref, flash_attention_ref, fm_interaction_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------ bsr_spmm
@pytest.mark.parametrize("n,e,f", [(300, 900, 64), (1000, 5000, 96), (257, 800, 128)])
def test_bsr_spmm_matches_ref(n, e, f):
    ei = RNG.integers(0, n, size=(2, e)).astype(np.int32)
    w = RNG.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    z = jnp.asarray(RNG.standard_normal((ba.n_padded, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)
    ref = bsr_spmm_ref(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_bsr_spmm_equals_segment_sum():
    """The kernel computes the same aggregation as the segment-op reference
    path used by the models — ties the Pallas layer to the system layer."""
    from repro.graph.ops import aggregate

    n, e, f = 500, 2500, 64
    ei = RNG.integers(0, n, size=(2, e)).astype(np.int32)
    w = RNG.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    z = jnp.asarray(RNG.standard_normal((ba.n_padded, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)[:n]
    seg = aggregate(z[:n], jnp.asarray(ei[0]), jnp.asarray(ei[1]), n, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(seg), rtol=3e-4, atol=3e-4)


@settings(max_examples=10, deadline=None)
@given(
    nb=st.integers(1, 4),
    t=st.integers(1, 5),
    f=st.sampled_from([128, 256]),
    seed=st.integers(0, 99),
)
def test_bsr_spmm_hypothesis_blocks(nb, t, f, seed):
    """Random block structures (including repeated columns = padding)."""
    r = np.random.default_rng(seed)
    B = 128
    vals = r.standard_normal((nb, t, B, B)).astype(np.float32) * 0.1
    cols = r.integers(0, nb, size=(nb, t)).astype(np.int32)
    z = jnp.asarray(r.standard_normal((nb * B, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(vals), jnp.asarray(cols), z, f_tile=128)
    ref = bsr_spmm_ref(jnp.asarray(vals), jnp.asarray(cols), z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ fm_interaction
@pytest.mark.parametrize("b,f,d", [(32, 13, 10), (256, 39, 10), (64, 8, 16)])
def test_fm_matches_ref_and_pairwise(b, f, d):
    emb = jnp.asarray(RNG.standard_normal((b, f, d)), jnp.float32)
    out = fm_interaction(emb)
    ref = fm_interaction_ref(emb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
    # explicit O(F²) pairwise oracle
    pair = 0.5 * (
        jnp.einsum("bfd,bgd->b", emb, emb) - jnp.einsum("bfd,bfd->b", emb, emb)
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(pair), rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([8, 64, 200]),
    f=st.integers(2, 40),
    d=st.sampled_from([4, 10, 32]),
    seed=st.integers(0, 99),
)
def test_fm_hypothesis(b, f, d, seed):
    r = np.random.default_rng(seed)
    emb = jnp.asarray(r.standard_normal((b, f, d)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(fm_interaction(emb)), np.asarray(fm_interaction_ref(emb)),
        rtol=2e-4, atol=2e-4,
    )


# ----------------------------------------------------------- flash_attention
@pytest.mark.parametrize("s,d,window", [(128, 64, None), (256, 64, 48), (128, 128, 16)])
def test_flash_matches_ref(s, d, window):
    q = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    out = flash_attention(q, k, v, window=window, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    s, d = 128, 64
    q = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.bfloat16)
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref), rtol=2e-2, atol=2e-2
    )


def test_flash_matches_model_attention():
    """Kernel == the chunked-jnp attention the models actually run on CPU."""
    from repro.nn.attention import _chunked_attention

    s, d = 128, 64
    q = jnp.asarray(RNG.standard_normal((2, s, 4, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, s, 4, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, s, 4, d)), jnp.float32)
    model_out = _chunked_attention(q, k, v, jnp.arange(s), 32, chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(8, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(8, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(8, s, d)
    kern = flash_attention(qf, kf, vf, window=32, bq=64, bk=64)
    kern = kern.reshape(2, 4, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model_out), rtol=3e-5, atol=3e-5)
