"""Per-kernel allclose vs the ref.py oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph.structure import (
    blocked_adjacency,
    locality_block_order,
    permute_edge_index,
    relocate_rows,
    restore_rows,
)
from repro.kernels.ops import bsr_spmm, flash_attention, fm_interaction, fused_gcn_layer
from repro.kernels.ref import (
    bsr_spmm_ref,
    flash_attention_ref,
    fm_interaction_ref,
    fused_gcn_layer_ref,
)

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------ bsr_spmm
@pytest.mark.parametrize("n,e,f", [(300, 900, 64), (1000, 5000, 96), (257, 800, 128)])
def test_bsr_spmm_matches_ref(n, e, f):
    ei = RNG.integers(0, n, size=(2, e)).astype(np.int32)
    w = RNG.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    z = jnp.asarray(RNG.standard_normal((ba.n_padded, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)
    ref = bsr_spmm_ref(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_bsr_spmm_equals_segment_sum():
    """The kernel computes the same aggregation as the segment-op reference
    path used by the models — ties the Pallas layer to the system layer."""
    from repro.graph.ops import aggregate

    n, e, f = 500, 2500, 64
    ei = RNG.integers(0, n, size=(2, e)).astype(np.int32)
    w = RNG.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    z = jnp.asarray(RNG.standard_normal((ba.n_padded, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), z)[:n]
    seg = aggregate(z[:n], jnp.asarray(ei[0]), jnp.asarray(ei[1]), n, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(seg), rtol=3e-4, atol=3e-4)


@settings(max_examples=10, deadline=None)
@given(
    nb=st.integers(1, 4),
    t=st.integers(1, 5),
    f=st.sampled_from([128, 256]),
    seed=st.integers(0, 99),
)
def test_bsr_spmm_hypothesis_blocks(nb, t, f, seed):
    """Random block structures (including repeated columns = padding)."""
    r = np.random.default_rng(seed)
    B = 128
    vals = r.standard_normal((nb, t, B, B)).astype(np.float32) * 0.1
    cols = r.integers(0, nb, size=(nb, t)).astype(np.int32)
    z = jnp.asarray(r.standard_normal((nb * B, f)), jnp.float32)
    out = bsr_spmm(jnp.asarray(vals), jnp.asarray(cols), z, f_tile=128)
    ref = bsr_spmm_ref(jnp.asarray(vals), jnp.asarray(cols), z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_bsr_spmm_ragged_skips_padding_tiles():
    """The pl.when(t < lens[r]) guard really skips padded tiles: poison the
    tiles past each row's length with garbage — the ragged kernel must be
    unaffected (a dense-T kernel would fold the garbage in)."""
    r = np.random.default_rng(3)
    B, nb, T = 128, 3, 4
    vals = r.standard_normal((nb, T, B, B)).astype(np.float32) * 0.1
    cols = r.integers(0, nb, size=(nb, T)).astype(np.int32)
    lens = np.array([1, 3, 2], np.int32)
    clean = vals.copy()
    for rr in range(nb):
        clean[rr, lens[rr]:] = 0.0                       # the layout contract
        vals[rr, lens[rr]:] = 1e6                        # poison the padding
    z = jnp.asarray(r.standard_normal((nb * B, 128)), jnp.float32)
    out = bsr_spmm(jnp.asarray(vals), jnp.asarray(cols), z, lens=jnp.asarray(lens))
    ref = bsr_spmm_ref(jnp.asarray(clean), jnp.asarray(cols), z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_bsr_spmm_row_pad_wrapper():
    """z rows not a multiple of 128 are padded inside the wrapper."""
    n, e, f = 300, 1200, 64
    ei = RNG.integers(0, n, size=(2, e)).astype(np.int32)
    w = RNG.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    z = jnp.asarray(RNG.standard_normal((n, f)), jnp.float32)   # unpadded rows
    vals, cols, lens = ba.arrays()
    out = bsr_spmm(vals, cols, z, lens=lens)
    zp = jnp.pad(z, ((0, ba.n_col_padded - n), (0, 0)))
    ref = bsr_spmm_ref(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), zp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ fused_gcn_layer
@pytest.mark.parametrize("order", ["feature_first", "aggregation_first"])
@pytest.mark.parametrize("relu", [True, False])
def test_fused_gcn_layer_matches_ref(order, relu):
    """One pallas_call == the unfused matmul ∘ SpMM ∘ bias ∘ act pipeline,
    at awkward widths (F_in/F_out not 128 multiples, ragged tail block)."""
    n, e, d_in, d_out = 300, 1500, 50, 7
    ei = RNG.integers(0, n, size=(2, e)).astype(np.int32)
    w = RNG.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    x = jnp.asarray(RNG.standard_normal((n, d_in)), jnp.float32)
    W = jnp.asarray(RNG.standard_normal((d_in, d_out)) * 0.2, jnp.float32)
    b = jnp.asarray(RNG.standard_normal(d_out), jnp.float32)
    out = fused_gcn_layer(*ba.arrays(), x, W, b, order=order, relu=relu)[:n]
    xp = jnp.pad(x, ((0, ba.n_col_padded - n), (0, 0)))
    ref = fused_gcn_layer_ref(
        jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), xp, W, b,
        order=order, relu=relu,
    )[:n]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)


def test_fused_gcn_layer_bf16_fp32_accumulation():
    """bf16 vals/features with fp32 accumulation: output within bf16 noise of
    the fp32 oracle, and the output dtype follows the inputs."""
    n, e, d_in, d_out = 256, 1200, 32, 16
    ei = RNG.integers(0, n, size=(2, e)).astype(np.int32)
    w = RNG.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    vals, cols, lens = ba.arrays()
    x = jnp.asarray(RNG.standard_normal((n, d_in)), jnp.float32)
    W = jnp.asarray(RNG.standard_normal((d_in, d_out)) * 0.2, jnp.float32)
    b = jnp.asarray(RNG.standard_normal(d_out), jnp.float32)
    out = fused_gcn_layer(
        vals.astype(jnp.bfloat16), cols, lens, x.astype(jnp.bfloat16),
        W.astype(jnp.bfloat16), b, order="feature_first", relu=True,
    )[:n]
    assert out.dtype == jnp.bfloat16
    ref = fused_gcn_layer_ref(vals, cols, jnp.pad(x, ((0, ba.n_col_padded - n), (0, 0))),
                              W, b, order="feature_first", relu=True)[:n]
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=5e-2, atol=5e-2
    )


def test_fused_gcn_layer_grad_matches_ref():
    """The custom VJP (blocked-transpose scatter-add) == autodiff of the
    unfused oracle, for every differentiable operand."""
    n, e, d_in, d_out = 260, 1000, 24, 5
    ei = RNG.integers(0, n, size=(2, e)).astype(np.int32)
    w = RNG.standard_normal(e).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    vals, cols, lens = ba.arrays()
    x = jnp.asarray(RNG.standard_normal((n, d_in)), jnp.float32)
    W = jnp.asarray(RNG.standard_normal((d_in, d_out)) * 0.2, jnp.float32)
    b = jnp.asarray(RNG.standard_normal(d_out), jnp.float32)
    pad = ba.n_col_padded - n
    for order in ("feature_first", "aggregation_first"):
        def loss_k(W, b, x, vals):
            return (fused_gcn_layer(vals, cols, lens, x, W, b, order=order)[:n] ** 2).sum()

        def loss_r(W, b, x, vals):
            xp = jnp.pad(x, ((0, pad), (0, 0)))
            return (fused_gcn_layer_ref(vals, cols, xp, W, b, order=order)[:n] ** 2).sum()

        gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(W, b, x, vals)
        gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(W, b, x, vals)
        # dvals: the ragged kernel does not read padding tiles, so its true
        # gradient there is zero; the dense-T oracle multiplies them. Compare
        # on the valid tiles (and check the kernel's padding grads ARE zero).
        tile_ok = (np.arange(ba.max_nnzb)[None, :] < ba.row_nnzb[:, None])
        assert np.all(np.asarray(gk[3])[~tile_ok] == 0.0)
        gk = (*gk[:3], jnp.asarray(np.asarray(gk[3]) * tile_ok[:, :, None, None]))
        gr = (*gr[:3], jnp.asarray(np.asarray(gr[3]) * tile_ok[:, :, None, None]))
        for name, a, r in zip(("dW", "db", "dx", "dvals"), gk, gr):
            scale = float(jnp.abs(r).max()) + 1e-9
            np.testing.assert_allclose(
                np.asarray(a) / scale, np.asarray(r) / scale, rtol=2e-5, atol=2e-5,
                err_msg=f"{order}/{name}",
            )


# --------------------------------------------- ragged layout + reorder props
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(100, 700),
    e=st.integers(50, 3000),
    seed=st.integers(0, 99),
)
def test_ragged_blocked_adjacency_invariants(n, e, seed):
    """Layout contract of the ragged BSR (docs/kernels.md): lens ≤ T, every
    tile past a row's length is a zero tile with a repeated in-range col id,
    and the locality permutation round-trips node arrays exactly."""
    r = np.random.default_rng(seed)
    ei = r.integers(0, n, size=(2, e)).astype(np.int32)
    w = (np.abs(r.standard_normal(e)) + 0.1).astype(np.float32)
    ba = blocked_adjacency(n, ei, w, block=128)
    T = ba.max_nnzb
    assert ba.row_nnzb.shape == (ba.n_block_rows,)
    assert (ba.row_nnzb <= T).all() and (ba.row_nnzb >= 0).all()
    assert ba.nnz_blocks == int(ba.row_nnzb.sum())
    assert 0.0 <= ba.padded_tile_fraction < 1.0
    assert (ba.block_cols >= 0).all() and (ba.block_cols < ba.n_block_cols).all()
    for rr in range(ba.n_block_rows):
        ln = int(ba.row_nnzb[rr])
        assert np.all(ba.block_vals[rr, ln:] == 0.0), "pad tiles must be zero"
        if 0 < ln < T:
            assert np.all(ba.block_cols[rr, ln:] == ba.block_cols[rr, ln - 1])
        # valid tiles: at least one nonzero entry each (they exist by def)
        for t in range(ln):
            assert np.any(ba.block_vals[rr, t] != 0.0)
    # permutation round-trip: restore ∘ relocate == id, and the permuted
    # graph's blocked aggregation equals the original after restore
    perm = locality_block_order(n, ei, block=128)
    assert np.array_equal(np.sort(perm), np.arange(n))
    x = r.standard_normal((n, 3)).astype(np.float32)
    np.testing.assert_array_equal(restore_rows(perm, relocate_rows(perm, x)), x)
    ei_p = permute_edge_index(perm, ei)
    # relabeling round-trip: mapping the new ids back through perm gives the
    # original endpoints (perm[inv[v]] == v)
    assert np.array_equal(perm[ei_p], ei.astype(np.int64))
    ba_p = blocked_adjacency(n, ei_p, w, block=128)
    z = r.standard_normal((n, 8)).astype(np.float32)
    zp = np.zeros((ba_p.n_col_padded, 8), np.float32)
    zp[:n] = relocate_rows(perm, z)
    agg_p = np.asarray(bsr_spmm_ref(*[jnp.asarray(a) for a in (ba_p.block_vals, ba_p.block_cols)], jnp.asarray(zp)))[:n]
    z0 = np.zeros((ba.n_col_padded, 8), np.float32)
    z0[:n] = z
    agg_0 = np.asarray(bsr_spmm_ref(jnp.asarray(ba.block_vals), jnp.asarray(ba.block_cols), jnp.asarray(z0)))[:n]
    np.testing.assert_allclose(restore_rows(perm, agg_p), agg_0, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ fm_interaction
@pytest.mark.parametrize("b,f,d", [(32, 13, 10), (256, 39, 10), (64, 8, 16)])
def test_fm_matches_ref_and_pairwise(b, f, d):
    emb = jnp.asarray(RNG.standard_normal((b, f, d)), jnp.float32)
    out = fm_interaction(emb)
    ref = fm_interaction_ref(emb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
    # explicit O(F²) pairwise oracle
    pair = 0.5 * (
        jnp.einsum("bfd,bgd->b", emb, emb) - jnp.einsum("bfd,bfd->b", emb, emb)
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(pair), rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([8, 64, 200]),
    f=st.integers(2, 40),
    d=st.sampled_from([4, 10, 32]),
    seed=st.integers(0, 99),
)
def test_fm_hypothesis(b, f, d, seed):
    r = np.random.default_rng(seed)
    emb = jnp.asarray(r.standard_normal((b, f, d)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(fm_interaction(emb)), np.asarray(fm_interaction_ref(emb)),
        rtol=2e-4, atol=2e-4,
    )


# ----------------------------------------------------------- flash_attention
@pytest.mark.parametrize("s,d,window", [(128, 64, None), (256, 64, 48), (128, 128, 16)])
def test_flash_matches_ref(s, d, window):
    q = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    out = flash_attention(q, k, v, window=window, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    s, d = 128, 64
    q = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.bfloat16)
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref), rtol=2e-2, atol=2e-2
    )


def test_flash_matches_model_attention():
    """Kernel == the chunked-jnp attention the models actually run on CPU."""
    from repro.nn.attention import _chunked_attention

    s, d = 128, 64
    q = jnp.asarray(RNG.standard_normal((2, s, 4, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, s, 4, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, s, 4, d)), jnp.float32)
    model_out = _chunked_attention(q, k, v, jnp.arange(s), 32, chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(8, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(8, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(8, s, d)
    kern = flash_attention(qf, kf, vf, window=32, bq=64, bk=64)
    kern = kern.reshape(2, 4, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model_out), rtol=3e-5, atol=3e-5)
