"""repro.dist coverage beyond the seed suite: plan round-trips, degenerate
partitions, padding hygiene, and the collective path on a 1-device mesh (so
`halo_exchange` is exercised without --xla_force_host_platform_device_count).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.partition import partition_graph
from repro.dist.halo import build_halo_plan, halo_aggregate, halo_exchange
from repro.graph.generators import citation_like
from repro.graph.ops import aggregate


# ------------------------------------------------------------ plan properties
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(32, 300),
    e=st.integers(50, 1500),
    k=st.sampled_from([1, 2, 4, 8]),
    method=st.sampled_from(["block", "random", "bfs"]),
    seed=st.integers(0, 30),
)
def test_halo_plan_perm_roundtrip(n, e, k, seed, method):
    """Scattering device blocks back through perm restores global order."""
    g = citation_like(n, e, seed=seed)
    part = partition_graph(n, g.edge_index, k, method=method, seed=seed)
    plan = build_halo_plan(part, g.edge_index)
    # perm is a bijection and its inverse undoes it.
    inv = np.empty(n, np.int64)
    inv[plan.perm] = np.arange(n)
    assert np.array_equal(plan.perm[inv], np.arange(n))
    # Block b of the permuted order holds exactly the nodes assigned to b.
    off = 0
    for b in range(k):
        sz = int(part.part_sizes[b])
        assert np.all(part.assignment[plan.perm[off:off + sz]] == b)
        off += sz
    # Relocalization is consistent: mapping every (sender→receiver) pair back
    # to global ids recovers the original edge multiset.
    local_ids = np.full((k, plan.n_local + k * plan.s_max), -1, np.int64)
    sizes = part.part_sizes
    offs = np.concatenate([[0], np.cumsum(sizes)])
    for b in range(k):
        local_ids[b, : sizes[b]] = plan.perm[offs[b]:offs[b + 1]]
    if plan.s_max:
        for b in range(k):
            for j in range(k):
                # halo slot t of source device j is j's exported local row
                base = plan.n_local + j * plan.s_max
                local_ids[b, base: base + plan.s_max] = local_ids[j, plan.send_idx[j]]
    rebuilt = []
    for b in range(k):
        valid = plan.edge_w[b] > 0
        s_glob = local_ids[b, plan.senders_l[b][valid]]
        d_glob = local_ids[b, plan.receivers_l[b][valid]]
        rebuilt.append(np.stack([s_glob, d_glob]))
    rebuilt = np.concatenate(rebuilt, axis=1)
    orig = np.sort(g.edge_index[0].astype(np.int64) * n + g.edge_index[1])
    got = np.sort(rebuilt[0] * n + rebuilt[1])
    assert np.array_equal(got, orig)


def test_halo_plan_k1_has_no_halo():
    g = citation_like(120, 700, seed=5)
    part = partition_graph(120, g.edge_index, 1, method="block")
    plan = build_halo_plan(part, g.edge_index)
    assert plan.k == 1 and plan.s_max == 0 and plan.n_local == 120
    assert int((plan.edge_w > 0).sum()) == 700
    # All senders are local rows — nothing crosses a device boundary.
    assert plan.senders_l.max() < plan.n_local
    assert np.array_equal(plan.perm, np.arange(120))  # block k=1 is identity


def test_halo_plan_isolated_nodes():
    """Nodes with no edges still get block slots; invariants still hold."""
    n, k = 64, 4
    # Edges only among the first 16 nodes: 48 isolated nodes.
    rng = np.random.default_rng(0)
    ei = rng.integers(0, 16, size=(2, 120)).astype(np.int32)
    part = partition_graph(n, ei, k, method="block")
    plan = build_halo_plan(part, ei)
    assert np.array_equal(np.sort(plan.perm), np.arange(n))
    assert int((plan.edge_w > 0).sum()) == 120
    assert plan.receivers_l.max() < plan.n_local
    assert plan.senders_l.max() < plan.n_local + plan.k * plan.s_max
    # Isolated nodes export nothing and receive nothing beyond padding.
    assert plan.s_max <= 16


def test_halo_plan_padding_is_inert():
    g = citation_like(150, 900, seed=2)
    part = partition_graph(150, g.edge_index, 4, method="bfs", seed=0)
    plan = build_halo_plan(part, g.edge_index)
    pad = plan.k * plan.e_local - 900
    assert pad >= 0
    assert int((plan.edge_w == 0).sum()) == pad
    # Padding rows/indices stay in range so gathers never go out of bounds.
    assert plan.senders_l.min() >= 0 and plan.receivers_l.min() >= 0
    assert plan.send_idx.min() >= 0
    if plan.s_max:
        assert plan.send_idx.max() < plan.n_local


def test_halo_plan_custom_weights_and_zero_weight_edges():
    """Explicit weights ride through; a real zero-weight edge is counted as
    padding by the >0 mask (documented contract) but aggregates identically."""
    g = citation_like(80, 400, seed=9)
    w = np.abs(np.random.default_rng(0).standard_normal(400)).astype(np.float32) + 0.1
    w[17] = 0.0                             # one REAL edge with zero weight
    part = partition_graph(80, g.edge_index, 4, method="bfs", seed=1)
    plan = build_halo_plan(part, g.edge_index, w)
    valid = plan.edge_w > 0
    # The zero-weight edge is indistinguishable from padding under the >0
    # mask — by contract it counts as padding (and aggregates identically,
    # since a 0-weight message contributes nothing).
    assert int(valid.sum()) == 399
    np.testing.assert_allclose(np.sort(plan.edge_w[valid]), np.sort(w[w > 0]), rtol=0)


# --------------------------------------------- collectives on a 1-device mesh
def _one_device_mesh():
    if jax.device_count() < 1:  # pragma: no cover
        pytest.skip("no devices")
    return jax.make_mesh((1,), ("model",))


@pytest.mark.parametrize("via", ["all_gather", "ppermute"])
def test_halo_exchange_identity_one_device(via):
    """On a k=1 mesh the halo block is exactly the exported rows."""
    mesh = _one_device_mesh()
    h = jnp.asarray(np.random.default_rng(0).standard_normal((10, 4)), jnp.float32)
    send_idx = jnp.asarray([7, 0, 3], jnp.int32)
    f = jax.shard_map(
        lambda hh, si: halo_exchange(hh[0], si[0], "model", via=via)[None],
        mesh=mesh, in_specs=(P("model"), P("model")), out_specs=P("model"),
        check_vma=False,
    )
    out = np.asarray(f(h[None], send_idx[None]))[0]
    np.testing.assert_array_equal(out, np.asarray(h)[np.asarray(send_idx)])


@pytest.mark.parametrize("via", ["all_gather", "ppermute"])
def test_halo_aggregate_equals_global_one_device(via):
    """The full collective path (k=1 plan) reproduces the global aggregate."""
    mesh = _one_device_mesh()
    g = citation_like(90, 500, seed=4)
    w = np.abs(np.random.default_rng(1).standard_normal(500)).astype(np.float32)
    part = partition_graph(90, g.edge_index, 1, method="block")
    plan = build_halo_plan(part, g.edge_index, w)
    z = np.random.default_rng(2).standard_normal((90, 8)).astype(np.float32)
    si, sl, rl, ew = plan.device_arrays()
    f = jax.shard_map(
        lambda zz, a, b, c, d: halo_aggregate(zz[0], a[0], b[0], c[0], d[0], "model", via=via)[None],
        mesh=mesh, in_specs=(P("model"),) * 5, out_specs=P("model"),
        check_vma=False,
    )
    out = np.asarray(f(jnp.asarray(z)[None], si, sl, rl, ew))[0]
    ref = np.asarray(aggregate(jnp.asarray(z), jnp.asarray(g.edge_index[0]),
                               jnp.asarray(g.edge_index[1]), 90, jnp.asarray(w)))
    np.testing.assert_allclose(out[plan.perm.argsort()], ref, rtol=1e-5, atol=1e-5)


def test_wire_volume_helpers_match_invariant():
    g = citation_like(2000, 12000, seed=1)
    part = partition_graph(2000, g.edge_index, 8, method="bfs", seed=0, refine=True)
    plan = build_halo_plan(part, g.edge_index)
    assert plan.halo_rows_per_device == plan.k * plan.s_max
    assert plan.broadcast_rows_per_device == (plan.k - 1) * plan.n_local
    assert plan.wire_fraction() < 1.0


# --------------------------------------------------------------- plan cache
def test_plan_cache_same_graph_reuses_object():
    from repro.dist import halo

    halo.invalidate_halo_plans()
    g = citation_like(120, 700, seed=7)
    part = partition_graph(120, g.edge_index, 4, method="bfs", seed=0)
    before = halo.plan_cache_stats()
    p1 = halo.get_halo_plan(part, g.edge_index)
    p2 = halo.get_halo_plan(part, g.edge_index)
    assert p1 is p2                              # same graph/partition/k → same object
    after = halo.plan_cache_stats()
    assert after["misses"] - before["misses"] == 1
    assert after["hits"] - before["hits"] == 1


def test_plan_cache_mutated_graph_or_k_rebuilds():
    from repro.dist import halo

    halo.invalidate_halo_plans()
    g = citation_like(120, 700, seed=7)
    part4 = partition_graph(120, g.edge_index, 4, method="bfs", seed=0)
    p1 = halo.get_halo_plan(part4, g.edge_index)
    # Different k → different cache entry.
    part8 = partition_graph(120, g.edge_index, 8, method="bfs", seed=0)
    p8 = halo.get_halo_plan(part8, g.edge_index)
    assert p8 is not p1 and p8.k == 8
    # Mutated edge list → different fingerprint → rebuild.
    ei2 = g.edge_index.copy()
    ei2[1, 0] = (ei2[1, 0] + 1) % 120
    part_m = partition_graph(120, ei2, 4, method="bfs", seed=0)
    pm = halo.get_halo_plan(part_m, ei2)
    assert pm is not p1
    # Same graph, different partition (seed) → no collision either.
    part_s = partition_graph(120, g.edge_index, 4, method="random", seed=3)
    ps = halo.get_halo_plan(part_s, g.edge_index)
    assert ps is not p1
    assert halo.plan_cache_stats()["size"] >= 4
    evicted = halo.invalidate_halo_plans()
    assert evicted >= 4
    assert halo.get_halo_plan(part4, g.edge_index) is not p1   # rebuilt


def test_plan_cache_lazy_builder_runs_once():
    from repro.dist.halo import cached_halo_plan, invalidate_halo_plans

    invalidate_halo_plans()
    calls = []

    def build():
        calls.append(1)
        g = citation_like(64, 300, seed=1)
        part = partition_graph(64, g.edge_index, 2, method="block")
        from repro.dist.halo import build_halo_plan

        return build_halo_plan(part, g.edge_index)

    p1 = cached_halo_plan("unit:lazy", 2, builder=build)
    p2 = cached_halo_plan("unit:lazy", 2, builder=build)
    assert p1 is p2 and len(calls) == 1
    # Axis is part of the key (hierarchical meshes cache per axis).
    p3 = cached_halo_plan("unit:lazy", 2, "pod", builder=build)
    assert p3 is not p1 and len(calls) == 2


def test_plan_cache_elastic_resize_invalidates():
    from repro.dist import halo
    from repro.train.elastic import elastic_replan

    halo.invalidate_halo_plans()
    g = citation_like(100, 500, seed=2)
    part = partition_graph(100, g.edge_index, 8, method="bfs", seed=0)
    p1 = halo.get_halo_plan(part, g.edge_index)
    # Data-axis-only shrink keeps the model degree → plans stay valid.
    keep = elastic_replan(32, 8)
    assert keep.shape == (4, 8)
    assert halo.get_halo_plan(part, g.edge_index) is p1
    # Model-degree change = re-partition event → full invalidation.
    shrink = elastic_replan(4, 8)
    assert shrink.shape[1] == 4
    assert halo.get_halo_plan(part, g.edge_index) is not p1


def test_relocate_restore_roundtrip_and_node_mask():
    from repro.dist.halo import get_halo_plan, node_mask, relocate_node_array, restore_node_array

    g = citation_like(90, 400, seed=11)
    part = partition_graph(90, g.edge_index, 4, method="bfs", seed=1)
    plan = get_halo_plan(part, g.edge_index)
    x = np.random.default_rng(0).standard_normal((90, 5)).astype(np.float32)
    blocks = relocate_node_array(plan, x)
    assert blocks.shape == (4, plan.n_local, 5)
    np.testing.assert_array_equal(restore_node_array(plan, blocks), x)
    mask = node_mask(plan)
    assert mask.shape == (4, plan.n_local)
    assert int(mask.sum()) == 90
    # Padding rows are zero in the blocked layout.
    assert np.all(blocks[mask == 0] == 0)


# -------------------------------------------------------------------- policy
def test_policy_constrain_noop_and_named():
    from repro.dist.policy import NO_POLICY, ShardingPolicy

    x = jnp.ones((4, 4))
    assert NO_POLICY.constrain(x, "anything") is x
    mesh = jax.make_mesh((1,), ("model",))
    pol = ShardingPolicy(mesh=mesh, specs={"h": P("model", None)})
    assert pol.constrain(x, "unregistered") is x
    y = pol.constrain(x, "h")                      # applies, values unchanged
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert pol.spec("h") == P("model", None)
    assert pol.sharding("h").mesh is not None
    pol2 = pol.with_specs(h=P(None, "model"))
    assert pol2.spec("h") == P(None, "model") and pol.spec("h") == P("model", None)


def test_policy_comm_mode_and_neighbor_table():
    from repro.dist.policy import NO_POLICY, ShardingPolicy

    x = jnp.arange(12.0).reshape(6, 2)
    # Broadcast / NO_POLICY: the table is the identity.
    assert NO_POLICY.neighbor_table(x) is x
    halo_pol = ShardingPolicy(comm="halo")
    # Unbound halo (outside shard_map) is inert too.
    assert not halo_pol.is_halo
    assert halo_pol.neighbor_table(x) is x
    bound = halo_pol.bind_halo(jnp.asarray([0, 3], jnp.int32))
    assert bound.is_halo and not halo_pol.is_halo       # bind returns a copy
    # with_specs preserves the comm mode.
    assert halo_pol.with_specs(h=P("model", None)).comm == "halo"
