"""Dataflow reordering (§IV-C3), chip capacity (§V-C), quantization (§V-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.chip import ChipModel, chips_required
from repro.core.dataflow import choose_order, dense_multiply_count, sparse_multiply_count
from repro.core.quant import QuantConfig, fake_quant


def test_nell_311x_reduction():
    """§IV-C3 verbatim: 2.3e13 vs 7.4e10 multiplies, ≈311× reduction."""
    c = dense_multiply_count(65755, 5414, 16)
    assert np.isclose(c.aggregation_first, 2.3e13, rtol=0.03)
    assert np.isclose(c.feature_first, 7.4e10, rtol=0.02)
    assert 300 < c.reduction < 320
    assert c.best == "feature_first"


def test_chooser_flips_when_widths_flip():
    assert choose_order(1000, d_in=512, d_out=16) == "feature_first"
    assert choose_order(1000, d_in=16, d_out=512) == "aggregation_first"
    assert choose_order(1000, 512, 16, n_edges=5000) == "feature_first"


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(10, 10_000),
    e=st.integers(10, 100_000),
    d_in=st.integers(1, 2048),
    d_out=st.integers(1, 2048),
)
def test_chooser_optimal_under_both_cost_models(n, e, d_in, d_out):
    dc = dense_multiply_count(n, d_in, d_out)
    sc = sparse_multiply_count(n, e, d_in, d_out)
    assert dc.best == min(
        ("aggregation_first", dc.aggregation_first), ("feature_first", dc.feature_first),
        key=lambda kv: kv[1],
    )[0] or dc.aggregation_first == dc.feature_first
    assert sc.reduction > 0


def test_chip_counts_match_paper_where_derivable():
    cm = ChipModel()
    table = {
        "cora": (2708, [1433, 16, 7]),
        "citeseer": (3327, [3703, 16, 6]),
        "pubmed": (19717, [500, 16, 3]),
        "nell": (65755, [5414, 16, 210]),
    }
    # crossbar-granular reproduces Cora/Citeseer (1) and Nell (45) exactly.
    assert chips_required(cm, *table["cora"]) == 1
    assert chips_required(cm, *table["citeseer"]) == 1
    assert chips_required(cm, *table["nell"]) == 45
    # cell-granular reproduces Pubmed ≈ 3 (paper rounds 3.09 down; we ceil).
    assert chips_required(cm, *table["pubmed"], mode="cell") in (3, 4)
    # 30 MB chip (§IV-B3).
    assert abs(cm.bytes_per_chip - 30 * 2**20) / (30 * 2**20) < 0.01


def test_chips_monotone_in_nodes():
    cm = ChipModel()
    prev = 0
    for n in [1000, 5000, 20_000, 60_000, 120_000]:
        c = chips_required(cm, n, [128, 16, 4])
        assert c >= prev
        prev = c


def test_fake_quant_level_count_and_ste():
    x = jnp.linspace(-1, 1, 1001)
    for bits in [2, 3, 4, 8]:
        q = fake_quant(x, bits)
        assert len(np.unique(np.asarray(q))) <= 2**bits
    # straight-through: gradient of sum(fake_quant(x)) is all-ones
    g = jax.grad(lambda x: fake_quant(x, 4).sum())(x)
    assert np.allclose(np.asarray(g), 1.0)
    # ≥32 bits is a no-op
    assert np.array_equal(np.asarray(fake_quant(x, 32)), np.asarray(x))


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 1000))
def test_fake_quant_error_bound(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256), jnp.float32)
    q = fake_quant(x, bits)
    amax = float(jnp.max(jnp.abs(x)))
    step = amax / (2 ** (bits - 1) - 1)
    assert float(jnp.max(jnp.abs(q - x))) <= step * 0.5 + 1e-6
