"""Dataflow reordering (§IV-C3), chip capacity (§V-C), quantization (§V-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.chip import ChipModel, chips_required
from repro.core.dataflow import (
    choose_order,
    dense_multiply_count,
    exchange_cost,
    sparse_multiply_count,
)
from repro.core.quant import (
    QuantConfig,
    dequantize_payload,
    fake_quant,
    payload_bits,
    quantize_payload,
    quantize_tree,
)


def test_nell_311x_reduction():
    """§IV-C3 verbatim: 2.3e13 vs 7.4e10 multiplies, ≈311× reduction."""
    c = dense_multiply_count(65755, 5414, 16)
    assert np.isclose(c.aggregation_first, 2.3e13, rtol=0.03)
    assert np.isclose(c.feature_first, 7.4e10, rtol=0.02)
    assert 300 < c.reduction < 320
    assert c.best == "feature_first"


def test_chooser_flips_when_widths_flip():
    assert choose_order(1000, d_in=512, d_out=16) == "feature_first"
    assert choose_order(1000, d_in=16, d_out=512) == "aggregation_first"
    assert choose_order(1000, 512, 16, n_edges=5000) == "feature_first"


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(10, 10_000),
    e=st.integers(10, 100_000),
    d_in=st.integers(1, 2048),
    d_out=st.integers(1, 2048),
)
def test_chooser_optimal_under_both_cost_models(n, e, d_in, d_out):
    dc = dense_multiply_count(n, d_in, d_out)
    sc = sparse_multiply_count(n, e, d_in, d_out)
    assert dc.best == min(
        ("aggregation_first", dc.aggregation_first), ("feature_first", dc.feature_first),
        key=lambda kv: kv[1],
    )[0] or dc.aggregation_first == dc.feature_first
    assert sc.reduction > 0


def test_chip_counts_match_paper_where_derivable():
    cm = ChipModel()
    table = {
        "cora": (2708, [1433, 16, 7]),
        "citeseer": (3327, [3703, 16, 6]),
        "pubmed": (19717, [500, 16, 3]),
        "nell": (65755, [5414, 16, 210]),
    }
    # crossbar-granular reproduces Cora/Citeseer (1) and Nell (45) exactly.
    assert chips_required(cm, *table["cora"]) == 1
    assert chips_required(cm, *table["citeseer"]) == 1
    assert chips_required(cm, *table["nell"]) == 45
    # cell-granular reproduces Pubmed ≈ 3 (paper rounds 3.09 down; we ceil).
    assert chips_required(cm, *table["pubmed"], mode="cell") in (3, 4)
    # 30 MB chip (§IV-B3).
    assert abs(cm.bytes_per_chip - 30 * 2**20) / (30 * 2**20) < 0.01


def test_chips_monotone_in_nodes():
    cm = ChipModel()
    prev = 0
    for n in [1000, 5000, 20_000, 60_000, 120_000]:
        c = chips_required(cm, n, [128, 16, 4])
        assert c >= prev
        prev = c


def test_fake_quant_level_count_and_ste():
    x = jnp.linspace(-1, 1, 1001)
    for bits in [2, 3, 4, 8]:
        q = fake_quant(x, bits)
        assert len(np.unique(np.asarray(q))) <= 2**bits
    # straight-through: gradient of sum(fake_quant(x)) is all-ones
    g = jax.grad(lambda x: fake_quant(x, 4).sum())(x)
    assert np.allclose(np.asarray(g), 1.0)
    # ≥32 bits is a no-op
    assert np.array_equal(np.asarray(fake_quant(x, 32)), np.asarray(x))


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 1000))
def test_fake_quant_error_bound(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256), jnp.float32)
    q = fake_quant(x, bits)
    amax = float(jnp.max(jnp.abs(x)))
    step = amax / (2 ** (bits - 1) - 1)
    assert float(jnp.max(jnp.abs(q - x))) <= step * 0.5 + 1e-6


def test_fake_quant_percentile_clips_small_tensor_outlier():
    """Regression (ISSUE 6 satellite 1): the nearest-rank percentile must
    still clip on SMALL tensors. The old ``int(n·(1−p/100))`` floored to 0
    for n < 1/(1−p/100) (e.g. n=100 at p=99), silently degrading to amax —
    one outlier then owned the whole calibration range."""
    x = np.zeros(100, np.float32)
    x[:99] = np.linspace(-1.0, 1.0, 99)
    x[99] = 50.0                                     # the outlier
    q99 = np.asarray(fake_quant(jnp.asarray(x), 4, percentile=99.0))
    # nearest-rank: p=99, n=100 → k = 100 − ceil(99) + 1 = 2 → scale from the
    # 2nd-largest magnitude (1.0), NOT the outlier. Code points cover [-1, 1]:
    # the quantized inliers stay tight and the outlier saturates at ≈ -qmin·step.
    step = 1.0 / 7.0
    inlier_err = np.abs(q99[:99] - x[:99]).max()
    assert inlier_err <= step * 0.5 + 1e-6
    assert q99[99] <= 8 * step + 1e-6               # clipped, nowhere near 50
    # pure-amax scale for contrast: inliers collapse onto ~1 code point
    q_amax = np.asarray(fake_quant(jnp.asarray(x), 4))
    assert np.abs(q_amax[:99] - x[:99]).max() > 10 * inlier_err


def test_fake_quant_percentile_degrades_to_amax_when_rank_saturates():
    """n=50 at p=99: ceil(0.99·50)=50 → k=1 — the percentile IS the max
    (documented nearest-rank behavior, not the old silent floor-to-zero)."""
    x = np.linspace(-1.0, 1.0, 49).astype(np.float32)
    x = np.concatenate([x, [20.0]]).astype(np.float32)
    q = np.asarray(fake_quant(jnp.asarray(x), 4, percentile=99.0))
    q_amax = np.asarray(fake_quant(jnp.asarray(x), 4))
    np.testing.assert_array_equal(q, q_amax)


def test_quantize_tree_threads_percentile():
    """quantize_tree(percentile=) must reach every leaf's calibration (it was
    silently dropped before — tree-level quantization always ran pure-amax)."""
    x = np.zeros(100, np.float32)
    x[:99] = np.linspace(-1.0, 1.0, 99)
    x[99] = 50.0
    tree = {"a": jnp.asarray(x), "n": 3}
    out = quantize_tree(tree, 4, percentile=99.0)
    ref = np.asarray(fake_quant(jnp.asarray(x), 4, percentile=99.0))
    np.testing.assert_array_equal(np.asarray(out["a"]), ref)
    assert out["n"] == 3
    out_amax = quantize_tree(tree, 4)
    assert not np.array_equal(np.asarray(out_amax["a"]), ref)


# --------------------------------------------------------- halo wire payloads
def test_payload_bits_table_and_unknown():
    assert payload_bits(None) == payload_bits("fp32") == 32
    assert payload_bits("bf16") == 16
    assert payload_bits("int8") == 8
    with pytest.raises(ValueError, match="unknown halo payload"):
        payload_bits("fp8")


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 500))
def test_payload_roundtrip_error_bounds(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((24, 8)), jnp.float32)
    w, s = quantize_payload(x, "fp32")
    assert s is None and np.array_equal(np.asarray(w), np.asarray(x))
    w, s = quantize_payload(x, "bf16")
    assert s is None and w.dtype == jnp.bfloat16
    back = np.asarray(dequantize_payload(w, s))
    # bf16: 8 mantissa bits → ≤ 2^-8 relative per element
    assert np.abs(back - np.asarray(x)).max() <= 2.0**-8 * np.abs(x).max() + 1e-7
    w, s = quantize_payload(x, "int8")
    assert w.dtype == jnp.int8 and s.shape == (1, 1)
    back = np.asarray(dequantize_payload(w, s))
    amax = float(np.abs(np.asarray(x)).max())
    assert np.abs(back - np.asarray(x)).max() <= amax / 127.0 * 0.5 + 1e-6


def test_int8_payload_multiblock_dequant_uses_per_sender_scale():
    """dequantize_payload with (n_blocks, 1) scales rescales each gathered
    export block by ITS sender's amax — mixing magnitudes across senders."""
    small = np.full((4, 3), 0.5, np.float32)
    big = np.full((4, 3), 100.0, np.float32)
    w1, s1 = quantize_payload(jnp.asarray(small), "int8")
    w2, s2 = quantize_payload(jnp.asarray(big), "int8")
    wire = jnp.concatenate([w1, w2], axis=0)
    scales = jnp.concatenate([s1, s2], axis=0)      # (2, 1)
    back = np.asarray(dequantize_payload(wire, scales))
    np.testing.assert_allclose(back[:4], small, atol=0.5 / 127 + 1e-6)
    np.testing.assert_allclose(back[4:], big, atol=100.0 / 127 + 1e-4)


def test_exchange_cost_model():
    ec = exchange_cost(1000, 64, 32, 0.0)
    assert ec.wire_bytes == 1000 * 64 * 4 and ec.exposed_bytes == ec.wire_bytes
    assert ec.compression == 1.0
    ec = exchange_cost(1000, 64, 16, 0.75)
    assert ec.wire_bytes == 1000 * 64 * 2          # bf16 halves the wire
    assert ec.exposed_bytes == pytest.approx(ec.wire_bytes * 0.25)
    assert ec.compression == 2.0
    assert exchange_cost(1000, 64, 8).compression == 4.0
    # overlap=1 → nothing exposed
    assert exchange_cost(10, 4, 32, 1.0).exposed_bytes == 0.0


@settings(max_examples=30, deadline=None)
@given(
    d_in=st.integers(1, 512),
    d_out=st.integers(1, 512),
    halo_rows=st.integers(0, 5000),
    bits=st.sampled_from([8, 16, 32]),
    ov=st.floats(0.0, 1.0),
)
def test_choose_order_argmax_invariant_under_exchange_term(d_in, d_out, halo_rows, bits, ov):
    """The exchange term moves with the same d_out-vs-d_in sign as compute,
    so adding it never flips the chooser (documented on choose_order)."""
    base = choose_order(2000, d_in, d_out, n_edges=10_000)
    with_exchange = choose_order(
        2000, d_in, d_out, n_edges=10_000,
        halo_rows=halo_rows, payload_bits=bits, overlap_fraction=ov,
    )
    assert with_exchange == base
