"""Reusable delta-vs-rebuild differential oracle (tests/test_graph_delta.py).

Everything here is pure numpy and deliberately INDEPENDENT of the delta
module's internals: plans are compared by decoding their sender encodings
back to global (src, dst, w) edge multisets, by their export SETS, and by
emulating the halo exchange + aggregation against the global reference —
so a bookkeeping bug in `repro.dist.delta` cannot cancel out in the
comparison. Slot LAYOUT inside a send table is deliberately NOT pinned:
the builder emits sorted prefixes while the delta path keeps slots stable
across mutations (freed slots become reusable holes), so the oracle checks
the set of referenced exports + that unreferenced entries are zero, not
slot order. Blocked tables are compared densified (the delta path and the
re-blocker legitimately order tiles differently within a ragged row).
"""
from __future__ import annotations

import numpy as np

from repro.dist.delta import GraphDelta
from repro.dist.halo import build_halo_plan

TOL = 1e-5


# ------------------------------------------------------------- random deltas
def random_delta(
    rng: np.random.Generator,
    n: int,
    edge_index: np.ndarray,
    *,
    max_ops: int = 10,
    p_delete: float = 0.45,
    feat_dim: int | None = None,
    w_lo: float = 0.1,
) -> GraphDelta:
    """One random mutation batch against the CURRENT edge list: deletes are
    drawn from existing edges (≥1 edge always survives), inserts are uniform
    node pairs with positive weights, and (optionally) a few feature rows
    are touched with replacement values."""
    e = int(edge_index.shape[1])
    n_ops = int(rng.integers(1, max_ops + 1))
    n_del = min(int(rng.binomial(n_ops, p_delete)), max(e - 1, 0))
    n_ins = n_ops - n_del
    del_idx = rng.choice(e, size=n_del, replace=False) if n_del else np.zeros(0, np.int64)
    ins = rng.integers(0, n, size=(2, n_ins), dtype=np.int64)
    touches = np.zeros(0, np.int64)
    values = None
    if feat_dim is not None and rng.random() < 0.5:
        touches = np.unique(rng.integers(0, n, size=int(rng.integers(1, 4))))
        values = rng.standard_normal((touches.size, feat_dim)).astype(np.float32)
    return GraphDelta(
        edge_inserts=ins,
        edge_deletes=np.asarray(edge_index[:, del_idx], np.int64),
        insert_w=(w_lo + rng.random(n_ins)).astype(np.float32),
        feature_touches=touches,
        feature_values=values,
    )


def apply_delta_to_edges(
    edge_index: np.ndarray, w: np.ndarray, delta: GraphDelta
) -> tuple[np.ndarray, np.ndarray]:
    """Numpy oracle edge-list application (multiset deletes, appended
    inserts) — the ground truth every repaired plan is compared against."""
    s = np.asarray(edge_index[0], np.int64)
    r = np.asarray(edge_index[1], np.int64)
    n = max(int(s.max(initial=0)), int(r.max(initial=0))) + 1
    keep = np.ones(s.shape[0], bool)
    want: dict[int, int] = {}
    for kk in (delta.edge_deletes[0] * n + delta.edge_deletes[1]).tolist():
        want[kk] = want.get(kk, 0) + 1
    for i, kk in enumerate((s * n + r).tolist()):
        if want.get(kk, 0) > 0:
            keep[i] = False
            want[kk] -= 1
    assert not any(want.values()), "oracle asked to delete an absent edge"
    iw = (np.ones(delta.edge_inserts.shape[1], np.float32)
          if delta.insert_w is None else delta.insert_w)
    ei = np.concatenate([edge_index[:, keep], delta.edge_inserts], axis=1)
    return ei, np.concatenate([np.asarray(w, np.float32)[keep], iw])


# ------------------------------------------------------------- plan decoding
def node_table(plan) -> np.ndarray:
    """(k, n_local) global node id per local row (padding rows are -1)."""
    nt = np.full((plan.k, max(plan.n_local, 1)), -1, np.int64)
    off = 0
    for b in range(plan.k):
        sz = int(plan.part_sizes[b])
        nt[b, :sz] = plan.perm[off:off + sz]
        off += sz
    return nt


def decode_plan_edges(plan) -> np.ndarray:
    """Decode every real edge back to global coordinates by INVERTING the
    sender encoding (flat `send_idx` slots, or the hierarchical two-tier
    member-block layout). Returns (3, E) rows [src, dst, w], lexsorted —
    a canonical multiset for equality checks."""
    nt = node_table(plan)
    out_s, out_d, out_w = [], [], []
    for b in range(plan.k):
        m = plan.edge_w[b] > 0
        s = plan.senders_l[b][m].astype(np.int64)
        dst = nt[b, plan.receivers_l[b][m].astype(np.int64)]
        src = np.full(s.shape[0], -1, np.int64)
        loc = s < plan.n_local
        src[loc] = nt[b, s[loc]]
        h = s[~loc] - plan.n_local
        if h.size:
            if plan.is_hierarchical:
                km, B = plan.k_model, plan.block_rows
                p = b // km
                mp, t = np.divmod(h, B)
                hsrc = np.full(h.shape[0], -1, np.int64)
                il = t < plan.s_loc
                if il.any():
                    dev = p * km + mp[il]
                    hsrc[il] = nt[dev, plan.send_loc[dev, t[il]]]
                if (~il).any():
                    q, tt = np.divmod(t[~il] - plan.s_loc, plan.s_rem)
                    dev = q * km + mp[~il]
                    hsrc[~il] = nt[dev, plan.send_rem[dev, tt]]
                src[~loc] = hsrc
            else:
                dev, t = np.divmod(h, plan.s_max)
                src[~loc] = nt[dev, plan.send_idx[dev, t]]
        out_s.append(src)
        out_d.append(dst)
        out_w.append(plan.edge_w[b][m])
    s = np.concatenate(out_s)
    d = np.concatenate(out_d)
    w = np.concatenate(out_w).astype(np.float64)
    order = np.lexsort((w, d, s))
    return np.stack([s[order].astype(np.float64), d[order].astype(np.float64),
                     w[order]])


def expected_exports(plan, edge_index: np.ndarray, kind: str) -> list[np.ndarray]:
    """Per-device sorted exported LOCAL rows one tier should hold, computed
    straight from the edge list: ``flat`` = all cut edges, ``loc`` =
    intra-pod cut, ``rem`` = inter-pod cut (pods read off the plan)."""
    nt = node_table(plan)
    n = plan.n_nodes
    dev_of = np.full(n, -1, np.int64)
    loc_of = np.full(n, -1, np.int64)
    for b in range(plan.k):
        rows = nt[b][nt[b] >= 0]
        dev_of[rows] = b
        loc_of[rows] = np.arange(rows.size)
    src = np.asarray(edge_index[0], np.int64)
    dst = np.asarray(edge_index[1], np.int64)
    a_s, a_d = dev_of[src], dev_of[dst]
    cut = a_s != a_d
    if kind == "flat":
        m = cut
    else:
        km = plan.k // plan.n_pods
        same_pod = (a_s // km) == (a_d // km)
        m = cut & same_pod if kind == "loc" else cut & ~same_pod
    return [np.unique(loc_of[src[m & (a_s == d)]]) for d in range(plan.k)]


def referenced_slots(plan, kind: str) -> list[np.ndarray]:
    """Per-device sorted-unique slot indices actually referenced by some
    receiver's halo encoding in `senders_l`. With stable slot assignment
    the send tables are keyed sets (holes allowed), not sorted prefixes —
    so the oracle verifies exactly the referenced entries instead of
    assuming a layout. ``kind`` is ``"flat"`` (only meaningful on flat
    plans), ``"loc"`` or ``"rem"`` (hierarchical plans)."""
    refs: list[list[np.ndarray]] = [[] for _ in range(plan.k)]
    for b in range(plan.k):
        m = plan.edge_w[b] > 0
        s = plan.senders_l[b][m].astype(np.int64)
        h = s[s >= plan.n_local] - plan.n_local
        if not h.size:
            continue
        if plan.is_hierarchical:
            km, B = plan.k_model, plan.block_rows
            p = b // km
            mp, t = np.divmod(h, B)
            il = t < plan.s_loc
            if kind == "loc":
                dev, slot = p * km + mp[il], t[il]
            else:
                q, tt = np.divmod(t[~il] - plan.s_loc, plan.s_rem)
                dev, slot = q * km + mp[~il], tt
        else:
            dev, slot = np.divmod(h, plan.s_max)
        for d in range(plan.k):
            refs[d].append(slot[dev == d])
    return [np.unique(np.concatenate(r)) if r else np.zeros(0, np.int64)
            for r in refs]


# --------------------------------------------------- numpy exchange emulation
def relocate(plan, x: np.ndarray) -> np.ndarray:
    out = np.zeros((plan.k, max(plan.n_local, 1)) + x.shape[1:], x.dtype)
    off = 0
    for b in range(plan.k):
        sz = int(plan.part_sizes[b])
        out[b, :sz] = x[plan.perm[off:off + sz]]
        off += sz
    return out


def emulate_halo_table(plan, zb: np.ndarray, b: int) -> np.ndarray:
    """Device b's ``[local ‖ halo]`` neighbor table, emulated in numpy from
    the plan's send tables (flat all-gather, or the hierarchical two-phase
    member-block layout documented on HaloPlan)."""
    if not plan.is_hierarchical:
        halo = [zb[j][plan.send_idx[j]] for j in range(plan.k)]
    else:
        km = plan.k_model
        p = b // km
        halo = []
        for mp in range(km):
            halo.append(zb[p * km + mp][plan.send_loc[p * km + mp]])
            for q in range(plan.n_pods):
                halo.append(zb[q * km + mp][plan.send_rem[q * km + mp]])
    return np.concatenate([zb[b]] + halo, axis=0)


def plan_aggregate(plan, zb: np.ndarray) -> np.ndarray:
    """w-weighted neighbor aggregation over the emulated halo tables —
    the numpy ground truth of `halo_exchange` + `halo_aggregate`."""
    out = np.zeros(zb.shape, np.float64)
    for b in range(plan.k):
        tbl = emulate_halo_table(plan, zb, b).astype(np.float64)
        m = plan.edge_w[b] > 0
        s = plan.senders_l[b][m].astype(np.int64)
        r = plan.receivers_l[b][m].astype(np.int64)
        np.add.at(out[b], r, tbl[s] * plan.edge_w[b][m].astype(np.float64)[:, None])
    return out


def global_aggregate(edge_index, w, x: np.ndarray) -> np.ndarray:
    out = np.zeros(x.shape, np.float64)
    np.add.at(out, np.asarray(edge_index[1], np.int64),
              x[np.asarray(edge_index[0], np.int64)].astype(np.float64)
              * np.asarray(w, np.float64)[:, None])
    return out


# -------------------------------------------------------------- plan asserts
def assert_plan_matches_rebuild(plan, part, edge_index, w) -> None:
    """The differential core: a delta-repaired plan must agree with a
    from-scratch `build_halo_plan` of the SAME schedule on everything except
    pad width (which may only be ≥, never <)."""
    rebuilt = build_halo_plan(
        part, edge_index, w, axes=plan.axes, pods=plan.n_pods)
    assert np.array_equal(plan.perm, rebuilt.perm)
    assert np.array_equal(plan.part_sizes, rebuilt.part_sizes)
    assert plan.n_local == rebuilt.n_local

    # pads: keep-or-grow, never shrink below what the boundary needs
    assert plan.s_max >= rebuilt.s_max, "flat pad shrank"
    if plan.is_hierarchical:
        assert plan.s_loc >= rebuilt.s_loc, "loc pad shrank"
        assert plan.s_rem >= rebuilt.s_rem, "rem pad shrank"

    # export sets: every expected export referenced through exactly one
    # slot, every unreferenced table entry zero (slot ORDER is free — the
    # builder sorts, the delta path keeps slots stable across mutations)
    if plan.is_hierarchical:
        tiers = [("loc", plan.send_loc, rebuilt.send_loc),
                 ("rem", plan.send_rem, rebuilt.send_rem)]
    else:
        tiers = [("flat", plan.send_idx, rebuilt.send_idx)]
    for kind, mine_tbl, ref_tbl in tiers:
        exp = expected_exports(plan, edge_index, kind)
        for name, p, tbl in (("delta", plan, mine_tbl),
                             ("rebuild", rebuilt, ref_tbl)):
            refd = referenced_slots(p, kind)
            for d in range(p.k):
                assert refd[d].size == exp[d].size, (
                    f"{name} {kind} device {d}: {refd[d].size} referenced "
                    f"slots for {exp[d].size} exports (duplicate or missing)")
                assert np.array_equal(np.unique(tbl[d][refd[d]]), exp[d]), (
                    f"{name} {kind} exports of device {d} diverge")
                unref = np.ones(tbl[d].size, bool)
                unref[refd[d]] = False
                assert not tbl[d][unref].any(), (
                    f"{name} {kind} unreferenced entries of device {d} "
                    "are nonzero")
    if plan.is_hierarchical:
        # hierarchical senders never reference the flat accounting table,
        # so check its nonzero entries as a set (a genuine export of local
        # row 0 is indistinguishable from a hole — strictly weaker, but the
        # flat tier gets the strong check through every flat plan)
        exp = expected_exports(plan, edge_index, "flat")
        for name, tbl in (("delta", plan.send_idx),
                          ("rebuild", rebuilt.send_idx)):
            for d in range(plan.k):
                nz = tbl[d][tbl[d] != 0]
                expnz = exp[d][exp[d] != 0]
                assert nz.size == expnz.size and np.array_equal(
                    np.unique(nz), expnz), (
                    f"{name} flat exports of device {d} diverge")

    # the decoded edge multiset: delta == rebuild == the true edge list
    true = np.stack([
        np.asarray(edge_index[0], np.float64),
        np.asarray(edge_index[1], np.float64),
        np.asarray(w, np.float64),
    ])
    true = true[:, np.lexsort((true[2], true[1], true[0]))]
    for name, p in (("delta", plan), ("rebuild", rebuilt)):
        dec = decode_plan_edges(p)
        assert dec.shape == true.shape, f"{name} plan edge count diverges"
        assert np.allclose(dec, true, atol=TOL), f"{name} plan edges diverge"

    assert np.array_equal(plan.boundary_row_mask(), rebuilt.boundary_row_mask())

    # numeric: emulated exchange + aggregation vs the global reference
    rng = np.random.default_rng(0)
    x = rng.standard_normal((plan.n_nodes, 8)).astype(np.float32)
    ref = global_aggregate(edge_index, w, x)
    for name, p in (("delta", plan), ("rebuild", rebuilt)):
        zb = relocate(p, x)
        agg = plan_aggregate(p, zb)
        got = np.zeros(x.shape, np.float64)
        off = 0
        for b in range(p.k):
            sz = int(p.part_sizes[b])
            got[p.perm[off:off + sz]] = agg[b, :sz]
            off += sz
        assert np.abs(got - ref).max() < TOL, f"{name} plan aggregation diverges"


# ----------------------------------------------------------- blocked asserts
def densify(vals, cols, lens, n_rows: int, n_cols: int) -> np.ndarray:
    """One device's ragged BSR table as a dense (n_rows, n_cols) matrix —
    the order-insensitive canonical form (the delta patcher appends/swaps
    tiles, the re-blocker sorts them; densified they must be equal)."""
    B = vals.shape[-1]
    nbr, T = cols.shape
    out = np.zeros((nbr * B, -(-n_cols // B) * B), np.float32)
    for rb in range(nbr):
        seen = set()
        for t in range(int(lens[rb])):
            cb = int(cols[rb, t])
            assert cb not in seen, f"duplicate block-col {cb} in row {rb}"
            seen.add(cb)
            out[rb * B:(rb + 1) * B, cb * B:(cb + 1) * B] += vals[rb, t]
        # contract: padding tiles are zero, padding cols repeat the last valid
        if int(lens[rb]) < T:
            assert not vals[rb, int(lens[rb]):].any(), f"nonzero padding tile row {rb}"
            expect = cols[rb, int(lens[rb]) - 1] if int(lens[rb]) else 0
            assert (cols[rb, int(lens[rb]):] == expect).all(), (
                f"repeat-last cols contract broken in row {rb}")
    return out[:n_rows, :n_cols]


def assert_blocked_matches(mine, ref) -> None:
    """Delta-patched `PlanBlockedAdjacency` vs a re-blocked one: identical
    shape metadata, identical densified matrices per device (tile ORDER in a
    ragged row may differ; T padding may only be ≥)."""
    assert mine.block == ref.block and mine.k == ref.k
    assert mine.n_rows == ref.n_rows and mine.n_cols == ref.n_cols
    assert mine.max_nnzb >= ref.max_nnzb, "patched T shrank below the rebuild"
    for b in range(mine.k):
        dm = densify(mine.vals[b], mine.cols[b], mine.lens[b],
                     mine.n_rows, mine.n_cols)
        dr = densify(ref.vals[b], ref.cols[b], ref.lens[b],
                     ref.n_rows, ref.n_cols)
        assert np.abs(dm - dr).max() < TOL, f"device {b} blocked tiles diverge"
