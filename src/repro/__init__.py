"""repro — COIN (communication-aware GCN acceleration) as a multi-pod JAX framework.

Layers:
  repro.core      — the paper's contribution: energy model, optimal-CE solver,
                    graph partitioning, NoC trace model, dataflow chooser,
                    quantization, TPU-retargeted planner.
  repro.graph     — graph substrate (segment-op message passing, BSR blocking,
                    neighbor sampling, synthetic generators).
  repro.nn        — neural-net layers (attention, MoE, norms, embeddings).
  repro.models    — model zoo (GCN + 10 assigned architectures).
  repro.kernels   — Pallas TPU kernels (+ jnp oracles).
  repro.recsys    — embedding-bag / feature-interaction substrate.
  repro.train     — optimizers, loop, checkpointing, compression, elasticity.
  repro.dist      — mesh/sharding utilities and collective helpers.
  repro.configs   — one config per assigned architecture.
  repro.launch    — production mesh, multi-pod dry-run, train/serve drivers.
"""

__version__ = "1.0.0"

# Install the jax compat shims (modern `jax.shard_map` signature and
# dict-returning `Compiled.cost_analysis` on older jax builds) as soon as any
# repro module is imported — subprocess tests and drivers use the modern
# spellings without importing repro.dist first. Touches no jax device state
# (DESIGN.md §7.4).
import repro.dist.compat as _compat  # noqa: F401  (shims install on import)

del _compat
