"""Graph containers: host-side data, padded static-shape device form, and the
128×128 blocked (BSR) adjacency that mirrors COIN's crossbar mapping.

COIN stores the adjacency in 128×128 RRAM crossbars; the TPU-native analogue
is a block-sparse matrix whose nonzero 128×128 blocks are dense MXU tiles
(DESIGN.md §2). `blocked_adjacency` produces that representation (numpy,
host-side, one-time cost), consumed by `repro.kernels.bsr_spmm`.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = ["GraphData", "PaddedGraph", "to_padded", "BlockedAdjacency", "blocked_adjacency"]


@dataclasses.dataclass
class GraphData:
    """Host-side (numpy) graph with optional features/labels/positions."""

    n_nodes: int
    edge_index: np.ndarray                  # (2, E) int32, [senders; receivers]
    edge_weight: np.ndarray | None = None   # (E,) float32
    features: np.ndarray | None = None      # (N, F) float32
    labels: np.ndarray | None = None        # (N,) int32
    positions: np.ndarray | None = None     # (N, 3) float32 (geometric models)

    @property
    def n_edges(self) -> int:
        return int(self.edge_index.shape[1])

    def with_self_loops(self) -> "GraphData":
        loops = np.arange(self.n_nodes, dtype=self.edge_index.dtype)
        ei = np.concatenate([self.edge_index, np.stack([loops, loops])], axis=1)
        ew = None
        if self.edge_weight is not None:
            ew = np.concatenate([self.edge_weight, np.ones(self.n_nodes, np.float32)])
        return dataclasses.replace(self, edge_index=ei, edge_weight=ew)

    def symmetrized(self) -> "GraphData":
        rev = self.edge_index[::-1]
        ei = np.concatenate([self.edge_index, rev], axis=1)
        ei = np.unique(ei, axis=1)
        return dataclasses.replace(self, edge_index=ei.astype(np.int32), edge_weight=None)

    def sym_normalized_weights(self) -> np.ndarray:
        """D^-1/2 Ã D^-1/2 weights (Kipf–Welling; the paper's GCN [11])."""
        s, r = self.edge_index
        deg = np.bincount(r, minlength=self.n_nodes).astype(np.float64)
        deg_s = np.bincount(s, minlength=self.n_nodes).astype(np.float64)
        inv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
        inv_s = 1.0 / np.sqrt(np.maximum(deg_s, 1.0))
        return (inv_s[s] * inv[r]).astype(np.float32)


@dataclasses.dataclass
class PaddedGraph:
    """Static-shape device form: edges padded with a ghost node (id = n_nodes).

    Ghost-targeted messages land in segment id `n_nodes` and are sliced off,
    so no mask multiply is needed in the hot loop.
    """

    senders: jnp.ndarray        # (E_pad,) int32
    receivers: jnp.ndarray      # (E_pad,) int32
    edge_weight: jnp.ndarray    # (E_pad,) float32; 0 at padding
    n_nodes: int                # static
    n_real_edges: int           # static

    @property
    def n_edges_padded(self) -> int:
        return int(self.senders.shape[0])


def to_padded(g: GraphData, pad_to: int | None = None, weights: np.ndarray | None = None) -> PaddedGraph:
    e = g.n_edges
    pad_to = pad_to or e
    assert pad_to >= e, "pad_to smaller than edge count"
    if weights is None:
        weights = g.edge_weight if g.edge_weight is not None else np.ones(e, np.float32)
    s = np.full(pad_to, g.n_nodes, np.int32)
    r = np.full(pad_to, g.n_nodes, np.int32)
    w = np.zeros(pad_to, np.float32)
    s[:e], r[:e], w[:e] = g.edge_index[0], g.edge_index[1], weights
    return PaddedGraph(
        senders=jnp.asarray(s),
        receivers=jnp.asarray(r),
        edge_weight=jnp.asarray(w),
        n_nodes=g.n_nodes,
        n_real_edges=e,
    )


@dataclasses.dataclass
class BlockedAdjacency:
    """BSR-like 128×128 blocking of A (COIN crossbar map → MXU tiles).

    Per block-row, the nonzero block-columns are padded to the max row degree
    so the Pallas kernel can scalar-prefetch a rectangular index array:

      block_vals : (n_block_rows, max_nnzb, B, B) float32 — dense tiles
      block_cols : (n_block_rows, max_nnzb) int32 — column-block ids,
                   padding repeats the last valid id with a zero tile
      row_nnzb   : (n_block_rows,) int32 — valid tiles per block-row
    """

    block_vals: np.ndarray
    block_cols: np.ndarray
    row_nnzb: np.ndarray
    n_nodes: int
    block: int

    @property
    def n_block_rows(self) -> int:
        return int(self.block_vals.shape[0])

    @property
    def n_padded(self) -> int:
        return self.n_block_rows * self.block

    @property
    def density(self) -> float:
        """Fraction of 128×128 blocks that are materialized (incl. padding)."""
        grid = self.n_block_rows * (self.n_padded // self.block)
        return float(self.block_vals.shape[0] * self.block_vals.shape[1]) / max(grid, 1)


def blocked_adjacency(
    n_nodes: int,
    edge_index: np.ndarray,
    edge_weight: np.ndarray | None = None,
    block: int = 128,
) -> BlockedAdjacency:
    """Build the 128×128 blocked adjacency (numpy, one-time host cost).

    A[r, c] = w for each edge (sender=c, receiver=r): aggregation computes
    O = A·Z, rows = receivers.
    """
    s = np.asarray(edge_index[0], dtype=np.int64)
    r = np.asarray(edge_index[1], dtype=np.int64)
    w = (
        np.ones(s.shape[0], np.float32)
        if edge_weight is None
        else np.asarray(edge_weight, np.float32)
    )
    nbr = -(-n_nodes // block)  # ceil
    br, bc = r // block, s // block
    # Unique nonzero blocks, then scatter edges into dense tiles.
    key = br * nbr + bc
    uniq, inv = np.unique(key, return_inverse=True)
    n_blocks = uniq.shape[0]
    vals = np.zeros((n_blocks, block, block), np.float32)
    np.add.at(vals, (inv, r % block, s % block), w)
    ubr, ubc = uniq // nbr, uniq % nbr
    # Group blocks by block-row, pad to max row nnzb.
    row_nnzb = np.bincount(ubr, minlength=nbr).astype(np.int32)
    max_nnzb = max(int(row_nnzb.max(initial=1)), 1)
    block_vals = np.zeros((nbr, max_nnzb, block, block), np.float32)
    block_cols = np.zeros((nbr, max_nnzb), np.int32)
    order = np.argsort(ubr, kind="stable")
    pos = np.zeros(nbr, np.int64)
    for idx in order:
        rr = ubr[idx]
        block_vals[rr, pos[rr]] = vals[idx]
        block_cols[rr, pos[rr]] = ubc[idx]
        pos[rr] += 1
    # Pad columns repeat the last valid id (zero tiles → harmless matmuls).
    for rr in range(nbr):
        if 0 < pos[rr] < max_nnzb:
            block_cols[rr, pos[rr]:] = block_cols[rr, pos[rr] - 1]
    return BlockedAdjacency(
        block_vals=block_vals,
        block_cols=block_cols,
        row_nnzb=row_nnzb,
        n_nodes=n_nodes,
        block=block,
    )
