"""Graph containers: host-side data, padded static-shape device form, and the
128×128 blocked (BSR) adjacency that mirrors COIN's crossbar mapping.

COIN stores the adjacency in 128×128 RRAM crossbars; the TPU-native analogue
is a block-sparse matrix whose nonzero 128×128 blocks are dense MXU tiles
(DESIGN.md §2, docs/kernels.md). `blocked_adjacency` produces that
representation (numpy, host-side, one-time cost), consumed by
`repro.kernels.bsr_spmm` and `repro.kernels.fused_gcn`. The layout is
**ragged**: the rectangular `(R, T)` tile tables are padded to the max
block-row degree T, but `row_nnzb` records each block-row's true tile count
so the kernel can skip the padding (power-law hub rows stop taxing every
other row). `locality_block_order` computes the COIN CE-mapping / I-GCN
islandization node permutation that densifies blocks before blocking.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = [
    "GraphData",
    "PaddedGraph",
    "to_padded",
    "BlockedAdjacency",
    "blocked_adjacency",
    "blocked_stats",
    "locality_block_order",
    "permute_edge_index",
    "relocate_rows",
    "restore_rows",
]


@dataclasses.dataclass
class GraphData:
    """Host-side (numpy) graph with optional features/labels/positions."""

    n_nodes: int
    edge_index: np.ndarray                  # (2, E) int32, [senders; receivers]
    edge_weight: np.ndarray | None = None   # (E,) float32
    features: np.ndarray | None = None      # (N, F) float32
    labels: np.ndarray | None = None        # (N,) int32
    positions: np.ndarray | None = None     # (N, 3) float32 (geometric models)

    @property
    def n_edges(self) -> int:
        return int(self.edge_index.shape[1])

    def with_self_loops(self) -> "GraphData":
        loops = np.arange(self.n_nodes, dtype=self.edge_index.dtype)
        ei = np.concatenate([self.edge_index, np.stack([loops, loops])], axis=1)
        ew = None
        if self.edge_weight is not None:
            ew = np.concatenate([self.edge_weight, np.ones(self.n_nodes, np.float32)])
        return dataclasses.replace(self, edge_index=ei, edge_weight=ew)

    def symmetrized(self) -> "GraphData":
        rev = self.edge_index[::-1]
        ei = np.concatenate([self.edge_index, rev], axis=1)
        ei = np.unique(ei, axis=1)
        return dataclasses.replace(self, edge_index=ei.astype(np.int32), edge_weight=None)

    def sym_normalized_weights(self) -> np.ndarray:
        """D^-1/2 Ã D^-1/2 weights (Kipf–Welling; the paper's GCN [11])."""
        s, r = self.edge_index
        deg = np.bincount(r, minlength=self.n_nodes).astype(np.float64)
        deg_s = np.bincount(s, minlength=self.n_nodes).astype(np.float64)
        inv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
        inv_s = 1.0 / np.sqrt(np.maximum(deg_s, 1.0))
        return (inv_s[s] * inv[r]).astype(np.float32)


@dataclasses.dataclass
class PaddedGraph:
    """Static-shape device form: edges padded with a ghost node (id = n_nodes).

    Ghost-targeted messages land in segment id `n_nodes` and are sliced off,
    so no mask multiply is needed in the hot loop.
    """

    senders: jnp.ndarray        # (E_pad,) int32
    receivers: jnp.ndarray      # (E_pad,) int32
    edge_weight: jnp.ndarray    # (E_pad,) float32; 0 at padding
    n_nodes: int                # static
    n_real_edges: int           # static

    @property
    def n_edges_padded(self) -> int:
        return int(self.senders.shape[0])


def to_padded(g: GraphData, pad_to: int | None = None, weights: np.ndarray | None = None) -> PaddedGraph:
    e = g.n_edges
    pad_to = pad_to or e
    assert pad_to >= e, "pad_to smaller than edge count"
    if weights is None:
        weights = g.edge_weight if g.edge_weight is not None else np.ones(e, np.float32)
    s = np.full(pad_to, g.n_nodes, np.int32)
    r = np.full(pad_to, g.n_nodes, np.int32)
    w = np.zeros(pad_to, np.float32)
    s[:e], r[:e], w[:e] = g.edge_index[0], g.edge_index[1], weights
    return PaddedGraph(
        senders=jnp.asarray(s),
        receivers=jnp.asarray(r),
        edge_weight=jnp.asarray(w),
        n_nodes=g.n_nodes,
        n_real_edges=e,
    )


@dataclasses.dataclass
class BlockedAdjacency:
    """Ragged BSR-like 128×128 blocking of A (COIN crossbar map → MXU tiles).

    Per block-row, the nonzero block-columns are padded to the max row degree
    so the Pallas kernel can scalar-prefetch a rectangular index array — but
    the true per-row tile count rides along as ``row_nnzb`` (the ragged
    lengths), so `repro.kernels.bsr_spmm` skips the padding tiles entirely
    instead of multiplying zeros:

      block_vals : (n_block_rows, max_nnzb, B, B) float32 — dense tiles
      block_cols : (n_block_rows, max_nnzb) int32 — column-block ids,
                   padding repeats the last valid id with a zero tile
      row_nnzb   : (n_block_rows,) int32 — valid tiles per block-row
                   (the scalar-prefetched ragged lengths; ≤ max_nnzb)

    The matrix may be **rectangular**: rows span ``n_nodes`` receiver nodes
    and columns span ``n_col_nodes`` sender rows (== n_nodes for the global
    square adjacency; == n_local + halo rows for the per-shard halo-path
    blocking of `repro.dist.halo.plan_blocked_adjacency`).
    """

    block_vals: np.ndarray
    block_cols: np.ndarray
    row_nnzb: np.ndarray
    n_nodes: int
    block: int
    n_col_nodes: int = 0              # 0 (legacy) ⇒ square: == n_nodes

    def __post_init__(self):
        if not self.n_col_nodes:
            self.n_col_nodes = self.n_nodes

    @property
    def n_block_rows(self) -> int:
        return int(self.block_vals.shape[0])

    @property
    def n_block_cols(self) -> int:
        return -(-self.n_col_nodes // self.block)

    @property
    def max_nnzb(self) -> int:
        """The rectangular tile-table width T (global max block-row degree)."""
        return int(self.block_vals.shape[1])

    @property
    def n_padded(self) -> int:
        """Row count of the kernel output (block-row grid × B)."""
        return self.n_block_rows * self.block

    @property
    def n_col_padded(self) -> int:
        """Row count the dense feature operand must be padded to."""
        return self.n_block_cols * self.block

    @property
    def nnz_blocks(self) -> int:
        """Total nonzero (materialized, non-padding) 128×128 tiles."""
        return int(self.row_nnzb.sum())

    @property
    def padded_tile_fraction(self) -> float:
        """Fraction of the rectangular (R, T) tile table that is padding —
        the work a dense-T kernel wastes and the ragged kernel skips."""
        grid = self.n_block_rows * self.max_nnzb
        return 1.0 - self.nnz_blocks / max(grid, 1)

    @property
    def density(self) -> float:
        """Fraction of the full R×C block grid that is materialized."""
        grid = self.n_block_rows * self.n_block_cols
        return float(self.nnz_blocks) / max(grid, 1)

    def arrays(self) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """(vals, cols, lens) as device arrays — the kernel operand triple."""
        return (
            jnp.asarray(self.block_vals),
            jnp.asarray(self.block_cols),
            jnp.asarray(self.row_nnzb),
        )


def blocked_adjacency(
    n_nodes: int,
    edge_index: np.ndarray,
    edge_weight: np.ndarray | None = None,
    block: int = 128,
    n_col_nodes: int | None = None,
) -> BlockedAdjacency:
    """Build the ragged 128×128 blocked adjacency (numpy, one-time host cost).

    A[r, c] = w for each edge (sender=c, receiver=r): aggregation computes
    O = A·Z, rows = receivers. ``n_col_nodes`` widens the column space past
    ``n_nodes`` for rectangular matrices (halo path: senders index the
    ``[local ‖ halo]`` table, receivers the local block); senders must be
    ``< n_col_nodes`` and receivers ``< n_nodes``.
    """
    s = np.asarray(edge_index[0], dtype=np.int64)
    r = np.asarray(edge_index[1], dtype=np.int64)
    w = (
        np.ones(s.shape[0], np.float32)
        if edge_weight is None
        else np.asarray(edge_weight, np.float32)
    )
    n_cols = n_nodes if n_col_nodes is None else int(n_col_nodes)
    nbr = -(-n_nodes // block)   # ceil: receiver block-rows
    nbc = -(-n_cols // block)    # ceil: sender block-cols
    br, bc = r // block, s // block
    # Unique nonzero blocks, then scatter edges into dense tiles.
    key = br * nbc + bc
    uniq, inv = np.unique(key, return_inverse=True)
    n_blocks = uniq.shape[0]
    vals = np.zeros((n_blocks, block, block), np.float32)
    np.add.at(vals, (inv, r % block, s % block), w)
    ubr, ubc = uniq // nbc, uniq % nbc
    # Group blocks by block-row, pad to max row nnzb (the ragged lengths).
    row_nnzb = np.bincount(ubr, minlength=nbr).astype(np.int32)
    max_nnzb = max(int(row_nnzb.max(initial=1)), 1)
    block_vals = np.zeros((nbr, max_nnzb, block, block), np.float32)
    block_cols = np.zeros((nbr, max_nnzb), np.int32)
    order = np.argsort(ubr, kind="stable")
    pos = np.zeros(nbr, np.int64)
    for idx in order:
        rr = ubr[idx]
        block_vals[rr, pos[rr]] = vals[idx]
        block_cols[rr, pos[rr]] = ubc[idx]
        pos[rr] += 1
    # Pad columns repeat the last valid id (zero tiles; the ragged kernel
    # never touches them, the dense-T ref multiplies harmless zeros).
    for rr in range(nbr):
        if 0 < pos[rr] < max_nnzb:
            block_cols[rr, pos[rr]:] = block_cols[rr, pos[rr] - 1]
    return BlockedAdjacency(
        block_vals=block_vals,
        block_cols=block_cols,
        row_nnzb=row_nnzb,
        n_nodes=n_nodes,
        block=block,
        n_col_nodes=n_cols,
    )


def blocked_stats(
    n_nodes: int,
    edge_index: np.ndarray,
    block: int = 128,
    n_col_nodes: int | None = None,
) -> dict:
    """Blocked-layout statistics WITHOUT materializing any (B, B) tile.

    O(E) integer work — usable at ogbn-products scale where the dense tiles
    of :func:`blocked_adjacency` would not fit. Returns the layout record
    the benchmarks and the dry-run report: ``n_block_rows`` (R),
    ``max_nnzb`` (T, the dense-T pad), ``nnz_blocks`` (tiles the ragged
    kernel executes), ``dense_tiles`` (R·T, tiles a dense-T kernel
    executes), and ``padded_tile_fraction`` (the dense-T waste the ragged
    lengths skip).
    """
    s = np.asarray(edge_index[0], dtype=np.int64)
    r = np.asarray(edge_index[1], dtype=np.int64)
    n_cols = n_nodes if n_col_nodes is None else int(n_col_nodes)
    nbr = -(-n_nodes // block)
    nbc = -(-n_cols // block)
    uniq = np.unique((r // block) * nbc + (s // block))
    row_nnzb = np.bincount(uniq // nbc, minlength=nbr)
    T = max(int(row_nnzb.max(initial=1)), 1)
    nnz = int(row_nnzb.sum())
    return {
        "block": block,
        "n_block_rows": nbr,
        "n_block_cols": nbc,
        "max_nnzb": T,
        "nnz_blocks": nnz,
        "dense_tiles": nbr * T,
        "padded_tile_fraction": 1.0 - nnz / max(nbr * T, 1),
    }


# ======================================================= locality reordering
def locality_block_order(
    n_nodes: int,
    edge_index: np.ndarray,
    block: int = 128,
    method: str = "bfs",
    seed: int = 0,
    refine: bool = True,
) -> np.ndarray:
    """COIN CE-mapping / I-GCN islandization permutation for dense blocking.

    Returns ``perm`` (new position → original node id) — a node order under
    which a community's edges land in few 128×128 tiles instead of smearing
    across the whole block grid. Apply it with :func:`relocate_rows` /
    :func:`permute_edge_index` before :func:`blocked_adjacency` and undo
    outputs with :func:`restore_rows` (round-trip pinned by the hypothesis
    test in `tests/test_kernels.py`).

    method="bfs" (default) — `repro.core.partition.bfs_traversal_order`:
    parent-ordered BFS islandization. On shuffled planted-partition graphs
    it cuts nonzero tiles 3–6× (measured at or beyond the planted ordering
    itself — children pack under their discoverer).
    method="partition" — `repro.core.partition.partition_graph` into
    ``ceil(n_nodes / block)`` parts (BFS region growing, optional ``refine``
    boundary passes, balance-capped — COIN's balanced CE map) laid out
    contiguously; weaker blocks than the traversal but exactly the
    partitioner the halo layer uses.

    On graphs whose node ids are arbitrary (every real-world dataset), both
    measurably cut ``nnz_blocks`` and the dense-T executed-tile count — the
    numbers `benchmarks/kernel_bench.py` records in BENCH_kernels.json.
    """
    from repro.core.partition import bfs_traversal_order, partition_graph

    if n_nodes <= block:
        return np.arange(n_nodes, dtype=np.int64)
    if method == "bfs":
        return bfs_traversal_order(n_nodes, edge_index[0], edge_index[1])
    if method != "partition":
        raise ValueError(f"unknown locality method: {method!r} (bfs | partition)")
    k = -(-n_nodes // block)
    part = partition_graph(n_nodes, edge_index, k, method="bfs", seed=seed, refine=refine)
    return np.argsort(part.assignment, kind="stable").astype(np.int64)


def permute_edge_index(perm: np.ndarray, edge_index: np.ndarray) -> np.ndarray:
    """Rewrite edge endpoints into the permuted node order (perm: new → old)."""
    inv = np.empty(perm.shape[0], np.int64)
    inv[perm] = np.arange(perm.shape[0], dtype=np.int64)
    return inv[np.asarray(edge_index, dtype=np.int64)].astype(np.int32)


def relocate_rows(perm: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Gather per-node rows into the permuted order (row i ← old row perm[i])."""
    return np.asarray(x)[perm]


def restore_rows(perm: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Inverse of :func:`relocate_rows`: scatter permuted rows back to the
    original node order (accepts trailing feature axes)."""
    x = np.asarray(x)
    out = np.empty_like(x)
    out[perm] = x
    return out
