"""Graph substrate: message passing, blocking, sampling, synthetic datasets.

JAX has no native sparse message-passing (BCOO only), so this package IS part
of the system: edge-index scatter/gather aggregation via segment ops, padded
static-shape graph containers, a 128×128 BSR blocker feeding the Pallas SpMM
kernel, a CSR fanout neighbor sampler, and deterministic synthetic graph
generators matching the paper's Table I statistics.
"""

from repro.graph.structure import (
    GraphData,
    PaddedGraph,
    to_padded,
    blocked_adjacency,
    BlockedAdjacency,
    locality_block_order,
    permute_edge_index,
    relocate_rows,
    restore_rows,
)
from repro.graph.ops import (
    aggregate,
    segment_softmax,
    sym_norm_edge_weights,
    degrees,
)
from repro.graph.generators import (
    TABLE_I,
    GNN_SHAPES,
    citation_like,
    random_graph,
    molecule_batch,
    make_dataset,
)
from repro.graph.sampler import NeighborSampler, SampledBlock

__all__ = [
    "GraphData",
    "PaddedGraph",
    "to_padded",
    "blocked_adjacency",
    "BlockedAdjacency",
    "locality_block_order",
    "permute_edge_index",
    "relocate_rows",
    "restore_rows",
    "aggregate",
    "segment_softmax",
    "sym_norm_edge_weights",
    "degrees",
    "TABLE_I",
    "GNN_SHAPES",
    "citation_like",
    "random_graph",
    "molecule_batch",
    "make_dataset",
    "NeighborSampler",
    "SampledBlock",
]
