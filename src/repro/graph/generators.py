"""Deterministic synthetic graph generation (DESIGN.md §5).

No network access → we synthesize graphs with the EXACT node/edge/feature/
label counts of the paper's Table I (plus the assigned GNN input-shape cells)
so every analytic result that depends only on shapes — energy model,
optimal-k, mesh sweep, dataflow FLOPs, chip count, NoC traces, rooflines —
is computed on the true published sizes. Structure is homophilous
planted-partition with power-law-ish degrees (citation-network-like), fully
seeded.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structure import GraphData

__all__ = [
    "DatasetSpec",
    "TABLE_I",
    "GNN_SHAPES",
    "citation_like",
    "random_graph",
    "molecule_batch",
    "make_dataset",
]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_nodes: int
    n_edges: int
    n_features: int
    n_labels: int
    n_layers: int = 2
    hidden: int = 16  # Kipf–Welling default, used in the paper's Nell example


# Paper Table I, verbatim.
TABLE_I: dict[str, DatasetSpec] = {
    "cora": DatasetSpec("cora", 2708, 10556, 1433, 7),
    "citeseer": DatasetSpec("citeseer", 3327, 9228, 3703, 6),
    "pubmed": DatasetSpec("pubmed", 19717, 88651, 500, 3),
    "extcora": DatasetSpec("extcora", 19793, 130622, 8710, 70),
    "nell": DatasetSpec("nell", 65755, 266144, 5414, 210),
}

# Assigned GNN input-shape cells (the 4 shapes every GNN arch must run).
GNN_SHAPES: dict[str, dict] = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433, kind="full-batch"),
    "minibatch_lg": dict(
        n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024, fanout=(15, 10), kind="sampled-training"
    ),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, kind="full-batch-large"),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, kind="batched-small-graphs"),
}


def _powerlaw_degrees(n: int, total_edges: int, alpha: float, rng: np.random.Generator) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    w /= w.sum()
    deg = rng.multinomial(total_edges, w)
    return rng.permutation(deg)


def citation_like(
    n_nodes: int,
    n_edges: int,
    n_features: int | None = None,
    n_labels: int = 7,
    homophily: float = 0.8,
    alpha: float = 1.6,
    feature_nnz: int = 32,
    seed: int = 0,
    feature_dtype=np.float32,
    with_positions: bool = False,
) -> GraphData:
    """Homophilous power-law graph with bag-of-words-ish features.

    Labels are contiguous blocks (so block/BFS partitions align with the
    community structure, matching how citation datasets cluster). Directed
    edge count equals ``n_edges`` exactly; ghost-free.
    """
    rng = np.random.default_rng(seed)
    labels = (np.arange(n_nodes, dtype=np.int64) * n_labels // n_nodes).astype(np.int32)
    # Label block boundaries for homophilous destination sampling.
    block_lo = np.searchsorted(labels, np.arange(n_labels))
    block_hi = np.searchsorted(labels, np.arange(n_labels), side="right")
    src_deg = _powerlaw_degrees(n_nodes, n_edges, alpha, rng)
    src = np.repeat(np.arange(n_nodes, dtype=np.int64), src_deg)
    same = rng.random(n_edges) < homophily
    lbl = labels[src]
    lo, hi = block_lo[lbl], block_hi[lbl]
    dst_same = lo + (rng.random(n_edges) * (hi - lo)).astype(np.int64)
    dst_rand = rng.integers(0, n_nodes, size=n_edges)
    dst = np.where(same, dst_same, dst_rand)
    # Avoid trivial self loops (model layers add their own).
    self_loop = dst == src
    dst[self_loop] = (dst[self_loop] + 1) % n_nodes
    edge_index = np.stack([src, dst]).astype(np.int32)
    features = None
    if n_features is not None:
        features = _bow_features(n_nodes, n_features, feature_nnz, labels, rng, feature_dtype)
    positions = rng.standard_normal((n_nodes, 3)).astype(np.float32) if with_positions else None
    return GraphData(
        n_nodes=n_nodes,
        edge_index=edge_index,
        features=features,
        labels=labels,
        positions=positions,
    )


def _bow_features(
    n_nodes: int, n_features: int, nnz: int, labels: np.ndarray, rng: np.random.Generator, dtype
) -> np.ndarray:
    """Sparse binary features with a label-correlated slice, so a GCN can
    actually learn the labels (needed for the Fig. 7 accuracy trend)."""
    x = np.zeros((n_nodes, n_features), dtype=dtype)
    cols = rng.integers(0, n_features, size=(n_nodes, nnz))
    np.put_along_axis(x, cols, 1.0, axis=1)
    n_labels = int(labels.max()) + 1
    sig = min(8, max(1, n_features // max(n_labels, 1) // 4))
    for c in range(n_labels):
        idx = np.flatnonzero(labels == c)
        lo = (c * sig) % max(n_features - sig, 1)
        mask = rng.random((idx.shape[0], sig)) < 0.75
        x[idx[:, None], np.arange(lo, lo + sig)[None, :]] += mask.astype(dtype)
    return x


def random_graph(n_nodes: int, n_edges: int, seed: int = 0) -> GraphData:
    """Uniform random directed graph (structure-only paths: NoC traces etc.)."""
    rng = np.random.default_rng(seed)
    edge_index = rng.integers(0, n_nodes, size=(2, n_edges)).astype(np.int32)
    return GraphData(n_nodes=n_nodes, edge_index=edge_index)


def molecule_batch(
    n_graphs: int = 128,
    nodes_per_graph: int = 30,
    edges_per_graph: int = 64,
    d_feat: int = 16,
    seed: int = 0,
) -> GraphData:
    """Batched small graphs (assigned `molecule` cell) packed into one big
    disconnected graph with 3-D positions — the standard batching for
    EGNN/Equiformer-style models."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per_graph
    offs = np.repeat(np.arange(n_graphs) * nodes_per_graph, edges_per_graph)
    src = rng.integers(0, nodes_per_graph, size=n_graphs * edges_per_graph) + offs
    dst = rng.integers(0, nodes_per_graph, size=n_graphs * edges_per_graph) + offs
    loops = src == dst
    dst[loops] = offs[loops] + (dst[loops] - offs[loops] + 1) % nodes_per_graph
    return GraphData(
        n_nodes=n,
        edge_index=np.stack([src, dst]).astype(np.int32),
        features=rng.standard_normal((n, d_feat)).astype(np.float32),
        positions=rng.standard_normal((n, 3)).astype(np.float32),
        labels=np.zeros(n, np.int32),
    )


def make_dataset(name: str, seed: int = 0, reduced: bool = False) -> tuple[DatasetSpec, GraphData]:
    """Materialize a Table-I dataset (or a `reduced` 1/8-scale version for
    smoke tests). Feature matrices above ~200 MB are emitted as float16."""
    spec = TABLE_I[name]
    if reduced:
        spec = DatasetSpec(
            spec.name + "-reduced",
            max(spec.n_nodes // 8, 64),
            max(spec.n_edges // 8, 256),
            min(spec.n_features, 64),
            min(spec.n_labels, 7),
            hidden=spec.hidden,
        )
    fbytes = spec.n_nodes * spec.n_features * 4
    dtype = np.float16 if fbytes > 200e6 else np.float32
    g = citation_like(
        spec.n_nodes,
        spec.n_edges,
        spec.n_features,
        spec.n_labels,
        seed=seed,
        feature_dtype=dtype,
    )
    return spec, g
