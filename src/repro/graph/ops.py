"""Message-passing primitives over edge indices (jax.ops.segment_* based).

This is the system's SpMM layer: aggregation `O = A·Z` expressed as
gather(senders) → weight → segment-reduce(receivers). All functions take a
static ``num_segments`` so they stay shard_map/pjit-friendly. Ghost-padded
edges (receiver == n_nodes) accumulate into an extra row that callers slice
off (see PaddedGraph).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "aggregate",
    "aggregate_padded",
    "segment_softmax",
    "sym_norm_edge_weights",
    "degrees",
    "multi_aggregate",
]


def degrees(receivers: jnp.ndarray, num_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(
        jnp.ones_like(receivers, dtype=jnp.float32), receivers, num_segments=num_nodes
    )


def sym_norm_edge_weights(
    senders: jnp.ndarray, receivers: jnp.ndarray, num_nodes: int
) -> jnp.ndarray:
    """D^-1/2 Ã D^-1/2 edge weights (Kipf–Welling normalization), in-graph."""
    deg_r = degrees(receivers, num_nodes)
    deg_s = degrees(senders, num_nodes)
    inv_r = jax.lax.rsqrt(jnp.maximum(deg_r, 1.0))
    inv_s = jax.lax.rsqrt(jnp.maximum(deg_s, 1.0))
    return inv_s[senders] * inv_r[receivers]


def aggregate(
    features: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    num_nodes: int,
    edge_weight: jnp.ndarray | None = None,
    reduce: str = "sum",
) -> jnp.ndarray:
    """O[r] = reduce_{(s,r) ∈ E} w_sr · Z[s] — the GCN aggregation stage."""
    msgs = features[senders]
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    if reduce == "sum":
        return jax.ops.segment_sum(msgs, receivers, num_segments=num_nodes)
    if reduce == "mean":
        total = jax.ops.segment_sum(msgs, receivers, num_segments=num_nodes)
        cnt = degrees(receivers, num_nodes)
        return total / jnp.maximum(cnt, 1.0)[:, None]
    if reduce == "max":
        return jax.ops.segment_max(msgs, receivers, num_segments=num_nodes)
    if reduce == "min":
        return jax.ops.segment_min(msgs, receivers, num_segments=num_nodes)
    raise ValueError(f"unknown reduce: {reduce!r}")


def aggregate_padded(
    features: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    num_nodes: int,
    edge_weight: jnp.ndarray | None = None,
    reduce: str = "sum",
) -> jnp.ndarray:
    """Aggregation when edges are ghost-padded: features has a zero ghost row
    appended, the segment space is num_nodes+1, and the ghost row is dropped."""
    feats = jnp.concatenate([features, jnp.zeros_like(features[:1])], axis=0)
    out = aggregate(feats, senders, receivers, num_nodes + 1, edge_weight, reduce)
    return out[:num_nodes]


def multi_aggregate(
    features: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    num_nodes: int,
    edge_weight: jnp.ndarray | None = None,
) -> dict[str, jnp.ndarray]:
    """PNA-style parallel aggregators computed off shared messages:
    mean / max / min / std (std via E[x²]−E[x]² on the same segments)."""
    msgs = features[senders]
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    ssum = jax.ops.segment_sum(msgs, receivers, num_segments=num_nodes)
    sqsum = jax.ops.segment_sum(msgs * msgs, receivers, num_segments=num_nodes)
    cnt = jnp.maximum(degrees(receivers, num_nodes), 1.0)[:, None]
    mean = ssum / cnt
    var = jnp.maximum(sqsum / cnt - mean * mean, 0.0)
    smax = jax.ops.segment_max(msgs, receivers, num_segments=num_nodes)
    smin = jax.ops.segment_min(msgs, receivers, num_segments=num_nodes)
    # Empty segments: segment_max/min give -inf/+inf; zero them.
    finite = jnp.isfinite(smax)
    smax = jnp.where(finite, smax, 0.0)
    smin = jnp.where(jnp.isfinite(smin), smin, 0.0)
    return {"mean": mean, "max": smax, "min": smin, "std": jnp.sqrt(var + 1e-8)}


def multi_aggregate_edges(
    messages: jnp.ndarray,
    receivers: jnp.ndarray,
    num_nodes: int,
    edge_mask: jnp.ndarray | None = None,
) -> dict[str, jnp.ndarray]:
    """PNA aggregators over per-edge messages (already gathered/transformed).

    edge_mask: optional (E,) 0/1 validity — masked edges are excluded from
    every statistic (count, mean, std, max, min). Used by the halo comm path,
    whose plan pads edge lists with weight-0 edges (DESIGN.md §8).
    """
    if edge_mask is None:
        msum = messages
        cnt = jnp.maximum(degrees(receivers, num_nodes), 1.0)[:, None]
        mmax = mmin = messages
    else:
        m = edge_mask[:, None]
        msum = messages * m
        cnt = jnp.maximum(
            jax.ops.segment_sum(edge_mask, receivers, num_segments=num_nodes), 1.0
        )[:, None]
        mmax = jnp.where(m > 0, messages, -jnp.inf)
        mmin = jnp.where(m > 0, messages, jnp.inf)
    ssum = jax.ops.segment_sum(msum, receivers, num_segments=num_nodes)
    sqsum = jax.ops.segment_sum(msum * messages, receivers, num_segments=num_nodes)
    mean = ssum / cnt
    var = jnp.maximum(sqsum / cnt - mean * mean, 0.0)
    smax = jax.ops.segment_max(mmax, receivers, num_segments=num_nodes)
    smin = jax.ops.segment_min(mmin, receivers, num_segments=num_nodes)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    smin = jnp.where(jnp.isfinite(smin), smin, 0.0)
    return {"mean": mean, "max": smax, "min": smin, "std": jnp.sqrt(var + 1e-8)}


@partial(jax.jit, static_argnames=("num_nodes",))
def segment_softmax(
    logits: jnp.ndarray, receivers: jnp.ndarray, num_nodes: int
) -> jnp.ndarray:
    """Numerically-stable per-destination softmax over incoming edges (GAT)."""
    seg_max = jax.ops.segment_max(logits, receivers, num_segments=num_nodes)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[receivers]
    expd = jnp.exp(shifted)
    denom = jax.ops.segment_sum(expd, receivers, num_segments=num_nodes)
    return expd / jnp.maximum(denom[receivers], 1e-16)
