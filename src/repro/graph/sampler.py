"""CSR fanout neighbor sampler (the `minibatch_lg` cell's real sampler).

GraphSAGE-style layered uniform sampling: given seed nodes, sample up to
``fanout[0]`` in-neighbors per seed, then ``fanout[1]`` per frontier node,
etc. Output is a :class:`SampledBlock` with *static* shapes (padded with a
ghost node) so the jitted train step never recompiles.

Implementation notes (this IS part of the system, per the assignment):
  * host-side numpy against an int64 CSR; vectorized over the frontier,
  * sampling WITH replacement (standard for uniform fanout samplers; avoids
    per-node rejection loops and keeps shapes static),
  * node relabeling via np.unique over the union of layers; seeds first.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structure import GraphData

__all__ = ["NeighborSampler", "SampledBlock"]


@dataclasses.dataclass
class SampledBlock:
    """One sampled computation block (all layers merged into one subgraph)."""

    node_ids: np.ndarray       # (max_nodes,) original ids, ghost-padded
    senders: np.ndarray        # (max_edges,) local ids into node_ids
    receivers: np.ndarray      # (max_edges,) local ids
    n_seeds: int
    n_nodes: int               # valid prefix of node_ids
    n_edges: int               # valid prefix of senders/receivers
    max_nodes: int
    max_edges: int

    @property
    def edge_mask(self) -> np.ndarray:
        m = np.zeros(self.max_edges, bool)
        m[: self.n_edges] = True
        return m


class NeighborSampler:
    def __init__(self, graph: GraphData, fanout: tuple[int, ...] = (15, 10), seed: int = 0):
        self.fanout = tuple(fanout)
        self.n_nodes = graph.n_nodes
        s, r = graph.edge_index[0].astype(np.int64), graph.edge_index[1].astype(np.int64)
        # In-neighbor CSR: for each receiver, the list of senders.
        order = np.argsort(r, kind="stable")
        self._nbr = s[order]
        self._indptr = np.zeros(graph.n_nodes + 1, np.int64)
        np.add.at(self._indptr, r + 1, 1)
        np.cumsum(self._indptr, out=self._indptr)
        self._rng = np.random.default_rng(seed)

    def max_shapes(self, batch_nodes: int) -> tuple[int, int]:
        """Static (max_nodes, max_edges) for a given seed-batch size."""
        nodes, edges = batch_nodes, 0
        frontier = batch_nodes
        for f in self.fanout:
            edges += frontier * f
            frontier *= f
            nodes += frontier
        return nodes, edges

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        seeds = np.asarray(seeds, dtype=np.int64)
        if np.unique(seeds).shape[0] != seeds.shape[0]:
            # The relabeling contract puts each seed in its own leading row;
            # duplicate seeds would leave all but one of their rows with no
            # in-edges (silent zero aggregation), so reject them outright.
            raise ValueError("NeighborSampler.sample: duplicate seed nodes")
        max_nodes, max_edges = self.max_shapes(len(seeds))
        all_src: list[np.ndarray] = []
        all_dst: list[np.ndarray] = []
        frontier = seeds
        for f in self.fanout:
            deg = self._indptr[frontier + 1] - self._indptr[frontier]
            has = deg > 0
            # Uniform with replacement among each node's in-neighbors — this
            # also covers fanout > degree (repeats instead of rejection
            # loops, keeping shapes static).
            pick = (self._rng.random((frontier.shape[0], f)) * np.maximum(deg, 1)[:, None]).astype(np.int64)
            if self._nbr.size:
                idx = self._indptr[frontier][:, None] + pick
                src = self._nbr[np.minimum(idx, self._nbr.size - 1)]
            else:
                # Edgeless graph: every node is isolated; all self-messages.
                src = np.broadcast_to(frontier[:, None], (frontier.shape[0], f)).copy()
            src = np.where(has[:, None], src, frontier[:, None])  # isolated: self-message
            dst = np.repeat(frontier, f)
            all_src.append(src.reshape(-1))
            all_dst.append(dst)
            frontier = src.reshape(-1)
        src = np.concatenate(all_src)
        dst = np.concatenate(all_dst)
        # Relabel: seeds occupy [0, n_seeds), then other touched nodes.
        uniq = np.unique(np.concatenate([seeds, src, dst]))
        rest = uniq[~np.isin(uniq, seeds, assume_unique=False)]
        node_ids_valid = np.concatenate([seeds, rest])
        lut = np.empty(self.n_nodes, np.int64)
        lut[node_ids_valid] = np.arange(node_ids_valid.shape[0])
        src_l, dst_l = lut[src], lut[dst]
        n_nodes, n_edges = node_ids_valid.shape[0], src_l.shape[0]
        node_ids = np.full(max_nodes, self.n_nodes, np.int64)  # ghost id pad
        node_ids[:n_nodes] = node_ids_valid
        senders = np.full(max_edges, max_nodes, np.int32)
        receivers = np.full(max_edges, max_nodes, np.int32)
        senders[:n_edges] = src_l
        receivers[:n_edges] = dst_l
        return SampledBlock(
            node_ids=node_ids,
            senders=senders,
            receivers=receivers,
            n_seeds=len(seeds),
            n_nodes=n_nodes,
            n_edges=n_edges,
            max_nodes=max_nodes,
            max_edges=max_edges,
        )

    def epoch(self, batch_nodes: int, n_batches: int):
        """Deterministic seed-node stream of sampled blocks."""
        for _ in range(n_batches):
            seeds = self._rng.choice(self.n_nodes, size=batch_nodes, replace=False)
            yield self.sample(seeds)
