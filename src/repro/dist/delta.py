"""Incremental halo replan for mutating graphs (docs/communication.md §7).

Every communication structure in `repro.dist.halo` is precomputed host data:
the relocation (`HaloPlan`), its export tiers, and the ragged blocked
adjacencies derived from it. Before this module, ANY graph mutation — an
edge insert, an edge delete, a feature-row touch — could only be handled by
`invalidate_halo_plans` + a from-scratch `build_halo_plan` (plus re-blocking
every tile table), which is exactly the failure mode that kills a serving
stack under a live mutating graph.

`GraphDelta` names a batch of mutations against a FIXED node set and FIXED
partition (node insertion is a re-partition event and stays a full rebuild).
`DeltaPlanner` owns the mutable edge store for one partitioned graph and
repairs every plan it has materialized, in place, per delta:

* **Export tiers** (flat ``send_idx``, hierarchical ``send_loc``/``send_rem``)
  are maintained as per-device refcounted boundary sets with STABLE slots:
  a new export takes the lowest freed slot (or appends at the high-water
  mark), and a surviving export never moves. A non-structural repair
  therefore remaps only newly-cut edges and refreshes only the *dirty*
  devices' send-table rows — O(delta), not O(boundary).
* **Pads never shrink.** If a dirty device's new boundary still fits the
  tier's pad, every other device's slots are untouched; if not, the pad
  grows geometrically (``max(needed, 2·pad)``) and that tier's sender
  encoding is rebuilt (a *structural* repair — still no re-partition).
* **Blocked adjacencies** (`plan_blocked_adjacency` and the PR 6
  interior/boundary split pair) are patched tile-wise: touched 128×128
  tiles are recomputed from the live edges and appended / tombstone-swapped
  in their ragged block row (``row_nnzb`` bump), instead of re-blocking the
  graph. Structural repairs drop the blocked cache (column space changed).
* Repaired plans move to a **versioned cache key** (``{base}@d{version}``)
  via `repro.dist.halo.register_halo_plan`, so stale keys miss and current
  keys hit without ever re-running a builder.

`apply_delta_to_graph` is the order-preserving `GraphData` counterpart
(deletes compact, inserts append) used by the serving layer: untouched CSR
rows keep their exact neighbor order, which is what makes
`repro.serve.graph.GraphBatcher.apply_graph_delta`'s scoped cache
invalidation sound. `delta_update_blocked_adjacency` applies the same
tile-patching to a standalone global `BlockedAdjacency`.

Incremental repair keeps plans CORRECT under churn, but not GOOD: the
blocked node order degrades (executed tiles creep back toward the shuffled
baseline) and pads only ever grow. Online maintenance closes that loop
(docs/communication.md §8): `RelocalizePolicy` watches the
``locality_drift`` ratio with hysteresis and triggers
:meth:`DeltaPlanner.relocalize` — an in-place re-localization that installs
a fresh BFS-derived balanced partition, rebuilds every materialized plan,
and re-keys the cache — while :meth:`DeltaPlanner.compact` shrinks pads and
tile capacities from their high-water marks back to current occupancy.

The whole module is pinned by the delta-vs-rebuild differential harness
(`tests/_delta_oracle.py` / `tests/test_graph_delta.py`): every random
mutation step asserts the repaired structures match a from-scratch rebuild.
"""
from __future__ import annotations

import dataclasses
import heapq
import time

import numpy as np

from repro.core.partition import partition_from_assignment
from repro.dist.halo import (
    HaloPlan,
    PlanLayout,
    _blocked_layout,
    graph_fingerprint,
    invalidate_halo_plans,
    plan_layout,
    register_halo_plan,
)
from repro.graph.structure import BlockedAdjacency, GraphData
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

__all__ = [
    "GraphDelta",
    "DeltaPlanner",
    "RelocalizePolicy",
    "apply_delta_to_graph",
    "delta_update_blocked_adjacency",
]


# ================================================================ GraphDelta
def _as_edge_array(a) -> np.ndarray:
    a = np.asarray(a, np.int64)
    if a.size == 0:
        return np.zeros((2, 0), np.int64)
    if a.ndim != 2 or a.shape[0] != 2:
        raise ValueError(f"edge array must be (2, E), got shape {a.shape}")
    return a


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """A batch of mutations against a fixed node set / fixed partition.

    edge_inserts    (2, Ei) int64 — directed (src, dst) edges to add.
    edge_deletes    (2, Ed) int64 — directed edges to remove; each delete
                    consumes the OLDEST matching edge instance (parallel
                    edges are multiset-counted, insertion order decides which
                    instance goes — `apply_delta_to_graph` and `DeltaPlanner`
                    agree on it); deleting an absent edge is an error.
    insert_w        (Ei,) float32 — weights of the inserts (default 1.0;
                    must be > 0, weight 0 is the padding sentinel).
    feature_touches (Tn,) int64   — node rows whose features changed.
    feature_values  (Tn, F) f32   — replacement rows (optional: a touch
                    without values still scopes cache invalidation).
    """

    edge_inserts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((2, 0), np.int64))
    edge_deletes: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((2, 0), np.int64))
    insert_w: np.ndarray | None = None
    feature_touches: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    feature_values: np.ndarray | None = None

    def __post_init__(self):
        object.__setattr__(self, "edge_inserts", _as_edge_array(self.edge_inserts))
        object.__setattr__(self, "edge_deletes", _as_edge_array(self.edge_deletes))
        object.__setattr__(
            self, "feature_touches",
            np.asarray(self.feature_touches, np.int64).ravel())
        if self.insert_w is not None:
            object.__setattr__(
                self, "insert_w", np.asarray(self.insert_w, np.float32).ravel())

    @classmethod
    def empty(cls) -> "GraphDelta":
        return cls()

    @property
    def is_empty(self) -> bool:
        return (self.edge_inserts.shape[1] == 0
                and self.edge_deletes.shape[1] == 0
                and self.feature_touches.size == 0)

    @property
    def n_ops(self) -> int:
        return (int(self.edge_inserts.shape[1])
                + int(self.edge_deletes.shape[1])
                + int(self.feature_touches.size))

    def edge_nodes(self) -> np.ndarray:
        """Distinct endpoints of every inserted/deleted edge."""
        return np.unique(np.concatenate(
            [self.edge_inserts.ravel(), self.edge_deletes.ravel()]))

    def touched_nodes(self) -> np.ndarray:
        """Edge endpoints ∪ feature-touched rows — the invalidation frontier
        seed for scoped serve-side cache drops."""
        return np.unique(np.concatenate(
            [self.edge_nodes(), self.feature_touches]))

    def validate(self, n_nodes: int, feat_dim: int | None = None) -> None:
        for name, arr in (("edge_inserts", self.edge_inserts),
                          ("edge_deletes", self.edge_deletes),
                          ("feature_touches", self.feature_touches)):
            if arr.size and (arr.min() < 0 or arr.max() >= n_nodes):
                raise ValueError(
                    f"{name} references nodes outside [0, {n_nodes}) — node "
                    "insertion/removal is a re-partition, not a GraphDelta")
        if self.insert_w is not None:
            if self.insert_w.shape[0] != self.edge_inserts.shape[1]:
                raise ValueError("insert_w length must match edge_inserts")
            if self.insert_w.size and self.insert_w.min() <= 0:
                raise ValueError(
                    "insert weights must be > 0 (weight 0 is the padding "
                    "sentinel of the relocated edge tables)")
        if self.feature_values is not None:
            fv = np.asarray(self.feature_values)
            if fv.shape[0] != self.feature_touches.size:
                raise ValueError("feature_values rows must match feature_touches")
            if feat_dim is not None and fv.shape[1] != feat_dim:
                raise ValueError(
                    f"feature_values dim {fv.shape[1]} != graph feature dim {feat_dim}")


def apply_delta_to_graph(graph: GraphData, delta: GraphDelta) -> GraphData:
    """The order-preserving `GraphData` application of a delta.

    Deletes are removed by boolean-mask COMPACTION (first matching instance
    per requested multiplicity) and inserts APPEND — so every edge not named
    by the delta keeps its relative position. The serving layer depends on
    this: `repro.serve.graph.ServeSampler`'s CSR rows of untouched receivers
    come out identical, which is what makes the scoped (frontier-walk) cache
    invalidation exact rather than heuristic.
    """
    feat_dim = None if graph.features is None else int(graph.features.shape[1])
    delta.validate(graph.n_nodes, feat_dim)
    n = graph.n_nodes
    s = graph.edge_index[0].astype(np.int64)
    r = graph.edge_index[1].astype(np.int64)
    keep = np.ones(s.shape[0], bool)
    if delta.edge_deletes.shape[1]:
        want: dict[int, int] = {}
        for kk in (delta.edge_deletes[0] * n + delta.edge_deletes[1]).tolist():
            want[kk] = want.get(kk, 0) + 1
        ekey = s * n + r
        for i in np.nonzero(np.isin(ekey, np.fromiter(want, np.int64, len(want))))[0]:
            kk = int(ekey[i])
            if want.get(kk, 0) > 0:
                keep[i] = False
                want[kk] -= 1
        missing = {kk: c for kk, c in want.items() if c}
        if missing:
            bad = [(kk // n, kk % n) for kk in list(missing)[:4]]
            raise ValueError(f"delta deletes absent edges, e.g. {bad}")
    ei = np.concatenate(
        [graph.edge_index[:, keep],
         delta.edge_inserts.astype(graph.edge_index.dtype)], axis=1)
    ew = graph.edge_weight
    ni = delta.edge_inserts.shape[1]
    if ew is not None or delta.insert_w is not None:
        base = (np.ones(s.shape[0], np.float32) if ew is None
                else np.asarray(ew, np.float32))
        iw = (np.ones(ni, np.float32) if delta.insert_w is None
              else delta.insert_w)
        ew = np.concatenate([base[keep], iw])
    feats = graph.features
    if delta.feature_touches.size and delta.feature_values is not None:
        feats = np.array(graph.features)
        feats[delta.feature_touches] = np.asarray(
            delta.feature_values, feats.dtype)
    return dataclasses.replace(
        graph, edge_index=ei, edge_weight=ew, features=feats)


# ========================================================== tile-level patch
# Shared by the standalone BlockedAdjacency path and the per-plan tables:
# recompute every TOUCHED 128×128 tile from the live edges (no incremental
# float adds — 200-step mutation runs must not accumulate drift), then
# append / overwrite / tombstone it in its ragged block row.
def _tile_updates(s, r, w, pairs, nbc: int, block: int):
    """Recompute the tiles containing any (row, col) in ``pairs``.

    ``(s, r, w)`` are the CURRENT (post-delta) edges in this table's column
    space; ``pairs`` is an (m, 2) [row, col] int array. Returns
    ``(rbs, cbs, live, tiles)`` — per touched tile its block row, block
    col, whether it still holds any edge (live=False marks a tombstone;
    its ``tiles`` row is zeros), and the recomputed dense tile. All touched
    tiles are rebuilt by ONE scatter-add: a whole-boundary sender remap
    touches thousands of tiles, and a per-tile loop here is what the bench
    gate would die on. Returns None when nothing is touched.
    """
    if pairs.shape[0] == 0:
        return None
    tkeys = np.unique((pairs[:, 0] // block) * nbc + pairs[:, 1] // block)
    key = (r // block) * nbc + (s // block)
    sel = np.isin(key, tkeys)
    ks, ss, rs, ws = key[sel], s[sel], r[sel], w[sel]
    tiles = np.zeros((tkeys.size, block, block), np.float32)
    pos = np.searchsorted(tkeys, ks)
    np.add.at(tiles, (pos, rs % block, ss % block), ws)
    live = np.zeros(tkeys.size, bool)
    live[pos] = True
    return tkeys // nbc, tkeys % nbc, live, tiles


def _find_tile(cols_row: np.ndarray, valid: int, cb: int) -> int:
    pos = np.nonzero(cols_row[:valid] == cb)[0]
    return int(pos[0]) if pos.size else -1


def _apply_tile_update(vals, cols, lens, rb: int, cb: int, tile) -> None:
    """Overwrite / append / tombstone ONE tile in block row ``rb`` of a
    per-device ragged table ((R, T, B, B) vals, (R, T) cols, (R,) lens).
    The caller has already grown T if an append could overflow. Maintains
    the repeat-last cols padding contract and zeroes freed tiles (so a
    poisoned-padding check can prove the kernel never reads them).
    """
    valid = int(lens[rb])
    p = _find_tile(cols[rb], valid, cb)
    if tile is None:                      # tombstone: swap-remove, zero slot
        if p < 0:
            return
        last = valid - 1
        vals[rb, p] = vals[rb, last]
        cols[rb, p] = cols[rb, last]
        vals[rb, last] = 0.0
        lens[rb] = last
        cols[rb, last:] = cols[rb, last - 1] if last > 0 else 0
        return
    if p >= 0:                            # recomputed in place
        vals[rb, p] = tile
        return
    vals[rb, valid] = tile                # append in the ragged row
    cols[rb, valid] = cb
    lens[rb] = valid + 1
    cols[rb, valid + 1:] = cb


def _grow_tiles(vals, cols, new_t: int):
    """Geometrically grown (… , T, B, B)/(… , T) tables; padding tiles are
    zero and padding cols repeat the previous last entry (contract-safe)."""
    pad = new_t - vals.shape[-3]
    vals = np.concatenate(
        [vals, np.zeros(vals.shape[:-3] + (pad,) + vals.shape[-2:], vals.dtype)],
        axis=-3)
    cols = np.concatenate([cols, np.repeat(cols[..., -1:], pad, axis=-1)], axis=-1)
    return vals, cols


def _sim_extra_tiles(vals, cols, lens, ups) -> int:
    """Max valid-tile count any block row reaches DURING ``ups`` — presence
    is read from the pre-patch table (tile updates are per-tile unique, so
    membership is stable under the other updates in the batch). Tracks the
    running count in apply order, not just the net: an append that precedes
    a tombstone in the same row transiently exceeds the final count, and
    `_apply_tile_update` replays ``ups`` in exactly this order."""
    need = int(lens.max(initial=0))
    per_row: dict[int, int] = {}
    for rb, cb, tile in ups:
        present = _find_tile(cols[rb], int(lens[rb]), cb) >= 0
        d = per_row.get(rb, 0)
        if tile is None and present:
            d -= 1
        elif tile is not None and not present:
            d += 1
        per_row[rb] = d
        need = max(need, int(lens[rb]) + d)
    return need


def delta_update_blocked_adjacency(
    ba: BlockedAdjacency,
    edge_index: np.ndarray,
    edge_weight: np.ndarray | None,
    delta: GraphDelta,
) -> BlockedAdjacency:
    """Patch a global `BlockedAdjacency` in place for one delta.

    ``edge_index``/``edge_weight`` are the POST-delta edges (what
    `apply_delta_to_graph` returned). Only the tiles containing a touched
    (receiver, sender) coordinate are recomputed; tombstoned tiles are
    swap-removed from their ragged row and zeroed. Equivalent — up to T
    padding, which never shrinks and grows geometrically — to re-running
    `repro.graph.structure.blocked_adjacency` on the new edges.
    """
    s = np.asarray(edge_index[0], np.int64)
    r = np.asarray(edge_index[1], np.int64)
    w = (np.ones(s.shape[0], np.float32) if edge_weight is None
         else np.asarray(edge_weight, np.float32))
    pairs = set()
    for arr in (delta.edge_inserts, delta.edge_deletes):
        for u, v in arr.T.tolist():
            if v >= ba.n_nodes or u >= ba.n_col_nodes:
                raise ValueError(
                    f"delta edge ({u}, {v}) outside the blocked "
                    f"{ba.n_nodes}×{ba.n_col_nodes} space")
            pairs.add((v, u))             # A[receiver, sender]
    parr = np.array(sorted(pairs), np.int64).reshape(-1, 2)
    res = _tile_updates(s, r, w, parr, ba.n_block_cols, ba.block)
    if res is None:
        return ba
    rbs, cbs, live, tiles = res
    # tombstones first: replaying must never transiently exceed a row's
    # final tile count (an append before a tombstone in the same row would
    # need a capacity slot the net count does not)
    ups = [(rb, cb, None)
           for rb, cb in zip(rbs[~live].tolist(), cbs[~live].tolist())]
    ups += [(rb, cb, tiles[i])
            for i, rb, cb in zip(np.nonzero(live)[0].tolist(),
                                 rbs[live].tolist(), cbs[live].tolist())]
    need = _sim_extra_tiles(ba.block_vals, ba.block_cols, ba.row_nnzb, ups)
    if need > ba.max_nnzb:
        ba.block_vals, ba.block_cols = _grow_tiles(
            ba.block_vals, ba.block_cols, max(need, 2 * ba.max_nnzb))
    for rb, cb, tile in ups:
        _apply_tile_update(ba.block_vals, ba.block_cols, ba.row_nnzb, rb, cb, tile)
    return ba


# ========================================================== re-localization
def _relocalized_assignment(
    n: int, edge_index: np.ndarray, k: int, *,
    block: int = 128, method: str = "bfs",
) -> np.ndarray:
    """The node→device assignment an online re-localization installs.

    Edges are first CANONICALIZED (lexsorted by (src, dst)) so the result is
    a pure function of the edge MULTISET — the planner's store groups edges
    by receiver device, a fresh builder sees them in input order, and
    `locality_block_order`'s BFS tie-breaks on edge order. Canonicalization
    is what makes ``drift_ratio == 1.0`` hold EXACTLY right after
    :meth:`DeltaPlanner.relocalize`: the drift denominator and the installed
    order are the same deterministic construction, however the edges happen
    to be stored. The locality order is then cut into k balanced contiguous
    chunks (devices keep equal loads; BFS neighbors stay co-resident).
    """
    from repro.graph.structure import locality_block_order

    ei = np.asarray(edge_index, np.int64)
    canon = ei[:, np.lexsort((ei[1], ei[0]))]
    order = np.asarray(
        locality_block_order(n, canon, block, method=method), np.int64)
    bounds = np.linspace(0, n, k + 1).astype(np.int64)
    assignment = np.empty(n, np.int32)
    for i in range(k):
        assignment[order[bounds[i]:bounds[i + 1]]] = i
    return assignment


@dataclasses.dataclass
class RelocalizePolicy:
    """Hysteresis trigger for online re-localization (ISSUE 9 / ROADMAP).

    Attached to a :class:`DeltaPlanner`, the policy observes the
    ``drift_ratio`` after every edge-mutating apply and fires — i.e. the
    planner runs :meth:`DeltaPlanner.relocalize` — only when the ratio has
    exceeded ``threshold`` for ``patience`` CONSECUTIVE structural applies
    (one sub-threshold reading resets the streak). After firing, the next
    ``cooldown`` observations are ignored entirely, so a burst of churn
    cannot re-trigger while the fresh order is still settling.

    ``block``/``method`` parameterize both the drift measurement and the
    re-localization itself — they MUST agree, or post-fire drift is not
    exactly 1.0.
    """

    threshold: float = 1.25
    patience: int = 3
    cooldown: int = 10
    block: int = 128
    method: str = "bfs"
    streak: int = 0
    cooldown_left: int = 0

    def observe(self, drift_ratio: float) -> bool:
        """Feed one drift reading; True ⇒ the caller should relocalize."""
        if self.cooldown_left > 0:
            self.cooldown_left -= 1
            return False
        if drift_ratio > self.threshold:
            self.streak += 1
        else:
            self.streak = 0
        if self.streak >= self.patience:
            self.streak = 0
            self.cooldown_left = self.cooldown
            return True
        return False


# =============================================================== DeltaPlanner
@dataclasses.dataclass
class _TierState:
    """One export tier's refcounted boundary bookkeeping.

    ref[d]     — {local row: #cut edges of this tier sourced at it}.
    exports[d] — slot → exported local row (python list; -1 marks a freed
                 hole). Slots are STABLE: v0 is the builder's sorted-unique
                 order, a new export takes the lowest freed slot (or
                 appends), and a surviving export NEVER moves — the property
                 that lets a non-structural repair remap only newly-cut
                 edges and tile-patch only delta-sized table regions.
    slot_arr   — (k, n_local) local row → slot (-1 = not exported); the
                 vectorized inverse of ``exports``.
    free[d]    — freed-slot min-heap (deterministic reuse order).
    pad        — the tier's padded segment width (s_max / s_loc / s_rem);
                 never shrinks, grows geometrically when the slot
                 high-water mark (len(exports[d]), holes included)
                 outgrows it.
    dirty      — devices whose export table row changed since last repair.
    """

    ref: list[dict[int, int]]
    exports: list[list[int]]
    slot_arr: np.ndarray
    free: list[list[int]]
    pad: int
    dirty: set[int] = dataclasses.field(default_factory=set)


class DeltaPlanner:
    """Mutable edge store + incremental plan repair for ONE partitioned graph.

    Materialize plans through :meth:`plan` (flat and hierarchical variants
    share the planner's slot layout, so one repair pass fixes all of them),
    then feed `GraphDelta` batches to :meth:`apply`. Each apply:

      1. updates the per-device edge store (delete = swap-fill, insert =
         append; per-device capacity ``e_local`` grows geometrically),
      2. refreshes only the DIRTY devices' export segments per tier, keeping
         pads when the new boundary fits and growing them geometrically
         otherwise (a *structural* repair),
      3. remaps `senders_l` only for edges whose encoding could have moved
         (sourced at a dirty device, or newly cut) — or for the whole cut
         class on a structural repair,
      4. patches the plans' memoized blocked adjacencies tile-wise
         (structural repairs drop them — the halo column space changed),
      5. re-registers every plan under the new versioned ``graph_key``
         (``{base}@d{version}``) and evicts the stale key, so plan-cache
         users migrate keys without ever re-running a builder.

    The node set and the partition are FIXED for the planner's lifetime —
    re-partitioning is `invalidate_halo_plans` + a fresh planner.
    """

    def __init__(self, part, edge_index: np.ndarray,
                 w: np.ndarray | None = None, *, graph_key: str | None = None,
                 relocalize_policy: "RelocalizePolicy | None" = None):
        self.part = part
        self.assignment = np.asarray(part.assignment, np.int64)
        self.k = int(part.k)
        self.n = int(part.n_nodes)
        src = np.asarray(edge_index[0], np.int64)
        dst = np.asarray(edge_index[1], np.int64)
        e = int(src.shape[0])
        w = np.ones(e, np.float32) if w is None else np.asarray(w, np.float32)
        self.base_key = (graph_fingerprint(self.n, edge_index, w, self.assignment)
                         if graph_key is None else graph_key)
        self.version = 0
        self.relocalize_policy = relocalize_policy
        # (block, method) → (era, executed_tiles_reordered): the memoized
        # fresh-reorder denominator of `locality_drift`. The era advances on
        # structural applies and rebuilds, so non-structural applies reuse
        # the BFS result instead of re-running it per apply.
        self._drift_era = 0
        self._drift_memo: dict[tuple[int, str], tuple[int, int]] = {}
        self._init_layout()
        self._init_store(src, dst, w)
        self._tiers: dict[tuple[str, int], _TierState] = {}
        self._plans: dict[object, HaloPlan] = {}

    def _init_layout(self) -> None:
        """(Re)derive the blocked layout from ``self.assignment``."""
        perm, sizes, n_local, local = _blocked_layout(self.assignment, self.k, self.n)
        self.perm, self.part_sizes, self.n_local, self.local = perm, sizes, n_local, local
        # node_of[b, local_row] — inverse of `local` per device block.
        self.node_of = np.zeros((self.k, max(n_local, 1)), np.int64)
        off = 0
        for b in range(self.k):
            sz = int(sizes[b])
            self.node_of[b, :sz] = perm[off:off + sz]
            off += sz

    def _init_store(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray) -> None:
        """(Re)build the per-receiver-device edge store — same stable
        grouping as `_group_edges_by_receiver`, so the first materialized
        plan is bit-identical to `build_halo_plan`."""
        e = int(src.shape[0])
        local = self.local
        a_d = self.assignment[dst]
        counts = np.bincount(a_d, minlength=self.k).astype(np.int64)
        self.e_local = max(int(counts.max()) if e else 0, 1)
        self._cnt = counts
        self._src = np.zeros((self.k, self.e_local), np.int64)
        self._dst = np.zeros((self.k, self.e_local), np.int32)
        self._w = np.zeros((self.k, self.e_local), np.float32)
        start = np.zeros(self.k + 1, np.int64)
        np.cumsum(counts, out=start[1:])
        self._pos: list[dict[tuple[int, int], list[int]]] = [
            {} for _ in range(self.k)]
        if e:
            order = np.argsort(a_d, kind="stable")
            own = a_d[order]
            slot = np.arange(e, dtype=np.int64) - start[own]
            self._src[own, slot] = src[order]
            self._dst[own, slot] = local[dst[order]].astype(np.int32)
            self._w[own, slot] = w[order]
            for b, sl, u, v in zip(own.tolist(), slot.tolist(),
                                   src[order].tolist(), dst[order].tolist()):
                self._pos[b].setdefault((u, v), []).append(sl)
        self._new_cut: np.ndarray | None = None

    # ------------------------------------------------------------- identity
    @property
    def graph_key(self) -> str:
        """The current plan-cache key: base for v0, ``{base}@d{v}`` after."""
        return self.base_key if self.version == 0 else f"{self.base_key}@d{self.version}"

    @property
    def n_edges(self) -> int:
        return int(self._cnt.sum())

    def edge_index(self) -> np.ndarray:
        """Current (2, E) global edges, grouped by receiver device."""
        cols = [np.stack([self._src[b, :self._cnt[b]],
                          self.node_of[b, self._dst[b, :self._cnt[b]]]])
                for b in range(self.k)]
        return (np.concatenate(cols, axis=1) if cols
                else np.zeros((2, 0), np.int64))

    def edge_weights(self) -> np.ndarray:
        """Current (E,) weights, aligned with :meth:`edge_index`'s order."""
        return np.concatenate(
            [self._w[b, :self._cnt[b]] for b in range(self.k)])

    # ----------------------------------------------------------------- tiers
    def _tier_member(self, kind: str, pods: int, a_s, a_d):
        if kind == "flat":
            return a_s != a_d
        km = self.k // pods
        if kind == "loc":
            return (a_s != a_d) & (a_s // km == a_d // km)
        return a_s // km != a_d // km

    def _ensure_tier(self, kind: str, pods: int) -> _TierState:
        key = (kind, int(pods))
        ts = self._tiers.get(key)
        if ts is not None:
            return ts
        grid = np.arange(self.e_local)[None, :] < self._cnt[:, None]
        a_s = self.assignment[self._src]
        owner = np.broadcast_to(
            np.arange(self.k, dtype=np.int64)[:, None], a_s.shape)
        m = grid & self._tier_member(kind, pods, a_s, owner)
        ref: list[dict[int, int]] = [{} for _ in range(self.k)]
        if m.any():
            pair = a_s[m] * self.n + self._src[m]
            uniq, cnts = np.unique(pair, return_counts=True)
            dev, node = uniq // self.n, uniq % self.n
            lrows = self.local[node]
            for d, lr, c in zip(dev.tolist(), lrows.tolist(), cnts.tolist()):
                ref[d][lr] = c
        exports = [sorted(ref[d]) for d in range(self.k)]
        slot_arr = np.full((self.k, max(self.n_local, 1)), -1, np.int64)
        for d in range(self.k):
            if exports[d]:
                slot_arr[d, np.asarray(exports[d], np.int64)] = np.arange(
                    len(exports[d]))
        ts = _TierState(ref=ref, exports=exports, slot_arr=slot_arr,
                        free=[[] for _ in range(self.k)],
                        pad=max((len(ex) for ex in exports), default=0))
        self._tiers[key] = ts
        return ts

    def _bump_tiers(self, a_s: int, a_d: int, lrow: int, dlt: int) -> None:
        # per-edge hot path (apply's delete/insert loops): membership is
        # inlined rather than routed through `_tier_member`
        k = self.k
        for (kind, pods), ts in self._tiers.items():
            if kind == "flat":
                member = a_s != a_d
            else:
                km = k // pods
                if kind == "loc":
                    member = a_s != a_d and a_s // km == a_d // km
                else:
                    member = a_s // km != a_d // km
            if not member:
                continue
            ref = ts.ref[a_s]
            c = ref.get(lrow, 0) + dlt
            if c <= 0:
                if lrow in ref:
                    del ref[lrow]
                    ts.dirty.add(a_s)
                    slot = int(ts.slot_arr[a_s, lrow])
                    ts.slot_arr[a_s, lrow] = -1
                    ts.exports[a_s][slot] = -1
                    heapq.heappush(ts.free[a_s], slot)
            else:
                if c == 1 and dlt > 0:
                    ts.dirty.add(a_s)
                    exp = ts.exports[a_s]
                    fr = ts.free[a_s]
                    slot = heapq.heappop(fr) if fr else len(exp)
                    if slot == len(exp):
                        exp.append(lrow)
                    else:
                        exp[slot] = lrow
                    ts.slot_arr[a_s, lrow] = slot
                ref[lrow] = c

    def _tier_lookup(self, ts: _TierState):
        """Vectorized (devs, global nodes) → STABLE slot resolver — one
        fancy read off the tier's dense row→slot inverse."""
        slot_arr, local = ts.slot_arr, self.local

        def slots(devs, nodes):
            return slot_arr[devs, local[nodes]]

        return slots

    def _send_table(self, ts: _TierState) -> np.ndarray:
        tbl = np.zeros((self.k, ts.pad), np.int32)
        for d in range(self.k):
            ex = np.asarray(ts.exports[d], np.int64)
            if ex.size:
                row = tbl[d, :ex.size]
                valid = ex >= 0
                row[valid] = ex[valid]    # freed holes stay 0: no receiver
        return tbl                        # ever references them

    # ----------------------------------------------------------------- plans
    def plan(self, axes: tuple[str, ...] = ("model",), pods: int = 1) -> HaloPlan:
        """The (memoized) plan for one schedule; repaired in place by every
        subsequent :meth:`apply` and registered in the global plan cache
        under the planner's current ``graph_key``."""
        axes = tuple(axes)
        pods = int(pods)
        if len(axes) not in (1, 2) or (len(axes) == 1 and pods != 1):
            raise ValueError(f"bad schedule: axes={axes!r} pods={pods}")
        if pods < 1 or self.k % pods:
            raise ValueError(f"pods={pods} must divide k={self.k}")
        key_axes = axes[0] if len(axes) == 1 else (axes, pods)
        p = self._plans.get(key_axes)
        if p is None:
            p = self._materialize_plan(axes, pods)
            self._plans[key_axes] = p
            register_halo_plan(
                self.graph_key, self.k,
                axes[0] if len(axes) == 1 else axes, pods=pods, plan=p)
        return p

    def _materialize_plan(self, axes: tuple[str, ...], pods: int) -> HaloPlan:
        flat = self._ensure_tier("flat", 1)
        k, n_local, cap = self.k, self.n_local, self.e_local
        grid = np.arange(cap)[None, :] < self._cnt[:, None]
        a_s = self.assignment[self._src]
        owner = np.broadcast_to(np.arange(k, dtype=np.int64)[:, None], a_s.shape)
        senders = np.zeros((k, cap), np.int32)
        interior = grid & (a_s == owner)
        senders[interior] = self.local[self._src[interior]].astype(np.int32)
        cut = grid & (a_s != owner)
        if len(axes) == 2:
            loc = self._ensure_tier("loc", pods)
            rem = self._ensure_tier("rem", pods)
            km = k // pods
            b_width = loc.pad + pods * rem.pad
            icut = cut & (a_s // km == owner // km)
            xcut = grid & (a_s // km != owner // km)
            if icut.any():
                d_, nd_ = a_s[icut], self._src[icut]
                senders[icut] = (n_local + (d_ % km) * b_width
                                 + self._tier_lookup(loc)(d_, nd_))
            if xcut.any():
                d_, nd_ = a_s[xcut], self._src[xcut]
                senders[xcut] = (n_local + (d_ % km) * b_width + loc.pad
                                 + (d_ // km) * rem.pad
                                 + self._tier_lookup(rem)(d_, nd_))
            s_loc, s_rem = loc.pad, rem.pad
            send_loc, send_rem = self._send_table(loc), self._send_table(rem)
        else:
            s_loc = s_rem = 0
            send_loc = send_rem = None
            if cut.any():
                d_, nd_ = a_s[cut], self._src[cut]
                senders[cut] = (n_local + d_ * flat.pad
                                + self._tier_lookup(flat)(d_, nd_))
        # receivers_l / edge_w are the store arrays THEMSELVES — all plans
        # of this planner share them, so the store update in `apply` is the
        # plan update.
        return HaloPlan(
            k=k, n_local=n_local, s_max=flat.pad, e_local=cap, n_nodes=self.n,
            perm=self.perm, send_idx=self._send_table(flat), senders_l=senders,
            receivers_l=self._dst, edge_w=self._w, part_sizes=self.part_sizes,
            axes=axes, n_pods=pods, s_loc=s_loc, s_rem=s_rem,
            send_loc=send_loc, send_rem=send_rem,
        )

    # ----------------------------------------------------------------- store
    def _grow_capacity(self, new_cap: int) -> None:
        add = new_cap - self.e_local

        def wide(a):
            return np.concatenate(
                [a, np.zeros((self.k, add), a.dtype)], axis=1)

        self._src, self._dst, self._w = wide(self._src), wide(self._dst), wide(self._w)
        if self._new_cut is not None:
            self._new_cut = wide(self._new_cut)
        for p in self._plans.values():
            p.senders_l = wide(p.senders_l)
            p.receivers_l, p.edge_w = self._dst, self._w
            p.e_local = new_cap
        self.e_local = new_cap

    # ----------------------------------------------------------------- apply
    def apply(self, delta: GraphDelta, *, measure_drift: bool = False,
              drift_block: int = 128) -> dict:
        """Apply one delta; repair every materialized plan in place; migrate
        the plan-cache entries to the new versioned key. Returns a repair
        report (counts of dirty devices, remapped senders, patched/dropped
        blocked tables, grown pads, repair latency ``apply_ms``, and the
        ``structural`` flag — True when some tier's pads grew, i.e. the
        halo column space changed and memoized blocked tables were dropped
        rather than patched). ``measure_drift=True`` additionally runs
        :meth:`locality_drift` on the post-apply graph and attaches the
        executed-tile drift record under ``"drift"`` (None otherwise).
        When `repro.obs.metrics` is enabled the report is mirrored into the
        ``delta.*`` series."""
        t_apply = time.perf_counter()
        delta.validate(self.n)
        old_key = self.graph_key
        plans = list(self._plans.values())
        track = {id(p): bool(p.__dict__.get("_blocked_cache")) for p in plans}
        pairs = {id(p): [set() for _ in range(self.k)] for p in plans}
        self._new_cut = np.zeros((self.k, self.e_local), bool)

        # -- 1. deletes (batched hole-fill; tiles captured pre-remap) -------
        # The replan latency budget (the 1%-delta bench gate) lives here, so
        # deletes run in two phases: a dict-only python pass resolving each
        # delete to a slot, then one vectorized compaction per device —
        # survivors from the tail drop into the holes in a single fancy
        # write instead of an edge-at-a-time swap-fill.
        src, dst, w_arr = self._src, self._dst, self._w
        new_cut, node_of = self._new_cut, self.node_of
        pos, cnt = self._pos, self._cnt
        plan_sl = [(p.senders_l, pairs[id(p)] if track[id(p)] else None)
                   for p in plans]
        dels = delta.edge_deletes
        del_slots: list[list[int]] = [[] for _ in range(self.k)]
        for u, v, b, a_u, lrow_u in zip(
                dels[0].tolist(), dels[1].tolist(),
                self.assignment[dels[1]].tolist(),
                self.assignment[dels[0]].tolist(),
                self.local[dels[0]].tolist()):
            slots = pos[b].get((u, v))
            if not slots:
                raise ValueError(f"delta deletes absent edge ({u}, {v})")
            # oldest instance first — same parallel-edge tie-break as
            # `apply_delta_to_graph`'s in-order scan, so weighted duplicate
            # edges stay in lockstep between the two representations
            del_slots[b].append(slots.pop(0))
            if not slots:
                del pos[b][(u, v)]
            self._bump_tiers(a_u, b, lrow_u, -1)
        for b, dead in enumerate(del_slots):
            if not dead:
                continue
            s_arr = np.asarray(dead, np.int64)
            for sl, ppairs in plan_sl:
                if ppairs is not None:
                    ppairs[b].update(zip(dst[b, s_arr].tolist(),
                                         sl[b, s_arr].tolist()))
            cnt_b = int(cnt[b])
            keep_n = cnt_b - len(dead)
            dead_set = set(dead)
            movers = [t for t in range(keep_n, cnt_b) if t not in dead_set]
            if movers:
                holes = sorted(s for s in dead_set if s < keep_n)
                mv = np.asarray(movers, np.int64)
                hl = np.asarray(holes, np.int64)
                mus = src[b, mv].tolist()
                mvs = node_of[b, dst[b, mv]].tolist()
                src[b, hl] = src[b, mv]
                dst[b, hl] = dst[b, mv]
                w_arr[b, hl] = w_arr[b, mv]
                new_cut[b, hl] = new_cut[b, mv]
                for sl, _ in plan_sl:
                    sl[b, hl] = sl[b, mv]
                for mu, mvv, old_t, new_t in zip(mus, mvs, movers, holes):
                    moved = pos[b][(mu, mvv)]
                    moved[moved.index(old_t)] = new_t
            tail = slice(keep_n, cnt_b)
            src[b, tail] = 0
            dst[b, tail] = 0
            w_arr[b, tail] = 0.0
            new_cut[b, tail] = False
            for sl, _ in plan_sl:
                sl[b, tail] = 0
            cnt[b] = keep_n

        # -- 2. inserts (append; cut senders resolved in the remap pass) ----
        # Also batched per device: one bulk tail write per device, python
        # only for the _pos bookkeeping and the tier bumps of cut edges.
        n_ins = delta.edge_inserts.shape[1]
        ins_w = (np.ones(n_ins, np.float32) if delta.insert_w is None
                 else delta.insert_w)
        inss = delta.edge_inserts
        if n_ins:
            ins_b = self.assignment[inss[1]]
            need = int((cnt + np.bincount(ins_b, minlength=self.k)).max())
            if need > self.e_local:
                cap = self.e_local
                while cap < need:
                    cap *= 2
                self._grow_capacity(cap)
                plans = list(self._plans.values())
                src, dst, w_arr = self._src, self._dst, self._w
                new_cut = self._new_cut
                plan_sl = [(p.senders_l,
                            pairs[id(p)] if track[id(p)] else None)
                           for p in plans]
            ins_as = self.assignment[inss[0]]
            ins_lu = self.local[inss[0]]
            ins_lv = self.local[inss[1]]
            # stable grouping keeps each device's append order = the delta's
            # edge order (the oldest-first _pos contract)
            order = np.argsort(ins_b, kind="stable")
            bounds = np.searchsorted(ins_b[order], np.arange(self.k + 1))
            for b in range(self.k):
                idx = order[bounds[b]:bounds[b + 1]]
                if not idx.size:
                    continue
                slots = int(cnt[b]) + np.arange(idx.size, dtype=np.int64)
                cnt[b] += idx.size
                src[b, slots] = inss[0, idx]
                dst[b, slots] = ins_lv[idx]
                w_arr[b, slots] = ins_w[idx]
                for u, v, s in zip(inss[0, idx].tolist(),
                                   inss[1, idx].tolist(), slots.tolist()):
                    pos[b].setdefault((u, v), []).append(s)
                interior = ins_as[idx] == b
                lus = ins_lu[idx]
                for sl, ppairs in plan_sl:
                    sl[b, slots[interior]] = lus[interior]
                    if ppairs is not None:
                        ppairs[b].update(zip(ins_lv[idx][interior].tolist(),
                                             lus[interior].tolist()))
                new_cut[b, slots[~interior]] = True
                for a_u, lu in zip(ins_as[idx][~interior].tolist(),
                                   lus[~interior].tolist()):
                    self._bump_tiers(a_u, b, lu, +1)

        # -- 3. tier refresh: pads keep-or-grow on the slot high-water mark
        # (exports/slots were maintained in place by `_bump_tiers`) ---------
        pads_grown: list[tuple[str, int]] = []
        tier_info: dict[tuple[str, int], tuple[set[int], bool]] = {}
        for key, ts in self._tiers.items():
            needed = max((len(ex) for ex in ts.exports), default=0)
            grew = needed > ts.pad
            if grew:
                ts.pad = needed if ts.pad == 0 else max(needed, 2 * ts.pad)
                pads_grown.append(key)
            tier_info[key] = (set(ts.dirty), grew)
            ts.dirty.clear()

        # -- 4. per-plan sender remap + blocked patch ----------------------
        # ONE nonzero over the cut mask extracts every cut edge; all class
        # selection (intra/inter pod, dirty-sourced, newly-cut) then runs on
        # the extracted ~|cut| vectors instead of repeated (k, e_local) mask
        # algebra — the other half of the 1%-delta bench gate.
        grid = np.arange(self.e_local)[None, :] < self._cnt[:, None]
        a_s = self.assignment[self._src]
        owner = np.broadcast_to(
            np.arange(self.k, dtype=np.int64)[:, None], a_s.shape)
        cut = grid & (a_s != owner)
        bm, sm = np.nonzero(cut)
        d_cut = a_s[bm, sm]
        n_cut = self._src[bm, sm]
        nc_cut = self._new_cut[bm, sm]
        pod_sel: dict[int, np.ndarray] = {}
        remapped = 0
        patched = dropped = 0
        self._tables_grown = 0
        flat_info = tier_info.get(("flat", 1), (set(), False))
        all_cut = np.ones(d_cut.size, bool)
        for p in plans:
            ppairs = pairs[id(p)]
            if p.is_hierarchical:
                pods = p.n_pods
                km = p.k_model
                loc = self._tiers[("loc", pods)]
                rem = self._tiers[("rem", pods)]
                loc_info = tier_info[("loc", pods)]
                rem_info = tier_info[("rem", pods)]
                structural = loc_info[1] or rem_info[1]
                p.s_loc, p.s_rem = loc.pad, rem.pad
                b_width = p.block_rows
                same_pod = pod_sel.get(pods)
                if same_pod is None:
                    same_pod = pod_sel[pods] = d_cut // km == bm // km
                lslots, rslots = self._tier_lookup(loc), self._tier_lookup(rem)
                remapped += self._remap_class(
                    p, bm, sm, d_cut, n_cut, nc_cut,
                    same_pod, structural,
                    lambda d_, nd_: (self.n_local + (d_ % km) * b_width
                                     + lslots(d_, nd_)),
                    ppairs if track[id(p)] else None)
                remapped += self._remap_class(
                    p, bm, sm, d_cut, n_cut, nc_cut,
                    ~same_pod, structural,
                    lambda d_, nd_: (self.n_local + (d_ % km) * b_width
                                     + loc.pad + (d_ // km) * rem.pad
                                     + rslots(d_, nd_)),
                    ppairs if track[id(p)] else None)
                if loc_info[0] or loc_info[1] or structural:
                    p.send_loc = self._send_table(loc)
                if rem_info[0] or rem_info[1] or structural:
                    p.send_rem = self._send_table(rem)
            else:
                flat = self._tiers[("flat", 1)]
                structural = flat_info[1]
                p.s_max = flat.pad
                fslots = self._tier_lookup(flat)
                remapped += self._remap_class(
                    p, bm, sm, d_cut, n_cut, nc_cut,
                    all_cut, structural,
                    lambda d_, nd_: self.n_local + d_ * flat.pad + fslots(d_, nd_),
                    ppairs if track[id(p)] else None)
            # every plan carries the flat table as the accounting baseline
            flat = self._tiers[("flat", 1)]
            p.s_max = flat.pad
            if flat_info[0] or flat_info[1]:
                p.send_idx = self._send_table(flat)
            cache = p.__dict__.get("_blocked_cache")
            if structural:
                if cache:
                    dropped += len(cache)
                p.__dict__.pop("_blocked_cache", None)
            elif cache:
                patched += self._patch_blocked(p, cache, ppairs)
            p.__dict__.pop("_edge_locality_cache", None)
        self._new_cut = None

        # -- 5. versioned re-key: stale key evicted, plans re-registered ----
        self.version += 1
        evicted = invalidate_halo_plans(old_key)
        for key_axes, p in self._plans.items():
            if isinstance(key_axes, str):
                register_halo_plan(self.graph_key, self.k, key_axes, plan=p)
            else:
                axes, pods = key_axes
                register_halo_plan(self.graph_key, self.k, axes,
                                   pods=pods, plan=p)
        if pads_grown:
            # structural apply: the halo column space changed, so the
            # memoized fresh-reorder drift denominator is refreshed too
            self._drift_era += 1
        pol = self.relocalize_policy
        edge_ops = bool(n_ins or delta.edge_deletes.shape[1])
        # the policy watches drift at ITS OWN granularity (pol.block, the
        # tile size it would re-localize at) — not the report's drift_block
        pol_drift = (self.locality_drift(pol.block, method=pol.method)
                     if pol is not None and edge_ops else None)
        drift = self.locality_drift(drift_block) if measure_drift else pol_drift
        report = {
            "graph_key": self.graph_key,
            "version": self.version,
            "inserts": n_ins,
            "deletes": int(delta.edge_deletes.shape[1]),
            "dirty_devices": {f"{kind}/{pods}": len(info[0])
                              for (kind, pods), info in tier_info.items()},
            "pads_grown": [f"{kind}/{pods}" for kind, pods in pads_grown],
            "senders_remapped": remapped,
            "blocked_patched": patched,
            "blocked_dropped": dropped,
            "blocked_grown": self._tables_grown,
            "stale_keys_evicted": evicted,
            "structural": bool(pads_grown),
            "apply_ms": (time.perf_counter() - t_apply) * 1e3,
            "drift": drift,
            "relocalized": None,
        }
        if (pol is not None and edge_ops
                and pol.observe(pol_drift["drift_ratio"])):
            report["relocalized"] = self.relocalize(
                block=pol.block, method=pol.method)
            report["graph_key"] = self.graph_key
            report["version"] = self.version
        if _obs_metrics.enabled():
            from repro.obs.instrument import record_delta_report

            record_delta_report(report)
        _obs_trace.instant("delta.apply", {
            "inserts": report["inserts"], "deletes": report["deletes"],
            "apply_ms": report["apply_ms"],
        })
        return report

    def locality_drift(self, block: int = 128, method: str = "bfs") -> dict:
        """Executed-tile locality drift of the mutated graph (the ROADMAP
        drift-metrics item): how much blocked-layout quality the CURRENT
        node order has lost to mutations, measured in the executed-tile
        currency the ragged bsr kernel actually pays.

        Both sides are O(E) `repro.graph.structure.blocked_stats` counts
        over the SAME current edge list, differing only in node order:

          * ``executed_tiles_current``   — edges relabeled by the planner's
            live blocked layout (``perm``, the order every patched blocked
            table tiles over),
          * ``executed_tiles_reordered`` — edges relabeled by the order an
            online re-localization WOULD install: the canonicalized
            `repro.graph.structure.locality_block_order` of the mutated
            graph, cut into k balanced device chunks
            (`_relocalized_assignment` — the exact construction
            :meth:`relocalize` runs, so right after a re-localization the
            two sides coincide and ``drift_ratio == 1.0`` exactly).

        ``drift_ratio = current / reordered`` — 1.0 means the standing
        order is still as tile-dense as a re-localization would be; growth
        beyond a caller-chosen threshold (see :class:`RelocalizePolicy`) is
        the re-localize trigger. The ``reordered`` term is memoized per
        drift era — non-structural applies reuse it instead of re-running
        BFS; structural applies, :meth:`relocalize`, and :meth:`compact`
        rebuilds advance the era and refresh it. Mirrored into the
        ``delta.drift_ratio`` gauge when metrics are enabled."""
        from repro.graph.structure import blocked_stats, permute_edge_index

        ei = self.edge_index()
        cur_edges = permute_edge_index(self.perm, ei)
        current = blocked_stats(self.n, cur_edges, block)["nnz_blocks"]
        memo = self._drift_memo.get((block, method))
        if memo is not None and memo[0] == self._drift_era:
            reordered = memo[1]
        else:
            fresh_a = _relocalized_assignment(
                self.n, ei, self.k, block=block, method=method)
            fresh_perm = np.argsort(fresh_a, kind="stable").astype(np.int64)
            reordered = int(blocked_stats(
                self.n, permute_edge_index(fresh_perm, ei), block)["nnz_blocks"])
            self._drift_memo[(block, method)] = (self._drift_era, reordered)
        drift = {
            "block": block,
            "executed_tiles_current": int(current),
            "executed_tiles_reordered": int(reordered),
            "drift_ratio": current / max(reordered, 1),
        }
        if _obs_metrics.enabled():
            _obs_metrics.set_gauge("delta.drift_ratio", drift["drift_ratio"])
            _obs_metrics.set_gauge("delta.executed_tiles_current", current)
            _obs_metrics.set_gauge("delta.executed_tiles_reordered", reordered)
        return drift

    # -------------------------------------------------- online maintenance
    def _host_bytes(self) -> int:
        """Host bytes held by the store, the plan tables, and the memoized
        blocked tiles — the pad-compaction accounting currency."""
        total = self._src.nbytes + self._dst.nbytes + self._w.nbytes
        for p in self._plans.values():
            total += p.senders_l.nbytes + p.send_idx.nbytes
            if p.send_loc is not None:
                total += p.send_loc.nbytes
            if p.send_rem is not None:
                total += p.send_rem.nbytes
            for key, entry in (p.__dict__.get("_blocked_cache") or {}).items():
                tabs = (entry if isinstance(key, tuple) and key[0] == "split"
                        else (entry,))
                for t in tabs:
                    total += t.vals.nbytes + t.cols.nbytes + t.lens.nbytes
        return total

    def _rebuild_in_place(self, assignment: np.ndarray, part,
                          edge_index: np.ndarray, w: np.ndarray) -> None:
        """Swap in a (possibly new) assignment and rebuild everything tight:
        layout, edge store, tiers (fresh pads = exact occupancy), and every
        materialized plan — IN PLACE, preserving plan object identity so
        callers holding a plan reference keep working. Bumps the version and
        migrates the plan-cache entries to the new key."""
        old_key = self.graph_key
        self.part = part
        self.assignment = np.asarray(assignment, np.int64)
        self._init_layout()
        self._init_store(np.asarray(edge_index[0], np.int64),
                         np.asarray(edge_index[1], np.int64),
                         np.asarray(w, np.float32))
        tier_keys = list(self._tiers)
        self._tiers = {}
        for kind, pods in tier_keys:
            self._ensure_tier(kind, pods)
        for p in self._plans.values():
            q = self._materialize_plan(p.axes, p.n_pods)
            p.__dict__.pop("_blocked_cache", None)
            p.__dict__.pop("_edge_locality_cache", None)
            for f in dataclasses.fields(HaloPlan):
                setattr(p, f.name, getattr(q, f.name))
        self.version += 1
        self._drift_era += 1
        self._drift_memo.clear()
        invalidate_halo_plans(old_key)
        for key_axes, p in self._plans.items():
            if isinstance(key_axes, str):
                register_halo_plan(self.graph_key, self.k, key_axes, plan=p)
            else:
                axes, pods = key_axes
                register_halo_plan(self.graph_key, self.k, axes,
                                   pods=pods, plan=p)

    def relocalize(self, *, block: int = 128, method: str = "bfs") -> dict:
        """Online re-localization: install a fresh locality order on the
        MUTATED graph, in place (docs/communication.md §8).

        Recomputes `locality_block_order` on the current edges (canonical
        form, `_relocalized_assignment`), cuts it into k balanced device
        chunks, and rebuilds layout, store, tiers, and every materialized
        plan under the new order — pads drop to exact occupancy, blocked
        caches rebuild lazily and tight, and the plan cache re-keys to the
        next version. Returns a report carrying ``old_layout`` — a frozen
        :class:`repro.dist.halo.PlanLayout` of the PRE-relocalize blocked
        layout, which is exactly what `repro.train.elastic.relocate_state_tree`
        needs to move live per-node training state (params, optimizer
        moments) into the new row order. Forward results are bit-equivalent
        before vs. after modulo row order (the subprocess equivalence test).

        Immediately afterwards ``locality_drift(block, method) == 1.0``
        exactly: the installed order IS the drift denominator's
        construction, and the memo is seeded with the just-measured tiles.
        """
        t0 = time.perf_counter()
        with _obs_trace.span("delta.relocalize", args={"block": block}):
            from repro.graph.structure import blocked_stats, permute_edge_index

            ei = self.edge_index()
            w = self.edge_weights()
            old_layout = plan_layout(self)
            tiles_before = int(blocked_stats(
                self.n, permute_edge_index(self.perm, ei), block)["nnz_blocks"])
            pads_before = {f"{kind}/{pods}": ts.pad
                           for (kind, pods), ts in self._tiers.items()}
            assignment = _relocalized_assignment(
                self.n, ei, self.k, block=block, method=method)
            part = partition_from_assignment(assignment, self.k, ei)
            self._rebuild_in_place(assignment, part, ei, w)
            tiles_after = int(blocked_stats(
                self.n, permute_edge_index(self.perm, ei), block)["nnz_blocks"])
            # the installed order is the drift denominator's construction —
            # seed the memo so the next drift read costs no BFS
            self._drift_memo[(block, method)] = (self._drift_era, tiles_after)
            report = {
                "graph_key": self.graph_key,
                "version": self.version,
                "block": block,
                "method": method,
                "executed_tiles_before": tiles_before,
                "executed_tiles_after": tiles_after,
                "pads_before": pads_before,
                "pads_after": {f"{kind}/{pods}": ts.pad
                               for (kind, pods), ts in self._tiers.items()},
                "old_layout": old_layout,
                "relocalize_ms": (time.perf_counter() - t0) * 1e3,
            }
        if _obs_metrics.enabled():
            from repro.obs.instrument import record_relocalize_report

            record_relocalize_report(report)
        return report

    def _tight(self) -> tuple[bool, list]:
        """(planner tight?, loose blocked-cache entries).

        Tight = no reclaimable slack anywhere: every tier is hole-free with
        pad == occupancy and builder-canonical (sorted) slot order, and the
        store capacity equals the live max. Loose blocked entries are cache
        keys whose tile capacity exceeds the live ragged maximum."""
        store_tight = self.e_local == max(int(self._cnt.max(initial=0)), 1)
        tiers_tight = all(
            not any(ts.free)
            and all(x >= 0 for ex in ts.exports for x in ex)
            and all(ex == sorted(ex) for ex in ts.exports)
            and ts.pad == max((len(ex) for ex in ts.exports), default=0)
            for ts in self._tiers.values())
        loose = []
        for p in self._plans.values():
            cache = p.__dict__.get("_blocked_cache")
            if not cache:
                continue
            for key, entry in cache.items():
                tabs = (entry if isinstance(key, tuple) and key[0] == "split"
                        else (entry,))
                if any(t.max_nnzb > max(int(t.lens.max(initial=0)), 1)
                       for t in tabs):
                    loose.append((p, key))
        return store_tight and tiers_tight, loose

    def compact(self) -> dict:
        """Shrink pads and tile capacities from their high-water marks back
        to current occupancy (docs/communication.md §8).

        Three outcomes, cheapest wins:

          * everything already tight → full no-op (``changed=False``; no
            version bump, plans untouched — a v0 planner stays bit-identical
            to `build_halo_plan`),
          * planner tight but some memoized blocked tables over-provisioned
            → drop just those cache entries (they rebuild lazily and tight;
            no version bump — the plan TABLES are unchanged),
          * otherwise → full in-place rebuild under the CURRENT assignment:
            slot heaps re-pack, survivors remap, pads drop to exact
            occupancy, and the plan cache re-keys to the next version
            (receivers still hold the old key's plans — same contract as a
            structural apply).

        Returns a report with per-tier ``pad_rows_reclaimed`` and
        ``bytes_reclaimed`` (host bytes across store, plan tables, and
        blocked tiles). Mirrored to ``delta.compact*`` metrics.
        """
        t0 = time.perf_counter()
        bytes_before = self._host_bytes()
        pads_before = {f"{kind}/{pods}": ts.pad
                       for (kind, pods), ts in self._tiers.items()}
        e_local_before = self.e_local
        tight, loose = self._tight()
        dropped = 0
        if tight and not loose:
            changed = rebuilt = False
        elif tight:
            for p, key in loose:
                del p.__dict__["_blocked_cache"][key]
                dropped += 1
            changed, rebuilt = True, False
        else:
            for p in self._plans.values():
                dropped += len(p.__dict__.get("_blocked_cache") or {})
            self._rebuild_in_place(
                self.assignment, self.part, self.edge_index(),
                self.edge_weights())
            changed = rebuilt = True
        report = {
            "graph_key": self.graph_key,
            "version": self.version,
            "changed": changed,
            "rebuilt": rebuilt,
            "pad_rows_reclaimed": {
                key: pads_before[key] - ts.pad
                for (kind, pods), ts in self._tiers.items()
                for key in [f"{kind}/{pods}"]},
            "e_local_before": e_local_before,
            "e_local_after": self.e_local,
            "blocked_entries_dropped": dropped,
            "bytes_reclaimed": bytes_before - self._host_bytes(),
            "pad_occupancy": self.pad_occupancy(),
            "compact_ms": (time.perf_counter() - t0) * 1e3,
        }
        if _obs_metrics.enabled():
            from repro.obs.instrument import record_compact_report

            record_compact_report(report)
        return report

    def pad_occupancy(self) -> dict:
        """Live occupancy vs padded capacity, per tier and for the edge
        store — the ``delta.pad_occupancy`` gauge's source. ``frac`` is the
        overall live/padded slot ratio (1.0 = nothing reclaimable)."""
        tiers = {}
        used = cap = 0
        for (kind, pods), ts in self._tiers.items():
            occ = max((len(r) for r in ts.ref), default=0)
            high = max((len(ex) for ex in ts.exports), default=0)
            tiers[f"{kind}/{pods}"] = {
                "pad": ts.pad, "occupancy": occ, "high_water": high}
            used += sum(len(r) for r in ts.ref)
            cap += self.k * ts.pad
        cnt_max = int(self._cnt.max(initial=0))
        used += int(self._cnt.sum())
        cap += self.k * self.e_local
        return {
            "tiers": tiers,
            "e_local": self.e_local,
            "e_local_occupancy": cnt_max,
            "frac": used / cap if cap else 1.0,
        }

    def _remap_class(self, plan: HaloPlan, bm, sm, d_cut, n_cut, nc_cut,
                     class_sel, structural: bool, formula, ppairs) -> int:
        """Re-encode `senders_l` for one tier class. Slots are STABLE —
        a surviving export never moves — so a surviving cut edge's encoding
        only changes when a tier pad grew (structural). Non-structural
        repairs therefore touch ONLY the class's newly-cut edges; structural
        repairs re-encode the whole class (and drop blocked caches, so no
        tile bookkeeping). All inputs are the per-cut-edge vectors extracted
        once in `apply` (`bm`/`sm` the store coordinates, `class_sel` this
        class's membership). ``ppairs`` (when the plan has live blocked
        tables) collects the (row, new sender) tile coordinates the patcher
        must recompute — newly-cut edges held fresh placeholder senders, so
        there is no old coordinate to erase."""
        pick = class_sel if structural else class_sel & nc_cut
        idx = np.nonzero(pick)[0]
        if not idx.size:
            return 0
        bi, si = bm[idx], sm[idx]
        new = formula(d_cut[idx], n_cut[idx]).astype(np.int64)
        if ppairs is not None and not structural:
            rr = self._dst[bi, si]
            for b, r_, n_ in zip(bi.tolist(), rr.tolist(), new.tolist()):
                ppairs[b].add((r_, n_))
        plan.senders_l[bi, si] = new
        return int(idx.size)

    # --------------------------------------------------------- blocked patch
    def _class_edges(self, plan: HaloPlan, b: int, which: str):
        cnt = int(self._cnt[b])
        s = plan.senders_l[b, :cnt].astype(np.int64)
        r = self._dst[b, :cnt].astype(np.int64)
        w = self._w[b, :cnt]
        real = w > 0
        s, r, w = s[real], r[real], w[real]
        if which == "interior":
            m = s < plan.n_local
            return s[m], r[m], w[m]
        if which == "boundary":
            m = s >= plan.n_local
            return s[m] - plan.n_local, r[m], w[m]
        return s, r, w

    def _patch_blocked(self, plan: HaloPlan, cache: dict, ppairs) -> int:
        """Tile-patch every memoized blocked table of one plan: the combined
        `plan_blocked_adjacency` per block size, and the interior/boundary
        `plan_split_blocked_adjacency` pairs (each class sees only its own
        re-based coordinates). Returns #tables patched."""
        n_local = plan.n_local
        parr = [
            np.array(sorted(ppairs[b]), np.int64).reshape(-1, 2)
            if ppairs[b] else np.empty((0, 2), np.int64)
            for b in range(self.k)
        ]
        done = 0
        for key, entry in cache.items():
            if isinstance(key, tuple) and key[0] == "split":
                interior, boundary = entry
                done += self._patch_one(
                    plan, interior, parr, "interior",
                    lambda p: p[p[:, 1] < n_local])
                done += self._patch_one(
                    plan, boundary, parr, "boundary",
                    lambda p: p[p[:, 1] >= n_local] - [0, n_local])
            else:
                done += self._patch_one(
                    plan, entry, parr, "combined", lambda p: p)
        return done

    def _patch_one(self, plan, pba, parr, which: str, coord) -> int:
        nbc = -(-pba.n_cols // pba.block)
        updates = []
        need = int(pba.lens.max(initial=0))
        for b in range(self.k):
            mapped = coord(parr[b])
            if mapped.shape[0] == 0:
                continue
            s, r, w = self._class_edges(plan, b, which)
            res = _tile_updates(s, r, w, mapped, nbc, pba.block)
            if res is None:
                continue
            rbs, cbs, live, tiles = res
            # current ragged slot of every touched tile (-1 = absent), via
            # a dense (R, nbc) column->slot map — no per-tile scans
            lens_b, cols_b = pba.lens[b], pba.cols[b]
            n_rows, t_cap = cols_b.shape
            slot_map = np.full((n_rows, nbc), -1, np.int64)
            rr, tt = np.nonzero(np.arange(t_cap)[None, :] < lens_b[:, None])
            slot_map[rr, cols_b[rr, tt]] = tt
            slots = slot_map[rbs, cbs]
            dn = (np.bincount(rbs[live & (slots < 0)], minlength=n_rows)
                  - np.bincount(rbs[~live & (slots >= 0)], minlength=n_rows))
            need = max(need, int((lens_b + dn).max(initial=0)))
            updates.append((b, rbs, cbs, live, tiles, slots))
        if not updates:
            return 0
        if need > pba.max_nnzb:
            pba.vals, pba.cols = _grow_tiles(
                pba.vals, pba.cols, max(need, 2 * pba.max_nnzb))
            self._tables_grown += 1
        for b, rbs, cbs, live, tiles, slots in updates:
            vals_b, cols_b, lens_b = pba.vals[b], pba.cols[b], pba.lens[b]
            # the common case — a tile that exists both before and after —
            # is ONE batched fancy write; only the rare membership changes
            # (tombstones, then appends, so the row never transiently
            # overflows its net count) replay through the scalar path
            ov = live & (slots >= 0)
            vals_b[rbs[ov], slots[ov]] = tiles[ov]
            for i in np.nonzero(~live & (slots >= 0))[0].tolist():
                _apply_tile_update(vals_b, cols_b, lens_b,
                                   int(rbs[i]), int(cbs[i]), None)
            for i in np.nonzero(live & (slots < 0))[0].tolist():
                _apply_tile_update(vals_b, cols_b, lens_b,
                                   int(rbs[i]), int(cbs[i]), tiles[i])
        return 1
