"""Named sharding policies: the model↔mesh contract (DESIGN.md §7.1, §8).

A :class:`ShardingPolicy` is a mesh plus a name→PartitionSpec dictionary.
Models never mention mesh axes; they annotate semantic activation names
(``"node_hidden"``, ``"act"``, ``"moe_buf"`` …) via ``policy.constrain`` and
the launch layer decides what those names mean on the actual mesh
(`repro.launch.shardings` builds the per-family policies). Names absent from
the policy — and everything under :data:`NO_POLICY` — pass through untouched,
so the same model code runs unsharded on one CPU device and sharded on a
multi-pod mesh.

The policy also carries the GNN **communication mode** (DESIGN.md §8):

* ``comm="broadcast"`` — the paper-faithful Fig. 5c schedule: node arrays are
  pjit-sharded and XLA inserts layer-output all-gathers for cross-shard edge
  reads. ``neighbor_table`` is the identity (senders index global rows).
* ``comm="halo"`` — the default full-graph schedule: the model runs inside
  ``shard_map`` over a :class:`~repro.dist.halo.HaloPlan` layout, and
  ``neighbor_table(h)`` returns ``[local ‖ halo]`` — the device block plus
  the exchanged boundary rows — which plan-relocalized senders index. On a
  2-level ``(pod, model)`` mesh (``halo_axes`` set, hierarchical plan bound
  via the ``send_loc``/``send_rem`` pair) the exchange is the two-phase
  hierarchical collective of ``repro.dist.halo.hier_halo_exchange``
  (docs/communication.md).

Models call ``policy.neighbor_table(x)`` before every sender-side gather and
work identically under both modes (and under :data:`NO_POLICY`, where the
table is again the identity). The halo mode only activates once the launch
layer binds the device's export rows via ``bind_halo`` inside ``shard_map``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
from jax.sharding import NamedSharding, PartitionSpec

__all__ = ["ShardingPolicy", "NO_POLICY"]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """A mesh, the PartitionSpec each named activation should carry, and the
    GNN communication mode (broadcast vs halo — DESIGN.md §8)."""

    mesh: Any = None
    specs: Mapping[str, PartitionSpec] = dataclasses.field(default_factory=dict)
    comm: str = "broadcast"            # "broadcast" | "halo"
    halo_axis: str = "model"           # mesh axis the flat exchange runs over
    halo_axes: tuple | None = None     # hierarchical axes, e.g. ("pod","model");
                                       # None → the flat single-axis schedule
    halo_via: str = "all_gather"       # collective lowering (see halo_exchange)
    halo_send_idx: Any = None          # (s_max,) device export rows; bound
                                       # inside shard_map via bind_halo
    halo_send_loc: Any = None          # (s_loc,) intra-pod export rows and
    halo_send_rem: Any = None          # (s_rem,) inter-pod export rows —
                                       # the hierarchical pair bind_halo binds
    halo_payload: str | None = None    # wire format: None/"fp32" | "bf16" |
                                       # "int8" (repro.core.quant payloads)
    halo_overlap: bool = True          # split interior/boundary aggregation
                                       # so compute hides the collective

    def spec(self, name: str) -> PartitionSpec | None:
        """The PartitionSpec registered for ``name`` (None if unconstrained)."""
        return self.specs.get(name)

    def sharding(self, name: str) -> NamedSharding | None:
        """The NamedSharding for ``name`` (None if unconstrained/mesh-less)."""
        s = self.specs.get(name)
        if self.mesh is None or s is None:
            return None
        return NamedSharding(self.mesh, s)

    def constrain(self, x: jax.Array, name: str) -> jax.Array:
        """Annotate ``x`` with the sharding registered under ``name``.

        A no-op when the policy has no mesh (the :data:`NO_POLICY` case) or
        the name is not registered — models can annotate freely without
        caring which names the launch layer chose to constrain.
        """
        sh = self.sharding(name)
        if sh is None:
            return x
        return jax.lax.with_sharding_constraint(x, sh)

    def with_specs(self, **overrides: PartitionSpec) -> "ShardingPolicy":
        """A copy with some names re-mapped (launch-layer experimentation)."""
        return dataclasses.replace(self, specs={**self.specs, **overrides})

    # ------------------------------------------------- GNN communication mode
    @property
    def is_halo(self) -> bool:
        """True once halo mode is armed: comm == "halo" AND the device's
        export rows are bound (i.e. we are inside the shard_map body) —
        either the flat ``halo_send_idx`` or the hierarchical
        ``halo_send_loc``/``halo_send_rem`` pair."""
        return self.comm == "halo" and (
            self.halo_send_idx is not None
            or (self.halo_send_loc is not None and self.halo_send_rem is not None)
        )

    def bind_halo(
        self,
        send_idx: jax.Array | None = None,
        *,
        send_loc: jax.Array | None = None,
        send_rem: jax.Array | None = None,
    ) -> "ShardingPolicy":
        """Copy with this device's export rows bound — called by the launch
        layer inside the shard_map body.

        Flat (single mesh axis): pass ``send_idx``, the device's (s_max,)
        slice of ``HaloPlan.send_idx`` — unchanged from the single-axis era.
        Hierarchical (``halo_axes=("pod", "model")``): pass the keyword pair
        ``send_loc``/``send_rem``, the device's (s_loc,) intra-pod and
        (s_rem,) inter-pod slices of ``HaloPlan.send_loc``/``send_rem``;
        ``neighbor_table`` then runs the two-phase exchange. Exactly one of
        the two forms must be provided.
        """
        if send_idx is not None and (send_loc is not None or send_rem is not None):
            raise ValueError("bind_halo takes send_idx OR (send_loc, send_rem), not both")
        if send_idx is None and (send_loc is None) != (send_rem is None):
            raise ValueError("hierarchical bind_halo needs BOTH send_loc and send_rem")
        if send_idx is None and send_loc is None:
            raise ValueError("bind_halo needs send_idx or the (send_loc, send_rem) pair")
        return dataclasses.replace(
            self, halo_send_idx=send_idx, halo_send_loc=send_loc, halo_send_rem=send_rem
        )

    def neighbor_table(self, x: jax.Array) -> jax.Array:
        """The table sender indices gather from.

        Broadcast / NO_POLICY / unbound halo: ``x`` itself (senders are
        global rows). Armed flat halo: ``[x ‖ halo_exchange(x)]`` of shape
        ``(n_local + k·s_max, d)``. Armed hierarchical halo (bound via the
        ``send_loc``/``send_rem`` pair, with ``halo_axes`` naming the
        (pod, model) axes): ``[x ‖ hier_halo_exchange(x)]`` of shape
        ``(n_local + k_model·(s_loc + n_pods·s_rem), d)``. Either way the
        plan's re-localized senders index the result. Models call this before
        every sender-side gather; receiver-side gathers stay on ``x``
        directly (receivers are always local rows). The table also feeds the
        MXU path: under ``backend="bsr"`` the GCN aggregates it through the
        per-shard blocked adjacency of
        ``repro.dist.halo.plan_blocked_adjacency`` (whose column space is
        exactly this concatenation) instead of a segment-sum — same rows,
        same exchange, blocked compute (docs/kernels.md).
        """
        if not self.is_halo:
            return x
        return jax.numpy.concatenate([x, self.halo_block(x)], axis=0)

    def halo_block(self, x: jax.Array) -> jax.Array:
        """Just the exchanged halo rows of :meth:`neighbor_table` (armed halo
        only) — the overlapped schedule consumes this directly: the boundary
        aggregation term reads the halo block while interior terms read ``x``,
        so the collective is off the interior critical path
        (``repro.dist.halo.split_halo_aggregate``, docs/communication.md).
        The wire is encoded per :attr:`halo_payload` and decoded here, so
        callers always see ``x.dtype`` rows."""
        if self.halo_send_loc is not None:
            from repro.dist.halo import hier_halo_exchange

            axes = self.halo_axes or ("pod", self.halo_axis)
            return hier_halo_exchange(
                x, self.halo_send_loc, self.halo_send_rem, axes,
                via=self.halo_via, payload=self.halo_payload,
            )
        from repro.dist.halo import halo_exchange

        return halo_exchange(
            x, self.halo_send_idx, self.halo_axis,
            via=self.halo_via, payload=self.halo_payload,
        )


#: The unsharded singleton: every ``constrain`` is the identity.
NO_POLICY = ShardingPolicy()
