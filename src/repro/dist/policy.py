"""Named sharding policies: the model↔mesh contract (DESIGN.md §7.1, §8).

A :class:`ShardingPolicy` is a mesh plus a name→PartitionSpec dictionary.
Models never mention mesh axes; they annotate semantic activation names
(``"node_hidden"``, ``"act"``, ``"moe_buf"`` …) via ``policy.constrain`` and
the launch layer decides what those names mean on the actual mesh
(`repro.launch.shardings` builds the per-family policies). Names absent from
the policy — and everything under :data:`NO_POLICY` — pass through untouched,
so the same model code runs unsharded on one CPU device and sharded on a
multi-pod mesh.

The policy also carries the GNN **communication mode** (DESIGN.md §8):

* ``comm="broadcast"`` — the paper-faithful Fig. 5c schedule: node arrays are
  pjit-sharded and XLA inserts layer-output all-gathers for cross-shard edge
  reads. ``neighbor_table`` is the identity (senders index global rows).
* ``comm="halo"`` — the default full-graph schedule: the model runs inside
  ``shard_map`` over a :class:`~repro.dist.halo.HaloPlan` layout, and
  ``neighbor_table(h)`` returns ``[local ‖ halo]`` — the device block plus
  the exchanged boundary rows — which plan-relocalized senders index.

Models call ``policy.neighbor_table(x)`` before every sender-side gather and
work identically under both modes (and under :data:`NO_POLICY`, where the
table is again the identity). The halo mode only activates once the launch
layer binds the device's export rows via ``bind_halo`` inside ``shard_map``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
from jax.sharding import NamedSharding, PartitionSpec

__all__ = ["ShardingPolicy", "NO_POLICY"]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """A mesh, the PartitionSpec each named activation should carry, and the
    GNN communication mode (broadcast vs halo — DESIGN.md §8)."""

    mesh: Any = None
    specs: Mapping[str, PartitionSpec] = dataclasses.field(default_factory=dict)
    comm: str = "broadcast"            # "broadcast" | "halo"
    halo_axis: str = "model"           # mesh axis the exchange runs over
    halo_via: str = "all_gather"       # collective lowering (see halo_exchange)
    halo_send_idx: Any = None          # (s_max,) device export rows; bound
                                       # inside shard_map via bind_halo

    def spec(self, name: str) -> PartitionSpec | None:
        """The PartitionSpec registered for ``name`` (None if unconstrained)."""
        return self.specs.get(name)

    def sharding(self, name: str) -> NamedSharding | None:
        """The NamedSharding for ``name`` (None if unconstrained/mesh-less)."""
        s = self.specs.get(name)
        if self.mesh is None or s is None:
            return None
        return NamedSharding(self.mesh, s)

    def constrain(self, x: jax.Array, name: str) -> jax.Array:
        """Annotate ``x`` with the sharding registered under ``name``.

        A no-op when the policy has no mesh (the :data:`NO_POLICY` case) or
        the name is not registered — models can annotate freely without
        caring which names the launch layer chose to constrain.
        """
        sh = self.sharding(name)
        if sh is None:
            return x
        return jax.lax.with_sharding_constraint(x, sh)

    def with_specs(self, **overrides: PartitionSpec) -> "ShardingPolicy":
        """A copy with some names re-mapped (launch-layer experimentation)."""
        return dataclasses.replace(self, specs={**self.specs, **overrides})

    # ------------------------------------------------- GNN communication mode
    @property
    def is_halo(self) -> bool:
        """True once halo mode is armed: comm == "halo" AND the device's
        export rows are bound (i.e. we are inside the shard_map body)."""
        return self.comm == "halo" and self.halo_send_idx is not None

    def bind_halo(self, send_idx: jax.Array) -> "ShardingPolicy":
        """Copy with this device's (s_max,) export rows bound — called by the
        launch layer inside the shard_map body, where ``send_idx`` is the
        device's slice of ``HaloPlan.send_idx``."""
        return dataclasses.replace(self, halo_send_idx=send_idx)

    def neighbor_table(self, x: jax.Array) -> jax.Array:
        """The table sender indices gather from.

        Broadcast / NO_POLICY / unbound halo: ``x`` itself (senders are
        global rows). Armed halo: ``[x ‖ halo_exchange(x)]`` of shape
        ``(n_local + k·s_max, d)``, which the plan's re-localized senders
        index. Models call this before every sender-side gather; receiver-side
        gathers stay on ``x`` directly (receivers are always local rows).
        """
        if not self.is_halo:
            return x
        from repro.dist.halo import halo_exchange

        halo = halo_exchange(x, self.halo_send_idx, self.halo_axis, via=self.halo_via)
        return jax.numpy.concatenate([x, halo], axis=0)


#: The unsharded singleton: every ``constrain`` is the identity.
NO_POLICY = ShardingPolicy()
