"""Named sharding policies: the model↔mesh contract (DESIGN.md §7.1).

A :class:`ShardingPolicy` is a mesh plus a name→PartitionSpec dictionary.
Models never mention mesh axes; they annotate semantic activation names
(``"node_hidden"``, ``"act"``, ``"moe_buf"`` …) via ``policy.constrain`` and
the launch layer decides what those names mean on the actual mesh
(`repro.launch.shardings` builds the per-family policies). Names absent from
the policy — and everything under :data:`NO_POLICY` — pass through untouched,
so the same model code runs unsharded on one CPU device and sharded on a
multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
from jax.sharding import NamedSharding, PartitionSpec

__all__ = ["ShardingPolicy", "NO_POLICY"]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """A mesh and the PartitionSpec each named activation should carry."""

    mesh: Any = None
    specs: Mapping[str, PartitionSpec] = dataclasses.field(default_factory=dict)

    def spec(self, name: str) -> PartitionSpec | None:
        """The PartitionSpec registered for ``name`` (None if unconstrained)."""
        return self.specs.get(name)

    def sharding(self, name: str) -> NamedSharding | None:
        """The NamedSharding for ``name`` (None if unconstrained/mesh-less)."""
        s = self.specs.get(name)
        if self.mesh is None or s is None:
            return None
        return NamedSharding(self.mesh, s)

    def constrain(self, x: jax.Array, name: str) -> jax.Array:
        """Annotate ``x`` with the sharding registered under ``name``.

        A no-op when the policy has no mesh (the :data:`NO_POLICY` case) or
        the name is not registered — models can annotate freely without
        caring which names the launch layer chose to constrain.
        """
        sh = self.sharding(name)
        if sh is None:
            return x
        return jax.lax.with_sharding_constraint(x, sh)

    def with_specs(self, **overrides: PartitionSpec) -> "ShardingPolicy":
        """A copy with some names re-mapped (launch-layer experimentation)."""
        return ShardingPolicy(mesh=self.mesh, specs={**self.specs, **overrides})


#: The unsharded singleton: every ``constrain`` is the identity.
NO_POLICY = ShardingPolicy()
