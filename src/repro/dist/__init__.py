"""repro.dist — the communication layer (DESIGN.md §7).

COIN's central claim is that minimizing inter-CE communication — exchanging
only boundary ("halo") vertices between partitions instead of broadcasting
full layer outputs (paper Fig. 5c, §IV-C) — is what buys the energy win.
This package makes that contract executable on a JAX mesh:

  policy — :class:`ShardingPolicy`, the name→PartitionSpec map every model
           threads through its forward pass (``policy.constrain(x, name)``),
           with the :data:`NO_POLICY` no-op singleton for unsharded runs.
  halo   — :class:`HaloPlan` / :func:`build_halo_plan`: host-side relocation
           of a partitioned graph into contiguous per-device blocks plus the
           padded send/edge tables, and the :func:`halo_exchange` /
           :func:`halo_aggregate` collectives (all_gather / ppermute inside
           shard_map) that ship only ``k·s_max`` halo rows per device instead
           of the ``(k−1)·n_local`` rows of the broadcast schedule. On a
           2-level ``(pod, model)`` mesh the plan turns hierarchical
           (``axes=("pod", "model")``): :func:`hier_halo_exchange` /
           :func:`hier_halo_aggregate` run a two-phase collective in which
           only deduplicated remote-needed rows (``s_rem`` per device) cross
           the expensive inter-pod tier (docs/communication.md).
  delta  — :class:`GraphDelta` / :class:`DeltaPlanner`: incremental repair
           of cached plans under edge inserts/deletes on a FIXED partition
           (docs/communication.md §7) — dirty-device segment recompute,
           keep-or-grow pads, tile-level blocked-adjacency patching, and
           versioned plan-cache re-keying — plus
           :func:`apply_delta_to_graph`, the order-preserving `GraphData`
           application the serving layer's scoped invalidation builds on.
"""
from repro.dist.compat import ensure_shard_map
from repro.dist.delta import DeltaPlanner, GraphDelta, apply_delta_to_graph
from repro.dist.halo import (
    HaloPlan,
    build_halo_plan,
    halo_aggregate,
    halo_exchange,
    hier_halo_aggregate,
    hier_halo_exchange,
)
from repro.dist.policy import NO_POLICY, ShardingPolicy

__all__ = [
    "ShardingPolicy",
    "NO_POLICY",
    "HaloPlan",
    "build_halo_plan",
    "halo_exchange",
    "halo_aggregate",
    "hier_halo_exchange",
    "hier_halo_aggregate",
    "GraphDelta",
    "DeltaPlanner",
    "apply_delta_to_graph",
    "ensure_shard_map",
]
