"""jax version compatibility shims (DESIGN.md §7.4).

The codebase targets the modern jax API surface:

  * ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
    — older jaxlib builds (≤ 0.4.x, the pinned CPU toolchain in CI) only ship
    ``jax.experimental.shard_map.shard_map`` whose replication-check kwarg is
    spelled ``check_rep``; ``ensure_shard_map`` installs a forwarding wrapper
    as ``jax.shard_map`` exactly once.
  * ``Compiled.cost_analysis() -> dict`` — older jaxlib returns a one-element
    LIST of cost dicts; ``ensure_cost_analysis_dict`` normalizes the return
    to the dict the modern API produces (the dry-run/hillclimb/tests all do
    ``(compiled.cost_analysis() or {}).get(...)``).

Importing any ``repro`` module applies both shims (``repro/__init__.py``), so
call sites use one spelling everywhere. Neither touches jax device state.
"""
from __future__ import annotations

import functools

import jax

__all__ = ["ensure_shard_map", "ensure_cost_analysis_dict"]


def ensure_shard_map():
    """Return a ``shard_map`` callable accepting the modern kwargs.

    Installs it as ``jax.shard_map`` when the running jax predates it; a
    native ``jax.shard_map`` is returned untouched.
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        return native

    from jax.experimental.shard_map import shard_map as _legacy

    @functools.wraps(_legacy)
    def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, check_rep=None, auto=frozenset()):
        check = True
        if check_rep is not None:
            check = check_rep
        if check_vma is not None:        # modern spelling wins if both given
            check = check_vma
        return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=check, auto=auto)

    jax.shard_map = shard_map
    return shard_map


def ensure_cost_analysis_dict() -> None:
    """Normalize ``jax.stages.Compiled.cost_analysis`` to return a dict.

    jaxlib ≤ 0.4.x returns ``[{...}]`` (one entry per program); the modern
    API returns the dict itself. Unwraps the singleton list, once.
    """
    cls = jax.stages.Compiled
    if getattr(cls.cost_analysis, "_repro_dict_shim", False):
        return

    legacy = cls.cost_analysis

    @functools.wraps(legacy)
    def cost_analysis(self):
        out = legacy(self)
        if isinstance(out, list):
            if not out:
                return None
            if len(out) == 1 and isinstance(out[0], dict):
                return out[0]
        return out

    cost_analysis._repro_dict_shim = True
    cls.cost_analysis = cost_analysis


ensure_shard_map()
ensure_cost_analysis_dict()
