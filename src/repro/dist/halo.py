"""Halo-exchange plans + collectives, flat and hierarchical (DESIGN.md §7–§8,
docs/communication.md).

COIN's broadcast schedule (paper Fig. 5c) ships each CE's FULL layer output
to every other CE: ``(k−1)·n_local`` rows received per device per layer. The
halo schedule ships only boundary vertices — the distinct sources of cut
edges — so each device receives at most ``k·s_max`` rows, where ``s_max`` is
the largest per-device export set. The paper's communication tradeoff is the
executable invariant

    k · s_max  <  (k − 1) · n_local        (halo beats broadcast)

checked by ``tests/test_halo_dist.py`` on the 2000-node/8-partition case.

On a single mesh axis every boundary row pays the same (worst-case) link.
COIN's deeper claim is that intra-CE and inter-CE communication are DISTINCT
cost tiers; the **hierarchical** plan (``axes=("pod", "model")``) maps that
onto a 2-level mesh: devices within a pod talk over cheap links, pods talk
over the expensive inter-pod fabric. Each device's boundary set splits into

  * an **intra-pod segment** (``send_loc``) — rows some pod-mate reads,
    padded to ``s_loc`` (the pad cheap-link traffic pays; no longer the
    global worst case), and
  * an **inter-pod segment** (``send_rem``) — rows some device in ANOTHER
    pod reads, padded to ``s_rem``. Only these deduplicated rows — the rows
    no pod-mate holds — ever cross the expensive tier.

``halo_exchange`` lowers the flat plan to one collective over the single
axis; ``hier_halo_exchange`` lowers the hierarchical plan to two phases:
an inter-pod gather of the ``(s_rem, d)`` remote exports over the ``pod``
axis, then an intra-pod gather over the ``model`` axis whose payload is the
device's own ``(s_loc, d)`` intra exports concatenated with the relayed
inter-pod block — remote rows cross the expensive link exactly once per
pod pair and are re-distributed pod-internally over the cheap tier.

``build_halo_plan`` is the one-time host-side (numpy) relocation:

  1. permute nodes into contiguous per-device blocks (``perm``), one block
     per CE of the :class:`~repro.core.partition.Partition`,
  2. pad every block to ``n_local`` rows and every export set to its tier's
     pad (``s_max``, or ``s_loc``/``s_rem``) so all devices run the same
     static shapes,
  3. re-localize edges: every edge lives on its RECEIVER's device; receivers
     become local row ids and senders index the concatenation
     ``[local block ‖ halo block]`` (layouts documented on :class:`HaloPlan`).

Since plans are pure host data and expensive to build at scale (partition +
relocation over up to 10⁷–10⁸ edges), this module also owns the **plan
cache** (DESIGN.md §8): plans are memoized per ``(graph_hash, k, mesh_axes)``
where ``mesh_axes`` is the single axis name (``"model"`` — single-axis keys
are unchanged from PR 2) or the axes tuple WITH the pod count
(``(("pod", "model"), n_pods)`` — the member-block layout depends on it), so
flat and hierarchical plans for the same graph coexist without
cross-invalidation and differently-podded meshes never collide.
``cached_halo_plan`` is the lazy entry point (the builder only runs on a
miss), ``get_halo_plan`` the eager one, and ``invalidate_halo_plans`` drops
entries — called by ``train/elastic.py`` when an elastic resize changes the
model-parallel degree (a re-partition event stales every plan derived from
the partition). For graph mutations that KEEP the partition (edge
inserts/deletes, feature-row touches) the full rebuild is no longer the
only path: `repro.dist.delta` repairs cached plans incrementally and
re-registers them under a versioned key via ``register_halo_plan``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import dequantize_payload, quantize_payload
from repro.dist.compat import ensure_shard_map
from repro.graph.ops import aggregate
from repro.graph.structure import blocked_adjacency
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

ensure_shard_map()

__all__ = [
    "HaloPlan",
    "build_halo_plan",
    "validate_pod_map",
    "pod_map_order",
    "pod_map_fingerprint",
    "halo_exchange",
    "halo_aggregate",
    "split_halo_aggregate",
    "hier_halo_exchange",
    "hier_halo_aggregate",
    "graph_fingerprint",
    "cached_halo_plan",
    "get_halo_plan",
    "register_halo_plan",
    "invalidate_halo_plans",
    "plan_cache_stats",
    "reset_plan_cache_stats",
    "relocate_node_array",
    "restore_node_array",
    "node_mask",
    "PlanLayout",
    "plan_layout",
    "PlanBlockedAdjacency",
    "plan_blocked_adjacency",
    "plan_blocked_shape",
    "plan_split_blocked_adjacency",
    "plan_split_blocked_shape",
]


@dataclasses.dataclass
class HaloPlan:
    """Static-shape relocation of a partitioned graph onto k devices.

    One plan describes ONE exchange schedule, selected by ``axes``:

    * ``axes == ("model",)`` (default) — the **flat** single-axis plan of
      DESIGN.md §7.2: one collective over ``k`` devices.
    * ``axes == ("pod", "model")`` — the **hierarchical** plan: ``k ==
      n_pods · k_model`` devices arranged pod-major (device ``g`` sits in
      pod ``g // k_model`` as member ``g % k_model``, matching the
      flattening order of ``jax.make_mesh((n_pods, k_model),
      ("pod", "model"))``), exchanged in two phases by
      :func:`hier_halo_exchange`.

    Array layout shared by both (leading axis k = one slice per device):

      perm        (n_nodes,) int64   — new position → original node id; the
                                       first ``part_sizes[0]`` entries are
                                       device 0's nodes, and so on.
      senders_l   (k, e_local) int32 — per-edge source index into the
                                       ``[local ‖ halo]`` concatenation
                                       (halo layout depends on ``axes``,
                                       see below).
      receivers_l (k, e_local) int32 — per-edge local destination row
                                       (``< n_local``).
      edge_w      (k, e_local) f32   — edge weight; exactly 0 ⇒ padding edge
                                       (contributes nothing to aggregates).
      part_sizes  (k,) int64         — real (un-padded) rows per device block;
                                       rows ≥ part_sizes[b] of block b are
                                       zero padding.

    **Flat plan** (``axes == ("model",)``): ``send_idx`` is ``(k, s_max)``
    int32 — the local rows each device exports (the distinct sources of its
    outgoing cut edges), padded with row 0. The **s_max contract**: every
    device pads its export to exactly ``s_max`` rows so all k devices run
    the same static-shape program; one exchange delivers exactly ``k·s_max``
    halo rows per device and halo slot ``j·s_max + t`` always holds row
    ``send_idx[j, t]`` of device j. ``senders_l < n_local + k·s_max``.

    **Hierarchical plan** (``axes == ("pod", "model")``): the boundary set of
    each device splits into two padded export tables —

      send_loc  (k, s_loc) int32 — rows read by some POD-MATE (cheap tier),
      send_rem  (k, s_rem) int32 — rows read by some device in ANOTHER pod
                                   (expensive tier; deduplicated — only rows
                                   no pod-mate of the reader holds).

    After the two-phase exchange, device ``(p, m)``'s neighbor table is
    ``[local (n_local) ‖ k_model member blocks of width B]`` with
    ``B = s_loc + n_pods·s_rem``; member block ``m'`` is
    ``[send_loc rows of (p, m') ‖ for q in pods: send_rem rows of (q, m')]``.
    So halo slot ``m'·B + t`` (t < s_loc) holds row ``send_loc[(p,m'), t]``
    and slot ``m'·B + s_loc + q·s_rem + t`` holds row ``send_rem[(q,m'), t]``
    — every boundary row in the system is addressable, and ``senders_l <
    n_local + k_model·B``. For hierarchical plans ``s_max``/``send_idx``
    still describe the flat single-axis exchange of the SAME partition: they
    are retained as the accounting baseline (``flat_*`` properties) and must
    NOT be mixed with the hierarchically remapped ``senders_l``.
    """

    k: int
    n_local: int                      # rows per device block (max part size)
    s_max: int                        # flat export rows per device (padded)
    e_local: int                      # edges per device (padded)
    n_nodes: int
    perm: np.ndarray
    send_idx: np.ndarray
    senders_l: np.ndarray
    receivers_l: np.ndarray
    edge_w: np.ndarray
    part_sizes: np.ndarray | None = None
    # ------------------------------------------------ hierarchy (multi-axis)
    axes: tuple[str, ...] = ("model",)
    n_pods: int = 1
    s_loc: int = 0                    # intra-pod export rows per device
    s_rem: int = 0                    # inter-pod export rows per device
    send_loc: np.ndarray | None = None
    send_rem: np.ndarray | None = None

    # ---------------------------------------------------------------- shape
    @property
    def is_hierarchical(self) -> bool:
        """True for (pod, model) plans; False for single-axis plans."""
        return len(self.axes) > 1

    @property
    def k_model(self) -> int:
        """Devices per pod (== k for flat plans, where n_pods == 1)."""
        return self.k // self.n_pods

    @property
    def block_rows(self) -> int:
        """Hierarchical per-member halo block width B = s_loc + n_pods·s_rem."""
        return self.s_loc + self.n_pods * self.s_rem

    @property
    def neighbor_table_rows(self) -> int:
        """Row count of the ``[local ‖ halo]`` table ``neighbor_table``
        concatenates per device — the column space of the per-shard blocked
        adjacency. Flat: ``n_local + k·s_max``. Hierarchical: ``n_local +
        k_model·B`` (phase-1 inter-pod rows are RELAYED inside the member
        blocks, so they do not widen the table — unlike
        :attr:`halo_rows_per_device`, which counts both phases as wire)."""
        if self.is_hierarchical:
            return self.n_local + self.intra_pod_rows_per_device
        return self.n_local + self.k * self.s_max

    # ---------------------------------------------------------------- wire
    @property
    def halo_rows_per_device(self) -> int:
        """Rows received per device per exchange under THIS plan's schedule
        (flat: ``k·s_max``; hierarchical: both phases summed)."""
        if self.is_hierarchical:
            return self.inter_pod_rows_per_device + self.intra_pod_rows_per_device
        return self.k * self.s_max

    @property
    def broadcast_rows_per_device(self) -> int:
        """Rows received per device per layer under the broadcast schedule."""
        return (self.k - 1) * self.n_local

    @property
    def inter_pod_rows_per_device(self) -> int:
        """Hierarchical phase-1 rows received per device (``n_pods·s_rem``,
        self-pod slot included for uniform static shapes)."""
        return self.n_pods * self.s_rem

    @property
    def intra_pod_rows_per_device(self) -> int:
        """Hierarchical phase-2 rows received per device over the cheap tier
        (``k_model·(s_loc + n_pods·s_rem)`` — pod-mates' intra exports plus
        the relayed inter-pod blocks)."""
        return self.k_model * self.block_rows

    @property
    def inter_pod_rows_crossing(self) -> int:
        """Rows that actually CROSS the expensive inter-pod fabric per device
        per exchange (``(n_pods−1)·s_rem`` — the self-pod slot never leaves)."""
        return (self.n_pods - 1) * self.s_rem

    @property
    def flat_inter_pod_rows_crossing(self) -> int:
        """Inter-pod crossing rows the FLAT single-axis schedule would move on
        the same partition and pod grouping: ``(n_pods−1)·k_model·s_max``
        (every remote device's full padded export reaches every device)."""
        return (self.n_pods - 1) * self.k_model * self.s_max

    def wire_fraction(self) -> float:
        """halo ÷ broadcast received-row ratio (< 1 ⇔ halo wins)."""
        return self.halo_rows_per_device / max(self.broadcast_rows_per_device, 1)

    # ------------------------------------------- interior / boundary split
    # Derived lazily from senders_l/edge_w/n_local and memoized on the
    # instance — deliberately NOT stored fields, so plans reloaded from
    # pre-overlap archives (e.g. results/halo_plan_ogb.npz) grow the split
    # for free and no serialized format changes.
    def _edge_locality(self) -> dict:
        cached = self.__dict__.get("_edge_locality_cache")
        if cached is None:
            real = self.edge_w > 0
            remote = self.senders_l >= self.n_local
            mask = np.zeros((self.k, self.n_local), bool)
            for b in range(self.k):
                mask[b, self.receivers_l[b][real[b] & remote[b]]] = True
            cached = {
                "interior_edges": int((real & ~remote).sum()),
                "boundary_edges": int((real & remote).sum()),
                "boundary_mask": mask,
            }
            self.__dict__["_edge_locality_cache"] = cached
        return cached

    def boundary_row_mask(self) -> np.ndarray:
        """(k, n_local) bool: local rows with ≥1 real halo-sender edge —
        the rows whose aggregate depends on the exchange. The complement
        (interior rows, zero-padding rows included) can be aggregated
        entirely from the local block, concurrently with the collective."""
        return self._edge_locality()["boundary_mask"]

    def interior_row_mask(self) -> np.ndarray:
        """(k, n_local) bool complement of :meth:`boundary_row_mask`."""
        return ~self.boundary_row_mask()

    def boundary_rows_per_device(self) -> np.ndarray:
        """(k,) count of boundary rows per device."""
        return self.boundary_row_mask().sum(axis=1)

    def interior_rows_per_device(self) -> np.ndarray:
        """(k,) count of interior rows per device (padding rows included)."""
        return self.interior_row_mask().sum(axis=1)

    @property
    def interior_edges(self) -> int:
        """Real edges whose sender is a local row (no wire dependence)."""
        return self._edge_locality()["interior_edges"]

    @property
    def boundary_edges(self) -> int:
        """Real edges whose sender is a halo row (wire-dependent)."""
        return self._edge_locality()["boundary_edges"]

    def overlap_fraction(self) -> float:
        """Fraction of real aggregation work with NO halo dependence — the
        interior compute available to hide the exchange behind (the
        ``1 − overlap_fraction`` of the exposed-bytes model in
        docs/communication.md and the dry-run `exchange` accounting)."""
        loc = self._edge_locality()
        total = loc["interior_edges"] + loc["boundary_edges"]
        return loc["interior_edges"] / total if total else 0.0

    # -------------------------------------------------------------- device
    def device_arrays(self) -> tuple[jnp.ndarray, ...]:
        """The plan tables as device arrays, each with the leading k axis to
        be sharded one-slice-per-device.

        Flat plans return ``(send_idx, senders_l, receivers_l, edge_w)``;
        hierarchical plans return ``(send_loc, send_rem, senders_l,
        receivers_l, edge_w)`` (the two export tiers replace ``send_idx``).
        """
        tail = (
            jnp.asarray(self.senders_l, jnp.int32),
            jnp.asarray(self.receivers_l, jnp.int32),
            jnp.asarray(self.edge_w, jnp.float32),
        )
        if self.is_hierarchical:
            return (
                jnp.asarray(self.send_loc, jnp.int32),
                jnp.asarray(self.send_rem, jnp.int32),
            ) + tail
        return (jnp.asarray(self.send_idx, jnp.int32),) + tail

    def abstract_inputs(self) -> tuple[jax.ShapeDtypeStruct, ...]:
        """ShapeDtypeStructs mirroring :meth:`device_arrays` (dry-run path):
        4-tuple for flat plans, 5-tuple for hierarchical ones."""
        tail = (
            jax.ShapeDtypeStruct((self.k, self.e_local), jnp.int32),
            jax.ShapeDtypeStruct((self.k, self.e_local), jnp.int32),
            jax.ShapeDtypeStruct((self.k, self.e_local), jnp.float32),
        )
        if self.is_hierarchical:
            return (
                jax.ShapeDtypeStruct((self.k, self.s_loc), jnp.int32),
                jax.ShapeDtypeStruct((self.k, self.s_rem), jnp.int32),
            ) + tail
        return (jax.ShapeDtypeStruct((self.k, self.s_max), jnp.int32),) + tail


# ============================================================= host builders
def _blocked_layout(assignment: np.ndarray, k: int, n: int):
    """Contiguous per-device blocks: (perm, sizes, n_local, local-row map)."""
    perm = np.argsort(assignment, kind="stable").astype(np.int64)
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n, dtype=np.int64)
    sizes = np.bincount(assignment, minlength=k).astype(np.int64)
    offsets = np.zeros(k + 1, np.int64)
    np.cumsum(sizes, out=offsets[1:])
    n_local = int(sizes.max()) if n else 0
    local = inv - offsets[assignment]          # local row of every node
    return perm, sizes, n_local, local


def _export_sets(a_sel: np.ndarray, src_sel: np.ndarray, k: int, n: int, local: np.ndarray):
    """Distinct (source device, source node) export sets of a cut-edge subset.

    Returns ``(s, send, slots_for)``: the pad ``s`` (largest per-device set),
    the padded ``(k, s)`` table of exported local rows, and a vectorized
    ``slots_for(devs, nodes) -> slot`` resolving each pair's position inside
    its device's export set.
    """
    pair = a_sel * n + src_sel                 # unique id per (dev, node)
    uniq = np.unique(pair)
    dev = uniq // max(n, 1)
    node = uniq % max(n, 1)
    counts = np.bincount(dev, minlength=k).astype(np.int64)
    s = int(counts.max()) if uniq.size else 0
    start = np.zeros(k + 1, np.int64)
    np.cumsum(counts, out=start[1:])
    send = np.zeros((k, s), np.int32)
    if uniq.size:
        slot = np.arange(uniq.size, dtype=np.int64) - start[dev]
        send[dev, slot] = local[node].astype(np.int32)

    def slots_for(devs: np.ndarray, nodes: np.ndarray) -> np.ndarray:
        # np.unique output is sorted, so searchsorted recovers each pair's
        # slot in its source device's export set.
        pos = np.searchsorted(uniq, devs * n + nodes)
        return pos - start[devs]

    return s, send, slots_for


def _group_edges_by_receiver(
    owner: np.ndarray, senders_full: np.ndarray, receivers_full: np.ndarray,
    w: np.ndarray, k: int, e: int,
):
    """Pack re-localized edges into padded per-receiver-device tables."""
    e_counts = np.bincount(owner, minlength=k).astype(np.int64)
    e_local = max(int(e_counts.max()) if e else 0, 1)
    e_start = np.zeros(k + 1, np.int64)
    np.cumsum(e_counts, out=e_start[1:])
    senders_l = np.zeros((k, e_local), np.int32)
    receivers_l = np.zeros((k, e_local), np.int32)
    edge_w = np.zeros((k, e_local), np.float32)
    if e:
        order = np.argsort(owner, kind="stable")
        own_o = owner[order]
        e_slot = np.arange(e, dtype=np.int64) - e_start[own_o]
        senders_l[own_o, e_slot] = senders_full[order].astype(np.int32)
        receivers_l[own_o, e_slot] = receivers_full[order].astype(np.int32)
        edge_w[own_o, e_slot] = w[order]
    return senders_l, receivers_l, edge_w, e_local


def validate_pod_map(pod_map: np.ndarray, k: int, pods: int) -> np.ndarray:
    """Check a part→pod map is a balanced assignment of k parts to pods.

    Every pod must host exactly ``k // pods`` parts — the halo plan realizes
    the map by relabeling parts into pod-major device slots, so an
    unbalanced map has no device raveling. Returns the map as int64.
    """
    pm = np.asarray(pod_map, dtype=np.int64)
    if pm.shape != (k,):
        raise ValueError(f"pod_map must have shape ({k},), got {pm.shape}")
    if pm.min() < 0 or pm.max() >= pods:
        raise ValueError(f"pod_map entries must lie in [0, {pods}), got {pm!r}")
    sizes = np.bincount(pm, minlength=pods)
    if np.any(sizes != k // pods):
        raise ValueError(
            f"pod_map must place exactly {k // pods} parts per pod, got sizes {sizes!r}"
        )
    return pm


def pod_map_order(pod_map: np.ndarray, k: int, pods: int) -> np.ndarray:
    """Device-slot → part order realizing ``pod_map`` pod-major.

    Slot g hosts ``order[g]``; parts mapped to pod q occupy the contiguous
    slots ``q*k_model .. (q+1)*k_model - 1`` (ties broken by part id), so
    the mesh's pod-major raveling (device g → pod ``g // k_model``) agrees
    with the map without any change to device order.
    """
    pm = validate_pod_map(pod_map, k, pods)
    return np.lexsort((np.arange(k), pm))


def pod_map_fingerprint(pod_map: np.ndarray | None) -> str:
    """Short stable hash of a part→pod map for the plan-cache key.

    ``None`` (the contiguous pod-major default) maps to ``"contig"`` so
    default-mapped plans keep their pre-autotune cache keys byte-identical.
    """
    if pod_map is None:
        return "contig"
    pm = np.ascontiguousarray(pod_map, dtype=np.int64)
    return hashlib.sha1(pm.tobytes()).hexdigest()[:16]


def build_halo_plan(
    part,
    edge_index: np.ndarray,
    w: np.ndarray | None = None,
    *,
    axes: tuple[str, ...] = ("model",),
    pods: int = 1,
    pod_map: np.ndarray | None = None,
) -> HaloPlan:
    """Relocate a :class:`~repro.core.partition.Partition` into a HaloPlan.

    edge_index — (2, E) directed (src, dst); each edge is placed on its
    destination's device. ``w`` defaults to all-ones; padding edges get
    weight 0, so ``(edge_w > 0).sum() == E`` accounts for every real edge
    exactly once (the seed-suite invariant).

    axes/pods — select the exchange schedule. The default (a single axis,
    ``pods == 1``) builds the flat plan of DESIGN.md §7.2, byte-identical to
    the pre-hierarchy builder. ``axes=("pod", "model"), pods=n`` builds the
    hierarchical plan: ``part.k`` must be divisible by ``pods``, devices are
    grouped pod-major (device g → pod ``g // (k/pods)``), and ``senders_l``
    is remapped against the two-phase halo table documented on
    :class:`HaloPlan`. Hierarchical plans also carry the flat
    ``send_idx``/``s_max`` of the same partition as the accounting baseline.

    pod_map — optional (k,) part→pod assignment from the communication-aware
    autotuner (``repro.core.autotune``). Default ``None`` keeps the
    contiguous pod-major grouping (part g → pod ``g // (k/pods)``). A map is
    realized by RELABELING parts into pod-major device slots (pod q's parts
    occupy slots ``q*k_model..``); ``perm`` absorbs the relayout, so
    collectives, meshes, and every consumer see an ordinary hierarchical
    plan — only which rows land in the deduplicated ``send_rem`` tier
    changes. Must place exactly ``k // pods`` parts per pod.
    """
    if len(axes) not in (1, 2):
        raise ValueError(f"axes must name 1 or 2 mesh axes, got {axes!r}")
    if len(axes) == 2 and len(set(axes)) != 2:
        raise ValueError(f"hierarchical axes must be distinct, got {axes!r}")
    if len(axes) == 1 and pods != 1:
        raise ValueError("pods > 1 requires two mesh axes, e.g. ('pod', 'model')")
    assignment = np.asarray(part.assignment, dtype=np.int64)
    k = int(part.k)
    if pods < 1 or k % pods:
        raise ValueError(f"pods={pods} must divide the partition's k={k}")
    if pod_map is not None:
        if len(axes) != 2:
            raise ValueError("pod_map requires hierarchical axes, e.g. ('pod', 'model')")
        order = pod_map_order(pod_map, k, pods)
        rank = np.empty(k, dtype=np.int64)
        rank[order] = np.arange(k)
        assignment = rank[assignment]
    n = int(part.n_nodes)
    src = np.asarray(edge_index[0], dtype=np.int64)
    dst = np.asarray(edge_index[1], dtype=np.int64)
    e = int(src.shape[0])
    w = np.ones(e, np.float32) if w is None else np.asarray(w, np.float32)

    # 1. contiguous per-device blocks --------------------------------------
    perm, sizes, n_local, local = _blocked_layout(assignment, k, n)
    a_s, a_d = assignment[src], assignment[dst]
    cut = a_s != a_d

    # 2. export sets: distinct (source device, source node) of cut edges ---
    s_max, send_idx, flat_slots = _export_sets(a_s[cut], src[cut], k, n, local)

    hierarchical = len(axes) == 2
    senders_full = local[src].copy()
    if hierarchical:
        # Tier split: an intra-pod cut edge reads a pod-mate's row (cheap
        # link); an inter-pod cut edge reads a row no pod-mate holds
        # (expensive link). Padding is per tier, so cheap traffic no longer
        # pays the global worst-case s_max.
        k_model = k // pods
        p_s, p_d = a_s // k_model, a_d // k_model
        m_s = a_s % k_model
        icut = cut & (p_s == p_d)
        xcut = p_s != p_d
        s_loc, send_loc, loc_slots = _export_sets(a_s[icut], src[icut], k, n, local)
        s_rem, send_rem, rem_slots = _export_sets(a_s[xcut], src[xcut], k, n, local)
        B = s_loc + pods * s_rem
        if np.any(icut):
            senders_full[icut] = (
                n_local + m_s[icut] * B + loc_slots(a_s[icut], src[icut])
            )
        if np.any(xcut):
            senders_full[xcut] = (
                n_local + m_s[xcut] * B + s_loc
                + p_s[xcut] * s_rem + rem_slots(a_s[xcut], src[xcut])
            )
    else:
        s_loc = s_rem = 0
        send_loc = send_rem = None
        if np.any(cut):
            senders_full[cut] = n_local + a_s[cut] * s_max + flat_slots(a_s[cut], src[cut])

    # 3. re-localized edges, grouped by the receiver's device --------------
    senders_l, receivers_l, edge_w, e_local = _group_edges_by_receiver(
        a_d, senders_full, local[dst], w, k, e
    )

    return HaloPlan(
        k=k, n_local=n_local, s_max=s_max, e_local=e_local, n_nodes=n,
        perm=perm, send_idx=send_idx, senders_l=senders_l,
        receivers_l=receivers_l, edge_w=edge_w, part_sizes=sizes,
        axes=tuple(axes), n_pods=pods, s_loc=s_loc, s_rem=s_rem,
        send_loc=send_loc, send_rem=send_rem,
    )


# ===================================================================== cache
# Plans are pure host data keyed by (graph_hash, k, mesh_axes); one build
# serves every layer of every epoch. The axes component is the single axis
# name (str — unchanged from the single-axis era) or the hierarchical
# (axes tuple, n_pods) pair, so flat and (pod, model) plans for one graph
# coexist side by side and differently-podded meshes never collide.
_PLAN_CACHE: dict[tuple[str, int, object], HaloPlan] = {}
_PLAN_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _observe_cache_stats() -> None:
    """Mirror the cache counters into ``plan_cache.*`` gauges — kept in
    lockstep with every hit/miss/eviction so an exported snapshot always
    equals :func:`plan_cache_stats` (the pinned obs equality test)."""
    if not _obs_metrics.enabled():
        return
    _obs_metrics.set_gauge("plan_cache.hits", _PLAN_STATS["hits"])
    _obs_metrics.set_gauge("plan_cache.misses", _PLAN_STATS["misses"])
    _obs_metrics.set_gauge("plan_cache.evictions", _PLAN_STATS["evictions"])
    _obs_metrics.set_gauge("plan_cache.size", len(_PLAN_CACHE))


def graph_fingerprint(
    n_nodes: int,
    edge_index: np.ndarray,
    w: np.ndarray | None = None,
    assignment: np.ndarray | None = None,
) -> str:
    """Stable content hash of a (graph, weights, partition) triple.

    Used as the ``graph_hash`` component of the plan-cache key when the
    caller has materialized arrays; callers that synthesize graphs
    deterministically (e.g. the launch layer's shape-statistics graphs) can
    pass their own string key instead and skip the hash entirely.
    """
    h = hashlib.sha1()
    h.update(np.int64(n_nodes).tobytes())
    h.update(np.ascontiguousarray(edge_index, dtype=np.int64).tobytes())
    if w is not None:
        h.update(np.ascontiguousarray(w, dtype=np.float32).tobytes())
    if assignment is not None:
        h.update(np.ascontiguousarray(assignment, dtype=np.int32).tobytes())
    return h.hexdigest()


def _hier_key_axes(
    mesh_axis: "str | tuple[str, ...]", pods: int, pod_map: np.ndarray | None
) -> object:
    """The axes component of a plan-cache key.

    Flat plans keep the bare axis name (pre-hierarchy key, unchanged).
    Hierarchical plans use ``(axes, pods)`` — and, only when a non-default
    ``pod_map`` is present, ``(axes, pods, pod_map_fingerprint)``: autotuned
    and default plans of one graph coexist without cross-invalidation, while
    ``invalidate_halo_plans(graph_key=...)`` still sweeps every flavor (the
    fingerprint lives inside the axes component, never in ``key[0]``).
    """
    if isinstance(mesh_axis, str):
        return mesh_axis
    if pod_map is None:
        return (tuple(mesh_axis), int(pods))
    return (tuple(mesh_axis), int(pods), pod_map_fingerprint(pod_map))


def cached_halo_plan(
    graph_key: str,
    k: int,
    mesh_axis: "str | tuple[str, ...]" = "model",
    *,
    pods: int = 1,
    pod_map: np.ndarray | None = None,
    builder: Callable[[], HaloPlan],
) -> HaloPlan:
    """Memoized plan lookup: ``builder()`` runs only on a cache miss.

    ``graph_key`` identifies the graph (and, when relevant, the partition) —
    either a :func:`graph_fingerprint` or any caller-chosen stable string.
    ``mesh_axis`` completes the key ``(graph_key, k, mesh_axis)``: a single
    axis name for flat plans (the pre-hierarchy key, unchanged — ``pods``
    is ignored) or the axes tuple — e.g. ``("pod", "model")`` — for
    hierarchical plans, where ``pods`` joins the key component (the
    member-block layout depends on the pod count, so a 2×4 and a 4×2 plan
    of the same k=8 partition must never collide). Flat and hierarchical
    plans therefore coexist without cross-invalidation. The lazy builder
    matters at scale: on a hit, neither the graph nor the partition needs
    to exist in memory at all. An autotuned ``pod_map`` joins the key via
    its fingerprint (see :func:`_hier_key_axes`), so autotuned and default
    mappings of the same graph coexist too.
    """
    key_axes = _hier_key_axes(mesh_axis, pods, pod_map)
    key = (graph_key, int(k), key_axes)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_STATS["hits"] += 1
        _observe_cache_stats()
        return plan
    _PLAN_STATS["misses"] += 1
    with _obs_trace.span("halo.plan_build", args={"k": int(k)}):
        t0 = time.perf_counter()
        plan = builder()
        if _obs_metrics.enabled():
            _obs_metrics.observe(
                "halo.plan_build_ms", (time.perf_counter() - t0) * 1e3
            )
    _PLAN_CACHE[key] = plan
    _observe_cache_stats()
    return plan


def get_halo_plan(
    part,
    edge_index: np.ndarray,
    w: np.ndarray | None = None,
    *,
    mesh_axis: "str | tuple[str, ...]" = "model",
    graph_key: str | None = None,
    pods: int | None = None,
    pod_map: np.ndarray | None = None,
) -> HaloPlan:
    """Cached :func:`build_halo_plan`: same graph/partition/k/axes → same
    object.

    When ``graph_key`` is omitted the key is content-hashed from the edge
    list, weights, AND the partition assignment (two partitions of the same
    graph never collide). Mutating the graph or re-partitioning produces a
    different key, i.e. a fresh plan.

    Single-axis (default): ``mesh_axis`` is the axis name, exactly as before
    the hierarchy landed. Hierarchical: pass ``pods=n`` (axes default to
    ``("pod", mesh_axis)``) or ``mesh_axis=("pod", "model")`` explicitly —
    ``pods`` is then required; the cache key's axes component is the
    (axes, pods) pair, so plans for different pod counts never collide.
    An autotuned ``pod_map`` (hierarchical only) adds its fingerprint to
    that component, so tuned and default mappings coexist — and one scoped
    ``invalidate_halo_plans(graph_key=...)`` still sweeps both.
    """
    if isinstance(mesh_axis, tuple):
        axes = mesh_axis
        if len(axes) == 2 and not pods:
            raise ValueError(f"hierarchical axes {axes!r} require pods=<n_pods>")
    elif pods and pods > 1:
        axes = ("pod", mesh_axis)
    else:
        axes = (mesh_axis,)
    n_pods = pods if len(axes) == 2 else 1
    key_axes = axes if len(axes) > 1 else axes[0]
    if graph_key is None:
        graph_key = graph_fingerprint(part.n_nodes, edge_index, w, part.assignment)
    return cached_halo_plan(
        graph_key, part.k, key_axes, pods=n_pods, pod_map=pod_map,
        builder=lambda: build_halo_plan(
            part, edge_index, w, axes=axes, pods=n_pods, pod_map=pod_map
        ),
    )


def register_halo_plan(
    graph_key: str,
    k: int,
    mesh_axis: "str | tuple[str, ...]" = "model",
    *,
    pods: int = 1,
    pod_map: np.ndarray | None = None,
    plan: HaloPlan,
) -> HaloPlan:
    """Install an already-built plan under the cache key the lazy lookups
    use — the write-side counterpart of :func:`cached_halo_plan`.

    `repro.dist.delta` repairs plan objects in place and re-registers them
    here under the mutated graph's new versioned key, so the next
    ``cached_halo_plan``/``get_halo_plan`` with that key is a HIT and never
    re-runs the builder. Overwriting an existing entry is allowed (latest
    registration wins) and is not counted as an eviction.
    """
    key_axes = _hier_key_axes(mesh_axis, pods, pod_map)
    _PLAN_CACHE[(graph_key, int(k), key_axes)] = plan
    return plan


def invalidate_halo_plans(graph_key: str | None = None, *, k: int | None = None) -> int:
    """Drop cached plans (all of them, or one graph's). Returns #evicted.

    Matching is on the ``graph_key`` component (optionally narrowed by
    ``k``), so ONE scoped call evicts a graph's flat plan AND every
    hierarchical variant — all ``(axes, n_pods)`` key flavors sharing that
    hash — together, while plans of other graphs coexist untouched.
    ``train/elastic.py`` calls this on an elastic resize that changes the
    model-parallel degree: the node→CE partition is stale, so every plan
    derived from it is too. The next ``get_halo_plan``/``cached_halo_plan``
    rebuilds from scratch. Graph mutations that keep the partition should
    prefer the incremental path: `repro.dist.delta.DeltaPlanner` repairs the
    plan objects and moves them to the new key via :func:`register_halo_plan`
    instead of rebuilding.
    """
    if graph_key is None:
        n = len(_PLAN_CACHE)
        _PLAN_CACHE.clear()
        _PLAN_STATS["evictions"] += n
        _observe_cache_stats()
        return n
    victims = [
        key for key in _PLAN_CACHE
        if key[0] == graph_key and (k is None or key[1] == k)
    ]
    for key in victims:
        del _PLAN_CACHE[key]
    _PLAN_STATS["evictions"] += len(victims)
    _observe_cache_stats()
    return len(victims)


def plan_cache_stats() -> dict[str, int]:
    """{'hits', 'misses', 'evictions', 'size'} counters. hits/misses/
    evictions accumulate since process start or the last
    :func:`reset_plan_cache_stats`; ``size`` is the current entry count."""
    return {**_PLAN_STATS, "size": len(_PLAN_CACHE)}


def reset_plan_cache_stats() -> None:
    """Zero the hit/miss/eviction counters (cached plans stay resident).

    Long-lived serving processes sample :func:`plan_cache_stats` per
    reporting interval; without a reset the counters are process-lifetime
    and interval hit rates are unrecoverable."""
    for key in _PLAN_STATS:
        _PLAN_STATS[key] = 0


# ============================================================= host relayout
def relocate_node_array(plan: HaloPlan, x: np.ndarray) -> np.ndarray:
    """Scatter a global per-node array (n_nodes, …) into the plan's blocked
    layout (k, n_local, …); rows past ``part_sizes[b]`` are zero padding."""
    if plan.part_sizes is None:
        raise ValueError("plan has no part_sizes (built by an older writer)")
    x = np.asarray(x)
    out = np.zeros((plan.k, plan.n_local) + x.shape[1:], x.dtype)
    off = 0
    for b in range(plan.k):
        sz = int(plan.part_sizes[b])
        out[b, :sz] = x[plan.perm[off:off + sz]]
        off += sz
    return out


def restore_node_array(plan: HaloPlan, blocks: np.ndarray) -> np.ndarray:
    """Inverse of :func:`relocate_node_array`: gather (k, n_local, …) device
    blocks back into global node order, dropping the padding rows."""
    if plan.part_sizes is None:
        raise ValueError("plan has no part_sizes (built by an older writer)")
    blocks = np.asarray(blocks)
    out = np.zeros((plan.n_nodes,) + blocks.shape[2:], blocks.dtype)
    off = 0
    for b in range(plan.k):
        sz = int(plan.part_sizes[b])
        out[plan.perm[off:off + sz]] = blocks[b, :sz]
        off += sz
    return out


def node_mask(plan: HaloPlan) -> np.ndarray:
    """(k, n_local) float32 validity mask: 1 on real rows, 0 on padding."""
    if plan.part_sizes is None:
        raise ValueError("plan has no part_sizes (built by an older writer)")
    rows = np.arange(plan.n_local)[None, :]
    return (rows < np.asarray(plan.part_sizes)[:, None]).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class PlanLayout:
    """Frozen snapshot of JUST a plan's blocked row layout.

    :func:`relocate_node_array` / :func:`restore_node_array` only read
    ``k / n_local / n_nodes / perm / part_sizes``, so this snapshot is a
    drop-in "plan" for them. An in-place re-localization
    (`repro.dist.delta.DeltaPlanner.relocalize`) mutates the live plan
    objects — a PlanLayout captured beforehand is the only remaining handle
    on the OLD row order, which is exactly what
    `repro.train.elastic.relocate_state_tree` needs to carry live per-node
    state across the swap.
    """

    k: int
    n_local: int
    n_nodes: int
    perm: np.ndarray
    part_sizes: np.ndarray


def plan_layout(plan) -> PlanLayout:
    """Snapshot the blocked row layout of a plan — or of anything carrying
    ``k / n_local / perm / part_sizes`` (a `DeltaPlanner` works). Arrays are
    copied: the snapshot stays valid after the source is rebuilt in place."""
    if plan.part_sizes is None:
        raise ValueError("plan has no part_sizes (built by an older writer)")
    perm = np.array(plan.perm, np.int64, copy=True)
    return PlanLayout(
        k=int(plan.k), n_local=int(plan.n_local), n_nodes=int(perm.shape[0]),
        perm=perm, part_sizes=np.array(plan.part_sizes, np.int64, copy=True))


# =============================================== blocked (BSR) halo adjacency
@dataclasses.dataclass
class PlanBlockedAdjacency:
    """Per-device ragged BSR over the ``[local ‖ halo]`` neighbor table.

    The ``backend="bsr"`` counterpart of a plan's edge lists (DESIGN.md §2,
    docs/kernels.md): device b's rows span its ``n_local`` local receivers
    and its columns span the full ``n_local + halo`` table that
    ``policy.neighbor_table`` produces inside shard_map, so the MXU kernel
    aggregates exactly the rows the segment path gathers. Arrays carry the
    leading k axis to be sharded one-slice-per-device (like
    :meth:`HaloPlan.device_arrays`); T is the max nonzero-tile count across
    ALL devices (uniform static shapes), with per-device raggedness kept in
    ``lens`` so the kernel skips the cross-device padding too.

      vals : (k, R, T, B, B) float32 — dense tiles
      cols : (k, R, T) int32         — column-block ids into the padded table
      lens : (k, R) int32            — ragged valid-tile counts
    """

    vals: np.ndarray
    cols: np.ndarray
    lens: np.ndarray
    block: int
    n_rows: int                        # n_local (receiver rows per device)
    n_cols: int                        # n_local + halo rows (table width)

    @property
    def k(self) -> int:
        return int(self.vals.shape[0])

    @property
    def n_block_rows(self) -> int:
        return int(self.vals.shape[1])

    @property
    def max_nnzb(self) -> int:
        return int(self.vals.shape[2])

    @property
    def nnz_blocks(self) -> int:
        """Total nonzero tiles across all devices."""
        return int(self.lens.sum())

    @property
    def nnz_blocks_max_device(self) -> int:
        """Critical-path device's nonzero tiles (devices run in lockstep)."""
        return int(self.lens.sum(axis=1).max(initial=0))

    @property
    def padded_tile_fraction(self) -> float:
        """Fraction of the (k, R, T) tile tables that is padding — skipped
        by the ragged kernel, paid in full by a dense-T one."""
        grid = self.k * self.n_block_rows * self.max_nnzb
        return 1.0 - self.nnz_blocks / max(grid, 1)

    def stats(self) -> dict:
        """The dry-run / benchmark accounting record (all static host ints)."""
        return {
            "block": self.block,
            "n_block_rows": self.n_block_rows,
            "max_nnzb": self.max_nnzb,
            "nnz_blocks": self.nnz_blocks,
            "nnz_blocks_max_device": self.nnz_blocks_max_device,
            "padded_tile_fraction": self.padded_tile_fraction,
        }

    def device_arrays(self) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """(vals, cols, lens) as device arrays, leading k axis to shard."""
        return (
            jnp.asarray(self.vals),
            jnp.asarray(self.cols, jnp.int32),
            jnp.asarray(self.lens, jnp.int32),
        )

    def abstract_inputs(self) -> tuple[jax.ShapeDtypeStruct, ...]:
        """ShapeDtypeStructs mirroring :meth:`device_arrays` (dry-run path)."""
        k, R, T, B = self.k, self.n_block_rows, self.max_nnzb, self.block
        return (
            jax.ShapeDtypeStruct((k, R, T, B, B), jnp.float32),
            jax.ShapeDtypeStruct((k, R, T), jnp.int32),
            jax.ShapeDtypeStruct((k, R), jnp.int32),
        )


def _plan_real_edges(plan: HaloPlan, b: int):
    """Device b's real (non-padding) re-localized edges: (senders, receivers, w)."""
    mask = plan.edge_w[b] > 0
    return (
        plan.senders_l[b][mask].astype(np.int64),
        plan.receivers_l[b][mask].astype(np.int64),
        plan.edge_w[b][mask],
    )


def plan_blocked_shape(plan: HaloPlan, block: int = 128) -> dict:
    """Blocked-adjacency statistics of a plan WITHOUT materializing tiles.

    Counts each device's distinct (receiver-block, sender-block) pairs over
    the real edges — O(E) ints, no (…, B, B) allocation — so abstract
    dry-run cells (`repro.launch.steps`) can size ``backend="bsr"`` batch
    entries and report nonzero-block / padded-tile accounting at shapes
    (ogbn-products) where materializing the tiles would not fit. Returns the
    :meth:`PlanBlockedAdjacency.stats` dict plus ``n_rows``/``n_cols``.
    """
    n_cols = plan.neighbor_table_rows
    nbr = max(-(-plan.n_local // block), 1)
    nbc = -(-n_cols // block)
    lens = np.zeros((plan.k, nbr), np.int64)
    for b in range(plan.k):
        s, r, _ = _plan_real_edges(plan, b)
        uniq = np.unique((r // block) * nbc + (s // block))
        lens[b] = np.bincount(uniq // nbc, minlength=nbr)
    T = max(int(lens.max(initial=1)), 1)
    nnz = int(lens.sum())
    return {
        "block": block,
        "n_rows": plan.n_local,
        "n_cols": n_cols,
        "n_block_rows": nbr,
        "max_nnzb": T,
        "nnz_blocks": nnz,
        "nnz_blocks_max_device": int(lens.sum(axis=1).max(initial=0)),
        "padded_tile_fraction": 1.0 - nnz / max(plan.k * nbr * T, 1),
    }


def plan_blocked_adjacency(plan: HaloPlan, block: int = 128) -> PlanBlockedAdjacency:
    """Materialize (and cache next to the plan) the per-shard blocked
    adjacency that lets ``backend="bsr"`` run inside the halo shard_map path.

    Each device's real edges — padding edges carry ``edge_w == 0`` and are
    dropped, so padded gathers never materialize a tile — are blocked over
    the rectangular (n_local) × (n_local + halo) space by
    `repro.graph.structure.blocked_adjacency`, then padded to the max
    nonzero-tile count T across devices (uniform shapes for shard_map). The
    result is memoized on the plan instance per block size: like the plan
    itself, one host-side build serves every layer of every epoch, and
    dropping the plan (cache invalidation on re-partition) drops the blocks
    with it.
    """
    cache = plan.__dict__.setdefault("_blocked_cache", {})
    hit = cache.get(block)
    if hit is not None:
        return hit
    _obs_trace.instant("halo.blocked_build", {"block": block})
    n_cols = plan.neighbor_table_rows
    nbr = max(-(-plan.n_local // block), 1)
    per_dev = []
    for b in range(plan.k):
        s, r, w = _plan_real_edges(plan, b)
        per_dev.append(
            blocked_adjacency(
                max(plan.n_local, 1), np.stack([s, r]), w, block, n_col_nodes=n_cols
            )
        )
    T = max(ba.max_nnzb for ba in per_dev)
    vals = np.zeros((plan.k, nbr, T, block, block), np.float32)
    cols = np.zeros((plan.k, nbr, T), np.int32)
    lens = np.zeros((plan.k, nbr), np.int32)
    for b, ba in enumerate(per_dev):
        t = ba.max_nnzb
        vals[b, :, :t] = ba.block_vals
        cols[b, :, :t] = ba.block_cols
        cols[b, :, t:] = ba.block_cols[:, -1:]   # repeat-last padding contract
        lens[b] = ba.row_nnzb
    out = PlanBlockedAdjacency(
        vals=vals, cols=cols, lens=lens, block=block,
        n_rows=plan.n_local, n_cols=n_cols,
    )
    cache[block] = out
    if _obs_metrics.enabled():
        from repro.obs.instrument import record_blocked

        record_blocked(out, scope="plan")
    return out


def _part_edges(plan: HaloPlan, b: int, boundary: bool):
    """Device b's real edges restricted to one locality class. Boundary
    senders are re-based into the halo-only column space (− n_local)."""
    s, r, w = _plan_real_edges(plan, b)
    m = (s >= plan.n_local) if boundary else (s < plan.n_local)
    return s[m] - (plan.n_local if boundary else 0), r[m], w[m]


def _part_blocked(plan: HaloPlan, block: int, boundary: bool) -> PlanBlockedAdjacency:
    n_cols = plan.neighbor_table_rows - plan.n_local if boundary else plan.n_local
    n_cols = max(n_cols, 1)
    nbr = max(-(-plan.n_local // block), 1)
    per_dev = []
    for b in range(plan.k):
        s, r, w = _part_edges(plan, b, boundary)
        per_dev.append(
            blocked_adjacency(
                max(plan.n_local, 1), np.stack([s, r]), w, block, n_col_nodes=n_cols
            )
        )
    T = max(ba.max_nnzb for ba in per_dev)
    vals = np.zeros((plan.k, nbr, T, block, block), np.float32)
    cols = np.zeros((plan.k, nbr, T), np.int32)
    lens = np.zeros((plan.k, nbr), np.int32)
    for b, ba in enumerate(per_dev):
        t = ba.max_nnzb
        vals[b, :, :t] = ba.block_vals
        cols[b, :, :t] = ba.block_cols
        cols[b, :, t:] = ba.block_cols[:, -1:]   # repeat-last padding contract
        lens[b] = ba.row_nnzb
    return PlanBlockedAdjacency(
        vals=vals, cols=cols, lens=lens, block=block,
        n_rows=plan.n_local, n_cols=n_cols,
    )


def plan_split_blocked_adjacency(
    plan: HaloPlan, block: int = 128
) -> tuple[PlanBlockedAdjacency, PlanBlockedAdjacency]:
    """The overlapped-schedule BSR pair ``(interior, boundary)``.

    The combined :func:`plan_blocked_adjacency` table makes every output
    tile read the ``[local ‖ halo]`` column space, so the whole kernel
    waits on the exchange. Splitting by sender locality re-blocks each
    class independently (Pallas BlockSpec index maps run for every grid
    step, so the boundary tiles must be their own ragged table — a
    truncated view of the combined one would still prefetch halo columns):

      * ``interior`` — columns span the (n_local) local block only; its
        ``bsr_spmm`` has no data dependence on the collective.
      * ``boundary`` — columns span the halo-only space (senders − n_local,
        width ``neighbor_table_rows − n_local``); its ``bsr_spmm`` consumes
        the gathered halo block directly.

    ``interior(z) + boundary(halo)`` ≡ ``combined([z ‖ halo])`` row for row
    (every real edge lands in exactly one class). Memoized on the plan like
    the combined table.
    """
    cache = plan.__dict__.setdefault("_blocked_cache", {})
    key = ("split", block)
    hit = cache.get(key)
    if hit is None:
        hit = (
            _part_blocked(plan, block, boundary=False),
            _part_blocked(plan, block, boundary=True),
        )
        cache[key] = hit
    return hit


def plan_split_blocked_shape(plan: HaloPlan, block: int = 128) -> dict:
    """:func:`plan_blocked_shape` for the split pair — O(E) statistics, no
    tiles. Returns ``{"interior": stats, "boundary": stats,
    "overlap_fraction": f}`` so abstract dry-run cells can size the two
    ragged tables and report how much aggregation work hides the wire.
    """
    out = {}
    for name, boundary in (("interior", False), ("boundary", True)):
        n_cols = plan.neighbor_table_rows - plan.n_local if boundary else plan.n_local
        n_cols = max(n_cols, 1)
        nbr = max(-(-plan.n_local // block), 1)
        nbc = max(-(-n_cols // block), 1)
        lens = np.zeros((plan.k, nbr), np.int64)
        for b in range(plan.k):
            s, r, _ = _part_edges(plan, b, boundary)
            uniq = np.unique((r // block) * nbc + (s // block))
            lens[b] = np.bincount(uniq // nbc, minlength=nbr)
        T = max(int(lens.max(initial=1)), 1)
        nnz = int(lens.sum())
        out[name] = {
            "block": block,
            "n_rows": plan.n_local,
            "n_cols": n_cols,
            "n_block_rows": nbr,
            "max_nnzb": T,
            "nnz_blocks": nnz,
            "nnz_blocks_max_device": int(lens.sum(axis=1).max(initial=0)),
            "padded_tile_fraction": 1.0 - nnz / max(plan.k * nbr * T, 1),
        }
    out["overlap_fraction"] = plan.overlap_fraction()
    return out


# ======================================================= device collectives
def _axis_gather(export: jnp.ndarray, axis_name: str, via: str) -> jnp.ndarray:
    """Gather every device's ``(s, d)`` export block along one named mesh
    axis → ``(axis_size·s, d)``, slots in absolute device order.

    via="all_gather" lowers to one fused collective; via="ppermute" runs an
    axis_size−1 step neighbor ring (the NoC-shaped schedule COIN's mesh
    model assumes) — identical results, different lowering.
    """
    if export.shape[0] == 0:
        # Nothing crosses this tier; XLA rejects zero-width collectives,
        # and (axis_size·0, d) == (0, d) anyway.
        return export
    if via == "all_gather":
        return jax.lax.all_gather(export, axis_name, axis=0, tiled=True)
    if via != "ppermute":
        raise ValueError(f"unknown exchange lowering: {via!r}")
    k = jax.lax.psum(1, axis_name)                        # static axis size
    perm = [((j + 1) % k, j) for j in range(k)]
    blocks, cur = [export], export
    for _ in range(k - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        blocks.append(cur)
    # blocks[t] on device i is device (i+t) mod k's export; roll by the
    # device index to arrange slots in absolute device order.
    stack = jnp.stack(blocks)                             # (k, s, d)
    stack = jnp.roll(stack, jax.lax.axis_index(axis_name), axis=0)
    return stack.reshape(k * export.shape[0], *export.shape[1:])


def _quantized_gather(
    export: jnp.ndarray, axis_name: str, via: str, payload: str | None
) -> jnp.ndarray:
    """:func:`_axis_gather` with the export block encoded for the wire.

    Only the quantized representation (plus, for int8, one fp32 scale per
    export block) crosses the fabric; the gathered rows are decoded back to
    the compute dtype on receive, so callers see the same shapes/dtypes as
    the fp32 path — only wire bytes change (× bits/32).
    """
    if payload in (None, "fp32") or export.shape[0] == 0:
        return _axis_gather(export, axis_name, via)
    wire, scale = quantize_payload(export, payload)
    gathered = _axis_gather(wire, axis_name, via)
    if scale is None:                                     # bf16: plain upcast
        return gathered.astype(export.dtype)
    scales = _axis_gather(scale, axis_name, via)          # (n_dev, 1) fp32
    return dequantize_payload(gathered, scales, export.dtype)


def halo_exchange(
    h: jnp.ndarray,
    send_idx: jnp.ndarray,
    axis_name: str,
    via: str = "all_gather",
    payload: str | None = None,
) -> jnp.ndarray:
    """Exchange boundary rows across ONE named mesh axis (inside shard_map).

    h        — (n_local, d) this device's block.
    send_idx — (s_max,) local rows this device exports.
    payload  — wire format (`repro.core.quant.quantize_payload`): None/"fp32"
               ships raw rows; "bf16"/"int8" quantize the export before the
               collective and dequantize on receive (int8 carries one fp32
               scale per sender block).
    Returns the (k·s_max, d) halo block: slot ``j·s_max + t`` holds row
    ``send_idx[j, t]`` of device j, for every j including self (the self
    rows are redundant but keep the indexing uniform and the shapes static).
    This is the flat schedule; hierarchical (pod, model) plans go through
    :func:`hier_halo_exchange` instead.
    """
    return _quantized_gather(h[send_idx], axis_name, via, payload)


def hier_halo_exchange(
    h: jnp.ndarray,
    send_loc: jnp.ndarray,
    send_rem: jnp.ndarray,
    axes: tuple[str, str] = ("pod", "model"),
    via: str = "all_gather",
    payload: str | None = None,
) -> jnp.ndarray:
    """Two-phase (pod, model) boundary exchange (inside shard_map).

    h        — (n_local, d) this device's block.
    send_loc — (s_loc,) local rows some pod-mate reads.
    send_rem — (s_rem,) local rows some OTHER pod reads (the deduplicated
               inter-pod segment — the only rows that cross the expensive
               tier).

    Phase 1 (inter-pod, ``axes[0]``): gather the ``(s_rem, d)`` remote
    exports across pods → ``(n_pods·s_rem, d)``; only these rows pay the
    inter-pod fabric. Phase 2 (intra-pod, ``axes[1]``): gather
    ``[h[send_loc] ‖ phase-1 block]`` across pod-mates — the cheap tier
    both distributes local boundary rows and relays every remote row to the
    pod members that need it. Returns the ``(k_model·B, d)`` halo block,
    ``B = s_loc + n_pods·s_rem``, in the member-block layout documented on
    :class:`HaloPlan` (slot ``m'·B + t`` ↦ intra row t of pod-mate m'; slot
    ``m'·B + s_loc + q·s_rem + t`` ↦ remote row t of device (q, m')).

    ``payload`` quantizes BOTH phases' wire blocks independently. For int8
    the relayed inter-pod rows are therefore rounded twice (dequantized
    after phase 1, re-quantized into the phase-2 block) — the documented
    extra hierarchical int8 error, bounded by one extra amax/127 half-step.
    bf16 is closed under the relay (a bf16 value re-cast to bf16 is itself),
    so the hierarchical bf16 path adds no second rounding.
    """
    pod_axis, model_axis = axes
    inter = _quantized_gather(h[send_rem], pod_axis, via, payload)
    block = jnp.concatenate([h[send_loc], inter], axis=0)  # (B, d)
    return _quantized_gather(block, model_axis, via, payload)


def split_halo_aggregate(
    z: jnp.ndarray,
    halo: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_w: jnp.ndarray,
) -> jnp.ndarray:
    """Interior/boundary-split aggregation over an already-gathered halo.

    The serialized form ``aggregate(concat([z, halo]), …)`` makes EVERY
    output row data-dependent on the collective that produced ``halo``.
    Splitting the edge set by sender locality —

      interior:  O_int[r] = Σ_{s < n_local}  w · z[s]        (no wire dep)
      boundary:  O_bnd[r] = Σ_{s ≥ n_local}  w · halo[s−n_local]

    — leaves the interior term a pure function of the local block, so XLA's
    latency-hiding scheduler is free to run it WHILE the exchange is in
    flight and only the (small) boundary term waits on the wire; that is
    the overlapped schedule of docs/communication.md. Masked weights (not
    gathered subsets) keep shapes static: each edge contributes to exactly
    one term, so interior + boundary ≡ the serialized sum exactly (padding
    edges carry w == 0 and vanish from both).
    """
    n_local = z.shape[0]
    if halo.shape[0] == 0:
        return aggregate(
            z, jnp.minimum(senders, n_local - 1), receivers, n_local, edge_w
        )
    remote = senders >= n_local
    zero = jnp.zeros((), edge_w.dtype)
    w_int = jnp.where(remote, zero, edge_w)
    w_bnd = jnp.where(remote, edge_w, zero)
    interior = aggregate(
        z, jnp.minimum(senders, n_local - 1), receivers, n_local, w_int
    )
    boundary = aggregate(
        halo,
        jnp.clip(senders - n_local, 0, halo.shape[0] - 1),
        receivers,
        n_local,
        w_bnd,
    )
    return interior + boundary


def halo_aggregate(
    z: jnp.ndarray,
    send_idx: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_w: jnp.ndarray,
    axis_name: str,
    via: str = "all_gather",
    payload: str | None = None,
    overlap: bool = False,
) -> jnp.ndarray:
    """One distributed weighted aggregation O[r] = Σ w · Z[s] (per device).

    z        — (n_local, d) this device's feature block.
    send_idx — (s_max,) this device's export rows (see the s_max contract on
               :class:`HaloPlan`).
    senders  — (e_local,) per-edge source index into ``[local ‖ halo]``
               (< n_local + k·s_max).
    receivers— (e_local,) per-edge local destination row (< n_local).
    edge_w   — (e_local,) weights; exactly 0 marks a padding edge, which
               therefore contributes nothing to any sum.
    Returns the (n_local, d) aggregate. Exactly equals the global
    ``repro.graph.ops.aggregate`` on the permuted layout (the subprocess
    equivalence test): padding edges carry weight 0 and drop out of the sum.
    ``payload`` quantizes the wire (see :func:`halo_exchange`); ``overlap``
    routes through :func:`split_halo_aggregate` so interior compute hides
    the collective — bit-identical terms, reordered schedule.
    """
    halo = halo_exchange(z, send_idx, axis_name, via=via, payload=payload)
    if overlap:
        return split_halo_aggregate(z, halo, senders, receivers, edge_w)
    full = jnp.concatenate([z, halo], axis=0)             # [local ‖ halo]
    return aggregate(full, senders, receivers, z.shape[0], edge_w)


def hier_halo_aggregate(
    z: jnp.ndarray,
    send_loc: jnp.ndarray,
    send_rem: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_w: jnp.ndarray,
    axes: tuple[str, str] = ("pod", "model"),
    via: str = "all_gather",
    payload: str | None = None,
    overlap: bool = False,
) -> jnp.ndarray:
    """:func:`halo_aggregate` over the two-phase (pod, model) exchange: the
    ``senders`` here must come from a hierarchical plan (they index the
    member-block table of :func:`hier_halo_exchange`, < n_local + k_model·B).
    ``payload``/``overlap`` behave as on :func:`halo_aggregate`.
    """
    halo = hier_halo_exchange(z, send_loc, send_rem, axes, via=via, payload=payload)
    if overlap:
        return split_halo_aggregate(z, halo, senders, receivers, edge_w)
    full = jnp.concatenate([z, halo], axis=0)             # [local ‖ halo]
    return aggregate(full, senders, receivers, z.shape[0], edge_w)
