"""Halo-exchange plan + collectives (DESIGN.md §7.2–7.3).

COIN's broadcast schedule (paper Fig. 5c) ships each CE's FULL layer output
to every other CE: ``(k−1)·n_local`` rows received per device per layer. The
halo schedule ships only boundary vertices — the distinct sources of cut
edges — so each device receives at most ``k·s_max`` rows, where ``s_max`` is
the largest per-device export set. The paper's communication tradeoff is the
executable invariant

    k · s_max  <  (k − 1) · n_local        (halo beats broadcast)

checked by ``tests/test_halo_dist.py`` on the 2000-node/8-partition case.

``build_halo_plan`` is the one-time host-side (numpy) relocation:

  1. permute nodes into contiguous per-device blocks (``perm``), one block
     per CE of the :class:`~repro.core.partition.Partition`,
  2. pad every block to ``n_local`` rows and every export set to ``s_max``
     entries so all devices run the same static shapes,
  3. re-localize edges: every edge lives on its RECEIVER's device; receivers
     become local row ids and senders index the concatenation
     ``[local block ‖ halo block]`` where halo slot ``j·s_max + t`` holds
     row ``send_idx[j, t]`` exported by device ``j``.

``halo_exchange`` / ``halo_aggregate`` are the matching device-side
collectives, written against a 1-D mesh axis inside ``shard_map`` (all
shapes static, so they lower to a single all_gather — or a ppermute ring —
of the (s_max, d) export block).

Since plans are pure host data and expensive to build at scale (partition +
relocation over up to 10⁷–10⁸ edges), this module also owns the **plan
cache** (DESIGN.md §8): plans are memoized per ``(graph_hash, k, mesh_axis)``
so every layer of every epoch reuses the one relocation. ``cached_halo_plan``
is the lazy entry point (the builder only runs on a miss), ``get_halo_plan``
the eager one, and ``invalidate_halo_plans`` drops entries — called by
``train/elastic.py`` when an elastic resize changes the model-parallel degree
(a re-partition event; the current replan is the full rebuild, an incremental
boundary-delta replan is a future optimization).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compat import ensure_shard_map
from repro.graph.ops import aggregate

ensure_shard_map()

__all__ = [
    "HaloPlan",
    "build_halo_plan",
    "halo_exchange",
    "halo_aggregate",
    "graph_fingerprint",
    "cached_halo_plan",
    "get_halo_plan",
    "invalidate_halo_plans",
    "plan_cache_stats",
    "relocate_node_array",
    "restore_node_array",
    "node_mask",
]


@dataclasses.dataclass
class HaloPlan:
    """Static-shape relocation of a partitioned graph onto k devices.

    Array layout (leading axis k = one slice per device):

      perm        (n_nodes,) int64   — new position → original node id; the
                                       first ``part_sizes[0]`` entries are
                                       device 0's nodes, and so on.
      send_idx    (k, s_max)  int32  — local rows each device exports (the
                                       distinct sources of its outgoing cut
                                       edges), padded with row 0.
      senders_l   (k, e_local) int32 — per-edge source index into the
                                       ``[local(n_local) ‖ halo(k·s_max)]``
                                       concatenation.
      receivers_l (k, e_local) int32 — per-edge local destination row.
      edge_w      (k, e_local) f32   — edge weight; exactly 0 ⇒ padding edge
                                       (contributes nothing to aggregates).
      part_sizes  (k,) int64         — real (un-padded) rows per device block;
                                       rows ≥ part_sizes[b] of block b are
                                       zero padding.

    The **s_max contract**: ``s_max`` is the size of the largest per-device
    export set, and every device pads its export to exactly ``s_max`` rows
    (with local row 0) so all k devices run the same static-shape program.
    Consequently one exchange delivers exactly ``k·s_max`` halo rows per
    device — the wire quantity the dry-run reports — and halo slot
    ``j·s_max + t`` always holds row ``send_idx[j, t]`` of device j.
    """

    k: int
    n_local: int                      # rows per device block (max part size)
    s_max: int                        # export rows per device (padded)
    e_local: int                      # edges per device (padded)
    n_nodes: int
    perm: np.ndarray
    send_idx: np.ndarray
    senders_l: np.ndarray
    receivers_l: np.ndarray
    edge_w: np.ndarray
    part_sizes: np.ndarray | None = None

    # ---------------------------------------------------------------- wire
    @property
    def halo_rows_per_device(self) -> int:
        """Rows received per device per exchange under the halo schedule."""
        return self.k * self.s_max

    @property
    def broadcast_rows_per_device(self) -> int:
        """Rows received per device per layer under the broadcast schedule."""
        return (self.k - 1) * self.n_local

    def wire_fraction(self) -> float:
        """halo ÷ broadcast received-row ratio (< 1 ⇔ halo wins)."""
        return self.halo_rows_per_device / max(self.broadcast_rows_per_device, 1)

    # -------------------------------------------------------------- device
    def device_arrays(self) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """(send_idx, senders_l, receivers_l, edge_w) as device arrays, each
        with the leading k axis to be sharded one-slice-per-device."""
        return (
            jnp.asarray(self.send_idx, jnp.int32),
            jnp.asarray(self.senders_l, jnp.int32),
            jnp.asarray(self.receivers_l, jnp.int32),
            jnp.asarray(self.edge_w, jnp.float32),
        )

    def abstract_inputs(self) -> tuple[jax.ShapeDtypeStruct, ...]:
        """ShapeDtypeStructs mirroring :meth:`device_arrays` (dry-run path)."""
        return (
            jax.ShapeDtypeStruct((self.k, self.s_max), jnp.int32),
            jax.ShapeDtypeStruct((self.k, self.e_local), jnp.int32),
            jax.ShapeDtypeStruct((self.k, self.e_local), jnp.int32),
            jax.ShapeDtypeStruct((self.k, self.e_local), jnp.float32),
        )


def build_halo_plan(part, edge_index: np.ndarray, w: np.ndarray | None = None) -> HaloPlan:
    """Relocate a :class:`~repro.core.partition.Partition` into a HaloPlan.

    edge_index: (2, E) directed (src, dst); each edge is placed on its
    destination's device. ``w`` defaults to all-ones; padding edges get
    weight 0, so ``(edge_w > 0).sum() == E`` accounts for every real edge
    exactly once (the seed-suite invariant).
    """
    assignment = np.asarray(part.assignment, dtype=np.int64)
    k = int(part.k)
    n = int(part.n_nodes)
    src = np.asarray(edge_index[0], dtype=np.int64)
    dst = np.asarray(edge_index[1], dtype=np.int64)
    e = int(src.shape[0])
    w = np.ones(e, np.float32) if w is None else np.asarray(w, np.float32)

    # 1. contiguous per-device blocks --------------------------------------
    perm = np.argsort(assignment, kind="stable").astype(np.int64)
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n, dtype=np.int64)
    sizes = np.bincount(assignment, minlength=k).astype(np.int64)
    offsets = np.zeros(k + 1, np.int64)
    np.cumsum(sizes, out=offsets[1:])
    n_local = int(sizes.max()) if n else 0
    local = inv - offsets[assignment]          # local row of every node

    a_s, a_d = assignment[src], assignment[dst]
    cut = a_s != a_d

    # 2. export sets: distinct (source device, source node) of cut edges ---
    pair = a_s[cut] * n + src[cut]             # unique id per (dev, node)
    uniq = np.unique(pair)
    send_dev = uniq // max(n, 1)
    send_node = uniq % max(n, 1)
    send_counts = np.bincount(send_dev, minlength=k).astype(np.int64)
    s_max = int(send_counts.max()) if uniq.size else 0
    dev_start = np.zeros(k + 1, np.int64)
    np.cumsum(send_counts, out=dev_start[1:])
    send_idx = np.zeros((k, s_max), np.int32)
    if uniq.size:
        slot = np.arange(uniq.size, dtype=np.int64) - dev_start[send_dev]
        send_idx[send_dev, slot] = local[send_node].astype(np.int32)

    # 3. re-localized edges, grouped by the receiver's device --------------
    senders_full = local[src].copy()
    if uniq.size:
        # np.unique output is sorted, so searchsorted recovers each cut
        # edge's slot in its source device's export set.
        pos = np.searchsorted(uniq, a_s[cut] * n + src[cut])
        halo_slot = pos - dev_start[a_s[cut]]
        senders_full[cut] = n_local + a_s[cut] * s_max + halo_slot
    receivers_full = local[dst]

    owner = a_d
    e_counts = np.bincount(owner, minlength=k).astype(np.int64)
    e_local = max(int(e_counts.max()) if e else 0, 1)
    e_start = np.zeros(k + 1, np.int64)
    np.cumsum(e_counts, out=e_start[1:])
    senders_l = np.zeros((k, e_local), np.int32)
    receivers_l = np.zeros((k, e_local), np.int32)
    edge_w = np.zeros((k, e_local), np.float32)
    if e:
        order = np.argsort(owner, kind="stable")
        own_o = owner[order]
        e_slot = np.arange(e, dtype=np.int64) - e_start[own_o]
        senders_l[own_o, e_slot] = senders_full[order].astype(np.int32)
        receivers_l[own_o, e_slot] = receivers_full[order].astype(np.int32)
        edge_w[own_o, e_slot] = w[order]

    return HaloPlan(
        k=k, n_local=n_local, s_max=s_max, e_local=e_local, n_nodes=n,
        perm=perm, send_idx=send_idx, senders_l=senders_l,
        receivers_l=receivers_l, edge_w=edge_w, part_sizes=sizes,
    )


# ===================================================================== cache
# Plans are pure host data keyed by (graph_hash, k, mesh_axis); one build
# serves every layer of every epoch. The mesh axis participates in the key so
# hierarchical (pod, model) extensions can cache per-axis plans side by side.
_PLAN_CACHE: dict[tuple[str, int, str], HaloPlan] = {}
_PLAN_STATS = {"hits": 0, "misses": 0}


def graph_fingerprint(
    n_nodes: int,
    edge_index: np.ndarray,
    w: np.ndarray | None = None,
    assignment: np.ndarray | None = None,
) -> str:
    """Stable content hash of a (graph, weights, partition) triple.

    Used as the ``graph_hash`` component of the plan-cache key when the
    caller has materialized arrays; callers that synthesize graphs
    deterministically (e.g. the launch layer's shape-statistics graphs) can
    pass their own string key instead and skip the hash entirely.
    """
    h = hashlib.sha1()
    h.update(np.int64(n_nodes).tobytes())
    h.update(np.ascontiguousarray(edge_index, dtype=np.int64).tobytes())
    if w is not None:
        h.update(np.ascontiguousarray(w, dtype=np.float32).tobytes())
    if assignment is not None:
        h.update(np.ascontiguousarray(assignment, dtype=np.int32).tobytes())
    return h.hexdigest()


def cached_halo_plan(
    graph_key: str,
    k: int,
    mesh_axis: str = "model",
    *,
    builder: Callable[[], HaloPlan],
) -> HaloPlan:
    """Memoized plan lookup: ``builder()`` runs only on a cache miss.

    ``graph_key`` identifies the graph (and, when relevant, the partition) —
    either a :func:`graph_fingerprint` or any caller-chosen stable string.
    The lazy builder matters at scale: on a hit, neither the graph nor the
    partition needs to exist in memory at all.
    """
    key = (graph_key, int(k), mesh_axis)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_STATS["hits"] += 1
        return plan
    _PLAN_STATS["misses"] += 1
    plan = builder()
    _PLAN_CACHE[key] = plan
    return plan


def get_halo_plan(
    part,
    edge_index: np.ndarray,
    w: np.ndarray | None = None,
    *,
    mesh_axis: str = "model",
    graph_key: str | None = None,
) -> HaloPlan:
    """Cached :func:`build_halo_plan`: same graph/partition/k → same object.

    When ``graph_key`` is omitted the key is content-hashed from the edge
    list, weights, AND the partition assignment (two partitions of the same
    graph never collide). Mutating the graph or re-partitioning produces a
    different key, i.e. a fresh plan.
    """
    if graph_key is None:
        graph_key = graph_fingerprint(part.n_nodes, edge_index, w, part.assignment)
    return cached_halo_plan(
        graph_key, part.k, mesh_axis, builder=lambda: build_halo_plan(part, edge_index, w)
    )


def invalidate_halo_plans(graph_key: str | None = None) -> int:
    """Drop cached plans (all of them, or one graph's). Returns #evicted.

    ``train/elastic.py`` calls this on an elastic resize that changes the
    model-parallel degree: the node→CE partition is stale, so every plan
    derived from it is too. The next ``get_halo_plan``/``cached_halo_plan``
    rebuilds from scratch (full replan — correct; an incremental
    boundary-delta replan can slot in behind the same API later).
    """
    if graph_key is None:
        n = len(_PLAN_CACHE)
        _PLAN_CACHE.clear()
        return n
    victims = [key for key in _PLAN_CACHE if key[0] == graph_key]
    for key in victims:
        del _PLAN_CACHE[key]
    return len(victims)


def plan_cache_stats() -> dict[str, int]:
    """{'hits', 'misses', 'size'} counters (hits/misses are process-lifetime)."""
    return {**_PLAN_STATS, "size": len(_PLAN_CACHE)}


# ============================================================= host relayout
def relocate_node_array(plan: HaloPlan, x: np.ndarray) -> np.ndarray:
    """Scatter a global per-node array (n_nodes, …) into the plan's blocked
    layout (k, n_local, …); rows past ``part_sizes[b]`` are zero padding."""
    if plan.part_sizes is None:
        raise ValueError("plan has no part_sizes (built by an older writer)")
    x = np.asarray(x)
    out = np.zeros((plan.k, plan.n_local) + x.shape[1:], x.dtype)
    off = 0
    for b in range(plan.k):
        sz = int(plan.part_sizes[b])
        out[b, :sz] = x[plan.perm[off:off + sz]]
        off += sz
    return out


def restore_node_array(plan: HaloPlan, blocks: np.ndarray) -> np.ndarray:
    """Inverse of :func:`relocate_node_array`: gather (k, n_local, …) device
    blocks back into global node order, dropping the padding rows."""
    if plan.part_sizes is None:
        raise ValueError("plan has no part_sizes (built by an older writer)")
    blocks = np.asarray(blocks)
    out = np.zeros((plan.n_nodes,) + blocks.shape[2:], blocks.dtype)
    off = 0
    for b in range(plan.k):
        sz = int(plan.part_sizes[b])
        out[plan.perm[off:off + sz]] = blocks[b, :sz]
        off += sz
    return out


def node_mask(plan: HaloPlan) -> np.ndarray:
    """(k, n_local) float32 validity mask: 1 on real rows, 0 on padding."""
    if plan.part_sizes is None:
        raise ValueError("plan has no part_sizes (built by an older writer)")
    rows = np.arange(plan.n_local)[None, :]
    return (rows < np.asarray(plan.part_sizes)[:, None]).astype(np.float32)


def halo_exchange(
    h: jnp.ndarray, send_idx: jnp.ndarray, axis_name: str, via: str = "all_gather"
) -> jnp.ndarray:
    """Exchange boundary rows across the named mesh axis (inside shard_map).

    h        — (n_local, d) this device's block.
    send_idx — (s_max,) local rows this device exports.
    Returns the (k·s_max, d) halo block: slot ``j·s_max + t`` holds row
    ``send_idx[j, t]`` of device j, for every j including self (the self
    rows are redundant but keep the indexing uniform and the shapes static).

    via="all_gather" lowers to one fused collective; via="ppermute" runs a
    k−1 step neighbor ring (the NoC-shaped schedule COIN's mesh model
    assumes) — identical results, different lowering.
    """
    export = h[send_idx]                                  # (s_max, d)
    if export.shape[0] == 0:
        # Nothing crosses the boundary (k = 1 or a fully-local partition);
        # XLA rejects zero-width collectives, and (k·0, d) == (0, d) anyway.
        return export
    if via == "all_gather":
        return jax.lax.all_gather(export, axis_name, axis=0, tiled=True)
    if via != "ppermute":
        raise ValueError(f"unknown exchange lowering: {via!r}")
    k = jax.lax.psum(1, axis_name)                        # static axis size
    perm = [((j + 1) % k, j) for j in range(k)]
    blocks, cur = [export], export
    for _ in range(k - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        blocks.append(cur)
    # blocks[t] on device i is device (i+t) mod k's export; roll by the
    # device index to arrange slots in absolute device order.
    stack = jnp.stack(blocks)                             # (k, s_max, d)
    stack = jnp.roll(stack, jax.lax.axis_index(axis_name), axis=0)
    return stack.reshape(k * export.shape[0], *export.shape[1:])


def halo_aggregate(
    z: jnp.ndarray,
    send_idx: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_w: jnp.ndarray,
    axis_name: str,
    via: str = "all_gather",
) -> jnp.ndarray:
    """One distributed weighted aggregation O[r] = Σ w · Z[s] (per device).

    z        — (n_local, d) this device's feature block.
    send_idx — (s_max,) this device's export rows (see the s_max contract on
               :class:`HaloPlan`).
    senders  — (e_local,) per-edge source index into ``[local ‖ halo]``
               (< n_local + k·s_max).
    receivers— (e_local,) per-edge local destination row (< n_local).
    edge_w   — (e_local,) weights; exactly 0 marks a padding edge, which
               therefore contributes nothing to any sum.
    Returns the (n_local, d) aggregate. Exactly equals the global
    ``repro.graph.ops.aggregate`` on the permuted layout (the subprocess
    equivalence test): padding edges carry weight 0 and drop out of the sum.
    """
    halo = halo_exchange(z, send_idx, axis_name, via=via)
    full = jnp.concatenate([z, halo], axis=0)             # [local ‖ halo]
    return aggregate(full, senders, receivers, z.shape[0], edge_w)
