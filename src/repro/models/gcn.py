"""The paper's GCN (Kipf–Welling [11]) with the COIN dataflow (§IV-C).

Each layer computes O = Ã · X · W with the multiplication order chosen by
the COIN rule (feature-extraction first when d_out < d_in — §IV-C3), optional
fake quantization of weights/activations (§V-B, Fig. 7), and three
aggregation backends:

  * "segment" — jax.ops.segment_sum over the edge list (reference; sparse),
  * "bsr"     — the ragged 128×128 blocked Pallas path (COIN crossbar→MXU
                mapping, DESIGN.md §2 / docs/kernels.md): unsharded layers
                run entirely inside ONE `repro.kernels.fused_gcn` pallas_call
                (transform, aggregation, bias, and ReLU fused — no per-layer
                HBM round-trips for Z), and the dataflow chooser sees the
                blocked cost model (nonzero blocks · B² · F),
  * "dense"   — dense Ã matmul (the paper's crossbars store zeros too; used
                by the FLOP-accounting benchmarks, not for large graphs).

Communication (DESIGN.md §8): the aggregation gathers sender rows from
``policy.neighbor_table(z)``. Under the default halo mode (inside shard_map
over a `repro.dist.halo.HaloPlan`) that table is ``[local ‖ halo]`` and only
boundary vertices cross the wire; under ``comm="broadcast"`` (the paper's
Fig. 5c schedule, kept as the escape hatch) the table is the identity and
XLA inserts the layer-output all-gather for the node-sharded gather — see
`repro.launch.shardings` and DESIGN.md §2. The `policy.constrain` calls
below are the ShardingPolicy contract of DESIGN.md §7.1.

The halo path accepts ``backend="bsr"`` too: pass the per-shard blocked
adjacency built over the ``[local ‖ halo]`` neighbor table by
`repro.dist.halo.plan_blocked_adjacency` (this device's (vals, cols, lens)
slice) and each layer's aggregation runs on the MXU kernel —
aggregation-first layers stay fully fused; feature-first layers exchange
the transformed Z between the X·W matmul and the blocked aggregation (the
collective cannot be fused through).

**Overlapped schedule** (docs/communication.md): with ``policy.halo_overlap``
(segment backend) or an ``adjacency_boundary`` split pair from
`repro.dist.halo.plan_split_blocked_adjacency` (bsr backend), each layer's
aggregation splits into an interior term that reads only the local block and
a boundary term that alone consumes the collective — XLA's latency-hiding
scheduler runs interior tiles while the exchange is in flight, and across
layers the next layer's exchange issues against the previous layer's
interior compute (double-buffering expressed as dataflow independence, not
manual scheduling). ``policy.halo_payload`` quantizes the wire (bf16/int8
via `repro.core.quant.quantize_payload`, dequantized on receive; the fused
aggregation-first path feeds bf16 rows straight into the fp32-accumulating
MXU kernel — in-kernel dequant).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import choose_order
from repro.core.quant import QuantConfig, fake_quant
from repro.dist.halo import split_halo_aggregate
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.ops import aggregate, aggregate_padded
from repro.graph.structure import BlockedAdjacency
from repro.kernels.ops import bsr_spmm, fused_gcn_layer

__all__ = ["GCNConfig", "gcn_init", "gcn_forward", "gcn_loss"]


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    layer_dims: tuple[int, ...]            # (F_in, hidden..., n_labels)
    dataflow: str = "auto"                 # auto | feature_first | aggregation_first
    quant: QuantConfig = QuantConfig(enabled=False)
    backend: str = "segment"               # segment | bsr | dense

    @property
    def n_layers(self) -> int:
        return len(self.layer_dims) - 1


def gcn_init(key: jax.Array, cfg: GCNConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, cfg.n_layers)
    params = {}
    for i, (d_in, d_out) in enumerate(zip(cfg.layer_dims[:-1], cfg.layer_dims[1:])):
        std = (2.0 / (d_in + d_out)) ** 0.5
        params[f"w{i}"] = jax.random.normal(keys[i], (d_in, d_out), dtype) * std
        params[f"b{i}"] = jnp.zeros((d_out,), dtype)
    return params


def _order(
    cfg: GCNConfig, n_nodes: int, d_in: int, d_out: int, n_edges: int,
    nnz_blocks: int | None = None, block: int = 128,
) -> str:
    if cfg.dataflow != "auto":
        return cfg.dataflow
    if cfg.backend == "bsr" and nnz_blocks is not None:
        # Density-aware: the bsr backend's aggregation cost is per nonzero
        # 128×128 tile, not per edge (repro.core.dataflow, DESIGN.md §3).
        return choose_order(
            n_nodes, d_in, d_out, backend="bsr", nnz_blocks=nnz_blocks, block=block
        )
    return choose_order(n_nodes, d_in, d_out, n_edges=n_edges)


def _normalize_adjacency(adjacency):
    """Validate/unpack the ``adjacency`` argument of :func:`gcn_forward`.

    Accepts a :class:`~repro.graph.structure.BlockedAdjacency` (preferred —
    carries the ragged lengths and static block statistics), a
    ``(vals, cols, lens)`` array triple (the halo shard_map form, this
    device's slice of `repro.dist.halo.plan_blocked_adjacency`), or the
    legacy ``(vals, cols)`` pair (dense-T: every tile treated as valid).
    Returns ``(vals, cols, lens_or_None, static_nnz_blocks_or_None, block)``.
    """
    if isinstance(adjacency, BlockedAdjacency):
        vals, cols, lens = adjacency.arrays()
        return vals, cols, lens, adjacency.nnz_blocks, adjacency.block
    if isinstance(adjacency, (tuple, list)):
        if len(adjacency) == 3:
            vals, cols, lens = adjacency
        elif len(adjacency) == 2:
            (vals, cols), lens = adjacency, None
        else:
            raise ValueError(
                "backend='bsr' adjacency must be a BlockedAdjacency, "
                "(vals, cols, lens), or (vals, cols) — got a "
                f"{len(adjacency)}-tuple"
            )
        if getattr(vals, "ndim", 0) != 4 or getattr(cols, "ndim", 0) != 2:
            raise ValueError(
                "backend='bsr' adjacency arrays must be vals (R, T, B, B) and "
                f"cols (R, T); got shapes {getattr(vals, 'shape', None)} and "
                f"{getattr(cols, 'shape', None)}"
            )
        nnz = None
        if lens is not None and not isinstance(lens, jax.core.Tracer):
            nnz = int(np.asarray(lens).sum())
        return vals, cols, lens, nnz, int(vals.shape[-1])
    raise ValueError(
        "backend='bsr' requires adjacency=BlockedAdjacency or its "
        f"(vals, cols, lens) arrays; got {type(adjacency).__name__}"
    )


def _validate_backend_args(
    cfg: GCNConfig, policy: ShardingPolicy, adjacency, dense_adj, adjacency_boundary
):
    """Up-front argument validation with actionable errors (not asserts)."""
    if cfg.backend not in ("segment", "bsr", "dense"):
        raise ValueError(
            f"unknown GCN backend {cfg.backend!r}; expected 'segment', 'bsr', or 'dense'"
        )
    if adjacency_boundary is not None and not (cfg.backend == "bsr" and policy.is_halo):
        raise ValueError(
            "adjacency_boundary is the overlapped halo-bsr split "
            "(repro.dist.halo.plan_split_blocked_adjacency) and requires "
            "backend='bsr' under an armed halo policy"
        )
    if cfg.backend == "dense":
        if policy.is_halo:
            raise ValueError(
                "halo comm supports the 'segment' and 'bsr' backends; 'dense' "
                "materializes the global adjacency and cannot run per-shard"
            )
        if dense_adj is None:
            raise ValueError("backend='dense' requires the dense_adj=(N, N) matrix")
    if cfg.backend == "bsr":
        if adjacency is None:
            raise ValueError(
                "backend='bsr' requires adjacency= (a BlockedAdjacency from "
                "repro.graph.structure.blocked_adjacency, or — under halo — "
                "this device's slice of repro.dist.halo.plan_blocked_adjacency)"
            )
        return _normalize_adjacency(adjacency)
    return None


def gcn_forward(
    params: dict,
    x: jnp.ndarray,                        # (N, F)
    senders: jnp.ndarray,                  # (E_pad,)
    receivers: jnp.ndarray,                # (E_pad,)
    edge_weight: jnp.ndarray,              # (E_pad,)
    cfg: GCNConfig,
    policy: ShardingPolicy = NO_POLICY,
    adjacency=None,                        # BlockedAdjacency (or arrays) for "bsr"
    dense_adj: jnp.ndarray | None = None,  # (N, N) for "dense"
    adjacency_boundary=None,               # halo-bsr overlap: the boundary
                                           # table of plan_split_blocked_adjacency
                                           # (adjacency= is then the interior one)
) -> jnp.ndarray:
    n_nodes = x.shape[0]
    n_edges = int(senders.shape[0])
    q = cfg.quant
    adj = _validate_backend_args(cfg, policy, adjacency, dense_adj, adjacency_boundary)
    vals, cols, lens, nnz_blocks, block = adj if adj is not None else (None,) * 4 + (128,)
    adj_b = (
        _normalize_adjacency(adjacency_boundary)
        if adjacency_boundary is not None
        else None
    )
    if adj_b is not None and nnz_blocks is not None and adj_b[3] is not None:
        nnz_blocks = nnz_blocks + adj_b[3]     # chooser sees the combined work
    # Unsharded bsr runs the whole layer in one fused pallas_call; under halo
    # only aggregation-first layers can fuse (the boundary collective sits
    # between X·W and the aggregation on feature-first layers).
    fused = cfg.backend == "bsr" and not policy.is_halo
    overlap = policy.is_halo and policy.halo_overlap

    def agg(z: jnp.ndarray) -> jnp.ndarray:
        if policy.is_halo:
            # Halo mode (DESIGN.md §8): senders index [local ‖ halo]; padding
            # edges carry weight 0 so no ghost row is needed.
            if cfg.backend == "bsr":
                if adj_b is not None:
                    # Overlapped split (docs/communication.md): the interior
                    # SpMM reads only the local block, so it has no data
                    # dependence on the collective producing `halo` and runs
                    # while the exchange is in flight.
                    b_vals, b_cols, b_lens = adj_b[0], adj_b[1], adj_b[2]
                    halo = policy.halo_block(z)
                    interior = bsr_spmm(vals, cols, z, lens=lens)[:n_nodes]
                    boundary = bsr_spmm(b_vals, b_cols, halo, lens=b_lens)[:n_nodes]
                    return interior + boundary
                return bsr_spmm(vals, cols, policy.neighbor_table(z), lens=lens)[:n_nodes]
            if overlap:
                return split_halo_aggregate(
                    z, policy.halo_block(z), senders, receivers, edge_weight
                )
            return aggregate(policy.neighbor_table(z), senders, receivers, n_nodes, edge_weight)
        if cfg.backend == "segment":
            return aggregate_padded(z, senders, receivers, n_nodes, edge_weight)
        if cfg.backend == "dense":
            return dense_adj @ z
        return bsr_spmm(vals, cols, z, lens=lens)[:n_nodes]

    h = x
    for i in range(cfg.n_layers):
        w = params[f"w{i}"]
        if q.enabled:
            w = fake_quant(w, q.weight_bits)
            h = fake_quant(h, q.act_bits, percentile=q.act_percentile)
        d_in, d_out = w.shape
        order = _order(cfg, n_nodes, d_in, d_out, n_edges, nnz_blocks, block)
        last = i == cfg.n_layers - 1
        if fused:
            h = fused_gcn_layer(
                vals, cols, lens, h, w, params[f"b{i}"], order=order, relu=not last
            )[:n_nodes]
        elif (
            cfg.backend == "bsr" and policy.is_halo
            and order == "aggregation_first" and adj_b is None
        ):
            # Exchange h, then one fused (Ã·table)·W + b + act pallas_call.
            # With a bf16 wire payload the table rows enter the kernel in
            # bf16 and the fp32 MXU accumulation IS the dequant (in-kernel);
            # split-table layers (adj_b) take the overlapped agg() path
            # above instead.
            table = policy.neighbor_table(h)
            if policy.halo_payload == "bf16":
                table = table.astype(jnp.bfloat16)
            h = fused_gcn_layer(
                vals, cols, lens, table, w, params[f"b{i}"],
                order="aggregation_first", relu=not last,
            )[:n_nodes]
        else:
            if order == "feature_first":
                z = h @ w                   # feature extraction (Fig. 5a)
                z = policy.constrain(z, "node_hidden")
                h = agg(z)                  # aggregation (Fig. 5b)
            else:
                z = agg(h)
                z = policy.constrain(z, "node_hidden")
                h = z @ w
            h = h + params[f"b{i}"]
            if not last:
                h = jax.nn.relu(h)          # activation unit (Fig. 3b)
        h = policy.constrain(h, "node_hidden")
    return h


def gcn_loss(
    params: dict,
    x: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_weight: jnp.ndarray,
    labels: jnp.ndarray,                   # (N,) int32
    label_mask: jnp.ndarray,               # (N,) float32
    cfg: GCNConfig,
    policy: ShardingPolicy = NO_POLICY,
    **fw_kwargs,
) -> jnp.ndarray:
    logits = gcn_forward(params, x, senders, receivers, edge_weight, cfg, policy, **fw_kwargs)
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    per_node = (lse - gold) * label_mask
    return per_node.sum() / jnp.maximum(label_mask.sum(), 1.0)
