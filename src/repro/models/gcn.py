"""The paper's GCN (Kipf–Welling [11]) with the COIN dataflow (§IV-C).

Each layer computes O = Ã · X · W with the multiplication order chosen by
the COIN rule (feature-extraction first when d_out < d_in — §IV-C3), optional
fake quantization of weights/activations (§V-B, Fig. 7), and three
aggregation backends:

  * "segment" — jax.ops.segment_sum over the edge list (reference; sparse),
  * "bsr"     — the 128×128 blocked Pallas SpMM (COIN crossbar→MXU mapping),
  * "dense"   — dense Ã matmul (the paper's crossbars store zeros too; used
                by the FLOP-accounting benchmarks, not for large graphs).

Communication (DESIGN.md §8): the aggregation gathers sender rows from
``policy.neighbor_table(z)``. Under the default halo mode (inside shard_map
over a `repro.dist.halo.HaloPlan`) that table is ``[local ‖ halo]`` and only
boundary vertices cross the wire; under ``comm="broadcast"`` (the paper's
Fig. 5c schedule, kept as the escape hatch) the table is the identity and
XLA inserts the layer-output all-gather for the node-sharded gather — see
`repro.launch.shardings` and DESIGN.md §2. The `policy.constrain` calls
below are the ShardingPolicy contract of DESIGN.md §7.1.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.dataflow import choose_order
from repro.core.quant import QuantConfig, fake_quant
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.ops import aggregate, aggregate_padded

__all__ = ["GCNConfig", "gcn_init", "gcn_forward", "gcn_loss"]


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    layer_dims: tuple[int, ...]            # (F_in, hidden..., n_labels)
    dataflow: str = "auto"                 # auto | feature_first | aggregation_first
    quant: QuantConfig = QuantConfig(enabled=False)
    backend: str = "segment"               # segment | bsr | dense

    @property
    def n_layers(self) -> int:
        return len(self.layer_dims) - 1


def gcn_init(key: jax.Array, cfg: GCNConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, cfg.n_layers)
    params = {}
    for i, (d_in, d_out) in enumerate(zip(cfg.layer_dims[:-1], cfg.layer_dims[1:])):
        std = (2.0 / (d_in + d_out)) ** 0.5
        params[f"w{i}"] = jax.random.normal(keys[i], (d_in, d_out), dtype) * std
        params[f"b{i}"] = jnp.zeros((d_out,), dtype)
    return params


def _order(cfg: GCNConfig, n_nodes: int, d_in: int, d_out: int, n_edges: int) -> str:
    if cfg.dataflow != "auto":
        return cfg.dataflow
    return choose_order(n_nodes, d_in, d_out, n_edges=n_edges)


def gcn_forward(
    params: dict,
    x: jnp.ndarray,                        # (N, F)
    senders: jnp.ndarray,                  # (E_pad,)
    receivers: jnp.ndarray,                # (E_pad,)
    edge_weight: jnp.ndarray,              # (E_pad,)
    cfg: GCNConfig,
    policy: ShardingPolicy = NO_POLICY,
    adjacency=None,                        # BlockedAdjacency arrays for "bsr"
    dense_adj: jnp.ndarray | None = None,  # (N, N) for "dense"
) -> jnp.ndarray:
    n_nodes = x.shape[0]
    n_edges = int(senders.shape[0])
    q = cfg.quant

    def agg(z: jnp.ndarray) -> jnp.ndarray:
        if policy.is_halo:
            # Halo mode (DESIGN.md §8): senders index [local ‖ halo]; padding
            # edges carry weight 0 so no ghost row is needed.
            if cfg.backend != "segment":
                raise ValueError("halo comm supports only the 'segment' backend")
            return aggregate(policy.neighbor_table(z), senders, receivers, n_nodes, edge_weight)
        if cfg.backend == "segment":
            return aggregate_padded(z, senders, receivers, n_nodes, edge_weight)
        if cfg.backend == "dense":
            assert dense_adj is not None
            return dense_adj @ z
        if cfg.backend == "bsr":
            from repro.kernels.ops import bsr_spmm

            block_vals, block_cols = adjacency
            out = bsr_spmm(block_vals, block_cols, z)
            return out[:n_nodes]
        raise ValueError(cfg.backend)

    h = x
    for i in range(cfg.n_layers):
        w = params[f"w{i}"]
        if q.enabled:
            w = fake_quant(w, q.weight_bits)
            h = fake_quant(h, q.act_bits, percentile=q.act_percentile)
        d_in, d_out = w.shape
        order = _order(cfg, n_nodes, d_in, d_out, n_edges)
        if order == "feature_first":
            z = h @ w                       # feature extraction (Fig. 5a)
            z = policy.constrain(z, "node_hidden")
            h = agg(z)                      # aggregation (Fig. 5b)
        else:
            z = agg(h)
            z = policy.constrain(z, "node_hidden")
            h = z @ w
        h = h + params[f"b{i}"]
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)              # activation unit (Fig. 3b)
        h = policy.constrain(h, "node_hidden")
    return h


def gcn_loss(
    params: dict,
    x: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    edge_weight: jnp.ndarray,
    labels: jnp.ndarray,                   # (N,) int32
    label_mask: jnp.ndarray,               # (N,) float32
    cfg: GCNConfig,
    policy: ShardingPolicy = NO_POLICY,
    **fw_kwargs,
) -> jnp.ndarray:
    logits = gcn_forward(params, x, senders, receivers, edge_weight, cfg, policy, **fw_kwargs)
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    per_node = (lse - gold) * label_mask
    return per_node.sum() / jnp.maximum(label_mask.sum(), 1.0)
