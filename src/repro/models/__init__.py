"""Model zoo: the paper's GCN + the 10 assigned architectures."""

from repro.models.gcn import GCNConfig, gcn_init, gcn_forward, gcn_loss
from repro.models.transformer_lm import (
    LMConfig,
    lm_init,
    lm_forward,
    lm_loss,
    lm_prefill,
    lm_decode_step,
    lm_init_cache,
)

__all__ = [
    "GCNConfig",
    "gcn_init",
    "gcn_forward",
    "gcn_loss",
    "LMConfig",
    "lm_init",
    "lm_forward",
    "lm_loss",
    "lm_prefill",
    "lm_decode_step",
    "lm_init_cache",
]
