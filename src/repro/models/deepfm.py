"""DeepFM [arXiv:1703.04247] — FM interaction ∥ deep MLP over shared
field embeddings.

Assigned config: n_sparse=39 fields, embed_dim=10, MLP 400-400-400,
FM interaction. Four serving/training shapes (train 65 536, p99 512,
bulk 262 144, retrieval 1×1 000 000 candidates).

FM second-order term uses the linearized identity (the same "reorder the
math" trick as COIN's dataflow — DESIGN.md §4):
    Σ_{i<j} ⟨v_i, v_j⟩ = ½ (‖Σ_i v_i‖² − Σ_i ‖v_i‖²)      — O(F·D), not O(F²·D)
and is also provided as a Pallas kernel (`repro.kernels.fm_interaction`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.nn.layers import mlp_apply, mlp_init
from repro.recsys.embedding import field_lookup

__all__ = ["DeepFMConfig", "deepfm_init", "deepfm_forward", "deepfm_loss", "deepfm_retrieval"]


@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    n_fields: int = 39
    embed_dim: int = 10
    mlp_dims: tuple[int, ...] = (400, 400, 400)
    rows_per_field: int = 100_000     # hashed bucket size per field
    d_tower: int = 64                 # retrieval tower width

    @property
    def total_rows(self) -> int:
        return self.n_fields * self.rows_per_field

    @property
    def field_offsets(self):
        import numpy as np

        return np.arange(self.n_fields, dtype=np.int32) * self.rows_per_field


def deepfm_init(key: jax.Array, cfg: DeepFMConfig, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    dims = [cfg.n_fields * cfg.embed_dim, *cfg.mlp_dims, 1]
    return {
        "table": jax.random.normal(k1, (cfg.total_rows, cfg.embed_dim), dtype) * 0.01,
        "w_linear": jax.random.normal(k2, (cfg.total_rows,), dtype) * 0.01,
        "bias": jnp.zeros((), dtype),
        "mlp": mlp_init(k3, dims, dtype),
        "user_tower": mlp_init(k4, [cfg.n_fields * cfg.embed_dim, cfg.d_tower], dtype),
        "item_proj": jax.random.normal(k5, (cfg.embed_dim, cfg.d_tower), dtype) * 0.1,
    }


def fm_interaction(emb: jnp.ndarray) -> jnp.ndarray:
    """(B, F, D) → (B,) second-order FM term via the linearized identity."""
    s = emb.sum(axis=1)                       # (B, D)
    sq = (emb * emb).sum(axis=1)              # (B, D)
    return 0.5 * (s * s - sq).sum(axis=-1)


def deepfm_forward(
    params: dict,
    ids: jnp.ndarray,                          # (B, F) per-field hashed ids
    cfg: DeepFMConfig,
    policy: ShardingPolicy = NO_POLICY,
) -> jnp.ndarray:
    offs = jnp.asarray(cfg.field_offsets)
    emb = field_lookup(params["table"], ids, offs)     # (B, F, D)
    emb = policy.constrain(emb, "emb")
    first = jnp.take(params["w_linear"], (ids + offs[None, :]).reshape(-1)).reshape(ids.shape).sum(-1)
    second = fm_interaction(emb)
    deep = mlp_apply(params["mlp"], emb.reshape(ids.shape[0], -1))[:, 0]
    return first + second + deep + params["bias"]


def deepfm_loss(params, ids, labels, cfg, policy=NO_POLICY) -> jnp.ndarray:
    """Binary cross-entropy on click labels (stable logit form)."""
    logits = deepfm_forward(params, ids, cfg, policy)
    z = jnp.clip(logits, -30.0, 30.0)
    return jnp.mean(jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z))))


def deepfm_retrieval(
    params: dict,
    user_ids: jnp.ndarray,                     # (B, F)
    cand_ids: jnp.ndarray,                     # (B, Ncand) item ids (field 0)
    cfg: DeepFMConfig,
    policy: ShardingPolicy = NO_POLICY,
) -> jnp.ndarray:
    """Retrieval scoring: user tower vs N candidates as ONE batched matmul
    (the assigned `retrieval_cand` cell: 1 query × 10⁶ candidates)."""
    offs = jnp.asarray(cfg.field_offsets)
    emb = field_lookup(params["table"], user_ids, offs)
    u = mlp_apply(params["user_tower"], emb.reshape(user_ids.shape[0], -1))  # (B, T)
    cand = jnp.take(params["table"], cand_ids.reshape(-1), axis=0)
    cand = cand.reshape(*cand_ids.shape, cfg.embed_dim) @ params["item_proj"]  # (B, N, T)
    cand = policy.constrain(cand, "cand")
    return jnp.einsum("bt,bnt->bn", u, cand)
