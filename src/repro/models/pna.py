"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

Assigned config: 4 layers, d_hidden=75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation. Each layer:

  m_ij = M(h_i, h_j)            (pre-transform MLP on endpoint features)
  agg  = ⨁ (4 aggregators × 3 degree scalers) → 12·d concat
  h_i' = U(h_i ‖ agg)           (post-transform) + residual
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.ops import degrees, multi_aggregate_edges
from repro.nn.layers import dense_init, linear

__all__ = ["PNAConfig", "pna_init", "pna_forward", "pna_loss"]


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 16
    d_out: int = 1
    mean_log_degree: float = 2.5   # the PNA δ normalizer (train-set statistic)

    @property
    def n_agg_feats(self) -> int:
        return 4 * 3  # aggregators × scalers


def pna_init(key: jax.Array, cfg: PNAConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 2 * cfg.n_layers + 2)
    p: dict = {"enc": dense_init(keys[0], cfg.d_in, cfg.d_hidden, dtype=dtype)}
    for i in range(cfg.n_layers):
        p[f"pre{i}"] = dense_init(keys[2 * i + 1], 2 * cfg.d_hidden, cfg.d_hidden, dtype=dtype)
        p[f"post{i}"] = dense_init(
            keys[2 * i + 2], cfg.d_hidden * (1 + cfg.n_agg_feats), cfg.d_hidden, dtype=dtype
        )
    p["dec"] = dense_init(keys[-1], cfg.d_hidden, cfg.d_out, dtype=dtype)
    return p


def pna_forward(
    params: dict,
    x: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    cfg: PNAConfig,
    policy: ShardingPolicy = NO_POLICY,
    edge_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    n = x.shape[0]
    h = jax.nn.relu(linear(params["enc"], x))
    if edge_mask is None:
        deg = degrees(receivers, n)
    else:
        # Halo comm path: padding edges (mask 0) must not count as neighbors.
        deg = jax.ops.segment_sum(edge_mask, receivers, num_segments=n)
    logd = jnp.log1p(deg)[:, None]
    amp = logd / cfg.mean_log_degree
    att = cfg.mean_log_degree / jnp.maximum(logd, 1e-6)
    for i in range(cfg.n_layers):
        tab = policy.neighbor_table(h)
        msg_in = jnp.concatenate([tab[senders], h[receivers]], axis=-1)
        msg = jax.nn.relu(linear(params[f"pre{i}"], msg_in))
        # Aggregate the transformed messages by receiver.
        aggs = multi_aggregate_edges(msg, receivers, n, edge_mask)
        feats = []
        for a in ("mean", "max", "min", "std"):
            v = aggs[a]
            feats += [v, v * amp, v * att]
        z = jnp.concatenate([h] + feats, axis=-1)
        h = h + jax.nn.relu(linear(params[f"post{i}"], z))
        h = policy.constrain(h, "node_hidden")
    return linear(params["dec"], h)


def pna_loss(params, x, senders, receivers, target, cfg, policy=NO_POLICY) -> jnp.ndarray:
    pred = pna_forward(params, x, senders, receivers, cfg, policy)
    return jnp.mean(jnp.square(pred - target))
