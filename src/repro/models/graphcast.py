"""GraphCast-style encoder–processor–decoder mesh GNN [arXiv:2212.12794].

Assigned config: 16 processor layers, d_hidden=512, sum aggregator,
n_vars=227, mesh_refinement=6. Per DESIGN.md §4 the assigned input shapes
are generic graphs, so we implement the encode-process-decode stack over the
given graph (the paper's grid↔mesh bipartite mapping becomes the generic
node/edge featurization; `mesh_refinement` sizes the native icosphere mesh
used by `icosphere_sizes`). Processor layers are interaction networks with
persistent edge latents and residual connections, exactly as GraphCast's.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.nn.layers import layer_norm, mlp_apply, mlp_init

__all__ = ["GraphCastConfig", "graphcast_init", "graphcast_forward", "graphcast_loss", "icosphere_sizes"]


def icosphere_sizes(refinement: int) -> tuple[int, int]:
    """(nodes, directed edges) of the refined icosahedral mesh: R6 → 40 962
    nodes / 245 760 edges (GraphCast's native processor mesh)."""
    n_nodes = 10 * 4**refinement + 2
    n_faces = 20 * 4**refinement
    n_edges_undirected = 30 * 4**refinement
    del n_faces
    return n_nodes, 2 * n_edges_undirected


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227               # output variables per node
    mesh_refinement: int = 6
    d_edge_in: int = 4              # relative-position edge features
    d_in: int | None = None         # input width; defaults to n_vars (native)

    @property
    def input_dim(self) -> int:
        return self.n_vars if self.d_in is None else self.d_in

    @property
    def residual_output(self) -> bool:
        return self.input_dim == self.n_vars


def _ln_params(d: int, dtype) -> dict:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def graphcast_init(key: jax.Array, cfg: GraphCastConfig, dtype=jnp.float32) -> dict:
    n_mlps = 2 + 2 * cfg.n_layers + 1
    keys = jax.random.split(key, n_mlps)
    d = cfg.d_hidden
    p: dict = {
        "enc_node": mlp_init(keys[0], [cfg.input_dim, d, d], dtype),
        "enc_edge": mlp_init(keys[1], [cfg.d_edge_in, d, d], dtype),
        "enc_node_ln": _ln_params(d, dtype),
        "enc_edge_ln": _ln_params(d, dtype),
    }
    for i in range(cfg.n_layers):
        p[f"edge_mlp{i}"] = mlp_init(keys[2 + 2 * i], [3 * d, d, d], dtype)
        p[f"node_mlp{i}"] = mlp_init(keys[3 + 2 * i], [2 * d, d, d], dtype)
        p[f"edge_ln{i}"] = _ln_params(d, dtype)
        p[f"node_ln{i}"] = _ln_params(d, dtype)
    p["dec"] = mlp_init(keys[-1], [d, d, cfg.n_vars], dtype)
    return p


def graphcast_forward(
    params: dict,
    x: jnp.ndarray,                 # (N, n_vars) node variables
    edge_feats: jnp.ndarray,        # (E, d_edge_in) e.g. relative positions
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    cfg: GraphCastConfig,
    policy: ShardingPolicy = NO_POLICY,
    edge_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    n = x.shape[0]
    h = layer_norm(mlp_apply(params["enc_node"], x), params["enc_node_ln"]["g"], params["enc_node_ln"]["b"])
    e = layer_norm(mlp_apply(params["enc_edge"], edge_feats), params["enc_edge_ln"]["g"], params["enc_edge_ln"]["b"])
    h = policy.constrain(h, "node_hidden")
    e = policy.constrain(e, "edge_hidden")
    for i in range(cfg.n_layers):
        # Interaction network: update edges, then nodes; residual + LN both.
        tab = policy.neighbor_table(h)
        e_in = jnp.concatenate([e, tab[senders], h[receivers]], axis=-1)
        e_upd = mlp_apply(params[f"edge_mlp{i}"], e_in)
        e = e + layer_norm(e_upd, params[f"edge_ln{i}"]["g"], params[f"edge_ln{i}"]["b"])
        # Halo comm path: padding-edge latents evolve but never aggregate.
        e_agg = e if edge_mask is None else e * edge_mask[:, None]
        agg = jax.ops.segment_sum(e_agg, receivers, num_segments=n)  # sum aggregator
        h_in = jnp.concatenate([h, agg], axis=-1)
        h_upd = mlp_apply(params[f"node_mlp{i}"], h_in)
        h = h + layer_norm(h_upd, params[f"node_ln{i}"]["g"], params[f"node_ln{i}"]["b"])
        h = policy.constrain(h, "node_hidden")
        e = policy.constrain(e, "edge_hidden")
    out = mlp_apply(params["dec"], h)
    return x + out if cfg.residual_output else out   # residual prediction (GraphCast)


def graphcast_loss(params, x, edge_feats, senders, receivers, target, cfg, policy=NO_POLICY) -> jnp.ndarray:
    pred = graphcast_forward(params, x, edge_feats, senders, receivers, cfg, policy)
    return jnp.mean(jnp.square(pred - target))
