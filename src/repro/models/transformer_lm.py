"""Decoder-only transformer LM covering the 5 assigned LM architectures.

One config-driven implementation provides:
  * dense SwiGLU or MoE FFN (moonshot 64e/top-6, olmoe 64e/top-8),
  * GQA / MQA (granite kv=1),
  * mixed sliding-window / global layers (gemma3 5:1) expressed as a traced
    per-layer window vector so the whole stack lowers as ONE lax.scan,
  * train forward (chunked flash-style attention), prefill, and KV-cache
    decode paths,
  * optional grouped sliding cache (local layers keep only `window` KV
    entries) — the beyond-paper memory optimization for the 500k cell.

Params are dicts with layer-stacked leaves (leading axis = n_layers) so the
HLO stays small enough to compile 40 dry-run cells on the CPU backend.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.nn.attention import (
    AttentionConfig,
    attention_apply,
    attention_decode,
    attention_init,
)


def _attn(layer_p, h, cfg: "LMConfig", win, policy: ShardingPolicy):
    h = policy.constrain(h, "act")
    out = attention_apply(layer_p["attn"], h, cfg.attn, window=win)
    return policy.constrain(out, "act")
from repro.nn.layers import rms_norm, silu
from repro.nn.moe import MoEConfig, moe_apply, moe_init

__all__ = ["LMConfig", "lm_init", "lm_forward", "lm_loss", "lm_prefill", "lm_decode_step", "lm_init_cache"]

GLOBAL_WINDOW = np.int32(2**30)  # "window" meaning full causal attention


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    moe_experts: int | None = None
    moe_top_k: int | None = None
    moe_groups: int = 1          # hierarchical dispatch groups (= data shards)
    moe_capacity_factor: float = 1.25
    window: int | None = None          # sliding window for local layers
    global_every: int | None = None    # gemma3: every 6th layer global
    rope_theta: float = 10_000.0
    kv_chunk: int = 1024
    tie_embeddings: bool = True
    # Unroll the layer scan in the lowered HLO. Needed by the dry-run:
    # XLA's cost_analysis counts a while-loop body ONCE, so a rolled scan
    # under-reports FLOPs/bytes/collectives by ~n_layers (EXPERIMENTS.md).
    unroll_layers: bool = False
    # Rematerialize layer activations in backward (jax.checkpoint on the
    # scan body): trades recompute FLOPs for peak-memory (§Perf lever).
    remat: bool = False

    @property
    def scan_unroll(self) -> int | bool:
        return self.n_layers if self.unroll_layers else 1

    @property
    def attn(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.d_head,
            rope_theta=self.rope_theta,
            kv_chunk=self.kv_chunk,
        )

    @property
    def is_moe(self) -> bool:
        return self.moe_experts is not None

    @property
    def sub_quadratic(self) -> bool:
        """True iff most layers are sliding-window (long_500k eligibility)."""
        return self.window is not None

    def moe_cfg(self) -> MoEConfig:
        assert self.is_moe
        return MoEConfig(
            num_experts=self.moe_experts,
            top_k=self.moe_top_k,
            d_model=self.d_model,
            d_ff=self.d_ff,
            groups=self.moe_groups,
            capacity_factor=self.moe_capacity_factor,
        )

    def window_sizes(self) -> np.ndarray:
        """Per-layer attention window (int32). Global layers get 2^30."""
        if self.window is None:
            return np.full(self.n_layers, GLOBAL_WINDOW, np.int32)
        ws = np.full(self.n_layers, self.window, np.int32)
        if self.global_every:
            ws[self.global_every - 1 :: self.global_every] = GLOBAL_WINDOW
        return ws

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.d_head or d // self.n_heads
        attn = d * hd * (self.n_heads * 2) + d * hd * (self.n_kv_heads * 2)
        if self.is_moe:
            ffn = d * self.moe_experts + self.moe_experts * 3 * d * f
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + v * d + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        hd = self.d_head or d // self.n_heads
        attn = d * hd * (self.n_heads * 2) + d * hd * (self.n_kv_heads * 2)
        ffn = d * self.moe_experts + self.moe_top_k * 3 * d * f
        return self.n_layers * (attn + ffn + 2 * d) + self.vocab * d + d


# --------------------------------------------------------------------- params
def _layer_init(key: jax.Array, cfg: LMConfig, dtype) -> dict:
    ka, km = jax.random.split(key)
    p = {
        "attn": attention_init(ka, cfg.attn, dtype),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(km, cfg.moe_cfg(), dtype)
    else:
        k1, k2, k3 = jax.random.split(km, 3)
        d, f = cfg.d_model, cfg.d_ff
        std_in, std_out = (1.0 / d) ** 0.5, (1.0 / f) ** 0.5
        p["mlp"] = {
            "w_gate": jax.random.normal(k1, (d, f), dtype) * std_in,
            "w_up": jax.random.normal(k2, (d, f), dtype) * std_in,
            "w_down": jax.random.normal(k3, (f, d), dtype) * std_out,
        }
    return p


def lm_init(key: jax.Array, cfg: LMConfig, dtype=jnp.float32) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = [_layer_init(k, cfg, dtype) for k in layer_keys]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kh, (cfg.d_model, cfg.vocab), dtype) * 0.02
    return params


def lm_param_shapes(cfg: LMConfig, dtype=jnp.float32):
    """ShapeDtypeStruct pytree — dry-run lowering without allocation."""
    return jax.eval_shape(lambda k: lm_init(k, cfg, dtype), jax.random.PRNGKey(0))


# -------------------------------------------------------------------- forward
def _ffn(layer_p: dict, x2: jnp.ndarray, cfg: LMConfig, policy: ShardingPolicy):
    B, S, D = x2.shape
    if cfg.is_moe:
        flat = x2.reshape(B * S, D)
        out, aux = moe_apply(layer_p["moe"], flat, cfg.moe_cfg(), policy=policy)
        return out.reshape(B, S, D), aux
    m = layer_p["mlp"]
    h = silu(x2 @ m["w_gate"]) * (x2 @ m["w_up"])
    h = policy.constrain(h, "ffn_hidden")
    return h @ m["w_down"], jnp.zeros((), jnp.float32)


def lm_forward(
    params: dict,
    tokens: jnp.ndarray,               # (B, S) int32
    cfg: LMConfig,
    policy: ShardingPolicy = NO_POLICY,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B,S,V), aux_loss)."""
    x = params["embed"][tokens] * (cfg.d_model ** 0.5)
    x = policy.constrain(x, "act")
    windows = jnp.asarray(cfg.window_sizes())

    def layer(carry, xs):
        x, aux = carry
        layer_p, win = xs
        h = rms_norm(x, layer_p["ln1"])
        h = _attn(layer_p, h, cfg, win, policy)
        x = x + h
        h2 = rms_norm(x, layer_p["ln2"])
        f, a = _ffn(layer_p, h2, cfg, policy)
        x = policy.constrain(x + f, "act")
        return (x, aux + a), None

    if cfg.remat:
        layer = jax.checkpoint(layer)
    (x, aux), _ = jax.lax.scan(
        layer, (x, jnp.zeros((), jnp.float32)), (params["layers"], windows),
        unroll=cfg.scan_unroll,
    )
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = policy.constrain(logits, "logits")
    return logits, aux


def lm_loss(
    params: dict,
    tokens: jnp.ndarray,
    cfg: LMConfig,
    policy: ShardingPolicy = NO_POLICY,
    aux_weight: float = 0.01,
) -> jnp.ndarray:
    """Next-token cross entropy (vocab-sharded-safe logsumexp form)."""
    logits, aux = lm_forward(params, tokens[:, :-1], cfg, policy)
    labels = tokens[:, 1:]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold) + aux_weight * aux


# -------------------------------------------------------------------- serving
def lm_prefill(
    params: dict,
    tokens: jnp.ndarray,
    cfg: LMConfig,
    policy: ShardingPolicy = NO_POLICY,
) -> jnp.ndarray:
    """Prefill: logits for the LAST position only (the serving quantity)."""
    logits, _ = lm_forward(params, tokens, cfg, policy)
    return logits[:, -1]


def lm_init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.float32) -> dict:
    hd = cfg.attn.head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def lm_cache_shapes(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.float32):
    return jax.eval_shape(lambda: lm_init_cache(cfg, batch, max_len, dtype))


def lm_decode_step(
    params: dict,
    cache: dict,                        # {"k","v"}: (L, B, Smax, Hk, Dh)
    token: jnp.ndarray,                 # (B,) int32 current token ids
    pos: jnp.ndarray,                   # scalar int32
    cfg: LMConfig,
    policy: ShardingPolicy = NO_POLICY,
) -> tuple[jnp.ndarray, dict]:
    """One decode step for all layers; returns (next-token logits, new cache)."""
    B = token.shape[0]
    x = params["embed"][token][:, None, :] * (cfg.d_model ** 0.5)
    x = policy.constrain(x, "dec_act")
    windows = jnp.asarray(cfg.window_sizes())

    def layer(x, xs):
        layer_p, win, ck, cv = xs
        h = rms_norm(x, layer_p["ln1"])
        h, new_c = attention_decode(
            layer_p["attn"], h, {"k": ck, "v": cv}, pos, cfg.attn, window=win
        )
        x = x + h
        h2 = rms_norm(x, layer_p["ln2"])
        f, _ = _ffn(layer_p, h2, cfg, policy)
        x = policy.constrain(x + f, "dec_act")
        return x, (new_c["k"], new_c["v"])

    x, (nk, nv) = jax.lax.scan(
        layer, x, (params["layers"], windows, cache["k"], cache["v"]),
        unroll=cfg.scan_unroll,
    )
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return policy.constrain(logits, "dec_logits"), {"k": nk, "v": nv}
