"""EGNN — E(n)-Equivariant Graph Neural Network [arXiv:2102.09844].

Assigned config: 4 layers, d_hidden=64, E(n) equivariance. Per layer:

  m_ij  = φ_e(h_i, h_j, ‖x_i − x_j‖²)
  x_i' = x_i + (1/deg) Σ_j (x_i − x_j) · φ_x(m_ij)      (coordinate update)
  h_i' = φ_h(h_i, Σ_j m_ij)                              (feature update)

Equivariance holds because coordinates enter only through squared distances
and relative vectors (property-tested in tests/test_models_gnn.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.ops import degrees
from repro.nn.layers import mlp_apply, mlp_init

__all__ = ["EGNNConfig", "egnn_init", "egnn_forward", "egnn_loss"]


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    d_out: int = 1
    coord_clamp: float = 100.0


def egnn_init(key: jax.Array, cfg: EGNNConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 3 * cfg.n_layers + 2)
    d = cfg.d_hidden
    p: dict = {"enc": mlp_init(keys[0], [cfg.d_in, d], dtype)}
    for i in range(cfg.n_layers):
        p[f"phi_e{i}"] = mlp_init(keys[3 * i + 1], [2 * d + 1, d, d], dtype)
        p[f"phi_x{i}"] = mlp_init(keys[3 * i + 2], [d, d, 1], dtype)
        p[f"phi_h{i}"] = mlp_init(keys[3 * i + 3], [2 * d, d, d], dtype)
    p["dec"] = mlp_init(keys[-1], [d, d, cfg.d_out], dtype)
    return p


def egnn_forward(
    params: dict,
    h: jnp.ndarray,            # (N, d_in) node features
    x: jnp.ndarray,            # (N, 3) coordinates
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    cfg: EGNNConfig,
    policy: ShardingPolicy = NO_POLICY,
    edge_mask: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    n = h.shape[0]
    h = mlp_apply(params["enc"], h)
    if edge_mask is None:
        deg = jnp.maximum(degrees(receivers, n), 1.0)
    else:
        # Halo comm path: padding edges (mask 0) must not count as neighbors.
        deg = jnp.maximum(jax.ops.segment_sum(edge_mask, receivers, num_segments=n), 1.0)
    for i in range(cfg.n_layers):
        # One fused exchange of [x ‖ h] per layer (x mutates each layer, so
        # unlike equiformer's static pos it cannot be exchanged once).
        xh = policy.neighbor_table(jnp.concatenate([x, h], axis=-1))
        xt, ht = xh[:, :3], xh[:, 3:]
        rel = x[receivers] - xt[senders]                     # (E, 3)
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        m_in = jnp.concatenate([h[receivers], ht[senders], d2], axis=-1)
        m = mlp_apply(params[f"phi_e{i}"], m_in)             # (E, d)
        if edge_mask is not None:
            m = m * edge_mask[:, None]
        # Coordinate update (equivariant): weighted relative vectors.
        cw = jnp.clip(mlp_apply(params[f"phi_x{i}"], m), -cfg.coord_clamp, cfg.coord_clamp)
        xw = rel * cw if edge_mask is None else rel * cw * edge_mask[:, None]
        dx = jax.ops.segment_sum(xw, receivers, num_segments=n)
        x = x + dx / deg[:, None]
        # Feature update (invariant).
        magg = jax.ops.segment_sum(m, receivers, num_segments=n)
        h = h + mlp_apply(params[f"phi_h{i}"], jnp.concatenate([h, magg], axis=-1))
        h = policy.constrain(h, "node_hidden")
    return mlp_apply(params["dec"], h), x


def egnn_loss(params, h, x, senders, receivers, target, cfg, policy=NO_POLICY) -> jnp.ndarray:
    pred, _ = egnn_forward(params, h, x, senders, receivers, cfg, policy)
    return jnp.mean(jnp.square(pred - target))
