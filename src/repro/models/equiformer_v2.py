"""EquiformerV2 [arXiv:2306.12059] — equivariant graph attention via eSCN.

Assigned config: 12 layers, d_hidden=128 (sphere channels), l_max=6,
m_max=2, 8 heads, SO(2)-eSCN convolutions.

Implementation (self-contained, no e3nn):
  * node features are real-SH irreps flattened to (N, K, C), K=(l_max+1)²,
  * per edge, features rotate into the edge frame (edge ∥ ẑ) with the
    Ivanic–Ruedenberg Wigner matrices (`repro.nn.so3`), where the tensor-
    product convolution reduces to per-|m| SO(2) linear maps limited to
    m ≤ m_max — the eSCN O(L⁶)→O(L³) trick that IS this arch's kernel regime,
  * attention weights come from rotation-invariant scalars (l=0 channels of
    both endpoints + radial basis) through an 8-head MLP + segment softmax,
  * equivariant RMS norm (per-l, over m and channels) and a gated per-l FFN.

Equivariance (output invariance under global SO(3) rotations of the input
positions) is property-tested in tests/test_models_gnn.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.ops import segment_softmax
from repro.nn.layers import mlp_apply, mlp_init
from repro.nn.so3 import (
    block_diag_apply,
    block_diag_apply_T,
    real_sh_rotations,
    rotation_align_z,
)

__all__ = ["EquiformerV2Config", "equiformer_init", "equiformer_forward", "equiformer_loss"]


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    n_layers: int = 12
    d_hidden: int = 128           # sphere channels C
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_in: int = 16                # input scalar features per node
    d_out: int = 1
    n_rbf: int = 16
    cutoff: float = 5.0
    edge_chunk: int | None = None   # chunk the (E, K, C) message tensor
    chunk_unroll: bool = False      # unroll the chunk scan (dry-run costing)

    @property
    def k_comps(self) -> int:
        return (self.l_max + 1) ** 2

    def m_l_count(self, m: int) -> int:
        """Number of l's carrying component m: l ∈ [m, l_max]."""
        return self.l_max + 1 - m


def _so2_init(key, cfg: EquiformerV2Config, dtype) -> dict:
    """Per-|m| SO(2) linear maps mixing (l ≥ m) × channels."""
    p = {}
    keys = jax.random.split(key, 2 * (cfg.m_max + 1))
    for m in range(cfg.m_max + 1):
        n = cfg.m_l_count(m) * cfg.d_hidden
        std = (1.0 / n) ** 0.5
        p[f"w{m}_r"] = jax.random.normal(keys[2 * m], (n, n), dtype) * std
        if m > 0:
            p[f"w{m}_i"] = jax.random.normal(keys[2 * m + 1], (n, n), dtype) * std
    return p


def _layer_init(key, cfg: EquiformerV2Config, dtype) -> dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    C = cfg.d_hidden
    p = {
        "so2": _so2_init(k1, cfg, dtype),
        "radial": mlp_init(k2, [cfg.n_rbf, C, cfg.m_max + 1], dtype),
        "attn": mlp_init(k3, [2 * C + cfg.n_rbf, C, cfg.n_heads], dtype),
        "ffn_scalar": mlp_init(k4, [C, 2 * C, C], dtype),
        "gate": mlp_init(k5, [C, cfg.l_max * C], dtype),
        "ffn_l": jax.random.normal(k6, (cfg.l_max + 1, C, C), dtype) * (1.0 / C) ** 0.5,
        "norm_g": jnp.ones((cfg.l_max + 1, C), dtype),
    }
    return p


def equiformer_init(key: jax.Array, cfg: EquiformerV2Config, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 2)
    return {
        "embed": mlp_init(keys[0], [cfg.d_in, cfg.d_hidden, cfg.d_hidden], dtype),
        "layers": [_layer_init(k, cfg, dtype) for k in keys[1:-1]],
        "head": mlp_init(keys[-1], [cfg.d_hidden, cfg.d_hidden, cfg.d_out], dtype),
    }


def _eq_norm(h: jnp.ndarray, gamma: jnp.ndarray, cfg: EquiformerV2Config) -> jnp.ndarray:
    """Equivariant RMS norm: per-l, normalize by RMS over (m, channels)."""
    outs = []
    for l in range(cfg.l_max + 1):
        s = l * l
        x = h[:, s : s + 2 * l + 1, :]
        rms = jnp.sqrt(jnp.mean(jnp.square(x), axis=(1, 2), keepdims=True) + 1e-8)
        outs.append(x / rms * gamma[l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def _rbf(d: jnp.ndarray, cfg: EquiformerV2Config) -> jnp.ndarray:
    mu = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    sigma = cfg.cutoff / cfg.n_rbf
    return jnp.exp(-jnp.square(d[:, None] - mu[None, :]) / (2 * sigma * sigma))


def _so2_conv(p: dict, x: jnp.ndarray, radial: jnp.ndarray, cfg: EquiformerV2Config) -> jnp.ndarray:
    """eSCN SO(2) convolution in the edge frame.

    x: (E, K, C) rotated features. Output has nonzeros only at m ≤ m_max.
    radial: (E, m_max+1) per-m gains from the distance MLP.
    """
    E, K, C = x.shape
    out = jnp.zeros_like(x)
    # m = 0: components at index l²+l.
    idx0 = jnp.asarray([l * l + l for l in range(cfg.l_max + 1)])
    x0 = x[:, idx0, :].reshape(E, -1)
    y0 = (x0 @ p["so2"]["w0_r"]) * radial[:, 0:1]
    out = out.at[:, idx0, :].set(y0.reshape(E, -1, C))
    for m in range(1, cfg.m_max + 1):
        ls = list(range(m, cfg.l_max + 1))
        idx_p = jnp.asarray([l * l + l + m for l in ls])
        idx_m = jnp.asarray([l * l + l - m for l in ls])
        xp = x[:, idx_p, :].reshape(E, -1)
        xm = x[:, idx_m, :].reshape(E, -1)
        wr, wi = p["so2"][f"w{m}_r"], p["so2"][f"w{m}_i"]
        yp = (xp @ wr - xm @ wi) * radial[:, m : m + 1]
        ym = (xp @ wi + xm @ wr) * radial[:, m : m + 1]
        out = out.at[:, idx_p, :].set(yp.reshape(E, len(ls), C))
        out = out.at[:, idx_m, :].set(ym.reshape(E, len(ls), C))
    return out


def _ffn(p: dict, h: jnp.ndarray, cfg: EquiformerV2Config) -> jnp.ndarray:
    """Gated per-l FFN: scalars get an MLP; l>0 get channel mixing gated by
    sigmoid gates derived from the scalar channel (S2-activation-style)."""
    scal = h[:, 0, :]                                        # (N, C)
    gates = jax.nn.sigmoid(mlp_apply(p["gate"], scal)).reshape(
        -1, cfg.l_max, cfg.d_hidden
    )
    outs = [mlp_apply(p["ffn_scalar"], scal)[:, None, :]]
    for l in range(1, cfg.l_max + 1):
        s = l * l
        x = h[:, s : s + 2 * l + 1, :]
        y = jnp.einsum("nmc,cd->nmd", x, p["ffn_l"][l]) * gates[:, l - 1][:, None, :]
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


def equiformer_forward(
    params: dict,
    feats: jnp.ndarray,            # (N, d_in) scalar node features
    pos: jnp.ndarray,              # (N, 3)
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    cfg: EquiformerV2Config,
    policy: ShardingPolicy = NO_POLICY,
    edge_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    N = feats.shape[0]
    C, K = cfg.d_hidden, cfg.k_comps
    h = jnp.zeros((N, K, C), feats.dtype)
    h = h.at[:, 0, :].set(mlp_apply(params["embed"], feats))

    # Edge geometry (shared across layers). Zero-length edges (self loops /
    # ghost padding) have no direction — masked out, which is both the
    # physically correct cutoff behaviour and what keeps the model exactly
    # SO(3)-equivariant (a directionless edge cannot carry l>0 messages).
    pos_tab = policy.neighbor_table(pos)
    rel = pos[receivers] - pos_tab[senders]
    dist = jnp.linalg.norm(rel, axis=-1) + 1e-9
    edge_ok = (dist > 1e-6).astype(feats.dtype)
    if edge_mask is not None:
        edge_ok = edge_ok * edge_mask
    u = rel / dist[:, None]
    D = real_sh_rotations(rotation_align_z(u), cfg.l_max)
    rbf = _rbf(dist, cfg)

    for lp in params["layers"]:
        hn = _eq_norm(h, lp["norm_g"], cfg)
        hn_tab = policy.neighbor_table(hn)
        radial = mlp_apply(lp["radial"], rbf)
        # Attention logits need only invariants — cheap, computed unchunked.
        inv = jnp.concatenate([hn_tab[senders][:, 0, :], hn[receivers][:, 0, :], rbf], axis=-1)
        logits = mlp_apply(lp["attn"], inv)                   # (E, heads)
        if edge_mask is not None:
            # Padding edges must not dilute the softmax of real incoming edges.
            logits = jnp.where(edge_mask[:, None] > 0, logits, -1e30)
        alpha = segment_softmax(logits, receivers, N)         # (E, heads)
        alpha_c = jnp.repeat(alpha, C // cfg.n_heads, axis=-1) * edge_ok[:, None]
        if cfg.edge_chunk is None:
            # ---- eSCN message: rotate → SO(2) conv → attn weight → rotate back
            src = block_diag_apply(D, hn_tab[senders])
            msg = _so2_conv(lp, src, radial, cfg)             # (E, K, C)
            msg = msg * alpha_c[:, None, :]
            msg = block_diag_apply_T(D, msg)
            agg = jax.ops.segment_sum(msg, receivers, num_segments=N)
        else:
            # Chunked path: the (E, K, C) message tensor never materializes —
            # required for the 10⁷–10⁸-edge assigned cells (memory roofline).
            agg = _chunked_messages(lp, hn_tab, D, radial, alpha_c, senders, receivers, N, cfg)
        h = h + agg
        h = policy.constrain(h, "irrep_hidden")
        # ---- gated equivariant FFN
        hn2 = _eq_norm(h, lp["norm_g"], cfg)
        h = h + _ffn(lp, hn2, cfg)
        h = policy.constrain(h, "irrep_hidden")
    return mlp_apply(params["head"], h[:, 0, :])


def _chunked_messages(
    lp: dict,
    hn: jnp.ndarray,
    D: list[jnp.ndarray],
    radial: jnp.ndarray,
    alpha_c: jnp.ndarray,
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    N: int,
    cfg: EquiformerV2Config,
) -> jnp.ndarray:
    """lax.scan over edge chunks; the (chunk, K, C) message tile is the only
    per-edge irrep tensor alive. Edges are padded to a chunk multiple with
    self-edges on node 0 weighted 0 (alpha padding is 0)."""
    E = senders.shape[0]
    ck = cfg.edge_chunk
    n_chunks = -(-E // ck)
    pad = n_chunks * ck - E
    if pad:
        senders = jnp.concatenate([senders, jnp.zeros(pad, senders.dtype)])
        receivers = jnp.concatenate([receivers, jnp.zeros(pad, receivers.dtype)])
        radial = jnp.concatenate([radial, jnp.zeros((pad, radial.shape[1]), radial.dtype)])
        alpha_c = jnp.concatenate([alpha_c, jnp.zeros((pad, alpha_c.shape[1]), alpha_c.dtype)])
        D = [jnp.concatenate([d, jnp.tile(jnp.eye(d.shape[-1], dtype=d.dtype)[None], (pad, 1, 1))]) for d in D]
    s_c = senders.reshape(n_chunks, ck)
    r_c = receivers.reshape(n_chunks, ck)
    rad_c = radial.reshape(n_chunks, ck, -1)
    a_c = alpha_c.reshape(n_chunks, ck, -1)
    D_c = [d.reshape(n_chunks, ck, d.shape[-1], d.shape[-1]) for d in D]

    def step(acc, xs):
        s, r, rad, a, *Dl = xs
        src = block_diag_apply(Dl, hn[s])
        msg = _so2_conv(lp, src, rad, cfg) * a[:, None, :]
        msg = block_diag_apply_T(Dl, msg)
        return acc + jax.ops.segment_sum(msg, r, num_segments=N), None

    acc0 = jnp.zeros((N, cfg.k_comps, cfg.d_hidden), hn.dtype)
    acc, _ = jax.lax.scan(
        step, acc0, (s_c, r_c, rad_c, a_c, *D_c),
        unroll=n_chunks if cfg.chunk_unroll else 1,
    )
    return acc


def equiformer_loss(params, feats, pos, senders, receivers, target, cfg, policy=NO_POLICY) -> jnp.ndarray:
    pred = equiformer_forward(params, feats, pos, senders, receivers, cfg, policy)
    return jnp.mean(jnp.square(pred - target))
