"""Sharded, atomic, resumable checkpointing.

Layout:  <dir>/step_<N>/
            manifest.msgpack   — treedef, leaf paths, shapes/dtypes, metadata
            arrays.npz         — one entry per leaf (host-gathered)
Writes go to <dir>/.tmp_step_<N> then os.replace() — a crash mid-save never
corrupts the latest complete checkpoint (the fault-tolerance contract the
train loop's restart path relies on).

On multi-host TPU each process would save only `addressable_shards` keyed by
shard index; this container is single-process so the gather is trivial, but
the manifest already records the intended PartitionSpec names so restore can
re-shard onto a *different* mesh (elastic restart — see train/elastic.py).
"""
from __future__ import annotations

import os
import re
import shutil
from typing import Any

import jax
import msgpack
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "available_steps"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    tree: Any,
    metadata: dict | None = None,
    keep: int = 3,
) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {}
    specs = []
    for i, leaf in enumerate(leaves):
        if leaf is None:
            specs.append({"kind": "none"})
            continue
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"leaf_{i}"] = arr
        specs.append({"kind": "array", "dtype": str(arr.dtype), "shape": list(arr.shape)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "specs": specs,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _cleanup(ckpt_dir, keep)
    return final


def _cleanup(ckpt_dir: str, keep: int) -> None:
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    like: Any,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[int, Any, dict]:
    """Restore into the structure of ``like`` (values replaced, treedef kept).

    ``shardings`` (optional pytree of jax.sharding.Sharding, same structure)
    re-shards each leaf with jax.device_put — the elastic-restart path: the
    saved arrays are mesh-agnostic host arrays, so restoring onto a smaller
    or larger mesh only changes the shardings passed here.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    like_leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(like_leaves), "checkpoint/model structure mismatch"
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(like_leaves)
    )
    out = []
    for i, (ref_leaf, shard) in enumerate(zip(like_leaves, shard_leaves)):
        spec = manifest["specs"][i]
        if spec["kind"] == "none":
            out.append(None)
            continue
        arr = data[f"leaf_{i}"]
        if ref_leaf is not None and hasattr(ref_leaf, "shape"):
            assert tuple(arr.shape) == tuple(ref_leaf.shape), (
                f"leaf {i}: ckpt {arr.shape} vs model {ref_leaf.shape}"
            )
        out.append(jax.device_put(arr, shard) if shard is not None else jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]
