"""Gradient compression for data-parallel all-reduce (beyond-paper
distributed-optimization tricks; DESIGN.md §3).

Two schemes, both with error feedback (the residual from lossy compression is
carried to the next step so the compressed-SGD iterates track the exact ones):

  * int8 per-tensor quantization — 4× wire-byte reduction,
  * top-k sparsification — k/N wire fraction.

``compressed_psum_mean`` performs the data-parallel mean with int8 *wire*
operands via a manual reduce-scatter (all_to_all) + all_gather under
shard_map, so the dry-run's collective-bytes parsing actually observes the
4× reduction (a float psum after local dequant would not save wire bytes).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "int8_compress",
    "int8_decompress",
    "topk_compress",
    "error_feedback_update",
    "compressed_psum_mean",
]

Tree = Any


def int8_compress(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return q.astype(dtype) * scale


def topk_compress(x: jnp.ndarray, k_fraction: float = 0.01) -> jnp.ndarray:
    """Keep the top-|k| entries (by magnitude), zero the rest (same shape —
    a real system would ship (values, indices); the zeroed tensor is the
    mathematically identical lossy channel for error-feedback analysis)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * k_fraction))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(x.shape)


def error_feedback_update(
    grads: Tree, residual: Tree, compress_fn
) -> tuple[Tree, Tree]:
    """g̃ = C(g + e);  e' = (g + e) − g̃   (Seide et al. 1-bit SGD schema)."""
    def one(g, e):
        target = g + e
        compressed = compress_fn(target)
        return compressed, target - compressed

    pairs = jax.tree_util.tree_map(one, grads, residual)
    comp = jax.tree_util.tree_map(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree_util.tree_map(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def compressed_psum_mean(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Data-parallel mean with int8 wire traffic (call inside shard_map).

    reduce-scatter phase: each device quantizes its shard-chunks to int8 and
    all_to_all's them; local dequant + sum; all_gather (int8 again) returns
    the mean. Wire bytes: 2 × n_elements × 1B vs 2 × n_elements × 4B for the
    fp32 psum — the 4× the roofline's collective term sees.
    """
    # psum of a literal folds to the static axis size on every jax version
    # (jax.lax.axis_size only exists on newer builds).
    n = jax.lax.psum(1, axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    q, scale = int8_compress(chunks)
    # Ship int8 chunks; scales are tiny (one fp32 per device).
    q_t = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0, tiled=False)
    scales = jax.lax.all_gather(scale, axis_name)
    local_sum = jnp.sum(q_t.astype(jnp.float32) * scales[:, None], axis=0) / n
    q2, scale2 = int8_compress(local_sum[None, :])
    gathered = jax.lax.all_gather(q2[0], axis_name, tiled=False)
    scales2 = jax.lax.all_gather(scale2, axis_name)
    full = (gathered.astype(jnp.float32) * scales2[:, None]).reshape(-1)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)
