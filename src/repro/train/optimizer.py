"""Optimizers from scratch (no optax): SGD(+momentum), Adam, AdamW, LAMB.

Functional interface:
    opt = adamw(lr=3e-4, weight_decay=0.1)
    state = opt.init(params)
    params, state = opt.update(grads, state, params)

All state is a pytree mirroring params (+ a scalar step), so it checkpoints
and re-shards exactly like the params themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adam", "adamw", "lamb"]

Tree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Tree], Tree]
    update: Callable[[Tree, Tree, Tree], tuple[Tree, Tree]]
    name: str = "opt"


def _zeros_like_tree(params: Tree) -> Tree:
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd(lr: float = 1e-2, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"mu": _zeros_like_tree(params) if momentum else None, "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        if momentum:
            mu = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state["mu"], grads)
            eff = (
                jax.tree_util.tree_map(lambda m, g: momentum * m + g, mu, grads)
                if nesterov
                else mu
            )
            new_params = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, eff)
            return new_params, {"mu": mu, "step": step}
        new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new_params, {"mu": None, "step": step}

    return Optimizer(init, update, "sgd")


def _adam_core(grads, state, b1, b2, eps):
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    t = step.astype(jnp.float32)
    c1, c2 = 1 - b1**t, 1 - b2**t
    upd = jax.tree_util.tree_map(
        lambda mm, vv: (mm / c1) / (jnp.sqrt(vv / c2) + eps), m, v
    )
    return upd, {"m": m, "v": v, "step": step}


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_tree(params), "v": _zeros_like_tree(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        upd, new_state = _adam_core(grads, state, b1, b2, eps)
        new_params = jax.tree_util.tree_map(lambda p, u: p - lr * u, params, upd)
        return new_params, new_state

    return Optimizer(init, update, "adam")


def adamw(
    lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    base = adam(lr, b1, b2, eps)

    def update(grads, state, params):
        upd, new_state = _adam_core(grads, state, b1, b2, eps)
        new_params = jax.tree_util.tree_map(
            lambda p, u: p - lr * (u + weight_decay * p), params, upd
        )
        return new_params, new_state

    return Optimizer(base.init, update, "adamw")


def lamb(
    lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-6,
    weight_decay: float = 0.01,
) -> Optimizer:
    """Layer-wise adaptive moments (large-batch training at pod scale)."""
    base = adam(lr, b1, b2, eps)

    def update(grads, state, params):
        upd, new_state = _adam_core(grads, state, b1, b2, eps)

        def apply(p, u):
            u = u + weight_decay * p
            pn = jnp.linalg.norm(p.reshape(-1))
            un = jnp.linalg.norm(u.reshape(-1))
            trust = jnp.where((pn > 0) & (un > 0), pn / un, 1.0)
            return p - lr * trust * u

        return jax.tree_util.tree_map(apply, params, upd), new_state

    return Optimizer(base.init, update, "lamb")
