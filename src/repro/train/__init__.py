"""Training substrate: optimizers, loop, checkpointing, compression, elasticity."""

from repro.train.optimizer import Optimizer, sgd, adam, adamw, lamb
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.train.compression import (
    int8_compress,
    int8_decompress,
    topk_compress,
    error_feedback_update,
    compressed_psum_mean,
)

__all__ = [
    "Optimizer",
    "sgd",
    "adam",
    "adamw",
    "lamb",
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "int8_compress",
    "int8_decompress",
    "topk_compress",
    "error_feedback_update",
    "compressed_psum_mean",
]
