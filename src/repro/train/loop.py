"""Training loop with fault tolerance and straggler monitoring.

Features (DESIGN.md §3):
  * jitted train step (loss + grads + optimizer update), optional gradient
    accumulation (lax.scan over microbatches),
  * optional gradient compression with error feedback (train/compression.py),
  * step-level checkpointing (atomic; train/checkpoint.py) and restart —
    `Trainer.fit` resumes from the latest complete checkpoint after a crash,
  * straggler monitoring: per-step wall time vs an EMA; steps slower than
    `straggler_factor ×` EMA are logged as events (at pod scale the same
    signal drives re-sharding / hot-spare swap; see train/elastic.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.compression import error_feedback_update, int8_compress, int8_decompress
from repro.train.optimizer import Optimizer

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    grad_accum: int = 1
    compress_grads: bool = False
    straggler_factor: float = 3.0
    ema_decay: float = 0.9


class Trainer:
    def __init__(
        self,
        loss_fn: Callable,              # (params, batch) -> scalar loss
        optimizer: Optimizer,
        params: Any,
        cfg: TrainerConfig = TrainerConfig(),
        donate: bool = True,
    ):
        self.cfg = cfg
        self.opt = optimizer
        self.params = params
        self.opt_state = optimizer.init(params)
        self.residual = (
            jax.tree_util.tree_map(jnp.zeros_like, params) if cfg.compress_grads else None
        )
        self.step = 0
        self.straggler_events: list[dict] = []
        self._ema_dt: float | None = None
        self._loss_fn = loss_fn
        self._step_fn = self._build_step(donate)

    # ------------------------------------------------------------- step build
    def _build_step(self, donate: bool):
        cfg = self.cfg

        def grads_of(params, batch):
            if cfg.grad_accum == 1:
                return jax.value_and_grad(self._loss_fn)(params, batch)
            # batch leaves have a leading microbatch axis of size grad_accum.
            def micro(carry, mb):
                loss, acc = carry
                l, g = jax.value_and_grad(self._loss_fn)(params, mb)
                return (loss + l, jax.tree_util.tree_map(jnp.add, acc, g)), None

            zero = jax.tree_util.tree_map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zero), batch)
            scale = 1.0 / cfg.grad_accum
            return loss * scale, jax.tree_util.tree_map(lambda g: g * scale, grads)

        def step(params, opt_state, residual, batch):
            loss, grads = grads_of(params, batch)
            if cfg.compress_grads:
                def chan(g):
                    q, s = int8_compress(g)
                    return int8_decompress(q, s, g.dtype)

                grads, residual = error_feedback_update(grads, residual, chan)
            new_params, new_opt = self.opt.update(grads, opt_state, params)
            return new_params, new_opt, residual, loss

        dn = (0, 1, 2) if donate else ()
        return jax.jit(step, donate_argnums=dn)

    # ---------------------------------------------------------------- resume
    def resume(self) -> bool:
        """Restore the latest checkpoint if one exists. Returns True if so."""
        if not self.cfg.ckpt_dir:
            return False
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        self.step, restored, _meta = restore_checkpoint(self.cfg.ckpt_dir, state, step=last)
        self.params, self.opt_state = restored["params"], restored["opt"]
        return True

    def checkpoint(self) -> None:
        if self.cfg.ckpt_dir:
            save_checkpoint(
                self.cfg.ckpt_dir,
                self.step,
                {"params": self.params, "opt": self.opt_state},
                metadata={"time": time.time()},
            )

    # ------------------------------------------------------------------- fit
    def fit(
        self,
        batches: Iterator[Any],
        max_steps: int,
        crash_at: int | None = None,     # fault-injection hook for tests
        log: Callable[[str], None] = print,
    ) -> list[float]:
        losses = []
        for batch in batches:
            if self.step >= max_steps:
                break
            t0 = time.perf_counter()
            with _obs_trace.span("train.step", args={"step": self.step}):
                self.params, self.opt_state, self.residual, loss = self._step_fn(
                    self.params, self.opt_state, self.residual, batch
                )
                loss = float(loss)      # blocks: the span covers device work
            dt = time.perf_counter() - t0
            self.step += 1
            losses.append(loss)
            if _obs_metrics.enabled():
                _obs_metrics.inc("train.steps")
                _obs_metrics.observe("train.step_ms", dt * 1e3)
                _obs_metrics.set_gauge("train.loss", loss)
            # ---- straggler monitor
            if self._ema_dt is not None and dt > self.cfg.straggler_factor * self._ema_dt:
                self.straggler_events.append({"step": self.step, "dt": dt, "ema": self._ema_dt})
            self._ema_dt = dt if self._ema_dt is None else (
                self.cfg.ema_decay * self._ema_dt + (1 - self.cfg.ema_decay) * dt
            )
            if self.step % self.cfg.log_every == 0:
                log(f"step {self.step}: loss={loss:.4f} dt={dt*1e3:.1f}ms")
            if self.cfg.ckpt_dir and self.step % self.cfg.ckpt_every == 0:
                self.checkpoint()
            if crash_at is not None and self.step == crash_at:
                raise RuntimeError(f"injected crash at step {self.step}")
        return losses
