"""Elastic scaling: re-mesh and re-shard after node loss (DESIGN.md §3).

The contract at pod scale: a failed host removes a slice of devices; the
controller (a) picks the largest still-healthy mesh from the preference
ladder, (b) restores the latest checkpoint with shardings rebuilt for the
new mesh (checkpoints are mesh-agnostic host arrays — train/checkpoint.py),
(c) rescales the data pipeline to the new data-parallel width. Everything
here is pure logic over device lists, so it is fully unit-testable on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

__all__ = [
    "MeshPlan",
    "elastic_replan",
    "relocate_state_tree",
    "reshard_tree",
    "scale_batch",
]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))

    def build(self, devices: Sequence[Any] | None = None):
        if devices is None:
            return jax.make_mesh(self.shape, self.axes)
        arr = np.asarray(devices[: self.n_devices]).reshape(self.shape)
        return jax.sharding.Mesh(arr, self.axes)


def elastic_replan(
    n_healthy: int,
    model_shards: int,
    axes: tuple[str, ...] = ("data", "model"),
    *,
    graph_key: str | None = None,
) -> MeshPlan:
    """Largest mesh ≤ n_healthy that preserves the model-parallel degree.

    Model-parallel shards hold partitioned state (the COIN CE partition —
    can't shrink without re-partitioning), so the data axis absorbs the
    loss: data' = floor(n_healthy / model_shards). A **pure resize** (the
    model degree survives, only the data axis narrows) keeps the node→CE
    partition intact, so NO cached halo plan is touched — plan-cache
    ``evictions`` stays 0 and the delta path (`repro.dist.delta`) keeps
    repairing the same plan objects across the resize.

    Only when fewer than one data replica remains do we halve the model
    shards — a re-partition event: the k of the node→CE partition changed,
    so the boundary relocation is stale and the affected plans are evicted
    (DESIGN.md §8). Pass ``graph_key`` (the training graph's fingerprint or
    the planner's current versioned key) to scope that eviction to the one
    graph being re-partitioned — every ``(axes, n_pods)`` flavor of it goes
    in the one call — instead of flushing every graph's plans.
    """
    if n_healthy < 1:
        raise ValueError("no healthy devices")
    m = model_shards
    while m > 1 and n_healthy < m:
        m //= 2
    if m != model_shards:
        from repro.dist.halo import invalidate_halo_plans

        invalidate_halo_plans(graph_key)
    d = max(n_healthy // m, 1)
    return MeshPlan(shape=(d, m), axes=axes)


def reshard_tree(tree: Any, mesh, spec_tree: Any) -> Any:
    """device_put every leaf with NamedShardings over the (new) mesh."""
    def put(leaf, spec):
        return jax.device_put(leaf, jax.sharding.NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        put, tree, spec_tree, is_leaf=lambda x: x is None or hasattr(x, "shape")
    )


def relocate_state_tree(old_layout: Any, new_plan: Any, tree: Any) -> Any:
    """Carry live per-node state across an in-place re-localization.

    ``old_layout`` is a `repro.dist.halo.PlanLayout` snapshot taken BEFORE
    `repro.dist.delta.DeltaPlanner.relocalize` (the relocalize report's
    ``old_layout``); ``new_plan`` is any plan/layout in the NEW row order.
    Every leaf whose leading dims match the old blocked shape
    ``(k, n_local)`` — relocated features, per-node optimizer moments, layer
    activations — is routed ``restore_node_array(old)`` →
    ``relocate_node_array(new)``: back to global node order, then into the
    fresh blocks. The round trip is EXACT (pure gathers, no arithmetic), so
    a forward pass after relocation is bit-equivalent modulo row order.
    Leaves of any other shape (dense weights, scalars, None) pass through
    untouched.
    """
    from repro.dist.halo import relocate_node_array, restore_node_array

    old_shape = (int(old_layout.k), int(old_layout.n_local))

    def move(leaf):
        if leaf is None or not hasattr(leaf, "shape"):
            return leaf
        if tuple(np.asarray(leaf).shape[:2]) != old_shape:
            return leaf
        return relocate_node_array(
            new_plan, restore_node_array(old_layout, np.asarray(leaf)))

    return jax.tree_util.tree_map(
        move, tree, is_leaf=lambda x: x is None or hasattr(x, "shape"))


def scale_batch(global_batch: int, old_data_shards: int, new_data_shards: int) -> int:
    """Keep per-device batch constant across a re-shard (linear-scaling rule:
    the caller rescales LR by new/old)."""
    per_device = max(global_batch // old_data_shards, 1)
    return per_device * new_data_shards
