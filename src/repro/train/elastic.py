"""Elastic scaling: re-mesh and re-shard after node loss (DESIGN.md §3).

The contract at pod scale: a failed host removes a slice of devices; the
controller (a) picks the largest still-healthy mesh from the preference
ladder, (b) restores the latest checkpoint with shardings rebuilt for the
new mesh (checkpoints are mesh-agnostic host arrays — train/checkpoint.py),
(c) rescales the data pipeline to the new data-parallel width. Everything
here is pure logic over device lists, so it is fully unit-testable on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

__all__ = ["MeshPlan", "elastic_replan", "reshard_tree", "scale_batch"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))

    def build(self, devices: Sequence[Any] | None = None):
        if devices is None:
            return jax.make_mesh(self.shape, self.axes)
        arr = np.asarray(devices[: self.n_devices]).reshape(self.shape)
        return jax.sharding.Mesh(arr, self.axes)


def elastic_replan(
    n_healthy: int,
    model_shards: int,
    axes: tuple[str, ...] = ("data", "model"),
) -> MeshPlan:
    """Largest mesh ≤ n_healthy that preserves the model-parallel degree.

    Model-parallel shards hold partitioned state (the COIN CE partition —
    can't shrink without re-partitioning), so the data axis absorbs the
    loss: data' = floor(n_healthy / model_shards). If fewer than one data
    replica remains, fall back to halving model shards — a re-partition
    event, which also invalidates every cached halo plan (DESIGN.md §8):
    the k of the node→CE partition changed, so the boundary relocation is
    stale. The next `repro.dist.halo.get_halo_plan` performs the full
    replan (an incremental boundary-delta replan can slot in behind the
    same cache API later).
    """
    if n_healthy < 1:
        raise ValueError("no healthy devices")
    m = model_shards
    while m > 1 and n_healthy < m:
        m //= 2
    if m != model_shards:
        from repro.dist.halo import invalidate_halo_plans

        invalidate_halo_plans()
    d = max(n_healthy // m, 1)
    return MeshPlan(shape=(d, m), axes=axes)


def reshard_tree(tree: Any, mesh, spec_tree: Any) -> Any:
    """device_put every leaf with NamedShardings over the (new) mesh."""
    def put(leaf, spec):
        return jax.device_put(leaf, jax.sharding.NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        put, tree, spec_tree, is_leaf=lambda x: x is None or hasattr(x, "shape")
    )


def scale_batch(global_batch: int, old_data_shards: int, new_data_shards: int) -> int:
    """Keep per-device batch constant across a re-shard (linear-scaling rule:
    the caller rescales LR by new/old)."""
    per_device = max(global_batch // old_data_shards, 1)
    return per_device * new_data_shards
