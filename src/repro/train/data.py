"""Deterministic, resumable, host-sharded data pipeline.

At pod scale each host feeds only its slice of the global batch, and after a
restart the stream must resume at the exact step the checkpoint captured —
otherwise data is repeated/skipped silently. `ShardedStream`:

  * derives every batch from (seed, step) — no hidden iterator state, so
    resuming = constructing with `start_step` (recorded in the checkpoint
    metadata by the Trainer),
  * yields only this host's shard: rows [host_id·B/h, (host_id+1)·B/h),
  * supports synthetic token streams (LM), graph-feature streams (GNN), and
    hashed click streams (recsys) through a user batch_fn.

`epoch_permutation` gives a deterministic full-epoch permutation for map-
style datasets (same (seed, epoch) on every host → consistent shards).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import numpy as np

__all__ = ["ShardedStream", "epoch_permutation", "token_batch_fn", "click_batch_fn"]


@dataclasses.dataclass
class ShardedStream:
    batch_fn: Callable[[np.random.Generator, int], Any]  # (rng, global_batch) -> batch
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    start_step: int = 0

    def __post_init__(self):
        assert 0 <= self.host_id < self.n_hosts
        assert self.global_batch % self.n_hosts == 0, "global batch must split across hosts"
        self._step = self.start_step

    @property
    def step(self) -> int:
        return self._step

    def batch_at(self, step: int) -> Any:
        """The host's shard of the batch for an arbitrary step (pure)."""
        rng = np.random.default_rng((self.seed, step))
        full = self.batch_fn(rng, self.global_batch)
        per = self.global_batch // self.n_hosts
        lo = self.host_id * per

        def shard(x):
            if isinstance(x, np.ndarray) and x.ndim >= 1 and x.shape[0] == self.global_batch:
                return x[lo : lo + per]
            return x

        if isinstance(full, dict):
            return {k: shard(v) for k, v in full.items()}
        return shard(full)

    def __iter__(self) -> Iterator[Any]:
        return self

    def __next__(self) -> Any:
        b = self.batch_at(self._step)
        self._step += 1
        return b


def epoch_permutation(n: int, epoch: int, seed: int = 0) -> np.ndarray:
    """Same permutation on every host for (seed, epoch) — shard by slicing."""
    return np.random.default_rng((seed, epoch)).permutation(n)


def token_batch_fn(vocab: int, seq_len: int) -> Callable:
    def fn(rng: np.random.Generator, batch: int):
        return rng.integers(0, vocab, (batch, seq_len + 1)).astype(np.int32)

    return fn


def click_batch_fn(n_fields: int, rows_per_field: int) -> Callable:
    def fn(rng: np.random.Generator, batch: int):
        return {
            "ids": rng.integers(0, rows_per_field, (batch, n_fields)).astype(np.int32),
            "labels": (rng.random(batch) > 0.5).astype(np.float32),
        }

    return fn
