"""Process-local metrics registry: labeled counters, gauges, histograms.

The runtime twin of the repo's dry-run accounting (docs/observability.md):
`repro.launch.dryrun` *predicts* wire bytes and executed tiles; the
instrumented layers (`repro.dist.halo`, `repro.serve.graph`,
`repro.train.loop`, `repro.dist.delta`) *measure* them at runtime and fold
the numbers into one registry with a deterministic snapshot, so a pinned
test can assert prediction == observation (`tests/test_obs_integration.py`).

Design constraints, in order:

1. **True no-op when disabled.** The halo/serve hot loops call the
   module-level helpers (:func:`inc`, :func:`set_gauge`, :func:`observe`)
   unconditionally; with the registry disabled each call is one global
   read + an early return — no dict, no tuple, no instrument lookup, no
   allocation. ``tests/test_obs.py`` pins this with an allocated-blocks
   counter over the halo accounting helper. Call sites that must build a
   label tuple or compute a value should guard with :func:`enabled` first.
2. **Deterministic snapshots.** :meth:`MetricsRegistry.snapshot` sorts
   series keys and carries no wall-clock state, so two identical runs
   produce byte-identical :meth:`MetricsRegistry.to_json` output — the
   property that makes metrics dumps diffable CI artifacts
   (`tools/bench_check.py` treats bench JSONs the same way).
3. **Fixed-bucket histograms.** :class:`Histogram` uses static upper
   bounds (default: :func:`exponential_buckets`), counts + sum + exact
   min/max; :meth:`Histogram.percentile` linearly interpolates inside the
   bucket, so its error is bounded by one bucket width (pinned against a
   numpy oracle).

Instruments are identified by ``(name, labels)`` where ``labels`` is a
tuple of ``(key, value)`` string pairs — hashable, order-normalized at
registration. The text form ``name{k=v,...}`` keys the snapshot.
"""
from __future__ import annotations

import json
import threading
from bisect import bisect_left

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "exponential_buckets",
    "default_registry",
    "set_default_registry",
    "enabled",
    "enable",
    "disable",
    "inc",
    "set_gauge",
    "observe",
    "snapshot",
    "to_json",
    "reset",
]

LabelPairs = "tuple[tuple[str, str], ...]"


def exponential_buckets(start: float = 0.001, factor: float = 2.0, count: int = 24):
    """``count`` exponentially-spaced upper bounds starting at ``start``.

    The default histogram layout: with start=1 ms-equivalent and factor 2,
    24 buckets span ~7 orders of magnitude — enough for everything from a
    µs-scale metrics call to a multi-second plan rebuild."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError(f"bad bucket spec start={start} factor={factor} count={count}")
    out, edge = [], float(start)
    for _ in range(count):
        out.append(edge)
        edge *= factor
    return tuple(out)


_DEFAULT_BUCKETS = exponential_buckets()


class Counter:
    """Monotonic accumulator. ``inc`` with a negative value is an error."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, value: float = 1.0) -> None:
        if value < 0:
            raise ValueError(f"counter increments must be >= 0, got {value}")
        self.value += value

    def _snap(self):
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins sample (cache hit rate, resident entries, loss)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def add(self, value: float) -> None:
        self.value += float(value)

    def _snap(self):
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram: static upper bounds + overflow, sum/count,
    exact min/max. ``observe`` is O(log buckets) (bisect)."""

    __slots__ = ("bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, bounds=_DEFAULT_BUCKETS):
        b = tuple(float(x) for x in bounds)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"histogram bounds must be strictly increasing, got {b!r}")
        self.bounds = b
        self.counts = [0] * (len(b) + 1)          # last slot = overflow
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Linear-interpolated percentile, error <= one bucket width.

        ``p`` in [0, 100]. Empty histogram -> 0.0. The first/last populated
        buckets interpolate against the exact recorded min/max, so p0 and
        p100 are exact and a single-bucket histogram stays inside the data
        range instead of snapping to bucket edges."""
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile must be in [0, 100], got {p}")
        if not self.count:
            return 0.0
        rank = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - seen) / c
                return lo + frac * (hi - lo)
            seen += c
        return self.max

    def _snap(self):
        return {
            "type": "histogram",
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "p50": self.percentile(50.0),
            "p99": self.percentile(99.0),
        }


def _series_key(name: str, labels) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


def _norm_labels(labels) -> tuple:
    if not labels:
        return ()
    if isinstance(labels, dict):
        items = labels.items()
    else:
        items = labels
    return tuple(sorted((str(k), str(v)) for k, v in items))


class MetricsRegistry:
    """Thread-safe instrument store keyed by ``(kind, name, labels)``.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent, so
    call sites never pre-register); a name re-used across kinds is an
    error — one metric name means one thing in the catalog
    (docs/observability.md)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._series: dict[str, object] = {}
        self._kinds: dict[str, str] = {}

    def _get(self, kind: str, name: str, labels, factory):
        key = _series_key(name, _norm_labels(labels))
        with self._lock:
            prev = self._kinds.get(name)
            if prev is not None and prev != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {prev}, not {kind}"
                )
            inst = self._series.get(key)
            if inst is None:
                self._kinds[name] = kind
                inst = self._series[key] = factory()
            return inst

    def counter(self, name: str, labels=()) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, labels=()) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, labels=(), bounds=_DEFAULT_BUCKETS) -> Histogram:
        h = self._get("histogram", name, labels, lambda: Histogram(bounds))
        return h

    # ------------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        """Sorted {series-key: state} dict — pure data, no timestamps, so
        identical runs produce identical snapshots."""
        with self._lock:
            return {k: self._series[k]._snap() for k in sorted(self._series)}

    def to_json(self, path: str | None = None, indent: int = 1) -> str:
        text = json.dumps(self.snapshot(), sort_keys=True, indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    def reset(self) -> None:
        with self._lock:
            self._series.clear()
            self._kinds.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._series)


# ============================================================ module fast path
# One module-global registry + one bool. The helpers below are what the
# instrumented layers call per event; `_ENABLED is False` must make each a
# single global load + return (the pinned zero-overhead contract), so the
# signatures are fixed — no *args/**kwargs packing on the disabled path.
_DEFAULT = MetricsRegistry()
_ENABLED = False


def default_registry() -> MetricsRegistry:
    return _DEFAULT


def set_default_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests isolate through this)."""
    global _DEFAULT
    old, _DEFAULT = _DEFAULT, reg
    return old


def enabled() -> bool:
    return _ENABLED


def enable(registry: MetricsRegistry | None = None) -> MetricsRegistry:
    """Turn the module fast-path helpers on (optionally onto a fresh
    registry). Returns the active registry."""
    global _ENABLED
    if registry is not None:
        set_default_registry(registry)
    _ENABLED = True
    return _DEFAULT


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def reset() -> None:
    """Clear the default registry (the enabled flag is left as is)."""
    _DEFAULT.reset()


def inc(name: str, value: float = 1.0, labels=()) -> None:
    if not _ENABLED:
        return
    _DEFAULT.counter(name, labels).inc(value)


def set_gauge(name: str, value: float, labels=()) -> None:
    if not _ENABLED:
        return
    _DEFAULT.gauge(name, labels).set(value)


def observe(name: str, value: float, labels=(), bounds=_DEFAULT_BUCKETS) -> None:
    if not _ENABLED:
        return
    _DEFAULT.histogram(name, labels, bounds).observe(value)


def snapshot() -> dict:
    return _DEFAULT.snapshot()


def to_json(path: str | None = None, indent: int = 1) -> str:
    return _DEFAULT.to_json(path, indent)
