"""Span tracing with Chrome trace-event export (Perfetto-loadable).

A :class:`TraceRecorder` collects *complete* events (``ph == "X"``) with
microsecond timestamps relative to the recorder's creation, plus counter
(``"C"``), instant (``"i"``) and metadata (``"M"``) events. The export
format is the Chrome trace-event JSON object form::

    {"traceEvents": [...], "displayTimeUnit": "ms"}

which chrome://tracing and https://ui.perfetto.dev load directly.

Two honesty mechanisms for JAX's async dispatch:

* **Sync points at span edges** — ``span(..., sync=x)`` (or setting
  ``handle.sync`` inside the block) calls ``jax.block_until_ready`` before
  recording the span end, so a span around a jitted call measures device
  work, not just Python dispatch time. Off by default: un-synced spans
  measure dispatch, which is exactly what the overlap timeline wants for
  the interior-compute track.
* **Raw complete events** — :meth:`TraceRecorder.complete` records a span
  from explicit start/duration, used by `repro.obs.instrument`'s
  ``overlap_timeline`` to place the boundary collective on its own
  ``wire`` track spanning dispatch → ready, visibly overlapping the
  interior-compute spans on the main track.

Thread-safe: the serve engine's async path and shard_map callbacks may
record concurrently. Each OS thread gets a small stable ``tid`` plus a
``thread_name`` metadata event; logical tracks (e.g. ``wire``) get their
own tids the same way. Span names follow ``layer.operation`` —
see docs/observability.md for the catalog.

When tracing is disabled the module-level helpers are no-ops on the same
fast-path contract as `repro.obs.metrics`. A passthrough to
``jax.profiler.trace`` (:func:`jax_profiler_trace`) is provided for
when a full XLA-level profile is wanted instead of span tracing.
"""
from __future__ import annotations

import contextlib
import functools
import json
import threading
import time

__all__ = [
    "TraceRecorder",
    "SpanHandle",
    "default_tracer",
    "set_default_tracer",
    "tracing_enabled",
    "enable_tracing",
    "disable_tracing",
    "span",
    "traced",
    "instant",
    "counter",
    "export",
    "jax_profiler_trace",
]


class SpanHandle:
    """Mutable handle yielded by :meth:`TraceRecorder.span`.

    ``handle.sync = value`` arranges a ``jax.block_until_ready(value)``
    before the span end is recorded; ``handle.args.update(...)`` attaches
    key/values shown in the Perfetto args pane."""

    __slots__ = ("sync", "args")

    def __init__(self, sync=None, args=None):
        self.sync = sync
        self.args = dict(args) if args else {}


def _block(x) -> None:
    import jax

    jax.block_until_ready(x)


class TraceRecorder:
    """Collects Chrome trace events; timestamps are µs since construction."""

    def __init__(self, pid: int = 1, process_name: str = "repro"):
        self.pid = pid
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._t0 = time.perf_counter_ns()
        self._tids: dict[object, int] = {}
        self._meta(
            {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": process_name}}
        )

    # ------------------------------------------------------------- plumbing
    def _meta(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    def now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    def _tid_for(self, key, label: str) -> int:
        with self._lock:
            tid = self._tids.get(key)
            if tid is None:
                tid = len(self._tids) + 1
                self._tids[key] = tid
                self._events.append(
                    {"name": "thread_name", "ph": "M", "pid": self.pid,
                     "tid": tid, "args": {"name": label}}
                )
            return tid

    def _thread_tid(self) -> int:
        t = threading.current_thread()
        return self._tid_for(t.ident, t.name)

    def track_tid(self, name: str) -> int:
        """tid for a named logical track (e.g. ``wire``) rather than an OS
        thread — lets async device work live on its own timeline row."""
        return self._tid_for(("track", name), name)

    # --------------------------------------------------------------- events
    def complete(self, name: str, ts_us: float, dur_us: float,
                 tid: int | None = None, args: dict | None = None) -> None:
        """Record a complete ("X") event from explicit start + duration."""
        ev = {"name": name, "ph": "X", "ts": ts_us, "dur": max(dur_us, 0.0),
              "pid": self.pid, "tid": self._thread_tid() if tid is None else tid}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, args: dict | None = None) -> None:
        ev = {"name": name, "ph": "i", "ts": self.now_us(), "pid": self.pid,
              "tid": self._thread_tid(), "s": "t"}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def counter(self, name: str, values: dict) -> None:
        """Counter ("C") event — renders as a stacked area track."""
        ev = {"name": name, "ph": "C", "ts": self.now_us(), "pid": self.pid,
              "tid": 0, "args": {k: float(v) for k, v in values.items()}}
        with self._lock:
            self._events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, sync=None, args: dict | None = None,
             track: str | None = None):
        """Context manager recording one complete event around the block.

        ``sync`` (or ``handle.sync`` set inside) is passed to
        ``jax.block_until_ready`` before the end timestamp, attributing
        device time to the span. ``track`` places the span on a named
        logical track instead of the calling thread's row."""
        handle = SpanHandle(sync=sync, args=args)
        t_start = self.now_us()
        try:
            yield handle
        finally:
            if handle.sync is not None:
                _block(handle.sync)
            t_end = self.now_us()
            tid = self.track_tid(track) if track else self._thread_tid()
            self.complete(name, t_start, t_end - t_start, tid=tid,
                          args=handle.args or None)

    def traced(self, name: str | None = None, sync_result: bool = False):
        """Decorator form of :meth:`span`. ``sync_result=True`` blocks on
        the wrapped function's return value before closing the span."""
        def deco(fn):
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                with self.span(label) as h:
                    out = fn(*a, **kw)
                    if sync_result:
                        h.sync = out
                    return out

            return wrapper

        return deco

    # --------------------------------------------------------------- export
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
            f.write("\n")

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


# ============================================================ module fast path
_DEFAULT: TraceRecorder | None = None


def default_tracer() -> TraceRecorder | None:
    return _DEFAULT


def set_default_tracer(tr: TraceRecorder | None) -> TraceRecorder | None:
    global _DEFAULT
    old, _DEFAULT = _DEFAULT, tr
    return old


def tracing_enabled() -> bool:
    return _DEFAULT is not None


def enable_tracing() -> TraceRecorder:
    """Install (or return) the process-global recorder."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = TraceRecorder()
    return _DEFAULT


def disable_tracing() -> None:
    global _DEFAULT
    _DEFAULT = None


class _NullSpan:
    """Disabled-path context manager: no recorder, no event, near-zero cost.

    A single module-level instance is reused; the handle it yields still
    accepts ``.sync``/``.args`` writes (they go nowhere)."""

    __slots__ = ("_handle",)

    def __init__(self):
        self._handle = SpanHandle()

    def __enter__(self):
        return self._handle

    def __exit__(self, *exc):
        self._handle.sync = None
        return False


_NULL_SPAN = _NullSpan()


def span(name: str, sync=None, args: dict | None = None, track: str | None = None):
    if _DEFAULT is None:
        return _NULL_SPAN
    return _DEFAULT.span(name, sync=sync, args=args, track=track)


def traced(name: str | None = None, sync_result: bool = False):
    """Decorator that records through whatever tracer is installed at call
    time (so enabling tracing after import still takes effect)."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            tr = _DEFAULT
            if tr is None:
                return fn(*a, **kw)
            with tr.span(label) as h:
                out = fn(*a, **kw)
                if sync_result:
                    h.sync = out
                return out

        return wrapper

    return deco


def instant(name: str, args: dict | None = None) -> None:
    if _DEFAULT is not None:
        _DEFAULT.instant(name, args)


def counter(name: str, values: dict) -> None:
    if _DEFAULT is not None:
        _DEFAULT.counter(name, values)


def export(path: str) -> bool:
    """Export the global recorder's events; False if tracing is disabled."""
    if _DEFAULT is None:
        return False
    _DEFAULT.export(path)
    return True


@contextlib.contextmanager
def jax_profiler_trace(log_dir: str):
    """Passthrough to ``jax.profiler.trace`` for full XLA-level profiles.

    Span tracing answers "does the collective overlap the interior
    compute"; the jax profiler answers "what is XLA doing inside that
    span". Degrades to a no-op if the profiler is unavailable (e.g.
    stripped CPU builds)."""
    try:
        import jax.profiler as _prof

        ctx = _prof.trace(log_dir)
    except Exception:  # noqa: BLE001 - profiler availability is best-effort
        ctx = contextlib.nullcontext()
    with ctx:
        yield
