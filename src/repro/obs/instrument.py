"""Bridges from existing accounting paths into the obs registry/tracer.

Nothing here invents a number: every gauge is fed from a value an existing
layer already computes — `repro.dist.halo.HaloPlan` wire properties,
`repro.core.dataflow.exchange_cost`, `plan_cache_stats`,
`repro.graph.structure.blocked_stats` / `PlanBlockedAdjacency.stats`, the
`repro.dist.delta.DeltaPlanner.apply` report. That makes the pinned
metrics-vs-accounting equality tests (`tests/test_obs_integration.py`)
meaningful: the snapshot must reproduce the accounting bit-for-bit.

Every recorder early-returns when metrics are disabled BEFORE touching the
source object (the zero-overhead contract of `repro.obs.metrics` extends
to these helpers — they sit on the halo/serve hot paths).

`repro.dist` / `jax` are imported lazily inside functions so that
``import repro.obs`` stays dependency-light and free of import cycles
(`repro.dist.halo` itself imports `repro.obs.metrics`).
"""
from __future__ import annotations

from repro.obs import metrics, trace

__all__ = [
    "record_exchange",
    "observe_plan_cache",
    "record_blocked",
    "record_delta_report",
    "record_relocalize_report",
    "record_compact_report",
    "overlap_timeline",
]


def record_exchange(plan, d_feat: int, payload: str | None = None) -> None:
    """Runtime twin of the dry-run ``exchange`` accounting
    (`repro.launch.dryrun.exchange_accounting`): fold one halo exchange's
    wire model for ``plan`` at feature width ``d_feat`` into the registry.

    Gauges (bytes are per device per exchange, from
    `repro.core.dataflow.ExchangeCost`): ``halo.rows_per_device`` per tier,
    ``halo.wire_bytes_per_exchange``, ``halo.exposed_bytes_per_exchange``,
    ``halo.payload_bits``, ``halo.overlap_fraction``, ``halo.wire_fraction``,
    ``halo.compression_vs_fp32``, ``halo.boundary_rows_max_device``.
    Counter ``halo.exchanges`` counts recorded exchanges."""
    if not metrics.enabled():
        return
    from repro.core.dataflow import exchange_cost
    from repro.core.quant import payload_bits

    bits = payload_bits(payload)
    ov = plan.overlap_fraction()
    cost = exchange_cost(plan.halo_rows_per_device, d_feat, bits, ov)
    metrics.inc("halo.exchanges")
    metrics.set_gauge("halo.rows_per_device", plan.halo_rows_per_device,
                      (("tier", "total"),))
    metrics.set_gauge("halo.rows_per_device", plan.broadcast_rows_per_device,
                      (("tier", "broadcast"),))
    if plan.is_hierarchical:
        metrics.set_gauge("halo.rows_per_device", plan.inter_pod_rows_crossing,
                          (("tier", "inter_pod_crossing"),))
        metrics.set_gauge("halo.rows_per_device", plan.intra_pod_rows_per_device,
                          (("tier", "intra_pod"),))
    metrics.set_gauge("halo.payload_bits", bits)
    metrics.set_gauge("halo.overlap_fraction", ov)
    metrics.set_gauge("halo.wire_fraction", plan.wire_fraction())
    metrics.set_gauge("halo.wire_bytes_per_exchange", cost.wire_bytes)
    metrics.set_gauge("halo.exposed_bytes_per_exchange", cost.exposed_bytes)
    metrics.set_gauge("halo.compression_vs_fp32", cost.compression)
    bnd = plan.boundary_rows_per_device()
    metrics.set_gauge("halo.boundary_rows_max_device",
                      int(bnd.max()) if bnd.size else 0)


def observe_plan_cache() -> None:
    """Mirror `repro.dist.halo.plan_cache_stats` into ``plan_cache.*``
    gauges (hits, misses, evictions, size)."""
    if not metrics.enabled():
        return
    from repro.dist.halo import plan_cache_stats

    for key, v in plan_cache_stats().items():
        metrics.set_gauge(f"plan_cache.{key}", v)


def record_blocked(stats, scope: str = "plan") -> None:
    """Fold a blocked-adjacency accounting record into ``bsr.*`` gauges.

    ``stats`` is the dict from `repro.graph.structure.blocked_stats` /
    `repro.dist.halo.plan_blocked_shape`, or a materialized
    `repro.dist.halo.PlanBlockedAdjacency` (its ``stats()`` is used; its
    ``lens.sum()`` IS ``nnz_blocks``, the executed-tile count). ``scope``
    labels the series (e.g. ``plan``, ``interior``, ``boundary``,
    ``global``)."""
    if not metrics.enabled():
        return
    if not isinstance(stats, dict):
        stats = stats.stats()
    labels = (("scope", scope),)
    metrics.set_gauge("bsr.executed_tiles", stats["nnz_blocks"], labels)
    metrics.set_gauge("bsr.max_nnzb", stats["max_nnzb"], labels)
    metrics.set_gauge("bsr.padded_tile_fraction",
                      stats["padded_tile_fraction"], labels)
    if "dense_tiles" in stats:
        metrics.set_gauge("bsr.dense_tiles", stats["dense_tiles"], labels)


def record_delta_report(report: dict) -> None:
    """Fold a `repro.dist.delta.DeltaPlanner.apply` report into ``delta.*``
    series: edit/remap counters, dirty-device gauge, the structural flag,
    repair latency (``delta.apply_ms`` histogram, if timed), and the
    executed-tile locality-drift gauge (``delta.drift_ratio``, if the
    report measured drift)."""
    if not metrics.enabled():
        return
    metrics.inc("delta.applies")
    metrics.inc("delta.inserts", float(report.get("inserts", 0)))
    metrics.inc("delta.deletes", float(report.get("deletes", 0)))
    metrics.inc("delta.senders_remapped", float(report.get("senders_remapped", 0)))
    metrics.inc("delta.blocked_patched", float(report.get("blocked_patched", 0)))
    dirty = report.get("dirty_devices") or ()
    metrics.set_gauge("delta.dirty_devices", len(dirty))
    metrics.set_gauge("delta.structural", 1.0 if report.get("structural") else 0.0)
    if "apply_ms" in report:
        metrics.observe("delta.apply_ms", float(report["apply_ms"]))
    if report.get("drift") is not None:
        d = report["drift"]
        metrics.set_gauge("delta.drift_ratio", d["drift_ratio"])
        metrics.set_gauge("delta.executed_tiles_current", d["executed_tiles_current"])
        metrics.set_gauge("delta.executed_tiles_reordered", d["executed_tiles_reordered"])


def record_relocalize_report(report: dict) -> None:
    """Fold a `repro.dist.delta.DeltaPlanner.relocalize` report into
    ``delta.relocalize*`` series: a fire counter, the re-localization
    latency histogram, and the executed-tile counts the fresh order was
    installed against (before = the drifted layout it replaced)."""
    if not metrics.enabled():
        return
    metrics.inc("delta.relocalizes")
    if "relocalize_ms" in report:
        metrics.observe("delta.relocalize_ms", float(report["relocalize_ms"]))
    metrics.set_gauge("delta.relocalize_tiles_before",
                      report.get("executed_tiles_before", 0))
    metrics.set_gauge("delta.relocalize_tiles_after",
                      report.get("executed_tiles_after", 0))


def record_compact_report(report: dict) -> None:
    """Fold a `repro.dist.delta.DeltaPlanner.compact` report into
    ``delta.compact*`` series plus the ``delta.pad_occupancy`` gauge (live
    slots / padded slots across tiers and store — 1.0 after a rebuildful
    compact, by construction)."""
    if not metrics.enabled():
        return
    metrics.inc("delta.compacts")
    metrics.inc("delta.pad_bytes_reclaimed",
                float(max(report.get("bytes_reclaimed", 0), 0)))
    occ = report.get("pad_occupancy") or {}
    metrics.set_gauge("delta.pad_occupancy", float(occ.get("frac", 1.0)))
    if "compact_ms" in report:
        metrics.observe("delta.compact_ms", float(report["compact_ms"]))


def overlap_timeline(plan, feats, mesh, tracer=None, payload: str | None = None,
                     steps: int = 3, via: str = "all_gather"):
    """Record a trace that SHOWS the boundary collective hiding behind
    interior compute — the overlapped schedule of docs/communication.md as
    a Perfetto timeline instead of an exposed-bytes formula.

    Runs the split schedule as three separately-jitted shard_map programs
    over the relocated ``(k, n_local, d)`` feature blocks:

      1. ``collect``  — the boundary collective alone
         (`repro.dist.halo.halo_exchange` / ``hier_halo_exchange``),
      2. ``interior`` — the wire-independent aggregation term (masked
         weights, exactly `repro.dist.halo.split_halo_aggregate`'s
         interior half),
      3. ``combine``  — the boundary term + sum.

    Each step dispatches (1) asynchronously, runs (2) inside a synced span
    on the calling thread's track, THEN blocks on (1) and records it as a
    complete event on the ``wire`` track spanning dispatch → ready. The
    wire span therefore encloses the interior span whenever the collective
    was still in flight while interior compute ran — which is exactly
    JAX's async-dispatch overlap mechanism, honestly measured (span edges
    use ``block_until_ready``; nothing is drawn that did not happen).
    Returns the final ``(k, n_local, d)`` aggregate (bit-identical to the
    serialized schedule, per the `split_halo_aggregate` contract)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist.halo import halo_exchange, hier_halo_exchange
    from repro.graph.ops import aggregate

    if tracer is None:
        tracer = trace.enable_tracing()
    hier = plan.is_hierarchical
    spec_axes = plan.axes if hier else plan.axes[0]
    arrs = plan.device_arrays()
    if hier:
        send_tabs, (senders, receivers, edge_w) = arrs[:2], arrs[2:]
    else:
        send_tabs, (senders, receivers, edge_w) = arrs[:1], arrs[1:]
    n_local = plan.n_local

    def _smap(body, n_in):
        return jax.jit(jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(spec_axes),) * n_in, out_specs=P(spec_axes),
            check_vma=False,
        ))

    def collect_body(h, *tabs):
        h = h[0]
        if hier:
            halo = hier_halo_exchange(h, tabs[0][0], tabs[1][0], plan.axes,
                                      via=via, payload=payload)
        else:
            halo = halo_exchange(h, tabs[0][0], plan.axes[0],
                                 via=via, payload=payload)
        return halo[None]

    def interior_body(h, s, r, w):
        h, s, r, w = h[0], s[0], r[0], w[0]
        w_int = jnp.where(s >= n_local, jnp.zeros((), w.dtype), w)
        return aggregate(h, jnp.minimum(s, n_local - 1), r, n_local, w_int)[None]

    def combine_body(halo, out_int, s, r, w):
        halo, out_int, s, r, w = halo[0], out_int[0], s[0], r[0], w[0]
        if halo.shape[0] == 0:
            return out_int[None]
        w_bnd = jnp.where(s >= n_local, w, jnp.zeros((), w.dtype))
        bnd = aggregate(halo, jnp.clip(s - n_local, 0, halo.shape[0] - 1),
                        r, n_local, w_bnd)
        return (out_int + bnd)[None]

    collect = _smap(collect_body, 1 + len(send_tabs))
    interior = _smap(interior_body, 4)
    combine = _smap(combine_body, 5)

    # Compile all three programs outside the timed loop so the recorded
    # steps show steady-state dispatch, not tracing/lowering time.
    with tracer.span("overlap.compile") as h:
        halo = collect(feats, *send_tabs)
        out_int = interior(feats, senders, receivers, edge_w)
        h.sync = combine(halo, out_int, senders, receivers, edge_w)

    wire_tid = tracer.track_tid("wire")
    out = None
    for i in range(steps):
        t0 = tracer.now_us()
        halo = collect(feats, *send_tabs)              # async dispatch
        with tracer.span("overlap.interior_compute", args={"step": i}) as h:
            out_int = interior(feats, senders, receivers, edge_w)
            h.sync = out_int
        jax.block_until_ready(halo)
        tracer.complete(
            "halo.exchange.boundary_collective", t0, tracer.now_us() - t0,
            tid=wire_tid,
            args={"step": i, "rows_per_device": plan.halo_rows_per_device,
                  "payload": payload or "fp32"},
        )
        with tracer.span("overlap.boundary_combine", args={"step": i}) as h:
            out = combine(halo, out_int, senders, receivers, edge_w)
            h.sync = out
    record_exchange(plan, int(feats.shape[-1]), payload)
    return out
