"""repro.obs — runtime observability: metrics registry + span tracing.

`repro.obs.metrics` is the process-local registry (counters, gauges,
histograms; deterministic snapshots; disabled-path no-op). `repro.obs.trace`
records spans and exports Chrome trace-event JSON for Perfetto.
`repro.obs.instrument` (imported explicitly — it reaches into `repro.dist`)
bridges the existing accounting paths into both. See docs/observability.md
for the metric catalog and span naming convention.

Only ``metrics`` and ``trace`` are imported eagerly: instrumented layers
(`repro.dist.halo`, `repro.serve.graph`, …) import ``repro.obs`` at module
load, so this package must stay leaf-level (no repro.dist / jax imports).
"""
from repro.obs import metrics, trace

__all__ = ["metrics", "trace"]
