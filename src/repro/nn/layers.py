"""Basic layers: linear, norms, MLPs — functional style, dict pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "linear",
    "rms_norm",
    "layer_norm",
    "mlp_init",
    "mlp_apply",
    "gelu",
    "silu",
]


def dense_init(key: jax.Array, d_in: int, d_out: int, scale: str = "fan_in", dtype=jnp.float32) -> dict:
    if scale == "fan_in":
        std = (1.0 / d_in) ** 0.5
    elif scale == "fan_avg":
        std = (2.0 / (d_in + d_out)) ** 0.5
    else:
        std = float(scale)
    w = jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(std, dtype)
    return {"w": w, "b": jnp.zeros((d_out,), dtype)}


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return out * gamma


def layer_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return out * gamma + beta


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x, approximate=True)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(x)


def mlp_init(key: jax.Array, dims: list[int], dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": dense_init(k, dims[i], dims[i + 1], dtype=dtype) for i, k in enumerate(keys)}


def mlp_apply(p: dict, x: jnp.ndarray, act=silu, final_act: bool = False) -> jnp.ndarray:
    n = len(p)
    for i in range(n):
        x = linear(p[f"l{i}"], x)
        if i < n - 1 or final_act:
            x = act(x)
    return x
