"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Top-k routing → stable sort by expert id → position-in-expert via exclusive
cumsum of expert counts → scatter into an (E, C, D) buffer → batched expert
GEMMs → gather + gate-weighted combine. All shapes static (capacity factor),
no (T, E, C) one-hot tensors, so it scales to the 64-expert assigned configs
and shards cleanly: the (E, C, D) buffer carries the expert-parallel axis and
pjit lowers dispatch/return as all-to-alls over the `model` mesh axis.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_model: int
    d_ff: int
    capacity_factor: float = 1.25
    # Hierarchical dispatch: sort/bucket tokens WITHIN each of `groups`
    # token groups (one per data shard). Keeps the dispatch sort local to a
    # device and turns the expert exchange into the canonical EP all-to-all
    # of a (groups, E, C, D) buffer — the fix for the collective-bound MoE
    # cells found in EXPERIMENTS.md §Perf. groups=1 reproduces the flat
    # (baseline) dispatch.
    groups: int = 1

    def capacity(self, n_tokens: int) -> int:
        cap = int(self.capacity_factor * n_tokens * self.top_k / self.num_experts)
        cap = max(8, -(-cap // 8) * 8)  # round up to 8 for tiling
        # Streams of ≤ 512 tokens (decode steps, small teacher-forced
        # prefills, unit graphs) dispatch drop-free: a token takes at most
        # one slot per expert, so C ≥ T can never overflow. Within that
        # bound stepwise decode equals the full forward pass exactly; above
        # it capacity reverts to the Switch throughput/memory tradeoff and
        # may drop tokens under routing imbalance (cf. hillclimb T1-c).
        if n_tokens <= 512:
            cap = max(cap, -(-n_tokens // 8) * 8)
        return cap


def moe_init(key: jax.Array, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    std_in, std_out = (1.0 / D) ** 0.5, (1.0 / F) ** 0.5
    return {
        "router": jax.random.normal(kr, (D, E), dtype) * std_in,
        "w_gate": jax.random.normal(kg, (E, D, F), dtype) * std_in,
        "w_up": jax.random.normal(ku, (E, D, F), dtype) * std_in,
        "w_down": jax.random.normal(kd, (E, F, D), dtype) * std_out,
    }


def _dispatch(x, gate_vals, expert_idx, E, K, C):
    """Sort-based dispatch of one token group → ((E, C, D) buffer, meta)."""
    T, D = x.shape
    flat_e = expert_idx.reshape(-1)                       # (T·K,)
    flat_t = jnp.tile(jnp.arange(T)[:, None], (1, K)).reshape(-1)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros(E, jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * K, dtype=jnp.int32) - starts[se]
    keep = pos < C
    pos_c = jnp.minimum(pos, C - 1)
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[se, pos_c].add(jnp.where(keep[:, None], x[st], 0.0))
    return buf, (se, st, sg, keep, pos_c)


def _combine(y, meta, T, D):
    se, st, sg, keep, pos_c = meta
    tok_y = y[se, pos_c] * jnp.where(keep, sg, 0.0)[:, None].astype(y.dtype)
    return jnp.zeros((T, D), y.dtype).at[st].add(tok_y)


def moe_apply(
    p: dict, x: jnp.ndarray, cfg: MoEConfig, policy=None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (T, D) flattened tokens → (out: (T, D), aux_loss: scalar).

    aux_loss is the Switch/GShard load-balance loss E·Σ_e f_e·p_e.
    With cfg.groups = G > 1, routing/sort/scatter run independently per
    group of T/G tokens (vmap) and only the (G, E, C_loc, D) buffer crosses
    the expert-parallel axis (all-to-all under pjit).
    """
    T, D = x.shape
    E, K, G = cfg.num_experts, cfg.top_k, cfg.groups
    assert T % G == 0, (T, G)
    logits = x @ p["router"]                              # (T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)       # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (global statistics)
    frac_tokens = jnp.zeros(E).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    C = cfg.capacity(T // G)
    xg = x.reshape(G, T // G, D)
    gg = gate_vals.reshape(G, T // G, K)
    eg = expert_idx.reshape(G, T // G, K)
    buf, meta = jax.vmap(lambda xi, gi, ei: _dispatch(xi, gi, ei, E, K, C))(xg, gg, eg)
    if policy is not None:
        buf = policy.constrain(buf, "moe_buf")            # (G, E, C, D): EP axis

    # ---- expert GEMMs (SwiGLU), E-major so EP shards over experts
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", buf, p["w_up"]
    )
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])      # (G, E, C, D)
    if policy is not None:
        y = policy.constrain(y, "moe_buf")

    out = jax.vmap(lambda yi, mi: _combine(yi, mi, T // G, D))(y, meta)
    return out.reshape(T, D), aux
