"""Neural-net layer library (pure-functional, pytree params)."""

from repro.nn.layers import (
    dense_init,
    linear,
    rms_norm,
    layer_norm,
    mlp_init,
    mlp_apply,
    gelu,
    silu,
)
from repro.nn.attention import (
    AttentionConfig,
    attention_init,
    attention_apply,
    attention_decode,
    rope,
    init_kv_cache,
)
from repro.nn.moe import MoEConfig, moe_init, moe_apply

__all__ = [
    "dense_init",
    "linear",
    "rms_norm",
    "layer_norm",
    "mlp_init",
    "mlp_apply",
    "gelu",
    "silu",
    "AttentionConfig",
    "attention_init",
    "attention_apply",
    "attention_decode",
    "rope",
    "init_kv_cache",
    "MoEConfig",
    "moe_init",
    "moe_apply",
]
