"""Real-spherical-harmonic rotation matrices + eSCN frame alignment.

EquiformerV2 [arXiv:2306.12059] relies on the eSCN trick [arXiv:2302.03655]:
rotate each edge's irrep features so the edge direction maps to the z-axis;
in that frame SO(3) tensor-product convolutions reduce to per-m SO(2) linear
maps (block-diagonal in |m|), dropping the cost from O(L⁶) to O(L³).

We implement the two ingredients from scratch (no e3nn dependency):

  * `rotation_align_z`   — batched Rodrigues rotation taking unit vectors to ẑ,
  * `real_sh_rotations`  — Wigner-D matrices in the REAL SH basis, built with
    the Ivanic–Ruedenberg recursion (J. Phys. Chem. 1996, 100, 6342; the same
    construction e3nn tabulates). Pure jnp, vectorized over edges, static
    Python loops over (l, m, m′) — fine for l ≤ 6 (≤ 13×13 blocks).

Conventions: real SH index m ∈ [−l, l]; the l=1 basis ordering is (y, z, x),
so rotations about ẑ act on each (m, −m) pair as a 2-D rotation by m·γ —
the block-diagonal property eSCN needs (property-tested in tests/).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rotation_align_z", "real_sh_rotations", "sh_block_slices"]

_EPS = 1e-9


def rotation_align_z(u: jnp.ndarray) -> jnp.ndarray:
    """(E, 3) unit vectors → (E, 3, 3) rotations R with R @ u = ẑ.

    Rodrigues formula about axis a = u × ẑ; the antipodal case u ≈ −ẑ falls
    back to a π rotation about x̂.
    """
    c = u[..., 2]                                           # cos θ = u·ẑ
    a = jnp.stack([u[..., 1], -u[..., 0], jnp.zeros_like(c)], axis=-1)  # u × ẑ
    s2 = jnp.sum(a * a, axis=-1)                            # sin² θ
    K = _skew(a)
    K2 = K @ K
    factor = jnp.where(s2 > _EPS, (1.0 - c) / jnp.maximum(s2, _EPS), 0.0)
    eye = jnp.eye(3, dtype=u.dtype)
    R = eye + K + K2 * factor[..., None, None]
    # Antipodal: rotate π about x̂ (diag(1, −1, −1)).
    flip = jnp.asarray([[1.0, 0, 0], [0, -1.0, 0], [0, 0, -1.0]], u.dtype)
    anti = (c < -1.0 + 1e-6)[..., None, None]
    return jnp.where(anti, flip, R)


def _skew(a: jnp.ndarray) -> jnp.ndarray:
    z = jnp.zeros_like(a[..., 0])
    return jnp.stack(
        [
            jnp.stack([z, -a[..., 2], a[..., 1]], -1),
            jnp.stack([a[..., 2], z, -a[..., 0]], -1),
            jnp.stack([-a[..., 1], a[..., 0], z], -1),
        ],
        -2,
    )


def _r1_from_cartesian(R: jnp.ndarray) -> jnp.ndarray:
    """l=1 real-SH rotation from the Cartesian matrix; basis order (y, z, x)."""
    perm = jnp.asarray([1, 2, 0])
    return R[..., perm[:, None], perm[None, :]]


def real_sh_rotations(R: jnp.ndarray, l_max: int) -> list[jnp.ndarray]:
    """[D_0, D_1, …, D_{l_max}] with D_l of shape (..., 2l+1, 2l+1).

    Ivanic–Ruedenberg recursion: D_l is assembled from D_{l−1} and D_1 via
    the U/V/W helper functions with closed-form u/v/w coefficients.
    """
    batch = R.shape[:-2]
    D = [jnp.ones(batch + (1, 1), R.dtype)]
    if l_max == 0:
        return D
    r1 = _r1_from_cartesian(R)
    D.append(r1)

    def P(i: int, l: int, mu: int, mp: int, Rp: jnp.ndarray) -> jnp.ndarray:
        # r1 indexed by m ∈ {−1,0,1} → +1; Rp (=D_{l−1}) by m ∈ [−l+1, l−1] → +l−1
        if abs(mp) < l:
            return r1[..., i + 1, 1] * Rp[..., mu + l - 1, mp + l - 1]
        if mp == l:
            return (
                r1[..., i + 1, 2] * Rp[..., mu + l - 1, (l - 1) + (l - 1)]
                - r1[..., i + 1, 0] * Rp[..., mu + l - 1, (-l + 1) + (l - 1)]
            )
        # mp == −l
        return (
            r1[..., i + 1, 2] * Rp[..., mu + l - 1, (-l + 1) + (l - 1)]
            + r1[..., i + 1, 0] * Rp[..., mu + l - 1, (l - 1) + (l - 1)]
        )

    for l in range(2, l_max + 1):
        Rp = D[l - 1]
        size = 2 * l + 1
        rows = []
        for m in range(-l, l + 1):
            row = []
            for mp in range(-l, l + 1):
                denom = float((l + mp) * (l - mp)) if abs(mp) < l else float(2 * l * (2 * l - 1))
                # --- u coefficient & U term
                u2 = (l + m) * (l - m) / denom
                val = jnp.zeros(batch, R.dtype)
                if u2 > 0:
                    val = val + (u2 ** 0.5) * P(0, l, m, mp, Rp)
                # --- v coefficient & V term
                d_m0 = 1.0 if m == 0 else 0.0
                v2 = (1.0 + d_m0) * (l + abs(m) - 1) * (l + abs(m)) / denom
                if v2 > 0:
                    v = 0.5 * (v2 ** 0.5) * (1.0 - 2.0 * d_m0)
                    if m == 0:
                        V = P(1, l, 1, mp, Rp) + P(-1, l, -1, mp, Rp)
                    elif m > 0:
                        d_m1 = 1.0 if m == 1 else 0.0
                        V = P(1, l, m - 1, mp, Rp) * ((1.0 + d_m1) ** 0.5)
                        if m != 1:
                            V = V - P(-1, l, -m + 1, mp, Rp)
                    else:
                        d_m1 = 1.0 if m == -1 else 0.0
                        V = P(-1, l, -m - 1, mp, Rp) * ((1.0 + d_m1) ** 0.5)
                        if m != -1:
                            V = V + P(1, l, m + 1, mp, Rp)
                    val = val + v * V
                # --- w coefficient & W term
                w2 = (l - abs(m) - 1) * (l - abs(m)) / denom
                if w2 > 0 and m != 0:
                    w = -0.5 * (w2 ** 0.5)
                    if m > 0:
                        W = P(1, l, m + 1, mp, Rp) + P(-1, l, -m - 1, mp, Rp)
                    else:
                        W = P(1, l, m - 1, mp, Rp) - P(-1, l, -m + 1, mp, Rp)
                    val = val + w * W
                row.append(val)
            rows.append(jnp.stack(row, axis=-1))
        D.append(jnp.stack(rows, axis=-2).reshape(batch + (size, size)))
    return D


def sh_block_slices(l_max: int) -> list[tuple[int, int]]:
    """(start, size) of each l-block in the flattened (l_max+1)² SH axis."""
    return [(l * l, 2 * l + 1) for l in range(l_max + 1)]


def block_diag_apply(D: list[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Apply per-l rotations to flattened features x: (..., K, C), K=(l_max+1)²."""
    outs = []
    for l, Dl in enumerate(D):
        s = l * l
        outs.append(jnp.einsum("...ij,...jc->...ic", Dl, x[..., s : s + 2 * l + 1, :]))
    return jnp.concatenate(outs, axis=-2)


def block_diag_apply_T(D: list[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Apply the inverse (transpose) rotations."""
    outs = []
    for l, Dl in enumerate(D):
        s = l * l
        outs.append(jnp.einsum("...ji,...jc->...ic", Dl, x[..., s : s + 2 * l + 1, :]))
    return jnp.concatenate(outs, axis=-2)
