"""GQA attention: RoPE, causal/sliding-window masks, chunked (flash-style)
training path, and a KV-cache decode path.

The training/prefill path scans over key/value chunks with an online softmax
(running max + normalizer), so peak memory is O(S·chunk) instead of O(S²) —
required for the 32k prefill and 500k cells, and the exact algorithm the
Pallas kernel (`repro.kernels.flash_attention`) implements on TPU. Sliding
windows are expressed as a *traced* per-layer window size so a stack of
mixed local/global layers (gemma3's 5:1) lowers as a single lax.scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "AttentionConfig",
    "attention_init",
    "attention_apply",
    "attention_decode",
    "rope",
    "init_kv_cache",
]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int | None = None
    rope_theta: float = 10_000.0
    kv_chunk: int = 1024            # online-softmax chunk length

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads


def attention_init(key: jax.Array, cfg: AttentionConfig, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    std = (1.0 / d) ** 0.5
    return {
        "wq": jax.random.normal(kq, (d, cfg.n_heads * hd), dtype) * std,
        "wk": jax.random.normal(kk, (d, cfg.n_kv_heads * hd), dtype) * std,
        "wv": jax.random.normal(kv, (d, cfg.n_kv_heads * hd), dtype) * std,
        "wo": jax.random.normal(ko, (cfg.n_heads * hd, d), dtype) * std,
    }


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :].astype(x.dtype)
    sin = sin[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(p: dict, x: jnp.ndarray, cfg: AttentionConfig, positions: jnp.ndarray):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunked_attention(
    q: jnp.ndarray,          # (B, Sq, H, Dh)
    k: jnp.ndarray,          # (B, Sk, Hk, Dh)
    v: jnp.ndarray,          # (B, Sk, Hk, Dh)
    q_positions: jnp.ndarray,  # (Sq,)
    window: jnp.ndarray | int,  # attend to q_pos-window < k_pos <= q_pos
    chunk: int,
) -> jnp.ndarray:
    B, Sq, H, Dh = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, Hk, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hk, Dh).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(B, Sq, Hk, G, Dh) * (Dh ** -0.5)
    win = jnp.asarray(window, jnp.int32)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, c_idx = xs
        k_pos = c_idx * chunk + jnp.arange(chunk)
        # (B, Hk, G, Sq, C)
        s = jnp.einsum("bqhgd,bchd->bhgqc", qg, kb, preferred_element_type=jnp.float32)
        valid = (k_pos[None, :] <= q_positions[:, None]) & (
            k_pos[None, :] > q_positions[:, None] - win
        ) & (k_pos[None, :] < Sk)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(vb.dtype), vb)
        acc_new = acc * scale[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hk, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hk, G, Sq, Dh), q.dtype)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    # (B, Hk, G, Sq, Dh) -> (B, Sq, H, Dh)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dh)


def attention_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: AttentionConfig,
    window: jnp.ndarray | int | None = None,
    positions: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Causal (optionally sliding-window) self-attention for train/prefill."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    if window is None:
        window = S
    q, k, v = _qkv(p, x, cfg, positions)
    out = _chunked_attention(q, k, v, positions, window, cfg.kv_chunk)
    return out.reshape(B, S, -1) @ p["wo"]


def init_kv_cache(
    batch: int, max_len: int, cfg: AttentionConfig, n_layers: int, dtype=jnp.float32
) -> dict:
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(
    p: dict,
    x: jnp.ndarray,              # (B, 1, D) current token embedding
    layer_cache: dict,           # {"k","v"}: (B, Smax, Hk, Dh) for THIS layer
    pos: jnp.ndarray,            # scalar int32 current position
    cfg: AttentionConfig,
    window: jnp.ndarray | int | None = None,
) -> tuple[jnp.ndarray, dict]:
    """One decode step against a per-layer KV cache; returns (out, new_cache)."""
    B = x.shape[0]
    hd = cfg.head_dim
    positions = pos[None] if pos.ndim == 0 else pos
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(layer_cache["k"], k, (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(layer_cache["v"], v, (0, pos, 0, 0))
    Smax, Hk = ck.shape[1], cfg.n_kv_heads
    G = cfg.q_groups
    win = jnp.asarray(Smax if window is None else window, jnp.int32)
    qg = q.reshape(B, Hk, G, hd) * (hd ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, ck, preferred_element_type=jnp.float32)
    k_pos = jnp.arange(Smax)
    valid = (k_pos <= pos) & (k_pos > pos - win)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w.astype(cv.dtype), cv)
    out = out.reshape(B, 1, cfg.n_heads * hd) @ p["wo"]
    return out, {"k": ck, "v": cv}
