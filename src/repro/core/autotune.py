"""Communication-aware placement + configuration autotuner (COIN's loop, closed).

COIN's thesis is that the node→CE *mapping* determines the communication that
dominates GCN energy and latency — so mapping and the execution config must
be optimized together, not defaulted independently. This module closes that
loop over the knobs the rest of the repo already exposes:

  pod_map   — which parts share a pod (the expensive ``send_rem`` tier only
              carries rows that cross pods; see docs/communication.md §4)
  pods      — hierarchy degree of the (pod, model) mesh
  block     — bsr tile edge (``plan_blocked_shape``)
  backend   — "segment" vs "bsr" aggregation engine
  order     — "feature_first" vs "aggregation_first" dataflow
  payload   — wire format (fp32/bf16/int8, ``repro.core.quant``)
  overlap   — interior/boundary split overlapped schedule

The three pieces:

  * :class:`BoundaryIndex` — the deduplicated boundary-pair index of a
    partitioned graph. Evaluates the exact per-tier pads (s_loc, s_rem) of
    ANY candidate part→pod map in O(boundary pairs) — no plan build — by
    reproducing ``repro.dist.halo._export_sets`` uniqueness analytically.
  * :func:`predict_config_cost` — one scalar objective per candidate,
    composing the per-tier ``exchange_cost`` wire/exposed bytes, the
    ``blocked_multiply_count`` executed-tile compute, and the
    ``CoinEnergyModel``/``MeshNoC`` energy+latency models. Its comm fields
    use the *same formulas* as the measured dry-run ``exchange_accounting``,
    so prediction-vs-measurement is an exact-field comparison (pinned in
    tests/test_autotune.py).
  * :func:`autotune_config` — coordinate descent: the pod_map knob moves by
    FM-style swap passes on the quotient graph (:func:`refine_pod_map`),
    discrete knobs are enumerated in place, and the block-size knob is
    searched with ``core.solver``'s golden-section over log2(block) before
    snapping to the tile grid. Seeded from today's defaults; every candidate
    evaluation emits a ``repro.obs`` span + metrics.

Objective units (documented per-term in docs/autotune.md):

  compute_s   = executed multiplies / ``PEAK_FLOPS``                [s]
  wire_s      = exposed halo bytes × layers / ``ICI_BYTES_PER_S``   [s]
  noc_latency_s = MeshNoC serialization bound of the dedup-row
                  traffic matrix under the candidate placement       [s]
  noc_energy_j  = MeshNoC energy of the same trace                  [J]
  coin_energy_j = CoinEnergyModel Eq. 3 at k, scaled to joules by
                  the NoC link energy (placement-independent anchor) [J]
  objective_s = compute_s + wire_s + noc_latency_s
                + ENERGY_WEIGHT_S_PER_J · (noc_energy_j + coin_energy_j)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dataflow import blocked_multiply_count, exchange_cost, sparse_multiply_count
from repro.core.energy import CoinEnergyModel
from repro.core.noc import MeshNoC
from repro.core.partition import (
    Partition,
    partition_graph,
    quotient_graph,
    refine_partition,
)
from repro.core.quant import payload_bits
from repro.core.solver import _golden_section
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

__all__ = [
    "CandidateConfig",
    "CommStats",
    "BoundaryIndex",
    "comm_stats_from_plan",
    "map_parts_to_pods",
    "refine_pod_map",
    "predict_config_cost",
    "autotune_config",
    "AutotuneResult",
    "PEAK_FLOPS",
    "ICI_BYTES_PER_S",
    "ENERGY_WEIGHT_S_PER_J",
]

# Roofline anchors for the scalar objective. Absolute values only set the
# exchange-rate between terms; every comparison the autotuner makes is
# between candidates under the SAME constants.
PEAK_FLOPS = 100e12           # multiplies/s one device sustains (bf16-class)
ICI_BYTES_PER_S = 40e9        # per-device interconnect bandwidth
ENERGY_WEIGHT_S_PER_J = 10.0  # how many seconds one joule is worth
# Fraction of PEAK_FLOPS each aggregation engine sustains: the fused bsr
# kernel runs dense tile MACs (every multiply counted IS a tile multiply);
# segment-sum is a memory-bound gather/scatter whose "multiplies" move one
# operand per element (the pinned kernel benches are why bsr is the
# production default despite executing padded tiles).
BACKEND_EFFICIENCY = {"bsr": 1.0, "segment": 0.05}
BLOCK_GRID = (32, 64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class CandidateConfig:
    """One point of the joint search space (defaults == today's defaults)."""

    pods: int = 1
    pod_map: tuple[int, ...] | None = None   # None → contiguous pod-major
    block: int = 128
    backend: str = "bsr"                     # "segment" | "bsr"
    order: str = "feature_first"             # | "aggregation_first"
    payload: str | None = None               # None/"fp32" | "bf16" | "int8"
    overlap: bool = True

    def pod_map_array(self) -> np.ndarray | None:
        return None if self.pod_map is None else np.asarray(self.pod_map, np.int64)


@dataclasses.dataclass(frozen=True)
class CommStats:
    """The deterministic comm geometry :func:`predict_config_cost` reads.

    Derivable two ways — analytically from a :class:`BoundaryIndex`
    (``index.comm_stats``) or from a built plan
    (:func:`comm_stats_from_plan`); the two agree exactly, which is the
    calibration contract the dry-run ``predicted`` block pins.
    """

    k: int
    pods: int
    n_local: int
    s_max: int
    s_loc: int
    s_rem: int
    overlap_fraction: float


def comm_stats_from_plan(plan) -> CommStats:
    """Read a built ``HaloPlan``'s geometry back as :class:`CommStats`."""
    return CommStats(
        k=int(plan.k),
        pods=int(plan.n_pods),
        n_local=int(plan.n_local),
        s_max=int(plan.s_max),
        s_loc=int(plan.s_loc),
        s_rem=int(plan.s_rem),
        overlap_fraction=float(plan.overlap_fraction()),
    )


class BoundaryIndex:
    """Deduplicated boundary-pair index of one (graph, partition).

    Stores every distinct (source node, destination part) pair of the cut —
    the unit that occupies one export slot in a halo plan — so the exact
    per-tier pads of any candidate pod_map come from an O(pairs) numpy
    reduction instead of a full plan build.
    """

    def __init__(self, part: Partition, edge_index: np.ndarray):
        self.k = int(part.k)
        self.n_nodes = int(part.n_nodes)
        a = np.asarray(part.assignment, np.int64)
        src = np.asarray(edge_index[0], np.int64)
        dst = np.asarray(edge_index[1], np.int64)
        self.n_edges = int(src.shape[0])
        cut = a[src] != a[dst]
        self.cut_edges = int(cut.sum())
        self.interior_edges = self.n_edges - self.cut_edges
        uniq = np.unique(src[cut] * self.k + a[dst[cut]])
        self.pair_node = uniq // self.k          # (P,) distinct source nodes
        self.pair_dst = (uniq % self.k).astype(np.int64)
        self.pair_src = a[self.pair_node]        # source part of each pair
        self.part_sizes = np.bincount(a, minlength=self.k).astype(np.int64)
        self.n_local = int(self.part_sizes.max()) if self.n_nodes else 0
        # Flat pad: distinct exported nodes per part over ALL cut pairs.
        flat_nodes = np.unique(self.pair_node)
        flat_counts = np.bincount(a[flat_nodes], minlength=self.k)
        self.s_max = int(flat_counts.max()) if flat_nodes.size else 0
        # Quotient weight matrix: W[i, j] = dedup rows i exports to j.
        self.row_traffic = np.bincount(
            self.pair_src * self.k + self.pair_dst, minlength=self.k * self.k
        ).reshape(self.k, self.k).astype(np.int64)

    @property
    def overlap_fraction(self) -> float:
        return self.interior_edges / self.n_edges if self.n_edges else 0.0

    def tier_sizes(self, pods: int, pod_map: np.ndarray | None) -> tuple[int, int]:
        """Exact (s_loc, s_rem) pads of the hierarchical plan under pod_map.

        Mirrors ``_export_sets``: within each tier a source node counts once
        per source device no matter how many destinations read it; a node
        exported on both tiers counts once in each.
        """
        if pods <= 1:
            return 0, 0
        if pod_map is None:
            k_model = self.k // pods
            pod_of = np.arange(self.k) // k_model
        else:
            pod_of = np.asarray(pod_map, np.int64)
        cross = pod_of[self.pair_src] != pod_of[self.pair_dst]
        s_loc = self._max_distinct(~cross)
        s_rem = self._max_distinct(cross)
        return s_loc, s_rem

    def _max_distinct(self, mask: np.ndarray) -> int:
        nodes = np.unique(self.pair_node[mask])
        if not nodes.size:
            return 0
        counts = np.bincount(self.pair_src[np.searchsorted(self.pair_node, nodes)], minlength=self.k)
        return int(counts.max())

    def comm_stats(self, pods: int = 1, pod_map: np.ndarray | None = None) -> CommStats:
        s_loc, s_rem = self.tier_sizes(pods, pod_map)
        return CommStats(
            k=self.k, pods=int(pods), n_local=self.n_local, s_max=self.s_max,
            s_loc=s_loc, s_rem=s_rem, overlap_fraction=self.overlap_fraction,
        )


def map_parts_to_pods(
    part: Partition,
    edge_index: np.ndarray,
    pods: int,
    *,
    seed: int = 0,
    passes: int = 8,
    restarts: int = 4,
    index: BoundaryIndex | None = None,
) -> np.ndarray:
    """Quotient-graph pod mapper: balanced (k,) part→pod assignment.

    Contracts the partitioned graph with :func:`quotient_graph`, seeds a pod
    assignment by partitioning the quotient (``partition_graph`` BFS +
    ``refine_partition`` over the weight-expanded edge list), rebalances to
    exactly ``k // pods`` parts per pod, then runs FM-style swap passes
    (:func:`refine_pod_map`) minimizing the deduplicated crossing rows.
    ``restarts`` BFS seeds (``seed .. seed+restarts−1``) are tried and the
    best final objective kept — deterministic (ties favor the lowest seed).
    """
    k = int(part.k)
    if pods < 1 or k % pods:
        raise ValueError(f"pods={pods} must divide k={k}")
    index = index or BoundaryIndex(part, edge_index)
    if pods == 1:
        return np.zeros(k, np.int64)
    q_ei, q_w = quotient_graph(part, edge_index)
    # Weight-aware seeding: repeat each quotient edge by its row weight so
    # the unweighted BFS/refine machinery sees the boundary-row mass.
    rep = np.repeat(np.arange(q_ei.shape[1]), q_w)
    expanded = q_ei[:, rep]
    best_map, best_obj = None, None
    for s in range(seed, seed + max(restarts, 1)):
        seeded = partition_graph(k, expanded, pods, method="bfs", seed=s, refine=True)
        pod_map = _balance_pod_map(seeded.assignment.astype(np.int64), k, pods, index)
        pod_map = refine_pod_map(pod_map, pods, index, passes=passes)
        obj = _crossing_objective(pod_map, pods, index)
        if best_obj is None or obj < best_obj:
            best_map, best_obj = pod_map, obj
    return best_map


def _balance_pod_map(pod_map: np.ndarray, k: int, pods: int, index: BoundaryIndex) -> np.ndarray:
    """Force exactly ``k // pods`` parts per pod, greedily moving the part
    whose move costs the fewest crossing rows (deterministic tie-break on
    part id)."""
    target = k // pods
    pod_map = pod_map.copy()
    sizes = np.bincount(pod_map, minlength=pods)
    while np.any(sizes != target):
        over = int(np.argmax(sizes))
        under = int(np.argmin(sizes))
        members = np.flatnonzero(pod_map == over)
        best_part, best_cost = -1, None
        for p in members:
            pod_map[p] = under
            cost = _crossing_objective(pod_map, pods, index)
            pod_map[p] = over
            if best_cost is None or cost < best_cost:
                best_part, best_cost = int(p), cost
        pod_map[best_part] = under
        sizes[over] -= 1
        sizes[under] += 1
    return pod_map


def _crossing_objective(pod_map: np.ndarray, pods: int, index: BoundaryIndex) -> tuple[int, int]:
    """(crossing rows under the pad, total crossing pair count) — lexicographic.

    The pad term ``(pods−1)·s_rem`` is what the plan actually ships (the
    acceptance metric); the raw pair count breaks ties smoothly so passes
    keep making progress while the max-device pad is flat.
    """
    pod_s = pod_map[index.pair_src]
    pod_d = pod_map[index.pair_dst]
    cross = pod_s != pod_d
    s_rem = index._max_distinct(cross)
    return ((pods - 1) * s_rem, int(cross.sum()))


def refine_pod_map(
    pod_map: np.ndarray,
    pods: int,
    index: BoundaryIndex,
    *,
    passes: int = 8,
) -> np.ndarray:
    """FM-style quotient boundary refinement under an EXACT balance cap.

    Balance must stay exact (every pod hosts ``k // pods`` parts — the plan
    relabeling has no raveling otherwise), so the move unit is a SWAP of two
    parts across pods. Each pass evaluates every cross-pod pair and commits
    the best strictly-improving swap until none improves; the objective is
    :func:`_crossing_objective`, so crossing rows never increase and the
    result is deterministic (first-best on ties, part-id order).
    """
    pod_map = np.asarray(pod_map, np.int64).copy()
    k = pod_map.shape[0]
    cur = _crossing_objective(pod_map, pods, index)
    for _ in range(passes):
        best_swap, best_obj = None, cur
        for i in range(k):
            for j in range(i + 1, k):
                if pod_map[i] == pod_map[j]:
                    continue
                pod_map[i], pod_map[j] = pod_map[j], pod_map[i]
                obj = _crossing_objective(pod_map, pods, index)
                pod_map[i], pod_map[j] = pod_map[j], pod_map[i]
                if obj < best_obj:
                    best_swap, best_obj = (i, j), obj
        if best_swap is None:
            break
        i, j = best_swap
        pod_map[i], pod_map[j] = pod_map[j], pod_map[i]
        cur = best_obj
    return pod_map


def predict_config_cost(
    cfg: CandidateConfig,
    stats: CommStats,
    *,
    d_feat: int,
    n_nodes: int | None = None,
    layer_dims: tuple[int, ...] | None = None,
    nnz_blocks: int | None = None,
    n_edges: int | None = None,
    row_traffic: np.ndarray | None = None,
    noc: MeshNoC | None = None,
    energy_model: CoinEnergyModel | None = None,
) -> dict:
    """Analytic cost of one candidate config — the search's objective.

    The comm fields reproduce the dry-run ``exchange_accounting`` formulas
    verbatim (same names, same units), so a plan built from ``cfg`` measures
    exactly what this predicts for every deterministic field — the pinned
    calibration contract. The scalar lives under ``"objective_s"``; the
    breakdown terms and their units are in the module docstring and
    docs/autotune.md.
    """
    k, pods = stats.k, cfg.pods
    if pods != stats.pods:
        raise ValueError(f"cfg.pods={pods} disagrees with stats.pods={stats.pods}")
    hierarchical = pods > 1
    if hierarchical:
        k_model = k // pods
        block_rows = stats.s_loc + pods * stats.s_rem
        halo_rows = pods * stats.s_rem + k_model * block_rows
    else:
        halo_rows = k * stats.s_max
    broadcast_rows = (k - 1) * stats.n_local
    bits = payload_bits(cfg.payload)
    ov = stats.overlap_fraction if cfg.overlap else 0.0
    d = int(d_feat)
    ec = exchange_cost(halo_rows, d, bits, ov)
    out = {
        "halo_rows_per_device": halo_rows,
        "broadcast_rows_per_device": broadcast_rows,
        "wire_fraction": halo_rows / max(broadcast_rows, 1),
        "halo_bytes_per_exchange": halo_rows * d * 4,
        "payload": cfg.payload or "fp32",
        "payload_bits": bits,
        "payload_compression": ec.compression,
        "overlap": bool(cfg.overlap),
        "overlap_fraction": ov,
        "halo_wire_bytes_per_exchange": ec.wire_bytes,
        "halo_exposed_bytes_per_exchange": ec.exposed_bytes,
    }
    if hierarchical:
        out.update(
            pods=pods,
            intra_pod_rows_per_device=k_model * block_rows,
            inter_pod_rows_per_device=pods * stats.s_rem,
            inter_pod_rows_crossing=(pods - 1) * stats.s_rem,
            flat_inter_pod_rows_crossing=(pods - 1) * k_model * stats.s_max,
            inter_pod_bytes_crossing=(pods - 1) * stats.s_rem * d * 4,
            flat_inter_pod_bytes_crossing=(pods - 1) * k_model * stats.s_max * d * 4,
        )

    # ---------------------------------------------------- objective terms
    dims = tuple(layer_dims) if layer_dims else (d, d)
    n = int(n_nodes if n_nodes is not None else stats.k * stats.n_local)
    flops = 0.0
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        if cfg.backend == "bsr" and nnz_blocks is not None:
            cost = blocked_multiply_count(n, nnz_blocks, d_in, d_out, block=cfg.block)
        else:
            cost = sparse_multiply_count(n, int(n_edges or 0), d_in, d_out)
        flops += getattr(cost, cfg.order)
    n_exchanges = max(len(dims) - 1, 1)
    compute_s = flops / (PEAK_FLOPS * BACKEND_EFFICIENCY.get(cfg.backend, 1.0))
    wire_s = ec.exposed_bytes * n_exchanges / ICI_BYTES_PER_S
    out.update(compute_flops=flops, compute_s=compute_s, wire_s=wire_s)

    noc_energy_j = noc_latency_s = 0.0
    if noc is not None and row_traffic is not None:
        ts = noc.summarize(row_traffic.astype(np.float64) * d * bits)
        noc_energy_j, noc_latency_s = ts.energy_j * n_exchanges, ts.latency_s * n_exchanges
    coin_energy_j = 0.0
    if energy_model is not None:
        scale = (noc or MeshNoC.square(k)).e_link_j_per_bit
        coin_energy_j = energy_model.total(k) * scale
    out.update(
        noc_energy_j=noc_energy_j,
        noc_latency_s=noc_latency_s,
        coin_energy_j=coin_energy_j,
        objective_s=compute_s + wire_s + noc_latency_s
        + ENERGY_WEIGHT_S_PER_J * (noc_energy_j + coin_energy_j),
    )
    return out


@dataclasses.dataclass
class AutotuneResult:
    """Chosen config + the predicted breakdowns the report prints."""

    config: CandidateConfig
    predicted: dict
    baseline_config: CandidateConfig
    baseline: dict
    history: list[tuple[str, float]]    # (knob description, objective_s)

    @property
    def predicted_improvement(self) -> float:
        return self.baseline["objective_s"] / max(self.predicted["objective_s"], 1e-30)


def _device_order(pod_map: np.ndarray | None, k: int, pods: int) -> np.ndarray:
    if pod_map is None:
        return np.arange(k)
    return np.lexsort((np.arange(k), np.asarray(pod_map, np.int64)))


def autotune_config(
    part: Partition,
    edge_index: np.ndarray,
    *,
    pods: int,
    d_feat: int,
    layer_dims: tuple[int, ...] | None = None,
    nnz_blocks_for: "dict[int, int] | None" = None,
    energy_model: CoinEnergyModel | None = None,
    seed: int = 0,
    rounds: int = 3,
    seed_config: CandidateConfig | None = None,
) -> AutotuneResult:
    """Coordinate descent over the joint (pod_map, exec config) space.

    Each round moves one knob at a time against :func:`predict_config_cost`
    with every other knob fixed: the pod_map by quotient FM swap passes, the
    discrete knobs (backend, order, payload, overlap) by enumeration, and
    the block size by ``core.solver`` golden-section over log2(block)
    snapped to the tile grid. Converges when a round changes nothing.

    ``nnz_blocks_for`` maps block size → nonzero tile count (from
    ``plan_blocked_shape``); omit it to cost the compute term with the
    edge-exact ``sparse_multiply_count`` instead.
    """
    k = int(part.k)
    index = BoundaryIndex(part, edge_index)
    noc = MeshNoC.square(k)
    baseline_cfg = seed_config or CandidateConfig(pods=pods)
    cfg = baseline_cfg

    def evaluate(c: CandidateConfig) -> dict:
        pm = c.pod_map_array()
        stats = index.comm_stats(c.pods, pm)
        order = _device_order(pm, k, c.pods)
        traffic = index.row_traffic[np.ix_(order, order)]
        nnz = (nnz_blocks_for or {}).get(c.block)
        with _obs_trace.span("autotune.candidate", args={"block": c.block, "payload": c.payload or "fp32"}):
            pred = predict_config_cost(
                c, stats, d_feat=d_feat, n_nodes=index.n_nodes,
                layer_dims=layer_dims, nnz_blocks=nnz, n_edges=index.n_edges,
                row_traffic=traffic, noc=noc, energy_model=energy_model,
            )
        if _obs_metrics.enabled():
            _obs_metrics.inc("autotune.candidates")
            _obs_metrics.observe("autotune.objective_s", pred["objective_s"])
        return pred

    baseline = evaluate(baseline_cfg)
    best = evaluate(cfg)
    history: list[tuple[str, float]] = [("seed defaults", best["objective_s"])]

    for _ in range(rounds):
        changed = False
        # --- pod_map: quotient mapper + FM swap passes -------------------
        if pods > 1:
            pm = map_parts_to_pods(part, edge_index, pods, seed=seed, index=index)
            cand = dataclasses.replace(cfg, pod_map=tuple(int(x) for x in pm))
            pred = evaluate(cand)
            if pred["objective_s"] < best["objective_s"]:
                cfg, best, changed = cand, pred, True
                history.append(("pod_map (quotient FM)", best["objective_s"]))
        # --- discrete knobs ----------------------------------------------
        for knob, values in (
            ("backend", ("segment", "bsr")),
            ("order", ("feature_first", "aggregation_first")),
            ("payload", (None, "bf16", "int8")),
            ("overlap", (False, True)),
        ):
            for v in values:
                if getattr(cfg, knob) == v:
                    continue
                cand = dataclasses.replace(cfg, **{knob: v})
                pred = evaluate(cand)
                if pred["objective_s"] < best["objective_s"]:
                    cfg, best, changed = cand, pred, True
                    history.append((f"{knob}={v}", best["objective_s"]))
        # --- block size: golden-section over log2(block), snapped --------
        # Searched jointly with backend="bsr" (block is meaningless for the
        # segment engine), so a descent step into "segment" can still be
        # overturned by bsr at a better tile size next round.
        if nnz_blocks_for:
            def snap(x: float) -> int:
                return min(BLOCK_GRID, key=lambda b: abs(np.log2(b) - x))

            def f(x: float) -> float:
                cand = dataclasses.replace(cfg, backend="bsr", block=snap(x))
                return evaluate(cand)["objective_s"]

            x_star = _golden_section(f, np.log2(min(BLOCK_GRID)), np.log2(max(BLOCK_GRID)), iters=12)
            cand = dataclasses.replace(cfg, backend="bsr", block=snap(x_star))
            pred = evaluate(cand)
            if pred["objective_s"] < best["objective_s"]:
                cfg, best, changed = cand, pred, True
                history.append((f"backend=bsr block={cand.block}", best["objective_s"]))
        if not changed:
            break

    if _obs_metrics.enabled():
        _obs_metrics.set_gauge("autotune.objective_best_s", best["objective_s"])
    return AutotuneResult(
        config=cfg, predicted=best, baseline_config=baseline_cfg,
        baseline=baseline, history=history,
    )
