"""Interior-point minimization of the COIN objective (paper §IV-B3).

The paper minimizes E(k) subject to k > 0 with an interior-point (log-barrier)
method [38], reporting a 10 ms solve and an optimum of k = 16 (4×4 mesh).

A note on Appendix A: the paper claims d²E/dk² > 0 for all k ∈ [4, 100] and
N > 2000. Evaluating the paper's own Eq. 5 shows this is *not* true for the
whole range (e.g. N = 6000, k = 100 gives d²E/dk² < 0; positivity holds only
for k ≲ 3.96·N^¼). E(k) is nonetheless *unimodal* (strictly decreasing, then
increasing) on the range of interest and convex in a neighborhood of the
minimizer, so the interior-point conclusion stands. We therefore run a
golden-section localization over the full feasible range (robust to the
non-convex tail) followed by a log-barrier damped-Newton polish (the paper's
method, valid in the locally convex basin). The discrepancy is recorded in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.energy import CoinEnergyModel

__all__ = [
    "interior_point_minimize",
    "SolveResult",
    "optimal_ce_count",
    "mesh_sweep",
    "SQUARE_MESHES",
]

# Fig. 9 sweeps square meshes 3×3 .. 10×10.
SQUARE_MESHES: tuple[int, ...] = tuple(m * m for m in range(3, 11))


@dataclasses.dataclass(frozen=True)
class SolveResult:
    k_star: float              # continuous minimizer
    k_mesh: int                # nearest feasible square-mesh CE count
    mesh_shape: tuple[int, int]
    energy_at_k: float
    solve_ms: float
    iterations: int
    converged: bool


def _golden_section(f: Callable[[float], float], a: float, b: float, iters: int = 96) -> float:
    gr = (math.sqrt(5.0) - 1.0) / 2.0
    c, d = b - gr * (b - a), a + gr * (b - a)
    fc, fd = f(c), f(d)
    for _ in range(iters):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - gr * (b - a)
            fc = f(c)
        else:
            a, c, fc = c, d, fd
            d = a + gr * (b - a)
            fd = f(d)
        if b - a < 1e-10 * max(1.0, abs(b)):
            break
    return 0.5 * (a + b)


def interior_point_minimize(
    f: Callable[[float], float],
    df: Callable[[float], float] | None = None,
    d2f: Callable[[float], float] | None = None,
    k_lo: float = 1.0,
    k_hi: float = 1e4,
    mu0: float = 1e-3,
    mu_shrink: float = 0.2,
    tol: float = 1e-9,
    max_outer: int = 30,
    max_newton: int = 40,
) -> tuple[float, int, bool]:
    """min f(k) s.t. k_lo < k < k_hi: golden localization + log-barrier Newton.

    φ_μ(k) = f(k) − μ·(log(k − k_lo) + log(k_hi − k)); damped Newton with a
    gradient-descent fallback when the local Hessian is non-PSD; μ shrinks
    geometrically (the standard barrier path). Returns (k*, iters, converged).
    """
    if df is None:
        h = 1e-4
        df = lambda k: (f(k + h) - f(k - h)) / (2 * h)  # noqa: E731
    if d2f is None:
        h = 1e-3
        d2f = lambda k: (f(k + h) - 2.0 * f(k) + f(k - h)) / (h * h)  # noqa: E731

    k = _golden_section(f, k_lo + 1e-9, k_hi - 1e-9)
    fscale = max(abs(f(k)), 1.0)
    mu = mu0 * fscale
    total_iters = 0
    converged = False

    def phi(x: float, mu: float) -> float:
        return f(x) - mu * (math.log(x - k_lo) + math.log(k_hi - x))

    for _ in range(max_outer):
        for _ in range(max_newton):
            total_iters += 1
            g = df(k) - mu / (k - k_lo) + mu / (k_hi - k)
            hss = d2f(k) + mu / (k - k_lo) ** 2 + mu / (k_hi - k) ** 2
            step = g / hss if (np.isfinite(hss) and hss > 0) else math.copysign(0.1 * k, g)
            t, phi_k = 1.0, phi(k, mu)
            while t > 1e-14:
                cand = k - t * step
                if k_lo < cand < k_hi and phi(cand, mu) <= phi_k + 1e-18 * abs(phi_k):
                    break
                t *= 0.5
            k_new = k - t * step
            if abs(k_new - k) < tol * max(1.0, abs(k)):
                k = k_new
                break
            k = k_new
        mu *= mu_shrink
        if mu < 1e-12 * fscale:
            converged = True
            break
    return float(k), total_iters, converged


def _best_square_mesh(candidates: Sequence[int], f: Callable[[float], float]) -> int:
    """Snap to the feasible square mesh minimizing the (unimodal) objective."""
    return int(min(candidates, key=lambda c: f(float(c))))


def optimal_ce_count(
    model: CoinEnergyModel,
    mesh_candidates: Sequence[int] = SQUARE_MESHES,
) -> SolveResult:
    """§IV-B3: minimize E(k), k > 0, then snap to a square mesh (paper → 16)."""
    t0 = time.perf_counter()
    k_star, iters, converged = interior_point_minimize(
        f=lambda k: float(model.total(k)),
        df=lambda k: float(model.d_total(k)),
        d2f=lambda k: float(model.d2_total(k)),
        k_lo=1.0,
        k_hi=float(max(mesh_candidates) * 4),
    )
    k_mesh = _best_square_mesh(mesh_candidates, lambda k: float(model.total(k)))
    ms = (time.perf_counter() - t0) * 1e3
    side = int(round(math.sqrt(k_mesh)))
    return SolveResult(
        k_star=k_star,
        k_mesh=k_mesh,
        mesh_shape=(side, side),
        energy_at_k=float(model.total(k_mesh)),
        solve_ms=ms,
        iterations=iters,
        converged=converged,
    )


def mesh_sweep(model: CoinEnergyModel, mesh_candidates: Sequence[int] = SQUARE_MESHES) -> dict[int, float]:
    """Fig. 9: modeled communication energy for each square-mesh CE count."""
    return {int(k): float(model.total(float(k))) for k in mesh_candidates}
