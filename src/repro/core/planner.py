"""COIN's communication objective re-targeted to a TPU pod (DESIGN.md §2).

The paper chooses the CE count k by minimizing an analytic model of
intra-CE + inter-CE communication energy. On a TPU pod the same decision is
"how many model-parallel shards should hold the graph", with:

  intra term  → HBM traffic of the local aggregation on each shard
                (bytes/s capability: 819 GB/s per chip),
  inter term  → ICI collective traffic of the layer-output exchange
                (bytes/s capability: ~50 GB/s per link).

We model one GCN layer under the COIN schedule on k shards:

  local extract : reads N/k·F, writes N/k·H          (HBM)
  exchange      : all-gather of Z (paper broadcast)  → (k−1)/k · N·H bytes in,
                  or halo exchange (beyond paper)    → cut_edges(k)/k · H per shard
  local aggregate: reads E/k edges + gathered Z      (HBM)

and pick the k (divisor of the available devices) that minimizes the max of
the two timed terms — the same "balance intra vs inter" insight as Eq. 3,
expressed in seconds instead of joules. This drives the default shardings in
`repro.launch` and is exercised by the hillclimb in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

__all__ = ["TPUPlan", "coin_objective_tpu", "plan_gnn_sharding", "TPUHardware"]


@dataclasses.dataclass(frozen=True)
class TPUHardware:
    """TPU v5e constants (per the assignment's roofline section)."""

    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link (~per chip per direction)
    bytes_per_elt: float = 2.0          # bf16


@dataclasses.dataclass(frozen=True)
class TPUPlan:
    model_shards: int
    data_shards: int
    est_step_s: float
    intra_s: float                      # HBM-bound local time
    inter_s: float                      # ICI-bound exchange time
    compute_s: float
    schedule: str                       # "broadcast" (paper) or "halo"

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.intra_s, "collective": self.inter_s}
        return max(terms, key=terms.get)


def coin_objective_tpu(
    n_nodes: int,
    n_edges: int,
    feat_dims: Sequence[int],
    k: int,
    hw: TPUHardware = TPUHardware(),
    schedule: str = "broadcast",
    cut_fraction: float | None = None,
) -> tuple[float, float, float]:
    """(compute_s, intra_hbm_s, inter_ici_s) for one forward pass on k shards.

    ``cut_fraction`` (edges crossing shards / total edges) parameterizes the
    halo schedule; the paper's broadcast schedule ignores it.
    """
    b = hw.bytes_per_elt
    compute = intra = inter = 0.0
    for d_in, d_out in zip(feat_dims[:-1], feat_dims[1:]):
        n_loc, e_loc = n_nodes / k, n_edges / k
        # local X·W (feature-first, paper dataflow)
        flops = 2.0 * n_loc * d_in * d_out
        compute += flops / hw.peak_flops
        intra += (n_loc * d_in + d_in * d_out + n_loc * d_out) * b / hw.hbm_bw
        # exchange of Z over ICI
        if schedule == "broadcast":
            inter += (k - 1) / k * n_nodes * d_out * b / hw.ici_bw
        elif schedule == "halo":
            cf = 1.0 if cut_fraction is None else cut_fraction
            inter += (cf * n_edges / k) * d_out * b / hw.ici_bw
        else:
            raise ValueError(schedule)
        # local aggregation A_loc · Z
        compute += 2.0 * e_loc * d_out / hw.peak_flops
        intra += (e_loc * d_out * 2.0 + n_loc * d_out) * b / hw.hbm_bw
    return compute, intra, inter


def plan_gnn_sharding(
    n_nodes: int,
    n_edges: int,
    feat_dims: Sequence[int],
    n_devices: int,
    hw: TPUHardware = TPUHardware(),
    schedule: str = "broadcast",
    cut_fraction: float | None = None,
) -> TPUPlan:
    """Choose the model-parallel degree by the COIN balance criterion.

    Candidates are divisors of n_devices; the remaining factor becomes data
    (replica/feature) parallelism. The estimated step time is
    max(compute, intra) + inter (exchange not overlapped — paper's serial
    layer schedule); the minimizer balances the terms exactly as Eq. 3 does.
    """
    best: TPUPlan | None = None
    for k in _divisors(n_devices):
        comp, intra, inter = coin_objective_tpu(
            n_nodes, n_edges, feat_dims, k, hw, schedule, cut_fraction
        )
        step = max(comp, intra) + inter
        plan = TPUPlan(
            model_shards=k,
            data_shards=n_devices // k,
            est_step_s=step,
            intra_s=intra,
            inter_s=inter,
            compute_s=comp,
            schedule=schedule,
        )
        if best is None or plan.est_step_s < best.est_step_s:
            best = plan
    assert best is not None
    return best


def _divisors(n: int) -> list[int]:
    out = []
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            out.append(d)
            if d != n // d:
                out.append(n // d)
    return sorted(out)
