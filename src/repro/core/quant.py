"""Quantization for weights and activations (paper §V-B, Fig. 7).

COIN stores 4-bit weights/activations in the RRAM crossbars (2 bits/cell,
bit-serial inputs) after verifying on GPU that 4-bit quantization-aware
accuracy is within a few points of fp32. We implement symmetric per-tensor
fake quantization with a straight-through estimator so the same GCN can be
trained/evaluated at 2–32 bits, reproducing the Fig. 7 sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["QuantConfig", "fake_quant", "quantize_tree"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    weight_bits: int = 4
    act_bits: int = 4
    enabled: bool = True
    act_percentile: float | None = 99.9   # clip activation outliers (QAT)

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


def fake_quant(x: jax.Array, bits: int, percentile: float | None = None) -> jax.Array:
    """Symmetric per-tensor fake quantization with a straight-through grad.

    bits ≥ 32 (or ≤ 0) is a no-op (fp32 reference). The scale is amax-based
    by default; ``percentile`` clips the calibration range (e.g. 99.9) — at
    ≤4 bits GCN aggregation outputs have heavy degree-driven outliers and a
    pure-amax scale wastes most of the code points (§V-B reproduction note
    in EXPERIMENTS.md).
    """
    if bits >= 32 or bits <= 0:
        return x
    qmax = float(2 ** (bits - 1) - 1)
    mag = jnp.abs(x)
    if percentile is None:
        amax = jnp.max(mag)
    else:
        # k-th largest magnitude via top_k (cheaper than a full sort; the
        # calibration statistic carries no gradient, per standard QAT).
        flat = jax.lax.stop_gradient(mag).reshape(-1)
        k = max(1, int(flat.shape[0] * (1.0 - percentile / 100.0)))
        amax = jax.lax.top_k(flat, k)[0][-1]
    scale = jax.lax.stop_gradient(jnp.where(amax > 0, amax / qmax, 1.0))
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax) * scale
    # Straight-through estimator: forward q, backward identity.
    return x + jax.lax.stop_gradient(q - x)


def quantize_tree(params: Any, bits: int) -> Any:
    """Fake-quantize every float leaf of a parameter pytree."""
    def leaf(p):
        if isinstance(p, jax.Array) and jnp.issubdtype(p.dtype, jnp.floating):
            return fake_quant(p, bits)
        return p

    return jax.tree_util.tree_map(leaf, params)
