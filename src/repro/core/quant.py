"""Quantization for weights and activations (paper §V-B, Fig. 7).

COIN stores 4-bit weights/activations in the RRAM crossbars (2 bits/cell,
bit-serial inputs) after verifying on GPU that 4-bit quantization-aware
accuracy is within a few points of fp32. We implement symmetric per-tensor
fake quantization with a straight-through estimator so the same GCN can be
trained/evaluated at 2–32 bits, reproducing the Fig. 7 sweep.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "PAYLOAD_BITS",
    "fake_quant",
    "quantize_tree",
    "payload_bits",
    "quantize_payload",
    "dequantize_payload",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    weight_bits: int = 4
    act_bits: int = 4
    enabled: bool = True
    act_percentile: float | None = 99.9   # clip activation outliers (QAT)

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


def fake_quant(x: jax.Array, bits: int, percentile: float | None = None) -> jax.Array:
    """Symmetric per-tensor fake quantization with a straight-through grad.

    bits ≥ 32 (or ≤ 0) is a no-op (fp32 reference). The scale is amax-based
    by default; ``percentile`` clips the calibration range (e.g. 99.9) — at
    ≤4 bits GCN aggregation outputs have heavy degree-driven outliers and a
    pure-amax scale wastes most of the code points (§V-B reproduction note
    in EXPERIMENTS.md).
    """
    if bits >= 32 or bits <= 0:
        return x
    qmax = float(2 ** (bits - 1) - 1)
    mag = jnp.abs(x)
    if percentile is None:
        amax = jnp.max(mag)
    else:
        # k-th largest magnitude via top_k (cheaper than a full sort; the
        # calibration statistic carries no gradient, per standard QAT).
        # Nearest-rank percentile: the p-th percentile of n magnitudes is the
        # ceil(p·n/100)-th smallest, i.e. the (n − ceil(p·n/100) + 1)-th
        # largest. The old `int(n·(1−p/100))` floored to 0 for any tensor
        # with fewer than 1/(1−p/100) elements, so k=1 == pure amax and a
        # single outlier silently owned the whole calibration range.
        flat = jax.lax.stop_gradient(mag).reshape(-1)
        n = int(flat.shape[0])
        k = min(n, max(1, n - math.ceil(percentile / 100.0 * n) + 1))
        amax = jax.lax.top_k(flat, k)[0][-1]
    scale = jax.lax.stop_gradient(jnp.where(amax > 0, amax / qmax, 1.0))
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax) * scale
    # Straight-through estimator: forward q, backward identity.
    return x + jax.lax.stop_gradient(q - x)


def quantize_tree(params: Any, bits: int, percentile: float | None = None) -> Any:
    """Fake-quantize every float leaf of a parameter pytree.

    ``percentile`` reaches every leaf's calibration (it was silently dropped
    before, so tree-level quantization always ran pure-amax).
    """
    def leaf(p):
        if isinstance(p, jax.Array) and jnp.issubdtype(p.dtype, jnp.floating):
            return fake_quant(p, bits, percentile=percentile)
        return p

    return jax.tree_util.tree_map(leaf, params)


# --------------------------------------------------------- halo wire payloads
# Wire formats for the halo exchange (DESIGN.md §8, docs/communication.md
# "Overlapped schedule"): the export block is encoded before the collective
# and decoded on receive, so only the compressed representation crosses the
# inter-chip fabric. Unlike fake_quant (QAT emulation in fp32), these change
# the actual transferred dtype.
PAYLOAD_BITS = {None: 32, "fp32": 32, "bf16": 16, "int8": 8}


def payload_bits(payload: str | None) -> int:
    """Wire bits per element for a halo payload format."""
    try:
        return PAYLOAD_BITS[payload]
    except KeyError:
        raise ValueError(
            f"unknown halo payload {payload!r}; expected one of "
            "None/'fp32', 'bf16', 'int8'"
        ) from None


def quantize_payload(
    x: jax.Array, payload: str | None
) -> tuple[jax.Array, jax.Array | None]:
    """Encode an export block for the wire. Returns ``(wire, scale)``.

    * ``None``/``"fp32"`` — identity, scale None.
    * ``"bf16"``          — bfloat16 cast, scale None (dequant is an upcast).
    * ``"int8"``          — symmetric per-export-block scale (amax/127); the
                            (1, 1) fp32 scale travels alongside the payload so
                            the receiver can decode every sender's block.
    """
    if payload in (None, "fp32") or x.shape[0] == 0:
        return x, None
    if payload == "bf16":
        return x.astype(jnp.bfloat16), None
    if payload == "int8":
        amax = jnp.max(jnp.abs(x))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q, scale.reshape(1, 1)
    payload_bits(payload)  # raises the canonical error
    raise AssertionError  # pragma: no cover


def dequantize_payload(
    wire: jax.Array, scale: jax.Array | None, dtype=jnp.float32
) -> jax.Array:
    """Decode gathered wire rows back to ``dtype``.

    For int8, ``scale`` holds one row per gathered export block — shape
    (n_blocks, 1) against wire (n_blocks·s, d) — and each block is rescaled
    by its sender's amax/127.
    """
    if scale is None:
        return wire.astype(dtype)
    n_blocks = scale.shape[0]
    rows = wire.shape[0]
    if n_blocks > 1 and rows:
        per = rows // n_blocks
        return (
            wire.astype(dtype).reshape(n_blocks, per, -1) * scale[:, :, None]
        ).reshape(rows, -1)
    return wire.astype(dtype) * scale[0]
