"""Trace-driven 2D-mesh NoC model (the BookSim analogue of paper §V-A).

The paper feeds per-layer packet traces (src router, dst router, timestamp)
into a customized cycle-accurate BookSim. On this substrate we implement a
vectorized trace-driven model with the same architectural parameters
(Table II: 32-bit bus, X-Y routing, 5-port routers, mesh topology):

  * energy  — exact per-pair accounting: bits × (hops·E_link + (hops+1)·E_router),
  * latency — congestion bound: max per-link serialization under X-Y routing
              (computed exactly from the traffic matrix) + pipeline latency,
  * c-mesh  — concentrated-mesh variant (Fig. 12/14 comparison): express
              links halve hop count, 8-port routers raise per-hop energy.

For the *baseline* architecture (one router per GCN node, k up to 65 755) the
k×k traffic matrix is too large to materialize, so uniform-broadcast closed
forms (exact mean Manhattan distance on an r×c grid) are used instead.

Absolute joules require an energy-per-bit calibration; `MeshNoC.calibrated()`
scales the 32 nm defaults so the COIN reference point (Cora, 4×4 mesh →
2.7 µJ communication energy, §V-D) is matched, after which all other numbers
are predictions of the model.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.partition import Partition

__all__ = ["MeshNoC", "CMeshNoC", "TrafficSummary", "gcn_layer_traffic", "baseline_broadcast_summary"]

PJ = 1e-12


@dataclasses.dataclass(frozen=True)
class TrafficSummary:
    """Result of pushing one trace (traffic matrix) through the NoC model."""

    total_bits: float
    hop_bits: float            # Σ bits × hops (the "data communicated" metric of Fig. 1)
    energy_j: float
    latency_cycles: float
    latency_s: float

    @property
    def edp(self) -> float:
        return self.energy_j * self.latency_s

    def __add__(self, other: "TrafficSummary") -> "TrafficSummary":
        # Layers execute serially (paper §IV-C2) → bits/energy/latency add.
        return TrafficSummary(
            self.total_bits + other.total_bits,
            self.hop_bits + other.hop_bits,
            self.energy_j + other.energy_j,
            self.latency_cycles + other.latency_cycles,
            self.latency_s + other.latency_s,
        )


def _mean_manhattan(rows: int, cols: int) -> float:
    """Exact E|Δr|+E|Δc| for two independent uniform points on a rows×cols grid."""
    er = (rows * rows - 1.0) / (3.0 * rows)
    ec = (cols * cols - 1.0) / (3.0 * cols)
    return er + ec


@dataclasses.dataclass(frozen=True)
class MeshNoC:
    """2D-mesh NoC with X-Y routing (paper Table II parameters)."""

    rows: int
    cols: int
    bus_width_bits: int = 32
    freq_hz: float = 1.0e9
    # 32 nm per-bit energies (defaults in literature range; see calibrated()).
    e_router_j_per_bit: float = 0.060 * PJ
    e_link_j_per_bit: float = 0.025 * PJ
    router_delay_cycles: int = 2
    link_delay_cycles: int = 1
    energy_scale: float = 1.0

    # ------------------------------------------------------------------ setup
    @property
    def k(self) -> int:
        return self.rows * self.cols

    @classmethod
    def square(cls, k: int, **kw) -> "MeshNoC":
        side = int(round(math.sqrt(k)))
        if side * side == k:
            return cls(rows=side, cols=side, **kw)
        rows = int(math.floor(math.sqrt(k)))
        while k % rows:
            rows -= 1
        return cls(rows=rows, cols=k // rows, **kw)

    def _coords(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        return ids // self.cols, ids % self.cols

    # ----------------------------------------------------------------- energy
    def _hops_matrix(self) -> np.ndarray:
        ids = np.arange(self.k)
        r, c = self._coords(ids)
        return (np.abs(r[:, None] - r[None, :]) + np.abs(c[:, None] - c[None, :])).astype(np.float64)

    def energy_for_traffic(self, traffic_bits: np.ndarray) -> tuple[float, float]:
        """(energy_joules, hop_bits) for a (k,k) traffic matrix in bits."""
        t = np.asarray(traffic_bits, dtype=np.float64)
        hops = self._hops_matrix()
        hop_bits = float((t * hops).sum())
        link_j = hop_bits * self.e_link_j_per_bit
        router_j = float((t * (hops + 1.0)).sum()) * self.e_router_j_per_bit
        return (link_j + router_j) * self.energy_scale, hop_bits

    # ---------------------------------------------------------------- latency
    def link_loads(self, traffic_bits: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Per-link bit loads under X-first-then-Y dimension-order routing.

        Returns (h_load, v_load): h_load[r, c] is the load on the horizontal
        link between (r,c)↔(r,c+1) (both directions summed); v_load[r, c]
        likewise for (r,c)↔(r+1,c).
        """
        t = np.asarray(traffic_bits, dtype=np.float64)
        R, C = self.rows, self.cols
        h_load = np.zeros((R, max(C - 1, 1)))
        v_load = np.zeros((max(R - 1, 1), C))
        ids = np.arange(self.k)
        rr, cc = self._coords(ids)
        src, dst = np.nonzero(t)
        bits = t[src, dst]
        rs, cs, rd, cd = rr[src], cc[src], rr[dst], cc[dst]
        # Horizontal segment: row rs, columns [min(cs,cd), max(cs,cd)).
        lo, hi = np.minimum(cs, cd), np.maximum(cs, cd)
        for i in range(bits.shape[0]):
            if hi[i] > lo[i]:
                h_load[rs[i], lo[i]:hi[i]] += bits[i]
            rlo, rhi = (rs[i], rd[i]) if rs[i] <= rd[i] else (rd[i], rs[i])
            if rhi > rlo:
                v_load[rlo:rhi, cd[i]] += bits[i]
        return h_load, v_load

    def latency_for_traffic(self, traffic_bits: np.ndarray) -> float:
        """Congestion-bound latency (cycles): bottleneck-link serialization
        plus mean path pipeline depth. Approximates the BookSim trace replay
        in the bandwidth-limited regime the GCN broadcasts operate in."""
        t = np.asarray(traffic_bits, dtype=np.float64)
        if t.sum() == 0.0:
            return 0.0
        h_load, v_load = self.link_loads(t)
        max_link_bits = max(float(h_load.max(initial=0.0)), float(v_load.max(initial=0.0)))
        serialization = max_link_bits / self.bus_width_bits
        hops = self._hops_matrix()
        w = t / t.sum()
        mean_hops = float((w * hops).sum())
        pipeline = mean_hops * (self.router_delay_cycles + self.link_delay_cycles) + self.router_delay_cycles
        return serialization + pipeline

    # ------------------------------------------------------------- summaries
    def summarize(self, traffic_bits: np.ndarray) -> TrafficSummary:
        energy, hop_bits = self.energy_for_traffic(traffic_bits)
        cycles = self.latency_for_traffic(traffic_bits)
        return TrafficSummary(
            total_bits=float(np.asarray(traffic_bits, dtype=np.float64).sum()),
            hop_bits=hop_bits,
            energy_j=energy,
            latency_cycles=cycles,
            latency_s=cycles / self.freq_hz,
        )

    def intra_ce_energy(self, intra_bits: np.ndarray, nodes_per_ce: float) -> float:
        """Paper Eq. 1 scaling: intra-CE energy/bit ∝ (N/k)^½.

        The local (within-CE) NoC grows with the number of nodes mapped to the
        CE, so its mean path — hence energy/bit — scales as sqrt(N/k). We use
        the same per-hop constants with hop count sqrt(nodes_per_ce)."""
        hops = math.sqrt(max(nodes_per_ce, 1.0))
        e_bit = hops * self.e_link_j_per_bit + (hops + 1.0) * self.e_router_j_per_bit
        return float(np.asarray(intra_bits, dtype=np.float64).sum()) * e_bit * self.energy_scale

    # ------------------------------------------------------------ calibration
    def calibrated(self, scale: float) -> "MeshNoC":
        return dataclasses.replace(self, energy_scale=self.energy_scale * scale)


@dataclasses.dataclass(frozen=True)
class CMeshNoC(MeshNoC):
    """Concentrated mesh (Fig. 12/14 comparison): express links roughly halve
    hop counts; wider (8-port) routers cost more energy per traversal."""

    express_hop_factor: float = 0.5
    router_energy_factor: float = 1.6  # 8-port vs 5-port crossbar energy

    def _hops_matrix(self) -> np.ndarray:
        base = super()._hops_matrix()
        return np.ceil(base * self.express_hop_factor)

    def energy_for_traffic(self, traffic_bits: np.ndarray) -> tuple[float, float]:
        t = np.asarray(traffic_bits, dtype=np.float64)
        hops = self._hops_matrix()
        hop_bits = float((t * hops).sum())
        link_j = hop_bits * self.e_link_j_per_bit * 1.3  # longer express wires
        router_j = float((t * (hops + 1.0)).sum()) * self.e_router_j_per_bit * self.router_energy_factor
        return (link_j + router_j) * self.energy_scale, hop_bits


# --------------------------------------------------------------------- traces
def gcn_layer_traffic(
    part: Partition,
    act_bits_per_node_per_layer: list[float],
    broadcast: bool = True,
) -> list[np.ndarray]:
    """One inter-CE traffic matrix per GCN layer boundary (Fig. 5c exchange).

    ``act_bits_per_node_per_layer`` holds a(l+1) for l = 1..L−1 — the hidden
    activation bits per node communicated after each layer (paper §IV-B2).
    """
    return [part.inter_ce_traffic_bits(a, broadcast=broadcast) for a in act_bits_per_node_per_layer]


def baseline_broadcast_summary(
    noc: MeshNoC, n_nodes: int, bits_per_node: float
) -> TrafficSummary:
    """Closed-form summary for the BASELINE architecture (one CE per node).

    Every node broadcasts ``bits_per_node`` to all N−1 others on an
    r×c ≈ √N×√N mesh. Exact mean Manhattan distance gives energy and
    hop-bits; the bottleneck-bisection bound gives latency.
    """
    r, c = noc.rows, noc.cols
    assert r * c >= n_nodes, "baseline mesh must host one router per node"
    total_bits = float(n_nodes) * float(n_nodes - 1) * bits_per_node
    # Mean over DISTINCT ordered pairs: the all-pairs mean (which includes
    # the zero-distance self pairs) rescaled by k/(k−1).
    k = r * c
    mean_hops = _mean_manhattan(r, c) * k / (k - 1)
    hop_bits = total_bits * mean_hops
    energy = (
        hop_bits * noc.e_link_j_per_bit
        + total_bits * (mean_hops + 1.0) * noc.e_router_j_per_bit
    ) * noc.energy_scale
    # Bisection bound: ~half of all pair-bits cross the central vertical cut
    # of r links.
    cross_bits = total_bits * 0.5
    serialization = cross_bits / r / noc.bus_width_bits
    pipeline = mean_hops * (noc.router_delay_cycles + noc.link_delay_cycles)
    cycles = serialization + pipeline
    return TrafficSummary(
        total_bits=total_bits,
        hop_bits=hop_bits,
        energy_j=energy,
        latency_cycles=cycles,
        latency_s=cycles / noc.freq_hz,
    )
