"""COIN core: the paper's contribution as composable JAX/numpy modules."""

from repro.core.energy import CoinEnergyModel, sum_hidden_activation_bits
from repro.core.solver import interior_point_minimize, optimal_ce_count, mesh_sweep
from repro.core.partition import (
    Partition,
    partition_graph,
    measured_probabilities,
)
from repro.core.noc import MeshNoC, CMeshNoC, TrafficSummary, gcn_layer_traffic
from repro.core.dataflow import (
    DataflowCost,
    dense_multiply_count,
    sparse_multiply_count,
    choose_order,
)
from repro.core.chip import ChipModel, chips_required
from repro.core.quant import fake_quant, quantize_tree, QuantConfig
from repro.core.planner import TPUPlan, plan_gnn_sharding, coin_objective_tpu

__all__ = [
    "CoinEnergyModel",
    "sum_hidden_activation_bits",
    "interior_point_minimize",
    "optimal_ce_count",
    "mesh_sweep",
    "Partition",
    "partition_graph",
    "measured_probabilities",
    "MeshNoC",
    "CMeshNoC",
    "TrafficSummary",
    "gcn_layer_traffic",
    "DataflowCost",
    "dense_multiply_count",
    "sparse_multiply_count",
    "choose_order",
    "ChipModel",
    "chips_required",
    "fake_quant",
    "quantize_tree",
    "QuantConfig",
    "TPUPlan",
    "plan_gnn_sharding",
    "coin_objective_tpu",
]
