"""COIN dataflow: feature-extraction-first matmul reordering (paper §IV-C3).

A GCN layer computes O = A · X · W (A: N×N adjacency, X: N×F features,
W: F×H weights). The multiplication order changes the work:

  aggregation-first   : (A·X)·W  → N·N·F + N·F·H multiplies
  feature-first (COIN): A·(X·W)  → N·F·H + N·N·H multiplies

With H ≪ F (e.g. Nell layer 1: F=5414, H=16) the paper reports a 311×
reduction (2.3·10¹³ → 7.4·10¹⁰). The same reordering carries to the TPU
implementation, where the dense-N² term becomes the E-edge sparse term:

  aggregation-first   : E·F + N·F·H  MACs
  feature-first (COIN): N·F·H + E·H  MACs

This module provides both cost models and the order chooser used by the GCN
layer (`repro.models.gcn`) at trace time.
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "DataflowCost",
    "dense_multiply_count",
    "sparse_multiply_count",
    "choose_order",
]


@dataclasses.dataclass(frozen=True)
class DataflowCost:
    aggregation_first: float
    feature_first: float

    @property
    def reduction(self) -> float:
        """How many × fewer multiplies feature-first performs."""
        return self.aggregation_first / max(self.feature_first, 1.0)

    @property
    def best(self) -> str:
        return "feature_first" if self.feature_first <= self.aggregation_first else "aggregation_first"


def dense_multiply_count(n_nodes: int, d_in: int, d_out: int) -> DataflowCost:
    """Paper's accounting (§IV-C3): crossbars store A densely (no sparsity)."""
    n = float(n_nodes)
    agg_first = n * n * d_in + n * d_in * d_out
    feat_first = n * d_in * d_out + n * n * d_out
    return DataflowCost(aggregation_first=agg_first, feature_first=feat_first)


def sparse_multiply_count(n_nodes: int, n_edges: int, d_in: int, d_out: int) -> DataflowCost:
    """TPU accounting: aggregation is an E-edge segment-sum / block-SpMM."""
    n, e = float(n_nodes), float(n_edges)
    agg_first = e * d_in + n * d_in * d_out
    feat_first = n * d_in * d_out + e * d_out
    return DataflowCost(aggregation_first=agg_first, feature_first=feat_first)


def choose_order(n_nodes: int, d_in: int, d_out: int, n_edges: int | None = None) -> str:
    """COIN's rule: run X·W first iff it shrinks the aggregated width.

    For both the dense and sparse cost models the comparison reduces to
    d_out vs d_in (the N·F·H term is shared), so the chooser is exact for
    either accounting. Ties go to feature-first (the paper's order).
    """
    cost = (
        sparse_multiply_count(n_nodes, n_edges, d_in, d_out)
        if n_edges is not None
        else dense_multiply_count(n_nodes, d_in, d_out)
    )
    return cost.best
