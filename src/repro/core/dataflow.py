"""COIN dataflow: feature-extraction-first matmul reordering (paper §IV-C3).

A GCN layer computes O = A · X · W (A: N×N adjacency, X: N×F features,
W: F×H weights). The multiplication order changes the work:

  aggregation-first   : (A·X)·W  → N·N·F + N·F·H multiplies
  feature-first (COIN): A·(X·W)  → N·F·H + N·N·H multiplies

With H ≪ F (e.g. Nell layer 1: F=5414, H=16) the paper reports a 311×
reduction (2.3·10¹³ → 7.4·10¹⁰). The same reordering carries to the TPU
implementation, where the dense-N² term becomes the E-edge sparse term:

  aggregation-first   : E·F + N·F·H  MACs
  feature-first (COIN): N·F·H + E·H  MACs

For the ``"bsr"`` backend the aggregation is neither dense-N² nor per-edge:
the MXU executes one 128×128 × 128×F matmul per **nonzero block**, padding
tiles skipped by the ragged kernel (DESIGN.md §2). Its cost term is
therefore ``nnz_blocks · B² · F`` — a graph whose communities pack into few
tiles aggregates cheaper than its edge count suggests, and a shuffled graph
pays for every smeared tile. `blocked_multiply_count` models it and
`choose_order(backend="bsr", nnz_blocks=…)` uses it; the dry-run threads the
same statistics into its FLOP accounting so hillclimb compares real kernel
cost (`repro.launch.dryrun`).

This module provides the cost models and the order chooser used by the GCN
layer (`repro.models.gcn`) at trace time.
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "DataflowCost",
    "ExchangeCost",
    "dense_multiply_count",
    "sparse_multiply_count",
    "blocked_multiply_count",
    "exchange_cost",
    "choose_order",
]


@dataclasses.dataclass(frozen=True)
class DataflowCost:
    aggregation_first: float
    feature_first: float

    @property
    def reduction(self) -> float:
        """How many × fewer multiplies feature-first performs."""
        return self.aggregation_first / max(self.feature_first, 1.0)

    @property
    def best(self) -> str:
        return "feature_first" if self.feature_first <= self.aggregation_first else "aggregation_first"


def dense_multiply_count(n_nodes: int, d_in: int, d_out: int) -> DataflowCost:
    """Paper's accounting (§IV-C3): crossbars store A densely (no sparsity)."""
    n = float(n_nodes)
    agg_first = n * n * d_in + n * d_in * d_out
    feat_first = n * d_in * d_out + n * n * d_out
    return DataflowCost(aggregation_first=agg_first, feature_first=feat_first)


def sparse_multiply_count(n_nodes: int, n_edges: int, d_in: int, d_out: int) -> DataflowCost:
    """TPU accounting: aggregation is an E-edge segment-sum / block-SpMM."""
    n, e = float(n_nodes), float(n_edges)
    agg_first = e * d_in + n * d_in * d_out
    feat_first = n * d_in * d_out + e * d_out
    return DataflowCost(aggregation_first=agg_first, feature_first=feat_first)


def blocked_multiply_count(
    n_nodes: int, nnz_blocks: int, d_in: int, d_out: int, block: int = 128
) -> DataflowCost:
    """BSR-backend accounting: aggregation runs one B×B × B×F MXU matmul per
    nonzero 128×128 tile (ragged kernel, padding skipped — DESIGN.md §2), so
    the aggregation term is ``nnz_blocks · B² · F``, not ``E · F``. Locality
    reordering (`repro.graph.structure.locality_block_order`) lowers
    ``nnz_blocks`` and with it this cost — density-awareness the edge-count
    model cannot see.
    """
    n, bb = float(n_nodes), float(nnz_blocks) * float(block) * float(block)
    agg_first = bb * d_in + n * d_in * d_out
    feat_first = n * d_in * d_out + bb * d_out
    return DataflowCost(aggregation_first=agg_first, feature_first=feat_first)


@dataclasses.dataclass(frozen=True)
class ExchangeCost:
    """The halo-exchange wire model of docs/communication.md: per-device
    per-layer rows crossing the fabric, compressed by the payload format and
    hidden behind interior compute.

      wire_bytes    = rows · d · payload_bits / 8        (what crosses)
      exposed_bytes = wire_bytes · (1 − overlap_fraction) (what the critical
                      path still waits on: the overlapped schedule hides a
                      ``overlap_fraction`` share of the exchange behind
                      interior aggregation work)
    """

    rows: int                         # halo rows received per device per layer
    d: int                            # feature width crossing the wire
    payload_bits: int = 32            # fp32 32 | bf16 16 | int8 8
    overlap_fraction: float = 0.0     # HaloPlan.overlap_fraction()

    @property
    def wire_bytes(self) -> float:
        return self.rows * self.d * self.payload_bits / 8.0

    @property
    def exposed_bytes(self) -> float:
        return self.wire_bytes * (1.0 - self.overlap_fraction)

    @property
    def compression(self) -> float:
        """Wire-byte reduction vs the fp32 baseline (32 / payload_bits)."""
        return 32.0 / max(self.payload_bits, 1)


def exchange_cost(
    rows: int, d: int, payload_bits: int = 32, overlap_fraction: float = 0.0
) -> ExchangeCost:
    """Convenience constructor for :class:`ExchangeCost` (dry-run accounting,
    hillclimb prints, and the ``choose_order`` exchange term)."""
    return ExchangeCost(
        rows=int(rows), d=int(d), payload_bits=int(payload_bits),
        overlap_fraction=float(overlap_fraction),
    )


def choose_order(
    n_nodes: int, d_in: int, d_out: int, n_edges: int | None = None,
    backend: str = "segment", nnz_blocks: int | None = None, block: int = 128,
    halo_rows: int | None = None, payload_bits: int = 32,
    overlap_fraction: float = 0.0,
) -> str:
    """COIN's rule: run X·W first iff it shrinks the aggregated width.

    For every cost model — dense, per-edge sparse, and the bsr backend's
    per-nonzero-block model (``backend="bsr"`` with ``nnz_blocks``) — the
    comparison reduces to d_out vs d_in (the N·F·H term is shared), so the
    chooser is exact for any accounting; what changes between models is the
    cost *magnitude*, which the dry-run/hillclimb FLOP accounting consumes.
    Ties go to feature-first (the paper's order).

    ``halo_rows`` adds the exchange term of the sharded halo schedule:
    feature-first exchanges the transformed (d_out-wide) rows and
    aggregation-first the raw (d_in-wide) rows, each scaled by the
    overlap/compression model ``payload_bits/32 · (1 − overlap_fraction)``
    (:class:`ExchangeCost`, in element-equivalents). The term moves with the
    SAME d_out-vs-d_in sign as the compute terms, so the argmax is unchanged
    — it exists so hillclimb and the dry-run see exchange-aware magnitudes,
    not to flip decisions.
    """
    if backend == "bsr" and nnz_blocks is not None:
        cost = blocked_multiply_count(n_nodes, nnz_blocks, d_in, d_out, block)
    elif n_edges is not None:
        cost = sparse_multiply_count(n_nodes, n_edges, d_in, d_out)
    else:
        cost = dense_multiply_count(n_nodes, d_in, d_out)
    if halo_rows:
        factor = (payload_bits / 32.0) * (1.0 - overlap_fraction)
        cost = DataflowCost(
            aggregation_first=cost.aggregation_first + halo_rows * d_in * factor,
            feature_first=cost.feature_first + halo_rows * d_out * factor,
        )
    return cost.best
