"""COIN communication-energy model (paper §IV-B, Eqs. 1–3, Appendix A Eq. 5).

The paper models the total on-chip communication energy of a GCN mapped onto
``k`` compute elements (CEs) as the sum of an intra-CE and an inter-CE term:

    E_intra(k) = Σ_m (N/k)(N/k − 1) p⁽¹⁾_m · Σ_{l=1..L−1} a(l+1) · (N/k)^(1/2)
    E_inter(k) = Σ_{i≠j} (N/k)² p⁽²⁾_ij · (Σ_{l=1..L−1} a(l+1)) · k^(1/2)

with
    N        — number of GCN (graph) nodes,
    k        — number of CEs (decision variable),
    a(l)     — input activation *bits* of layer l per node,
    p⁽¹⁾_m   — probability of an edge between two nodes mapped to CE m,
    p⁽²⁾_ij  — probability of an edge between a node in CE i and one in CE j,
    (N/k)^½  — energy/bit scaling of the intra-CE (local NoC) fabric,
    k^½      — energy/bit scaling of the inter-CE (global mesh NoC) fabric [37].

Everything here is exact to the paper; the only generality added is that the
connection probabilities may be scalars (the paper's closed form, used for the
convexity proof with p1=0.25, p2=0.22) or measured per-partition values
(computed by :mod:`repro.core.partition` from an actual graph partition).

Units: `a(l)` is in bits, so E(k) is in (bits · unit-energy). Multiply by an
energy-per-bit calibration constant (see :mod:`repro.core.noc`) to obtain
joules. The *optimum* k is invariant to that constant.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "sum_hidden_activation_bits",
    "CoinEnergyModel",
    "PAPER_P_INTRA",
    "PAPER_P_INTER",
]

# Appendix A: "the highest probability of intra-CE connection for the dataset
# we consider is 0.25 and the highest probability of [inter]-CE connection is
# 0.22" — used in the published convexity bound (Eq. 5).
PAPER_P_INTRA = 0.25
PAPER_P_INTER = 0.22


def sum_hidden_activation_bits(layer_dims: Sequence[int], act_bits: int) -> float:
    """Σ_{l=1..L−1} a(l+1): total per-node *hidden* activation bits communicated.

    ``layer_dims`` = [d_in, h_1, ..., h_{L-1}, d_out] for an L-layer network.
    a(l) is the number of input activation bits of layer l, so a(l+1) for
    l = 1..L−1 covers the hidden activations h_1..h_{L-1} (the final output is
    not forwarded to a subsequent layer). For the paper's 2-layer GCN
    [F, 16, C] this is simply 16·act_bits.
    """
    if len(layer_dims) < 3:
        return 0.0
    hidden = layer_dims[1:-1]
    return float(sum(hidden) * act_bits)


@dataclasses.dataclass(frozen=True)
class CoinEnergyModel:
    """Closed-form E(k) (Eqs. 1–3) with scalar or per-partition probabilities.

    Args:
      n_nodes: N, the number of GCN nodes.
      act_bits_sum: Σ_{l=1..L−1} a(l+1) (per-node hidden activation bits).
      p_intra: scalar edge probability inside a CE (paper's p⁽¹⁾). A scalar
        reproduces the paper's closed form `Σ_m → k · p_intra`.
      p_inter: scalar edge probability across CEs (paper's p⁽²⁾). A scalar
        reproduces `Σ_{i≠j} → k(k−1) · p_inter`.
    """

    n_nodes: int
    act_bits_sum: float
    p_intra: float = PAPER_P_INTRA
    p_inter: float = PAPER_P_INTER

    # ---------------------------------------------------------------- E terms
    def e_intra(self, k):
        """Eq. 1 with uniform p: k · (N/k)(N/k−1)·p1 · S_a · (N/k)^½ ."""
        k = np.asarray(k, dtype=np.float64)
        n_per = self.n_nodes / k
        return k * n_per * (n_per - 1.0) * self.p_intra * self.act_bits_sum * np.sqrt(n_per)

    def e_inter(self, k):
        """Eq. 2 with uniform p: k(k−1) · (N/k)² · p2 · S_a · k^½ ."""
        k = np.asarray(k, dtype=np.float64)
        n_per = self.n_nodes / k
        return k * (k - 1.0) * n_per * n_per * self.p_inter * self.act_bits_sum * np.sqrt(k)

    def total(self, k):
        """Eq. 3: E(k) = E_intra(k) + E_inter(k)."""
        return self.e_intra(k) + self.e_inter(k)

    # ------------------------------------------------------------ derivatives
    # Expand E(k)/S_a with uniform p:
    #   E_intra/S = p1 (N^2.5 k^-1.5 − N^1.5 k^-0.5)
    #   E_inter/S = p2 N² (k^0.5 − k^-0.5)
    def d_total(self, k):
        k = np.asarray(k, dtype=np.float64)
        n = float(self.n_nodes)
        d_intra = self.p_intra * (-1.5 * n**2.5 * k**-2.5 + 0.5 * n**1.5 * k**-1.5)
        d_inter = self.p_inter * n * n * (0.5 * k**-0.5 + 0.5 * k**-1.5)
        return (d_intra + d_inter) * self.act_bits_sum

    def d2_total(self, k):
        """Appendix A Eq. 5 (generalized to arbitrary p1/p2).

        With the paper's p1=0.25, p2=0.22 the coefficients evaluate to the
        published 0.94·N^2.5/k^3.5 − 0.06·N²/k^1.5 − (0.17·N²+0.19·N^1.5)/k^2.5.
        """
        k = np.asarray(k, dtype=np.float64)
        n = float(self.n_nodes)
        term = (
            3.75 * self.p_intra * n**2.5 * k**-3.5
            - 0.25 * self.p_inter * n**2 * k**-1.5
            - (0.75 * self.p_inter * n**2 + 0.75 * self.p_intra * n**1.5) * k**-2.5
        )
        return term * self.act_bits_sum

    def is_convex(self, k_min: float = 4.0, k_max: float = 100.0, num: int = 512) -> bool:
        """Appendix A claim: d²E/dk² > 0 over k ∈ [4, 100] for N > 2000.

        NOTE: evaluating the paper's own Eq. 5 shows this strict claim fails
        for k ≳ 3.96·N^¼ (e.g. N=6000, k=100) — see solver.py. Use
        :meth:`convex_k_limit` / :meth:`is_unimodal` for the properties that
        actually hold; this method reports the literal claim."""
        ks = np.linspace(k_min, k_max, num)
        return bool(np.all(self.d2_total(ks) > 0.0))

    def convex_k_limit(self) -> float:
        """Largest k below which d²E/dk² > 0 (bisection on Eq. 5)."""
        lo, hi = 1.0, 1e6
        if self.d2_total(lo) <= 0:
            return lo
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if self.d2_total(mid) > 0:
                lo = mid
            else:
                hi = mid
        return lo

    def is_unimodal(self, k_min: float = 2.0, k_max: float = 400.0, num: int = 4096) -> bool:
        """E(k) strictly decreases then increases (the property the
        interior-point conclusion actually needs)."""
        ks = np.linspace(k_min, k_max, num)
        d = np.diff(self.total(ks))
        sign_changes = np.flatnonzero(np.sign(d[:-1]) != np.sign(d[1:]))
        return sign_changes.size <= 1

    # -------------------------------------------------------------- utilities
    def normalized(self, ks) -> np.ndarray:
        """E(k)/max(E) over the given ks — reproduces Fig. 19."""
        e = self.total(np.asarray(ks, dtype=np.float64))
        return e / np.max(e)

    def continuous_argmin(self) -> float:
        """Stationary point from dE/dk = 0, leading-order closed form.

        Balancing the dominant terms −1.5·p1·N^2.5·k^-2.5 and 0.5·p2·N²·k^-0.5
        gives k* ≈ (3 p1 √N / p2)^(1/2) — a useful analytic sanity check for
        the interior-point solver (k* ≈ 16 at N≈6000 with paper constants).
        """
        return math.sqrt(3.0 * self.p_intra * math.sqrt(self.n_nodes) / self.p_inter)


def model_from_gcn(
    n_nodes: int, layer_dims: Sequence[int], act_bits: int = 4,
    p_intra: float = PAPER_P_INTRA, p_inter: float = PAPER_P_INTER,
) -> CoinEnergyModel:
    """Convenience constructor from a GCN layer-dimension list."""
    return CoinEnergyModel(
        n_nodes=n_nodes,
        act_bits_sum=sum_hidden_activation_bits(layer_dims, act_bits),
        p_intra=p_intra,
        p_inter=p_inter,
    )
