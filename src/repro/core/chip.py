"""COIN chip capacity model (paper §IV-A, §V-C).

Parameters from the paper: 128×128 RRAM crossbar PEs at 2 bits/cell, tiles of
PEs, 30 tiles per CE (6×5 mesh), 16 CEs per chip, 30 MB total on-chip memory.
From 30 MB / (16 CEs · 30 tiles) = 64 KB per tile = 16 PEs per tile
(each PE stores 128·128·2 bits = 4 KB).

Large GCNs use multiple chips (§V-C: Cora 1, Citeseer 1, Pubmed 3,
Ext. Cora 20, Nell 45). Each CE stores an N × (N/k_total) adjacency slice
mapped "as is" onto crossbars (crossbar-granular: ⌈N/128⌉ × ⌈cols/128⌉
arrays), plus the layer weights. We reproduce the paper's counts for
Cora/Citeseer/Pubmed under 1-cell-per-adjacency-entry crossbar-granular
mapping; for Ext. Cora/Nell the paper's exact bookkeeping is underdetermined
(see EXPERIMENTS.md) and we report our model's counts alongside the paper's.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

__all__ = ["ChipModel", "chips_required"]


@dataclasses.dataclass(frozen=True)
class ChipModel:
    crossbar_rows: int = 128
    crossbar_cols: int = 128
    bits_per_cell: int = 2
    pes_per_tile: int = 16
    tiles_per_ce: int = 30          # 6×5 mesh (§IV-A)
    ces_per_chip: int = 16          # 4×4 mesh (§IV-B3)
    max_adj_tiles_per_ce: int = 23  # paper: adjacency needs 10–23 tiles
    weight_bits: int = 4            # 4-bit quantization (§V-B)
    adj_cells_per_entry: int = 1    # one RRAM cell per adjacency entry

    @property
    def cells_per_pe(self) -> int:
        return self.crossbar_rows * self.crossbar_cols

    @property
    def cells_per_chip(self) -> int:
        return self.cells_per_pe * self.pes_per_tile * self.tiles_per_ce * self.ces_per_chip

    @property
    def bytes_per_chip(self) -> int:
        return self.cells_per_chip * self.bits_per_cell // 8

    def weight_crossbars(self, layer_dims: Sequence[int]) -> int:
        """Crossbars to hold all layer weights (stored column-wise, §IV-C2)."""
        cells_per_weight = max(1, self.weight_bits // self.bits_per_cell)
        total = 0
        for d_in, d_out in zip(layer_dims[:-1], layer_dims[1:]):
            rows = math.ceil(d_in / self.crossbar_rows)
            cols = math.ceil(d_out * cells_per_weight / self.crossbar_cols)
            total += rows * cols
        return total

    def adjacency_crossbars_total(self, n_nodes: int) -> int:
        """Total crossbars tiling the full N×N adjacency at 128×128 blocks."""
        rows = math.ceil(n_nodes / self.crossbar_rows)
        cols = math.ceil(n_nodes * self.adj_cells_per_entry / self.crossbar_cols)
        return rows * cols

    def adjacency_budget_per_ce(self, layer_dims: Sequence[int]) -> int:
        """Crossbars a CE can devote to adjacency: the paper's ≤23-tile cap,
        further reduced if the (replicated) weights overflow their 7 tiles."""
        pe_per_ce = self.pes_per_tile * self.tiles_per_ce
        w = self.weight_crossbars(layer_dims)
        return min(self.max_adj_tiles_per_ce * self.pes_per_tile, pe_per_ce - w)


def chips_required(
    model: ChipModel, n_nodes: int, layer_dims: Sequence[int], mode: str = "crossbar"
) -> int:
    """Chips needed for one GCN (§V-C: Cora 1, Citeseer 1, Pubmed 3,
    Ext. Cora 20, Nell 45).

    mode="crossbar" — crossbar-granular: the N×N adjacency is tiled into
      128×128 blocks packed across CEs, each CE capped at 23 adjacency tiles
      and holding a replicated weight copy. Reproduces Cora/Citeseer (1) and
      Nell (45) exactly.
    mode="cell" — cell-granular capacity (N²·cells / chip cells). Reproduces
      Pubmed (3). Ext. Cora's published 20 is not derivable from the stated
      parameters under either accounting (see EXPERIMENTS.md note).
    """
    if mode == "cell":
        cells = n_nodes * n_nodes * model.adj_cells_per_entry
        return max(1, math.ceil(cells / model.cells_per_chip))
    budget = model.adjacency_budget_per_ce(layer_dims)
    if budget <= 0:
        raise ValueError("weights alone overflow a CE")
    total = model.adjacency_crossbars_total(n_nodes)
    ces = math.ceil(total / budget)
    return max(1, math.ceil(ces / model.ces_per_chip))
