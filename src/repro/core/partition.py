"""Graph → CE partitioning (the node→CE map of COIN §IV-A/§IV-C).

COIN maps N graph nodes onto k compute elements (N/k nodes per CE). The paper
treats the map as given and measures connection probabilities p⁽¹⁾_m (within
CE m) and p⁽²⁾_ij (between CEs i,j) from it. We provide:

  * ``block``   — contiguous ranges (the paper's "as is, no transformation"
                  adjacency slicing; our paper-faithful default),
  * ``random``  — random balanced assignment (worst-case locality baseline),
  * ``bfs``     — multi-source BFS region growing (locality-seeking),
  * ``refine``  — greedy boundary refinement (Fiduccia–Mattheyses-style single
                  moves with balance caps) on top of any initial assignment —
                  this is our beyond-paper lever for cutting inter-CE volume.

All routines are vectorized numpy and handle the ogbn-products scale
(2.45M nodes / 62M edges) in seconds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "Partition",
    "partition_graph",
    "partition_from_assignment",
    "measured_probabilities",
    "refine_partition",
    "bfs_traversal_order",
    "quotient_graph",
]


@dataclasses.dataclass
class Partition:
    """A node→CE assignment plus the edge statistics COIN's model needs."""

    assignment: np.ndarray          # (N,) int32 CE id per node
    k: int
    part_sizes: np.ndarray          # (k,) nodes per CE
    edge_counts: np.ndarray         # (k, k) directed edge counts between CEs
    n_nodes: int
    n_edges: int

    @property
    def intra_edges(self) -> int:
        return int(np.trace(self.edge_counts))

    @property
    def cut_edges(self) -> int:
        return int(self.edge_counts.sum() - np.trace(self.edge_counts))

    @property
    def cut_fraction(self) -> float:
        tot = int(self.edge_counts.sum())
        return self.cut_edges / max(tot, 1)

    def inter_ce_traffic_bits(self, act_bits_per_node: float, broadcast: bool = True) -> np.ndarray:
        """(k,k) inter-CE traffic in bits for ONE layer's output exchange.

        broadcast=True  — paper-faithful dataflow (Fig. 5c): each CE sends its
          full layer output (n_m · a bits) to every other CE.
        broadcast=False — beyond-paper halo exchange: CE i sends to CE j only
          the activations of nodes that j's aggregation actually reads, i.e.
          the distinct source nodes of cut edges i→j (upper-bounded here by
          the edge count, exact when sources are distinct).
        """
        k = self.k
        if broadcast:
            out = np.repeat(self.part_sizes[:, None] * float(act_bits_per_node), k, axis=1)
            np.fill_diagonal(out, 0.0)
            return out
        out = self.edge_counts.astype(np.float64) * float(act_bits_per_node)
        np.fill_diagonal(out, 0.0)
        return out

    def intra_ce_traffic_bits(self, act_bits_per_node: float) -> np.ndarray:
        """(k,) intra-CE traffic in bits per layer (local edge messages)."""
        return np.diag(self.edge_counts).astype(np.float64) * float(act_bits_per_node)


def _edge_count_matrix(assignment: np.ndarray, k: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    pair = assignment[src].astype(np.int64) * k + assignment[dst].astype(np.int64)
    counts = np.bincount(pair, minlength=k * k)
    return counts.reshape(k, k).astype(np.int64)


def _csr_from_edges(n: int, src: np.ndarray, dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    order = np.argsort(src, kind="stable")
    s, d = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, s + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, d


def _bfs_assignment(n: int, src: np.ndarray, dst: np.ndarray, k: int, seed: int) -> np.ndarray:
    """Multi-source BFS region growing with balance caps."""
    rng = np.random.default_rng(seed)
    indptr, indices = _csr_from_edges(n, src, dst)
    cap = int(np.ceil(n / k) * 1.03) + 1
    assignment = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    seeds = rng.choice(n, size=k, replace=False)
    assignment[seeds] = np.arange(k, dtype=np.int32)
    sizes += 1
    frontier = seeds
    while frontier.size:
        # Expand all frontier nodes one level, vectorized over their edges.
        starts, ends = indptr[frontier], indptr[frontier + 1]
        counts = (ends - starts).astype(np.int64)
        if counts.sum() == 0:
            break
        owner = np.repeat(assignment[frontier], counts)
        flat = np.concatenate([indices[s:e] for s, e in zip(starts, ends)]) if frontier.size < 4096 else _gather_ranges(indices, starts, ends)
        unas = assignment[flat] == -1
        flat, owner = flat[unas], owner[unas]
        if flat.size == 0:
            break
        # First-come wins among duplicates; respect capacity.
        uniq, first = np.unique(flat, return_index=True)
        owner = owner[first]
        room = sizes[owner] < cap
        uniq, owner = uniq[room], owner[room]
        still = assignment[uniq] == -1
        uniq, owner = uniq[still], owner[still]
        assignment[uniq] = owner
        np.add.at(sizes, owner, 1)
        frontier = uniq
    # Orphans (disconnected or capacity-blocked) → fill underfull parts.
    orphans = np.flatnonzero(assignment == -1)
    if orphans.size:
        deficit = np.maximum(cap - sizes, 0)
        fill = np.repeat(np.arange(k), deficit)[: orphans.size]
        if fill.size < orphans.size:  # pathological: round-robin the rest
            extra = np.arange(orphans.size - fill.size) % k
            fill = np.concatenate([fill, extra])
        assignment[orphans] = fill.astype(np.int32)
    return assignment


def _gather_ranges(indices: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Vectorized np.concatenate([indices[s:e] ...]) for large frontiers."""
    counts = (ends - starts).astype(np.int64)
    total = int(counts.sum())
    out_off = np.zeros(len(starts) + 1, dtype=np.int64)
    np.cumsum(counts, out=out_off[1:])
    idx = np.arange(total, dtype=np.int64)
    seg = np.searchsorted(out_off[1:], idx, side="right")
    return indices[starts[seg] + (idx - out_off[seg])]


def bfs_traversal_order(n_nodes: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Parent-ordered BFS traversal order — I-GCN-style islandization.

    Returns ``order`` (position → node id): nodes appear in BFS discovery
    order over the symmetrized graph, with each frontier sorted by its
    PARENT's position (first-discoverer wins), so a community's members pack
    contiguously instead of interleaving with every other community at the
    same BFS depth — the property that makes this the default
    dense-blocking permutation (`repro.graph.structure.locality_block_order`:
    on shuffled planted-partition graphs it cuts nonzero 128×128 tiles
    3–6×, at or beyond the planted community ordering itself). Disconnected
    components are traversed in node-id order. Vectorized level-synchronous
    sweep: O(E) per level, ~1 s for 262k nodes / 1M edges.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    both_s = np.concatenate([src, dst])
    both_d = np.concatenate([dst, src])
    indptr, indices = _csr_from_edges(n_nodes, both_s, both_d)
    order = np.empty(n_nodes, np.int64)
    seen = np.zeros(n_nodes, bool)
    pos, next_root = 0, 0
    while pos < n_nodes:
        while next_root < n_nodes and seen[next_root]:
            next_root += 1
        frontier = np.array([next_root], np.int64)
        seen[next_root] = True
        while frontier.size:
            order[pos:pos + frontier.size] = frontier
            pos += frontier.size
            starts, ends = indptr[frontier], indptr[frontier + 1]
            counts = (ends - starts).astype(np.int64)
            if counts.sum() == 0:
                break
            flat = _gather_ranges(indices, starts, ends)
            flat = flat[~seen[flat]]
            if flat.size == 0:
                break
            # Dedupe keeping FIRST discovery, then sort by that discovery
            # position — children group under their (community-mate) parent.
            uniq, first = np.unique(flat, return_index=True)
            frontier = uniq[np.argsort(first, kind="stable")]
            seen[frontier] = True
    return order


def refine_partition(
    assignment: np.ndarray,
    k: int,
    src: np.ndarray,
    dst: np.ndarray,
    passes: int = 3,
    balance_slack: float = 0.05,
    seed: int = 0,
) -> np.ndarray:
    """Greedy single-move boundary refinement (beyond-paper cut reduction).

    For every node we compute its edge count to each CE (its "pull"), and move
    it to the strongest-pulling CE if (a) the gain is positive and (b) the
    destination is under the balance cap. One vectorized pass over all nodes
    per iteration; conflicts resolved by processing moves in random order with
    capacity bookkeeping.

    Deterministic for a given seed and invariant to the order of the input
    edge list (the pull matrix is an edge-multiset sum; mover ordering uses a
    stable gain sort). Greedy commits run against a pull matrix that goes
    stale as the pass proceeds, so a pass CAN make the cut worse — such a
    pass is reverted and refinement stops, making the cut monotone
    non-increasing across passes.
    """
    rng = np.random.default_rng(seed)
    n = assignment.shape[0]
    assignment = assignment.astype(np.int32).copy()
    cap = int(np.ceil(n / k) * (1.0 + balance_slack)) + 1
    cut_before = int((assignment[src] != assignment[dst]).sum())
    for _ in range(passes):
        prev = assignment.copy()
        # pull[v, c] = #edges from v into CE c (treat graph as undirected).
        pull = np.zeros((n, k), dtype=np.int32)
        np.add.at(pull, (src, assignment[dst]), 1)
        np.add.at(pull, (dst, assignment[src]), 1)
        cur = pull[np.arange(n), assignment]
        best_part = np.argmax(pull, axis=1).astype(np.int32)
        best = pull[np.arange(n), best_part]
        gain = best - cur
        movers = np.flatnonzero((gain > 0) & (best_part != assignment))
        if movers.size == 0:
            break
        movers = movers[np.argsort(-gain[movers], kind="stable")]
        # Capacity-aware commit (vectorized chunks, greedy order).
        sizes = np.bincount(assignment, minlength=k).astype(np.int64)
        rng.shuffle(movers[: movers.size // 2])  # break pathological orderings
        tgt = best_part[movers]
        moved = 0
        for i in range(0, movers.size, 65536):
            mv, tg = movers[i : i + 65536], tgt[i : i + 65536]
            for v, t in zip(mv, tg):
                if sizes[t] < cap:
                    sizes[assignment[v]] -= 1
                    sizes[t] += 1
                    assignment[v] = t
                    moved += 1
        if moved == 0:
            break
        cut_after = int((assignment[src] != assignment[dst]).sum())
        if cut_after > cut_before:
            assignment = prev
            break
        cut_before = cut_after
    return assignment


def quotient_graph(part: Partition, edge_index: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Contract a partitioned graph to its k-super-node quotient.

    Super-node i is CE/part i. The weight of quotient edge (i, j), i ≠ j, is
    the number of DEDUPLICATED boundary rows part i exports to part j: the
    count of distinct source nodes in i that appear on at least one cut edge
    into j. That is exactly the per-pair quantity the halo plan's export
    tiers pad and ship (each distinct (source device, source row) pair
    occupies one slot), so partitioning this quotient minimizes shipped rows
    rather than raw cut edges.

    Returns ``(q_edge_index, q_weights)``: a (2, Eq) int64 directed edge list
    over ``part.k`` super-nodes and the matching (Eq,) int64 weights.
    Self-loops (intra-part edges) are dropped.
    """
    k = int(part.k)
    a = part.assignment.astype(np.int64)
    src = np.asarray(edge_index[0], dtype=np.int64)
    dst = np.asarray(edge_index[1], dtype=np.int64)
    a_s, a_d = a[src], a[dst]
    cut = a_s != a_d
    s, dpart = src[cut], a_d[cut]
    # One boundary row per distinct (source node, destination part) pair.
    uniq = np.unique(s * k + dpart)
    q_src = a[uniq // k]
    q_dst = uniq % k
    counts = np.bincount(q_src * k + q_dst, minlength=k * k).reshape(k, k)
    i, j = np.nonzero(counts)
    q_edge_index = np.stack([i, j]).astype(np.int64)
    return q_edge_index, counts[i, j].astype(np.int64)


def partition_graph(
    n_nodes: int,
    edge_index: np.ndarray,
    k: int,
    method: str = "block",
    seed: int = 0,
    refine: bool = False,
) -> Partition:
    """Produce a node→CE :class:`Partition` of the given graph.

    edge_index: (2, E) int array of directed edges (src, dst).
    """
    src = np.asarray(edge_index[0], dtype=np.int64)
    dst = np.asarray(edge_index[1], dtype=np.int64)
    if method == "block":
        # Paper-faithful: adjacency sliced "as is" into N×(N/k) column bands.
        bounds = np.linspace(0, n_nodes, k + 1).astype(np.int64)
        assignment = (np.searchsorted(bounds, np.arange(n_nodes), side="right") - 1).astype(np.int32)
        assignment = np.clip(assignment, 0, k - 1)
    elif method == "random":
        rng = np.random.default_rng(seed)
        assignment = (rng.permutation(n_nodes) % k).astype(np.int32)
    elif method == "bfs":
        assignment = _bfs_assignment(n_nodes, src, dst, k, seed)
    else:
        raise ValueError(f"unknown partition method: {method!r}")
    if refine:
        assignment = refine_partition(assignment, k, src, dst, seed=seed)
    counts = _edge_count_matrix(assignment, k, src, dst)
    return Partition(
        assignment=assignment,
        k=k,
        part_sizes=np.bincount(assignment, minlength=k).astype(np.int64),
        edge_counts=counts,
        n_nodes=int(n_nodes),
        n_edges=int(src.shape[0]),
    )


def partition_from_assignment(
    assignment: np.ndarray,
    k: int,
    edge_index: np.ndarray,
) -> Partition:
    """Wrap an externally-computed node→CE assignment as a :class:`Partition`.

    Online re-localization (`repro.dist.delta.DeltaPlanner.relocalize`)
    derives its assignment from a BFS locality order of the MUTATED edge
    list rather than from any `partition_graph` method; this constructor
    attaches the edge statistics every Partition consumer expects (the same
    tail `partition_graph` runs on its own assignments).
    """
    assignment = np.asarray(assignment, dtype=np.int32)
    src = np.asarray(edge_index[0], dtype=np.int64)
    dst = np.asarray(edge_index[1], dtype=np.int64)
    counts = _edge_count_matrix(assignment, int(k), src, dst)
    return Partition(
        assignment=assignment,
        k=int(k),
        part_sizes=np.bincount(assignment, minlength=k).astype(np.int64),
        edge_counts=counts,
        n_nodes=int(assignment.shape[0]),
        n_edges=int(src.shape[0]),
    )


def measured_probabilities(p: Partition) -> tuple[np.ndarray, np.ndarray]:
    """Measured p⁽¹⁾_m (k,) and p⁽²⁾_ij (k,k) from a partition (paper §IV-B2).

    p⁽¹⁾_m  = intra-CE edges / ordered node pairs n_m(n_m−1)
    p⁽²⁾_ij = edges between i and j / (n_i · n_j), i ≠ j
    (directed-edge convention, matching the (N/k)(N/k−1) and (N/k)² pair
    counts used in Eqs. 1–2).
    """
    sizes = p.part_sizes.astype(np.float64)
    pairs_in = np.maximum(sizes * (sizes - 1.0), 1.0)
    p1 = np.diag(p.edge_counts) / pairs_in
    pairs_between = np.maximum(np.outer(sizes, sizes), 1.0)
    p2 = p.edge_counts / pairs_between
    np.fill_diagonal(p2, 0.0)
    return p1, p2
