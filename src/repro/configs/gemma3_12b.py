"""gemma3-12b [hf:google/gemma-3-1b-pt; unverified]
48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 — 5:1 local:global
sliding-window attention (window 1024), 128k context. The 5:1 pattern makes
this the one assigned LM arch eligible for the long_500k cell (DESIGN.md §4).
"""
from repro.configs.registry import ArchSpec, lm_shapes
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="gemma3-12b",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15_360,
    vocab=262_144,
    window=1024,
    global_every=6,          # layers 6, 12, … are global → 5 local : 1 global
    rope_theta=1_000_000.0,
)

REDUCED = LMConfig(
    name="gemma3-12b-reduced",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    window=8,
    global_every=6,
)

SPEC = ArchSpec(
    arch_id="gemma3-12b",
    family="lm",
    source="hf:google/gemma-3-1b-pt",
    make_config=lambda shape=None: FULL,
    make_reduced=lambda: REDUCED,
    shapes=lm_shapes(sub_quadratic=FULL.sub_quadratic),
)
