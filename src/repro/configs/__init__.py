"""Architecture configs: one module per assigned architecture + the paper's.

``get_arch(arch_id)`` returns an :class:`repro.configs.registry.ArchSpec`;
``ALL_ARCHS`` lists the 10 assigned ids (plus "coin_gcn", the paper's own).
"""

from repro.configs.registry import ArchSpec, ShapeSpec, get_arch, ALL_ARCHS, ASSIGNED_ARCHS

__all__ = ["ArchSpec", "ShapeSpec", "get_arch", "ALL_ARCHS", "ASSIGNED_ARCHS"]
