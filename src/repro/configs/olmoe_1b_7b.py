"""olmoe-1b-7b [arXiv:2409.02060; hf]
16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8."""
from repro.configs.registry import ArchSpec, lm_shapes
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50_304,
    moe_experts=64,
    moe_top_k=8,
)

REDUCED = LMConfig(
    name="olmoe-1b-7b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab=512,
    moe_experts=8,
    moe_top_k=2,
)

SPEC = ArchSpec(
    arch_id="olmoe-1b-7b",
    family="lm",
    source="arXiv:2409.02060",
    make_config=lambda shape=None: FULL,
    make_reduced=lambda: REDUCED,
    shapes=lm_shapes(sub_quadratic=FULL.sub_quadratic),
)
