"""coin_gcn — the paper's own model: 2-layer Kipf–Welling GCN with the COIN
feature-extraction-first dataflow and 4-bit quantization (§V-B), evaluated on
the Table I datasets. Not part of the assigned 10; included because the paper
is the floor (DESIGN.md §1)."""
from repro.configs.registry import ArchSpec, ShapeSpec
from repro.core.quant import QuantConfig
from repro.graph.generators import TABLE_I
from repro.models.gcn import GCNConfig


def make_config(shape: ShapeSpec | None = None, dataset: str = "cora", hidden: int = 16) -> GCNConfig:
    if shape is not None:
        dims = (shape.d_feat, hidden, shape.n_out)
    else:
        spec = TABLE_I[dataset]
        dims = (spec.n_features, spec.hidden, spec.n_labels)
    return GCNConfig(layer_dims=dims, dataflow="auto", quant=QuantConfig(4, 4, enabled=True))


_SHAPES = {
    name: ShapeSpec(
        name,
        "graph",
        n_nodes=spec.n_nodes,
        n_edges=spec.n_edges,
        d_feat=spec.n_features,
        n_out=spec.n_labels,
    )
    for name, spec in TABLE_I.items()
}

SPEC = ArchSpec(
    arch_id="coin_gcn",
    family="gnn",
    source="arXiv:1609.02907 + the reproduced paper",
    make_config=make_config,
    make_reduced=lambda: GCNConfig(layer_dims=(64, 16, 7), quant=QuantConfig(4, 4, enabled=True)),
    shapes=_SHAPES,
)
