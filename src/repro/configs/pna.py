"""pna [arXiv:2004.05718; paper] — 4L d_hidden=75,
aggregators mean-max-min-std, scalers id-amp-atten."""
from repro.configs.registry import ArchSpec, ShapeSpec, gnn_shapes
from repro.models.pna import PNAConfig


def make_config(shape: ShapeSpec | None = None) -> PNAConfig:
    d_in = shape.d_feat if shape is not None else 16
    n_out = shape.n_out if shape is not None else 1
    return PNAConfig(n_layers=4, d_hidden=75, d_in=d_in, d_out=n_out)


SPEC = ArchSpec(
    arch_id="pna",
    family="gnn",
    source="arXiv:2004.05718",
    make_config=make_config,
    make_reduced=lambda: PNAConfig(n_layers=2, d_hidden=24, d_in=8, d_out=3),
    shapes=gnn_shapes(),
)
