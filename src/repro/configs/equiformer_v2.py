"""equiformer-v2 [arXiv:2306.12059; unverified] — 12L d_hidden=128 l_max=6
m_max=2 8 heads, SO(2)-eSCN convolutions."""
from repro.configs.registry import ArchSpec, ShapeSpec, gnn_shapes
from repro.models.equiformer_v2 import EquiformerV2Config


def make_config(shape: ShapeSpec | None = None) -> EquiformerV2Config:
    d_in = shape.d_feat if shape is not None else 16
    n_out = shape.n_out if shape is not None else 1
    return EquiformerV2Config(
        n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8, d_in=d_in, d_out=n_out
    )


SPEC = ArchSpec(
    arch_id="equiformer-v2",
    family="gnn",
    source="arXiv:2306.12059",
    make_config=make_config,
    make_reduced=lambda: EquiformerV2Config(
        n_layers=2, d_hidden=16, l_max=2, m_max=1, n_heads=4, d_in=8, d_out=2
    ),
    shapes=gnn_shapes(),
)
