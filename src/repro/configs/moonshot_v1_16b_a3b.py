"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6."""
from repro.configs.registry import ArchSpec, lm_shapes
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163_840,
    moe_experts=64,
    moe_top_k=6,
)

REDUCED = LMConfig(
    name="moonshot-v1-16b-a3b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    vocab=512,
    moe_experts=8,
    moe_top_k=2,
)

SPEC = ArchSpec(
    arch_id="moonshot-v1-16b-a3b",
    family="lm",
    source="hf:moonshotai/Moonlight-16B-A3B",
    make_config=lambda shape=None: FULL,
    make_reduced=lambda: REDUCED,
    shapes=lm_shapes(sub_quadratic=FULL.sub_quadratic),
)
