"""Registry mapping --arch ids to model configs and assigned input shapes."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

__all__ = ["ArchSpec", "ShapeSpec", "get_arch", "ALL_ARCHS", "ASSIGNED_ARCHS"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (architecture × input-shape) cell."""

    name: str
    kind: str                      # train | prefill | decode | serve | retrieval | graph
    # LM fields
    seq_len: int | None = None
    global_batch: int | None = None
    # GNN fields
    n_nodes: int | None = None
    n_edges: int | None = None
    d_feat: int | None = None
    n_out: int | None = None
    batch_nodes: int | None = None
    fanout: tuple[int, ...] | None = None
    n_graphs: int | None = None
    # recsys fields
    batch: int | None = None
    n_candidates: int | None = None
    skip_reason: str | None = None  # e.g. full-attention arch on long_500k


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # lm | gnn | recsys
    source: str                    # citation tag from the assignment
    make_config: Callable[..., Any]          # (shape: ShapeSpec|None) -> model config
    make_reduced: Callable[[], Any]          # smoke-test config
    shapes: dict[str, ShapeSpec]

    def runnable_shapes(self) -> dict[str, ShapeSpec]:
        return {k: v for k, v in self.shapes.items() if v.skip_reason is None}


# ---------------------------------------------------------------- shape sets
def lm_shapes(sub_quadratic: bool) -> dict[str, ShapeSpec]:
    """The assigned LM shape set. long_500k runs only for sub-quadratic
    (sliding-window) archs — skip recorded per assignment instructions."""
    skip = None if sub_quadratic else "pure full-attention arch: 524k dense KV on every layer; skipped per assignment (DESIGN.md §4)"
    return {
        "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
        "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
        "long_500k": ShapeSpec(
            "long_500k", "decode", seq_len=524288, global_batch=1, skip_reason=skip
        ),
    }


def gnn_shapes() -> dict[str, ShapeSpec]:
    return {
        "full_graph_sm": ShapeSpec(
            "full_graph_sm", "graph", n_nodes=2708, n_edges=10556, d_feat=1433, n_out=7
        ),
        "minibatch_lg": ShapeSpec(
            "minibatch_lg",
            "graph",
            n_nodes=232_965,
            n_edges=114_615_892,
            d_feat=602,
            n_out=41,
            batch_nodes=1024,
            fanout=(15, 10),
        ),
        "ogb_products": ShapeSpec(
            "ogb_products", "graph", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_out=47
        ),
        "molecule": ShapeSpec(
            "molecule",
            "graph",
            n_nodes=30,
            n_edges=64,
            d_feat=16,
            n_out=1,
            n_graphs=128,
        ),
    }


def recsys_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "train", batch=65_536),
        "serve_p99": ShapeSpec("serve_p99", "serve", batch=512),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", batch=262_144),
        "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000),
    }


# ------------------------------------------------------------------ registry
ASSIGNED_ARCHS: tuple[str, ...] = (
    "moonshot-v1-16b-a3b",
    "olmoe-1b-7b",
    "gemma3-12b",
    "granite-34b",
    "stablelm-12b",
    "egnn",
    "graphcast",
    "equiformer-v2",
    "pna",
    "deepfm",
)
ALL_ARCHS: tuple[str, ...] = ASSIGNED_ARCHS + ("coin_gcn",)

_MODULES = {
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "granite-34b": "repro.configs.granite_34b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "egnn": "repro.configs.egnn",
    "graphcast": "repro.configs.graphcast",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "pna": "repro.configs.pna",
    "deepfm": "repro.configs.deepfm",
    "coin_gcn": "repro.configs.coin_gcn",
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        # Accept the hyphenated spelling of underscore ids (coin-gcn == coin_gcn).
        alias = arch_id.replace("-", "_")
        if alias in _MODULES:
            arch_id = alias
        else:
            raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.SPEC
