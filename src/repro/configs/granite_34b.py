"""granite-34b [arXiv:2405.04324; hf]
88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 — llama-arch, code."""
from repro.configs.registry import ArchSpec, lm_shapes
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="granite-34b",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24_576,
    vocab=49_152,
)

REDUCED = LMConfig(
    name="granite-34b-reduced",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=1,
    d_ff=256,
    vocab=512,
)

SPEC = ArchSpec(
    arch_id="granite-34b",
    family="lm",
    source="arXiv:2405.04324",
    make_config=lambda shape=None: FULL,
    make_reduced=lambda: REDUCED,
    shapes=lm_shapes(sub_quadratic=FULL.sub_quadratic),
)
