"""deepfm [arXiv:1703.04247; paper] — n_sparse=39 embed_dim=10
mlp=400-400-400 interaction=fm."""
from repro.configs.registry import ArchSpec, ShapeSpec, recsys_shapes
from repro.models.deepfm import DeepFMConfig

FULL = DeepFMConfig(
    n_fields=39,
    embed_dim=10,
    mlp_dims=(400, 400, 400),
    rows_per_field=1_000_000,   # 39M-row table: the hot sparse-lookup path
)

SPEC = ArchSpec(
    arch_id="deepfm",
    family="recsys",
    source="arXiv:1703.04247",
    make_config=lambda shape=None: FULL,
    make_reduced=lambda: DeepFMConfig(
        n_fields=8, embed_dim=10, mlp_dims=(32, 32, 32), rows_per_field=1000
    ),
    shapes=recsys_shapes(),
)
