"""egnn [arXiv:2102.09844; paper] — n_layers=4 d_hidden=64 E(n) equivariance."""
from repro.configs.registry import ArchSpec, ShapeSpec, gnn_shapes
from repro.models.egnn import EGNNConfig


def make_config(shape: ShapeSpec | None = None) -> EGNNConfig:
    d_in = shape.d_feat if shape is not None else 16
    n_out = shape.n_out if shape is not None else 1
    return EGNNConfig(n_layers=4, d_hidden=64, d_in=d_in, d_out=n_out)


SPEC = ArchSpec(
    arch_id="egnn",
    family="gnn",
    source="arXiv:2102.09844",
    make_config=make_config,
    make_reduced=lambda: EGNNConfig(n_layers=2, d_hidden=16, d_in=8, d_out=2),
    shapes=gnn_shapes(),
)
