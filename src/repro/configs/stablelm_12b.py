"""stablelm-12b [hf:stabilityai/stablelm-2-1_6b; hf]
40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352."""
from repro.configs.registry import ArchSpec, lm_shapes
from repro.models.transformer_lm import LMConfig

FULL = LMConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13_824,
    vocab=100_352,
)

REDUCED = LMConfig(
    name="stablelm-12b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab=512,
)

SPEC = ArchSpec(
    arch_id="stablelm-12b",
    family="lm",
    source="hf:stabilityai/stablelm-2-1_6b",
    make_config=lambda shape=None: FULL,
    make_reduced=lambda: REDUCED,
    shapes=lm_shapes(sub_quadratic=FULL.sub_quadratic),
)
