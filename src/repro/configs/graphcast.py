"""graphcast [arXiv:2212.12794; unverified] — 16L d_hidden=512
mesh_refinement=6 aggregator=sum n_vars=227 (encoder-processor-decoder)."""
from repro.configs.registry import ArchSpec, ShapeSpec, gnn_shapes
from repro.models.graphcast import GraphCastConfig


def make_config(shape: ShapeSpec | None = None) -> GraphCastConfig:
    d_in = shape.d_feat if shape is not None else None
    return GraphCastConfig(
        n_layers=16, d_hidden=512, n_vars=227, mesh_refinement=6, d_in=d_in
    )


SPEC = ArchSpec(
    arch_id="graphcast",
    family="gnn",
    source="arXiv:2212.12794",
    make_config=make_config,
    make_reduced=lambda: GraphCastConfig(n_layers=2, d_hidden=32, n_vars=12, mesh_refinement=1, d_in=8),
    shapes=gnn_shapes(),
)
