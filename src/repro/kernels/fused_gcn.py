"""Pallas TPU kernel: one fused ragged-BSR GCN layer (DESIGN.md §2, docs/kernels.md).

The unfused `backend="bsr"` pipeline ran four HBM round-trips per layer:
X·W matmul → SpMM → bias add → ReLU, each materializing an (N, F) tensor.
This kernel computes the whole layer

    H = act( Ã · (X · W) + b )        (feature-first, COIN §IV-C3)
    H = act( (Ã · X) · W + b )        (aggregation-first)

in ONE `pl.pallas_call` over the ragged blocked adjacency of
`repro.graph.structure.blocked_adjacency`: the intermediate Z = X·W (or
Ã·X) lives only in VMEM scratch, accumulation is fp32 regardless of the
(optionally bf16) vals/feature dtype, and bias + activation run on the
resident accumulator before the single output store.

**Feature-first** (d_out ≤ d_in, the COIN order) — grid (R, F_out/Ft, T):
per tile t < lens[r], compute z = X[cols[r,t]]·W[:, f-tile] on the fly and
accumulate vals[r,t]·z into a (B, Ft) fp32 scratch; at the last t apply
bias/activation and store. Z never exists in HBM; the X block is re-read
(and its transform re-multiplied) once per nonzero tile — the fusion
tradeoff, a win whenever the layer was HBM-bound (it was: Ft·B ≪ B·B).

**Aggregation-first** — grid (R, T): accumulate vals[r,t]·X[cols[r,t]] into
a (B, F_in) fp32 scratch, then one (B,F_in)×(F_in,F_out) matmul + bias +
activation at the last t. No recompute at all; needs F_in·F_out weights
resident in VMEM (fine for GCN widths; the wrapper asserts the footprint).

Ragged skip: both kernels scalar-prefetch `lens` and guard the per-tile
matmul with `pl.when(t < lens[r])`, so padding tiles cost a predicate, not
an MXU pass. Empty block-rows (lens[r] == 0) still produce act(b) — exactly
what a zero adjacency row contributes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_gcn_layer_pallas"]


def _ff_kernel(cols_ref, lens_ref, vals_ref, x_ref, w_ref, b_ref, out_ref, acc_ref, *, relu):
    """Feature-first body: acc += vals @ (x @ w), epilogue at the last tile."""
    r = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(t < lens_ref[r])
    def _accumulate():
        a = vals_ref[0, 0]                                     # (B, B)
        z = jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        ).astype(a.dtype)                                      # (B, Ft) on the fly
        acc_ref[...] += jnp.dot(a, z, preferred_element_type=jnp.float32)

    @pl.when(t == pl.num_programs(2) - 1)
    def _epilogue():
        h = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if relu:
            h = jnp.maximum(h, 0.0)
        out_ref[...] = h.astype(out_ref.dtype)


def _af_kernel(cols_ref, lens_ref, vals_ref, x_ref, w_ref, b_ref, out_ref, acc_ref, *, relu):
    """Aggregation-first body: acc += vals @ x, matmul + epilogue at the end."""
    r = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(t < lens_ref[r])
    def _accumulate():
        acc_ref[...] += jnp.dot(
            vals_ref[0, 0], x_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(t == pl.num_programs(1) - 1)
    def _epilogue():
        w = w_ref[...]
        h = jnp.dot(
            acc_ref[...].astype(w.dtype), w, preferred_element_type=jnp.float32
        ) + b_ref[...].astype(jnp.float32)
        if relu:
            h = jnp.maximum(h, 0.0)
        out_ref[...] = h.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("order", "relu", "f_tile", "interpret")
)
def fused_gcn_layer_pallas(
    vals: jax.Array,          # (R, T, B, B)
    cols: jax.Array,          # (R, T) int32
    lens: jax.Array,          # (R,) int32 ragged tile counts
    x: jax.Array,             # (Cb·B, F_in) dense features, row-padded
    w: jax.Array,             # (F_in, F_out)
    b: jax.Array,             # (1, F_out)
    order: str = "feature_first",
    relu: bool = True,
    f_tile: int = 512,
    interpret: bool = False,
) -> jax.Array:
    R, T, B, _ = vals.shape
    F_in, F_out = w.shape
    assert x.shape[0] % B == 0 and x.shape[1] == F_in, (x.shape, w.shape)
    assert b.shape == (1, F_out), b.shape
    assert lens.shape == (R,), (lens.shape, R)

    if order == "feature_first":
        assert F_out % f_tile == 0, (F_out, f_tile)
        grid = (R, F_out // f_tile, T)
        return pl.pallas_call(
            functools.partial(_ff_kernel, relu=relu),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=grid,
                in_specs=[
                    pl.BlockSpec((1, 1, B, B), lambda r, f, t, cols, lens: (r, t, 0, 0)),
                    pl.BlockSpec((B, F_in), lambda r, f, t, cols, lens: (cols[r, t], 0)),
                    pl.BlockSpec((F_in, f_tile), lambda r, f, t, cols, lens: (0, f)),
                    pl.BlockSpec((1, f_tile), lambda r, f, t, cols, lens: (0, f)),
                ],
                out_specs=pl.BlockSpec((B, f_tile), lambda r, f, t, cols, lens: (r, f)),
                scratch_shapes=[pltpu.VMEM((B, f_tile), jnp.float32)],
            ),
            out_shape=jax.ShapeDtypeStruct((R * B, F_out), x.dtype),
            interpret=interpret,
        )(cols, lens, vals, x, w, b)

    if order != "aggregation_first":
        raise ValueError(f"unknown dataflow order: {order!r}")
    grid = (R, T)
    return pl.pallas_call(
        functools.partial(_af_kernel, relu=relu),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, B, B), lambda r, t, cols, lens: (r, t, 0, 0)),
                pl.BlockSpec((B, F_in), lambda r, t, cols, lens: (cols[r, t], 0)),
                pl.BlockSpec((F_in, F_out), lambda r, t, cols, lens: (0, 0)),
                pl.BlockSpec((1, F_out), lambda r, t, cols, lens: (0, 0)),
            ],
            out_specs=pl.BlockSpec((B, F_out), lambda r, t, cols, lens: (r, 0)),
            scratch_shapes=[pltpu.VMEM((B, F_in), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((R * B, F_out), x.dtype),
        interpret=interpret,
    )(cols, lens, vals, x, w, b)
