"""Pallas TPU kernels for the perf-critical compute layers.

  bsr_spmm        — ragged 128×128 block-sparse Ã·Z (COIN crossbar → MXU
                    mapping; scalar-prefetched per-block-row lengths skip
                    padding tiles)
  fused_gcn_layer — one whole GCN layer act(Ã·(X·W) + b) in a single
                    pallas_call (fp32 accumulation, optional bf16 operands)
  fm_interaction  — DeepFM linearized second-order interaction
  flash_attention — causal/sliding-window online-softmax attention

Each kernel ships with a pure-jnp oracle in `ref.py` and a jit'd public
wrapper in `ops.py` (interpret mode on CPU, native on TPU). The kernel
guide is docs/kernels.md.
"""

from repro.kernels.ops import bsr_spmm, fused_gcn_layer, fm_interaction, flash_attention

__all__ = ["bsr_spmm", "fused_gcn_layer", "fm_interaction", "flash_attention"]
