"""Pallas TPU kernels for the perf-critical compute layers.

  bsr_spmm        — 128×128 block-sparse Ã·Z (COIN crossbar → MXU mapping)
  fm_interaction  — DeepFM linearized second-order interaction
  flash_attention — causal/sliding-window online-softmax attention

Each kernel ships with a pure-jnp oracle in `ref.py` and a jit'd public
wrapper in `ops.py` (interpret mode on CPU, native on TPU).
"""

from repro.kernels.ops import bsr_spmm, fm_interaction, flash_attention

__all__ = ["bsr_spmm", "fm_interaction", "flash_attention"]
