"""Pallas TPU kernel: ragged 128×128 block-sparse SpMM — COIN's crossbar → MXU map.

COIN stores the adjacency "as is" in 128×128 RRAM crossbars and drives them
with the intermediate features Z (paper §IV-C). The TPU-native adaptation
(DESIGN.md §2, docs/kernels.md) tiles Ã into 128×128 blocks, keeps only
nonzero blocks, and feeds the MXU one dense 128×128 × 128×F_t matmul per
nonzero block:

    out[r·B:(r+1)·B, f·Ft:(f+1)·Ft] = Σ_{t < lens[r]} vals[r,t] @ Z[cols[r,t]·B:…, f·Ft:…]

Layout (built host-side by `repro.graph.structure.blocked_adjacency`):
    vals : (R, T, B, B)  — per block-row, T = max nonzero blocks (padded with
                           zero tiles whose col id repeats the last valid one)
    cols : (R, T) int32  — block-column ids, SCALAR-PREFETCHED so the Z
                           BlockSpec index_map can do the indirect load
    lens : (R,) int32    — RAGGED per-block-row tile counts (≤ T), also
                           scalar-prefetched: tiles t ≥ lens[r] are padding
                           and their matmul is skipped via `pl.when`, so a
                           power-law hub row no longer taxes every other
                           block-row with its worst-case T
    z    : (Cb·B, F)     — dense features (Cb = column block count; may
                           exceed the row block count for the rectangular
                           halo-path matrices)

Grid: (R, F/Ft, T) — t innermost so the output tile stays resident in VMEM
across the accumulation; first t zero-initializes, padded t only re-asserts
the revisited output block. VMEM footprint per step: B·B + B·Ft + B·Ft floats
= 128·128 + 2·128·Ft → Ft=512 keeps it ≈ 0.6 MB, comfortably inside the
~16 MB v5e VMEM while MXU dims stay 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

__all__ = ["bsr_spmm_pallas", "poison_padding"]


def poison_padding(vals, cols, lens, poison=float("nan")):
    """Copy of ``vals`` with every padding tile (t ≥ lens[r]) set to
    ``poison`` (NaN by default). Host-side numpy; works for the global
    (R, T, B, B) layout and the per-device (k, R, T, B, B) one.

    The ragged-skip contract says the kernel NEVER reads those tiles —
    running the SpMM on a poisoned copy and checking the output for NaN
    proves it. The delta path (`repro.dist.delta`) leans on this: a
    tombstoned tile is swapped into the padding region, and this check is
    what pins "freed slot" as "never touched" rather than "zero by luck".
    """
    vals = np.array(vals, copy=True)
    cols = np.asarray(cols)
    t = cols.shape[-1]
    pad = np.arange(t) >= np.asarray(lens)[..., None]     # (..., T)
    vals[pad] = poison
    return vals


def _kernel(cols_ref, lens_ref, vals_ref, z_ref, out_ref):
    r = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Ragged skip: tiles past this block-row's true count are padding — their
    # vals are zero and their col id repeats the last valid one. Guarding the
    # matmul turns the dense-T worst case into per-row work.
    @pl.when(t < lens_ref[r])
    def _accumulate():
        a = vals_ref[0, 0]                   # (B, B)
        z = z_ref[...]                       # (B, Ft)
        out_ref[...] += jnp.dot(
            a, z, preferred_element_type=jnp.float32
        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f_tile", "interpret"))
def bsr_spmm_pallas(
    vals: jax.Array,          # (R, T, B, B)
    cols: jax.Array,          # (R, T) int32
    lens: jax.Array,          # (R,) int32 ragged tile counts
    z: jax.Array,             # (Cb·B, F) — F must be a multiple of f_tile
    f_tile: int = 512,
    interpret: bool = False,
) -> jax.Array:
    R, T, B, _ = vals.shape
    F = z.shape[1]
    assert F % f_tile == 0, (F, f_tile)
    assert z.shape[0] % B == 0
    assert lens.shape == (R,), (lens.shape, R)
    grid = (R, F // f_tile, T)
    # Trace-time only (this body runs under jit): record the STATIC grid —
    # the dense-T tile bound the ragged lens skip is judged against. The
    # runtime executed-tile count is host data (``lens.sum()``), recorded by
    # `repro.obs.instrument.record_blocked` at table-build time, never here.
    if _obs_metrics.enabled():
        _obs_metrics.inc("bsr.traces")
        _obs_metrics.set_gauge("bsr.grid_dense_tiles", R * T,
                               (("scope", "kernel"),))
        _obs_metrics.set_gauge("bsr.grid_block", B, (("scope", "kernel"),))
    _obs_trace.instant("kernels.bsr_spmm.trace",
                       {"R": R, "T": T, "B": B, "F": F, "f_tile": f_tile})
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, B, B), lambda r, f, t, cols, lens: (r, t, 0, 0)),
                pl.BlockSpec((B, f_tile), lambda r, f, t, cols, lens: (cols[r, t], f)),
            ],
            out_specs=pl.BlockSpec((B, f_tile), lambda r, f, t, cols, lens: (r, f)),
        ),
        out_shape=jax.ShapeDtypeStruct((R * B, F), z.dtype),
        interpret=interpret,
    )(cols, lens, vals, z)
