"""Pallas TPU kernel: flash attention (causal / sliding-window), fwd only.

Online-softmax attention for the LM family: grid (B·H, nQ, nK) with k-blocks
innermost; running max m, normalizer l live in VMEM scratch, the output tile
accumulates rescaled partial sums. Sliding windows reuse the same kernel with
a per-position validity mask  q−window < k ≤ q. The jnp oracle is
`repro.kernels.ref.flash_attention_ref` (identical math to
`repro.nn.attention._chunked_attention`, which the models run on CPU).

VMEM per step: q (Bq·d) + k,v (Bk·d) + scores (Bq·Bk) + acc (Bq·d) floats.
Bq=Bk=256, d=128 → ≈ 0.7 MB. MXU dims 128-aligned for d ∈ {128, 256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory; interpret mode accepts the same spec
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, win_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, causal):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (Bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (Bk, d)
    v = v_ref[0].astype(jnp.float32)                     # (Bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (q.shape[-1] ** -0.5)                            # (Bq, Bk)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    win = win_ref[0]
    valid = (k_pos > q_pos - win)
    if causal:
        valid &= k_pos <= q_pos
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * scale + p.sum(axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * scale[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention_pallas(
    q: jax.Array,              # (BH, S, d)
    k: jax.Array,              # (BH, S, d)
    v: jax.Array,              # (BH, S, d)
    window: jax.Array | int | None = None,
    bq: int = 256,
    bk: int = 256,
    causal: bool = True,
    interpret: bool = False,
) -> jax.Array:
    BH, S, d = q.shape
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    win = jnp.asarray(S if window is None else window, jnp.int32).reshape(1)
    grid = (BH, S // bq, S // bk)
    scratch = [
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq, d), jnp.float32),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v, win)
