"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile natively. `interpret=None` auto-detects the backend.

The graph kernels (`bsr_spmm`, `fused_gcn_layer`) carry custom VJPs so the
training path can differentiate straight through the pallas_call: the
backward of a blocked SpMM is the blocked-TRANSPOSE SpMM, expressed here as
a gathered einsum + scatter-add over the same ragged (vals, cols, lens)
tables (padding tiles masked out), so no transposed block structure needs
to be built or shipped. Integer operands (cols/lens) get symbolic-zero
cotangents.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_spmm import bsr_spmm_pallas
from repro.kernels.fused_gcn import fused_gcn_layer_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas
from repro.kernels.flash_attention import flash_attention_pallas

__all__ = ["bsr_spmm", "fused_gcn_layer", "fm_interaction", "flash_attention", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto(interpret: bool | None) -> bool:
    return (not on_tpu()) if interpret is None else interpret


def _pick_f_tile(F: int) -> int:
    return 512 if F >= 512 else max(128, 1 << (F - 1).bit_length())


def _pad_rows(z, block: int):
    """Row-pad a dense operand to the block grid (static shapes, zero rows)."""
    pad = (-z.shape[0]) % block
    return jnp.pad(z, ((0, pad),) + ((0, 0),) * (z.ndim - 1)) if pad else z


def _int_zero(x):
    """Symbolic-zero cotangent for integer operands (cols/lens)."""
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _tile_mask(cols, lens):
    """(R, T, 1, 1) validity mask of the ragged tile tables."""
    R, T = cols.shape
    return (jnp.arange(T)[None, :] < lens[:, None]).astype(jnp.float32)[:, :, None, None]


def _bsr_t_apply(vals, cols, mask, g, n_z_rows: int):
    """Blocked-transpose apply: dZ[c] = Σ_{(r,t): cols[r,t]=c} vals[r,t]ᵀ·g[r].

    ``g`` is (R·B, F) row-cotangents; returns (n_z_rows, F). This IS the
    backward of the blocked SpMM, written as einsum + scatter-add over the
    forward's own ragged tables — no transposed block structure needed.
    """
    R, T = cols.shape
    B = vals.shape[-1]
    F = g.shape[-1]
    gb = g.reshape(R, B, F)
    contrib = jnp.einsum(
        "rtij,rif->rtjf", (vals * mask).astype(jnp.float32), gb.astype(jnp.float32)
    )
    dz = jnp.zeros((n_z_rows // B, B, F), jnp.float32)
    dz = dz.at[cols.reshape(-1)].add(contrib.reshape(R * T, B, F))
    return dz.reshape(n_z_rows, F)


def _bsr_dvals(cols, mask, g, z):
    """dvals[r,t] = g[r] · Z[cols[r,t]]ᵀ (zero on padding tiles)."""
    R, T = cols.shape
    F = z.shape[-1]
    B = g.shape[0] // R
    zb = z.reshape(-1, B, F)[cols]                       # (R, T, B, F)
    gb = g.reshape(R, B, F)
    return jnp.einsum(
        "rif,rtjf->rtij", gb.astype(jnp.float32), zb.astype(jnp.float32)
    ) * mask


# --------------------------------------------------------- bsr_spmm (+ VJP)
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _bsr_diff(f_tile: int, interpret: bool, vals, cols, lens, z):
    return bsr_spmm_pallas(vals, cols, lens, z, f_tile=f_tile, interpret=interpret)


def _bsr_diff_fwd(f_tile, interpret, vals, cols, lens, z):
    out = _bsr_diff(f_tile, interpret, vals, cols, lens, z)
    return out, (vals, cols, lens, z)


def _bsr_diff_bwd(f_tile, interpret, res, g):
    vals, cols, lens, z = res
    mask = _tile_mask(cols, lens)
    dz = _bsr_t_apply(vals, cols, mask, g, z.shape[0]).astype(z.dtype)
    dvals = _bsr_dvals(cols, mask, g, z).astype(vals.dtype)
    return dvals, _int_zero(cols), _int_zero(lens), dz


_bsr_diff.defvjp(_bsr_diff_fwd, _bsr_diff_bwd)


def bsr_spmm(vals, cols, z, lens=None, f_tile: int | None = None,
             interpret: bool | None = None):
    """Ragged block-sparse Ã·Z (DESIGN.md §2).

    Pads the feature dim to the tile size and the rows of ``z`` to the block
    grid if needed; the output has ``R·B`` rows (the RECEIVER block grid —
    fewer than ``z``'s rows for the rectangular halo matrices, where ``z``
    is the wider ``[local ‖ halo]`` table). ``lens`` is the
    per-block-row valid tile count from
    `repro.graph.structure.BlockedAdjacency.row_nnzb`; omitted (None), every
    tile is treated as valid — correct for any layout (padding tiles are
    zero) but pays the dense-T worst case the ragged path exists to avoid.
    """
    R, T, B, _ = vals.shape
    F = z.shape[1]
    if f_tile is None:
        f_tile = _pick_f_tile(F)
    if lens is None:
        lens = jnp.full((R,), T, jnp.int32)
    pad = (-F) % f_tile
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)))
    z = _pad_rows(z, B)
    out = _bsr_diff(f_tile, _auto(interpret), vals, cols, lens, z)
    return out[:, :F] if pad else out


# -------------------------------------------------- fused_gcn_layer (+ VJP)
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _fused_diff(order: str, relu: bool, f_tile: int, interpret: bool,
                vals, cols, lens, x, w, b):
    return fused_gcn_layer_pallas(
        vals, cols, lens, x, w, b, order=order, relu=relu, f_tile=f_tile,
        interpret=interpret,
    )


def _fused_diff_fwd(order, relu, f_tile, interpret, vals, cols, lens, x, w, b):
    out = _fused_diff(order, relu, f_tile, interpret, vals, cols, lens, x, w, b)
    return out, (vals, cols, lens, x, w, b, out)


def _fused_diff_bwd(order, relu, f_tile, interpret, res, g):
    """Layer backward: dpre = g·act'(pre), then the two matmul transposes —
    the aggregation transpose is the blocked scatter-add of `_bsr_t_apply`,
    the A·X recompute (aggregation-first) re-runs the non-fused kernel."""
    vals, cols, lens, x, w, b, out = res
    mask = _tile_mask(cols, lens)
    g = g.astype(jnp.float32)
    if relu:
        # act' from the saved output: relu(pre) > 0 ⇔ pre > 0 (a.e.).
        g = g * (out > 0)
    db = g.sum(axis=0, keepdims=True).astype(b.dtype)
    wf = w.astype(jnp.float32)
    if order == "feature_first":
        # pre = Ã·(x@w) + b
        z = (x.astype(jnp.float32) @ wf).astype(x.dtype)       # recompute Z
        dz = _bsr_t_apply(vals, cols, mask, g, x.shape[0])     # Ãᵀ·dpre
        dvals = _bsr_dvals(cols, mask, g, z)
        dw = x.astype(jnp.float32).T @ dz
        dx = dz @ wf.T
    else:
        # pre = (Ã·x)·w + b — recompute M = Ã·x through the SpMM kernel.
        m = bsr_spmm_pallas(vals, cols, lens, x, f_tile=x.shape[1], interpret=interpret)
        dw = m.astype(jnp.float32).T @ g
        dm = g @ wf.T                                          # (R·B, F_in)
        dvals = _bsr_dvals(cols, mask, dm, x)
        dx = _bsr_t_apply(vals, cols, mask, dm, x.shape[0])
    return (
        dvals.astype(vals.dtype), _int_zero(cols), _int_zero(lens),
        dx.astype(x.dtype), dw.astype(w.dtype), db,
    )


_fused_diff.defvjp(_fused_diff_fwd, _fused_diff_bwd)


def fused_gcn_layer(vals, cols, lens, x, w, b, order: str = "feature_first",
                    relu: bool = True, f_tile: int | None = None,
                    interpret: bool | None = None):
    """One fused GCN layer act(Ã·(X·W) + b) / act((Ã·X)·W + b) — see
    `repro.kernels.fused_gcn`.

    Handles the alignment the kernel requires: rows of ``x`` pad to the
    block grid, F_in/F_out pad to 128 lanes (zero weight rows/cols, sliced
    back off). Returns (R·B, F_out) — callers slice to their real node
    count. Accumulation is fp32; pass bf16 ``vals``/``x``/``w`` for the
    half-width MXU path.
    """
    R, T, B, _ = vals.shape
    F_in, F_out = w.shape
    if lens is None:
        lens = jnp.full((R,), T, jnp.int32)
    if order == "aggregation_first":
        # The whole weight + the (B, F_in) accumulator stay VMEM-resident;
        # fail early with a real error instead of an opaque Mosaic OOM.
        resident = 4 * (F_in * F_out + 2 * B * F_in + B * F_out + B * B)
        if resident > 14_000_000:
            raise ValueError(
                f"aggregation_first fused layer needs ~{resident / 1e6:.0f} MB "
                f"VMEM-resident (F_in={F_in}, F_out={F_out}) — past the ~16 MB "
                "budget; use order='feature_first' or the unfused bsr_spmm path"
            )
    pad_in = (-F_in) % 128
    f_tile = _pick_f_tile(F_out) if f_tile is None else f_tile
    pad_out = (-F_out) % (f_tile if order == "feature_first" else 128)
    x = _pad_rows(x, B)
    if pad_in:
        x = jnp.pad(x, ((0, 0), (0, pad_in)))
        w = jnp.pad(w, ((0, pad_in), (0, 0)))
    if pad_out:
        w = jnp.pad(w, ((0, 0), (0, pad_out)))
    b2 = jnp.reshape(b, (1, F_out))
    if pad_out:
        b2 = jnp.pad(b2, ((0, 0), (0, pad_out)))
    out = _fused_diff(
        order, relu, min(f_tile, F_out + pad_out), _auto(interpret),
        vals, cols, lens, x, w, b2,
    )
    return out[:, :F_out] if pad_out else out


def fm_interaction(emb, b_tile: int = 256, interpret: bool | None = None):
    B = emb.shape[0]
    while B % b_tile:
        b_tile //= 2
    return fm_interaction_pallas(emb, b_tile=max(b_tile, 1), interpret=_auto(interpret))


def flash_attention(q, k, v, window=None, causal: bool = True,
                    bq: int = 256, bk: int = 256, interpret: bool | None = None):
    return flash_attention_pallas(
        q, k, v, window=window, bq=bq, bk=bk, causal=causal, interpret=_auto(interpret)
    )
