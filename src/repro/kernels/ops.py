"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile natively. `interpret=None` auto-detects the backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bsr_spmm import bsr_spmm_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas
from repro.kernels.flash_attention import flash_attention_pallas

__all__ = ["bsr_spmm", "fm_interaction", "flash_attention", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto(interpret: bool | None) -> bool:
    return (not on_tpu()) if interpret is None else interpret


def bsr_spmm(vals, cols, z, f_tile: int | None = None, interpret: bool | None = None):
    """Block-sparse Ã·Z. Pads the feature dim to the tile size if needed."""
    F = z.shape[1]
    if f_tile is None:
        f_tile = 512 if F >= 512 else max(128, 1 << (F - 1).bit_length())
    pad = (-F) % f_tile
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)))
    out = bsr_spmm_pallas(vals, cols, z, f_tile=f_tile, interpret=_auto(interpret))
    return out[:, :F] if pad else out


def fm_interaction(emb, b_tile: int = 256, interpret: bool | None = None):
    B = emb.shape[0]
    while B % b_tile:
        b_tile //= 2
    return fm_interaction_pallas(emb, b_tile=max(b_tile, 1), interpret=_auto(interpret))


def flash_attention(q, k, v, window=None, causal: bool = True,
                    bq: int = 256, bk: int = 256, interpret: bool | None = None):
    return flash_attention_pallas(
        q, k, v, window=window, bq=bq, bk=bk, causal=causal, interpret=_auto(interpret)
    )
