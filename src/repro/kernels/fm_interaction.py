"""Pallas TPU kernel: DeepFM second-order FM interaction.

Computes, per example, ½·Σ_d[(Σ_f v_fd)² − Σ_f v_fd²] — the linearized FM
identity (O(F·D) instead of O(F²·D), the same multiply-reordering insight as
COIN's dataflow). One batch-tile per grid step; the (Bt, F, D) tile reduces
entirely in VMEM, so the op is a single HBM read of the embeddings — it is
memory-bound and this fusion removes the two intermediate (B, D) tensors the
naive jnp graph materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fm_interaction_pallas"]


def _kernel(emb_ref, out_ref):
    e = emb_ref[...].astype(jnp.float32)          # (Bt, F, D)
    s = jnp.sum(e, axis=1)                        # (Bt, D)
    sq = jnp.sum(e * e, axis=1)                   # (Bt, D)
    out_ref[...] = (0.5 * jnp.sum(s * s - sq, axis=-1)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("b_tile", "interpret"))
def fm_interaction_pallas(
    emb: jax.Array,            # (B, F, D)
    b_tile: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, F, D = emb.shape
    b_tile = min(b_tile, B)
    assert B % b_tile == 0, (B, b_tile)
    return pl.pallas_call(
        _kernel,
        grid=(B // b_tile,),
        in_specs=[pl.BlockSpec((b_tile, F, D), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((b_tile,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), emb.dtype),
        interpret=interpret,
    )(emb)
