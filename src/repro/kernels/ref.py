"""Pure-jnp oracles for every Pallas kernel (the `ref.py` layer)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["bsr_spmm_ref", "fused_gcn_layer_ref", "fm_interaction_ref", "flash_attention_ref"]


def bsr_spmm_ref(vals: jax.Array, cols: jax.Array, z: jax.Array) -> jax.Array:
    """Dense-gather oracle: out[r] = Σ_t vals[r,t] @ Z_block[cols[r,t]].

    Ignores the ragged lengths on purpose — padding tiles are zero, so the
    dense-T sum equals the ragged kernel's skip-padding sum exactly.
    """
    R, T, B, _ = vals.shape
    F = z.shape[1]
    zb = z.reshape(-1, B, F)                       # (Cb, B, F)
    gathered = zb[cols]                            # (R, T, B, F)
    return jnp.einsum("rtij,rtjf->rif", vals, gathered).reshape(R * B, F)


def fused_gcn_layer_ref(
    vals: jax.Array, cols: jax.Array, z_or_x: jax.Array,
    w: jax.Array, b: jax.Array,
    order: str = "feature_first", relu: bool = True,
) -> jax.Array:
    """Unfused oracle of `repro.kernels.fused_gcn.fused_gcn_layer_pallas`:
    the same layer as three separate fp32 ops."""
    x = z_or_x.astype(jnp.float32)
    if order == "feature_first":
        h = bsr_spmm_ref(vals.astype(jnp.float32), cols, x @ w.astype(jnp.float32))
    else:
        h = bsr_spmm_ref(vals.astype(jnp.float32), cols, x) @ w.astype(jnp.float32)
    h = h + jnp.reshape(b, (1, -1)).astype(jnp.float32)
    return jnp.maximum(h, 0.0) if relu else h


def fm_interaction_ref(emb: jax.Array) -> jax.Array:
    e = emb.astype(jnp.float32)
    s = e.sum(axis=1)
    sq = (e * e).sum(axis=1)
    return (0.5 * (s * s - sq).sum(axis=-1)).astype(emb.dtype)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    window: int | None = None, causal: bool = True,
) -> jax.Array:
    BH, S, d = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * (d ** -0.5)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    win = S if window is None else window
    valid = kp > qp - win
    if causal:
        valid &= kp <= qp
    s = jnp.where(valid[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)
