"""Pure-jnp oracles for every Pallas kernel (the `ref.py` layer)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["bsr_spmm_ref", "fm_interaction_ref", "flash_attention_ref"]


def bsr_spmm_ref(vals: jax.Array, cols: jax.Array, z: jax.Array) -> jax.Array:
    """Dense-gather oracle: out[r] = Σ_t vals[r,t] @ Z_block[cols[r,t]]."""
    R, T, B, _ = vals.shape
    F = z.shape[1]
    zb = z.reshape(-1, B, F)                       # (Cb, B, F)
    gathered = zb[cols]                            # (R, T, B, F)
    return jnp.einsum("rtij,rtjf->rif", vals, gathered).reshape(R * B, F)


def fm_interaction_ref(emb: jax.Array) -> jax.Array:
    e = emb.astype(jnp.float32)
    s = e.sum(axis=1)
    sq = (e * e).sum(axis=1)
    return (0.5 * (s * s - sq).sum(axis=-1)).astype(emb.dtype)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    window: int | None = None, causal: bool = True,
) -> jax.Array:
    BH, S, d = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * (d ** -0.5)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    win = S if window is None else window
    valid = kp > qp - win
    if causal:
        valid &= kp <= qp
    s = jnp.where(valid[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)
