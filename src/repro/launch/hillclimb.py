import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init).

_DOC = """§Perf hillclimb driver: the three selected cells, baseline + variants.

Targets (picked from the baseline roofline table, EXPERIMENTS.md §Roofline):
  1. moonshot-v1-16b-a3b × train_4k — most collective-bound (MoE dispatch),
  2. granite-34b × train_4k         — worst peak memory (42.9 GB/device),
  3. pna × ogb_products             — most paper-representative: full-graph
                                      GNN whose exchange the COIN objective
                                      governs (broadcast → halo).

Each iteration records hypothesis → change → before/after roofline terms in
results/hillclimb.json; EXPERIMENTS.md §Perf narrates them.

    PYTHONPATH=src python -m repro.launch.hillclimb [--target 1|2|3|all]
"""

import argparse
import dataclasses
import json
import sys
import time

__doc__ = _DOC

RESULTS = "results/hillclimb.json"


def _measure(cell, mesh, tag: str) -> dict:
    """Lower + compile + roofline terms (same pipeline as dryrun.run_cell)."""
    from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS, collective_bytes
    from repro.launch.dryrun import extrapolated_cost

    t0 = time.time()
    lowered = cell.lower(mesh)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    try:
        mem = compiled.memory_analysis()
        peak = getattr(mem, "peak_memory_in_bytes", None)
    except Exception:
        peak = None
    if cell.cost_cells:
        flops, bytes_hbm, coll = extrapolated_cost(cell, mesh)
    else:
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        flops = float(cost.get("flops", 0.0))
        bytes_hbm = float(cost.get("bytes accessed", 0.0))
    rec = {
        "tag": tag,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": coll["total"] / ICI_BW,
        "collective_by_type": {k: v for k, v in coll.items() if v},
        "peak_bytes": peak,
        "compile_s": round(compile_s, 1),
        "model_flops": cell.model_flops,
    }
    print(f"  [{tag}] compute={rec['compute_s']:.3g}s memory={rec['memory_s']:.3g}s "
          f"collective={rec['collective_s']:.3g}s peak={(peak or 0)/1e9:.1f}GB "
          f"(compile {compile_s:.0f}s)")
    return rec


# ================================================== target 1: MoE collectives
def target1_moe() -> list[dict]:
    import jax

    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    mesh = make_production_mesh()
    spec = get_arch("moonshot-v1-16b-a3b")
    shape = spec.shapes["train_4k"]
    out = []

    print("[T1] moonshot-v1-16b-a3b × train_4k (collective-bound MoE)")
    print("  hypothesis A: the flat dispatch sorts/scatters a GLOBAL (T·K)"
          " token stream across shards → XLA emits all-gathers of activations"
          " per MoE layer; grouping dispatch per data shard (G=16) keeps the"
          " sort local and only the (G,E,C,D) buffer crosses the EP axis:"
          " predicted wire/layer ≈ 2·buf/256dev ≈ 0.25 GB vs ≳4 GB.")
    out.append(_measure(build_cell(spec, shape, mesh), mesh, "t1-baseline groups=1"))

    cfg16 = dataclasses.replace(spec.make_config(shape), moe_groups=16)
    spec16 = dataclasses.replace(spec, make_config=lambda s=None, c=cfg16: c)
    out.append(_measure(build_cell(spec16, shape, mesh), mesh, "t1-a groups=16 (EP all-to-all)"))

    print("  hypothesis B: with dispatch fixed, remat trims the activation"
          " traffic of the backward pass (fewer saved intermediates).")
    cfg_r = dataclasses.replace(cfg16, remat=True)
    spec_r = dataclasses.replace(spec, make_config=lambda s=None, c=cfg_r: c)
    out.append(_measure(build_cell(spec_r, shape, mesh), mesh, "t1-b groups=16 + remat"))

    print("  hypothesis C: with the collective fixed, memory dominates; the"
          " (G,E,C,D) buffer carries 25% capacity padding — cf 1.25 → 1.0"
          " should cut the dispatch-buffer traffic term by ~20% (drops"
          " overflow tokens; the standard Switch trade).")
    cfg_c = dataclasses.replace(cfg16, moe_capacity_factor=1.0)
    spec_c = dataclasses.replace(spec, make_config=lambda s=None, c=cfg_c: c)
    out.append(_measure(build_cell(spec_c, shape, mesh), mesh, "t1-c groups=16 + cf=1.0"))
    return out


# ================================================ target 2: granite peak mem
def target2_granite() -> list[dict]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_arch
    from repro.launch import shardings as sh
    from repro.launch.mesh import data_axes, make_production_mesh
    from repro.launch.steps import build_cell, _lm_cell
    from repro.train.optimizer import adamw

    mesh = make_production_mesh()
    spec = get_arch("granite-34b")
    shape = spec.shapes["train_4k"]
    out = []
    print("[T2] granite-34b × train_4k (memory-bound, 42.9 GB/device peak)")
    out.append(_measure(build_cell(spec, shape, mesh), mesh, "t2-baseline"))

    print("  hypothesis A: peak is dominated by saved per-layer activations"
          " (88 layers × B·S·D ≈ 88×16×4096×6144×2B/16TP ≈ 33 GB/dev);"
          " remat on the layer scan should cut peak to O(1 layer) + params"
          " at ~+30% recompute FLOPs.")
    cfg_r = dataclasses.replace(spec.make_config(shape), remat=True)
    spec_r = dataclasses.replace(spec, make_config=lambda s=None, c=cfg_r: c)
    out.append(_measure(build_cell(spec_r, shape, mesh), mesh, "t2-a remat"))

    print("  hypothesis B: microbatching (8×) shrinks live activations"
          " another 8× at constant math; combined with remat the step should"
          " fit 16 GB with headroom.")
    from repro.models.transformer_lm import lm_loss, lm_param_shapes

    cfg = cfg_r
    da = data_axes(mesh)
    policy = sh.lm_policy(mesh, cfg)
    params_abs = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16), lm_param_shapes(cfg)
    )
    p_specs = sh.lm_param_specs(params_abs, cfg, mesh)
    p_shard = sh.tree_named(mesh, p_specs)
    opt = adamw(3e-4)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_shard = sh.tree_named(mesh, {"m": p_specs, "v": p_specs, "step": P()})
    ACC = 8
    B = shape.global_batch

    def train_step_accum(params, opt_state, tokens):
        mb = tokens.reshape(ACC, B // ACC, shape.seq_len + 1)

        def micro(carry, t):
            loss, acc = carry
            l, g = jax.value_and_grad(lm_loss)(params, t, cfg, policy)
            return (loss + l, jax.tree_util.tree_map(jnp.add, acc, g)), None

        zero = jax.tree_util.tree_map(jnp.zeros_like, params)
        (loss, grads), _ = jax.lax.scan(micro, (0.0, zero), mb)
        grads = jax.tree_util.tree_map(lambda g: g / ACC, grads)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss / ACC

    from repro.launch.steps import Cell, _sds

    tokens = _sds((B, shape.seq_len + 1), jnp.int32)
    base = build_cell(spec_r, shape, mesh)      # reuse cost cells for costing
    cell = Cell(
        spec.arch_id, shape.name, "train_step", train_step_accum,
        (params_abs, opt_abs, tokens),
        (p_shard, o_shard, sh.named(mesh, P(da, None))),
        (p_shard, o_shard, sh.named(mesh, P())),
        model_flops=base.model_flops,
        cost_cells=base.cost_cells,
        cost_groups=base.cost_groups,
    )
    out.append(_measure(cell, mesh, "t2-b remat + 8x microbatch"))

    print("  hypothesis C: peak_bytes on this backend = arguments + outputs"
          " (params/opt counted twice without aliasing); donating params &"
          " opt state (the in-place update a real deployment uses) should"
          " remove the output copy: predicted peak 42.9 → ~18 GB.")
    donated = dataclasses.replace(base, donate_argnums=(0, 1))
    out.append(_measure(donated, mesh, "t2-c remat + donation"))
    return out


# =========================================== target 3: PNA broadcast → halo
def _pna_halo_cell(mesh, plan, cfg, shape, compute_dtype=None, payload=None):
    """Train cell for PNA over the halo plan (shard_map core).

    compute_dtype=bf16 (t3-b) casts features/messages for the exchange and
    the edge math — halves both the wire bytes and the dominant (E, ·)
    intermediate traffic; params/optimizer stay fp32. payload="bf16"/"int8"
    (t3-c) instead quantizes ONLY the wire (dequantized on receive,
    repro.core.quant payloads) — compute stays at compute_dtype."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist.policy import NO_POLICY
    from repro.graph.ops import multi_aggregate_edges
    from repro.launch import shardings as sh
    from repro.launch.steps import Cell, _gnn_params, _sds
    from repro.nn.layers import linear
    from repro.train.optimizer import adamw

    cd = compute_dtype or jnp.float32
    k = plan.k
    params_abs = _gnn_params("pna", cfg, jnp.float32)
    p_specs = sh.replicated_specs(params_abs)
    p_shard = sh.tree_named(mesh, p_specs)
    opt = adamw(1e-3)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_shard = sh.tree_named(mesh, {"m": p_specs, "v": p_specs, "step": P()})
    si, sl, rl, ew = plan.abstract_inputs()
    batch_abs = {
        "feats": _sds((k, plan.n_local, cfg.d_in), jnp.float32),
        "send_idx": si,
        "senders": sl,
        "receivers": rl,
        "edge_w": ew,
        "target": _sds((k, plan.n_local, cfg.d_out), jnp.float32),
    }
    b_shard = jax.tree_util.tree_map(
        lambda l: sh.named(mesh, P("model", *([None] * (len(l.shape) - 1)))), batch_abs
    )

    from repro.dist.halo import halo_exchange

    def device_forward(params, feats, send_idx, senders, receivers, edge_w, target):
        # One device's block (leading axis 1 stripped by shard_map).
        params = jax.tree_util.tree_map(lambda p: p.astype(cd), params)
        feats = feats.astype(cd)
        h = jax.nn.relu(linear(params["enc"], feats))
        deg = jax.ops.segment_sum(
            (edge_w > 0).astype(jnp.float32), receivers, num_segments=plan.n_local
        )
        logd = jnp.log1p(deg)[:, None]
        amp = logd / cfg.mean_log_degree
        att = cfg.mean_log_degree / jnp.maximum(logd, 1e-6)
        for i in range(cfg.n_layers):
            halo = halo_exchange(h, send_idx, "model", payload=payload)
            full = jnp.concatenate([h, halo], axis=0)
            msg_in = jnp.concatenate([full[senders], h[receivers]], axis=-1)
            msg = jax.nn.relu(linear(params[f"pre{i}"], msg_in)) * (edge_w > 0)[:, None]
            aggs = multi_aggregate_edges(msg, receivers, plan.n_local)
            feats_cat = [h]
            for a in ("mean", "max", "min", "std"):
                v = aggs[a]
                feats_cat += [v, v * amp, v * att]
            h = h + jax.nn.relu(linear(params[f"post{i}"], jnp.concatenate(feats_cat, -1)))
        pred = linear(params["dec"], h)
        loss = jnp.mean(jnp.square(pred.astype(jnp.float32) - target))
        return jax.lax.pmean(loss, "model")

    def loss_fn(params, batch):
        f = jax.shard_map(
            lambda fe, si, sl, rl, ew, tg: device_forward(
                params, fe[0], si[0], sl[0], rl[0], ew[0], tg[0]
            )[None],
            mesh=mesh,
            in_specs=(P("model"),) * 6,
            out_specs=P("model"),
        )
        losses = f(batch["feats"], batch["send_idx"], batch["senders"],
                   batch["receivers"], batch["edge_w"], batch["target"])
        return losses.mean()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    return Cell(
        "pna", shape.name, "train_step", train_step,
        (params_abs, opt_abs, batch_abs),
        (p_shard, o_shard, b_shard),
        (p_shard, o_shard, sh.named(mesh, P())),
        model_flops=0.0,
        note=f"halo s_max={plan.s_max} n_local={plan.n_local}"
        + (f" payload={payload}" if payload else ""),
        halo_plan=plan,
        halo_payload=payload,
    )


def target3_pna() -> list[dict]:
    import numpy as np

    from repro.configs import get_arch
    from repro.dist.halo import HaloPlan, cached_halo_plan
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell, _gnn_flops

    mesh = make_production_mesh()
    spec = get_arch("pna")
    shape = spec.shapes["ogb_products"]
    out = []
    print("[T3] pna × ogb_products (paper-representative: exchange schedule)")
    print("  NOTE: since PR 2 the halo exchange IS the build_cell default for"
          " full-graph GNN cells (DESIGN.md §8), so the baseline below is the"
          " halo schedule and the comparison point is the comm='broadcast'"
          " escape hatch (the pre-PR2 default, paper Fig. 5c).")
    # The in-memory plan cache dies with the process; for this 61.9M-edge
    # plan (minutes of BFS+refine), persist it and pre-seed the cache so
    # repeat runs load in seconds. The key matches steps._shape_halo_plan.
    plan_path = "results/halo_plan_ogb.npz"
    plan_key = f"citation_like:n{shape.n_nodes}:e{shape.n_edges}:seed0"
    if os.path.exists(plan_path):
        z = np.load(plan_path)
        if "part_sizes" in z:                      # pre-PR2 files lack it
            loaded = HaloPlan(
                k=int(z["k"]), n_local=int(z["n_local"]), s_max=int(z["s_max"]),
                e_local=int(z["e_local"]), n_nodes=int(z["n_nodes"]), perm=z["perm"],
                send_idx=z["send_idx"], senders_l=z["senders_l"],
                receivers_l=z["receivers_l"], edge_w=z["edge_w"],
                part_sizes=z["part_sizes"],
            )
            cached_halo_plan(plan_key, mesh.shape["model"], builder=lambda: loaded)
    t0 = time.time()
    cell = build_cell(spec, shape, mesh)                 # default = halo
    plan = cell.halo_plan
    if not os.path.exists(plan_path):
        os.makedirs(os.path.dirname(plan_path), exist_ok=True)
        np.savez_compressed(
            plan_path, k=plan.k, n_local=plan.n_local, s_max=plan.s_max,
            e_local=plan.e_local, n_nodes=plan.n_nodes, perm=plan.perm,
            send_idx=plan.send_idx, senders_l=plan.senders_l,
            receivers_l=plan.receivers_l, edge_w=plan.edge_w,
            part_sizes=plan.part_sizes,
        )
    print(f"  plan ready in {time.time()-t0:.0f}s: s_max={plan.s_max} "
          f"n_local={plan.n_local} wire_fraction={plan.wire_fraction():.4f}")
    rec = _measure(cell, mesh, "t3-baseline halo (the new default)")
    rec["plan"] = {"s_max": plan.s_max, "n_local": plan.n_local,
                   "wire_fraction": plan.wire_fraction()}
    out.append(rec)

    print("  comparison: the broadcast all-gather ships (k−1)/k·N·d per layer;"
          " the halo default ships only the per-pair boundary sources (the"
          " quantity COIN's Eq. 2 minimizes). Expect the collective term to"
          " blow back up under comm='broadcast'.")
    out.append(_measure(
        build_cell(spec, shape, mesh, comm="broadcast"), mesh,
        "t3-a broadcast escape hatch (pre-PR2 default)",
    ))

    print("  iteration: the halo default killed the collective term but the"
          " memory term now dominates ((E,2d) message tiles fully local)."
          " hypothesis: bf16 edge math halves the dominant intermediate"
          " traffic at harmless precision for message passing.")
    import jax.numpy as jnp

    cfg = spec.make_config(shape)
    cell_b = _pna_halo_cell(mesh, plan, cfg, shape, compute_dtype=jnp.bfloat16)
    cell_b.model_flops = _gnn_flops("pna", shape, cfg) * 3.0
    out.append(_measure(cell_b, mesh, "t3-b halo + bf16 edge math"))

    print("  iteration: the residual collective term is the per-layer halo"
          " gather itself. hypothesis: quantizing just the WIRE to bf16"
          " (dequantized on receive, repro.core.quant payloads) halves the"
          " exchange bytes without touching the fp32 edge math — and the"
          " overlapped schedule hides the rest behind interior aggregation"
          " (docs/communication.md 'Overlapped schedule').")
    from repro.core.dataflow import exchange_cost

    d = shape.d_feat or cfg.d_in
    for bits, tag in ((32, "fp32"), (16, "bf16")):
        ec = exchange_cost(plan.halo_rows_per_device, d, bits, plan.overlap_fraction())
        print(f"  exchange model [{tag}]: wire={ec.wire_bytes/1e6:.1f}MB/layer"
              f" exposed={ec.exposed_bytes/1e6:.1f}MB/layer"
              f" (overlap_fraction={plan.overlap_fraction():.3f},"
              f" compression={ec.compression:.0f}x)")
    cell_c = _pna_halo_cell(mesh, plan, cfg, shape, payload="bf16")
    cell_c.model_flops = _gnn_flops("pna", shape, cfg) * 3.0
    rec_c = _measure(cell_c, mesh, "t3-c halo + bf16 wire payload")
    ec = exchange_cost(plan.halo_rows_per_device, d, 16, plan.overlap_fraction())
    rec_c["exchange_model"] = {
        "wire_bytes_per_layer": ec.wire_bytes,
        "exposed_bytes_per_layer": ec.exposed_bytes,
        "overlap_fraction": ec.overlap_fraction,
        "compression": ec.compression,
    }
    out.append(rec_c)
    return out


# ===================================== stretch: gemma3 long-context KV cache
def _gemma_twostack_cell(mesh, spec, shape, ring: bool = False):
    """Decode step where the 40 local layers read only their 1024-token
    window (the 8 global layers still read all 524k), in the exact
    5-local+1-global interleaved order.

    ring=False — windows via dynamic_slice of the SHARDED full cache
      (t4-a; refuted: XLA must replicate across the 256-way seq sharding).
    ring=True  — local layers keep a separate REPLICATED W-slot ring buffer
      (slot = pos mod W; validity mask derived from pos, no stored
      positions needed); only global layers keep the sharded 524k cache
      (t4-b). Ring bytes: 40·1024·8·240·2·2B ≈ 315 MB replicated."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.launch import shardings as sh
    from repro.launch.steps import Cell, _abstract_tree, _sds
    from repro.models.transformer_lm import lm_init_cache, lm_param_shapes, _ffn
    from repro.nn.attention import rope
    from repro.nn.layers import rms_norm

    cfg = spec.make_config(shape)
    W = cfg.window                      # 1024, static
    period = cfg.global_every           # 6
    n_groups = cfg.n_layers // period   # 8
    acfg = cfg.attn
    hd, Hk, G = acfg.head_dim, cfg.n_kv_heads, acfg.q_groups
    B, S = shape.global_batch, shape.seq_len
    policy = sh.lm_policy(mesh, cfg)

    params_abs = jax.tree_util.tree_map(
        lambda l: _sds(l.shape, jnp.bfloat16), lm_param_shapes(cfg)
    )
    p_specs = sh.lm_param_specs(params_abs, cfg, mesh)
    p_shard = sh.tree_named(mesh, p_specs)
    n_local = period - 1
    cspec_full = sh.cache_spec(cfg, shape, mesh)
    if ring:
        cache_abs = {
            "k": _sds((n_groups, B, S, Hk, hd), jnp.bfloat16),      # globals only
            "v": _sds((n_groups, B, S, Hk, hd), jnp.bfloat16),
            "rk": _sds((n_groups, n_local, B, W, Hk, hd), jnp.bfloat16),
            "rv": _sds((n_groups, n_local, B, W, Hk, hd), jnp.bfloat16),
        }
        c_shard = {
            "k": sh.named(mesh, P(None, None, ("data", "model"), None, None)),
            "v": sh.named(mesh, P(None, None, ("data", "model"), None, None)),
            "rk": sh.named(mesh, P()),                               # replicated ring
            "rv": sh.named(mesh, P()),
        }
    else:
        cache_abs = _abstract_tree(jax.eval_shape(lambda: lm_init_cache(cfg, B, S, jnp.bfloat16)))
        c_shard = jax.tree_util.tree_map(lambda _: sh.named(mesh, cspec_full), cache_abs)

    def attend(q, ck, cv, k_pos, pos, win):
        # q: (B, H, hd); ck/cv: (B, L, Hk, hd); k_pos: (L,) absolute positions.
        qg = q.reshape(B, Hk, G, hd) * (hd ** -0.5)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, ck, preferred_element_type=jnp.float32)
        valid = (k_pos <= pos) & (k_pos > pos - win) & (k_pos >= 0)
        s = jnp.where(valid[None, None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgs,bshd->bhgd", w.astype(cv.dtype), cv).reshape(B, 1, Hk * G * hd)

    def qkv(lp, x, pos):
        h = rms_norm(x, lp["ln1"])
        q = rope((h @ lp["attn"]["wq"]).reshape(B, 1, cfg.n_heads, hd), pos[None], acfg.rope_theta)
        k = rope((h @ lp["attn"]["wk"]).reshape(B, 1, Hk, hd), pos[None], acfg.rope_theta)
        v = (h @ lp["attn"]["wv"]).reshape(B, 1, Hk, hd)
        return q, k, v

    def finish_layer(x, lp, attn):
        x = x + attn @ lp["attn"]["wo"]
        h2 = rms_norm(x, lp["ln2"])
        f, _ = _ffn(lp, h2, cfg, policy)
        return x + f

    def local_layer(x, lp, rk, rv, pos):
        q, k, v = qkv(lp, x, pos)
        slot = pos % W
        rk = jax.lax.dynamic_update_slice(rk, k, (0, slot, 0, 0))
        rv = jax.lax.dynamic_update_slice(rv, v, (0, slot, 0, 0))
        # Slot j holds absolute position pos − ((pos − j) mod W); always
        # inside the window, invalid only before warmup (p_j < 0).
        j = jnp.arange(W)
        k_pos = pos - ((pos - j) % W)
        attn = attend(q[:, 0], rk, rv, k_pos, pos, W)
        return finish_layer(x, lp, attn), rk, rv

    def global_layer(x, lp, ck, cv, pos):
        q, k, v = qkv(lp, x, pos)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, pos, 0, 0))
        attn = attend(q[:, 0], ck, cv, jnp.arange(S), pos, S + 1)
        return finish_layer(x, lp, attn), ck, cv

    def sliced_layer(x, lp, ck, cv, pos):
        """t4-a variant: window via dynamic_slice of the sharded full cache."""
        q, k, v = qkv(lp, x, pos)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, pos, 0, 0))
        start = jnp.clip(pos - W + 1, 0, S - W)
        ck_s = jax.lax.dynamic_slice(ck, (0, start, 0, 0), (B, W, Hk, hd))
        cv_s = jax.lax.dynamic_slice(cv, (0, start, 0, 0), (B, W, Hk, hd))
        attn = attend(q[:, 0], ck_s, cv_s, start + jnp.arange(W), pos, W)
        return finish_layer(x, lp, attn), ck, cv

    def decode_step(params, cache, token, pos):
        x = params["embed"][token][:, None, :] * (cfg.d_model ** 0.5)
        grp = jax.tree_util.tree_map(
            lambda l: l.reshape(n_groups, period, *l.shape[1:]), params["layers"]
        )
        if ring:
            carry_xs = (grp, cache["k"], cache["v"], cache["rk"], cache["rv"])
        else:
            ck_g = cache["k"].reshape(n_groups, period, B, S, Hk, hd)
            cv_g = cache["v"].reshape(n_groups, period, B, S, Hk, hd)
            carry_xs = (grp, ck_g, cv_g)

        def group(x, xs):
            if ring:
                gp, gk, gv, rk, rv = xs
                new_rk, new_rv = [], []
                for i in range(n_local):
                    lp = jax.tree_util.tree_map(lambda l: l[i], gp)
                    x, k_i, v_i = local_layer(x, lp, rk[i], rv[i], pos)
                    new_rk.append(k_i)
                    new_rv.append(v_i)
                lp = jax.tree_util.tree_map(lambda l: l[n_local], gp)
                x, gk, gv = global_layer(x, lp, gk, gv, pos)
                return x, (gk, gv, jnp.stack(new_rk), jnp.stack(new_rv))
            gp, ck, cv = xs
            new_k, new_v = [], []
            for i in range(period):
                lp = jax.tree_util.tree_map(lambda l: l[i], gp)
                fn = sliced_layer if i < period - 1 else global_layer
                x, k_i, v_i = fn(x, lp, ck[i], cv[i], pos)
                new_k.append(k_i)
                new_v.append(v_i)
            return x, (jnp.stack(new_k), jnp.stack(new_v))

        x, outs = jax.lax.scan(group, x, carry_xs)
        x = rms_norm(x, params["final_norm"])
        logits = (x[:, 0] @ params["embed"].T).astype(jnp.float32)
        if ring:
            gk, gv, rk, rv = outs
            new_cache = {"k": gk, "v": gv, "rk": rk, "rv": rv}
        else:
            nk, nv = outs
            new_cache = {"k": nk.reshape(cfg.n_layers, B, S, Hk, hd),
                         "v": nv.reshape(cfg.n_layers, B, S, Hk, hd)}
        return logits, new_cache

    token = _sds((B,), jnp.int32)
    pos = _sds((), jnp.int32)
    return Cell(
        "gemma3-12b", shape.name, "serve_step", decode_step,
        (params_abs, cache_abs, token, pos),
        (p_shard, c_shard, sh.named(mesh, P()), sh.named(mesh, P())),
        (sh.named(mesh, P(None, "model")), c_shard),
        model_flops=2.0 * cfg.active_param_count() * B,
        note="two-stack sliding decode" + (" (ring)" if ring else " (slice)"),
    )


def target4_gemma_cache() -> list[dict]:
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    mesh = make_production_mesh()
    spec = get_arch("gemma3-12b")
    shape = spec.shapes["long_500k"]
    out = []
    print("[T4] gemma3-12b × long_500k (sliding-window cache reads)")
    out.append(_measure(build_cell(spec, shape, mesh), mesh, "t4-baseline uniform reads"))
    print("  hypothesis: the baseline decode reads the full 524k cache in all"
          " 48 layers; only the 8 global layers need it — slicing the 40"
          " local layers to their 1024-token window cuts cache-read bytes to"
          " (8·524288 + 40·1024)/(48·524288) ≈ 17% → predicted ~6× lower"
          " memory term (the dominant term for this cell).")
    out.append(_measure(_gemma_twostack_cell(mesh, spec, shape), mesh, "t4-a two-stack sliced reads"))
    print("  iteration: t4-a REFUTED the slicing route — dynamic_slice across"
          " the 256-way sequence sharding forces XLA to replicate the cache"
          " (involuntary-remat warning), blowing the collective term up."
          " t4-b keeps a separate REPLICATED 1024-slot ring per local layer"
          " (315 MB total, slot = pos mod W): no cross-shard slicing at all.")
    out.append(_measure(
        _gemma_twostack_cell(mesh, spec, shape, ring=True), mesh,
        "t4-b local ring buffers (replicated)",
    ))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="all", choices=["1", "2", "3", "4", "all"])
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args(argv)
    targets = {
        "1": [target1_moe], "2": [target2_granite], "3": [target3_pna],
        "4": [target4_gemma_cache],
        "all": [target1_moe, target2_granite, target3_pna, target4_gemma_cache],
    }[args.target]
    try:
        with open(args.out) as f:
            records = json.load(f)
    except FileNotFoundError:
        records = {}
    for t in targets:
        recs = t()
        records[t.__name__] = recs
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1, default=str)
    return 0


if __name__ == "__main__":
    sys.exit(main())
