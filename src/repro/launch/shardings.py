"""Per-family sharding rules over the production mesh (DESIGN.md §6).

Rules are path-based over the param pytrees and return NamedShardings; the
activation-side policies built here are the name→PartitionSpec contract of
DESIGN.md §7.1 (`repro.dist.policy.ShardingPolicy`). Defaults encode the
COIN-derived plan:

  LM     — Megatron TP over `model` (QKV/up column-, O/down row-parallel),
           vocab-sharded embedding/logits, expert-parallel MoE weights,
           batch over (pod, data).
  GNN    — node/edge arrays sharded over `model` (the CE partition);
           params replicated (tiny); sampled cells batch blocks over
           (pod, data).
  recsys — embedding table row-sharded over `model` (the COIN adjacency-
           slice analogue); batch over (pod, data); MLP replicated.

KV caches shard over kv-heads when divisible by the model axis, otherwise
over sequence (the long-context path; batch 1 cells shard sequence over
every available axis).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.policy import ShardingPolicy
from repro.launch.mesh import data_axes

__all__ = [
    "lm_param_specs",
    "lm_policy",
    "gnn_policy",
    "recsys_policy",
    "replicated_specs",
    "recsys_param_specs",
    "cache_spec",
    "named",
    "tree_named",
]


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _model_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names if a == "model"]))


# ------------------------------------------------------------------------ LM
def lm_param_specs(param_tree: Any, cfg, mesh) -> Any:
    """PartitionSpec pytree mirroring the LM param pytree."""
    msize = mesh.shape["model"]
    # Shard K/V projections only when kv-heads split cleanly across the model
    # axis; otherwise replicate (they are small: D × Hk·hd with Hk ∈ {1, 8}).
    kv_shardable = cfg.n_kv_heads % msize == 0

    def rule(path, leaf) -> P:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = "/".join(str(k) for k in keys)
        nd = len(leaf.shape)
        if "embed" in name or "lm_head" in name:
            return P("model", None) if "embed" in name else P(None, "model")
        if name.endswith("wq"):
            return P(None, None, "model")
        if name.endswith("wk") or name.endswith("wv"):
            return P(None, None, "model") if kv_shardable else P(None, None, None)
        if name.endswith("wo"):
            return P(None, "model", None)
        if "mlp" in name and name.endswith("w_down"):
            return P(None, "model", None)
        if "mlp" in name and ("w_gate" in name or "w_up" in name):
            return P(None, None, "model")
        if "moe" in name and "router" in name:
            return P(None, None, None)
        if "moe" in name and nd == 4:          # (L, E, D, F): expert parallel
            return P(None, "model", None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, param_tree)


def lm_policy(mesh, cfg) -> ShardingPolicy:
    da = data_axes(mesh)
    return ShardingPolicy(
        mesh=mesh,
        specs={
            "act": P(da, None, None),
            "ffn_hidden": P(da, None, "model"),
            "logits": P(da, None, "model"),
            "dec_act": P(da, None, None),
            "dec_logits": P(da, "model"),
            # (groups, E, C, D) dispatch buffer: groups follow the data axes,
            # experts the model axis → the EP all-to-all boundary.
            "moe_buf": P(da, "model", None, None),
        },
    )


def cache_spec(cfg, shape_spec, mesh) -> P:
    """KV cache (L, B, S, Hk, Dh) sharding for decode cells."""
    da = data_axes(mesh)
    msize = mesh.shape["model"]
    batch = shape_spec.global_batch
    n_data = int(np.prod([mesh.shape[a] for a in da]))
    if batch is not None and batch >= n_data and batch % n_data == 0:
        batch_axes = da
        if cfg.n_kv_heads % msize == 0:
            return P(None, batch_axes, None, "model", None)
        return P(None, batch_axes, "model", None, None)     # sequence-sharded
    # batch too small (long-context, batch 1): shard sequence over everything.
    all_axes = tuple(mesh.axis_names)
    return P(None, None, all_axes, None, None)


# ----------------------------------------------------------------------- GNN
def replicated_specs(param_tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda leaf: P(*([None] * len(leaf.shape))), param_tree)


def gnn_policy(
    mesh, batched: bool, comm: str = "halo",
    halo_payload: str | None = None, halo_overlap: bool = True,
) -> ShardingPolicy:
    """GNN activation policy. ``comm`` selects the full-graph communication
    schedule (DESIGN.md §8): "halo" (default — boundary-only exchange over a
    HaloPlan, inside shard_map) or "broadcast" (the paper's Fig. 5c layer-
    output all-gather via pjit sharding propagation, kept as the escape
    hatch). On a mesh with a ``pod`` tier the halo policy carries
    ``halo_axes=("pod", "model")`` so ``neighbor_table`` runs the two-phase
    hierarchical exchange (docs/communication.md). ``halo_payload`` selects
    the wire format (bf16/int8 quantized payloads, dequantized on receive)
    and ``halo_overlap`` the interior/boundary-split schedule that hides the
    collective behind interior aggregation — both per docs/communication.md
    "Overlapped schedule". Batched (sampled-block) cells have no cross-shard
    edges, so the mode is irrelevant there."""
    from repro.launch.mesh import halo_axes

    da = data_axes(mesh)
    if batched:
        return ShardingPolicy(
            mesh=mesh,
            specs={
                "node_hidden": P(da, None, None),
                "edge_hidden": P(da, None, None),
                "irrep_hidden": P(da, None, None, None),
            },
        )
    if comm not in ("halo", "broadcast"):
        raise ValueError(f"unknown comm mode {comm!r} (expected 'halo' or 'broadcast')")
    if comm == "halo":
        # Inside shard_map the per-device block is unsharded; constrain calls
        # are no-ops (no registered names) and the exchange is explicit.
        ha = halo_axes(mesh)
        return ShardingPolicy(
            mesh=mesh, specs={}, comm="halo", halo_axis="model",
            halo_axes=ha if len(ha) > 1 else None,
            halo_payload=halo_payload, halo_overlap=halo_overlap,
        )
    return ShardingPolicy(
        mesh=mesh,
        specs={
            "node_hidden": P("model", None),
            "edge_hidden": P("model", None),
            "irrep_hidden": P("model", None, None),
        },
    )


# -------------------------------------------------------------------- recsys
def recsys_param_specs(param_tree: Any) -> Any:
    def rule(path, leaf) -> P:
        name = "/".join(str(getattr(p, "key", "")) for p in path)
        nd = len(leaf.shape)
        if "table" in name:
            return P("model", None)
        if "w_linear" in name:
            return P("model")
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, param_tree)


def recsys_policy(mesh) -> ShardingPolicy:
    da = data_axes(mesh)
    return ShardingPolicy(
        mesh=mesh,
        specs={"emb": P(da, None, None), "cand": P(None, "model", None)},
    )
