"""Training driver: ``--arch <id>`` selects any assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch pna --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --steps 20

Runs the REDUCED config on the local device(s) — the full configs are
exercised by the dry-run (`repro.launch.dryrun`) and, on real hardware, by
pointing `make_production_mesh` at the pod. The driver wires the complete
substrate: synthetic data stream → jitted train step → AdamW → checkpointing
→ straggler monitor, and resumes from the latest checkpoint on restart.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_arch
from repro.launch.obsflags import add_obs_args, obs_session
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import adamw


def _lm_setup(spec, batch=4, seq=64):
    from repro.models.transformer_lm import lm_init, lm_loss
    from repro.train.data import ShardedStream, token_batch_fn

    cfg = spec.make_reduced()
    params = lm_init(jax.random.PRNGKey(0), cfg)
    stream = ShardedStream(token_batch_fn(cfg.vocab, seq), global_batch=batch, seed=0)

    def batches():
        for b in stream:
            yield jnp.asarray(b)

    return params, (lambda p, b: lm_loss(p, b, cfg)), batches


def _gnn_setup(spec, relocalize_threshold: float = 0.0):
    from repro.graph.generators import citation_like
    from repro.launch.steps import _gnn_loss_fn
    from repro.dist.policy import NO_POLICY

    cfg = spec.make_reduced()
    d_in = getattr(cfg, "d_in", None) or getattr(cfg, "input_dim", 8)
    g = citation_like(256, 1024, seed=0)
    rng = np.random.default_rng(0)
    if spec.arch_id == "coin_gcn":
        d_in = cfg.layer_dims[0]
    base = {
        "feats": jnp.asarray(rng.standard_normal((g.n_nodes, d_in)), jnp.float32),
        "senders": jnp.asarray(g.edge_index[0]),
        "receivers": jnp.asarray(g.edge_index[1]),
    }
    if spec.arch_id in ("egnn", "equiformer-v2"):
        base["pos"] = jnp.asarray(rng.standard_normal((g.n_nodes, 3)), jnp.float32)
    if spec.arch_id == "graphcast":
        base["edge_feats"] = jnp.asarray(rng.standard_normal((g.n_edges, cfg.d_edge_in)), jnp.float32)
    if spec.arch_id == "coin_gcn":
        base["edge_weight"] = jnp.ones(g.n_edges)
        base["labels"] = jnp.asarray(g.labels)
        base["label_mask"] = jnp.ones(g.n_nodes)
    else:
        n_out = cfg.n_vars if spec.arch_id == "graphcast" else cfg.d_out
        base["target"] = jnp.asarray(rng.standard_normal((g.n_nodes, n_out)) * 0.1, jnp.float32)
    from repro.launch.steps import _gnn_params  # params via real init

    loss = _gnn_loss_fn(spec.arch_id, cfg, NO_POLICY)
    params = _init_gnn(spec.arch_id, cfg)

    if relocalize_threshold <= 0:
        def batches():
            while True:
                yield base

        return params, loss, batches

    # --relocalize-threshold: churn the training graph while a
    # drift-triggered RelocalizePolicy maintains the planner's locality
    # order online (docs/communication.md §8). Edge COUNT stays constant
    # (delete m, insert m) so the jitted step never retraces.
    from repro.core.partition import partition_graph
    from repro.dist.delta import DeltaPlanner, GraphDelta, RelocalizePolicy

    part = partition_graph(g.n_nodes, g.edge_index, 4, "bfs", seed=0, refine=True)
    planner = DeltaPlanner(
        part, g.edge_index, graph_key=f"launch-train-{spec.arch_id}",
        relocalize_policy=RelocalizePolicy(
            threshold=relocalize_threshold, patience=2, cooldown=3))
    churn = np.random.default_rng(1)

    def batches():
        step = 0
        while True:
            yield base
            step += 1
            if step % 10:
                continue
            ei = planner.edge_index()
            m = max(ei.shape[1] // 100, 2)
            drop = churn.choice(ei.shape[1], m, replace=False)
            mem = churn.choice(g.n_nodes, 16, replace=False)
            s = mem[churn.integers(0, mem.size, m)]
            d = mem[churn.integers(0, mem.size, m)]
            bad = s == d
            d[bad] = mem[(np.searchsorted(np.sort(mem), d[bad]) + 1) % mem.size]
            rep = planner.apply(GraphDelta(
                edge_inserts=np.stack([s, d]), edge_deletes=ei[:, drop]))
            if rep["relocalized"] is not None:
                r = rep["relocalized"]
                print(f"  relocalize @ step {step}: executed tiles "
                      f"{r['executed_tiles_before']} → {r['executed_tiles_after']}")
            new_ei = planner.edge_index()
            base["senders"] = jnp.asarray(new_ei[0].astype(np.int32))
            base["receivers"] = jnp.asarray(new_ei[1].astype(np.int32))
            if "edge_weight" in base:
                base["edge_weight"] = jnp.asarray(planner.edge_weights())

    return params, loss, batches


def _init_gnn(arch_id, cfg):
    key = jax.random.PRNGKey(0)
    if arch_id == "egnn":
        from repro.models.egnn import egnn_init

        return egnn_init(key, cfg)
    if arch_id == "graphcast":
        from repro.models.graphcast import graphcast_init

        return graphcast_init(key, cfg)
    if arch_id == "equiformer-v2":
        from repro.models.equiformer_v2 import equiformer_init

        return equiformer_init(key, cfg)
    if arch_id == "pna":
        from repro.models.pna import pna_init

        return pna_init(key, cfg)
    from repro.models.gcn import gcn_init

    return gcn_init(key, cfg)


def _recsys_setup(spec, batch=256):
    from repro.models.deepfm import deepfm_init, deepfm_loss
    from repro.train.data import ShardedStream, click_batch_fn

    cfg = spec.make_reduced()
    params = deepfm_init(jax.random.PRNGKey(0), cfg)
    stream = ShardedStream(
        click_batch_fn(cfg.n_fields, cfg.rows_per_field), global_batch=batch, seed=0
    )

    def batches():
        for b in stream:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    return params, (lambda p, b: deepfm_loss(p, b["ids"], b["labels"], cfg)), batches


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--relocalize-threshold", type=float, default=0.0,
                    help="drift ratio beyond which the churned training graph "
                         "re-localizes online (0 = static graph; gnn only)")
    add_obs_args(ap)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    setup = {"lm": _lm_setup, "gnn": _gnn_setup, "recsys": _recsys_setup}[spec.family]
    with obs_session(args):
        if spec.family == "gnn":
            params, loss_fn, batches = _gnn_setup(
                spec, relocalize_threshold=args.relocalize_threshold)
        else:
            params, loss_fn, batches = setup(spec)
        tr = Trainer(
            loss_fn,
            adamw(args.lr),
            params,
            TrainerConfig(
                ckpt_dir=args.ckpt_dir, log_every=10, compress_grads=args.compress_grads
            ),
        )
        if args.ckpt_dir:
            tr.resume()
        losses = tr.fit(batches(), max_steps=args.steps)
        print(f"{args.arch}: loss {losses[0]:.4f} → {losses[-1]:.4f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
