"""Shared ``--trace`` / ``--metrics`` wiring for the launch drivers.

Every `repro.launch` entrypoint (train, serve, dryrun) and the distributed
example accept the same two flags:

    --metrics out.json   enable `repro.obs.metrics`, write the deterministic
                         registry snapshot on exit
    --trace out.json     enable `repro.obs.trace`, write Chrome trace-event
                         JSON (load in chrome://tracing or ui.perfetto.dev)

`add_obs_args` registers the flags; `obs_session` is a context manager that
enables whichever were requested, runs the driver body, and exports on the
way out (also on exceptions — a crashing run still leaves its telemetry).
Neither flag given → everything stays on the disabled fast path.
"""
from __future__ import annotations

import contextlib

from repro.obs import metrics, trace

__all__ = ["add_obs_args", "obs_session"]


def add_obs_args(ap) -> None:
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="enable the metrics registry; write its snapshot here on exit")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="enable span tracing; write Chrome trace-event JSON here on exit")


@contextlib.contextmanager
def obs_session(args):
    """Enable obs per the parsed ``args``; export to the given paths on exit."""
    if getattr(args, "metrics", None):
        metrics.enable(metrics.MetricsRegistry())
    if getattr(args, "trace", None):
        trace.set_default_tracer(trace.TraceRecorder())
    try:
        yield
    finally:
        if getattr(args, "metrics", None):
            metrics.to_json(args.metrics)
            print(f"metrics snapshot → {args.metrics}")
        if getattr(args, "trace", None):
            trace.export(args.trace)
            print(f"chrome trace → {args.trace} (open in ui.perfetto.dev)")
