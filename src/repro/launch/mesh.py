"""Production mesh construction (multi-pod dry-run contract, DESIGN.md §6).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. Single-pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model); `pod` composes with
`data` for gradient reduction / replica serving.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "data_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch-carrying axes: ('pod','data') on the multi-pod mesh."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)
