"""Production mesh construction (multi-pod dry-run contract, DESIGN.md §6).

FUNCTIONS, not module-level constants — importing this module never touches
jax device state. Single-pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model); `pod` composes with
`data` for gradient reduction / replica serving, and with `model` for the
hierarchical (pod, model) halo exchange of full-graph GNN cells
(docs/communication.md).
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "make_halo_mesh",
    "data_axes",
    "halo_axes",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_halo_mesh(pods: int, devices_per_pod: int, *, pod_map=None):
    """2-D (pod, model) mesh for hierarchical halo exchange — e.g. the
    8-device 2×4 acceptance mesh. Devices are raveled pod-major, matching
    the device→(pod, member) grouping ``build_halo_plan`` assumes.

    pod_map — optional autotuned part→pod assignment (the
    ``repro.core.autotune`` quotient mapper). Validated here for balance,
    but REALIZED by the plan, not the mesh: ``build_halo_plan(...,
    pod_map=...)`` relabels parts into pod-major device slots, so the mesh's
    device raveling never changes and any plan (default- or autotuned-map)
    runs on the same mesh object. Pass the same map to both so validation
    happens at mesh-construction time, before any compile."""
    if pod_map is not None:
        from repro.dist.halo import validate_pod_map

        validate_pod_map(pod_map, pods * devices_per_pod, pods)
    return jax.make_mesh((pods, devices_per_pod), ("pod", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch-carrying axes: ('pod','data') on the multi-pod mesh; only
    axes the mesh actually has (a (pod, model) halo mesh yields ('pod',))."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names) or ("data",)


def halo_axes(mesh) -> tuple[str, ...]:
    """The axes a full-graph halo exchange runs over: ('pod','model') when
    the mesh has a pod tier of width > 1 (hierarchical two-phase schedule),
    else ('model',) (flat single-axis schedule — a size-1 pod axis is no
    hierarchy, so e.g. ``make_halo_mesh(1, k)`` degenerates to flat)."""
    if "pod" in mesh.axis_names and mesh.shape["pod"] > 1:
        return ("pod", "model")
    return ("model",)
