import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init).

_DOC = """Multi-pod dry-run (deliverable e) + roofline-term extraction (deliverable g).

For every (architecture × input shape) cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings, out_shardings).lower(*abstract)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes(parse HLO)

on BOTH the 16×16 single-pod mesh (roofline source) and the 2×16×16
multi-pod mesh (proves the `pod` axis shards). Results are appended to a
resumable JSON (one record per cell × mesh), consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch pna --shape molecule
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 512-chip pass
"""

import argparse

import json
import re
import sys
import time
import traceback

from repro.launch.obsflags import add_obs_args, obs_session
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

__doc__ = _DOC

__all__ = [
    "run_cell", "collective_bytes", "exchange_accounting", "load_results", "main",
]

RESULTS_PATH = "results/dryrun.json"
# Results-file schema: v1 was a bare list of records; v2 wraps it as
# {"schema": 2, "records": [...]} so consumers (tests, benchmarks/roofline.py)
# can tell a partially-regenerated file from a complete sweep and treat a
# stale v1/v2 file with missing meshes as "not yet executed" instead of
# failing on it.
RESULTS_SCHEMA = 2

# TPU v5e constants (per the assignment's §Roofline).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_bytes(type_str: str) -> float:
    """Bytes of one HLO shape string like 'bf16[256,4096]' or a tuple."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"=\s*([^=]*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in the post-SPMD HLO.

    The result shape of each collective instruction line —
    `%x = f32[170,75]{1,0} all-reduce(...)` — is per-device shaped after SPMD
    partitioning, so the sum is the per-device wire volume entering the
    network (the quantity the ICI roofline term needs). `-done` ops carry the
    same tuple as their `-start`; only lines that themselves name a
    collective op with an argument list are counted, and `-done`/`-update`
    variants don't match the pattern.
    """
    out: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    out["total"] = sum(out.values())
    return out


def extrapolated_cost(cell, mesh) -> tuple[float, float, dict]:
    """Scan-corrected (flops, bytes, collectives) from the cell's cost cells.

    One cost cell → use verbatim. Two → fit cost(g) = fixed + g·delta with
    delta = max((c₂−c₁)/(g₂−g₁), 0), fixed = max(c₁ − g₁·delta, 0), and
    evaluate at cell.cost_groups (see steps.Cell docs for why the clamps).
    """
    measured = []
    for sub, g in cell.cost_cells:
        sc = sub.lower(mesh).compile()
        s_cost = sc.cost_analysis() or {}
        s_coll = collective_bytes(sc.as_text())
        measured.append(
            (g, float(s_cost.get("flops", 0.0)), float(s_cost.get("bytes accessed", 0.0)), s_coll)
        )
    if len(measured) == 1:
        _, fl, by, co = measured[0]
        return fl, by, co
    (g1, f1, b1, c1), (g2, f2, b2, c2) = measured[:2]
    G = cell.cost_groups

    def fit(a, b):
        d = max((b - a) / (g2 - g1), 0.0)
        fixed = max(a - g1 * d, 0.0)
        return fixed + G * d

    coll = {}
    for k in set(c1) | set(c2):
        coll[k] = fit(c1.get(k, 0.0), c2.get(k, 0.0))
    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    return fit(f1, f2), fit(b1, b2), coll


def exchange_accounting(cell, shape) -> dict | None:
    """Analytic per-device wire rows of the GNN layer exchange (DESIGN.md §8,
    docs/communication.md).

    Halo cells carry their HaloPlan, so the reported bytes-moved reflects the
    boundary rows each device actually receives — not the ``(k−1)·n_local``
    a broadcast schedule would ship; both numbers are recorded so the wire
    cut is visible per record. Hierarchical (pod, model) plans additionally
    split the rows per tier — intra-pod (cheap links) vs inter-pod (rows
    crossing the expensive fabric) — alongside the flat single-axis baseline
    on the same partition, so the per-tier savings are visible.
    ``backend="bsr"`` GCN cells also carry the blocked-kernel statistics
    (`repro.dist.halo.plan_blocked_shape`: nonzero 128×128 tiles and the
    padded-tile fraction the ragged kernel skips), so hillclimb and the
    roofline see the real blocked compute cost next to the wire cost. Cells
    without a plan (non-GNN, sampled, or forced-broadcast) return just the
    comm tag.

    The overlap/compression model (`repro.core.dataflow.ExchangeCost`,
    docs/communication.md "Overlapped schedule") is reported alongside:
    ``halo_wire_bytes_per_exchange`` is what crosses the fabric under the
    cell's payload format (× bits/32 vs the fp32 total) and
    ``halo_exposed_bytes_per_exchange`` what the critical path still waits
    on (× (1 − overlap_fraction)) — exposed < total whenever the overlapped
    schedule and/or a quantized payload is active.
    """
    plan = getattr(cell, "halo_plan", None)
    if plan is None:
        return {"comm": cell.comm} if getattr(cell, "comm", None) else None
    from repro.core.dataflow import exchange_cost
    from repro.core.quant import payload_bits

    d = shape.d_feat or 0
    payload = getattr(cell, "halo_payload", None)
    bits = payload_bits(payload)
    overlap = bool(getattr(cell, "halo_overlap", False))
    ov_frac = plan.overlap_fraction() if overlap else 0.0
    ec = exchange_cost(plan.halo_rows_per_device, d, bits, ov_frac)
    out = {
        "comm": cell.comm,
        "halo_rows_per_device": plan.halo_rows_per_device,
        "broadcast_rows_per_device": plan.broadcast_rows_per_device,
        "wire_fraction": plan.wire_fraction(),
        "halo_bytes_per_exchange": plan.halo_rows_per_device * d * 4,
        "broadcast_bytes_per_exchange": plan.broadcast_rows_per_device * d * 4,
        "payload": payload or "fp32",
        "payload_bits": bits,
        "payload_compression": ec.compression,
        "overlap": overlap,
        "overlap_fraction": ov_frac,
        "halo_wire_bytes_per_exchange": ec.wire_bytes,
        "halo_exposed_bytes_per_exchange": ec.exposed_bytes,
        "boundary_rows_max_device": int(plan.boundary_rows_per_device().max(initial=0)),
        "interior_rows_min_device": int(plan.interior_rows_per_device().min(initial=0)),
    }
    if getattr(cell, "bsr_stats", None):
        out["bsr"] = dict(cell.bsr_stats)
    if _obs_metrics.enabled():
        # Mirror the prediction into the same series the runtime layers
        # measure into — the prediction-vs-observation diff is then a plain
        # snapshot diff (docs/observability.md).
        from repro.obs.instrument import record_blocked, record_exchange

        record_exchange(plan, d, payload)
        if getattr(cell, "bsr_stats", None):
            record_blocked(cell.bsr_stats, scope="dryrun")
    if plan.is_hierarchical:
        out.update(
            axes=list(plan.axes),
            pods=plan.n_pods,
            intra_pod_rows_per_device=plan.intra_pod_rows_per_device,
            inter_pod_rows_per_device=plan.inter_pod_rows_per_device,
            inter_pod_rows_crossing=plan.inter_pod_rows_crossing,
            flat_inter_pod_rows_crossing=plan.flat_inter_pod_rows_crossing,
            inter_pod_bytes_crossing=plan.inter_pod_rows_crossing * d * 4,
            flat_inter_pod_bytes_crossing=plan.flat_inter_pod_rows_crossing * d * 4,
        )
    # Calibration block: the autotuner's analytic model evaluated on this
    # cell's own config. Every deterministic comm field above must match its
    # ``predicted`` twin exactly (the autotuner searches with the same
    # formulas this accounting measures — pinned in tests/test_autotune.py).
    from repro.core.autotune import CandidateConfig, comm_stats_from_plan, predict_config_cost

    bsr = getattr(cell, "bsr_stats", None) or {}
    if "interior" in bsr:  # split record: per-half tables (overlap schedule)
        nnz_blocks = bsr["interior"]["nnz_blocks"] + bsr["boundary"]["nnz_blocks"]
        block = int(bsr["interior"]["block"])
    else:
        nnz_blocks = bsr.get("nnz_blocks")
        block = int(bsr.get("block", 128))
    cfg = CandidateConfig(
        pods=plan.n_pods,
        block=block,
        backend="bsr" if bsr else "segment",
        payload=payload,
        overlap=overlap,
    )
    out["predicted"] = predict_config_cost(
        cfg, comm_stats_from_plan(plan), d_feat=d, n_nodes=plan.n_nodes,
        nnz_blocks=nnz_blocks,
        n_edges=int((plan.edge_w > 0).sum()),
    )
    return out


def run_cell(
    arch_id: str, shape_name: str, multi_pod: bool, verbose: bool = True,
    optimized: bool = False, comm: str | None = None, payload: str | None = None,
) -> dict:
    import jax

    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    spec = get_arch(arch_id)
    shape = spec.shapes[shape_name]
    rec: dict = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": ("2x16x16" if multi_pod else "16x16")
        + ("+opt" if optimized else "")
        + (f"+{comm}" if comm else "")
        + (f"+{payload}" if payload else ""),
        "ts": time.time(),
    }
    if shape.skip_reason:
        rec.update(status="SKIP", reason=shape.skip_reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    try:
        t0 = time.time()
        cell = build_cell(spec, shape, mesh, optimized=optimized, comm=comm, payload=payload)
        with _obs_trace.span("dryrun.lower",
                             args={"arch": arch_id, "shape": shape_name}):
            lowered = cell.lower(mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        with _obs_trace.span("dryrun.compile",
                             args={"arch": arch_id, "shape": shape_name}):
            compiled = lowered.compile()
        t_compile = time.time() - t0
        if _obs_metrics.enabled():
            _obs_metrics.observe("dryrun.lower_s", t_lower)
            _obs_metrics.observe("dryrun.compile_s", t_compile)
            _obs_metrics.inc("dryrun.cells")
        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception:
            mem_d = {}
        hlo = compiled.as_text()
        if cell.cost_cells:
            flops, bytes_hbm, coll = extrapolated_cost(cell, mesh)
        else:
            coll = collective_bytes(hlo)
            flops = float(cost.get("flops", 0.0))
            bytes_hbm = float(cost.get("bytes accessed", 0.0))
        # cost_analysis on the CPU backend reports per-PROGRAM (per-device)
        # numbers for the SPMD-partitioned module.
        compute_s = flops / PEAK_FLOPS
        memory_s = bytes_hbm / HBM_BW
        collective_s = coll["total"] / ICI_BW
        dominant = max(
            ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
            key=lambda kv: kv[1],
        )[0]
        rec.update(
            status="OK",
            kind=cell.kind,
            n_chips=int(n_chips),
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=flops,
            hbm_bytes_per_device=bytes_hbm,
            collective_bytes_per_device=coll,
            memory=mem_d,
            roofline={
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "dominant": dominant,
            },
            model_flops=cell.model_flops,
            useful_flops_ratio=(cell.model_flops / (flops * n_chips)) if flops else None,
            note=cell.note,
            exchange=exchange_accounting(cell, shape),
        )
        if verbose:
            print(f"[{rec['mesh']}] {arch_id} × {shape_name}: OK "
                  f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s, "
                  f"dominant={dominant})")
            print(f"    memory_analysis: {mem_d}")
            print(f"    cost_analysis: flops/dev={flops:.3g} bytes/dev={bytes_hbm:.3g} "
                  f"coll_bytes/dev={coll['total']:.3g}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{rec['mesh']}] {arch_id} × {shape_name}: FAIL {type(e).__name__}: {e}")
    return rec


def load_results(path: str = RESULTS_PATH) -> list[dict]:
    """Load a results file in either schema: the v1 bare list or the v2
    ``{"schema": 2, "records": [...]}`` wrapper. Missing file → []. The
    single loader every consumer (the resumable sweep itself, the tier-1
    completeness test, benchmarks/roofline.py) shares, so a schema bump
    happens in exactly one place."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return []
    if isinstance(data, dict):
        return list(data.get("records", []))
    return list(data)


_load = load_results


def _save(path: str, records: list[dict]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"schema": RESULTS_SCHEMA, "records": records},
                  f, indent=1, default=str)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default=RESULTS_PATH)
    ap.add_argument("--force", action="store_true", help="re-run cached cells")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf findings (beyond-paper variants)")
    ap.add_argument("--comm", choices=["default", "halo", "broadcast"], default="default",
                    help="full-graph GNN communication schedule (DESIGN.md §8). "
                         "'halo' IS the default (same records, no tag suffix); "
                         "'broadcast' = the Fig. 5c escape hatch, recorded under "
                         "a '+broadcast' mesh tag. GNN records produced before "
                         "the halo default landed measured the broadcast "
                         "schedule — re-run them with --force.")
    ap.add_argument("--payload", choices=["fp32", "bf16", "int8"], default="fp32",
                    help="halo wire payload format (docs/communication.md "
                         "'Overlapped schedule'). 'fp32' IS the default (same "
                         "records, no tag suffix); 'bf16'/'int8' quantize the "
                         "boundary rows on the wire and record under a "
                         "'+bf16'/'+int8' mesh tag. Halo GNN cells only.")
    ap.add_argument("--autotune-config", default=None,
                    help="JSON emitted by repro.launch.autotune --out; applies "
                         "the chosen config's payload/backend/mesh knobs "
                         "(overriding --payload/--optimized/--mesh) so a "
                         "tuned config flows straight into the sweep.")
    add_obs_args(ap)
    args = ap.parse_args(argv)
    if args.autotune_config:
        with open(args.autotune_config) as f:
            tuned = json.load(f)["config"]
        args.payload = tuned.get("payload") or "fp32"
        args.optimized = tuned.get("backend") == "bsr"
        args.mesh = "multi" if tuned.get("pods", 1) > 1 else "single"
    # "halo" is the default schedule: map both spellings to comm=None so the
    # identical computation never gets cached twice under different tags.
    comm = "broadcast" if args.comm == "broadcast" else None
    # Same idea for the payload: fp32 is the default wire format.
    payload = None if args.payload == "fp32" else args.payload

    from repro.configs import get_arch, ASSIGNED_ARCHS

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    records = _load(args.out)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records if r.get("status") in ("OK", "SKIP")}
    failures = 0
    with obs_session(args):
        for arch_id in archs:
            spec = get_arch(arch_id)
            shapes = [args.shape] if args.shape else list(spec.shapes)
            for shape_name in shapes:
                for multi in meshes:
                    mesh_tag = (
                        ("2x16x16" if multi else "16x16")
                        + ("+opt" if args.optimized else "")
                        + (f"+{comm}" if comm else "")
                        + (f"+{payload}" if payload else "")
                    )
                    key = (arch_id, shape_name, mesh_tag)
                    if key in done and not args.force:
                        print(f"[cached] {key}")
                        continue
                    rec = run_cell(
                        arch_id, shape_name, multi,
                        optimized=args.optimized, comm=comm, payload=payload,
                    )
                    records = [r for r in records if (r["arch"], r["shape"], r["mesh"]) != key]
                    records.append(rec)
                    _save(args.out, records)
                    if rec["status"] == "FAIL":
                        failures += 1
    print(f"dry-run sweep complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
