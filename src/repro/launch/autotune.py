"""repro.launch.autotune — communication-aware placement + config search.

The CLI face of ``repro.core.autotune`` (docs/autotune.md): builds the pinned
graph + partition, runs the quotient-graph pod mapper and the
coordinate-descent config search, then re-measures BOTH the default and the
chosen config on really-built halo plans and prints a predicted-vs-measured
report. The chosen config is written as JSON (``--out``) in a form the other
drivers consume — ``repro.launch.dryrun --autotune-config <file>`` applies
it directly, and the report prints the matching flags for
``examples/train_distributed_gcn.py`` / ``repro.launch.serve``.

Everything here is host-side numpy: no mesh, no jax compile, so the search
runs in seconds even for the 16384-node benchmark graphs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.core.autotune import (
    BLOCK_GRID,
    CandidateConfig,
    autotune_config,
    comm_stats_from_plan,
)
from repro.core.dataflow import exchange_cost
from repro.core.energy import model_from_gcn
from repro.core.partition import partition_graph
from repro.core.quant import payload_bits
from repro.dist.halo import build_halo_plan, plan_blocked_shape
from repro.launch.obsflags import add_obs_args, obs_session

__all__ = ["measured_accounting", "run_autotune", "main"]


def measured_accounting(plan, cfg: CandidateConfig, d_feat: int) -> dict:
    """Measured comm/compute record of one config on a BUILT plan.

    Same field names and formulas as the dry-run ``exchange_accounting`` —
    this is the "measured" side of the predicted-vs-measured report and of
    BENCH_autotune.json (rows come from the plan's real export tables, not
    from the analytic index).
    """
    bits = payload_bits(cfg.payload)
    ov = plan.overlap_fraction() if cfg.overlap else 0.0
    ec = exchange_cost(plan.halo_rows_per_device, d_feat, bits, ov)
    out = {
        "halo_rows_per_device": plan.halo_rows_per_device,
        "payload": cfg.payload or "fp32",
        "payload_bits": bits,
        "overlap_fraction": ov,
        "halo_wire_bytes_per_exchange": ec.wire_bytes,
        "halo_exposed_bytes_per_exchange": ec.exposed_bytes,
        "executed_tiles": plan_blocked_shape(plan, block=cfg.block)["nnz_blocks"],
        "block": cfg.block,
    }
    if plan.is_hierarchical:
        out.update(
            pods=plan.n_pods,
            s_loc=plan.s_loc,
            s_rem=plan.s_rem,
            inter_pod_rows_crossing=plan.inter_pod_rows_crossing,
            flat_inter_pod_rows_crossing=plan.flat_inter_pod_rows_crossing,
            inter_pod_bytes_crossing=plan.inter_pod_rows_crossing * d_feat * 4,
        )
    return out


def _build_plan(part, ei, pods: int, pod_map) -> object:
    axes = ("pod", "model") if pods > 1 else ("model",)
    return build_halo_plan(
        part, ei, axes=axes, pods=pods,
        pod_map=None if pod_map is None else np.asarray(pod_map, np.int64),
    )


def run_autotune(
    *,
    n: int,
    e: int,
    k: int,
    pods: int,
    d_feat: int,
    layer_dims: tuple[int, ...],
    n_labels: int = 128,
    homophily: float = 0.9,
    graph_seed: int = 1,
    shuffle_seed: int | None = 7,
    partition_seed: int = 0,
    seed: int = 0,
    rounds: int = 3,
) -> dict:
    """Full search + measured report on a pinned synthetic graph.

    Returns the JSON-ready record: chosen config, predicted breakdown,
    measured default-vs-autotuned accounting, improvement ratios, and a
    calibration block listing any predicted field that disagrees with its
    measured twin (empty == exact, the shipped contract).
    """
    from repro.graph.generators import citation_like

    g = citation_like(n, e, n_labels=n_labels, homophily=homophily, seed=graph_seed)
    ei = g.edge_index
    if shuffle_seed is not None:
        shuf = np.random.default_rng(shuffle_seed).permutation(n)
        ei = shuf[ei]
    part = partition_graph(n, ei, k, method="bfs", seed=partition_seed, refine=True)

    default_plan = _build_plan(part, ei, pods, None)
    nnz_blocks_for = {
        b: plan_blocked_shape(default_plan, block=b)["nnz_blocks"] for b in BLOCK_GRID
    }
    result = autotune_config(
        part, ei, pods=pods, d_feat=d_feat, layer_dims=layer_dims,
        nnz_blocks_for=nnz_blocks_for,
        energy_model=model_from_gcn(n, layer_dims),
        seed=seed, rounds=rounds,
    )
    cfg = result.config
    tuned_plan = _build_plan(part, ei, pods, cfg.pod_map_array())
    measured_default = measured_accounting(default_plan, result.baseline_config, d_feat)
    measured_tuned = measured_accounting(tuned_plan, cfg, d_feat)

    improvement = {
        "exposed_improvement": measured_default["halo_exposed_bytes_per_exchange"]
        / max(measured_tuned["halo_exposed_bytes_per_exchange"], 1e-30),
        "tiles_ratio": measured_tuned["executed_tiles"]
        / max(measured_default["executed_tiles"], 1),
        "predicted_objective_improvement": result.predicted_improvement,
    }
    if pods > 1:
        improvement["crossing_improvement"] = (
            measured_default["inter_pod_rows_crossing"]
            / max(measured_tuned["inter_pod_rows_crossing"], 1)
        )

    # Calibration: the search predicted with the same formulas the measured
    # accounting uses, so shared deterministic fields must agree exactly.
    mismatches = {
        f: (result.predicted[f], measured_tuned[f])
        for f in (
            "halo_rows_per_device", "payload_bits", "overlap_fraction",
            "halo_wire_bytes_per_exchange", "halo_exposed_bytes_per_exchange",
        ) + (("inter_pod_rows_crossing", "flat_inter_pod_rows_crossing") if pods > 1 else ())
        if result.predicted[f] != measured_tuned[f]
    }
    return {
        "schema": 1,
        "graph": {
            "n": n, "e": e, "n_labels": n_labels, "homophily": homophily,
            "graph_seed": graph_seed, "shuffle_seed": shuffle_seed,
            "k": k, "pods": pods, "partition_seed": partition_seed,
            "d_feat": d_feat, "layer_dims": list(layer_dims),
        },
        "config": dataclasses.asdict(cfg),
        "history": [list(h) for h in result.history],
        "predicted": result.predicted,
        "predicted_baseline": result.baseline,
        "measured": {"default": measured_default, "autotuned": measured_tuned},
        "improvement": improvement,
        "calibration_mismatches": mismatches,
    }


def _print_report(rec: dict) -> None:
    cfg = rec["config"]
    print("chosen config:")
    for key in ("pods", "block", "backend", "order", "payload", "overlap"):
        print(f"  {key:<8} = {cfg[key]!r}")
    print(f"  pod_map  = {cfg['pod_map']}")
    print("search history (objective_s after each accepted move):")
    for desc, obj in rec["history"]:
        print(f"  {obj:.3e}  {desc}")
    md, mt = rec["measured"]["default"], rec["measured"]["autotuned"]
    print("measured (default → autotuned):")
    rows = [
        ("halo rows/device", "halo_rows_per_device"),
        ("wire bytes/exchange", "halo_wire_bytes_per_exchange"),
        ("exposed bytes/exchange", "halo_exposed_bytes_per_exchange"),
        ("executed tiles", "executed_tiles"),
    ]
    if "inter_pod_rows_crossing" in md:
        rows.insert(1, ("inter-pod crossing rows", "inter_pod_rows_crossing"))
    for label, key in rows:
        print(f"  {label:<24} {md[key]:>12} → {mt[key]:>12}")
    print("improvement:", json.dumps(rec["improvement"], sort_keys=True))
    if rec["calibration_mismatches"]:
        print("PREDICTED≠MEASURED:", rec["calibration_mismatches"])
    else:
        print("calibration: every shared predicted field matches measured exactly")
    pods, payload = cfg["pods"], cfg["payload"] or "fp32"
    print("hand-off:")
    print(f"  dryrun: PYTHONPATH=src python -m repro.launch.dryrun --arch coin-gcn "
          f"--autotune-config <out.json>")
    print(f"  train : PYTHONPATH=src python examples/train_distributed_gcn.py "
          f"--pods {pods}" + (f" --payload {payload}" if payload != "fp32" else ""))
    print(f"  serve : PYTHONPATH=src python -m repro.launch.serve --arch coin-gcn "
          f"--parts {rec['graph']['k']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--e", type=int, default=65536)
    ap.add_argument("--k", type=int, default=32, help="partition parts == devices")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--d-feat", type=int, default=64)
    ap.add_argument("--layer-dims", default="64,32,7",
                    help="comma-separated GCN layer dims (first == --d-feat)")
    ap.add_argument("--n-labels", type=int, default=128)
    ap.add_argument("--homophily", type=float, default=0.9)
    ap.add_argument("--graph-seed", type=int, default=1)
    ap.add_argument("--shuffle-seed", type=int, default=7,
                    help="node-id shuffle applied before partitioning; -1 disables")
    ap.add_argument("--partition-seed", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0, help="search seed")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--out", default=None, help="write the chosen config JSON here")
    add_obs_args(ap)
    args = ap.parse_args(argv)
    layer_dims = tuple(int(x) for x in args.layer_dims.split(","))
    with obs_session(args):
        rec = run_autotune(
            n=args.n, e=args.e, k=args.k, pods=args.pods, d_feat=args.d_feat,
            layer_dims=layer_dims, n_labels=args.n_labels,
            homophily=args.homophily, graph_seed=args.graph_seed,
            shuffle_seed=None if args.shuffle_seed < 0 else args.shuffle_seed,
            partition_seed=args.partition_seed, seed=args.seed,
            rounds=args.rounds,
        )
    _print_report(rec)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        print(f"wrote {args.out}")
    return 1 if rec["calibration_mismatches"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
