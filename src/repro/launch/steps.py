"""Cell builder: (arch × shape × mesh) → (step_fn, abstract inputs, shardings).

`build_cell` returns everything `dryrun.py` needs to
``jax.jit(fn, in_shardings, out_shardings).lower(*abstract_inputs)`` with no
real allocation (every input is a ShapeDtypeStruct, params included — the
same pattern the assignment's shannon/kernels reference uses).

Step kinds per family:
  lm/train      — loss + grads + AdamW update        (train_step)
  lm/prefill    — last-position logits               (serve_step)
  lm/decode     — one token against the KV cache     (serve_step)
  gnn/graph     — regression loss + grads + AdamW    (train_step; sampled
                  cells vmap a block per data shard)
  recsys/train  — BCE loss + grads + AdamW
  recsys/serve  — batched logits
  recsys/retrieval — 1×N candidate scoring

Full-graph GNN cells default to the **halo** communication schedule
(DESIGN.md §8): the step runs inside shard_map over a cached
`repro.dist.halo.HaloPlan`, exchanging only boundary rows per layer
(`k·s_max` received rows/device) instead of the broadcast all-gather
(`(k−1)·n_local`). On a mesh with a ``pod`` tier the cell shards the graph
over ("pod", "model") jointly and the exchange turns hierarchical — two
phases with per-tier padding, only deduplicated remote rows crossing the
inter-pod fabric (DESIGN.md §8.3, docs/communication.md). Pass
``comm="broadcast"`` to `build_cell` for the paper-faithful Fig. 5c
schedule (the escape hatch and the dry-run baseline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ArchSpec, ShapeSpec
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.launch import shardings as sh
from repro.launch.mesh import data_axes
from repro.train.optimizer import adamw

__all__ = ["Cell", "build_cell"]

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable
    abstract_args: tuple
    in_shardings: Any
    out_shardings: Any
    model_flops: float          # 6·N·D-style useful-FLOPs estimate
    note: str = ""
    # Cost correction: XLA cost_analysis counts a rolled lax.scan body ONCE,
    # so deep layer stacks under-report FLOPs/bytes/collectives. When set,
    # each entry is (small UNROLLED variant, its group count); the dry-run
    # fits cost(g) = fixed + g·delta with delta clamped ≥ 0 (XLA's SPMD
    # choices differ slightly between programs, so a raw two-point
    # extrapolation can go negative) and evaluates at `cost_groups`. A single
    # entry means "use its cost verbatim". memory_analysis / compile proof
    # always come from this Cell's real rolled program.
    cost_cells: list[tuple["Cell", float]] | None = None
    cost_groups: float = 1.0
    donate_argnums: tuple = ()
    # GNN full-graph cells: which communication schedule the step uses
    # ("halo" | "broadcast"; None for non-GNN / sampled cells) and, for halo,
    # the HaloPlan whose static shapes the abstract batch follows — the
    # dry-run reads wire accounting (k·s_max vs (k−1)·n_local) off it.
    comm: str | None = None
    halo_plan: Any = None
    # backend="bsr" GCN cells: the blocked-adjacency statistics of
    # `repro.dist.halo.plan_blocked_shape` (nonzero 128×128 tiles,
    # padded-tile fraction) — the dry-run reports them in the `exchange`
    # record and `model_flops` is computed from the blocked cost model
    # (nnz_blocks·B²·F, repro.core.dataflow) instead of the edge count.
    # Halo cells carry the split record of `plan_split_blocked_shape`
    # ("interior"/"boundary" sub-dicts + combined top-level keys).
    bsr_stats: dict | None = None
    # Halo cells: the wire payload format (None/"fp32" | "bf16" | "int8")
    # and whether the interior/boundary-split overlapped schedule is on —
    # the dry-run's exchange accounting reads both (ExchangeCost).
    halo_payload: str | None = None
    halo_overlap: bool = False

    def lower(self, mesh):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        with mesh:
            return jitted.lower(*self.abstract_args)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _abstract_tree(tree):
    return jax.tree_util.tree_map(lambda l: _sds(l.shape, l.dtype), tree)


# ========================================================================= LM
def _lm_cost_cells(
    spec: ArchSpec, shape: ShapeSpec, mesh, cfg
) -> tuple[list[tuple[Cell, float]], float]:
    """Two small fully-unrolled variants for cost extrapolation.

    period = the layer-pattern repeat (gemma3's 5:1 group, else 1 layer);
    cost(L) ≈ fixed + (L/period)·delta. We lower g ∈ {2, 4} groups (or
    {1, 2} when a group is multiple layers) and the dry-run fits the line
    with the non-negative estimator (see Cell.cost_cells). kv_chunk is
    raised to seq_len so the attention kv scan is also unrolled (single
    chunk) inside the cost cells.
    """
    period = cfg.global_every or 1
    if cfg.n_layers % period or cfg.n_layers < 2 * period:
        period = 1
    G = cfg.n_layers // period
    mults = (1, 2) if period > 1 else (2, 4)
    if G <= mults[1]:
        return [], float(G)
    seq = shape.seq_len or cfg.kv_chunk
    out = []
    for mult in mults:
        sub_cfg = dataclasses.replace(
            cfg,
            n_layers=mult * period,
            unroll_layers=True,
            kv_chunk=max(seq, cfg.kv_chunk),
        )
        sub_spec = dataclasses.replace(spec, make_config=lambda s=None, c=sub_cfg: c)
        out.append((_lm_cell(sub_spec, shape, mesh, _with_cost_cells=False), float(mult)))
    return out, float(G)


def _lm_cell(
    spec: ArchSpec, shape: ShapeSpec, mesh, dtype=BF16,
    _with_cost_cells: bool = True, optimized: bool = False,
) -> Cell:
    from repro.models.transformer_lm import (
        lm_decode_step,
        lm_init_cache,
        lm_loss,
        lm_param_shapes,
        lm_prefill,
    )

    cfg = spec.make_config(shape)
    da = data_axes(mesh)
    if optimized:
        # The §Perf findings as defaults: hierarchical MoE dispatch (T1),
        # remat for train (T2), donation handled below.
        n_data = int(np.prod([mesh.shape[a] for a in da]))
        kw = {}
        if cfg.is_moe:
            kw["moe_groups"] = n_data
        if shape.kind == "train":
            kw["remat"] = True
        if kw:
            cfg = dataclasses.replace(cfg, **kw)
    policy = sh.lm_policy(mesh, cfg)
    cost_cells, cost_groups = (
        _lm_cost_cells(spec, shape, mesh, cfg) if _with_cost_cells else (None, 1.0)
    )
    params_abs = jax.tree_util.tree_map(
        lambda l: _sds(l.shape, dtype), lm_param_shapes(cfg)
    )
    p_specs = sh.lm_param_specs(params_abs, cfg, mesh)
    p_shard = sh.tree_named(mesh, p_specs)

    if shape.kind == "train":
        opt = adamw(lr=3e-4)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_shard = sh.tree_named(mesh, _opt_specs(opt_abs, p_specs))
        tok_shard = sh.named(mesh, P(da, None))

        def train_step(params, opt_state, tokens):
            loss, grads = jax.value_and_grad(lm_loss)(params, tokens, cfg, policy)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, loss

        tokens = _sds((shape.global_batch, shape.seq_len + 1), I32)
        return Cell(
            spec.arch_id, shape.name, "train_step",
            train_step,
            (params_abs, opt_abs, tokens),
            (p_shard, o_shard, tok_shard),
            (p_shard, o_shard, sh.named(mesh, P())),
            model_flops=6.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len,
            cost_cells=cost_cells,
            cost_groups=cost_groups,
            donate_argnums=(0, 1) if optimized else (),
        )

    if shape.kind == "prefill":
        tok_shard = sh.named(mesh, P(da, None))

        def prefill_step(params, tokens):
            return lm_prefill(params, tokens, cfg, policy)

        tokens = _sds((shape.global_batch, shape.seq_len), I32)
        return Cell(
            spec.arch_id, shape.name, "serve_step",
            prefill_step,
            (params_abs, tokens),
            (p_shard, tok_shard),
            sh.named(mesh, P(da, "model")),
            model_flops=2.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len,
            cost_cells=cost_cells,
            cost_groups=cost_groups,
        )

    # decode: one new token with a KV cache of seq_len.
    cache_abs = _abstract_tree(
        jax.eval_shape(lambda: lm_init_cache(cfg, shape.global_batch, shape.seq_len, dtype))
    )
    cspec = sh.cache_spec(cfg, shape, mesh)
    c_shard = jax.tree_util.tree_map(lambda _: sh.named(mesh, cspec), cache_abs)
    n_data = int(np.prod([mesh.shape[a] for a in da]))
    tok_spec = P(da) if shape.global_batch % n_data == 0 and shape.global_batch >= n_data else P()

    def decode_step(params, cache, token, pos):
        return lm_decode_step(params, cache, token, pos, cfg, policy)

    token = _sds((shape.global_batch,), I32)
    pos = _sds((), I32)
    return Cell(
        spec.arch_id, shape.name, "serve_step",
        decode_step,
        (params_abs, cache_abs, token, pos),
        (p_shard, c_shard, sh.named(mesh, tok_spec), sh.named(mesh, P())),
        (sh.named(mesh, P(tok_spec[0] if len(tok_spec) else None, "model")), c_shard),
        model_flops=2.0 * cfg.active_param_count() * shape.global_batch,
        note=f"KV cache {shape.seq_len} tokens, spec {cspec}",
        cost_cells=cost_cells,
        cost_groups=cost_groups,
        donate_argnums=(1,) if optimized else (),   # in-place cache update
    )


def _opt_specs(opt_abs, p_specs):
    """AdamW state {m, v, step}: m/v mirror param specs; step replicated."""
    del opt_abs
    return {"m": p_specs, "v": p_specs, "step": P()}


# ======================================================================== GNN
def _gnn_loss_fn(arch_id: str, cfg, policy: ShardingPolicy, n_loss_nodes: int | None = None):
    """Regression loss over model output (sliced to the first ``n_loss_nodes``
    rows for sampled blocks — losses are computed on the seed nodes only)."""

    def _mse(pred, target):
        if n_loss_nodes is not None:
            pred = pred[:n_loss_nodes]
        return jnp.mean(jnp.square(pred - target))

    if arch_id == "egnn":
        from repro.models.egnn import egnn_forward

        def loss(params, batch):
            pred, _ = egnn_forward(
                params, batch["feats"], batch["pos"], batch["senders"],
                batch["receivers"], cfg, policy,
            )
            return _mse(pred, batch["target"])
    elif arch_id == "graphcast":
        from repro.models.graphcast import graphcast_forward

        def loss(params, batch):
            pred = graphcast_forward(
                params, batch["feats"], batch["edge_feats"], batch["senders"],
                batch["receivers"], cfg, policy,
            )
            return _mse(pred, batch["target"])
    elif arch_id == "equiformer-v2":
        from repro.models.equiformer_v2 import equiformer_forward

        def loss(params, batch):
            pred = equiformer_forward(
                params, batch["feats"], batch["pos"], batch["senders"],
                batch["receivers"], cfg, policy,
            )
            return _mse(pred, batch["target"])
    elif arch_id == "pna":
        from repro.models.pna import pna_forward

        def loss(params, batch):
            pred = pna_forward(
                params, batch["feats"], batch["senders"], batch["receivers"], cfg, policy
            )
            return _mse(pred, batch["target"])
    elif arch_id == "coin_gcn":
        from repro.models.gcn import gcn_loss

        def loss(params, batch):
            return gcn_loss(
                params, batch["feats"], batch["senders"], batch["receivers"],
                batch["edge_weight"], batch["labels"], batch["label_mask"], cfg, policy,
            )
    else:
        raise KeyError(arch_id)
    return loss


def _gnn_params(arch_id: str, cfg, dtype):
    key = jax.random.PRNGKey(0)
    if arch_id == "egnn":
        from repro.models.egnn import egnn_init

        return jax.eval_shape(lambda k: egnn_init(k, cfg, dtype), key)
    if arch_id == "graphcast":
        from repro.models.graphcast import graphcast_init

        return jax.eval_shape(lambda k: graphcast_init(k, cfg, dtype), key)
    if arch_id == "equiformer-v2":
        from repro.models.equiformer_v2 import equiformer_init

        return jax.eval_shape(lambda k: equiformer_init(k, cfg, dtype), key)
    if arch_id == "pna":
        from repro.models.pna import pna_init

        return jax.eval_shape(lambda k: pna_init(k, cfg, dtype), key)
    if arch_id == "coin_gcn":
        from repro.models.gcn import gcn_init

        return jax.eval_shape(lambda k: gcn_init(k, cfg, dtype), key)
    raise KeyError(arch_id)


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _gnn_sizes(shape: ShapeSpec, pad_mult: int) -> tuple[int, int]:
    """(nodes, edges) of the device graph: packed for molecule batches,
    fanout-expanded for sampled blocks, padded to the shard divisor."""
    if shape.batch_nodes is not None:       # sampled block
        n, e, frontier = shape.batch_nodes, 0, shape.batch_nodes
        for f in shape.fanout:
            e += frontier * f
            frontier *= f
            n += frontier
    elif shape.n_graphs is not None:        # packed small-graph batch
        n, e = shape.n_nodes * shape.n_graphs, shape.n_edges * shape.n_graphs
    else:                                   # one full graph
        n, e = shape.n_nodes, shape.n_edges
    return _pad_to(n, pad_mult), _pad_to(e, pad_mult)


def _gnn_batch_abstract(arch_id: str, shape: ShapeSpec, cfg, n_blocks: int | None, pad_mult: int):
    """Abstract batch dict. n_blocks=None → single global graph; else a
    leading block axis (one sampled block per data shard)."""
    n, e = _gnn_sizes(shape, pad_mult if n_blocks is None else 1)
    lead = () if n_blocks is None else (n_blocks,)
    batch = {
        "feats": _sds(lead + (n, shape.d_feat), F32),
        "senders": _sds(lead + (e,), I32),
        "receivers": _sds(lead + (e,), I32),
    }
    if arch_id in ("egnn", "equiformer-v2"):
        batch["pos"] = _sds(lead + (n, 3), F32)
    if arch_id == "graphcast":
        batch["edge_feats"] = _sds(lead + (e, cfg.d_edge_in), F32)
    if arch_id == "coin_gcn":
        batch["edge_weight"] = _sds(lead + (e,), F32)
        batch["labels"] = _sds(lead + (n,), I32)
        batch["label_mask"] = _sds(lead + (n,), F32)
    else:
        n_out = cfg.n_vars if arch_id == "graphcast" else getattr(cfg, "d_out", 1)
        n_tgt = shape.batch_nodes if n_blocks is not None else n
        batch["target"] = _sds(lead + (n_tgt, n_out), F32)
    return batch


def _gnn_flops(arch_id: str, shape: ShapeSpec, cfg, bsr_stats: dict | None = None) -> float:
    """Useful forward FLOPs (2 × MACs of the defining matmuls per arch).

    ``bsr_stats`` (a `repro.dist.halo.plan_blocked_shape` record) switches
    the coin_gcn aggregation term to the blocked cost model so hillclimb and
    the dry-run see the kernel's real nnz_blocks·B²·F work.
    """
    n, e = float(shape.n_nodes), float(shape.n_edges)
    L = cfg.n_layers
    if arch_id == "equiformer-v2":
        C, lmax, mmax = cfg.d_hidden, cfg.l_max, cfg.m_max
        K = (lmax + 1) ** 2
        so2 = ((lmax + 1) * C) ** 2 + 2 * sum(
            2 * ((lmax + 1 - m) * C) ** 2 for m in range(1, mmax + 1)
        )
        rot = 2 * sum((2 * l + 1) ** 2 for l in range(lmax + 1)) * C   # D + Dᵀ apply
        attn = (2 * C + cfg.n_rbf) * C + C * cfg.n_heads
        ffn_n = C * 2 * C + 2 * C * C + lmax * C * C                   # scalar MLP + per-l mix
        return 2.0 * L * (e * (so2 + rot + attn) + n * ffn_n)
    if arch_id == "egnn":
        d = cfg.d_hidden
        per_e = (2 * d + 1) * d + d * d + (d * d + d)                  # φ_e (2-layer) + φ_x
        per_n = 2 * d * d + d * d                                      # φ_h
        return 2.0 * L * (e * per_e + n * per_n)
    if arch_id == "graphcast":
        d = cfg.d_hidden
        per_e = 3 * d * d + d * d
        per_n = 2 * d * d + d * d
        return 2.0 * L * (e * per_e + n * per_n)
    if arch_id == "pna":
        d = cfg.d_hidden
        per_e = 2 * d * d                                              # pre-MLP on (h_i‖h_j)
        per_n = (1 + cfg.n_agg_feats) * d * d                          # post-MLP on 13·d concat
        return 2.0 * L * (e * per_e + n * per_n)
    if arch_id == "coin_gcn":
        bsr = bsr_stats
        total = 0.0
        for d_in, d_out in zip(cfg.layer_dims[:-1], cfg.layer_dims[1:]):
            if bsr is not None:
                # Blocked cost: the ragged MXU kernel runs nnz_blocks·B²
                # MACs per output feature, not E (repro.core.dataflow).
                from repro.core.dataflow import blocked_multiply_count

                total += blocked_multiply_count(
                    n, bsr["nnz_blocks"], d_in, d_out, bsr["block"]
                ).feature_first
            else:
                total += n * d_in * d_out + e * d_out                  # feature-first
        return 2.0 * total
    d = getattr(cfg, "d_hidden", 512)
    return 2.0 * L * (n * d * d + e * d)


def _sampled_edges(shape: ShapeSpec) -> int:
    e, frontier = 0, shape.batch_nodes
    for f in shape.fanout:
        e += frontier * f
        frontier *= f
    return e


def _shape_halo_plan(n: int, e: int, k: int, pods: int = 1):
    """Cached HaloPlan for the (n, e) shape-statistics synthetic graph.

    Abstract cells have no real graph — like the rest of the dry-run they run
    on the deterministic exact-count synthetic (DESIGN.md §5), partitioned
    with the locality-seeking BFS+refine that keeps export sets small
    (DESIGN.md §7.3). The plan is memoized per (graph, k, axes) in
    `repro.dist.halo` (``pods > 1`` caches under the ("pod", "model") axes
    tuple, side by side with the flat plan), so every layer/epoch/cell over
    the same shape reuses one host-side relocation; the deterministic string
    key means a cache hit skips graph synthesis and partitioning entirely.
    """
    from repro.dist.halo import build_halo_plan, cached_halo_plan

    axes = ("pod", "model") if pods > 1 else ("model",)

    def build():
        from repro.core.partition import partition_graph
        from repro.graph.generators import citation_like

        g = citation_like(n, e, seed=0)
        part = partition_graph(n, g.edge_index, k, method="bfs", seed=0, refine=True)
        return build_halo_plan(part, g.edge_index, axes=axes, pods=pods)

    return cached_halo_plan(
        f"citation_like:n{n}:e{e}:seed0", k,
        axes if pods > 1 else "model", pods=pods, builder=build,
    )


def _gnn_halo_device_loss(arch_id: str, cfg):
    """Per-device (weighted_sum, weight) of the arch's loss over one block.

    Runs inside the shard_map body: every array is this device's slice of the
    HaloPlan layout, ``pol`` has the device's export rows bound, and padding
    (edge_w == 0 edges, rows ≥ part_size) is masked out so the psum-combined
    loss equals the global single-device loss exactly.
    """

    def device_loss(params, b, pol):
        edge_mask = (b["edge_w"] > 0).astype(F32)
        if arch_id == "coin_gcn":
            from repro.models.gcn import gcn_forward

            adjacency = (
                (b["bsr_vals"], b["bsr_cols"], b["bsr_lens"])
                if "bsr_vals" in b else None
            )
            # Split pair (interior adjacency above + boundary tables below):
            # the overlapped schedule — interior tiles aggregate the local
            # block while the boundary tables consume the halo exchange.
            adjacency_boundary = (
                (b["bsr_bvals"], b["bsr_bcols"], b["bsr_blens"])
                if "bsr_bvals" in b else None
            )
            logits = gcn_forward(
                params, b["feats"], b["senders"], b["receivers"], b["edge_w"], cfg, pol,
                adjacency=adjacency, adjacency_boundary=adjacency_boundary,
            ).astype(F32)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, b["labels"][:, None], axis=-1)[:, 0]
            return ((lse - gold) * b["label_mask"]).sum(), b["label_mask"].sum()
        if arch_id == "pna":
            from repro.models.pna import pna_forward

            pred = pna_forward(
                params, b["feats"], b["senders"], b["receivers"], cfg, pol, edge_mask=edge_mask
            )
        elif arch_id == "egnn":
            from repro.models.egnn import egnn_forward

            pred, _ = egnn_forward(
                params, b["feats"], b["pos"], b["senders"], b["receivers"], cfg, pol,
                edge_mask=edge_mask,
            )
        elif arch_id == "graphcast":
            from repro.models.graphcast import graphcast_forward

            pred = graphcast_forward(
                params, b["feats"], b["edge_feats"], b["senders"], b["receivers"], cfg, pol,
                edge_mask=edge_mask,
            )
        elif arch_id == "equiformer-v2":
            from repro.models.equiformer_v2 import equiformer_forward

            pred = equiformer_forward(
                params, b["feats"], b["pos"], b["senders"], b["receivers"], cfg, pol,
                edge_mask=edge_mask,
            )
        else:
            raise KeyError(arch_id)
        sq = jnp.sum(jnp.square(pred.astype(F32) - b["target"]), axis=-1)
        return (sq * b["node_mask"]).sum(), b["node_mask"].sum() * pred.shape[-1]

    return device_loss


def _gnn_halo_batch_abstract(
    arch_id: str, shape: ShapeSpec, cfg, plan, bsr_stats: dict | None = None
) -> dict:
    """Abstract batch in the HaloPlan blocked layout: per-node arrays are
    (k, n_local, …), per-edge arrays (k, e_local, …), plus the plan tables
    (flat: send_idx; hierarchical: the send_loc/send_rem tier pair).
    ``backend="bsr"`` GCN cells additionally carry the per-shard blocked
    adjacency tables, sized by `repro.dist.halo.plan_split_blocked_shape`
    (an interior triple over local columns plus a boundary triple over the
    halo-only columns — the overlapped schedule's pair) so no tile is ever
    materialized for abstract cells. A legacy single-table record (no
    "interior" key, `plan_blocked_shape`) sizes just the combined triple."""
    k, n_local, e_local = plan.k, plan.n_local, plan.e_local
    if plan.is_hierarchical:
        sloc, srem, sl, rl, ew = plan.abstract_inputs()
        send = {"send_loc": sloc, "send_rem": srem}
    else:
        si, sl, rl, ew = plan.abstract_inputs()
        send = {"send_idx": si}
    batch = {
        "feats": _sds((k, n_local, shape.d_feat), F32),
        **send,
        "senders": sl,
        "receivers": rl,
        "edge_w": ew,
    }
    if arch_id in ("egnn", "equiformer-v2"):
        batch["pos"] = _sds((k, n_local, 3), F32)
    if arch_id == "graphcast":
        batch["edge_feats"] = _sds((k, e_local, cfg.d_edge_in), F32)
    if arch_id == "coin_gcn":
        if bsr_stats is not None:
            if "interior" in bsr_stats:
                parts = (("interior", "bsr_"), ("boundary", "bsr_b"))
                tables = [(bsr_stats[tag], prefix) for tag, prefix in parts]
            else:
                tables = [(bsr_stats, "bsr_")]
            for st, prefix in tables:
                R, T, B = st["n_block_rows"], st["max_nnzb"], st["block"]
                batch[prefix + "vals"] = _sds((k, R, T, B, B), F32)
                batch[prefix + "cols"] = _sds((k, R, T), I32)
                batch[prefix + "lens"] = _sds((k, R), I32)
        batch["labels"] = _sds((k, n_local), I32)
        batch["label_mask"] = _sds((k, n_local), F32)
    else:
        n_out = cfg.n_vars if arch_id == "graphcast" else getattr(cfg, "d_out", 1)
        batch["target"] = _sds((k, n_local, n_out), F32)
        batch["node_mask"] = _sds((k, n_local), F32)
    return batch


def _gnn_halo_cell(
    spec: ArchSpec, shape: ShapeSpec, mesh, cfg, cost_cells, dtype=F32,
    payload: str | None = None,
) -> Cell:
    """Full-graph GNN train cell over the halo schedule (the default path).

    The whole step runs inside shard_map: each device holds one HaloPlan
    block and every layer's neighbor aggregation goes through boundary
    collectives via ``policy.neighbor_table`` (DESIGN.md §8). On a flat mesh
    the exchange runs over the "model" axis (``k·s_max`` received rows vs
    the broadcast schedule's ``(k−1)·n_local``); on a mesh with a ``pod``
    tier the graph shards over (pod, model) jointly and the exchange is the
    two-phase hierarchical collective — only deduplicated remote rows cross
    the inter-pod fabric (docs/communication.md).

    ``payload`` quantizes the wire (bf16/int8, dequantized on receive) and
    the coin_gcn cell runs the overlapped schedule: segment backend via the
    interior/boundary split aggregation, bsr backend via the split blocked
    tables of `plan_split_blocked_adjacency` — either way layer ℓ's
    boundary collective is consumed only by the boundary term, so XLA's
    latency-hiding scheduler overlaps it with interior compute
    (docs/communication.md "Overlapped schedule").
    """
    from repro.launch.mesh import halo_axes

    axes = halo_axes(mesh)
    hier = len(axes) > 1
    pods = mesh.shape["pod"] if hier else 1
    k = pods * mesh.shape["model"]
    spec_axes = axes if hier else "model"
    n_raw, e_raw = _gnn_sizes(shape, pad_mult=1)
    plan = _shape_halo_plan(n_raw, e_raw, k, pods)
    policy = sh.gnn_policy(mesh, batched=False, comm="halo", halo_payload=payload)
    bsr_stats = None
    if spec.arch_id == "coin_gcn" and getattr(cfg, "backend", "segment") == "bsr":
        from repro.dist.halo import plan_split_blocked_shape

        split = plan_split_blocked_shape(plan)
        st_i, st_b = split["interior"], split["boundary"]
        nnzb = st_i["nnz_blocks"] + st_b["nnz_blocks"]
        grid = k * (
            st_i["n_block_rows"] * st_i["max_nnzb"]
            + st_b["n_block_rows"] * st_b["max_nnzb"]
        )
        bsr_stats = {
            "block": st_i["block"],
            "nnz_blocks": nnzb,
            "padded_tile_fraction": 1.0 - nnzb / max(grid, 1),
            "overlap_fraction": split["overlap_fraction"],
            "interior": st_i,
            "boundary": st_b,
        }

    params_abs = _gnn_params(spec.arch_id, cfg, dtype)
    p_specs = sh.replicated_specs(params_abs)
    p_shard = sh.tree_named(mesh, p_specs)
    batch_abs = _gnn_halo_batch_abstract(spec.arch_id, shape, cfg, plan, bsr_stats)
    keys = sorted(batch_abs)
    batch_spec = {
        kk: sh.named(mesh, P(spec_axes, *([None] * (len(v.shape) - 1))))
        for kk, v in batch_abs.items()
    }
    device_loss = _gnn_halo_device_loss(spec.arch_id, cfg)

    def total_loss(params, batch):
        def body(*args):
            b = {kk: a[0] for kk, a in zip(keys, args)}
            if hier:
                pol = policy.bind_halo(send_loc=b["send_loc"], send_rem=b["send_rem"])
            else:
                pol = policy.bind_halo(b["send_idx"])
            wsum, wcnt = device_loss(params, b, pol)
            loss = jax.lax.psum(wsum, spec_axes) / jnp.maximum(
                jax.lax.psum(wcnt, spec_axes), 1.0
            )
            return loss[None]
        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(spec_axes),) * len(keys), out_specs=P(spec_axes),
            # pallas_call (the backend="bsr" blocked aggregation) has no
            # replication rule; psum-combined scalars make rep moot anyway.
            check_vma=False,
        )
        return f(*[batch[kk] for kk in keys]).mean()

    opt = adamw(lr=1e-3)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_shard = sh.tree_named(mesh, _opt_specs(opt_abs, p_specs))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(total_loss)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    note = (
        f"full graph (hier halo pods={pods} k={k} s_loc={plan.s_loc} "
        f"s_rem={plan.s_rem} n_local={plan.n_local})"
        if hier else
        f"full graph (halo k={k} s_max={plan.s_max} n_local={plan.n_local})"
    )
    if bsr_stats is not None:
        note += (
            f" bsr nnzb={bsr_stats['nnz_blocks']}"
            f" (int={bsr_stats['interior']['nnz_blocks']}"
            f" bnd={bsr_stats['boundary']['nnz_blocks']})"
            f" padfrac={bsr_stats['padded_tile_fraction']:.2f}"
        )
    if payload:
        note += f" payload={payload}"
    return Cell(
        spec.arch_id, shape.name, "train_step",
        train_step,
        (params_abs, opt_abs, batch_abs),
        (p_shard, o_shard, batch_spec),
        (p_shard, o_shard, sh.named(mesh, P())),
        model_flops=_gnn_flops(spec.arch_id, shape, cfg, bsr_stats) * 3.0,
        note=note,
        cost_cells=cost_cells,
        comm="halo",
        halo_plan=plan,
        bsr_stats=bsr_stats,
        halo_payload=payload,
        halo_overlap=policy.halo_overlap,
    )


def _gnn_cell(
    spec: ArchSpec, shape: ShapeSpec, mesh, dtype=F32,
    _as_cost_cell: bool = False, comm: str | None = None, optimized: bool = False,
    payload: str | None = None,
) -> Cell:
    import dataclasses as dc

    cfg = spec.make_config(shape)
    if (
        optimized and spec.arch_id == "coin_gcn" and shape.batch_nodes is None
        and comm != "broadcast"
    ):
        # §Perf: full-graph GCN aggregation on the ragged blocked MXU kernel
        # (DESIGN.md §2) instead of the segment-sum reference. Halo cells
        # only — they thread the per-shard blocked adjacency through the
        # batch; the broadcast escape hatch has no adjacency to feed bsr.
        cfg = dc.replace(cfg, backend="bsr")
    cost_cells = None
    big = (shape.n_edges or 0) > 2_000_000
    if (
        spec.arch_id == "equiformer-v2" and big and not _as_cost_cell
        and getattr(cfg, "edge_chunk", None) is None
    ):
        # Real program: 64 rolled chunks bound the (chunk, K, C) irrep tensor.
        # Cost cell: the unchunked variant — its HLO is fully counted by
        # cost_analysis (the rolled chunk scan body would be counted once).
        flat_spec = dc.replace(spec, make_config=lambda s=None, c=cfg: c)
        cost_cells = [
            (_gnn_cell(flat_spec, shape, mesh, dtype, _as_cost_cell=True, comm=comm), 1.0)
        ]
        cfg = dc.replace(cfg, edge_chunk=-(-shape.n_edges // 64))
    da = data_axes(mesh)
    n_data = int(np.prod([mesh.shape[a] for a in da]))
    msize = mesh.shape["model"]
    sampled = shape.batch_nodes is not None
    if comm is None:
        comm = "broadcast" if sampled else "halo"
    if not sampled and comm == "halo":
        return _gnn_halo_cell(spec, shape, mesh, cfg, cost_cells, dtype, payload=payload)
    n_blocks = n_data if sampled else None
    policy = NO_POLICY if sampled else sh.gnn_policy(mesh, batched=False, comm="broadcast")

    params_abs = _gnn_params(spec.arch_id, cfg, dtype)
    p_specs = sh.replicated_specs(params_abs)
    p_shard = sh.tree_named(mesh, p_specs)
    loss_fn = _gnn_loss_fn(
        spec.arch_id, cfg, policy, n_loss_nodes=shape.batch_nodes if sampled else None
    )
    batch_abs = _gnn_batch_abstract(spec.arch_id, shape, cfg, n_blocks, pad_mult=msize)

    if sampled:
        batch_spec = jax.tree_util.tree_map(
            lambda l: sh.named(mesh, P(da, *([None] * (len(l.shape) - 1)))), batch_abs
        )

        def total_loss(params, batch):
            losses = jax.vmap(lambda b: loss_fn(params, b))(batch)
            return jnp.mean(losses)
    else:
        def node_or_edge_spec(l):
            # Shard the big axis (nodes or edges) over `model`.
            return sh.named(mesh, P("model", *([None] * (len(l.shape) - 1))))

        batch_spec = jax.tree_util.tree_map(node_or_edge_spec, batch_abs)
        total_loss = loss_fn

    opt = adamw(lr=1e-3)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    o_shard = sh.tree_named(mesh, _opt_specs(opt_abs, p_specs))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(total_loss)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    # train = fwd + bwd ≈ 3× forward FLOPs; sampled cells run one block per
    # data shard, so FLOPs count block sizes, not the full graph.
    if sampled:
        blk = dataclasses.replace(
            shape,
            n_nodes=int(batch_abs["feats"].shape[1]) * n_blocks,
            n_edges=int(batch_abs["senders"].shape[1]) * n_blocks,
        )
        flops = _gnn_flops(spec.arch_id, blk, cfg) * 3.0
    else:
        flops = _gnn_flops(spec.arch_id, shape, cfg) * 3.0
    return Cell(
        spec.arch_id, shape.name, "train_step",
        train_step,
        (params_abs, opt_abs, batch_abs),
        (p_shard, o_shard, batch_spec),
        (p_shard, o_shard, sh.named(mesh, P())),
        model_flops=flops,
        note="sampled blocks ×%d" % (n_blocks or 1) if sampled else "full graph (broadcast)",
        cost_cells=cost_cells,
        comm=None if sampled else "broadcast",
    )


# ===================================================================== recsys
def _recsys_cell(spec: ArchSpec, shape: ShapeSpec, mesh, dtype=F32) -> Cell:
    from repro.models.deepfm import (
        deepfm_forward,
        deepfm_init,
        deepfm_loss,
        deepfm_retrieval,
    )

    cfg = spec.make_config(shape)
    da = data_axes(mesh)
    policy = sh.recsys_policy(mesh)
    params_abs = jax.eval_shape(lambda k: deepfm_init(k, cfg, dtype), jax.random.PRNGKey(0))
    p_specs = sh.recsys_param_specs(params_abs)
    p_shard = sh.tree_named(mesh, p_specs)
    mlp_flops = 2.0 * sum(
        a * b for a, b in zip(
            (cfg.n_fields * cfg.embed_dim, *cfg.mlp_dims), (*cfg.mlp_dims, 1)
        )
    )
    per_ex = mlp_flops + 4.0 * cfg.n_fields * cfg.embed_dim

    if shape.kind == "train":
        opt = adamw(lr=1e-3)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_shard = sh.tree_named(mesh, _opt_specs(opt_abs, p_specs))

        def train_step(params, opt_state, ids, labels):
            loss, grads = jax.value_and_grad(deepfm_loss)(params, ids, labels, cfg, policy)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, loss

        ids = _sds((shape.batch, cfg.n_fields), I32)
        labels = _sds((shape.batch,), F32)
        bspec = sh.named(mesh, P(da, None))
        return Cell(
            spec.arch_id, shape.name, "train_step",
            train_step,
            (params_abs, opt_abs, ids, labels),
            (p_shard, o_shard, bspec, sh.named(mesh, P(da))),
            (p_shard, o_shard, sh.named(mesh, P())),
            model_flops=3.0 * per_ex * shape.batch,
        )

    if shape.kind == "retrieval":
        def retrieval_step(params, user_ids, cand_ids):
            return deepfm_retrieval(params, user_ids, cand_ids, cfg, policy)

        user = _sds((shape.batch, cfg.n_fields), I32)
        cands = _sds((shape.batch, shape.n_candidates), I32)
        return Cell(
            spec.arch_id, shape.name, "serve_step",
            retrieval_step,
            (params_abs, user, cands),
            (p_shard, sh.named(mesh, P(None, None)), sh.named(mesh, P(None, "model"))),
            sh.named(mesh, P(None, "model")),
            model_flops=2.0 * shape.batch * shape.n_candidates * cfg.d_tower,
        )

    def serve_step(params, ids):
        return deepfm_forward(params, ids, cfg, policy)

    ids = _sds((shape.batch, cfg.n_fields), I32)
    big = shape.batch >= int(np.prod([mesh.shape[a] for a in da]))
    bspec = sh.named(mesh, P(da, None) if big else P(None, None))
    return Cell(
        spec.arch_id, shape.name, "serve_step",
        serve_step,
        (params_abs, ids),
        (p_shard, bspec),
        sh.named(mesh, P(da) if big else P()),
        model_flops=per_ex * shape.batch,
    )


# ==================================================================== factory
def build_cell(
    spec: ArchSpec, shape: ShapeSpec, mesh, optimized: bool = False,
    comm: str | None = None, payload: str | None = None,
) -> Cell:
    """optimized=True applies the §Perf findings (hierarchical MoE dispatch,
    remat on train, param/opt/cache donation) — the beyond-paper variants
    recorded separately from the baselines in EXPERIMENTS.md.

    comm selects the full-graph GNN communication schedule: None → the
    family default ("halo" for full-graph cells, DESIGN.md §8);
    "broadcast" → the paper-faithful layer-output all-gather escape hatch.
    Non-GNN families ignore it. For coin_gcn full-graph cells optimized=True
    also switches the aggregation to ``backend="bsr"`` (the ragged blocked
    MXU kernel, with the per-shard split blocked adjacency threaded through
    the halo batch). payload selects the halo wire format (None/"fp32" |
    "bf16" | "int8" — quantized boundary rows, dequantized on receive;
    docs/communication.md "Overlapped schedule"); halo cells only."""
    if spec.family == "lm":
        return _lm_cell(spec, shape, mesh, optimized=optimized)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape, mesh, comm=comm, optimized=optimized, payload=payload)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape, mesh)
    raise KeyError(spec.family)
