"""Serving driver: batched decode for LM archs, batched scoring for DeepFM,
and online GCN node-query serving with the hot-neighbor cache (DESIGN.md §9).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --tokens 32
    PYTHONPATH=src python -m repro.launch.serve --arch deepfm --requests 4
    PYTHONPATH=src python -m repro.launch.serve --arch coin-gcn --queries 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_arch
from repro.launch.obsflags import add_obs_args, obs_session


def serve_lm(spec, gen_tokens: int, batch: int = 4) -> None:
    from repro.models.transformer_lm import lm_decode_step, lm_init, lm_init_cache

    cfg = spec.make_reduced()
    params = lm_init(jax.random.PRNGKey(0), cfg)
    max_len = gen_tokens + 8
    cache = lm_init_cache(cfg, batch, max_len)
    decode = jax.jit(lm_decode_step, static_argnames=("cfg",))
    tok = jax.random.randint(jax.random.PRNGKey(1), (batch,), 0, cfg.vocab)
    t0 = time.perf_counter()
    for t in range(gen_tokens):
        logits, cache = decode(params, cache, tok, jnp.asarray(t, jnp.int32), cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    print(f"{spec.arch_id}: {batch}×{gen_tokens} tokens in {dt*1e3:.1f} ms "
          f"({batch*gen_tokens/dt:.0f} tok/s)")


def serve_recsys(spec, requests: int, batch: int = 512) -> None:
    from repro.models.deepfm import deepfm_forward, deepfm_init

    cfg = spec.make_reduced()
    params = deepfm_init(jax.random.PRNGKey(0), cfg)
    fwd = jax.jit(lambda p, ids: deepfm_forward(p, ids, cfg))
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, cfg.rows_per_field, (batch, cfg.n_fields)), jnp.int32)
    fwd(params, ids).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(requests):
        fwd(params, ids).block_until_ready()
    dt = (time.perf_counter() - t0) / requests
    print(f"deepfm: batch={batch} p50≈{dt*1e3:.2f} ms ({batch/dt:.0f} examples/s)")


def build_graph_engine(
    spec,
    batch_seeds: int = 8,
    fanout: int = 4,
    cache_capacity: int = 256,
    n_parts: int = 0,
    seed: int = 0,
    n_nodes: int = 2000,
    n_edges: int = 12000,
):
    """A small serving engine for a GNN arch on a citation-like graph.

    Returns (engine, graph). Shared by the CLI, the example, and the serve
    benchmark so they exercise one code path.
    """
    from repro.core.partition import partition_graph
    from repro.graph.generators import citation_like
    from repro.serve.graph import GraphBatcher

    cfg = spec.make_reduced()
    part = None
    if spec.arch_id == "coin_gcn":
        from repro.models.gcn import gcn_init

        d_in, n_out = cfg.layer_dims[0], cfg.layer_dims[-1]
        graph = citation_like(n_nodes, n_edges, d_in, n_out, seed=seed)
        params = gcn_init(jax.random.PRNGKey(seed), cfg)
        model = "gcn"
    elif spec.arch_id == "pna":
        from repro.models.pna import pna_init

        graph = citation_like(n_nodes, n_edges, cfg.d_in, 4, seed=seed)
        params = pna_init(jax.random.PRNGKey(seed), cfg)
        model = "pna"
    elif spec.arch_id == "egnn":
        from repro.models.egnn import egnn_init

        graph = citation_like(n_nodes, n_edges, cfg.d_in, 4, seed=seed, with_positions=True)
        params = egnn_init(jax.random.PRNGKey(seed), cfg)
        model = "egnn"
    else:
        raise SystemExit(f"{spec.arch_id}: graph serving supports coin_gcn/pna/egnn")
    if n_parts:
        part = partition_graph(graph.n_nodes, graph.edge_index, n_parts, method="bfs",
                               seed=seed, refine=True)
    engine = GraphBatcher(
        params, graph, cfg,
        model=model, batch_seeds=batch_seeds, fanout=fanout,
        # Activation injection (the cache's truncation hook) exists only in
        # the GCN serve forward; other archs serve cache-off.
        cache_capacity=cache_capacity if model == "gcn" else 0,
        partition=part, seed=seed,
    )
    return engine, graph


def serve_graph(
    spec,
    n_queries: int,
    batch_seeds: int = 8,
    fanout: int = 4,
    cache_capacity: int = 256,
    n_parts: int = 4,
    seed: int = 0,
    relocalize_threshold: float = 0.0,
) -> None:
    """Serve ``n_queries`` node-classification queries (degree-weighted, so
    hub neighborhoods are hot — the COIN access pattern) and report latency
    plus hot-neighbor-cache accounting.

    With ``relocalize_threshold`` > 0 a churn burst is injected halfway
    through the stream: each delta goes to both the engine
    (`apply_graph_delta`, scoped cache invalidation) and a mirrored
    `DeltaPlanner` whose `RelocalizePolicy` watches drift; when it fires,
    the engine adopts the re-localized partition (docs/communication.md §8).
    """
    from repro.serve.graph import hot_query_stream

    engine, graph = build_graph_engine(
        spec, batch_seeds=batch_seeds, fanout=fanout,
        cache_capacity=cache_capacity, n_parts=n_parts, seed=seed,
    )
    planner = None
    if relocalize_threshold > 0 and engine.partition is not None:
        from repro.dist.delta import DeltaPlanner, RelocalizePolicy

        planner = DeltaPlanner(
            engine.partition, graph.edge_index, graph_key="launch-serve",
            relocalize_policy=RelocalizePolicy(
                threshold=relocalize_threshold, patience=2, cooldown=3))
    nodes = hot_query_stream(graph, n_queries, seed=seed + 1)
    t0 = time.perf_counter()
    half = len(nodes) // 2 if planner is not None else len(nodes)
    for v in nodes[:half]:
        engine.submit(int(v))
    engine.run_until_drained()
    if planner is not None:
        fired = _serve_churn_burst(engine, planner, graph, seed)
        for v in nodes[half:]:
            engine.submit(int(v))
        engine.run_until_drained()
        drift = planner.locality_drift()["drift_ratio"]
        print(f"  maintenance: {fired} relocalization(s) over churn burst, "
              f"residual drift {drift:.3f}")
    dt = time.perf_counter() - t0
    s = engine.export_metrics()       # == stats(), mirrored into the registry
    print(
        f"{spec.arch_id}: {s['queries']} queries in {s['micro_batches']} micro-batches "
        f"({s['traces']} trace) in {dt*1e3:.1f} ms ({s['queries']/dt:.0f} q/s)"
    )
    print(
        f"  latency p50={s['p50_ms']:.2f} ms p99={s['p99_ms']:.2f} ms | "
        f"sampled {s['nodes_per_query']:.1f} nodes/q {s['edges_per_query']:.1f} edges/q"
        + (f" | foreign rows {s['foreign_rows']}" if n_parts else "")
    )
    if "cache" in s:
        c = s["cache"]
        print(
            f"  hot-neighbor cache: hit-rate {c['hit_rate']:.1%} "
            f"({c['hits']} hits / {c['misses']} misses), resident {c['resident']}/"
            f"{c['capacity']}, evictions {c['evictions']}, "
            f"rows saved {c['rows_saved']}, bytes saved {c['bytes_saved']/1e3:.1f} kB"
        )


def _serve_churn_burst(engine, planner, graph, seed: int, rounds: int = 8) -> int:
    """Apply ``rounds`` clustered churn deltas to engine AND planner; adopt
    the re-localized partition whenever the policy fires. Returns #fires."""
    from repro.dist.delta import GraphDelta

    churn = np.random.default_rng(seed + 2)
    fired = 0
    for _ in range(rounds):
        ei = planner.edge_index()
        m = max(ei.shape[1] // 50, 2)
        drop = churn.choice(ei.shape[1], m, replace=False)
        mem = churn.choice(graph.n_nodes, 24, replace=False)
        s = mem[churn.integers(0, mem.size, m)]
        d = mem[churn.integers(0, mem.size, m)]
        bad = s == d
        d[bad] = mem[(np.searchsorted(np.sort(mem), d[bad]) + 1) % mem.size]
        delta = GraphDelta(edge_inserts=np.stack([s, d]), edge_deletes=ei[:, drop])
        engine.apply_graph_delta(delta)
        rep = planner.apply(delta)
        if rep["relocalized"] is not None:
            fired += 1
            engine.adopt_partition(planner.part)
    return fired


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {', '.join(ALL_ARCHS)} (hyphen/underscore both fine)")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--queries", type=int, default=64, help="graph node queries to serve")
    ap.add_argument("--batch-seeds", type=int, default=8)
    ap.add_argument("--fanout", type=int, default=4)
    ap.add_argument("--cache-capacity", type=int, default=256)
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--parts", type=int, default=4, help="partition-aligned packing parts")
    ap.add_argument("--relocalize-threshold", type=float, default=0.0,
                    help="drift ratio beyond which a mid-stream churn burst "
                         "triggers online re-localization (0 = off; gnn only)")
    add_obs_args(ap)
    args = ap.parse_args(argv)
    spec = get_arch(args.arch)
    with obs_session(args):
        if spec.family == "lm":
            serve_lm(spec, args.tokens)
        elif spec.family == "recsys":
            serve_recsys(spec, args.requests)
        elif spec.family == "gnn":
            serve_graph(
                spec, args.queries,
                batch_seeds=args.batch_seeds, fanout=args.fanout,
                cache_capacity=0 if args.no_cache else args.cache_capacity,
                n_parts=args.parts,
                relocalize_threshold=args.relocalize_threshold,
            )
        else:
            raise SystemExit(
                f"{args.arch} is a training architecture; use repro.launch.train")


if __name__ == "__main__":
    main()
