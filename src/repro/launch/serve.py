"""Serving driver: batched decode for LM archs / batched scoring for DeepFM.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --tokens 32
    PYTHONPATH=src python -m repro.launch.serve --arch deepfm --requests 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_arch


def serve_lm(spec, gen_tokens: int, batch: int = 4) -> None:
    from repro.models.transformer_lm import lm_decode_step, lm_init, lm_init_cache

    cfg = spec.make_reduced()
    params = lm_init(jax.random.PRNGKey(0), cfg)
    max_len = gen_tokens + 8
    cache = lm_init_cache(cfg, batch, max_len)
    decode = jax.jit(lm_decode_step, static_argnames=("cfg",))
    tok = jax.random.randint(jax.random.PRNGKey(1), (batch,), 0, cfg.vocab)
    t0 = time.perf_counter()
    for t in range(gen_tokens):
        logits, cache = decode(params, cache, tok, jnp.asarray(t, jnp.int32), cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    print(f"{spec.arch_id}: {batch}×{gen_tokens} tokens in {dt*1e3:.1f} ms "
          f"({batch*gen_tokens/dt:.0f} tok/s)")


def serve_recsys(spec, requests: int, batch: int = 512) -> None:
    from repro.models.deepfm import deepfm_forward, deepfm_init

    cfg = spec.make_reduced()
    params = deepfm_init(jax.random.PRNGKey(0), cfg)
    fwd = jax.jit(lambda p, ids: deepfm_forward(p, ids, cfg))
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, cfg.rows_per_field, (batch, cfg.n_fields)), jnp.int32)
    fwd(params, ids).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(requests):
        fwd(params, ids).block_until_ready()
    dt = (time.perf_counter() - t0) / requests
    print(f"deepfm: batch={batch} p50≈{dt*1e3:.2f} ms ({batch/dt:.0f} examples/s)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args(argv)
    spec = get_arch(args.arch)
    if spec.family == "lm":
        serve_lm(spec, args.tokens)
    elif spec.family == "recsys":
        serve_recsys(spec, args.requests)
    else:
        raise SystemExit(f"{args.arch} is a training architecture; use repro.launch.train")


if __name__ == "__main__":
    main()
